"""Serving with GSE-SEM-quantized weights: one stored copy, pick your
precision per request class (the paper's storage/compute decoupling).

  PYTHONPATH=src python examples/serve_quantized.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import stepfns, transformer as T
from repro.quant import gse_tensor as Q


def main():
    cfg = configs.get_config("qwen3_4b", smoke=True)
    params, _ = T.init_params(cfg, jax.random.key(0))
    packed = Q.quantize_tree(params, k=8, min_size=2048)

    B, P, GEN = 4, 10, 6
    prompts = jax.random.randint(jax.random.key(1), (B, P), 0,
                                 cfg.vocab_size)

    def generate(p):
        state = T.decode_state_init(cfg, B, max_len=P + GEN)
        serve = jax.jit(stepfns.make_serve_step(cfg))
        tok = prompts[:, 0]
        outs = []
        for pos in range(P + GEN - 1):
            nxt, state = serve(p, state, tok, jnp.asarray(pos, jnp.int32))
            tok = prompts[:, pos + 1] if pos + 1 < P else nxt
            if pos >= P - 1:
                outs.append(np.asarray(nxt))
        return np.stack(outs, 1)

    ref = generate(params)
    print(f"{'precision':12s} {'weight MB':>10s} {'tokens match ref':>18s}")
    for tag in (3, 2, 1):
        served = Q.dequantize_tree(packed, tag=tag, dtype=jnp.float32)
        out = generate(served)
        match = (out == ref).mean()
        mb = Q.tree_bytes(packed, tag) / 1e6
        print(f"gse tag {tag:4d} {mb:10.2f} {match:17.0%}")
    bf16 = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16).astype(jnp.float32)
        if x.dtype == jnp.float32 else x, params)
    out = generate(bf16)
    mb = sum(x.size * 2 for x in jax.tree.leaves(params)) / 1e6
    print(f"{'bf16':12s} {mb:10.2f} {(out == ref).mean():17.0%}")


if __name__ == "__main__":
    main()
