"""End-to-end driver (paper-native): stepped mixed-precision GMRES.

Solves an asymmetric convection-diffusion system from one stored GSE-SEM
matrix, starting at 16-bit heads and stepping precision when the residual
stalls -- then compares against FP64 / FP16 / BF16 baselines (Tables
III/IV phenomenology).

  PYTHONPATH=src python examples/solve_stepped_gmres.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.precision import MonitorParams  # noqa: E402
from repro.sparse import generators as G  # noqa: E402
from repro.sparse.csr import pack_csr  # noqa: E402
from repro.sparse.spmv import spmv  # noqa: E402
from repro.solvers import (  # noqa: E402
    make_fixed_operator, make_gse_operator, solve_gmres,
)


def main():
    a = G.diag_rescale(G.convection_diffusion_2d(32, beta=5.0), 3.0, 7)
    rng = np.random.default_rng(7)
    x_true = rng.normal(size=a.shape[1])
    b = spmv(a, jnp.asarray(x_true))
    g = pack_csr(a, k=8)
    params = MonitorParams(t=40, l=60, m=30, rsd_limit=0.5,
                           reldec_limit=0.45)

    print(f"system: {a.shape[0]} unknowns, {a.nnz} non-zeros "
          f"(asymmetric, diag-rescaled 6 binades)\n")
    print(f"{'format':10s} {'converged':10s} {'iters':>7s} {'relres':>10s} "
          f"{'final tag':>9s}")
    for label, op in {
        "fp64": make_fixed_operator(a),
        "fp16": make_fixed_operator(a, store_dtype=jnp.float16),
        "bf16": make_fixed_operator(a, store_dtype=jnp.bfloat16),
        "gse-sem": make_gse_operator(g),
    }.items():
        res = solve_gmres(op, b, tol=1e-7, restart=80, maxiter=8000,
                          params=params)
        rr = float(res.relres)
        print(f"{label:10s} {str(bool(res.converged)):10s} "
              f"{int(res.iters):7d} {rr:10.2e} {int(res.tag):9d}"
              + (f"   switches at {res.switch_iters.tolist()}"
                 if label == "gse-sem" else ""))


if __name__ == "__main__":
    main()
