"""End-to-end LM training example with checkpoint/restart + GSE-SEM
gradient compression.

Defaults to a fast CPU-sized model; ``--model-100m`` trains a ~100M-param
granite-family config for a few hundred steps (slow on CPU, the shape a
TPU pod would run via launch/train.py).

  PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import dataclasses
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.train import build
from repro.models.config import ModelConfig


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="lm_100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32000,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--model-100m", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()

    cfg = model_100m() if args.model_100m else configs.get_config(
        "granite_3_2b", smoke=True)
    n_params = None

    state, step_fn = build(cfg, args.steps, lr=1e-3,
                           grad_compress=args.grad_compress)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params "
          f"(grad_compress={args.grad_compress})")

    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=8, seed=0,
                                    d_model=cfg.d_model))
    with tempfile.TemporaryDirectory() as ckdir:
        losses = []
        for step in range(args.steps):
            state, m = step_fn(state, pipe.batch_at(step))
            losses.append(float(m["loss"]))
            if step % 10 == 0:
                print(f"step {step:4d} loss {losses[-1]:.4f}")
            if (step + 1) % 25 == 0:
                ckpt.save_async(ckdir, state, step + 1)
        ckpt.wait_pending(ckdir)
        first, last = losses[0], sum(losses[-5:]) / 5
        print(f"\nloss: {first:.4f} -> {last:.4f} "
              f"({'LEARNING' if last < first else 'NOT LEARNING'})")
        saved = ckpt.latest_step(ckdir)
        print(f"latest checkpoint step: {saved}")


if __name__ == "__main__":
    main()
