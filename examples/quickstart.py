"""Quickstart: the GSE-SEM format in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import gse  # noqa: E402
from repro.sparse import generators as G  # noqa: E402
from repro.sparse.csr import iteration_stream_bytes, pack_csr  # noqa: E402
from repro.solvers import (  # noqa: E402
    make_gse_operator,
    make_jacobi,
    solve_cg,
    solve_ir,
    solve_pcg,
)
from repro.core.precision import MonitorParams  # noqa: E402


def main():
    # --- 1. pack a float vector against 8 shared exponents ---------------
    rng = np.random.default_rng(0)
    vals = rng.normal(size=4096) * np.exp2(rng.integers(-2, 3, 4096))
    packed = gse.pack(vals, k=8)
    print("shared exponents (unbiased):",
          (np.asarray(packed.table) - 1023).tolist())
    for tag, name in ((1, "head        16b"), (2, "head+tail1  32b"),
                      (3, "head+t1+t2  64b")):
        dec = gse.decode(packed, tag)
        rel = np.abs(dec - vals) / np.abs(vals)
        print(f"  tag {tag} ({name}): max rel err {rel.max():.3e}")

    # --- 2. one stored sparse matrix, three SpMV precisions --------------
    a = G.random_spd(2000, seed=1)
    g = pack_csr(a, k=8)
    print(f"\nCSR packed: {a.nnz} nnz")
    # Per-call byte accounting: what a tag-t SpMV actually streams from
    # HBM (values + packed colidx + rowptr/table).  The tag-specialized
    # kernels provably touch nothing else (DESIGN.md §2.4).
    print("  modeled SpMV bytes/nnz: "
          + " ".join(f"tag{t}={g.bytes_per_nnz(t)}" for t in (1, 2, 3))
          + f"  (fp64 CSR={a.bytes_per_nnz(jnp.float64)})")
    print("  modeled SpMV MB/call:   "
          + " ".join(f"tag{t}={g.bytes_touched(t)/1e6:.2f}"
                     for t in (1, 2, 3)))

    # --- 3. stepped mixed-precision CG (the paper's algorithm) -----------
    # Passing the GSECSR directly (instead of make_gse_operator(g))
    # selects the fused iteration path: one decoded-value pass per step
    # with the dots/axpys folded around the SpMV -- bit-identical
    # trajectory, fewer kernel launches (DESIGN.md §4).
    x_true = rng.normal(size=a.shape[1])
    from repro.sparse.spmv import spmv

    b = spmv(a, jnp.asarray(x_true))
    res = solve_cg(
        g, b, tol=1e-8, maxiter=3000,
        params=MonitorParams(t=40, l=60, m=30),
    )
    print(f"\nstepped CG (fused): converged={bool(res.converged)} "
          f"iters={int(res.iters)} final tag={int(res.tag)} "
          f"relres={float(res.relres):.2e} "
          f"switches at {res.switch_iters.tolist()}")
    err = np.abs(np.asarray(res.x) - x_true).max()
    print(f"solution max abs error vs truth: {err:.2e}")

    # The generic-operator path produces the same trajectory:
    res2 = solve_cg(
        make_gse_operator(g), b, tol=1e-8, maxiter=3000,
        params=MonitorParams(t=40, l=60, m=30),
    )
    agrees = (int(res2.iters) == int(res.iters)
              and float(res2.relres) == float(res.relres))
    print(f"unfused path agrees: {agrees} (iters={int(res2.iters)}, "
          f"relres={float(res2.relres):.2e})")

    # --- 4. preconditioned stepped CG on an ill-conditioned system ------
    # The GSE-packed Jacobi preconditioner is packed ONCE and applied at
    # the monitor's current tag -- same one-copy/three-precision storage
    # as the operator, so a tag-1 apply streams 2 bytes per stored entry
    # (DESIGN.md §10).
    ill = G.ill_conditioned_spd(32, decades=8.0, seed=0)
    gi = pack_csr(ill, k=8)
    mi = make_jacobi(ill, k=8)
    bi = spmv(ill, jnp.asarray(rng.normal(size=ill.shape[1])))
    fast = MonitorParams(t=30, l=30, m=15, rsd_limit=0.5, reldec_limit=0.45)
    res_cg = solve_cg(gi, bi, tol=1e-10, maxiter=30000, params=fast)
    res_pcg = solve_pcg(gi, bi, mi, tol=1e-10, maxiter=30000, params=fast)
    print(f"\nill-conditioned SPD (cond >= 1e6):")
    print(f"  stepped CG :          iters={int(res_cg.iters):5d} "
          f"converged={bool(res_cg.converged)}")
    print(f"  stepped PCG (jacobi): iters={int(res_pcg.iters):5d} "
          f"converged={bool(res_pcg.converged)}")
    print("  iteration stream bytes (matrix+precond): "
          + " ".join(f"tag{t}={iteration_stream_bytes(gi, t, mi)}"
                     for t in (1, 2, 3)))

    # --- 5. stepped iterative refinement (Carson-Khan shape) ------------
    # Outer loop: tag-3 residual + full-precision correction.  Inner loop:
    # loose stepped PCG that mostly stays on the cheap tags.
    res_ir = solve_ir(gi, bi, tol=1e-11, max_outer=10, inner="cg",
                      inner_tol=1e-4, inner_maxiter=4000, params=fast,
                      precond=mi)
    print(f"stepped IR: converged={res_ir.converged} "
          f"outer={res_ir.outer_iters} inner={res_ir.inner_iters} "
          f"true relres={res_ir.relres:.2e}")

    # --- 6. batched multi-RHS stepped solve (DESIGN.md section 11) -------
    # Four right-hand sides share ONE packed operand: the matrix segment
    # bytes are charged once per iteration (vector bytes per active
    # column) and each column runs its OWN monitor/tag schedule, bit-
    # identical to four independent solve_cg runs.  Columns deactivate
    # as they converge -- watch the per-column iteration counts differ.
    from repro.solvers import solve_cg_batched, batched_run_bytes

    B = jnp.stack([spmv(a, jnp.asarray(rng.normal(size=a.shape[1])))
                   for _ in range(4)], axis=1)
    res_b = solve_cg_batched(g, B, tol=1e-8, maxiter=3000,
                             params=MonitorParams(t=40, l=60, m=30))
    print(f"\nbatched stepped CG on {B.shape[1]} RHS (one shared operand):")
    for j in range(B.shape[1]):
        print(f"  col {j}: iters={int(res_b.iters[j]):4d} "
              f"tag={int(res_b.tag[j])} "
              f"relres={float(res_b.relres[j]):.2e} "
              f"switches at {res_b.switch_iters[j].tolist()}")
    run_b = batched_run_bytes(g, res_b.iters, res_b.switch_iters)
    naive = sum(
        int(batched_run_bytes(g, res_b.iters[j:j + 1],
                              res_b.switch_iters[j:j + 1]))
        for j in range(B.shape[1])
    )
    print(f"  modeled stream: {run_b / 1e6:.2f} MB batched vs "
          f"{naive / 1e6:.2f} MB as 4 independent runs "
          f"(matrix bytes charged once per iteration)")
    print("  per-iteration bytes: "
          + " ".join(f"nrhs={m}:{iteration_stream_bytes(g, 1, nrhs=m)}"
                     for m in (1, 4)))

    # --- 7. SELL-C-sigma layout: padding-honest bytes on skewed rows -----
    # Uniform ELL pads EVERY row to the longest row's width, so a few
    # dense rows blow up the streamed bytes for the whole matrix.  The
    # sliced layout (DESIGN.md section 12) sorts rows by length in
    # sigma-windows and pads each C-row slice only to its own width;
    # solver trajectories through it are bit-identical to the CSR
    # reference, only the traffic changes.
    from repro.kernels.ops import sell_pack_gsecsr
    from repro.sparse.csr import ell_layout

    sk = G.skewed_spd(512, seed=0)           # power-law rows + dense hubs
    gsk = pack_csr(sk, k=8)
    sell = sell_pack_gsecsr(gsk)             # cached on the instance
    ell = ell_layout(gsk)
    print(f"\nskewed matrix ({sk.nnz} nnz, widths {list(sell.widths)}):")
    print(f"  uniform ELL : padding_ratio={ell.padding_ratio:.3f} "
          f"tag-1 {ell.bytes_touched(1) / sk.nnz:.1f} B/nnz")
    print(f"  SELL-C-sigma: padding_ratio={sell.padding_ratio:.3f} "
          f"tag-1 {sell.bytes_touched(1) / sk.nnz:.1f} B/nnz")
    res_sell = solve_cg(sell, spmv(sk, jnp.ones((sk.shape[1],))),
                        tol=1e-8, maxiter=2000, params=fast)
    print(f"  solve_cg over the SELL pack: iters={int(res_sell.iters)} "
          f"relres={float(res_sell.relres):.2e} (bit-identical to CSR)")

    # --- 8. row-sharded distributed solve + tag-aware halo wire ----------
    # The same packed operator split across devices (DESIGN.md section
    # 13): each shard streams its row block through the same
    # tag-specialized decode, and only boundary x-entries cross the
    # interconnect -- at tag 1 as 2-byte GSE heads, at tag 2 head+tail1,
    # at tag 3 exact float64.  Needs > 1 device; on CPU run with
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 (the import
    # above already happened, so we only demo when devices exist).
    from repro.distributed.partition import partition_gsecsr

    shards = min(4, jax.device_count())
    ap = G.poisson2d(24)
    gp = pack_csr(ap, k=8)
    bp = spmv(ap, jnp.ones((ap.shape[1],)))
    part = partition_gsecsr(gp, shards)
    print(f"\ndistributed ({shards} shard(s), poisson 24^2):")
    print("  per-shard matrix bytes (tag 1):",
          list(part.shard_stream_bytes(1)),
          "+ shared", part.shared_stream_bytes(),
          "= single-device", iteration_stream_bytes(gp, 1))
    print("  halo wire bytes/SpMV: "
          + " ".join(f"tag{t}={part.halo_wire_bytes(t, 'gse')}"
                     for t in (1, 2, 3))
          + "  (exact wire: "
          + str(part.halo_wire_bytes(1, "exact")) + " at every tag)")
    # solve_cg dispatches on the partition: the whole loop runs sharded
    # under shard_map (psum dots, halo exchange per iteration).
    res_d = solve_cg(part, bp, tol=1e-8, maxiter=2000, params=fast)
    print(f"  sharded solve_cg: iters={int(res_d.iters)} "
          f"relres={float(res_d.relres):.2e} "
          f"(exact wire: trajectory matches single-device)")

    # --- 9. guardrails, fault injection, tag-escalation recovery --------
    # (DESIGN.md section 14) Every solve now carries a structured
    # ``health`` status, and in-loop guards watch for breakdown
    # (p.Ap <= 0), divergence, non-finite residuals, and stalls.  Inject
    # a deterministic fault that makes the operator indefinite at tag 1
    # ONLY: the guard trips on the first iteration, rolls back to the
    # last finite checkpoint, promotes the tag (byte-accounted in
    # switch_iters), and finishes the solve on the healthy rungs -- the
    # paper's one-copy/three-precision storage is what makes this
    # escalation free of any repacking.
    from repro.robustness.faults import make_tag_fault_operator
    from repro.robustness.guards import health_name

    bad = make_tag_fault_operator(gp, mode="indefinite", fail_tag=1)
    res_f = solve_cg(bad, bp, tol=1e-8, maxiter=2000, params=fast)
    print("\nfault injection + recovery (indefinite at tag 1):")
    print(f"  tripped at iter {int(res_f.trip_iter)}, escalated: "
          f"switches={np.asarray(res_f.switch_iters).tolist()} -> "
          f"final tag {int(res_f.tag)}")
    print(f"  recovered: converged={bool(res_f.converged)} "
          f"relres={float(res_f.relres):.2e} "
          f"health={health_name(int(res_f.health))}")
    # The same guards ride every loop for free -- the clean solve above
    # reports health too:
    print(f"  clean sharded solve health: "
          f"{health_name(int(res_d.health))} "
          f"(trip_iter={int(res_d.trip_iter)})")

    # --- 10. launch-plan autotuner + roofline ledger ---------------------
    # (DESIGN.md section 15) Every Pallas kernel launch resolves its
    # blocks through one dispatcher: explicit > tuned cache > the
    # historical (8, 128) default -- with an empty cache nothing changes,
    # bit for bit.  ``autotune.get_or_tune`` sweeps the launch axes
    # (BM/BL, SELL C/sigma, width buckets) for this operator's shape
    # class ONCE and persists the winner (checksum-verified JSON, like
    # the pack cache); ``planned_spmv`` then dispatches through it.  The
    # ledger prices what each call SHOULD stream, and the roofline probe
    # turns wall time into fraction-of-attainable -- the unit the CI
    # perf gates use instead of microseconds.  Run the full sweep with:
    #   PYTHONPATH=src python benchmarks/run.py --tune
    from repro.kernels.ops import planned_spmv
    from repro.perf import autotune, roofline
    from repro.perf.ledger import achieved, spmv_ledger
    from repro.perf.timing import best_seconds

    plan, report, hit = autotune.get_or_tune(gsk, tag=1, layout="sell")
    print(f"\nautotuned launch plan for the skewed operator "
          f"(cache hit: {hit}):")
    print(f"  default plan: {report['default_us']:8.1f} us/SpMV")
    print(f"  tuned plan  : {report['us']:8.1f} us/SpMV  "
          f"{plan.to_dict()}")
    xs = jnp.ones((gsk.shape[1],), jnp.float32)
    sec = best_seconds(planned_spmv, gsk, xs, tag=1, layout="sell",
                      iters=5, warmup=2)
    roof = roofline.host_roofline(quick=True)   # persisted probe
    led = spmv_ledger(gsk, tag=1,
                      layout=sell_pack_gsecsr(gsk, plan=plan))
    rates = achieved(led, sec, roof)
    print(f"  re-measured through the tuned dispatcher: "
          f"{rates['us']:.1f} us, {rates['achieved_gbps']:.2f} GB/s "
          f"physical ({rates['effective_gbps']:.2f} effective), "
          f"roofline fraction {rates['roofline_fraction']:.3f}")

    # --- 11. flight recorder, span tracing, metrics ----------------------
    # (DESIGN.md section 16) Pass ``flight=FlightParams(...)`` to any
    # solver and a device-side ring buffer records one row per iteration
    # -- iteration, relres, the tag the iteration RAN at, guard health,
    # alpha/beta/curvature -- with ZERO host syncs in-loop and a
    # bit-identical trajectory (the recorder only observes values the
    # iteration already computed).  Spans capture the host-side timeline
    # around pack/tune/solve/serve, and the metrics registry exposes
    # every counter the caches and the solve service keep.
    from repro.obs import FlightParams, FlightLog, capture
    from repro.obs import metrics as om

    with capture("/tmp/quickstart_trace.jsonl") as tracer:
        res_fl = solve_cg(gi, bi, tol=1e-10, maxiter=30000, params=fast,
                          flight=FlightParams(capacity=64))
    flog = FlightLog.from_state(res_fl.flight)
    print("\nflight recording of the ill-conditioned stepped CG "
          f"(last {len(flog)} of {flog.recorded} iterations):")
    print(flog.pretty(max_rows=6))
    print(f"  summary: {flog.summary()['switch_iters']} switches, "
          f"first unhealthy iter {flog.first_unhealthy()}")
    print(f"  span capture: {len(tracer.events)} events -> "
          "/tmp/quickstart_trace.jsonl")
    # The registry already holds the pack-cache counters from every
    # solve above; Prometheus exposition is one call:
    line = [ln for ln in om.REGISTRY.to_prometheus().splitlines()
            if ln.startswith("repro_pack_cache_events_total")][:2]
    print("  metrics excerpt: " + "; ".join(line))
    # The full observability sweep (bit-identity, overhead <= 1.10x,
    # serve latency percentiles) runs with:
    #   PYTHONPATH=src python benchmarks/run.py --quick --obs

    # --- 12. resilient async serving: chunks, deadlines, breakers --------
    # (DESIGN.md section 17) The async service runs every solve in
    # bounded CHUNKS of iterations -- bit-identical to the unchunked
    # solve -- so at each chunk boundary it can join new requests into a
    # running batch, enforce deadlines mid-solve (an expired request
    # returns its last checkpoint FLAGGED, never silently dropped), and
    # shed typed responses under overload instead of queueing unboundedly.
    from repro.serve import AsyncSolveService, BreakerParams, Shed

    svc = AsyncSolveService(slots=4, params=fast, chunk_iters=32,
                            queue_limit=4,
                            breaker=BreakerParams(fail_threshold=2))
    svc.register("spd", a)
    svc.register("ill", ill)
    ids = [svc.submit("spd", b, tol=1e-10) for _ in range(3)]
    # More than the queue admits: the overflow submissions come back as
    # typed sheds carrying a reason (and retry_after_s for breaker sheds).
    extra = [svc.submit("spd", b, tol=1e-10) for _ in range(4)]
    sheds = [r for r in extra if isinstance(r, Shed)]
    reports = svc.run_until_idle()
    print("\nasync serve: "
          f"{sum(reports[i.id].converged for i in ids)}/{len(ids)} "
          f"converged, {len(sheds)} shed "
          f"({sheds[0].reason if sheds else '-'}), max batch "
          f"{max(r.batch_size for r in reports.values())}")
    # A request with a deadline comes back at the next chunk boundary
    # after expiry -- flagged, with the freshest finite iterate:
    rid = svc.submit("ill", bi, tol=1e-14, deadline_s=1e-3)
    rep = svc.run_until_idle()[rid.id]
    print(f"  deadline demo: health={rep.health} "
          f"deadline_exceeded={rep.deadline_exceeded} after {rep.iters} "
          "iterations (solution = last checkpoint)")
    # Repeat right-hand sides warm-start from the LRU keyed on
    # (handle, crc32(b)); breaker trips/sheds land in the registry:
    print("  warm LRU: " + ", ".join(
        f"{k}={int(svc.warm[k])}" for k in ("hit", "miss", "store")))
    # The chaos traffic replay (pack/wire/operand faults, stalls,
    # bursts; 100% detection and zero unflagged non-finites) runs with:
    #   PYTHONPATH=src python benchmarks/run.py --quick --serve

    # --- 13. adaptive per-group precision: the tag axis as a MAP ---------
    # (DESIGN.md section 18) Everything so far moved ONE scalar tag for
    # the whole operator.  The tags= axis generalizes it to a per-group
    # TagMap: each block of 8 rows carries its own tag, entries decode at
    # max(row tag, col tag) -- the masked operand stays exactly symmetric
    # -- and bytes blend per entry.  tags="adaptive" plans the map from
    # the data: run cheap, measure which groups' decode floor blocks the
    # TRUE residual, promote exactly those, restart from the iterate.
    import dataclasses

    from repro.solvers.adaptive import solve_adaptive
    from repro.sparse.spmv import spmv_gse

    adl = G.ill_conditioned_spd(16, decades=8.0, seed=0)
    ga = pack_csr(adl, k=8)
    ma = int(ga.shape[0])
    ba = np.zeros(ma)
    ba[np.random.default_rng(7).choice(ma, 4, replace=False)] = 1.0
    ba = jnp.asarray(ba)
    tol = 2e-3
    bn = float(jnp.linalg.norm(ba))
    print("\nadaptive per-group precision (ill-conditioned SPD, "
          f"n={ma}, tol={tol:g}):")
    best_uniform = None
    for t in (1, 2, 3):
        # max_tag=t pins the monitor: a pure uniform tag-t schedule.
        r = solve_cg(ga, ba, tol=tol, maxiter=4000,
                     params=dataclasses.replace(fast, max_tag=t), tags=t)
        true = float(jnp.linalg.norm(
            ba - spmv_gse(ga, r.x, tag=3))) / bn
        # (iters+1) streams at tag t + one tag-3 pass for the true check.
        by = (int(r.iters) + 1) * ga.bytes_touched(t) + ga.bytes_touched(3)
        ok = true <= tol
        if ok and (best_uniform is None or by < best_uniform):
            best_uniform = by
        print(f"  uniform tag {t}: iters={int(r.iters):4d} "
              f"true relres={true:.2e} bytes={by / 1e6:7.2f} MB"
              + ("" if ok else "  (misses tol: tag-1 decode floor)"))
    res_ad = solve_adaptive(ga, ba, tol=tol, maxiter=4000)
    counts = {t: c for t, c in res_ad.tagmap.tag_counts().items() if c}
    print(f"  adaptive map : iters={res_ad.iters:4d} "
          f"true relres={res_ad.true_relres:.2e} "
          f"bytes={res_ad.spmv_bytes / 1e6:7.2f} MB  groups={counts}")
    print(f"  -> beats best uniform schedule by "
          f"{100 * (1 - res_ad.spmv_bytes / best_uniform):.1f}% of bytes "
          "at equal-or-better residual")
    # The same axis rides every entry point: solve_cg(..., tags=TagMap)
    # masks per group; the serve layer takes register/submit
    # tags="adaptive"; uniform maps are bit-identical to the int tag.
    # The gated comparison (incl. a skewed generator where the upfront
    # Neumann profile plans the map) runs with:
    #   PYTHONPATH=src python benchmarks/run.py --adaptive


if __name__ == "__main__":
    main()
