"""Quickstart: the GSE-SEM format in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import gse  # noqa: E402
from repro.sparse import generators as G  # noqa: E402
from repro.sparse.csr import pack_csr  # noqa: E402
from repro.solvers import make_gse_operator, solve_cg  # noqa: E402
from repro.core.precision import MonitorParams  # noqa: E402


def main():
    # --- 1. pack a float vector against 8 shared exponents ---------------
    rng = np.random.default_rng(0)
    vals = rng.normal(size=4096) * np.exp2(rng.integers(-2, 3, 4096))
    packed = gse.pack(vals, k=8)
    print("shared exponents (unbiased):",
          (np.asarray(packed.table) - 1023).tolist())
    for tag, name in ((1, "head        16b"), (2, "head+tail1  32b"),
                      (3, "head+t1+t2  64b")):
        dec = gse.decode(packed, tag)
        rel = np.abs(dec - vals) / np.abs(vals)
        print(f"  tag {tag} ({name}): max rel err {rel.max():.3e}")

    # --- 2. one stored sparse matrix, three SpMV precisions --------------
    a = G.random_spd(2000, seed=1)
    g = pack_csr(a, k=8)
    print(f"\nCSR packed: {a.nnz} nnz; bytes/nnz at tags 1/2/3 = "
          f"{g.nbytes(1)/a.nnz:.1f}/{g.nbytes(2)/a.nnz:.1f}/"
          f"{g.nbytes(3)/a.nnz:.1f} (+4 colidx)")

    # --- 3. stepped mixed-precision CG (the paper's algorithm) -----------
    x_true = rng.normal(size=a.shape[1])
    from repro.sparse.spmv import spmv

    b = spmv(a, jnp.asarray(x_true))
    res = solve_cg(
        make_gse_operator(g), b, tol=1e-8, maxiter=3000,
        params=MonitorParams(t=40, l=60, m=30),
    )
    print(f"\nstepped CG: converged={bool(res.converged)} "
          f"iters={int(res.iters)} final tag={int(res.tag)} "
          f"relres={float(res.relres):.2e} "
          f"switches at {res.switch_iters.tolist()}")
    err = np.abs(np.asarray(res.x) - x_true).max()
    print(f"solution max abs error vs truth: {err:.2e}")


if __name__ == "__main__":
    main()
