"""Quickstart: the GSE-SEM format in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import gse  # noqa: E402
from repro.sparse import generators as G  # noqa: E402
from repro.sparse.csr import pack_csr  # noqa: E402
from repro.solvers import make_gse_operator, solve_cg  # noqa: E402
from repro.core.precision import MonitorParams  # noqa: E402


def main():
    # --- 1. pack a float vector against 8 shared exponents ---------------
    rng = np.random.default_rng(0)
    vals = rng.normal(size=4096) * np.exp2(rng.integers(-2, 3, 4096))
    packed = gse.pack(vals, k=8)
    print("shared exponents (unbiased):",
          (np.asarray(packed.table) - 1023).tolist())
    for tag, name in ((1, "head        16b"), (2, "head+tail1  32b"),
                      (3, "head+t1+t2  64b")):
        dec = gse.decode(packed, tag)
        rel = np.abs(dec - vals) / np.abs(vals)
        print(f"  tag {tag} ({name}): max rel err {rel.max():.3e}")

    # --- 2. one stored sparse matrix, three SpMV precisions --------------
    a = G.random_spd(2000, seed=1)
    g = pack_csr(a, k=8)
    print(f"\nCSR packed: {a.nnz} nnz")
    # Per-call byte accounting: what a tag-t SpMV actually streams from
    # HBM (values + packed colidx + rowptr/table).  The tag-specialized
    # kernels provably touch nothing else (DESIGN.md §2.4).
    print("  modeled SpMV bytes/nnz: "
          + " ".join(f"tag{t}={g.bytes_per_nnz(t)}" for t in (1, 2, 3))
          + f"  (fp64 CSR={a.bytes_per_nnz(jnp.float64)})")
    print("  modeled SpMV MB/call:   "
          + " ".join(f"tag{t}={g.bytes_touched(t)/1e6:.2f}"
                     for t in (1, 2, 3)))

    # --- 3. stepped mixed-precision CG (the paper's algorithm) -----------
    # Passing the GSECSR directly (instead of make_gse_operator(g))
    # selects the fused iteration path: one decoded-value pass per step
    # with the dots/axpys folded around the SpMV -- bit-identical
    # trajectory, fewer kernel launches (DESIGN.md §4).
    x_true = rng.normal(size=a.shape[1])
    from repro.sparse.spmv import spmv

    b = spmv(a, jnp.asarray(x_true))
    res = solve_cg(
        g, b, tol=1e-8, maxiter=3000,
        params=MonitorParams(t=40, l=60, m=30),
    )
    print(f"\nstepped CG (fused): converged={bool(res.converged)} "
          f"iters={int(res.iters)} final tag={int(res.tag)} "
          f"relres={float(res.relres):.2e} "
          f"switches at {res.switch_iters.tolist()}")
    err = np.abs(np.asarray(res.x) - x_true).max()
    print(f"solution max abs error vs truth: {err:.2e}")

    # The generic-operator path produces the same trajectory:
    res2 = solve_cg(
        make_gse_operator(g), b, tol=1e-8, maxiter=3000,
        params=MonitorParams(t=40, l=60, m=30),
    )
    agrees = (int(res2.iters) == int(res.iters)
              and float(res2.relres) == float(res.relres))
    print(f"unfused path agrees: {agrees} (iters={int(res2.iters)}, "
          f"relres={float(res2.relres):.2e})")


if __name__ == "__main__":
    main()
