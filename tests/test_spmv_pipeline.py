"""Tests for the tag-specialized SpMV pipeline + fused stepped-CG path.

Covers the PR-1 acceptance criteria:

  * per-tag kernel parity vs kernels/ref.py across tags 1/2/3 and
    ei_bit in {1, 3} (k = 2 / 8 shared exponents);
  * the tag-1/-2 ``pallas_call``s provably omit the unused tail operands
    (jaxpr operand-count inspection);
  * fused-CG (``solve_cg`` with a ``GSECSR`` operand) agrees with the
    unfused path bit-for-bit on an SPD suite;
  * ``bytes_touched`` accounting: tag-1 < tag-2 < tag-3 and tag-1 is
    ~6 bytes/nnz (2 head + 4 colpak).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import core as jcore

from repro.core import precision as P
from repro.core.gse import pack
from repro.kernels import ops, ref
from repro.kernels.gse_spmv import (
    LANE,
    gse_spmv_call,
    spmv_operand_names,
)
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.solvers import make_gse_operator, solve_cg


# ---------------------------------------------------------------------------
# Per-tag kernel parity vs ref, across ei_bit (shared-exponent count k)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 8])  # ei_bit 1 / 3
@pytest.mark.parametrize("tag", [1, 2, 3])
def test_tag_specialized_kernel_parity(k, tag):
    a = G.random_spd(500, seed=10 * k + tag)
    g = pack_csr(a, k=k)
    assert g.ei_bit == {2: 1, 8: 3}[k]
    ell = ops.ell_pack_gsecsr(g, lane=128)
    x = jnp.asarray(
        np.random.default_rng(tag).normal(size=a.shape[1]), jnp.float32
    )
    out = ops.gse_spmv_ell(ell, g.table, x, g.ei_bit, tag=tag)
    want = ref.spmv_ell_ref(*ell, g.table, x, g.ei_bit, tag)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=1e-4)


@pytest.mark.parametrize("tag", [1, 2, 3])
def test_kernel_lane_blocks_sweep(tag):
    """Wider BL tiles hit the multi-sublane-group reduction path."""
    a = G.poisson2d(16)
    g = pack_csr(a, k=8)
    ell = ops.ell_pack_gsecsr(g, lane=256)
    x = jnp.asarray(np.random.default_rng(0).normal(size=a.shape[1]),
                    jnp.float32)
    want = ref.spmv_ell_ref(*ell, g.table, x, g.ei_bit, tag)
    for blocks in [(8, 128), (8, 256), (16, 256)]:
        out = ops.gse_spmv_ell(ell, g.table, x, g.ei_bit, tag=tag,
                               blocks=blocks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# Operand-count inspection: unused tails never enter the pallas_call
# ---------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            if isinstance(v, jcore.ClosedJaxpr):
                yield from _iter_eqns(v.jaxpr)
            elif isinstance(v, jcore.Jaxpr):
                yield from _iter_eqns(v)


def _pallas_call_invars(tag):
    m, L, n, nk, ei = 8, 128, 64, 8, 3
    colpak = jnp.zeros((m, L), jnp.uint32)
    head = jnp.zeros((m, L), jnp.uint16)
    tail1 = jnp.zeros((m, L), jnp.uint16)
    tail2 = jnp.zeros((m, L), jnp.uint32)
    x = jnp.zeros((n,), jnp.float32)
    scales = jnp.ones((1, nk), jnp.float32)
    operands = {
        1: (colpak, head, None, None),
        2: (colpak, head, tail1, None),
        3: (colpak, head, tail1, tail2),
    }[tag]
    fn = functools.partial(gse_spmv_call, *operands, x, scales,
                           ei_bit=ei, tag=tag, interpret=True)
    jaxpr = jax.make_jaxpr(fn)()
    eqns = [e for e in _iter_eqns(jaxpr.jaxpr)
            if e.primitive.name == "pallas_call"]
    assert len(eqns) == 1, "expected exactly one pallas_call"
    return eqns[0].invars


@pytest.mark.parametrize("tag,n_operands", [(1, 4), (2, 5), (3, 6)])
def test_pallas_call_operand_count_per_tag(tag, n_operands):
    """tag-1 streams scales/colpak/head/x only; tag-2 adds tail1; tag-3
    adds tail2 -- asserted on the actual pallas_call jaxpr equation."""
    invars = _pallas_call_invars(tag)
    assert len(invars) == n_operands
    assert len(spmv_operand_names(tag)) == n_operands


def test_tag1_and_tag2_omit_tail_dtypes():
    """No u32 (M,L) tail2 operand at tags 1/2; no u16 tail at tag 1.

    The segment arrays are distinguishable by dtype: colpak u32, head u16,
    tail1 u16, tail2 u32, x/scales f32.  A (8,128) u32 operand besides
    colpak would be tail2; a second u16 would be tail1.
    """
    def dtypes(tag):
        return sorted(str(v.aval.dtype) for v in _pallas_call_invars(tag))

    assert dtypes(1) == ["float32", "float32", "uint16", "uint32"]
    assert dtypes(2) == ["float32", "float32", "uint16", "uint16", "uint32"]
    assert dtypes(3) == ["float32", "float32", "uint16", "uint16", "uint32",
                         "uint32"]


def test_spmv_dispatch_cache_is_stable():
    k1 = ops.spmv_kernel_for(1, 3, (8, 128), True)
    k2 = ops.spmv_kernel_for(1, 3, (8, 128), True)
    assert k1 is k2
    assert ops.spmv_kernel_for(2, 3, (8, 128), True) is not k1


def test_output_is_lane_reduced_vector():
    """The widened (BM, LANE) accumulator reduces back to a (M,) vector."""
    a = G.poisson2d(8)
    g = pack_csr(a, k=8)
    ell = ops.ell_pack_gsecsr(g, lane=LANE)
    x = jnp.ones((a.shape[1],), jnp.float32)
    out = ops.gse_spmv_ell(ell, g.table, x, g.ei_bit, tag=1)
    assert out.shape == (a.shape[0],)


# ---------------------------------------------------------------------------
# bytes_touched accounting
# ---------------------------------------------------------------------------

def test_bytes_touched_ladder():
    a = G.random_spd(400, seed=3)
    g = pack_csr(a, k=8)
    assert g.bytes_touched(1) < g.bytes_touched(2) < g.bytes_touched(3)
    assert (g.bytes_per_nnz(1), g.bytes_per_nnz(2), g.bytes_per_nnz(3)) == (
        6, 8, 12
    )
    # tag-1 ~ 6 bytes/nnz: 2 head + 4 colpak (+ small rowptr/table overhead)
    per_nnz = g.bytes_touched(1) / g.nnz
    assert 6.0 <= per_nnz < 6.5
    # FP64 CSR baseline: 8 value + 4 colidx = 12.  Tag 3 streams the same
    # per-nnz bytes plus the (tiny) shared-exponent table.
    assert a.bytes_per_nnz(jnp.float64) == 12
    assert a.bytes_per_nnz(jnp.float16) == 6
    assert g.bytes_touched(3) == (
        a.bytes_touched(jnp.float64) + g.table.size * 4
    )


def test_gsepacked_bytes_touched_matches_nbytes():
    p = pack(np.random.default_rng(0).normal(size=256), 8)
    for tag in (1, 2, 3):
        assert p.bytes_touched(tag) == p.nbytes(tag)
    assert p.bytes_touched(1) < p.bytes_touched(2) < p.bytes_touched(3)


# ---------------------------------------------------------------------------
# Fused CG == unfused CG, bit for bit
# ---------------------------------------------------------------------------

def _fast_params(**kw):
    d = dict(t=30, l=30, m=15, rsd_limit=0.5, reldec_limit=0.45)
    d.update(kw)
    return P.MonitorParams(**d)


def _b_for(a, seed=0):
    rng = np.random.default_rng(seed)
    from repro.sparse.spmv import spmv

    return jnp.asarray(np.asarray(spmv(a, jnp.asarray(
        rng.normal(size=a.shape[1])))))


@functools.lru_cache(maxsize=1)
def _stalling_spd():
    """SPD with eigenvalues down to 1e-6: the tag-1 decode error perturbs
    the small eigenvalues, so head-only CG genuinely stalls and the
    controller must step up (same construction as test_solvers)."""
    from repro.sparse.csr import from_coo

    rng = np.random.default_rng(7)
    n = 200
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    eigs = np.logspace(-6, 0, n)
    dense = (q * eigs) @ q.T
    dense = 0.5 * (dense + dense.T)
    rows, cols = np.nonzero(np.ones((n, n)))
    a = from_coo(rows, cols, dense[rows, cols], (n, n))
    return a, dense


def _spd_suite():
    yield "poisson2d_16", G.poisson2d(16), {}
    yield "random_spd_500", G.random_spd(500, seed=1), {}


@pytest.mark.parametrize("case", list(_spd_suite()), ids=lambda c: c[0])
def test_fused_cg_matches_unfused_trajectory(case):
    name, a, kw = case
    g = pack_csr(a, k=8)
    b = _b_for(a, seed=len(name))
    args = dict(tol=1e-8, maxiter=3000, params=_fast_params())
    args.update(kw)
    unfused = solve_cg(make_gse_operator(g), b, **args)
    fused = solve_cg(g, b, **args)
    assert int(fused.iters) == int(unfused.iters)
    assert abs(float(fused.relres) - float(unfused.relres)) <= 1e-12 * max(
        float(unfused.relres), 1.0
    )
    assert int(fused.tag) == int(unfused.tag)
    np.testing.assert_array_equal(np.asarray(fused.switch_iters),
                                  np.asarray(unfused.switch_iters))
    np.testing.assert_allclose(np.asarray(fused.x), np.asarray(unfused.x),
                               rtol=1e-12, atol=1e-14)


def test_fused_cg_steps_tags_and_matches_unfused():
    """On a genuinely stalling system the fused path must step tags at the
    same iterations as the unfused path and still converge."""
    a, dense = _stalling_spd()
    g = pack_csr(a, k=8)
    b = jnp.asarray(dense @ np.random.default_rng(7).normal(size=a.shape[1]))
    args = dict(tol=1e-8, maxiter=20000,
                params=_fast_params(t=60, l=60, m=30))
    fused = solve_cg(g, b, **args)
    assert bool(fused.converged)
    assert int(fused.tag) >= 2  # the stepped controller actually stepped
    assert int(fused.switch_iters[0]) > 0
    unfused = solve_cg(make_gse_operator(g), b, **args)
    assert int(fused.iters) == int(unfused.iters)
    np.testing.assert_array_equal(np.asarray(fused.switch_iters),
                                  np.asarray(unfused.switch_iters))


def test_fused_cg_final_correction():
    a = G.random_spd(800, seed=4)
    g = pack_csr(a, k=8)
    b = _b_for(a, seed=4)
    res = solve_cg(g, b, tol=1e-6, maxiter=6000, params=_fast_params(),
                   final_correction=True)
    from repro.solvers import gse_matvec

    true_rel = jnp.linalg.norm(b - gse_matvec(g, res.x, jnp.int32(3)))
    true_rel = float(true_rel / jnp.linalg.norm(b))
    assert true_rel < 5e-6
