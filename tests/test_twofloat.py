"""Two-float arithmetic vs native f64 (TPU FP64-surrogate validation)."""
import jax.numpy as jnp
import numpy as np

from repro.core import twofloat as tf


def test_two_sum_exact():
    a = jnp.asarray(1.0, jnp.float32)
    b = jnp.asarray(1e-8, jnp.float32)
    s, e = tf.two_sum(a, b)
    assert float(jnp.float64(s) + jnp.float64(e)) == 1.0 + 1e-8


def test_two_prod_exact_f32():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0.5, 2.0, 256), jnp.float32)
    b = jnp.asarray(rng.uniform(0.5, 2.0, 256), jnp.float32)
    p, e = tf.two_prod(a, b)
    exact = np.asarray(a, np.float64) * np.asarray(b, np.float64)
    got = np.asarray(p, np.float64) + np.asarray(e, np.float64)
    np.testing.assert_array_equal(got, exact)


def test_df_dot_beats_naive_f32():
    rng = np.random.default_rng(1)
    n = 50000
    a = rng.uniform(-1, 1, n)
    b = rng.uniform(-1, 1, n)
    exact = np.dot(a, b)  # f64 reference
    a32, b32 = jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)
    naive = float(jnp.dot(a32, b32))
    hi, lo = tf.df_dot(a32, b32)
    comp = float(jnp.float64(hi) + jnp.float64(lo))
    assert abs(comp - exact) <= abs(naive - exact)
    assert abs(comp - exact) / abs(exact) < 1e-6


def test_df_add_mul_roundtrip():
    ahi, alo = tf.df_from(jnp.asarray(1.0, jnp.float32))
    bhi, blo = tf.df_from(jnp.asarray(3.0, jnp.float32))
    shi, slo = tf.df_add(ahi, alo, bhi, blo)
    assert float(tf.df_to(shi, slo)) == 4.0
    phi, plo = tf.df_mul(ahi, alo, bhi, blo)
    assert float(tf.df_to(phi, plo)) == 3.0
