"""Tests for the stepped-precision controller (paper Section III.D)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import precision as P


def _feed(params, residuals):
    st = P.init(params)
    tags = []
    for r in residuals:
        st = P.record(st, jnp.asarray(r, jnp.float64))
        st = P.update_tag(st, params)
        tags.append(int(st.tag))
    return st, tags


def test_no_switch_before_l():
    params = P.MonitorParams(t=10, l=100, m=10)
    # Perfectly flat residual would trigger C3 -- but not before l.
    _, tags = _feed(params, [1.0] * 99)
    assert all(t == 1 for t in tags)


def test_c3_fires_on_flat_residual():
    params = P.MonitorParams(t=10, l=20, m=10)
    st, tags = _feed(params, [1.0] * 40)
    assert tags[-1] >= 2  # flat -> nDec == 0 -> step up


def test_no_switch_on_healthy_convergence():
    params = P.MonitorParams(t=10, l=20, m=10, rsd_limit=10.0, reldec_limit=0.01)
    # Residual falling 5%/iter: nDec==t-1, relDec large -> no condition fires.
    resid = [0.95 ** i for i in range(60)]
    _, tags = _feed(params, resid)
    assert all(t == 1 for t in tags)


def test_c2_fires_on_slow_decrease():
    params = P.MonitorParams(t=10, l=20, m=10, rsd_limit=10.0, reldec_limit=0.4)
    # Residual falling but only ~1e-4 per window -> relDec < 0.4.
    resid = [1.0 - 1e-5 * i for i in range(60)]
    _, tags = _feed(params, resid)
    assert tags[-1] >= 2


def test_c1_fires_on_oscillation():
    params = P.MonitorParams(t=10, l=20, m=10, rsd_limit=0.05, reldec_limit=0.0)
    rng = np.random.default_rng(0)
    resid = list(1.0 + 0.5 * rng.standard_normal(60) ** 2)
    _, tags = _feed(params, resid)
    assert tags[-1] >= 2


def test_tag_caps_at_max():
    params = P.MonitorParams(t=4, l=4, m=4, max_tag=3)
    _, tags = _feed(params, [1.0] * 200)
    assert tags[-1] == 3


def test_metrics_values():
    params = P.MonitorParams(t=4)
    st = P.init(params)
    for r in [4.0, 3.0, 2.0, 1.0]:
        st = P.record(st, jnp.asarray(r, jnp.float64))
    rsd, ndec, reldec = P.metrics(st)
    assert int(ndec) == 3
    assert float(reldec) == (4.0 - 1.0) / 4.0
    w = np.array([4, 3, 2, 1.0])
    assert np.isclose(float(rsd), w.std() / w.mean())


def test_ring_buffer_ordering_after_wrap():
    params = P.MonitorParams(t=4)
    st = P.init(params)
    for r in [9.0, 8.0, 7.0, 4.0, 3.0, 2.0, 1.0]:  # wraps
        st = P.record(st, jnp.asarray(r, jnp.float64))
    _, ndec, reldec = P.metrics(st)
    assert int(ndec) == 3
    assert float(reldec) == (4.0 - 1.0) / 4.0


def test_jittable_inside_while_loop():
    params = P.MonitorParams(t=8, l=8, m=8)

    def body(carry):
        i, st = carry
        st = P.record(st, jnp.asarray(1.0, jnp.float64))
        st = P.update_tag(st, params)
        return i + 1, st

    def cond(carry):
        return carry[0] < 50

    _, st = jax.lax.while_loop(cond, body, (jnp.int32(0), P.init(params)))
    assert int(st.tag) >= 2


def test_rsd_finite_in_float32_window():
    """Regression: the RSD division guard used the literal 1e-300, which
    underflows to 0 in a float32 history buffer -- an all-equal (or tiny)
    residual window then divided 0/0 into a NaN RSD, and NaN > rsd_limit
    is False, silently disabling switch condition C1."""
    params = P.MonitorParams(t=8, l=8, m=8)
    st = P.init(params, dtype=jnp.float32)
    for _ in range(8):
        st = P.record(st, jnp.asarray(0.0, jnp.float32))
    rsd, ndec, _ = P.metrics(st)
    assert np.isfinite(float(rsd))
    # The all-zero window must still step the tag (via C3 here; the point
    # is that the metrics pipeline stays NaN-free so conditions evaluate).
    st2 = P.update_tag(st, params)
    assert int(st2.tag) == 2


def test_rsd_finite_for_tiny_float32_residuals():
    params = P.MonitorParams(t=4, l=4, m=4)
    st = P.init(params, dtype=jnp.float32)
    for _ in range(4):
        # Subnormal-adjacent values whose mean underflows the old guard.
        st = P.record(st, jnp.asarray(1e-38, jnp.float32))
    rsd, _, _ = P.metrics(st)
    assert np.isfinite(float(rsd))
