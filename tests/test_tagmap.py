"""PR 10: the per-group tag-map precision axis (DESIGN.md §18).

Three contracts, each load-bearing for the refactor:

1. **Uniform identity** -- a uniform :class:`TagMap` (and the legacy int
   shim) is THE SAME precision axis as ``init_tag``: bit-identical
   trajectories across solver families, layouts, and batch widths.
2. **Per-group decode parity** -- the masked operand decoded with the
   map's MAX-tag formula is bitwise what a per-entry-tag decode
   produces (the "no new kernel bodies" claim).
3. **Blended byte model** -- ``bytes_touched(tagmap)`` and its
   distributed twins are exact hand-computable blends, with the
   redistribution identity preserved.

Property-based sweeps are guarded by ``pytest.importorskip`` so tier-1
collection never needs hypothesis.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as P
from repro.core.tagmap import GROUP_SIZE, TagMap, normalize_tags
from repro.kernels import ops, ref
from repro.solvers.batched import solve_cg_batched, solve_pcg_batched
from repro.solvers.cg import solve_cg, solve_pcg
from repro.solvers.ir import solve_ir
from repro.solvers.precond import make_jacobi
from repro.sparse import generators as G
from repro.sparse.csr import iteration_stream_bytes, pack_csr
from repro.sparse.spmv import spmv


def _sys(n=10, seed=0):
    a = G.poisson2d(n)
    g = pack_csr(a, k=8)
    rng = np.random.default_rng(seed)
    b = jnp.asarray(np.asarray(spmv(a, jnp.asarray(
        rng.normal(size=a.shape[1])))))
    return a, g, b


def _fast_params(**kw):
    d = dict(t=30, l=30, m=15, rsd_limit=0.5, reldec_limit=0.45)
    d.update(kw)
    return P.MonitorParams(**d)


def _mixed_map(m, lo=1, hi=2, period=3):
    """Deterministic non-uniform map: every ``period``-th group at ``hi``."""
    ng = -(-m // GROUP_SIZE)
    tags = np.full(ng, lo, np.uint8)
    tags[::period] = hi
    return TagMap(tags)


# ---------------------------------------------------------------------------
# The legacy shim: normalize_tags
# ---------------------------------------------------------------------------

def test_normalize_tags_shim():
    m = 64
    assert normalize_tags(None) is None
    assert normalize_tags(2, m) == 2
    # A uniform map IS the int tag (the legacy fast path).
    assert normalize_tags(TagMap.for_rows(m, 3), m) == 3
    tm = _mixed_map(m)
    assert normalize_tags(tm, m) is tm
    with pytest.raises(ValueError):
        normalize_tags(0, m)
    with pytest.raises(ValueError):
        normalize_tags(4, m)
    with pytest.raises(ValueError):
        normalize_tags(TagMap.for_rows(8, 1), m)  # too few groups for m


# ---------------------------------------------------------------------------
# Contract 1: uniform TagMap / int tags == init_tag, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tag", [1, 2, 3])
def test_uniform_identity_cg_fused(tag):
    _, g, b = _sys()
    m = int(g.shape[0])
    ref_res = solve_cg(g, b, tol=1e-8, maxiter=2000, params=_fast_params(),
                       init_tag=tag)
    for axis in (tag, TagMap.for_rows(m, tag)):
        res = solve_cg(g, b, tol=1e-8, maxiter=2000, params=_fast_params(),
                       tags=axis)
        np.testing.assert_array_equal(np.asarray(res.x),
                                      np.asarray(ref_res.x))
        assert int(res.iters) == int(ref_res.iters)
        assert int(res.tag) == int(ref_res.tag)


def test_uniform_identity_cg_generic_operator():
    from repro.solvers import make_gse_operator

    _, g, b = _sys(seed=1)
    m = int(g.shape[0])
    op = make_gse_operator(g)
    ref_res = solve_cg(op, b, tol=1e-8, maxiter=2000, params=_fast_params(),
                       init_tag=2)
    res = solve_cg(op, b, tol=1e-8, maxiter=2000, params=_fast_params(),
                   tags=TagMap.for_rows(m, 2))
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref_res.x))
    assert int(res.iters) == int(ref_res.iters)


def test_uniform_identity_pcg_fused():
    a, g, b = _sys(seed=2)
    m = int(g.shape[0])
    pre = make_jacobi(a, k=8)
    ref_res = solve_pcg(g, b, pre, tol=1e-8, maxiter=2000,
                        params=_fast_params(), init_tag=2)
    for axis in (2, TagMap.for_rows(m, 2)):
        res = solve_pcg(g, b, pre, tol=1e-8, maxiter=2000,
                        params=_fast_params(), tags=axis)
        np.testing.assert_array_equal(np.asarray(res.x),
                                      np.asarray(ref_res.x))
        assert int(res.iters) == int(ref_res.iters)


def test_uniform_identity_sell_layout():
    _, g, b = _sys(seed=3)
    m = int(g.shape[0])
    sell = ops.sell_pack_gsecsr(g)
    ref_res = solve_cg(sell, b, tol=1e-8, maxiter=2000,
                       params=_fast_params(), init_tag=1)
    res = solve_cg(sell, b, tol=1e-8, maxiter=2000, params=_fast_params(),
                   tags=TagMap.for_rows(m, 1))
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref_res.x))
    assert int(res.iters) == int(ref_res.iters)


@pytest.mark.parametrize("nrhs", [1, 4])
def test_uniform_identity_batched(nrhs):
    a, g, _ = _sys(seed=4)
    m = int(g.shape[0])
    rng = np.random.default_rng(4)
    b = jnp.stack([jnp.asarray(np.asarray(spmv(a, jnp.asarray(
        rng.normal(size=m))))) for _ in range(nrhs)], axis=1)
    ref_res = solve_cg_batched(g, b, tol=1e-8, maxiter=2000,
                               params=_fast_params())
    res = solve_cg_batched(g, b, tol=1e-8, maxiter=2000,
                           params=_fast_params(),
                           tags=TagMap.for_rows(m, 1))
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref_res.x))
    np.testing.assert_array_equal(np.asarray(res.iters),
                                  np.asarray(ref_res.iters))


def test_uniform_identity_batched_pcg_int_tag():
    a, g, _ = _sys(seed=5)
    m = int(g.shape[0])
    pre = make_jacobi(a, k=8)
    rng = np.random.default_rng(5)
    b = jnp.stack([jnp.asarray(np.asarray(spmv(a, jnp.asarray(
        rng.normal(size=m))))) for _ in range(3)], axis=1)
    r2 = solve_pcg_batched(g, b, pre, tol=1e-8, maxiter=2000,
                           params=_fast_params(), tags=2)
    rm = solve_pcg_batched(g, b, pre, tol=1e-8, maxiter=2000,
                           params=_fast_params(),
                           tags=TagMap.for_rows(m, 2))
    np.testing.assert_array_equal(np.asarray(r2.x), np.asarray(rm.x))
    np.testing.assert_array_equal(np.asarray(r2.iters),
                                  np.asarray(rm.iters))


def test_uniform_identity_ir():
    _, g, b = _sys(seed=6)
    m = int(g.shape[0])
    ref_res = solve_ir(g, b, tol=1e-12, max_outer=6, inner_tol=1e-4,
                       inner_maxiter=800, params=_fast_params())
    res = solve_ir(g, b, tol=1e-12, max_outer=6, inner_tol=1e-4,
                   inner_maxiter=800, params=_fast_params(),
                   tags=TagMap.for_rows(m, 1))
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref_res.x))
    assert bool(res.converged)


# ---------------------------------------------------------------------------
# Contract 2: masked max-tag decode == per-entry-tag decode, bitwise
# ---------------------------------------------------------------------------

def _per_entry_reference(g, tm):
    """NumPy oracle: every entry decoded at its own symmetric induced
    tag, straight from the flat packed segments."""
    cols = (np.asarray(g.colpak, np.uint32)
            & np.uint32((1 << (32 - g.ei_bit)) - 1)).astype(np.int64)
    et = tm.entry_tags(np.asarray(g.row_ids), cols)
    decs = {t: np.asarray(ref.decode_csr_ref(
        g.colpak, g.head, g.tail1, g.tail2, g.table, g.ei_bit, t),
        np.float64) for t in (1, 2, 3)}
    out = np.zeros(et.shape[0], np.float64)
    for t in (1, 2, 3):
        out[et == t] = decs[t][et == t]
    return out, cols


@pytest.mark.parametrize("lo,hi", [(1, 2), (1, 3), (2, 3)])
def test_masked_decode_matches_per_entry_numpy(lo, hi):
    _, g, _ = _sys(seed=7)
    tm = _mixed_map(int(g.shape[0]), lo=lo, hi=hi)
    masked = ops.masked_for_tagmap(g, tm)
    got = np.asarray(ref.decode_csr_ref(
        masked.colpak, masked.head, masked.tail1, masked.tail2,
        masked.table, masked.ei_bit, tm.max_tag), np.float64)
    want, _ = _per_entry_reference(g, tm)
    np.testing.assert_array_equal(got, want)


def test_masked_matvec_matches_per_entry_numpy():
    from repro.solvers.fused_cg import gse_matvec

    _, g, _ = _sys(seed=8)
    m = int(g.shape[0])
    tm = _mixed_map(m)
    masked = ops.masked_for_tagmap(g, tm)
    x = np.random.default_rng(8).normal(size=m)
    got = np.asarray(gse_matvec(masked, jnp.asarray(x),
                                jnp.int32(tm.max_tag)))
    vals, cols = _per_entry_reference(g, tm)
    want = np.zeros(m, np.float64)
    np.add.at(want, np.asarray(g.row_ids, np.int64), vals * x[cols])
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-13)


def test_masked_operand_stays_symmetric():
    """The induced entry tag is max(row, col) BY CONSTRUCTION, so a
    masked SPD operand is exactly symmetric -- CG's contract."""
    _, g, _ = _sys(seed=9)
    m = int(g.shape[0])
    tm = _mixed_map(m, lo=1, hi=3, period=2)
    masked = ops.masked_for_tagmap(g, tm)
    vals = np.asarray(ref.decode_csr_ref(
        masked.colpak, masked.head, masked.tail1, masked.tail2,
        masked.table, masked.ei_bit, tm.max_tag), np.float64)
    cols = (np.asarray(g.colpak, np.uint32)
            & np.uint32((1 << (32 - g.ei_bit)) - 1)).astype(np.int64)
    rows = np.asarray(g.row_ids, np.int64)
    dense = np.zeros((m, m))
    dense[rows, cols] = vals
    np.testing.assert_array_equal(dense, dense.T)


# ---------------------------------------------------------------------------
# Contract 3: the blended byte model
# ---------------------------------------------------------------------------

def test_bytes_touched_blend_gsecsr():
    _, g, _ = _sys(seed=10)
    m = int(g.shape[0])
    # Uniform maps charge exactly the int-tag model.
    for t in (1, 2, 3):
        assert g.bytes_touched(TagMap.for_rows(m, t)) == g.bytes_touched(t)
    # A mixed map blends per symmetric induced entry tag, exactly.
    tm = _mixed_map(m)
    cols = (np.asarray(g.colpak, np.uint32)
            & np.uint32((1 << (32 - g.ei_bit)) - 1)).astype(np.int64)
    et = tm.entry_tags(np.asarray(g.row_ids), cols)
    per_nnz = {1: 6, 2: 8, 3: 12}
    fixed = (np.asarray(g.rowptr).size + np.asarray(g.table).size) * 4
    want = fixed + sum(per_nnz[t] * int((et == t).sum()) for t in (1, 2, 3))
    assert g.bytes_touched(tm) == want
    # And sits strictly inside the uniform bracket.
    assert g.bytes_touched(1) < g.bytes_touched(tm) < g.bytes_touched(2)


def test_iteration_stream_bytes_tagmap():
    a, g, _ = _sys(seed=11)
    m = int(g.shape[0])
    pre = make_jacobi(a, k=8)
    tm = _mixed_map(m)
    # Vector/precond terms ride the map's MAX tag (one fused pass).
    want = (iteration_stream_bytes(g, tm.max_tag, pre, nrhs=2)
            - g.bytes_touched(tm.max_tag) + g.bytes_touched(tm))
    assert iteration_stream_bytes(g, tm, pre, nrhs=2) == want


def test_bytes_touched_blend_sell_uniform():
    _, g, _ = _sys(seed=12)
    m = int(g.shape[0])
    sell = ops.sell_pack_gsecsr(g)
    for t in (1, 2, 3):
        assert sell.bytes_touched(TagMap.for_rows(m, t)) \
            == sell.bytes_touched(t)
    tm = _mixed_map(m)
    assert sell.bytes_touched(1) <= sell.bytes_touched(tm) \
        <= sell.bytes_touched(2)


def test_partition_blend_identity():
    from repro.distributed.partition import partition_gsecsr

    _, g, _ = _sys(seed=13)
    tm = _mixed_map(int(g.shape[0]))
    for shards in (2, 4):
        part = partition_gsecsr(g, shards)
        # Redistribution identity, blended: sharding moves the stream,
        # it does not change it.
        assert (sum(part.shard_stream_bytes(tm))
                + part.shared_stream_bytes()
                == iteration_stream_bytes(g, tm)), shards
        # Uniform maps collapse to the int model on every distributed
        # byte surface.
        u2 = TagMap.for_rows(int(g.shape[0]), 2)
        assert part.halo_wire_bytes(u2, "gse") \
            == part.halo_wire_bytes(2, "gse")
        assert sum(part.shard_stream_bytes(u2)) \
            == sum(part.shard_stream_bytes(2))


def test_bnd_slot_tags_and_halo_blend():
    from repro.distributed.partition import partition_gsecsr

    _, g, _ = _sys(seed=14)
    m = int(g.shape[0])
    tm = _mixed_map(m)
    part = partition_gsecsr(g, 4)
    st = part.bnd_slot_tags(tm)
    assert st.shape == (part.n_shards, part.bnd_width)
    bnd = np.asarray(part.bnd_idx)
    row_tags = tm.row_tags(m)
    for i in range(part.n_shards):
        for s in range(part.bnd_width):
            if bnd[i, s] >= 0:
                gcol = int(bnd[i, s]) + i * part.rows_per_shard
                assert st[i, s] == row_tags[gcol], (i, s)
            else:
                # Padded slots ship (zeros) at the payload width.
                assert st[i, s] == tm.max_tag
    # The blended wire cost sits inside the uniform bracket and charges
    # the per-sender table only for shards shipping a packed slot.
    lo = part.halo_wire_bytes(tm.min_tag, "gse")
    hi = part.halo_wire_bytes(tm.max_tag, "gse")
    assert lo <= part.halo_wire_bytes(tm, "gse") <= hi
    # Exact wire ignores the map: full f64 slots either way.
    assert part.halo_wire_bytes(tm, "exact") \
        == part.halo_wire_bytes(3, "exact")


# ---------------------------------------------------------------------------
# The planner: only the limiting groups promote
# ---------------------------------------------------------------------------

def test_plan_tagmap_promotes_only_limiting_groups():
    a = G.diag_rescale(G.poisson2d(8), decades=6.0, seed=3)
    g = pack_csr(a, k=8)
    m = int(g.shape[0])
    scores = P.decode_error_scores(g, np.ones(m))
    floor1 = float(np.sqrt(scores[0].sum()))
    # A budget below the all-tag-1 floor forces promotions; the greedy
    # descent must only touch groups that dominate the floor.
    tm = P.plan_tagmap(scores, budget=floor1 / 4.0)
    promoted = np.nonzero(tm.tags > 1)[0]
    kept = np.nonzero(tm.tags == 1)[0]
    assert promoted.size > 0 and kept.size > 0
    assert scores[0][promoted].min() >= scores[0][kept].max()
    # The planned map's modeled floor fits the budget.
    assert float(np.sqrt(P.map_floor_contrib(scores, tm.tags).sum())) \
        <= floor1 / 4.0
    # A generous budget plans NO promotion at all.
    assert P.plan_tagmap(scores, budget=floor1 * 2.0).is_uniform


def test_promote_groups_touches_top_frac_only():
    tm = TagMap(np.ones(10, np.uint8))
    scores = np.arange(10, dtype=np.float64)
    out = P.promote_groups(tm, scores, frac=0.2)
    counts = {t: c for t, c in out.tag_counts().items() if c}
    assert counts == {1: 8, 2: 2}
    assert list(np.nonzero(out.tags == 2)[0]) == [8, 9]


# ---------------------------------------------------------------------------
# The adaptive driver + serve layer (light smokes; the strict byte gate
# lives in benchmarks/run.py --adaptive / BENCH_adaptive.json CI)
# ---------------------------------------------------------------------------

def test_solve_adaptive_converges_with_nonuniform_map():
    from repro.solvers.adaptive import solve_adaptive

    a = G.ill_conditioned_spd(16, decades=8.0, seed=0)
    g = pack_csr(a, k=8)
    m = int(g.shape[0])
    b = np.zeros(m)
    b[np.random.default_rng(7).choice(m, 4, replace=False)] = 1.0
    res = solve_adaptive(g, jnp.asarray(b), tol=2e-3, maxiter=4000)
    assert bool(res.converged)
    assert float(res.true_relres) <= 2e-3
    # The replan promoted SOME groups and left others cheap -- the whole
    # point of the per-group axis on this skewed-floor generator.
    assert not res.tagmap.is_uniform
    assert res.spmv_bytes > 0 and res.promotions


def test_serve_tags_axis():
    from repro.launch.solver_serve import SolverService

    a, g, b = _sys(seed=15)
    m = int(g.shape[0])
    svc = SolverService(slots=2, maxiter=3000)
    svc.register("p", a, k=8)
    r_int = svc.submit("p", b, tol=1e-8, tags=2)
    r_map = svc.submit("p", b, tol=1e-8, tags=TagMap.for_rows(m, 2))
    r_ad = svc.submit("p", b, tol=1e-8, tags="adaptive")
    reps = svc.flush()
    assert all(reps[r].converged for r in (r_int, r_map, r_ad))
    # Uniform map == int tag: same batched schedule, same iterations.
    assert reps[r_int].iters == reps[r_map].iters
    np.testing.assert_array_equal(np.asarray(svc.solution(r_int)),
                                  np.asarray(svc.solution(r_map)))
    with pytest.raises(ValueError):
        svc.register("ps", a, k=8, layout="sell", tags="adaptive")
    with pytest.raises(ValueError):
        svc.submit("p", b, tags="frobnicate")


# ---------------------------------------------------------------------------
# Property sweep (hypothesis; optional dependency)
# ---------------------------------------------------------------------------

def test_masked_decode_parity_random_maps_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    _, g, _ = _sys(n=8, seed=16)
    ng = -(-int(g.shape[0]) // GROUP_SIZE)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(min_value=1, max_value=3),
                    min_size=ng, max_size=ng))
    def check(tags):
        tm = TagMap(np.asarray(tags, np.uint8))
        masked = ops.masked_for_tagmap(g, tm)
        got = np.asarray(ref.decode_csr_ref(
            masked.colpak, masked.head, masked.tail1, masked.tail2,
            masked.table, masked.ei_bit, tm.max_tag), np.float64)
        want, _ = _per_entry_reference(g, tm)
        np.testing.assert_array_equal(got, want)
        # The blended byte model brackets: uniform min <= map <= max.
        assert g.bytes_touched(tm.min_tag) <= g.bytes_touched(tm) \
            <= g.bytes_touched(tm.max_tag)

    check()
