"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import stepfns, transformer as T
from repro.optim import AdamW

B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 4)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    batch = {
        "tokens": tokens,
        "labels": labels,
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "vlm":
        p = cfg.num_prefix_tokens
        batch["prefix_embeds"] = jax.random.normal(
            ks[2], (B, p, cfg.d_model), jnp.float32
        )
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            ks[3], (B, S, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = configs.get_config(arch, smoke=True)
    params, specs = T.init_params(cfg, jax.random.key(0))
    # specs tree mirrors params tree
    assert set(specs.keys()) <= set(params.keys()) | {"layers", "encoder",
                                                      "decoder"}
    batch = _batch(cfg, jax.random.key(1))
    h, aux = T.forward(cfg, params, batch["tokens"],
                       prefix_embeds=batch.get("prefix_embeds"),
                       enc_embeds=batch.get("enc_embeds"))
    exp_s = S + (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
    assert h.shape == (B, exp_s, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())
    logits = T.logits_from_hidden(cfg, params, h)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    params, _ = T.init_params(cfg, jax.random.key(0))
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    state = stepfns.TrainState(params=params, opt_state=opt.init(params),
                               step=jnp.zeros((), jnp.int32))
    train_step = jax.jit(stepfns.make_train_step(cfg, opt))
    batch = _batch(cfg, jax.random.key(1))
    state2, metrics = train_step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), state.params, state2.params
    )
    assert max(jax.tree.leaves(moved)) > 0
    # loss decreases over a few steps on a repeated batch
    for _ in range(5):
        state2, m2 = train_step(state2, batch)
    assert float(m2["loss"]) < float(metrics["loss"])


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    params, _ = T.init_params(cfg, jax.random.key(0))
    state = T.decode_state_init(cfg, B, max_len=S)
    serve = jax.jit(stepfns.make_serve_step(cfg))
    tokens = jnp.zeros((B,), jnp.int32)
    enc = (
        jax.random.normal(jax.random.key(9), (B, S, cfg.d_model), jnp.float32)
        if cfg.family == "encdec" else None
    )
    for pos in range(3):
        if enc is not None:
            tokens, state = serve(params, state, tokens,
                                  jnp.asarray(pos, jnp.int32), enc)
        else:
            tokens, state = serve(params, state, tokens,
                                  jnp.asarray(pos, jnp.int32))
        assert tokens.shape == (B,)
        assert tokens.dtype == jnp.int32


def test_decode_matches_prefill_dense():
    """Greedy decode path must agree with full-sequence forward."""
    cfg = configs.get_config("qwen3_4b", smoke=True)
    params, _ = T.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(2), (B, 8), 0, cfg.vocab_size)
    h, _ = T.forward(cfg, params, tokens)
    logits_full = T.logits_from_hidden(cfg, params, h)  # (B, 8, V)

    state = T.decode_state_init(cfg, B, max_len=8)
    outs = []
    for pos in range(8):
        logits, state = T.decode_step(cfg, params, state, tokens[:, pos],
                                      jnp.asarray(pos, jnp.int32))
        outs.append(logits)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), rtol=5e-2, atol=5e-2
    )


def test_decode_matches_prefill_rwkv():
    cfg = configs.get_config("rwkv6_1p6b", smoke=True)
    params, _ = T.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(2), (B, 8), 0, cfg.vocab_size)
    h, _ = T.forward(cfg, params, tokens)
    logits_full = T.logits_from_hidden(cfg, params, h)

    state = T.decode_state_init(cfg, B, max_len=8)
    outs = []
    for pos in range(8):
        logits, state = T.decode_step(cfg, params, state, tokens[:, pos],
                                      jnp.asarray(pos, jnp.int32))
        outs.append(logits)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), rtol=5e-2, atol=5e-2
    )


def test_decode_matches_prefill_hybrid():
    cfg = configs.get_config("recurrentgemma_2b", smoke=True)
    params, _ = T.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(2), (B, 8), 0, cfg.vocab_size)
    h, _ = T.forward(cfg, params, tokens)
    logits_full = T.logits_from_hidden(cfg, params, h)

    state = T.decode_state_init(cfg, B, max_len=8)
    outs = []
    for pos in range(8):
        logits, state = T.decode_step(cfg, params, state, tokens[:, pos],
                                      jnp.asarray(pos, jnp.int32))
        outs.append(logits)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), rtol=5e-2, atol=5e-2
    )


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact assigned hyperparams."""
    expect = {
        "seamless_m4t_large_v2": (24, 1024, 16, 16, 8192, 256206),
        "internvl2_2b": (24, 2048, 16, 8, 8192, 92553),
        "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 1536, 151936),
        "grok1_314b": (64, 6144, 48, 8, 32768, 131072),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen15_32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen3_4b": (36, 2560, 32, 8, 9728, 151936),
        "granite_34b": (88, 6144, 48, 1, 24576, 49152),
        "granite_3_2b": (40, 2048, 32, 8, 8192, 49155),
        "rwkv6_1p6b": (24, 2048, 32, 32, 7168, 65536),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = configs.get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    moe = configs.get_config("qwen3_moe_235b_a22b")
    assert moe.num_experts == 128 and moe.experts_per_token == 8
    g = configs.get_config("grok1_314b")
    assert g.num_experts == 8 and g.experts_per_token == 2
