"""Property-based SELL-C-σ sweeps (hypothesis; DESIGN.md §12).

Guarded with ``pytest.importorskip`` so tier-1 collection passes from a
clean checkout (hypothesis is optional -- see requirements.txt); the
deterministic twins of these sweeps live in tests/test_sell.py.

The properties are the pipeline's whole contract: over random row-skew,
slice/σ parameters, tags 1/2/3 and nrhs in {1, 4},

  * the packed layout is a bit-exact permutation of the CSR store
    (segment + row-permutation round trip);
  * SELL reference SpMV/SpMM are BITWISE equal to the CSR reference;
  * the bucketed Pallas kernels are BITWISE equal to the uniform-ELL
    kernels.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops  # noqa: E402
from repro.sparse.csr import from_coo, pack_csr, pack_sell  # noqa: E402
from repro.sparse.spmv import spmm_gse, spmv_gse  # noqa: E402


def _skew_csr(n, skew, seed):
    """Random matrix with controllable row-length skew (a few rows can be
    orders of magnitude longer than the median)."""
    rng = np.random.default_rng(seed)
    deg = np.minimum((rng.pareto(skew, n) * 3 + 1).astype(np.int64), n)
    deg[rng.integers(0, n)] = n  # at least one (near-)dense row
    rows = np.repeat(np.arange(n), deg)
    cols = np.concatenate(
        [rng.choice(n, size=d, replace=False) for d in deg]
    )
    bins = rng.choice([-2, -1, 0, 1], size=rows.size)
    vals = rng.uniform(1.0, 2.0, rows.size) * np.exp2(bins)
    vals *= rng.choice([-1.0, 1.0], size=vals.shape)
    return from_coo(rows, cols, vals, (n, n))


_case = dict(
    n=st.integers(2, 30).map(lambda k: k * 10),
    skew=st.sampled_from([0.8, 1.2, 2.0]),
    sigma=st.sampled_from([None, 16, 64]),
    tag=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 2**16),
)


@settings(max_examples=12, deadline=None)
@given(**_case)
def test_prop_sell_round_trip(n, skew, sigma, tag, seed):
    g = pack_csr(_skew_csr(n, skew, seed), k=8)
    s = pack_sell(g, sigma=sigma)
    gather = np.asarray(s.gather)
    for name in ("colpak", "head", "tail1", "tail2"):
        flat = np.concatenate(
            [np.asarray(b).reshape(-1) for b in getattr(s, name)]
        )
        np.testing.assert_array_equal(flat[gather],
                                      np.asarray(getattr(g, name)))
    perm = np.asarray(s.perm)
    np.testing.assert_array_equal(np.sort(perm[perm >= 0]), np.arange(n))
    np.testing.assert_array_equal(perm[np.asarray(s.unperm)], np.arange(n))


@settings(max_examples=12, deadline=None)
@given(**_case)
def test_prop_sell_reference_bitwise_csr(n, skew, sigma, tag, seed):
    a = _skew_csr(n, skew, seed)
    g = pack_csr(a, k=8)
    s = pack_sell(g, sigma=sigma)
    x = jnp.asarray(np.random.default_rng(seed + 1).normal(size=n))
    np.testing.assert_array_equal(np.asarray(spmv_gse(s, x, tag=tag)),
                                  np.asarray(spmv_gse(g, x, tag=tag)))


@settings(max_examples=8, deadline=None)
@given(nrhs=st.sampled_from([1, 4]), **_case)
def test_prop_sell_spmm_bitwise_csr(nrhs, n, skew, sigma, tag, seed):
    a = _skew_csr(n, skew, seed)
    g = pack_csr(a, k=8)
    s = pack_sell(g, sigma=sigma)
    x = jnp.asarray(np.random.default_rng(seed + 2).normal(size=(n, nrhs)))
    np.testing.assert_array_equal(np.asarray(spmm_gse(s, x, tag=tag)),
                                  np.asarray(spmm_gse(g, x, tag=tag)))


@settings(max_examples=8, deadline=None)
@given(nrhs=st.sampled_from([1, 4]), **_case)
def test_prop_sell_kernels_bitwise_uniform_ell(nrhs, n, skew, sigma, tag,
                                               seed):
    a = _skew_csr(n, skew, seed)
    g = pack_csr(a, k=8)
    s = pack_sell(g, sigma=sigma)
    ell = ops.ell_pack_gsecsr(g)
    rng = np.random.default_rng(seed + 3)
    x1 = jnp.asarray(rng.normal(size=n), jnp.float32)
    got = ops.gse_spmv_sell(s, x1, tag=tag)
    want = ops.gse_spmv_ell(ell, g.table, x1, g.ei_bit, tag=tag)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    xm = jnp.asarray(rng.normal(size=(n, nrhs)), jnp.float32)
    got = ops.gse_spmm_sell(s, xm, tag=tag)
    want = ops.gse_spmm_ell(ell, g.table, xm, g.ei_bit, tag=tag)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
