"""End-to-end behaviour tests for the paper's system.

The full paper loop on one stored matrix: pack -> three-precision SpMV ->
stepped mixed-precision solve -> solution verified against ground truth --
plus the LM-side loop: train a few steps, checkpoint, serve quantized.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gse
from repro.core.precision import MonitorParams
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.sparse.spmv import spmv, spmv_gse
from repro.solvers import make_gse_operator, solve_cg


def test_paper_system_end_to_end():
    # 1. build a system with clustered exponents (paper's data regime)
    a = G.random_spd(1200, seed=42)
    rng = np.random.default_rng(42)
    x_true = rng.normal(size=a.shape[1])
    b = spmv(a, jnp.asarray(x_true))

    # 2. ONE stored GSE-SEM copy provides three SpMV precisions
    g = pack_csr(a, k=8)
    errs = [
        float(jnp.abs(spmv_gse(g, jnp.asarray(x_true), tag=t)
                      - b).max())
        for t in (1, 2, 3)
    ]
    assert errs[0] > errs[1] > errs[2]  # paper's precision ladder
    table_bytes = int(g.table.size) * 4
    assert (g.nbytes(3) - table_bytes) == 4 * (g.nbytes(1) - table_bytes)

    # 3. stepped mixed-precision CG reaches an FP64-grade solution
    res = solve_cg(
        make_gse_operator(g), b, tol=1e-8, maxiter=4000,
        params=MonitorParams(t=40, l=60, m=30),
        final_correction=True,
    )
    assert bool(res.converged)
    assert float(jnp.abs(res.x - x_true).max()) < 1e-4


def test_lm_system_end_to_end(tmp_path):
    from repro import configs
    from repro.checkpoint import ckpt
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.launch.train import build
    from repro.models import stepfns, transformer as T
    from repro.quant import gse_tensor as Q

    cfg = configs.get_config("qwen3_4b", smoke=True)
    state, step_fn = build(cfg, steps=8, lr=1e-3)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4, seed=0,
                                    d_model=cfg.d_model))
    losses = []
    for step in range(8):
        state, m = step_fn(state, pipe.batch_at(step))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]  # learns

    ckpt.save(str(tmp_path), state, step=8)
    restored, step, _ = ckpt.restore(str(tmp_path), 8, state)
    assert step == 8

    # serve the trained weights from GSE-SEM segments (tag 2 ~ exact)
    packed = Q.quantize_tree(restored.params, k=8, min_size=1024)
    served = Q.dequantize_tree(packed, tag=2, dtype=jnp.float32)
    dstate = T.decode_state_init(cfg, 2, max_len=4)
    serve = stepfns.make_serve_step(cfg)
    toks, _ = serve(served, dstate, jnp.zeros((2,), jnp.int32),
                    jnp.asarray(0, jnp.int32))
    assert toks.shape == (2,)
