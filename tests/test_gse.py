"""Unit + property tests for the GSE-SEM core format (paper section III.B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gse


def _rand_clustered(n, seed=0, exps=(0, -1, 3), spread=2):
    """Values whose exponents cluster around a few points (paper Fig. 1)."""
    rng = np.random.default_rng(seed)
    base = rng.choice(exps, size=n)
    jitter = rng.integers(-spread, spread + 1, size=n)
    mant = rng.uniform(1.0, 2.0, size=n)
    sign = rng.choice([-1.0, 1.0], size=n)
    return sign * mant * np.exp2(base + jitter)


# ---------------------------------------------------------------------------
# Table extraction
# ---------------------------------------------------------------------------

def test_table_contains_max_exponent():
    vals = np.array([1.0, 2.0, 4.0, 1e300, 0.5, 0.5, 0.5])
    table = gse.extract_shared_exponents(vals, 4)
    bits = np.float64(1e300).view(np.uint64)
    e_max = int((bits >> np.uint64(52)) & np.uint64(0x7FF))
    assert e_max + 1 in table.tolist()


def test_table_shape_and_dtype():
    for k in (2, 4, 8, 16, 64):
        t = gse.extract_shared_exponents(_rand_clustered(1000), k)
        assert t.shape == (k,) and t.dtype == np.int32
        assert (np.diff(t) <= 0).all()  # descending


def test_table_all_zeros_input():
    t = gse.extract_shared_exponents(np.zeros(10), 8)
    assert t.shape == (8,)


@pytest.mark.parametrize(
    "vals",
    [
        np.array([1.0]),                        # one distinct exponent
        np.array([1.0, 1.5, 2.0, 3.0]),         # two distinct exponents
        np.full(100, 0.5),                      # below 1.0
        np.array([0.25] * 7 + [8.0] * 3),       # far-apart pair
        np.array([-4.0, 4.0, 4.0, 1.0]),        # signs mixed, three distinct
    ],
)
def test_extract_jnp_matches_numpy_few_exponents(vals):
    """Regression: with fewer than k-1 distinct exponents, ``lax.top_k``
    used to return zero-count bins as table entries (arbitrary indices),
    while the numpy reference pads with the max entry.  Compare unbiased
    tables (numpy reads f64 exponents, jnp reads f32)."""
    k = 8
    t_np = gse.extract_shared_exponents(vals, k).astype(np.int64) - 1023
    t_j = (
        np.asarray(gse.extract_shared_exponents_jnp(jnp.asarray(vals, jnp.float32), k))
        .astype(np.int64) - 127
    )
    np.testing.assert_array_equal(t_np, t_j)


# ---------------------------------------------------------------------------
# f32-source byte model respects frac_bits (no tail2 segment)
# ---------------------------------------------------------------------------

def test_f32_source_byte_model_rejects_tag3():
    vals = _rand_clustered(512, seed=5).astype(np.float32)
    p = gse.pack32(vals, 8)
    n = int(np.prod(p.head.shape))
    tbl = p.table.size * 4
    assert p.width == p.m_h + 16  # no tail2 for frac_bits=23
    assert p.nbytes(1) == 2 * n + tbl
    assert p.nbytes(2) == 4 * n + tbl
    assert p.bytes_touched(2) == p.nbytes(2)
    # tag 3 would charge 8 B/value for a segment that does not exist;
    # the byte model now rejects it exactly as the decode does.
    with pytest.raises(ValueError):
        p.nbytes(3)
    with pytest.raises(ValueError):
        p.bytes_touched(3)
    with pytest.raises(ValueError):
        gse.decode_jnp(p, 3)
    with pytest.raises(ValueError):
        gse.decode(p, 3)
    # Tags 1/2 still decode (round-trip sanity).
    dec = np.asarray(gse.decode_jnp(p, 2, jnp.float32))
    rel = np.abs(dec - vals) / np.maximum(np.abs(vals), 1e-30)
    assert np.median(rel) < 2 ** -22


def test_f64_source_byte_model_unchanged():
    p = gse.pack(_rand_clustered(256, seed=6), 8)
    n = 256
    tbl = p.table.size * 4
    assert [p.nbytes(t) - tbl for t in (1, 2, 3)] == [2 * n, 4 * n, 8 * n]


# ---------------------------------------------------------------------------
# Round-trip precision ladder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 4, 8, 16, 64])
def test_roundtrip_precision_ladder(k):
    vals = _rand_clustered(4096, seed=k)
    p = gse.pack(vals, k)
    errs = []
    for tag in (1, 2, 3):
        dec = gse.decode(p, tag)
        rel = np.abs(dec - vals) / np.abs(vals)
        errs.append(rel.max())
    # Monotone: more tail segments => strictly better or equal.
    assert errs[0] >= errs[1] >= errs[2]
    # head+tail1 covers >= 28 mantissa bits for near exponents.
    assert errs[1] < 2 ** -(15 - p.ei_bit + 16 - 1 - 8)
    # full precision: exact for values within 8 exponent steps of a table hit
    assert errs[2] < 2 ** -(p.width - 1 - 8)


def test_exact_match_exponents_head_error_bound():
    # All values share one exponent -> minDiff == 1 -> head has M_H-1
    # effective mantissa bits after the explicit leading 1.
    rng = np.random.default_rng(0)
    vals = rng.uniform(1.0, 2.0, size=2000)  # exponent 0 for all
    p = gse.pack(vals, 8)
    dec = gse.decode(p, 1)
    m_h = 15 - p.ei_bit
    rel = np.abs(dec - vals) / np.abs(vals)
    assert rel.max() < 2 ** -(m_h - 1)  # truncation error < 1 ulp of M_H-1 bits


def test_full_tag_exact_when_no_shift_loss():
    # Values exactly representable: mantissa fits in W bits after shift<=8.
    vals = np.array([1.0, 1.5, -2.25, 0.75, 1024.0, -0.015625])
    p = gse.pack(vals, 8)
    np.testing.assert_array_equal(gse.decode(p, 3), vals)


def test_zero_and_sign_handling():
    vals = np.array([0.0, -0.0, 1.0, -1.0, 2.5, -2.5])
    p = gse.pack(vals, 4)
    dec = gse.decode(p, 3)
    assert dec[0] == 0 and dec[1] == 0
    np.testing.assert_array_equal(dec[2:], vals[2:])


def test_small_values_flush_to_zero_at_head():
    # A value many binades below every shared exponent flushes to 0 at tag=1
    # (paper Algorithm 2 line 16) but is recovered by the tails.
    vals = np.array([1.0] * 64 + [2.0] * 64 + [2.0 ** -40])
    p = gse.pack(vals, 2)
    assert gse.decode(p, 1)[-1] == 0.0
    assert gse.decode(p, 3)[-1] == pytest.approx(2.0 ** -40, rel=1e-3)


def test_pack_with_stale_table_saturates():
    table = gse.extract_shared_exponents(np.array([1.0, 2.0]), 2)
    p = gse.pack_with_table(np.array([1e30, -1e30, 1.0]), table, 2)
    dec = gse.decode(p, 3)
    # Saturated to the max magnitude representable under the table, sign kept.
    assert dec[0] > 0 and dec[1] < 0 and abs(dec[2] - 1.0) < 1e-15
    assert np.isfinite(dec).all()
    assert dec[0] <= 4.0  # max entry is exp(2.0)+1 -> values < 2^2


def test_subnormal_input():
    vals = np.array([5e-324, 1e-310, 1.0])
    p = gse.pack(vals, 4)
    dec = gse.decode(p, 3)
    assert dec[2] == 1.0
    assert dec[0] >= 0 and np.isfinite(dec).all()


# ---------------------------------------------------------------------------
# jnp decode == numpy decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tag", [1, 2, 3])
def test_decode_jnp_matches_numpy(tag):
    vals = _rand_clustered(2048, seed=7)
    p = gse.pack(vals, 8)
    ref = gse.decode(p, tag)
    out64 = np.asarray(gse.decode_jnp(p, tag, jnp.float64))
    np.testing.assert_allclose(out64, ref, rtol=0, atol=0)
    out32 = np.asarray(gse.decode_jnp(p, tag, jnp.float32))
    np.testing.assert_allclose(out32, ref, rtol=2e-7, atol=1e-30)


# ---------------------------------------------------------------------------
# f32-source jittable pack/decode (gradient compression path)
# ---------------------------------------------------------------------------

def test_pack32_roundtrip():
    vals = _rand_clustered(4096, seed=3).astype(np.float32)
    table = gse.extract_shared_exponents_jnp(jnp.asarray(vals), 8)
    head, tail1 = gse.pack32_jnp(jnp.asarray(vals), table, 8)
    dec2 = np.asarray(gse.decode32_jnp(table, head, tail1, 8, 2))
    rel = np.abs(dec2 - vals) / np.maximum(np.abs(vals), 1e-30)
    # W=28 >= 24-bit f32 significand + shift slack: near-exact for hits.
    assert np.median(rel) < 2 ** -22
    dec1 = np.asarray(gse.decode32_jnp(table, head, tail1, 8, 1))
    rel1 = np.abs(dec1 - vals) / np.maximum(np.abs(vals), 1e-30)
    assert np.median(rel1) < 2 ** -9


def test_pack32_handles_zeros_and_signs():
    vals = jnp.asarray(np.array([0.0, -1.5, 3.25, -0.0], np.float32))
    table = gse.extract_shared_exponents_jnp(vals, 4)
    head, tail1 = gse.pack32_jnp(vals, table, 4)
    dec = np.asarray(gse.decode32_jnp(table, head, tail1, 4, 2))
    assert dec[0] == 0 and dec[3] == 0
    np.testing.assert_allclose(dec[1:3], [-1.5, 3.25], rtol=1e-6)


def test_fake_quant_straight_through_gradient():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(128,)), jnp.float32)
    g = jax.grad(lambda v: jnp.sum(gse.gse_fake_quant(v, 8, 1) ** 2))(x)
    # STE: gradient flows as if identity -> grad = 2*fq(x) (not zero).
    assert np.abs(np.asarray(g)).max() > 0
    fq = gse.gse_fake_quant(x, 8, 2)
    np.testing.assert_allclose(np.asarray(fq), np.asarray(x), rtol=1e-5, atol=1e-7)


# Property tests (hypothesis) live in test_gse_properties.py, guarded by
# pytest.importorskip so collection passes without hypothesis installed.


def test_exponent_stats_clustered():
    stats = gse.exponent_stats(_rand_clustered(20000))
    assert stats["entropy_exponent"] < stats["entropy_value"]
    assert stats["top64"] >= stats["top8"] >= stats["top1"]
    assert stats["top64"] > 0.99
