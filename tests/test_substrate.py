"""Substrate tests: data pipeline, checkpointing, optimizer, quant,
gradient compression, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed import compress
from repro.distributed import sharding as SH
from repro.optim import AdamW
from repro.quant import gse_tensor as Q


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=3)
    p = TokenPipeline(cfg)
    b1 = p.batch_at(5)
    b2 = p.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p.batch_at(6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_pipeline_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=0)
    p = TokenPipeline(cfg)
    s0 = p.batch_at(0, shard=0, num_shards=4)
    s1 = p.batch_at(0, shard=1, num_shards=4)
    assert s0["tokens"].shape == (2, 16)
    assert not np.array_equal(np.asarray(s0["tokens"]), np.asarray(s1["tokens"]))


def test_pipeline_has_learnable_structure():
    cfg = DataConfig(vocab_size=100, seq_len=256, global_batch=8, seed=1)
    b = TokenPipeline(cfg).batch_at(0)
    toks = np.asarray(b["tokens"])
    labs = np.asarray(b["labels"])
    # ~half of labels follow the deterministic bigram map
    pred = (toks * 7919 + 1) % 100
    frac = (pred == labs).mean()
    assert frac > 0.3


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
    }


def test_ckpt_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), t, step=7, extra={"note": "x"})
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    restored, step, extra = ckpt.restore(str(tmp_path), 7, like)
    assert step == 7 and extra["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_ckpt_async_and_latest(tmp_path):
    t = _tree()
    ckpt.save_async(str(tmp_path), t, step=1)
    ckpt.save_async(str(tmp_path), t, step=2)
    ckpt.wait_pending(str(tmp_path))
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_ckpt_integrity_check(tmp_path):
    t = _tree()
    d = ckpt.save(str(tmp_path), t, step=3)
    # corrupt payload
    p = os.path.join(d, "ckpt.msgpack.zst")
    with open(p, "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00\x00\x00")
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), 3, _tree())


def test_ckpt_partial_write_is_invisible(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), t, step=1)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 1  # tmp dirs skipped


def test_ckpt_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), _tree(), step=1)
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros((5,))}}
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, bad)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    opt = AdamW(lr=0.1, warmup_steps=1, total_steps=200, weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    for i in range(200):
        grads = {"x": 2 * params["x"]}
        upd, state = opt.update(grads, state, params, step + i)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(jnp.abs(params["x"]).max()) < 0.1


def test_adamw_clips_gradients():
    opt = AdamW(lr=0.1, clip_norm=1.0)
    params = {"x": jnp.zeros((4,))}
    state = opt.init(params)
    g = {"x": jnp.full((4,), 1e6)}
    upd, _ = opt.update(g, state, params, jnp.zeros((), jnp.int32))
    assert np.isfinite(np.asarray(upd["x"])).all()


# ---------------------------------------------------------------------------
# GSE-SEM weight quantization (paper -> LM bridge)
# ---------------------------------------------------------------------------

def test_quantize_tree_bytes_ladder():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(256, 256)), jnp.float32),
              "tiny": jnp.ones((4,), jnp.float32)}
    q = Q.quantize_tree(params, k=8, min_size=1024)
    from repro.core.gse import GSEPacked

    assert isinstance(q["w"], GSEPacked)
    assert not isinstance(q["tiny"], GSEPacked)
    b1, b2, b3 = (Q.tree_bytes(q, tag) for tag in (1, 2, 3))
    assert b1 < b2 < b3
    # tag1 halves the f32 stream (2 bytes vs 4), modulo the tiny leaf/table.
    assert b1 < params["w"].nbytes * 0.6


def test_quantized_serving_error_ladder():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(128, 512)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    exact = x @ w
    q = Q.quantize_tree({"w": w}, min_size=16)["w"]
    errs = []
    for tag in (1, 2, 3):
        y = Q.gse_linear(x, q, tag=tag, dtype=jnp.float32)
        errs.append(float(jnp.abs(y - exact).max()))
    assert errs[0] > errs[1] >= errs[2]
    assert errs[2] < 1e-4


def test_gse_bf16_comparison_on_lm_weights():
    """GSE head (16b) ~more precise than bf16 (16b) on clustered weights."""
    rng = np.random.default_rng(2)
    w = rng.normal(size=(512, 512)).astype(np.float32) * 0.02
    q = Q.quantize_tree({"w": jnp.asarray(w)}, min_size=16)["w"]
    from repro.core import gse

    dec1 = np.asarray(gse.decode_jnp(q, 1, jnp.float32))
    bf = np.asarray(jnp.asarray(w).astype(jnp.bfloat16).astype(jnp.float32))
    err_gse = np.abs(dec1 - w).mean()
    err_bf16 = np.abs(bf - w).mean()
    assert err_gse < err_bf16


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_compress_roundtrip_error_bounded():
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=(1 << 12,)), jnp.float32)
    g_hat, err = compress.compress_decompress(g, k=8, tag=1)
    rel = float(jnp.linalg.norm(g - g_hat) / jnp.linalg.norm(g))
    assert rel < 2e-3  # 15-bit head on clustered normal values
    np.testing.assert_allclose(np.asarray(g_hat + err), np.asarray(g),
                               rtol=1e-6, atol=1e-7)


def test_error_feedback_converges_mean():
    """With error feedback, the long-run compressed sum tracks the true sum."""
    init_buf, transform = compress.make_error_feedback_transform(
        k=8, tag=1, min_size=1
    )
    rng = np.random.default_rng(4)
    grads = {"w": jnp.asarray(rng.normal(size=(4096,)), jnp.float32)}
    buf = init_buf(grads)
    total_c = jnp.zeros_like(grads["w"])
    total_t = jnp.zeros_like(grads["w"])
    for i in range(20):
        g = {"w": grads["w"] * (1 + 0.01 * i)}
        gc, buf = transform(g, buf)
        total_c = total_c + gc["w"]
        total_t = total_t + g["w"]
    # residual error is bounded by one step's quantization error, not 20x
    resid = float(jnp.linalg.norm(total_c - total_t))
    one_step = float(jnp.linalg.norm(grads["w"])) * 2e-3
    assert resid < 5 * one_step


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def test_logical_to_pspec_basic():
    from jax.sharding import PartitionSpec as P

    rules = {"embed": "data", "mlp": "model", "batch": ("pod", "data")}
    with SH.axis_rules(rules):
        assert SH.logical_to_pspec(("embed", "mlp")) == P("data", "model")
        assert SH.logical_to_pspec(("batch", None)) == P(("pod", "data"))
        # conflict: second use of an axis falls back to replication
        assert SH.logical_to_pspec(("mlp", "mlp")) == P("model")


def test_shard_noop_outside_rules():
    x = jnp.ones((4, 4))
    y = SH.shard(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_specs_to_pspecs_tree():
    from jax.sharding import PartitionSpec as P

    tree = {"w": ("embed", "mlp"), "b": ("mlp",)}
    rules = {"embed": "data", "mlp": "model"}
    out = SH.specs_to_pspecs(tree, rules)
    assert out["w"] == P("data", "model")
    assert out["b"] == P("model")
