"""Observability tier-1 tests (DESIGN.md §16).

Covers the three obs layers end to end:

* **metrics** -- registry registration/idempotency, labeled children,
  histogram quantiles, Prometheus/JSON exposition, and the ``StatsView``
  back-compat dict the migrated PACK_STATS / TUNE_STATS / serve stats
  ride on;
* **trace** -- span nesting, JSONL schema round-trip, validator
  rejection of malformed records, and the near-free no-op path when no
  tracer is installed;
* **flight** -- ring semantics (append, wrap, drop accounting), decode,
  and the telemetry-vs-truth contract: recorder-on solves are
  BIT-IDENTICAL to recorder-off across CG/PCG/GMRES/batched/sharded,
  and the recorded tag/switch/health columns match the solver's own
  monitor switch_iters and guard trip_iter.

Sharded flight tests need 2 devices; under plain tier-1 they skip and
``test_sharded_flight_under_forced_devices`` re-runs them in one
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=2``.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as P
from repro.obs import flight as OF
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.robustness.faults import make_tag_fault_operator
from repro.robustness.guards import DEFAULT_GUARDS, HEALTH_OK
from repro.solvers.batched import solve_cg_batched, solve_pcg_batched
from repro.solvers.cg import solve_cg, solve_pcg
from repro.solvers.gmres import solve_gmres
from repro.solvers.operators import make_gse_operator
from repro.solvers.precond import make_jacobi
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.sparse.spmv import spmv

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
NEED_SHARDS = 2
sharded_devices = pytest.mark.skipif(
    jax.device_count() < NEED_SHARDS,
    reason=f"needs {NEED_SHARDS} devices; covered by the subprocess re-run",
)

# C2 fires at every due check: deterministic switches at iterations 10
# and 15, so the telemetry columns under test are never trivial.
_STEP = P.MonitorParams(t=10, l=10, m=5, rsd_limit=0.5, reldec_limit=2.0)
_FP = OF.FlightParams(capacity=256)


def _sys(n=12, seed=3):
    csr = G.poisson2d(n)
    g = pack_csr(csr, k=8)
    rng = np.random.default_rng(seed)
    b = spmv(csr, jnp.asarray(rng.normal(size=csr.shape[1])))
    return csr, g, b


# -- metrics registry -----------------------------------------------------


def _reg():
    return OM.Registry()


def test_counter_and_gauge_basics():
    r = _reg()
    c = r.counter("events_total", "help text")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)  # counters only go up
    g = r.gauge("depth", "queue depth")
    g.set(7)
    g.dec(2)
    assert g.value == 5


def test_registration_idempotent_and_type_checked():
    r = _reg()
    a = r.counter("x_total", "h")
    b = r.counter("x_total", "h")
    assert a is b
    with pytest.raises(ValueError):
        r.gauge("x_total", "h")  # same name, different type


def test_labeled_children_and_exposition():
    r = _reg()
    c = r.counter("hits_total", "h", labelnames=("kind",))
    c.labels(kind="a").inc(2)
    c.labels(kind="b").inc()
    text = r.to_prometheus()
    assert 'hits_total{kind="a"} 2' in text
    assert "# TYPE hits_total counter" in text
    j = r.to_json()
    assert j["schema"] == 1
    series = {tuple(s["labels"].items()): s
              for m in j["metrics"] if m["name"] == "hits_total"
              for s in m["series"]}
    assert series[(("kind", "a"),)]["value"] == 2


def test_histogram_quantiles_and_summary():
    r = _reg()
    h = r.histogram("lat_seconds", "h")
    for v in range(1, 101):
        h.observe(v / 100.0)
    s = h.summary()
    assert s["count"] == 100
    assert abs(s["p50"] - 0.50) <= 0.02
    assert abs(s["p95"] - 0.95) <= 0.02
    assert abs(s["p99"] - 0.99) <= 0.02
    assert s["min"] == 0.01 and s["max"] == 1.0


def test_stats_view_is_a_dict_shim():
    r = _reg()
    sv = OM.stats_view("pack_events_total", ("hits", "misses"),
                       registry=r)
    sv["hits"] += 1
    sv["hits"] += 1
    sv["misses"] = 5
    assert sv["hits"] == 2 and sv["misses"] == 5
    assert dict(sv) == {"hits": 2, "misses": 5}
    assert set(sv) == {"hits", "misses"}
    with pytest.raises(KeyError):
        sv["unknown"]
    with pytest.raises(TypeError):
        del sv["hits"]
    # zeroing through the view (the reset() idiom the caches use)
    for k in sv:
        sv[k] = 0
    assert dict(sv) == {"hits": 0, "misses": 0}


def test_migrated_stats_are_registry_backed():
    from repro.kernels.ops import PACK_STATS
    from repro.perf.tunecache import TUNE_STATS

    assert isinstance(PACK_STATS, OM.StatsView)
    assert isinstance(TUNE_STATS, OM.StatsView)
    # the live views expose through the global registry
    text = OM.REGISTRY.to_prometheus()
    assert "repro_pack_cache_events_total" in text
    assert "repro_tune_cache_events_total" in text


# -- span tracer ----------------------------------------------------------


def test_span_nesting_and_jsonl_roundtrip(tmp_path):
    tr = OT.Tracer()
    with tr.span("outer", phase="pack") as attrs:
        attrs["bytes"] = 123
        with tr.span("inner"):
            pass
        tr.event("mark", note="hi")
    spans = [e for e in tr.events if e["kind"] == "span"]
    byname = {e["name"]: e for e in spans}
    assert byname["inner"]["parent"] == byname["outer"]["id"]
    assert byname["inner"]["depth"] == 1
    assert byname["outer"]["attrs"]["bytes"] == 123
    path = tmp_path / "t.jsonl"
    tr.write_jsonl(str(path))
    assert OT.validate_jsonl(str(path)) == len(tr.events)


def test_validator_rejects_malformed(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text(json.dumps({"v": 1, "kind": "span", "name": "x"}) + "\n")
    with pytest.raises(ValueError):
        OT.validate_jsonl(str(path))
    path.write_text(json.dumps({
        "v": 1, "kind": "span", "name": "x", "id": 1, "parent": 99,
        "depth": 0, "t0": 0.0, "dur_s": 0.1, "attrs": {},
    }) + "\n")
    with pytest.raises(ValueError):  # dangling parent id
        OT.validate_jsonl(str(path))


def test_module_span_noop_without_tracer():
    assert OT.current() is None
    with OT.span("ignored", k=1) as attrs:
        attrs["x"] = 2  # must be writable even when dropped
    OT.event("ignored")


def test_capture_context(tmp_path):
    path = tmp_path / "cap.jsonl"
    with OT.capture(str(path)) as tr:
        with OT.span("solve.test", n=4):
            pass
    assert OT.current() is None  # uninstalled on exit
    assert OT.validate_jsonl(str(path)) == len(tr.events) == 1


# -- flight recorder: ring mechanics -------------------------------------


def test_flight_ring_append_and_decode():
    fs = OF.flight_init(OF.FlightParams(capacity=8), jnp.float64)
    for i in range(5):
        fs = OF.flight_record(fs, it=i, relres=1.0 / (i + 1), tag=1 + i // 3)
    log = OF.FlightLog.from_state(fs)
    assert len(log) == 5 and log.dropped == 0
    assert list(log.it) == [0, 1, 2, 3, 4]
    assert list(log.tag) == [1, 1, 1, 2, 2]
    np.testing.assert_allclose(log.relres, [1 / (i + 1) for i in range(5)])
    assert log.first_unhealthy() == -1


def test_flight_ring_wraps_and_reports_dropped():
    fs = OF.flight_init(OF.FlightParams(capacity=4), jnp.float64)
    for i in range(10):
        fs = OF.flight_record(fs, it=i, relres=float(i), tag=3)
    log = OF.FlightLog.from_state(fs)
    assert len(log) == 4
    assert log.recorded == 10 and log.dropped == 6
    assert list(log.it) == [6, 7, 8, 9]  # oldest -> newest after the roll
    assert not log.switch_visible(3)  # window starts at tag 3 already
    s = log.summary()
    assert s["dropped"] == 6 and s["last_it"] == 9


# -- flight recorder: telemetry vs truth ----------------------------------


def _check_identity_and_truth(off, on):
    assert np.array_equal(np.asarray(off.x), np.asarray(on.x))
    assert np.array_equal(np.asarray(off.iters), np.asarray(on.iters))
    log = OF.FlightLog.from_state(on.flight)
    OF.assert_consistent(log, on)
    return log


@pytest.mark.parametrize("guards", [None, DEFAULT_GUARDS],
                         ids=["fused", "guarded"])
def test_cg_flight_identity_and_truth(guards):
    _, g, b = _sys()
    kw = dict(tol=1e-10, maxiter=400, params=_STEP, guards=guards,
              recover=False)
    off = solve_cg(g, b, **kw)
    on = solve_cg(g, b, flight=_FP, **kw)
    log = _check_identity_and_truth(off, on)
    assert np.array_equal(log.switch_iters(),
                          np.asarray(on.switch_iters))
    assert log.switch_iters().tolist() == [10, 15]


def test_pcg_flight_identity_and_truth():
    csr, g, b = _sys()
    m = make_jacobi(csr)
    kw = dict(tol=1e-10, maxiter=400, params=_STEP, recover=False)
    off = solve_pcg(g, b, m, **kw)
    on = solve_pcg(g, b, m, flight=_FP, **kw)
    _check_identity_and_truth(off, on)


def test_gmres_flight_identity_and_truth():
    _, g, b = _sys()
    op = make_gse_operator(g)
    kw = dict(tol=1e-10, restart=25, maxiter=400, params=_STEP,
              recover=False)
    off = solve_gmres(op, b, **kw)
    on = solve_gmres(op, b, flight=_FP, **kw)
    log = _check_identity_and_truth(off, on)
    # a0 carries the Givens magnitude: positive wherever recorded
    assert np.all(log.a0 > 0)


def test_guard_trip_lands_in_health_column():
    _, g, b = _sys()
    op = make_tag_fault_operator(g, mode="indefinite", fail_tag=1)
    res = solve_cg(op, b, tol=1e-8, maxiter=400, params=_STEP,
                   recover=False, flight=_FP)
    log = OF.FlightLog.from_state(res.flight)
    OF.assert_consistent(log, res)
    assert int(res.trip_iter) >= 0
    assert log.first_unhealthy() == int(res.trip_iter)


def test_recovered_solve_keeps_final_segment_log():
    _, g, b = _sys()
    op = make_tag_fault_operator(g, mode="indefinite", fail_tag=1)
    res = solve_cg(op, b, tol=1e-8, maxiter=3000, params=_STEP,
                   flight=_FP)
    assert bool(res.converged) and int(res.tag) > 1
    log = OF.FlightLog.from_state(res.flight)
    OF.assert_consistent(log, res, is_recovered=True)
    assert len(log) > 0
    assert int(log.tag[-1]) >= 2  # the segment that escaped the fault


@pytest.mark.parametrize("pcg", [False, True], ids=["cg", "pcg"])
def test_batched_flight_matches_single_rhs(pcg):
    csr, g, _ = _sys()
    n = csr.shape[0]
    rng = np.random.default_rng(0)
    B = jnp.asarray(rng.standard_normal((n, 3)))
    kw = dict(tol=1e-10, maxiter=400, params=_STEP)
    if pcg:
        m = make_jacobi(csr)
        off = solve_pcg_batched(g, B, m, **kw)
        on = solve_pcg_batched(g, B, m, flight=_FP, **kw)
    else:
        off = solve_cg_batched(g, B, **kw)
        on = solve_cg_batched(g, B, flight=_FP, **kw)
    assert np.array_equal(np.asarray(off.x), np.asarray(on.x))
    assert np.array_equal(np.asarray(off.iters), np.asarray(on.iters))
    for j, st in enumerate(OF.split_batched(on.flight)):
        log = OF.FlightLog.from_state(st)
        if pcg:
            single = solve_pcg(g, B[:, j], make_jacobi(csr), flight=_FP,
                               recover=False, **kw)
        else:
            single = solve_cg(g, B[:, j], flight=_FP, recover=False, **kw)
        slog = OF.FlightLog.from_state(single.flight)
        assert np.array_equal(log.it, slog.it)
        assert np.array_equal(log.tag, slog.tag)
        assert np.array_equal(log.relres, slog.relres)
        assert np.array_equal(log.switch_iters(),
                              np.asarray(on.switch_iters)[j])


@sharded_devices
@pytest.mark.parametrize("pcg", [False, True], ids=["cg", "pcg"])
def test_sharded_flight_identity_and_truth(pcg):
    from repro.distributed.partition import partition_gsecsr
    from repro.solvers.sharded import solve_cg_sharded, solve_pcg_sharded

    csr, g, b = _sys()
    part = partition_gsecsr(g, NEED_SHARDS)
    kw = dict(tol=1e-10, maxiter=400, params=_STEP)
    if pcg:
        m = make_jacobi(csr)
        off = solve_pcg_sharded(part, b, m, **kw)
        on = solve_pcg_sharded(part, b, m, flight=_FP, **kw)
        ref = solve_pcg(g, b, m, flight=_FP, recover=False, **kw)
    else:
        off = solve_cg_sharded(part, b, **kw)
        on = solve_cg_sharded(part, b, flight=_FP, **kw)
        ref = solve_cg(g, b, flight=_FP, recover=False, **kw)
    log = _check_identity_and_truth(off, on)
    # Exact wire: same iterations and tag schedule as single-device.
    # relres rides the psum'd partial dots, which round differently from
    # one fused dot -- the dist-smoke 1e-10 trajectory bar applies, not
    # bit equality (recorder-on/off bit-identity is checked above).
    rlog = OF.FlightLog.from_state(ref.flight)
    assert np.array_equal(log.it, rlog.it)
    assert np.array_equal(log.tag, rlog.tag)
    np.testing.assert_allclose(log.relres, rlog.relres, rtol=1e-9)


def test_sharded_flight_under_forced_devices():
    """Re-run the sharded flight tests with 2 forced host devices when
    tier-1 runs on a single device (same pattern as test_robustness)."""
    if jax.device_count() >= NEED_SHARDS:
        pytest.skip("already running with enough devices")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count"
                         f"={NEED_SHARDS}")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.join(REPO, "tests", "test_obs.py"),
         "-k", "sharded_flight_identity"],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, (
        f"forced-device sharded flight run failed:\n{r.stdout}\n{r.stderr}"
    )


# -- serve + timing integration ------------------------------------------


def test_service_latency_histograms_populate():
    from repro.launch.solver_serve import SolverService

    csr, _, _ = _sys()
    n = csr.shape[0]
    svc = SolverService(slots=2, params=_STEP, maxiter=800)
    svc.register("op", csr, k=8)
    rng = np.random.default_rng(1)
    for _ in range(3):
        svc.submit("op", rng.standard_normal(n), tol=1e-8)
    assert svc.queue_depth.value == 3
    reports = svc.flush()
    assert all(r.converged for r in reports.values())
    assert svc.queue_depth.value == 0
    lat = svc.flush_latency.summary()
    assert lat["count"] >= 1 and lat["p99"] >= lat["p50"] > 0
    by = svc.request_bytes.summary()
    assert by["count"] == 3 and by["min"] > 0
    assert svc.stats["requests"] == 3 and svc.stats["batches"] == 2


def test_measure_split_orders_first_and_best():
    from repro.perf import timing

    @jax.jit
    def f(x):
        return (x * x).sum()

    x = jnp.arange(1024.0)
    out, first, best = timing.measure_split(f, x, iters=3, warmup=1)
    assert float(out) == float((x * x).sum())
    assert first > 0 and best > 0
    # the very first call pays trace+compile: never faster than steady state
    assert first >= best


def test_flight_solve_emits_spans():
    _, g, b = _sys()
    tr = OT.Tracer()
    OT.install(tr)
    try:
        solve_cg(g, b, tol=1e-10, maxiter=400, params=_STEP, flight=_FP)
    finally:
        OT.uninstall()
    names = [e["name"] for e in tr.events]
    assert "solve.cg" in names
