"""Solver integration tests: CG + GMRES, fixed and stepped precision."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as P
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.solvers import (
    make_fixed_operator,
    make_gse_operator,
    solve_cg,
    solve_gmres,
)


def _b_for(a, seed=0):
    rng = np.random.default_rng(seed)
    x_true = rng.normal(size=a.shape[1])
    import repro.sparse.spmv as S

    b = np.asarray(S.spmv(a, jnp.asarray(x_true)))
    return jnp.asarray(b), x_true


# ---------------------------------------------------------------------------
# FP64 baselines converge
# ---------------------------------------------------------------------------

def test_cg_fp64_poisson():
    a = G.poisson2d(24)
    b, x_true = _b_for(a)
    res = solve_cg(make_fixed_operator(a), b, tol=1e-10, maxiter=2000)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), x_true, rtol=1e-6, atol=1e-7)


def test_gmres_fp64_convdiff():
    a = G.convection_diffusion_2d(16)
    b, x_true = _b_for(a)
    res = solve_gmres(make_fixed_operator(a), b, tol=1e-10, restart=60,
                      maxiter=3000)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), x_true, rtol=1e-5, atol=1e-6)


def test_gmres_restart_smaller_than_needed_still_converges():
    a = G.poisson2d(12)
    b, _ = _b_for(a, seed=3)
    res = solve_gmres(make_fixed_operator(a), b, tol=1e-8, restart=10,
                      maxiter=5000)
    assert bool(res.converged)


# ---------------------------------------------------------------------------
# Stepped GSE-SEM solvers (the paper's contribution)
# ---------------------------------------------------------------------------

def _fast_params(**kw):
    d = dict(t=30, l=30, m=15, rsd_limit=0.5, reldec_limit=0.45)
    d.update(kw)
    return P.MonitorParams(**d)


def test_cg_gse_stepped_reaches_fp64_residual():
    a = G.random_spd(1500, seed=2)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=2)
    op = make_gse_operator(g)
    # Faithful mode: the recursive residual converges against the perturbed
    # low-precision operator (paper semantics).
    res = solve_cg(op, b, tol=1e-6, maxiter=4000, params=_fast_params())
    assert bool(res.converged)
    # final_correction drives the TRUE (tag-3) residual below tol.
    res_fc = solve_cg(op, b, tol=1e-6, maxiter=8000, params=_fast_params(),
                      final_correction=True)
    true_res = jnp.linalg.norm(b - op(res_fc.x, jnp.int32(3))) / jnp.linalg.norm(b)
    assert float(true_res) < 5e-6


def test_cg_gse_steps_up_when_head_only_stalls():
    # SPD matrix with eigenvalues down to 1e-6: the head-only decode error
    # (~1e-4 relative) perturbs the small eigenvalues below zero, so tag-1
    # CG genuinely stalls/oscillates -> the controller must step up.
    rng = np.random.default_rng(7)
    n = 200
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    eigs = np.logspace(-6, 0, n)
    dense = (q * eigs) @ q.T
    dense = 0.5 * (dense + dense.T)
    rows, cols = np.nonzero(np.ones((n, n)))
    from repro.sparse.csr import from_coo

    a = from_coo(rows, cols, dense[rows, cols], (n, n))
    g = pack_csr(a, k=8)
    b = jnp.asarray(dense @ rng.normal(size=n))
    res = solve_cg(make_gse_operator(g), b, tol=1e-8, maxiter=20000,
                   params=_fast_params(t=60, l=60, m=30))
    assert int(res.tag) >= 2  # controller had to leave tag 1
    assert bool(res.converged)
    assert int(res.switch_iters[0]) > 0


def test_gmres_gse_stepped_converges():
    a = G.convection_diffusion_2d(16, beta=10.0)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=4)
    res = solve_gmres(make_gse_operator(g), b, tol=1e-8, restart=60,
                      maxiter=6000, params=_fast_params())
    assert bool(res.converged)
    op = make_gse_operator(g)
    true_res = jnp.linalg.norm(b - op(res.x, jnp.int32(3))) / jnp.linalg.norm(b)
    assert float(true_res) < 1e-6


def test_switch_iters_recorded_in_order():
    a = G.random_spd(800, cond_decades=6.0, seed=9)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=9)
    res = solve_cg(make_gse_operator(g), b, tol=1e-11, maxiter=6000,
                   params=_fast_params(t=30, l=30, m=15))
    sw = np.asarray(res.switch_iters)
    if sw[1] >= 0:  # reached tag 3
        assert sw[0] >= 0 and sw[0] < sw[1]


# ---------------------------------------------------------------------------
# final_correction resume budget (regression: maxiter exhausted exactly at
# tolerance used to hand the tag-3 resume a non-positive iteration budget)
# ---------------------------------------------------------------------------

def test_cg_final_correction_resumes_when_maxiter_exhausted_at_tol():
    a = G.random_spd(600, seed=5)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=5)
    op = make_gse_operator(g)
    # Pin the monitor to tag 1: the recursive residual converges against
    # the perturbed operator while the TRUE residual stalls above tol.
    params = _fast_params(max_tag=1)
    res1 = solve_cg(op, b, tol=1e-8, maxiter=4000, params=params)
    assert bool(res1.converged)
    true_rel = float(
        jnp.linalg.norm(b - op(res1.x, jnp.int32(3))) / jnp.linalg.norm(b)
    )
    assert true_rel > 1e-8  # premise: correction is actually needed
    n = int(res1.iters)
    # Re-run with maxiter == iters: the first solve exhausts its budget
    # exactly at tolerance; the resume must still get >= 1 iteration.
    res2 = solve_cg(op, b, tol=1e-8, maxiter=n, params=params,
                    final_correction=True)
    assert int(res2.iters) > n


def test_gmres_final_correction_resumes_when_maxiter_exhausted_at_tol():
    a = G.diag_rescale(G.convection_diffusion_2d(12, beta=5.0), 4.0, 6)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=6)
    op = make_gse_operator(g)
    params = _fast_params(max_tag=1)
    res1 = solve_gmres(op, b, tol=1e-8, restart=60, maxiter=4000,
                       params=params)
    assert bool(res1.converged)
    true_rel = float(
        jnp.linalg.norm(b - op(res1.x, jnp.int32(3))) / jnp.linalg.norm(b)
    )
    assert true_rel > 1e-8
    n = int(res1.iters)
    res2 = solve_gmres(op, b, tol=1e-8, restart=60, maxiter=n, params=params,
                       final_correction=True)
    assert int(res2.iters) > n


# ---------------------------------------------------------------------------
# Paper Table III/IV phenomenology: FP16 overflows, BF16 stalls, GSE ok
# ---------------------------------------------------------------------------

def test_fp16_storage_overflow_behaviour():
    # Values beyond fp16 range (~6.5e4) become inf in storage.
    a = G.random_spd(400, seed=11)
    import numpy as np

    v = np.asarray(a.val).copy()
    v[0] = 1.0e5  # out of fp16 range
    a = type(a)(rowptr=a.rowptr, col=a.col, val=jnp.asarray(v),
                row_ids=a.row_ids, shape=a.shape)
    b, _ = _b_for(a, seed=11)
    res = solve_cg(make_fixed_operator(a, store_dtype=jnp.float16), b,
                   tol=1e-6, maxiter=50)
    assert not bool(res.converged) or not np.isfinite(float(res.relres))
    # GSE-SEM head handles the same matrix (wide exponent range is its point).
    g = pack_csr(a, k=8)
    res2 = solve_cg(make_gse_operator(g), b, tol=1e-6, maxiter=4000,
                    params=_fast_params())
    assert np.isfinite(float(res2.relres))
    assert bool(res2.converged)


def test_bf16_larger_error_than_gse_at_same_iters():
    a = G.random_spd(1000, seed=13)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=13)
    it = 200
    res_bf = solve_cg(make_fixed_operator(a, store_dtype=jnp.bfloat16), b,
                      tol=1e-30, maxiter=it)
    res_gse = solve_cg(make_gse_operator(g), b, tol=1e-30, maxiter=it,
                       params=_fast_params())
    assert float(res_gse.relres) <= float(res_bf.relres) * 1.5
