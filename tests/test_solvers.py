"""Solver integration tests: CG + GMRES, fixed and stepped precision."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as P
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.solvers import (
    make_fixed_operator,
    make_gse_operator,
    solve_cg,
    solve_gmres,
)


def _b_for(a, seed=0):
    rng = np.random.default_rng(seed)
    x_true = rng.normal(size=a.shape[1])
    import repro.sparse.spmv as S

    b = np.asarray(S.spmv(a, jnp.asarray(x_true)))
    return jnp.asarray(b), x_true


# ---------------------------------------------------------------------------
# FP64 baselines converge
# ---------------------------------------------------------------------------

def test_cg_fp64_poisson():
    a = G.poisson2d(24)
    b, x_true = _b_for(a)
    res = solve_cg(make_fixed_operator(a), b, tol=1e-10, maxiter=2000)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), x_true, rtol=1e-6, atol=1e-7)


def test_gmres_fp64_convdiff():
    a = G.convection_diffusion_2d(16)
    b, x_true = _b_for(a)
    res = solve_gmres(make_fixed_operator(a), b, tol=1e-10, restart=60,
                      maxiter=3000)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), x_true, rtol=1e-5, atol=1e-6)


def test_gmres_restart_smaller_than_needed_still_converges():
    a = G.poisson2d(12)
    b, _ = _b_for(a, seed=3)
    res = solve_gmres(make_fixed_operator(a), b, tol=1e-8, restart=10,
                      maxiter=5000)
    assert bool(res.converged)


# ---------------------------------------------------------------------------
# Stepped GSE-SEM solvers (the paper's contribution)
# ---------------------------------------------------------------------------

def _fast_params(**kw):
    d = dict(t=30, l=30, m=15, rsd_limit=0.5, reldec_limit=0.45)
    d.update(kw)
    return P.MonitorParams(**d)


def test_cg_gse_stepped_reaches_fp64_residual():
    a = G.random_spd(1500, seed=2)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=2)
    op = make_gse_operator(g)
    # Faithful mode: the recursive residual converges against the perturbed
    # low-precision operator (paper semantics).
    res = solve_cg(op, b, tol=1e-6, maxiter=4000, params=_fast_params())
    assert bool(res.converged)
    # final_correction drives the TRUE (tag-3) residual below tol.
    res_fc = solve_cg(op, b, tol=1e-6, maxiter=8000, params=_fast_params(),
                      final_correction=True)
    true_res = jnp.linalg.norm(b - op(res_fc.x, jnp.int32(3))) / jnp.linalg.norm(b)
    assert float(true_res) < 5e-6


def test_cg_gse_steps_up_when_head_only_stalls():
    # SPD matrix with eigenvalues down to 1e-6: the head-only decode error
    # (~1e-4 relative) perturbs the small eigenvalues below zero, so tag-1
    # CG genuinely stalls/oscillates -> the controller must step up.
    rng = np.random.default_rng(7)
    n = 200
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    eigs = np.logspace(-6, 0, n)
    dense = (q * eigs) @ q.T
    dense = 0.5 * (dense + dense.T)
    rows, cols = np.nonzero(np.ones((n, n)))
    from repro.sparse.csr import from_coo

    a = from_coo(rows, cols, dense[rows, cols], (n, n))
    g = pack_csr(a, k=8)
    b = jnp.asarray(dense @ rng.normal(size=n))
    res = solve_cg(make_gse_operator(g), b, tol=1e-8, maxiter=20000,
                   params=_fast_params(t=60, l=60, m=30))
    assert int(res.tag) >= 2  # controller had to leave tag 1
    assert bool(res.converged)
    assert int(res.switch_iters[0]) > 0


def test_gmres_gse_stepped_converges():
    a = G.convection_diffusion_2d(16, beta=10.0)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=4)
    res = solve_gmres(make_gse_operator(g), b, tol=1e-8, restart=60,
                      maxiter=6000, params=_fast_params())
    assert bool(res.converged)
    op = make_gse_operator(g)
    true_res = jnp.linalg.norm(b - op(res.x, jnp.int32(3))) / jnp.linalg.norm(b)
    assert float(true_res) < 1e-6


def test_switch_iters_recorded_in_order():
    a = G.random_spd(800, cond_decades=6.0, seed=9)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=9)
    res = solve_cg(make_gse_operator(g), b, tol=1e-11, maxiter=6000,
                   params=_fast_params(t=30, l=30, m=15))
    sw = np.asarray(res.switch_iters)
    if sw[1] >= 0:  # reached tag 3
        assert sw[0] >= 0 and sw[0] < sw[1]


# ---------------------------------------------------------------------------
# final_correction resume budget (regression: maxiter exhausted exactly at
# tolerance used to hand the tag-3 resume a non-positive iteration budget)
# ---------------------------------------------------------------------------

def test_cg_final_correction_resumes_when_maxiter_exhausted_at_tol():
    a = G.random_spd(600, seed=5)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=5)
    op = make_gse_operator(g)
    # Pin the monitor to tag 1: the recursive residual converges against
    # the perturbed operator while the TRUE residual stalls above tol.
    params = _fast_params(max_tag=1)
    res1 = solve_cg(op, b, tol=1e-8, maxiter=4000, params=params)
    assert bool(res1.converged)
    true_rel = float(
        jnp.linalg.norm(b - op(res1.x, jnp.int32(3))) / jnp.linalg.norm(b)
    )
    assert true_rel > 1e-8  # premise: correction is actually needed
    n = int(res1.iters)
    # Re-run with maxiter == iters: the first solve exhausts its budget
    # exactly at tolerance; the resume must still get >= 1 iteration.
    res2 = solve_cg(op, b, tol=1e-8, maxiter=n, params=params,
                    final_correction=True)
    assert int(res2.iters) > n


def test_gmres_final_correction_resumes_when_maxiter_exhausted_at_tol():
    a = G.diag_rescale(G.convection_diffusion_2d(12, beta=5.0), 4.0, 6)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=6)
    op = make_gse_operator(g)
    params = _fast_params(max_tag=1)
    res1 = solve_gmres(op, b, tol=1e-8, restart=60, maxiter=4000,
                       params=params)
    assert bool(res1.converged)
    true_rel = float(
        jnp.linalg.norm(b - op(res1.x, jnp.int32(3))) / jnp.linalg.norm(b)
    )
    assert true_rel > 1e-8
    n = int(res1.iters)
    res2 = solve_gmres(op, b, tol=1e-8, restart=60, maxiter=n, params=params,
                       final_correction=True)
    assert int(res2.iters) > n


# ---------------------------------------------------------------------------
# Paper Table III/IV phenomenology: FP16 overflows, BF16 stalls, GSE ok
# ---------------------------------------------------------------------------

def test_fp16_storage_overflow_behaviour():
    # Values beyond fp16 range (~6.5e4) become inf in storage.
    a = G.random_spd(400, seed=11)
    import numpy as np

    v = np.asarray(a.val).copy()
    v[0] = 1.0e5  # out of fp16 range
    a = type(a)(rowptr=a.rowptr, col=a.col, val=jnp.asarray(v),
                row_ids=a.row_ids, shape=a.shape)
    b, _ = _b_for(a, seed=11)
    res = solve_cg(make_fixed_operator(a, store_dtype=jnp.float16), b,
                   tol=1e-6, maxiter=50)
    assert not bool(res.converged) or not np.isfinite(float(res.relres))
    # GSE-SEM head handles the same matrix (wide exponent range is its point).
    g = pack_csr(a, k=8)
    res2 = solve_cg(make_gse_operator(g), b, tol=1e-6, maxiter=4000,
                    params=_fast_params())
    assert np.isfinite(float(res2.relres))
    assert bool(res2.converged)


def test_bf16_larger_error_than_gse_at_same_iters():
    a = G.random_spd(1000, seed=13)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=13)
    it = 200
    res_bf = solve_cg(make_fixed_operator(a, store_dtype=jnp.bfloat16), b,
                      tol=1e-30, maxiter=it)
    res_gse = solve_cg(make_gse_operator(g), b, tol=1e-30, maxiter=it,
                       params=_fast_params())
    assert float(res_gse.relres) <= float(res_bf.relres) * 1.5


# ---------------------------------------------------------------------------
# Givens rotation robustness (hypot-style scaling)
# ---------------------------------------------------------------------------

def test_givens_extreme_magnitudes_f64():
    """Regression: sqrt(a*a + b*b) overflows to inf above ~1e154 and
    underflows to 0 below ~1e-162 in f64, poisoning c/s and every later
    rotation.  The scaled form must stay finite and orthonormal."""
    from repro.solvers.gmres import _givens

    extremes = [1e-300, 1e-160, 1e-30, 1.0, 1e30, 1e160, 1e300]
    for av in extremes:
        for bv in extremes:
            for sa in (1.0, -1.0):
                a = jnp.asarray(sa * av, jnp.float64)
                b = jnp.asarray(bv, jnp.float64)
                c, s, d = _givens(a, b)
                assert np.isfinite(float(c)) and np.isfinite(float(s))
                assert np.isfinite(float(d)), (av, bv)
                # Rotation annihilates b: -s*a + c*b == 0 (to roundoff).
                m = max(av, bv)
                assert abs(float(-s * a + c * b)) <= 1e-15 * m
                assert float(c * a + s * b) == pytest.approx(float(d),
                                                             rel=1e-14)
                assert float(c * c + s * s) == pytest.approx(1.0, rel=1e-14)


def test_givens_extreme_magnitudes_f32():
    """float32 (the sharded deployment dtype) overflows sqrt(a*a+b*b)
    already at ~1e19 -- guaranteed territory for real residual scales."""
    from repro.solvers.gmres import _givens

    for av, bv in [(3e19, 1.0), (1.0, 3e19), (3e19, 3e19),
                   (1e-30, 1e-30), (0.0, 1e-38)]:
        a = jnp.asarray(av, jnp.float32)
        b = jnp.asarray(bv, jnp.float32)
        c, s, d = _givens(a, b)
        assert np.isfinite(float(c)) and np.isfinite(float(s))
        assert np.isfinite(float(d))
        assert float(d) == pytest.approx(float(np.hypot(av, bv)), rel=1e-6)


def test_givens_zero_inputs():
    from repro.solvers.gmres import _givens

    c, s, d = _givens(jnp.asarray(0.0), jnp.asarray(0.0))
    assert (float(c), float(s), float(d)) == (1.0, 0.0, 0.0)


def test_givens_property_random_matches_hypot():
    from repro.solvers.gmres import _givens

    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=80, deadline=None)
    @given(
        st.floats(min_value=-1e300, max_value=1e300,
                  allow_nan=False, allow_infinity=False),
        st.floats(min_value=-1e300, max_value=1e300,
                  allow_nan=False, allow_infinity=False),
    )
    def check(av, bv):
        c, s, d = _givens(jnp.asarray(av, jnp.float64),
                          jnp.asarray(bv, jnp.float64))
        ref = np.hypot(av, bv)
        assert np.isfinite(float(d))
        if ref > 0:
            assert float(d) == pytest.approx(ref, rel=1e-14)
            assert float(c * c + s * s) == pytest.approx(1.0, rel=1e-13)

    check()


# ---------------------------------------------------------------------------
# GMRES monitor fidelity: the restart residual is recorded
# ---------------------------------------------------------------------------

def test_gmres_monitor_records_restart_residual():
    """The explicitly recomputed restart residual beta = ||b - A x|| is
    the one TRUE residual per cycle; the monitor window must contain it
    (and exactly one record per inner iteration plus one per restart,
    none for the first cycle -- double-record guard)."""
    from repro.solvers.gmres import _solve_gmres

    a = G.convection_diffusion_2d(12)
    b, _ = _b_for(a)
    op = make_fixed_operator(a)
    params = P.MonitorParams(t=16, l=10_000, m=10_000)  # never switches
    restart, maxiter = 4, 8
    tol = jnp.asarray(1e-14, b.dtype)  # unreachable: exactly 2 full cycles
    x0 = jnp.zeros_like(b)
    res, mon = _solve_gmres(op, b, x0, tol, restart, maxiter, params,
                            return_monitor=True)
    assert int(res.iters) == maxiter
    # 8 inner records + 1 restart record (second cycle only).
    assert int(mon.count) == maxiter + 1
    # The recorded restart residual equals ||b - A x_1||/||b|| for the
    # first cycle's iterate, recomputed independently here.
    res1 = _solve_gmres(op, b, x0, tol, restart, restart, params)
    bnorm = float(jnp.linalg.norm(b))
    beta = float(jnp.linalg.norm(b - op(res1.x, jnp.int32(1)))) / bnorm
    window = np.asarray(mon.hist, np.float64)
    assert np.isclose(window, beta, rtol=1e-12, atol=0.0).any(), (
        f"restart residual {beta} missing from monitor window {window}"
    )


def test_gmres_monitor_no_restart_record_single_cycle():
    """A solve that converges inside the first cycle records ONLY the
    inner-iteration residuals (first-cycle guard: the initial residual
    precedes iteration 0 and must not enter the window)."""
    from repro.solvers.gmres import _solve_gmres

    a = G.convection_diffusion_2d(8)
    b, _ = _b_for(a)
    op = make_fixed_operator(a)
    params = P.MonitorParams(t=16, l=10_000, m=10_000)
    res, mon = _solve_gmres(op, b, jnp.zeros_like(b),
                            jnp.asarray(1e-14, b.dtype), 80, 80, params,
                            return_monitor=True)
    assert int(mon.count) == int(res.iters)
