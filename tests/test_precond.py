"""Preconditioned stepped solvers: GSE-packed preconditioners, PCG (fused +
generic, bit-identical), right-preconditioned GMRES, iterative refinement,
and the preconditioner byte accounting (DESIGN.md §10)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as P
from repro.sparse import generators as G
from repro.sparse.csr import iteration_stream_bytes, pack_csr
from repro.solvers import (
    make_block_jacobi,
    make_gse_operator,
    make_jacobi,
    make_precond_operator,
    make_spai0,
    solve_cg,
    solve_gmres,
    solve_ir,
    solve_pcg,
)
from repro.sparse.spmv import spmv


def _fast_params(**kw):
    d = dict(t=30, l=30, m=15, rsd_limit=0.5, reldec_limit=0.45)
    d.update(kw)
    return P.MonitorParams(**d)


def _b_for(a, seed=0):
    rng = np.random.default_rng(seed)
    x_true = rng.normal(size=a.shape[1])
    return spmv(a, jnp.asarray(x_true)), x_true


@pytest.fixture(scope="module")
def illcond():
    """Ill-conditioned SPD system + packed operand + rhs (shared setup)."""
    a = G.ill_conditioned_spd(32, decades=8.0, seed=0)
    g = pack_csr(a, k=8)
    b, x_true = _b_for(a, seed=0)
    return a, g, b, x_true


# ---------------------------------------------------------------------------
# Preconditioner construction + apply correctness
# ---------------------------------------------------------------------------

def test_jacobi_apply_matches_diag_inverse(illcond):
    a, g, b, _ = illcond
    m = make_jacobi(a, k=8)
    rows = np.asarray(a.row_ids)
    cols = np.asarray(a.col)
    vals = np.asarray(a.val)
    d = np.zeros(a.shape[0])
    d[rows[rows == cols]] = vals[rows == cols]
    r = jnp.asarray(np.random.default_rng(1).normal(size=a.shape[0]))
    z3 = np.asarray(m.apply_at(r, 3))
    np.testing.assert_allclose(z3, np.asarray(r) / d, rtol=1e-13)
    # Traced-tag dispatch agrees with the static-tag branch.
    for tag in (1, 2, 3):
        np.testing.assert_array_equal(
            np.asarray(m.apply(r, jnp.int32(tag))),
            np.asarray(m.apply_at(r, tag)),
        )
    # make_precond_operator is the same switch.
    op = make_precond_operator(m)
    np.testing.assert_array_equal(
        np.asarray(op(r, jnp.int32(2))), np.asarray(m.apply_at(r, 2))
    )


def test_precond_tag_precision_ladder(illcond):
    """Lower tags apply a coarser M^{-1}: error vs the exact diagonal
    inverse shrinks (weakly) as the tag steps up -- the one-copy/three-
    precision property, now on the preconditioner stream."""
    a, *_ = illcond
    m = make_jacobi(a, k=8)
    r = jnp.asarray(np.random.default_rng(2).normal(size=a.shape[0]))
    exact = np.asarray(m.apply_at(r, 3))
    errs = [
        float(np.linalg.norm(np.asarray(m.apply_at(r, t)) - exact))
        for t in (1, 2, 3)
    ]
    assert errs[0] >= errs[1] >= errs[2]
    assert errs[1] > 0  # tag-2 is genuinely coarser than tag-3 here


def test_spai0_entries():
    a = G.random_spd(300, seed=3)
    m = make_spai0(a, k=8)
    rows = np.asarray(a.row_ids)
    cols = np.asarray(a.col)
    vals = np.asarray(a.val)
    d = np.zeros(a.shape[0])
    d[rows[rows == cols]] = vals[rows == cols]
    row_sq = np.zeros(a.shape[0])
    np.add.at(row_sq, rows, vals * vals)
    r = jnp.ones(a.shape[0])
    np.testing.assert_allclose(
        np.asarray(m.apply_at(r, 3)), d / row_sq, rtol=1e-13
    )


def test_block_jacobi_inverts_blocks():
    a = G.random_spd(257, seed=4)  # non-multiple of block: pad path
    m = make_block_jacobi(a, block=4, k=8)
    # Apply to unit vectors through the tag-3 path and compare against the
    # dense block-diagonal solve.
    n = a.shape[0]
    dense = np.zeros((n, n))
    dense[np.asarray(a.row_ids), np.asarray(a.col)] = np.asarray(a.val)
    blocks = np.zeros_like(dense)
    for s in range(0, n, 4):
        e = min(s + 4, n)
        blocks[s:e, s:e] = dense[s:e, s:e]
    eye = jnp.eye(n)
    applied = np.stack([np.asarray(m.apply_at(eye[i], 3)) for i in range(8)])
    should = np.stack([np.linalg.solve(blocks, np.eye(n)[i])
                       for i in range(8)])
    np.testing.assert_allclose(applied, should, rtol=1e-9, atol=1e-12)


# ---------------------------------------------------------------------------
# Stepped PCG: acceptance criteria
# ---------------------------------------------------------------------------

def test_illcond_condition_number_at_least_1e6(illcond):
    a, *_ = illcond
    n = a.shape[0]
    dense = np.zeros((n, n))
    dense[np.asarray(a.row_ids), np.asarray(a.col)] = np.asarray(a.val)
    w = np.linalg.eigvalsh(dense)
    assert w[0] > 0  # SPD
    assert w[-1] / w[0] >= 1e6


@pytest.mark.slow
def test_pcg_jacobi_strictly_fewer_iters_than_cg(illcond):
    """Acceptance: on the cond>=1e6 matrix, stepped PCG with the
    GSE-packed Jacobi preconditioner converges to 1e-10 in strictly
    fewer iterations than unpreconditioned stepped CG."""
    a, g, b, _ = illcond
    params = _fast_params()
    res_cg = solve_cg(g, b, tol=1e-10, maxiter=30000, params=params)
    m = make_jacobi(a, k=8)
    res_pcg = solve_pcg(g, b, m, tol=1e-10, maxiter=30000, params=params)
    assert bool(res_pcg.converged)
    assert bool(res_cg.converged)
    assert int(res_pcg.iters) < int(res_cg.iters)


def test_pcg_fused_unfused_bit_identical(illcond):
    a, g, b, _ = illcond
    params = _fast_params()
    m = make_jacobi(a, k=8)
    fused = solve_pcg(g, b, m, tol=1e-10, maxiter=5000, params=params)
    unfused = solve_pcg(make_gse_operator(g), b, m, tol=1e-10, maxiter=5000,
                        params=params)
    assert int(fused.iters) == int(unfused.iters)
    assert float(fused.relres) == float(unfused.relres)
    assert bool(jnp.all(fused.x == unfused.x))
    np.testing.assert_array_equal(np.asarray(fused.switch_iters),
                                  np.asarray(unfused.switch_iters))


def test_pcg_block_jacobi_converges(illcond):
    a, g, b, x_true = illcond
    m = make_block_jacobi(a, block=4, k=8)
    res = solve_pcg(g, b, m, tol=1e-10, maxiter=5000, params=_fast_params())
    assert bool(res.converged)
    assert int(res.iters) < 1000


def test_pcg_spai0_converges_on_moderate_spd():
    a = G.random_spd(800, seed=6)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=6)
    m = make_spai0(a, k=8)
    res = solve_pcg(g, b, m, tol=1e-8, maxiter=4000, params=_fast_params())
    assert bool(res.converged)


def test_pcg_final_correction_drives_true_residual(illcond):
    a, g, b, _ = illcond
    m = make_jacobi(a, k=8)
    res = solve_pcg(g, b, m, tol=1e-8, maxiter=20000, params=_fast_params(),
                    final_correction=True)
    op = make_gse_operator(g)
    true_rel = float(
        jnp.linalg.norm(b - op(res.x, jnp.int32(3))) / jnp.linalg.norm(b)
    )
    assert true_rel < 5e-8


# ---------------------------------------------------------------------------
# Right-preconditioned GMRES
# ---------------------------------------------------------------------------

def test_gmres_right_precond_converges_faster():
    # Row-scaled convection-diffusion: right-Jacobi turns A M^{-1} into a
    # similarity transform of A diag(A)^{-1}, restoring the stencil's
    # spectrum; plain restarted GMRES stagnates on the raw row scaling.
    from repro.sparse.csr import from_coo

    rng = np.random.default_rng(11)
    a0 = G.convection_diffusion_2d(16, beta=10.0)
    d = np.exp2(rng.uniform(-4, 4, a0.shape[0]))
    rows = np.asarray(a0.row_ids)
    a = from_coo(rows, np.asarray(a0.col), np.asarray(a0.val) * d[rows],
                 a0.shape)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=7)
    op = make_gse_operator(g)
    m = make_jacobi(a, k=8)
    params = _fast_params()
    plain = solve_gmres(op, b, tol=1e-8, restart=60, maxiter=6000,
                        params=params)
    prec = solve_gmres(op, b, tol=1e-8, restart=60, maxiter=6000,
                       params=params, precond=m)
    assert bool(prec.converged)
    assert int(prec.iters) < int(plain.iters) or not bool(plain.converged)
    # Right preconditioning: the reported residual is the TRUE residual.
    true_rel = float(
        jnp.linalg.norm(b - op(prec.x, jnp.int32(3))) / jnp.linalg.norm(b)
    )
    assert true_rel < 1e-6


# ---------------------------------------------------------------------------
# Iterative refinement
# ---------------------------------------------------------------------------

def test_ir_converges_beyond_inner_tolerance(illcond):
    a, g, b, x_true = illcond
    m = make_jacobi(a, k=8)
    res = solve_ir(g, b, tol=1e-11, max_outer=12, inner="cg",
                   inner_tol=1e-4, inner_maxiter=4000,
                   params=_fast_params(), precond=m)
    assert res.converged
    assert res.relres <= 1e-11          # TRUE residual, not recursive
    assert res.outer_iters >= 2         # refinement actually refined
    assert (np.diff(res.history) < 0).all()
    np.testing.assert_allclose(np.asarray(res.x), x_true, rtol=1e-6,
                               atol=1e-8)


def test_ir_gmres_inner():
    a = G.convection_diffusion_2d(16, beta=10.0)
    g = pack_csr(a, k=8)
    b, _ = _b_for(a, seed=8)
    res = solve_ir(make_gse_operator(g), b, tol=1e-10, max_outer=10,
                   inner="gmres", inner_tol=1e-6, inner_maxiter=4000,
                   params=_fast_params(), restart=60)
    assert res.converged
    assert res.relres <= 1e-10


def test_ir_rejects_unknown_inner(illcond):
    _, g, b, _ = illcond
    with pytest.raises(ValueError):
        solve_ir(g, b, inner="bicgstab")


# ---------------------------------------------------------------------------
# Byte accounting for the preconditioner streams
# ---------------------------------------------------------------------------

def test_precond_bytes_ladder(illcond):
    a, g, _, _ = illcond
    n = a.shape[0]
    m = make_jacobi(a, k=8)
    tbl = m.packed.table.size * 4
    assert m.bytes_touched(1) == 2 * n + tbl
    assert m.bytes_touched(2) == 4 * n + tbl
    assert m.bytes_touched(3) == 8 * n + tbl
    mb = make_block_jacobi(a, block=4, k=8)
    assert mb.bytes_touched(1) < mb.bytes_touched(2) < mb.bytes_touched(3)
    # iteration_stream_bytes sums operator + preconditioner at one tag.
    for t in (1, 2, 3):
        assert iteration_stream_bytes(g, t, m) == (
            g.bytes_touched(t) + m.bytes_touched(t)
        )
        assert iteration_stream_bytes(g, t) == g.bytes_touched(t)


def test_fig89_charges_precond_bytes_at_run_tags(illcond):
    from benchmarks.fig89_solver_time import _gse_run_bytes

    a, g, _, _ = illcond
    m = make_jacobi(a, k=8)
    # 10 iters at tag 1, 5 at tag 2, 5 at tag 3 (switches at 10 and 15).
    got = _gse_run_bytes(g, 20, np.array([10, 15]), precond=m)
    want = (10 * iteration_stream_bytes(g, 1, m)
            + 5 * iteration_stream_bytes(g, 2, m)
            + 5 * iteration_stream_bytes(g, 3, m))
    assert got == want
    # Without a preconditioner the operator-only charge is preserved.
    assert _gse_run_bytes(g, 20, np.array([10, 15])) == (
        10 * g.bytes_touched(1) + 5 * g.bytes_touched(2)
        + 5 * g.bytes_touched(3)
    )
