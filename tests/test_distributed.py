"""Distributed row-sharded solver tests (DESIGN.md §13).

Host-side tests (partition round trip, byte model) run on any device
count.  The multi-device tests need 8 forced host CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` -- the CI
distributed-smoke job sets it); under plain tier-1 (single device) they
skip and ``test_suite_under_forced_devices`` re-runs this module in ONE
subprocess with the flag set, so the contracts are exercised either way.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision import MonitorParams
from repro.distributed.partition import partition_gsecsr, unshard
from repro.sparse import generators as G
from repro.sparse.csr import iteration_stream_bytes, pack_csr
from repro.sparse.spmv import spmm_gse, spmv, spmv_gse

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
NEED = 8
multidevice = pytest.mark.skipif(
    jax.device_count() < NEED,
    reason=f"needs {NEED} devices (XLA_FLAGS=--xla_force_host_platform_"
           f"device_count={NEED}); covered by the subprocess re-run",
)

_PARAMS = MonitorParams(t=40, l=60, m=30, rsd_limit=0.5, reldec_limit=0.45)
# Aggressive stepping schedule: C2 fires at every check (reldec_limit
# above 1 is unreachable), so the tag walks 1 -> 2 -> 3 early and the
# parity tests cover every decode tag inside one trajectory.
_STEP_PARAMS = MonitorParams(t=8, l=10, m=5, rsd_limit=0.0,
                             reldec_limit=1.5, ndec_limit=0)


def _poisson(n=24):
    a = G.poisson2d(n)
    return a, pack_csr(a, k=8)


def _b_for(a, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.asarray(spmv(a, jnp.asarray(
        rng.normal(size=a.shape[1])))))


# ---------------------------------------------------------------------------
# Host-side: partition round trip + byte model (no devices needed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [1, 3, 4, 5, 8])
def test_partition_unshard_round_trip(shards):
    """Partitioning is a pure redistribution: reassembling the shard
    blocks recovers the original packed segments bit-for-bit -- including
    shard counts that do not divide n (trailing short block)."""
    a, g = _poisson(20)  # n = 400; 3 and 5 do not divide it evenly
    part = partition_gsecsr(g, shards)
    g2 = unshard(part, g)
    for f in ("colpak", "head", "tail1", "tail2"):
        assert np.array_equal(np.asarray(getattr(g, f)),
                              np.asarray(getattr(g2, f))), f
    assert part.nnz == g.nnz
    assert sum(part.rows_real) == g.shape[0]


@pytest.mark.parametrize("shards", [1, 2, 4, 8])
def test_shard_bytes_sum_to_single_device_stream(shards):
    """The acceptance identity: per-shard matrix bytes + the shared terms
    sum EXACTLY to the single-device iteration_stream_bytes at every tag
    (sharding redistributes the stream, it does not change it)."""
    a, g = _poisson()
    part = partition_gsecsr(g, shards)
    for tag in (1, 2, 3):
        assert (sum(part.shard_stream_bytes(tag))
                + part.shared_stream_bytes()
                == iteration_stream_bytes(g, tag)), (shards, tag)
        assert (part.iteration_stream_bytes(tag, "gse")
                == iteration_stream_bytes(g, tag)
                + part.halo_wire_bytes(tag, "gse"))


def test_halo_wire_byte_ladder_shrinks_with_tag():
    """The GSE segmentation applied to the wire: tag-1 halo payloads
    (u16 heads + tables) must cost < 50% of tag-3's (raw f64), with the
    full ladder monotone -- at 4 and 8 shards."""
    a, g = _poisson()
    for shards in (4, 8):
        part = partition_gsecsr(g, shards)
        w = {t: part.halo_wire_bytes(t, "gse") for t in (1, 2, 3)}
        assert w[1] < 0.5 * w[3], (shards, w)
        assert w[1] < w[2] < w[3], (shards, w)
        # exact wire charges f64 at every tag; tag-3 gse == exact.
        assert part.halo_wire_bytes(3, "gse") == part.halo_wire_bytes(
            3, "exact")
        # nrhs scales the whole per-column payload, tables included (the
        # batched solvers apply the operator column by column).
        assert part.halo_wire_bytes(3, "gse", nrhs=4) == 4 * w[3]
        assert part.halo_wire_bytes(1, "gse", nrhs=4) == 4 * w[1]


def test_one_shard_has_no_wire_traffic():
    a, g = _poisson(8)
    part = partition_gsecsr(g, 1)
    assert part.halo_entries == 0
    for t in (1, 2, 3):
        assert part.halo_wire_bytes(t, "gse") == 0


def test_block_diagonal_operator_has_no_wire_traffic():
    """A (block-)diagonal operator row-shards with ZERO remote columns:
    no exchange runs and the wire model charges nothing (no phantom
    padded-slot or table bytes)."""
    a = G.mass_diagonal(64)
    part = partition_gsecsr(pack_csr(a, k=8), 4)
    assert part.halo_entries == 0
    assert part.bnd_width == 0
    for t in (1, 2, 3):
        assert part.halo_wire_bytes(t, "gse") == 0


def test_partition_rejects_bad_shapes():
    a, g = _poisson(8)
    with pytest.raises(ValueError, match="n_shards"):
        partition_gsecsr(g, 0)


def test_sharded_pcg_rejects_f32_source_precond():
    """An f32-source diagonal pack (pack32: no tail2) supports tags 1/2
    only; the sharded PCG must refuse it up front exactly as the
    single-device decode does, instead of letting the tag-3 branch
    decode garbage."""
    from repro.core import gse
    from repro.solvers import solve_pcg
    from repro.solvers.precond import DiagGSEPrecond

    a, g = _poisson(8)
    bad = DiagGSEPrecond(packed=gse.pack32(np.ones(a.shape[0])),
                         kind="jacobi")
    part = partition_gsecsr(g, 1)
    with pytest.raises(ValueError, match="f32-source"):
        solve_pcg(part, jnp.ones(a.shape[0]), bad, tol=1e-6, maxiter=10,
                  params=_PARAMS)


# ---------------------------------------------------------------------------
# Multi-device: SpMV/SpMM parity, solver contracts
# ---------------------------------------------------------------------------

@multidevice
@pytest.mark.parametrize("shards", [1, 4, 8])
@pytest.mark.parametrize("tag", [1, 2, 3])
def test_dist_spmv_bitwise_equals_reference(shards, tag):
    from repro.kernels.dist_spmv import dist_spmm, dist_spmv

    a, g = _poisson()
    part = partition_gsecsr(g, shards)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=a.shape[1]))
    ref = spmv_gse(g, x, tag=tag)
    y = dist_spmv(part, x, tag=tag, wire="exact")
    assert np.array_equal(np.asarray(ref), np.asarray(y))
    xb = jnp.asarray(rng.normal(size=(a.shape[1], 3)))
    refm = spmm_gse(g, xb, tag=tag)
    ym = dist_spmm(part, xb, tag=tag, wire="exact")
    assert np.array_equal(np.asarray(refm), np.asarray(ym))
    if tag == 3:  # full-precision halos ride raw IEEE bits: still exact
        assert np.array_equal(
            np.asarray(ref), np.asarray(dist_spmv(part, x, tag=3,
                                                  wire="gse")))


@multidevice
def test_gse_wire_low_tags_close_but_lossy():
    """Tag-1/2 compressed halos perturb ONLY boundary contributions: the
    SpMV error stays at the wire format's mantissa scale."""
    from repro.kernels.dist_spmv import dist_spmv

    a, g = _poisson()
    part = partition_gsecsr(g, 4)
    x = jnp.asarray(np.random.default_rng(2).normal(size=a.shape[1]))
    for tag, bound in ((1, 1e-3), (2, 1e-7)):
        ref = spmv_gse(g, x, tag=tag)
        y = dist_spmv(part, x, tag=tag, wire="gse")
        rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
        assert 0 < rel < bound, (tag, rel)


@multidevice
def test_gse_wire_pack_ignores_padded_boundary_slots():
    """Regression: boundary buffers are padded to the max per-shard width
    B, and padded slots used to replicate x_sh[0] into the wire pack's
    shared-exponent table.  A shard with ONE real boundary entry but a
    huge unrelated x_sh[0] (1e300 -> inf in the f32 wire cast) would then
    quantize its real boundary entry to garbage.  Padded slots must be
    masked to zero (excluded from the exponent histogram) so x values
    that never cross the wire cannot perturb entries that do."""
    from repro.kernels.dist_spmv import dist_spmv
    from repro.sparse.csr import from_coo

    n, s = 32, 4  # R = 8: shard 0 owns rows/cols 0..7, shard 1 8..15
    rows = list(range(n)) + list(range(8, 16)) + [0]
    cols = list(range(n)) + list(range(0, 8)) + [9]
    vals = [1.0] * len(rows)
    a = from_coo(rows, cols, vals, (n, n))
    g = pack_csr(a, k=8)
    part = partition_gsecsr(g, s)
    # Shard 0 sends 8 boundary entries -> B = 8; shard 1 sends only
    # col 9, so its buffer carries 7 padded slots.
    assert part.bnd_width == 8
    assert part.bnd_counts[1] == 1
    x = np.ones(n)
    x[9] = 1.5     # the one real boundary entry shard 1 ships
    x[8] = 1e300   # shard 1's local slot 0: NOT a boundary entry
    y = dist_spmv(part, jnp.asarray(x), tag=1, wire="gse")
    # Row 0 = x[0] + x[9]: x[9] crosses the wire at tag 1 (lossy but
    # small); a leaked 1e300 pad would zero it out entirely.
    assert abs(float(y[0]) - 2.5) < 0.01


@multidevice
def test_solve_cg_one_shard_bit_identical():
    from repro.solvers import solve_cg

    a, g = _poisson()
    b = _b_for(a)
    kw = dict(tol=1e-8, maxiter=2000, params=_PARAMS)
    ref = solve_cg(g, b, **kw)
    res = solve_cg(partition_gsecsr(g, 1), b, **kw)
    assert np.array_equal(np.asarray(ref.x), np.asarray(res.x))
    assert int(ref.iters) == int(res.iters)
    assert float(ref.relres) == float(res.relres)
    assert np.array_equal(np.asarray(ref.switch_iters),
                          np.asarray(res.switch_iters))


@multidevice
@pytest.mark.parametrize("shards", [4, 8])
@pytest.mark.parametrize("params", [_PARAMS, _STEP_PARAMS],
                         ids=["tag1", "stepped123"])
def test_solve_cg_kshard_trajectory_parity(shards, params):
    """Exact-wire k-shard runs converge to the same relres with the
    trajectory within 1e-10 of single-device -- the only arithmetic
    difference is the psum dot summation order.  The stepped variant
    forces the tag through 1 -> 2 -> 3, covering every decode tag."""
    from repro.solvers import solve_cg

    a, g = _poisson()
    b = _b_for(a)
    kw = dict(tol=1e-8, maxiter=2000, params=params)
    ref = solve_cg(g, b, **kw)
    res = solve_cg(partition_gsecsr(g, shards), b, **kw)
    assert bool(res.converged) and bool(ref.converged)
    assert int(res.iters) == int(ref.iters)
    assert np.array_equal(np.asarray(ref.switch_iters),
                          np.asarray(res.switch_iters))
    scale = float(jnp.max(jnp.abs(ref.x)))
    assert float(jnp.max(jnp.abs(res.x - ref.x))) < 1e-10 * scale
    assert abs(float(res.relres) - float(ref.relres)) < 1e-10


@multidevice
@pytest.mark.parametrize("shards", [4, 8])
def test_solve_cg_gse_wire_converges(shards):
    """The tag-aware compressed halo is lossy at tags 1/2, but the
    recursive residual still reaches tolerance -- the monitor simply sees
    a slightly stronger low-tag perturbation (paper semantics)."""
    from repro.solvers import solve_cg

    a, g = _poisson()
    b = _b_for(a)
    res = solve_cg(partition_gsecsr(g, shards), b, tol=1e-8, maxiter=2000,
                   params=_PARAMS, wire="gse")
    assert bool(res.converged)
    assert float(res.relres) <= 1e-8


@multidevice
def test_solve_cg_sharded_final_correction_certifies_true_residual():
    """With the lossy gse wire the recursive residual can converge against
    the perturbed operator while the TRUE tag-3 residual sits above tol;
    final_correction must certify (and if needed re-achieve) the true
    residual through the sharded resume path."""
    from repro.kernels.dist_spmv import dist_spmv
    from repro.solvers import solve_cg

    a, g = _poisson()
    b = _b_for(a)
    part = partition_gsecsr(g, 4)
    res = solve_cg(part, b, tol=1e-8, maxiter=4000, params=_PARAMS,
                   wire="gse", final_correction=True)
    assert bool(res.converged)
    true_rel = float(
        jnp.linalg.norm(b - dist_spmv(part, res.x, tag=3, wire="exact"))
        / jnp.linalg.norm(b)
    )
    assert true_rel <= 1e-8


@multidevice
def test_solve_pcg_sharded_parity():
    from repro.solvers import make_jacobi, solve_pcg

    a, g = _poisson()
    m = make_jacobi(a, k=8)
    b = _b_for(a)
    kw = dict(tol=1e-8, maxiter=2000, params=_PARAMS)
    ref = solve_pcg(g, b, m, **kw)
    r1 = solve_pcg(partition_gsecsr(g, 1), b, m, **kw)
    assert np.array_equal(np.asarray(ref.x), np.asarray(r1.x))
    r4 = solve_pcg(partition_gsecsr(g, 4), b, m, **kw)
    assert bool(r4.converged)
    assert int(r4.iters) == int(ref.iters)
    scale = float(jnp.max(jnp.abs(ref.x)))
    assert float(jnp.max(jnp.abs(r4.x - ref.x))) < 1e-10 * scale


@multidevice
@pytest.mark.parametrize("nrhs", [1, 3])
def test_solve_cg_batched_sharded_parity(nrhs):
    """Batched solves ride the distributed operator through the generic
    per-column body: column trajectories match the single-device batched
    solve across every active column."""
    from repro.solvers import solve_cg_batched

    a, g = _poisson(16)
    cols = [_b_for(a, seed=j) for j in range(nrhs)]
    b = jnp.stack(cols, axis=1)
    kw = dict(tol=1e-8, maxiter=2000, params=_PARAMS)
    ref = solve_cg_batched(g, b, **kw)
    res = solve_cg_batched(partition_gsecsr(g, 4), b, **kw)
    assert np.asarray(res.converged).all()
    assert np.array_equal(np.asarray(ref.iters), np.asarray(res.iters))
    scale = float(jnp.max(jnp.abs(ref.x)))
    assert float(jnp.max(jnp.abs(res.x - ref.x))) < 1e-10 * scale


@multidevice
def test_gmres_over_sharded_operator_parity():
    """make_sharded_operator is a drop-in operator callable: exact-wire
    applications match gse_matvec (standalone calls are bitwise equal;
    inlined into GMRES's larger jitted program the scatter-add
    accumulation order may differ in the last ulp across compilations),
    so GMRES trajectories track the single-device run to ~machine
    precision with identical iteration counts."""
    from repro.kernels.dist_spmv import make_sharded_operator
    from repro.solvers import make_gse_operator, solve_gmres

    a = G.convection_diffusion_2d(12)
    g = pack_csr(a, k=8)
    b = _b_for(a)
    kw = dict(tol=1e-8, restart=30, maxiter=600, params=_PARAMS)
    ref = solve_gmres(make_gse_operator(g), b, **kw)
    res = solve_gmres(make_sharded_operator(partition_gsecsr(g, 4)), b, **kw)
    assert bool(res.converged)
    assert int(ref.iters) == int(res.iters)
    scale = float(jnp.max(jnp.abs(ref.x)))
    assert float(jnp.max(jnp.abs(res.x - ref.x))) < 1e-10 * scale


@multidevice
def test_solver_service_sharded_handle():
    from repro.launch.solver_serve import SolverService

    a, g_unused = _poisson(16)
    params = MonitorParams(t=40, l=60, m=30, rsd_limit=0.5,
                           reldec_limit=0.45)
    svc = SolverService(slots=3, params=params, maxiter=4000)
    svc.register("p", a, k=8, sharded=True, shards=4, wire="gse")
    ids = [svc.submit("p", _b_for(a, seed=j), tol=1e-8) for j in range(3)]
    reports = svc.flush()
    for rid in ids:
        r = reports[rid]
        assert r.converged and r.relres <= 1e-8
        assert r.est_bytes > 0
    # Sharded handles charge halo wire traffic on top of the matrix
    # stream: the modeled bytes exceed an unsharded handle's.
    svc2 = SolverService(slots=3, params=params, maxiter=4000)
    svc2.register("p", a, k=8)
    for j in range(3):
        svc2.submit("p", _b_for(a, seed=j), tol=1e-8)
    svc2.flush()
    assert svc.stats["modeled_bytes"] > svc2.stats["modeled_bytes"]


@multidevice
def test_solve_ir_over_sharded_operand():
    """Stepped iterative refinement rides the distributed operator: the
    outer tag-3 residual reads and the inner stepped CG all go through
    the sharded apply, matching the single-device refinement exactly."""
    from repro.solvers import solve_ir

    a, g = _poisson(16)
    b = _b_for(a)
    kw = dict(tol=1e-10, inner_tol=1e-4, inner_maxiter=1500, params=_PARAMS)
    ref = solve_ir(g, b, **kw)
    res = solve_ir(partition_gsecsr(g, 4), b, **kw)
    assert res.converged
    assert res.outer_iters == ref.outer_iters
    scale = float(jnp.max(jnp.abs(ref.x)))
    assert float(jnp.max(jnp.abs(res.x - ref.x))) < 1e-9 * scale


@multidevice
def test_dist_spmv_rejects_too_many_shards():
    from repro.kernels.dist_spmv import dist_spmv

    a, g = _poisson(8)
    part = partition_gsecsr(g, jax.device_count() + 1)
    with pytest.raises(ValueError, match="devices"):
        dist_spmv(part, jnp.zeros(a.shape[1]), tag=1)


# ---------------------------------------------------------------------------
# Single-device fallback: run the whole module under forced devices once
# ---------------------------------------------------------------------------

def test_suite_under_forced_devices():
    """Under plain tier-1 (single real CPU device) the multi-device tests
    above skip; this wrapper re-runs the module in ONE subprocess with
    8 forced host devices so the distributed contracts are always
    exercised.  No-op when the devices are already present (CI job)."""
    if jax.device_count() >= NEED:
        pytest.skip("already running with forced devices")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={NEED}")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.join(REPO, "tests", "test_distributed.py")],
        env=env, capture_output=True, text=True, timeout=1500,
    )
    assert r.returncode == 0, (
        f"forced-device re-run failed:\n{r.stdout[-4000:]}\n{r.stderr[-2000:]}"
    )
