"""Tests for the tag-specialized GSE SpMM pipeline (DESIGN.md §11).

Covers the batched-subsystem kernel acceptance criteria:

  * per-tag Pallas SpMM parity vs the ``spmm_gse`` reference vs
    column-by-column ``spmv_gse`` (the multi-RHS pass must be exactly the
    per-column math, amortized);
  * the tag-1/-2 ``pallas_call``s provably omit the unused tail operands
    -- the SpMM streams the SAME matrix segment list as the SpMV however
    many right-hand sides ride along (jaxpr operand-count inspection);
  * ``iteration_stream_bytes`` nrhs generalization: nrhs=1 identity,
    matrix bytes charged once, vector bytes per extra column.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import core as jcore

from repro.kernels import ops, ref
from repro.kernels.gse_spmm import gse_spmm_call, spmm_operand_names
from repro.kernels.gse_spmv import spmv_operand_names
from repro.sparse import generators as G
from repro.sparse.csr import (
    iteration_stream_bytes,
    pack_csr,
    vector_stream_bytes,
)
from repro.sparse.spmv import spmm, spmm_gse, spmv, spmv_gse


# ---------------------------------------------------------------------------
# Reference-path parity: spmm_gse == column-by-column spmv_gse, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tag", [1, 2, 3])
@pytest.mark.parametrize("nrhs", [1, 4])
def test_spmm_gse_matches_columnwise_spmv(tag, nrhs):
    """One decoded-value pass over nrhs columns must be numerically the
    per-column SpMV -- same gather, same segment reduction order."""
    a = G.random_spd(500, seed=tag)
    g = pack_csr(a, k=8)
    x = jnp.asarray(np.random.default_rng(tag).normal(size=(a.shape[1], nrhs)))
    y = np.asarray(spmm_gse(g, x, tag=tag))
    want = np.stack(
        [np.asarray(spmv_gse(g, x[:, j], tag=tag)) for j in range(nrhs)],
        axis=1,
    )
    np.testing.assert_array_equal(y, want)


@pytest.mark.parametrize("store", [jnp.float64, jnp.float16, jnp.bfloat16])
def test_spmm_fixed_matches_columnwise_spmv(store):
    a = G.poisson2d(16)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(a.shape[1], 3)))
    y = np.asarray(spmm(a, x, store_dtype=store))
    want = np.stack(
        [np.asarray(spmv(a, x[:, j], store_dtype=store)) for j in range(3)],
        axis=1,
    )
    np.testing.assert_array_equal(y, want)


def test_spmm_rejects_1d_operand():
    a = G.poisson2d(8)
    g = pack_csr(a, k=8)
    x1 = jnp.ones((a.shape[1],))
    with pytest.raises(ValueError, match="nrhs"):
        spmm(a, x1)
    with pytest.raises(ValueError, match="nrhs"):
        spmm_gse(g, x1, tag=1)


# ---------------------------------------------------------------------------
# Pallas kernel parity vs per-column ELL reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 8])  # ei_bit 1 / 3
@pytest.mark.parametrize("tag", [1, 2, 3])
def test_spmm_kernel_parity(k, tag):
    a = G.random_spd(500, seed=10 * k + tag)
    g = pack_csr(a, k=k)
    ell = ops.ell_pack_gsecsr(g, lane=128)
    x = jnp.asarray(
        np.random.default_rng(tag).normal(size=(a.shape[1], 4)), jnp.float32
    )
    out = ops.gse_spmm_ell(ell, g.table, x, g.ei_bit, tag=tag)
    want = np.stack(
        [np.asarray(ref.spmv_ell_ref(*ell, g.table, x[:, j], g.ei_bit, tag))
         for j in range(4)],
        axis=1,
    )
    assert out.shape == (a.shape[0], 4)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("tag", [1, 3])
def test_spmm_kernel_blocks_sweep(tag):
    """Wider tiles hit the multi-sublane-group reduction path per column."""
    a = G.poisson2d(16)
    g = pack_csr(a, k=8)
    ell = ops.ell_pack_gsecsr(g, lane=256)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(a.shape[1], 2)),
                    jnp.float32)
    want = np.stack(
        [np.asarray(ref.spmv_ell_ref(*ell, g.table, x[:, j], g.ei_bit, tag))
         for j in range(2)],
        axis=1,
    )
    for blocks in [(8, 128), (8, 256), (16, 256)]:
        out = ops.gse_spmm_ell(ell, g.table, x, g.ei_bit, tag=tag,
                               blocks=blocks)
        np.testing.assert_allclose(np.asarray(out), want, rtol=2e-5,
                                   atol=1e-4)


def test_spmm_kernel_matches_spmv_kernel_at_nrhs1():
    """An (n, 1) SpMM is exactly the SpMV kernel's math."""
    a = G.poisson2d(16)
    g = pack_csr(a, k=8)
    ell = ops.ell_pack_gsecsr(g, lane=128)
    x = jnp.asarray(np.random.default_rng(1).normal(size=a.shape[1]),
                    jnp.float32)
    for tag in (1, 2, 3):
        y1 = ops.gse_spmv_ell(ell, g.table, x, g.ei_bit, tag=tag)
        y2 = ops.gse_spmm_ell(ell, g.table, x[:, None], g.ei_bit, tag=tag)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2[:, 0]))


def test_spmm_dispatch_cache_is_stable():
    k1 = ops.spmm_kernel_for(1, 3, (8, 128), True)
    k2 = ops.spmm_kernel_for(1, 3, (8, 128), True)
    assert k1 is k2
    assert ops.spmm_kernel_for(2, 3, (8, 128), True) is not k1
    with pytest.raises(ValueError, match="tag"):
        ops.spmm_kernel_for(4, 3, (8, 128), True)


# ---------------------------------------------------------------------------
# Operand-count inspection: unused tails never enter the pallas_call
# ---------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            if isinstance(v, jcore.ClosedJaxpr):
                yield from _iter_eqns(v.jaxpr)
            elif isinstance(v, jcore.Jaxpr):
                yield from _iter_eqns(v)


def _spmm_pallas_call_invars(tag, nrhs):
    m, L, n, nk, ei = 8, 128, 64, 8, 3
    colpak = jnp.zeros((m, L), jnp.uint32)
    head = jnp.zeros((m, L), jnp.uint16)
    tail1 = jnp.zeros((m, L), jnp.uint16)
    tail2 = jnp.zeros((m, L), jnp.uint32)
    x = jnp.zeros((n, nrhs), jnp.float32)
    scales = jnp.ones((1, nk), jnp.float32)
    operands = {
        1: (colpak, head, None, None),
        2: (colpak, head, tail1, None),
        3: (colpak, head, tail1, tail2),
    }[tag]
    fn = functools.partial(gse_spmm_call, *operands, x, scales,
                           ei_bit=ei, tag=tag, interpret=True)
    jaxpr = jax.make_jaxpr(fn)()
    eqns = [e for e in _iter_eqns(jaxpr.jaxpr)
            if e.primitive.name == "pallas_call"]
    assert len(eqns) == 1, "expected exactly one pallas_call"
    return eqns[0].invars


@pytest.mark.parametrize("tag,n_operands", [(1, 4), (2, 5), (3, 6)])
@pytest.mark.parametrize("nrhs", [1, 4])
def test_spmm_pallas_operand_count_per_tag(tag, n_operands, nrhs):
    """The SpMM operand list is the SpMV operand list -- the matrix
    segments are streamed once whatever the batch width; tag-1/-2 never
    stream the unused tail segments."""
    invars = _spmm_pallas_call_invars(tag, nrhs)
    assert len(invars) == n_operands
    assert spmm_operand_names(tag) == spmv_operand_names(tag)


@pytest.mark.parametrize("nrhs", [1, 4])
def test_spmm_tag1_and_tag2_omit_tail_dtypes(nrhs):
    """No u32 (M,L) tail2 operand at tags 1/2; no u16 tail at tag 1."""
    def dtypes(tag):
        return sorted(str(v.aval.dtype) for v in
                      _spmm_pallas_call_invars(tag, nrhs))

    assert dtypes(1) == ["float32", "float32", "uint16", "uint32"]
    assert dtypes(2) == ["float32", "float32", "uint16", "uint16", "uint32"]
    assert dtypes(3) == ["float32", "float32", "uint16", "uint16", "uint32",
                         "uint32"]


# ---------------------------------------------------------------------------
# iteration_stream_bytes nrhs generalization
# ---------------------------------------------------------------------------

def test_iteration_stream_bytes_nrhs1_identity():
    """nrhs=1 must reproduce the single-RHS figures exactly (the fig89
    accounting is unchanged for every existing caller)."""
    a = G.random_spd(400, seed=3)
    g = pack_csr(a, k=8)
    from repro.solvers import make_jacobi

    m = make_jacobi(a, k=8)
    for tag in (1, 2, 3):
        assert iteration_stream_bytes(g, tag, nrhs=1) == (
            iteration_stream_bytes(g, tag)
        )
        assert iteration_stream_bytes(g, tag, m, nrhs=1) == (
            iteration_stream_bytes(g, tag, m)
        )
    assert iteration_stream_bytes(a, jnp.float64, nrhs=1) == (
        iteration_stream_bytes(a, jnp.float64)
    )


def test_iteration_stream_bytes_nrhs_scaling():
    """Matrix bytes once; each extra column adds exactly one x/y stream."""
    a = G.random_spd(400, seed=3)
    g = pack_csr(a, k=8)
    vec = vector_stream_bytes(g)
    for tag in (1, 2, 3):
        one = iteration_stream_bytes(g, tag, nrhs=1)
        for nrhs in (2, 4, 8):
            got = iteration_stream_bytes(g, tag, nrhs=nrhs)
            assert got == one + (nrhs - 1) * vec
            # far below nrhs independent passes
            assert got < nrhs * one
    with pytest.raises(ValueError, match="nrhs"):
        iteration_stream_bytes(g, 1, nrhs=0)


def test_iteration_stream_bytes_nrhs4_under_2x():
    """The acceptance bound: on a stream-dominated matrix the nrhs=4
    per-iteration bytes sit under 2x the nrhs=1 figure at every tag."""
    a = G.random_spd(600, seed=5)  # ~17 nnz/row: matrix stream dominates
    g = pack_csr(a, k=8)
    for tag in (1, 2, 3):
        one = iteration_stream_bytes(g, tag, nrhs=1)
        four = iteration_stream_bytes(g, tag, nrhs=4)
        assert four < 2 * one


# ---------------------------------------------------------------------------
# Hypothesis property tests over nrhs
# ---------------------------------------------------------------------------

try:  # optional dep (see requirements.txt): guarded so tier-1 collection
    from hypothesis import given as _given, settings as _settings  # noqa
    from hypothesis import strategies as _st

    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @_settings(max_examples=12, deadline=None)
    @_given(
        nrhs=_st.sampled_from([1, 2, 5, 8]),
        tag=_st.sampled_from([1, 2, 3]),
        seed=_st.integers(min_value=0, max_value=2**16),
    )
    def test_prop_spmm_columnwise_parity(nrhs, tag, seed):
        """For every nrhs in {1, 2, 5, 8}: spmm_gse equals the column-by-
        column spmv_gse bitwise, and the Pallas kernel agrees with the
        per-column ELL reference."""
        a = G.poisson2d(8)
        g = pack_csr(a, k=8)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(a.shape[1], nrhs)))
        y = np.asarray(spmm_gse(g, x, tag=tag))
        want = np.stack(
            [np.asarray(spmv_gse(g, x[:, j], tag=tag)) for j in range(nrhs)],
            axis=1,
        )
        np.testing.assert_array_equal(y, want)

        ell = ops.ell_pack_gsecsr(g, lane=128)
        xf = x.astype(jnp.float32)
        out = np.asarray(ops.gse_spmm_ell(ell, g.table, xf, g.ei_bit,
                                          tag=tag))
        kref = np.stack(
            [np.asarray(ref.spmv_ell_ref(*ell, g.table, xf[:, j], g.ei_bit,
                                         tag))
             for j in range(nrhs)],
            axis=1,
        )
        np.testing.assert_allclose(out, kref, rtol=2e-5, atol=1e-4)

    @_settings(max_examples=8, deadline=None)
    @_given(nrhs=_st.sampled_from([1, 2, 5, 8]))
    def test_prop_stream_bytes_monotone_in_nrhs(nrhs):
        a = G.poisson2d(8)
        g = pack_csr(a, k=8)
        prev = iteration_stream_bytes(g, 1, nrhs=nrhs)
        assert prev >= iteration_stream_bytes(g, 1)
        assert iteration_stream_bytes(g, 1, nrhs=nrhs + 1) > prev
