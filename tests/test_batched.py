"""Tests for the batched multi-RHS stepped-solver subsystem (DESIGN.md §11).

Acceptance criteria covered:

  * batched stepped CG on 4 RHS over a shared Poisson GSECSR produces
    per-column trajectories BIT-IDENTICAL to 4 independent ``solve_cg``
    runs (iterates, iteration counts, tag schedules, switch iterations);
  * per-column monitors: on a stalling system different columns step tags
    at their own iterations;
  * columns deactivate on convergence (per-column iteration counts);
  * ``batched_run_bytes`` charges matrix segment bytes once per
    iteration, not nrhs times;
  * single-RHS ``solve_cg``/``solve_pcg``/``solve_gmres`` accept (n,) and
    (n, 1) with clear ValueErrors on mismatches (the shape-normalization
    satellite the batched wrappers delegate through).
"""
import functools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as P
from repro.sparse import generators as G
from repro.sparse.csr import from_coo, iteration_stream_bytes, pack_csr
from repro.sparse.spmv import spmv
from repro.solvers import (
    batched_run_bytes,
    make_gse_operator,
    make_jacobi,
    solve_cg,
    solve_cg_batched,
    solve_gmres,
    solve_ir,
    solve_ir_batched,
    solve_pcg,
    solve_pcg_batched,
)
from repro.solvers.batched import column_tags_at


def _fast_params(**kw):
    d = dict(t=30, l=30, m=15, rsd_limit=0.5, reldec_limit=0.45)
    d.update(kw)
    return P.MonitorParams(**d)


def _rhs_block(a, nrhs, seed=0):
    rng = np.random.default_rng(seed)
    cols = [
        jnp.asarray(np.asarray(spmv(a, jnp.asarray(
            rng.normal(size=a.shape[1])))))
        for _ in range(nrhs)
    ]
    return jnp.stack(cols, axis=1)


@functools.lru_cache(maxsize=1)
def _stalling_spd():
    """SPD with eigenvalues down to 1e-6 (as in test_spmv_pipeline): the
    tag-1 decode error perturbs the small eigenvalues, so head-only CG
    genuinely stalls and the per-column controllers must step up."""
    rng = np.random.default_rng(7)
    n = 200
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    eigs = np.logspace(-6, 0, n)
    dense = (q * eigs) @ q.T
    dense = 0.5 * (dense + dense.T)
    rows, cols = np.nonzero(np.ones((n, n)))
    a = from_coo(rows, cols, dense[rows, cols], (n, n))
    return a, dense


def _assert_columns_match_independent(res, solver, op, b, nrhs, **kw):
    for j in range(nrhs):
        ind = solver(op, b[:, j], **kw)
        assert int(ind.iters) == int(res.iters[j]), f"col {j}"
        assert float(ind.relres) == float(res.relres[j]), f"col {j}"
        assert int(ind.tag) == int(res.tag[j]), f"col {j}"
        np.testing.assert_array_equal(
            np.asarray(ind.switch_iters), np.asarray(res.switch_iters[j])
        )
        np.testing.assert_array_equal(
            np.asarray(ind.x), np.asarray(res.x[:, j])
        )
        assert bool(ind.converged) == bool(res.converged[j])


# ---------------------------------------------------------------------------
# Acceptance: batched == independent, bit for bit
# ---------------------------------------------------------------------------

def test_batched_cg_4rhs_bit_identical_to_independent():
    """THE acceptance criterion: 4-RHS batched stepped CG over a shared
    Poisson GSECSR == 4 independent fused solve_cg runs, bitwise."""
    a = G.poisson2d(16)
    g = pack_csr(a, k=8)
    b = _rhs_block(a, 4, seed=0)
    kw = dict(tol=1e-8, maxiter=3000, params=_fast_params())
    res = solve_cg_batched(g, b, **kw)
    _assert_columns_match_independent(res, solve_cg, g, b, 4, **kw)
    # Columns deactivate independently: the per-column counts differ.
    assert len(set(np.asarray(res.iters).tolist())) > 1


def test_batched_cg_generic_operator_bit_identical():
    a = G.random_spd(300, seed=2)
    g = pack_csr(a, k=8)
    op = make_gse_operator(g)
    b = _rhs_block(a, 3, seed=2)
    kw = dict(tol=1e-8, maxiter=3000, params=_fast_params())
    res = solve_cg_batched(op, b, **kw)
    _assert_columns_match_independent(res, solve_cg, op, b, 3, **kw)


def test_batched_cg_per_column_tag_schedules():
    """On a stalling system each column steps tags on ITS OWN schedule
    (Loe et al.: precision schedules must adapt per solve)."""
    a, dense = _stalling_spd()
    g = pack_csr(a, k=8)
    rng = np.random.default_rng(3)
    cols = [jnp.asarray(dense @ rng.normal(size=a.shape[1]))
            for _ in range(3)]
    # Make one column trivially easy so it never needs to leave tag 1.
    cols.append(jnp.asarray(dense @ (1e-3 * np.ones(a.shape[1]))))
    b = jnp.stack(cols, axis=1)
    kw = dict(tol=1e-8, maxiter=20000, params=_fast_params(t=60, l=60, m=30))
    res = solve_cg_batched(g, b, **kw)
    assert bool(res.converged.all())
    tags = np.asarray(res.tag)
    assert tags[:3].max() >= 2          # the hard columns stepped
    _assert_columns_match_independent(res, solve_cg, g, b, 4, **kw)


def test_batched_pcg_bit_identical_and_deactivation():
    ill = G.ill_conditioned_spd(32, 8.0)
    g = pack_csr(ill, k=8)
    m = make_jacobi(ill, k=8)
    b = _rhs_block(ill, 3, seed=4)
    kw = dict(tol=1e-10, maxiter=20000, params=_fast_params())
    res = solve_pcg_batched(g, b, m, **kw)
    for j in range(3):
        ind = solve_pcg(g, b[:, j], m, **kw)
        assert int(ind.iters) == int(res.iters[j])
        np.testing.assert_array_equal(np.asarray(ind.x),
                                      np.asarray(res.x[:, j]))
        np.testing.assert_array_equal(np.asarray(ind.switch_iters),
                                      np.asarray(res.switch_iters[j]))


def test_batched_zero_column_converges_immediately():
    """A zero RHS (the service's padding column) does zero iterations and
    never perturbs its neighbours."""
    a = G.poisson2d(12)
    g = pack_csr(a, k=8)
    b = _rhs_block(a, 2, seed=5)
    bz = jnp.concatenate([b, jnp.zeros((a.shape[0], 1))], axis=1)
    kw = dict(tol=1e-8, maxiter=3000, params=_fast_params())
    res2 = solve_cg_batched(g, b, **kw)
    res3 = solve_cg_batched(g, bz, **kw)
    assert int(res3.iters[2]) == 0
    assert bool(res3.converged[2])
    np.testing.assert_array_equal(np.asarray(res3.x[:, :2]),
                                  np.asarray(res2.x))
    np.testing.assert_array_equal(np.asarray(res3.iters[:2]),
                                  np.asarray(res2.iters))


def test_batched_accepts_1d_rhs_and_rejects_bad_shapes():
    a = G.poisson2d(8)
    g = pack_csr(a, k=8)
    b = _rhs_block(a, 1, seed=6)[:, 0]
    kw = dict(tol=1e-8, maxiter=2000, params=_fast_params())
    res = solve_cg_batched(g, b, **kw)
    assert res.x.shape == (a.shape[0], 1)
    ind = solve_cg(g, b, **kw)
    np.testing.assert_array_equal(np.asarray(ind.x), np.asarray(res.x[:, 0]))
    with pytest.raises(ValueError, match="shape mismatch"):
        solve_cg_batched(g, b[:, None], x0=jnp.zeros((a.shape[0], 2)), **kw)
    with pytest.raises(ValueError, match="dtype mismatch"):
        solve_cg_batched(
            g, b[:, None], x0=jnp.zeros((a.shape[0], 1), jnp.float32), **kw
        )


# ---------------------------------------------------------------------------
# Batched iterative refinement
# ---------------------------------------------------------------------------

def test_batched_ir_matches_independent():
    ill = G.ill_conditioned_spd(24, 8.0)
    g = pack_csr(ill, k=8)
    m = make_jacobi(ill, k=8)
    b = _rhs_block(ill, 3, seed=7)
    kw = dict(tol=1e-11, max_outer=10, inner_tol=1e-4, inner_maxiter=4000,
              params=_fast_params())
    res = solve_ir_batched(g, b, precond=m, **kw)
    assert res.converged.all()
    for j in range(3):
        ind = solve_ir(g, b[:, j], inner="cg", precond=m, **kw)
        assert ind.outer_iters == int(res.outer_iters[j])
        assert ind.inner_iters == int(res.inner_iters[j])
        np.testing.assert_array_equal(np.asarray(ind.x),
                                      np.asarray(res.x[:, j]))
        np.testing.assert_allclose(ind.history, res.history[j], rtol=0,
                                   atol=0)


# ---------------------------------------------------------------------------
# Batched byte model
# ---------------------------------------------------------------------------

def test_column_tags_at_reconstruction():
    iters = np.array([10, 6, 0])
    sw = np.array([[3, 7], [-1, -1], [-1, -1]])
    assert column_tags_at(iters, sw, 0).tolist() == [1, 1, 0]
    assert column_tags_at(iters, sw, 3).tolist() == [2, 1, 0]
    assert column_tags_at(iters, sw, 6).tolist() == [2, 0, 0]
    assert column_tags_at(iters, sw, 7).tolist() == [3, 0, 0]
    assert column_tags_at(iters, sw, 10).tolist() == [0, 0, 0]


def test_batched_run_bytes_charges_matrix_once():
    """The whole-run account: matrix segment bytes once per iteration --
    strictly under nrhs independent runs, and equal to the single-RHS
    trajectory account at nrhs=1."""
    a = G.poisson2d(16)
    g = pack_csr(a, k=8)
    b = _rhs_block(a, 4, seed=8)
    kw = dict(tol=1e-8, maxiter=3000, params=_fast_params())
    res = solve_cg_batched(g, b, **kw)
    batched = batched_run_bytes(g, res.iters, res.switch_iters)
    independent = sum(
        batched_run_bytes(g, res.iters[j:j + 1], res.switch_iters[j:j + 1])
        for j in range(4)
    )
    assert batched < independent
    # nrhs=1 reduction: equals the per-iteration sum of the single run.
    j0 = batched_run_bytes(g, res.iters[:1], res.switch_iters[:1])
    want = sum(
        iteration_stream_bytes(
            g, int(column_tags_at(res.iters[:1], res.switch_iters[:1], i)[0])
        )
        for i in range(int(res.iters[0]))
    )
    assert j0 == want


def test_batched_run_bytes_nrhs4_under_2x_single():
    """Acceptance bound on the trajectory account: a 4-RHS batched run on
    the stream-dominated matrix costs < 2x ONE column's run (and the
    per-iteration figures behave the same way)."""
    a = G.random_spd(600, seed=5)
    g = pack_csr(a, k=8)
    b = _rhs_block(a, 4, seed=9)
    kw = dict(tol=1e-8, maxiter=3000, params=_fast_params())
    res = solve_cg_batched(g, b, **kw)
    assert bool(res.converged.all())
    four = batched_run_bytes(g, res.iters, res.switch_iters)
    one = batched_run_bytes(g, res.iters[:1], res.switch_iters[:1])
    # The single-column run does fewer iterations than the widest column;
    # normalize per iteration for the 2x bound.
    four_per_it = four / int(np.asarray(res.iters).max())
    one_per_it = one / int(res.iters[0])
    assert four_per_it < 2 * one_per_it


# ---------------------------------------------------------------------------
# Shape-normalization satellite: (n,) vs (n, 1) + clear errors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("solver", ["cg", "pcg", "gmres"])
def test_solvers_accept_column_vector_rhs(solver):
    a = G.poisson2d(8)
    g = pack_csr(a, k=8)
    b = _rhs_block(a, 1, seed=10)[:, 0]
    params = _fast_params()
    if solver == "cg":
        run = lambda bb, **kw: solve_cg(g, bb, tol=1e-8, maxiter=2000,
                                        params=params, **kw)
    elif solver == "pcg":
        m = make_jacobi(a, k=8)
        run = lambda bb, **kw: solve_pcg(g, bb, m, tol=1e-8, maxiter=2000,
                                         params=params, **kw)
    else:
        op = make_gse_operator(g)
        run = lambda bb, **kw: solve_gmres(op, bb, tol=1e-8, maxiter=2000,
                                           params=params, **kw)
    flat = run(b)
    colv = run(b[:, None])
    assert flat.x.shape == (a.shape[0],)
    assert colv.x.shape == (a.shape[0], 1)  # solution in b's layout
    np.testing.assert_array_equal(np.asarray(flat.x),
                                  np.asarray(colv.x[:, 0]))
    assert int(flat.iters) == int(colv.iters)
    # (n, 1) x0 with (n,) b is fine too (normalized to one layout).
    mixed = run(b, x0=jnp.zeros((a.shape[0], 1)))
    np.testing.assert_array_equal(np.asarray(flat.x), np.asarray(mixed.x))


@pytest.mark.parametrize("solver", ["cg", "pcg", "gmres"])
def test_solvers_reject_bad_rhs_shapes_and_dtypes(solver):
    a = G.poisson2d(8)
    g = pack_csr(a, k=8)
    n = a.shape[0]
    b = _rhs_block(a, 1, seed=11)[:, 0]
    if solver == "cg":
        run = lambda bb, **kw: solve_cg(g, bb, **kw)
    elif solver == "pcg":
        m = make_jacobi(a, k=8)
        run = lambda bb, **kw: solve_pcg(g, bb, m, **kw)
    else:
        op = make_gse_operator(g)
        run = lambda bb, **kw: solve_gmres(op, bb, **kw)
    with pytest.raises(ValueError, match=r"\(n,\) or \(n, 1\)"):
        run(jnp.zeros((n, 2)))
    with pytest.raises(ValueError, match=r"\(n,\) or \(n, 1\)"):
        run(jnp.zeros((2, 3, 4)))
    with pytest.raises(ValueError, match="x0 must be"):
        run(b, x0=jnp.zeros((n, 3)))
    with pytest.raises(ValueError, match="shape mismatch"):
        run(b, x0=jnp.zeros((n + 1,)))
    with pytest.raises(ValueError, match="dtype mismatch"):
        run(b, x0=jnp.zeros((n,), jnp.float32))


def test_final_correction_preserves_rhs_layout():
    a = G.random_spd(300, seed=12)
    g = pack_csr(a, k=8)
    b = _rhs_block(a, 1, seed=12)
    res = solve_cg(g, b, tol=1e-6, maxiter=6000, params=_fast_params(),
                   final_correction=True)
    assert res.x.shape == b.shape
