"""Robustness tier-1 tests (DESIGN.md §14).

Covers the guardrail + recovery stack end to end: in-loop guard health on
every solver, guards-on/off bit-identity, tag-escalation recovery from
deterministic low-tag faults, the adversarial-input matrix
(NaN/Inf/zero right-hand sides, tol=0, maxiter=0, indefinite operators),
the NaN-mid-window monitor regression, pack/cache/wire integrity
checksums with seeded fault injection, the bounded ``_cached_pack`` LRU,
and the solve service's degradation contract (intake validation, bounded
tag-3 retries, deadlines, never-raise / never-unflagged-nonfinite).

Wire-checksum tests need 2 devices; under plain tier-1 they skip and
``test_wire_suite_under_forced_devices`` re-runs them in one subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=2``.
"""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as P
from repro.robustness.faults import (
    GSECSR_SEGMENTS,
    bitflip_array,
    corrupt_gsecsr,
    corrupt_pack_cache,
    gsecsr_checksums,
    make_tag_fault_operator,
    make_wire_fault,
    verify_gsecsr,
)
from repro.robustness.guards import (
    DEFAULT_GUARDS,
    GuardParams,
    HEALTH_BREAKDOWN,
    HEALTH_NONFINITE,
    HEALTH_OK,
    HEALTH_STALLED,
    health_name,
)
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.sparse.spmv import spmv
from repro.solvers.cg import solve_cg, solve_pcg
from repro.solvers.gmres import solve_gmres
from repro.solvers.precond import make_jacobi

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
NEED_WIRE = 2
wire_devices = pytest.mark.skipif(
    jax.device_count() < NEED_WIRE,
    reason=f"needs {NEED_WIRE} devices; covered by the subprocess re-run",
)

_PARAMS = P.MonitorParams(t=40, l=60, m=30, rsd_limit=0.5, reldec_limit=0.45)


def _sys(n=16, seed=3):
    csr = G.poisson2d(n)
    g = pack_csr(csr, k=8)
    rng = np.random.default_rng(seed)
    b = spmv(csr, jnp.asarray(rng.normal(size=csr.shape[1])))
    return csr, g, b


def _finite(x) -> bool:
    return bool(jnp.isfinite(jnp.vdot(x, x)))


# ---------------------------------------------------------------------------
# Guard health on clean solves + bit-identity with guards off
# ---------------------------------------------------------------------------

def test_clean_solve_health_ok():
    _, g, b = _sys()
    res = solve_cg(g, b, tol=1e-8, maxiter=2000, params=_PARAMS)
    assert bool(res.converged)
    assert int(res.health) == HEALTH_OK
    assert int(res.trip_iter) == -1
    assert health_name(int(res.health)) == "ok"


@pytest.mark.parametrize("pcg", [False, True])
def test_guards_on_off_bit_identical(pcg):
    csr, g, b = _sys()
    kw = dict(tol=1e-8, maxiter=2000, params=_PARAMS)
    if pcg:
        m = make_jacobi(csr)
        on = solve_pcg(g, b, m, guards=DEFAULT_GUARDS, **kw)
        off = solve_pcg(g, b, m, guards=None, **kw)
    else:
        on = solve_cg(g, b, guards=DEFAULT_GUARDS, **kw)
        off = solve_cg(g, b, guards=None, **kw)
    assert int(on.iters) == int(off.iters)
    assert np.array_equal(np.asarray(on.x), np.asarray(off.x))
    assert np.array_equal(np.asarray(on.switch_iters),
                          np.asarray(off.switch_iters))


def test_custom_guard_params_are_static_jit_args():
    _, g, b = _sys()
    tight = GuardParams(div_factor=1e3, stall_window=50)
    res = solve_cg(g, b, tol=1e-8, maxiter=2000, params=_PARAMS, guards=tight)
    assert bool(res.converged) and int(res.health) == HEALTH_OK


# ---------------------------------------------------------------------------
# Tag-escalation recovery from deterministic low-tag faults
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["indefinite", "nan"])
@pytest.mark.parametrize("fail_tag", [1, 2])
def test_low_tag_fault_recovers_by_escalation(mode, fail_tag):
    _, g, b = _sys()
    op = make_tag_fault_operator(g, mode=mode, fail_tag=fail_tag)
    res = solve_cg(op, b, tol=1e-8, maxiter=3000, params=_PARAMS)
    assert bool(res.converged), (mode, fail_tag)
    assert int(res.health) == HEALTH_OK  # convergence overrides the trip
    assert int(res.trip_iter) >= 0       # ...but the trip is still reported
    assert int(res.tag) > fail_tag       # escaped the faulty rungs
    assert _finite(res.x)
    # the escalation is byte-accounted: the promotion to tag fail_tag+1
    # landed in switch_iters
    assert int(np.asarray(res.switch_iters)[fail_tag - 1]) >= 0


def test_pcg_recovery_from_indefinite_tag1():
    csr, g, b = _sys()
    m = make_jacobi(csr)
    op = make_tag_fault_operator(g, mode="indefinite", fail_tag=1)
    res = solve_pcg(op, b, m, tol=1e-8, maxiter=3000, params=_PARAMS)
    assert bool(res.converged) and int(res.health) == HEALTH_OK
    assert int(res.trip_iter) >= 0 and int(res.tag) > 1


def test_recover_false_reports_breakdown():
    _, g, b = _sys()
    op = make_tag_fault_operator(g, mode="indefinite", fail_tag=1)
    res = solve_cg(op, b, tol=1e-8, maxiter=3000, params=_PARAMS,
                   recover=False)
    assert not bool(res.converged)
    assert int(res.health) == HEALTH_BREAKDOWN
    assert int(res.trip_iter) == 0
    assert _finite(res.x)  # rolled back to the last finite checkpoint


def test_unrecoverable_fault_stays_flagged_and_finite():
    """Indefinite at EVERY tag: escalation runs out of rungs; the result
    must be flagged, unconverged, and still finite (the checkpoint)."""
    _, g, b = _sys()
    op = make_tag_fault_operator(g, mode="indefinite", fail_tag=3)
    res = solve_cg(op, b, tol=1e-8, maxiter=3000, params=_PARAMS)
    assert not bool(res.converged)
    assert int(res.health) == HEALTH_BREAKDOWN
    assert int(res.tag) == 3
    assert _finite(res.x)


# ---------------------------------------------------------------------------
# Adversarial-input matrix (satellite d)
# ---------------------------------------------------------------------------

def _adversarial_b(kind, b):
    if kind == "nan":
        return b.at[0].set(jnp.nan)
    if kind == "inf":
        return b.at[1].set(jnp.inf)
    return jnp.zeros_like(b)  # "zero"


@pytest.mark.parametrize("solver", ["cg", "pcg", "gmres"])
@pytest.mark.parametrize("kind", ["nan", "inf", "zero"])
def test_adversarial_rhs_never_unflagged_nonfinite(solver, kind):
    csr, g, b = _sys()
    b = _adversarial_b(kind, b)
    if solver == "cg":
        res = solve_cg(g, b, tol=1e-8, maxiter=500, params=_PARAMS)
    elif solver == "pcg":
        res = solve_pcg(g, b, make_jacobi(csr), tol=1e-8, maxiter=500,
                        params=_PARAMS)
    else:
        from repro.solvers.cg import _gsecsr_operator
        res = solve_gmres(_gsecsr_operator(g), b, tol=1e-8, restart=20,
                          maxiter=200, params=_PARAMS)
    if kind == "zero":
        # ||b|| = 0: the zero solution satisfies the system immediately
        assert bool(res.converged) and int(res.health) == HEALTH_OK
        assert _finite(res.x) and float(jnp.abs(res.x).max()) == 0.0
    else:
        assert not bool(res.converged)
        assert int(res.health) == HEALTH_NONFINITE
        assert _finite(res.x)  # the checkpoint, never the poisoned iterate


@pytest.mark.parametrize("solver", ["cg", "pcg"])
def test_tol_zero_and_maxiter_zero_flag_cleanly(solver):
    csr, g, b = _sys()
    m = make_jacobi(csr)

    def run(**kw):
        if solver == "pcg":
            return solve_pcg(g, b, m, params=_PARAMS, **kw)
        return solve_cg(g, b, params=_PARAMS, **kw)

    res = run(tol=0.0, maxiter=60)
    assert not bool(res.converged)
    assert int(res.health) == HEALTH_STALLED  # clean exhaustion, no trip
    assert _finite(res.x)

    res0 = run(tol=1e-8, maxiter=0)
    assert int(res0.iters) == 0 and not bool(res0.converged)
    assert int(res0.health) == HEALTH_STALLED and int(res0.trip_iter) == -1
    assert _finite(res0.x)


def test_indefinite_operator_flagged_via_generic_path():
    """A genuinely indefinite operator (not a tag fault): CG must trip
    breakdown instead of silently iterating on garbage."""
    _, g, b = _sys()

    def indefinite(v, tag):
        from repro.solvers.cg import _gsecsr_operator
        return -_gsecsr_operator(g)(v, tag)

    res = solve_cg(indefinite, b, tol=1e-8, maxiter=500, params=_PARAMS)
    assert not bool(res.converged)
    assert int(res.health) == HEALTH_BREAKDOWN
    assert _finite(res.x)


# ---------------------------------------------------------------------------
# Monitor NaN-mid-window regression (satellite a)
# ---------------------------------------------------------------------------

def test_monitor_survives_nan_mid_window():
    st = P.init(_PARAMS)
    for i in range(10):
        st = P.record(st, jnp.asarray(1.0 / (i + 1)))
    st = P.record(st, jnp.asarray(jnp.nan))   # breakdown iteration
    st = P.record(st, jnp.asarray(jnp.inf))
    for i in range(_PARAMS.t):
        st = P.record(st, jnp.asarray(1e-3 / (i + 1)))
    assert bool(jnp.isfinite(st.hist).all())  # sentinel, not NaN, entered
    rsd, ndec, reldec = P.metrics(st)
    assert bool(jnp.isfinite(rsd)) and bool(jnp.isfinite(reldec))
    # switching is still alive after the poisoned window has rolled off
    st2 = P.update_tag(st, _PARAMS)
    assert int(st2.tag) >= int(st.tag)


# ---------------------------------------------------------------------------
# Pack-segment checksums + seeded bit-flip faults
# ---------------------------------------------------------------------------

def test_bitflip_is_seeded_xor_involution():
    a = np.arange(64, dtype=np.float64)
    once = bitflip_array(a, seed=5, nflips=3)
    assert not np.array_equal(once, a)
    twice = bitflip_array(once, seed=5, nflips=3)  # same positions: undo
    assert np.array_equal(twice, a)


@pytest.mark.parametrize("target", GSECSR_SEGMENTS)
def test_pack_segment_corruption_detected(target):
    _, g, _ = _sys()
    ref = gsecsr_checksums(g)
    assert verify_gsecsr(g, ref) == []  # clean operand verifies clean
    for seed in (0, 1, 2):
        bad = corrupt_gsecsr(g, target, seed)
        assert target in verify_gsecsr(bad, ref), (target, seed)
        assert verify_gsecsr(g, ref) == []  # original untouched


def test_table_flip_perturbs_decode():
    """The shared-exponent table is the high-leverage target: one flip in
    a REFERENCED entry rescales a whole group, so the decoded SpMV must
    actually change.  (A seeded flip may land in an unused padding entry
    -- silent for the decode, though still checksum-detected -- so pick a
    group the packed column indices actually point at.)"""
    import dataclasses

    from repro.sparse.spmv import spmv_gse

    _, g, b = _sys()
    used = int(np.unique(np.asarray(g.colpak) >> (32 - g.ei_bit))[0])
    table = np.asarray(g.table).copy()
    table[used] ^= 1  # +-1 on the shared exponent: the whole group scales
    bad = dataclasses.replace(g, table=jnp.asarray(table))
    y0 = np.asarray(spmv_gse(g, b, tag=1))
    y1 = np.asarray(spmv_gse(bad, b, tag=1))
    assert not np.array_equal(y0, y1)


# ---------------------------------------------------------------------------
# Bounded _cached_pack LRU + checksum detect-and-repack (satellite c)
# ---------------------------------------------------------------------------

def test_pack_cache_lru_bound_and_evictions():
    from repro.kernels.ops import PACK_CACHE_MAX, PACK_STATS, _cached_pack

    class Holder:
        pass

    a = Holder()
    ev0 = PACK_STATS["evictions"]
    extra = 3
    for i in range(PACK_CACHE_MAX + extra):
        _cached_pack(a, ("key", i), lambda i=i: (np.arange(4) + i,))
    assert len(a._pack_cache) == PACK_CACHE_MAX
    assert PACK_STATS["evictions"] == ev0 + extra
    # least-recently-used entries (the first `extra`) were the ones dropped
    assert ("key", 0) not in a._pack_cache
    assert ("key", PACK_CACHE_MAX + extra - 1) in a._pack_cache
    # a hit refreshes recency: touch the oldest survivor, add one more,
    # and the touched entry must survive while its neighbor is evicted
    oldest = ("key", extra)
    _cached_pack(a, oldest, lambda: pytest.fail("hit must not rebuild"))
    _cached_pack(a, ("key", 999), lambda: (np.arange(4),))
    assert oldest in a._pack_cache
    assert ("key", extra + 1) not in a._pack_cache


def test_pack_cache_corruption_detected_and_repacked():
    from repro.kernels.ops import PACK_STATS, sell_pack_gsecsr

    _, g, b = _sys(seed=9)
    clean = sell_pack_gsecsr(g)
    ref = [np.asarray(leaf).copy()
           for leaf in jax.tree_util.tree_leaves(clean)]
    assert corrupt_pack_cache(g, seed=0)
    before = PACK_STATS["corrupt"]
    repacked = sell_pack_gsecsr(g)  # hit -> checksum mismatch -> repack
    assert PACK_STATS["corrupt"] == before + 1
    for got, want in zip(jax.tree_util.tree_leaves(repacked), ref):
        assert np.array_equal(np.asarray(got), want)
    # the repacked entry is healthy: the next hit does not re-detect
    sell_pack_gsecsr(g)
    assert PACK_STATS["corrupt"] == before + 1


# ---------------------------------------------------------------------------
# Wire checksums (2 devices; subprocess re-run under plain tier-1)
# ---------------------------------------------------------------------------

def test_wire_checksum_sees_high_bits_of_f64():
    """Regression: a flip in bits 32-63 of a float64 wire element must
    change the u32 checksum (the mod-2^32 mask would otherwise erase it
    without the high-half fold)."""
    from repro.distributed.wire import wire_checksum

    arr = jnp.asarray(np.random.default_rng(0).normal(size=32))
    ck = wire_checksum(arr)
    raw = np.asarray(arr).copy()
    raw.view(np.uint64)[3] ^= np.uint64(1) << np.uint64(40)
    assert int(wire_checksum(jnp.asarray(raw))) != int(ck)
    for dtype in (np.uint16, np.uint64):
        a = np.arange(16, dtype=dtype)
        bad = bitflip_array(a, seed=1)
        assert int(wire_checksum(jnp.asarray(a))) != \
            int(wire_checksum(jnp.asarray(bad)))


def _wire_harness(tag, wire):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as Spec

    from repro.distributed.wire import halo_all_gather

    mesh = Mesh(np.array(jax.devices()[:2]), ("sh",))
    return shard_map(
        lambda bnd: halo_all_gather(bnd, "sh", tag=tag, wire=wire,
                                    check=True)[1],
        mesh=mesh, in_specs=Spec("sh"), out_specs=Spec(), check_rep=False,
    )


_WIRE_COMBOS = [("gse", 1, "head"), ("gse", 1, "table"),
                ("gse", 2, "tail1"), ("exact", 3, "raw"), ("gse", 3, "raw")]


@wire_devices
@pytest.mark.parametrize("wire,tag,target", _WIRE_COMBOS)
def test_wire_fault_detected_by_receivers(wire, tag, target):
    from repro.distributed.wire import set_wire_fault

    full = jnp.asarray(np.random.default_rng(4).normal(size=64))
    fn = _wire_harness(tag, wire)
    assert bool(fn(full))  # clean payload verifies
    for seed in (0, 1, 2):
        set_wire_fault(make_wire_fault(target, seed))
        try:
            assert not bool(fn(full)), (wire, tag, target, seed)
        finally:
            set_wire_fault(None)
    assert bool(fn(full))  # hook cleared: clean again


def test_wire_suite_under_forced_devices():
    """Re-run the wire tests with 2 forced host devices when tier-1 runs
    on a single device (same pattern as tests/test_distributed.py)."""
    if jax.device_count() >= NEED_WIRE:
        pytest.skip("already running with enough devices")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count"
                         f"={NEED_WIRE}")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.join(REPO, "tests", "test_robustness.py"),
         "-k", "wire_fault_detected"],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, (
        f"forced-device re-run failed:\n{r.stdout[-4000:]}\n{r.stderr[-2000:]}"
    )


# ---------------------------------------------------------------------------
# Batched solver health
# ---------------------------------------------------------------------------

def test_batched_nan_column_isolated_and_flagged():
    from repro.solvers.batched import solve_cg_batched

    csr, g, b1 = _sys()
    rng = np.random.default_rng(8)
    cols = [b1] + [spmv(csr, jnp.asarray(rng.normal(size=csr.shape[1])))
                   for _ in range(2)]
    b = jnp.stack(cols, axis=1).at[0, 1].set(jnp.nan)
    res = solve_cg_batched(g, b, tol=1e-8, maxiter=2000, params=_PARAMS)
    health = np.asarray(res.health)
    conv = np.asarray(res.converged)
    assert not conv[1] and health[1] == HEALTH_NONFINITE
    assert conv[0] and conv[2]
    assert health[0] == HEALTH_OK and health[2] == HEALTH_OK
    assert bool(jnp.isfinite(res.x).all())  # poisoned column frozen finite


def test_batched_guards_on_off_bit_identical():
    from repro.solvers.batched import solve_cg_batched

    csr, g, b1 = _sys()
    rng = np.random.default_rng(12)
    b = jnp.stack(
        [b1, spmv(csr, jnp.asarray(rng.normal(size=csr.shape[1])))], axis=1)
    on = solve_cg_batched(g, b, tol=1e-8, maxiter=2000, params=_PARAMS,
                          guards=DEFAULT_GUARDS)
    off = solve_cg_batched(g, b, tol=1e-8, maxiter=2000, params=_PARAMS,
                           guards=None)
    assert np.array_equal(np.asarray(on.x), np.asarray(off.x))
    assert np.array_equal(np.asarray(on.iters), np.asarray(off.iters))


# ---------------------------------------------------------------------------
# Iterative refinement health
# ---------------------------------------------------------------------------

def test_ir_health_clean_and_poisoned():
    from repro.solvers.ir import solve_ir

    _, g, b = _sys()
    res = solve_ir(g, b, tol=1e-10, inner_tol=1e-4, params=_PARAMS)
    assert bool(res.converged) and res.health == HEALTH_OK

    bad = make_tag_fault_operator(g, mode="nan", fail_tag=3)
    res2 = solve_ir(bad, b, tol=1e-10, max_outer=3, inner_tol=1e-4,
                    params=_PARAMS)
    assert not bool(res2.converged)
    assert res2.health != HEALTH_OK
    assert _finite(res2.x)  # the NaN correction was never folded in


# ---------------------------------------------------------------------------
# Sharded solver health (1 shard runs on any device count)
# ---------------------------------------------------------------------------

def test_sharded_single_shard_health_and_parity():
    from repro.distributed.partition import partition_gsecsr

    _, g, b = _sys()
    part = partition_gsecsr(g, 1)
    res = solve_cg(part, b, tol=1e-8, maxiter=2000, params=_PARAMS)
    ref = solve_cg(g, b, tol=1e-8, maxiter=2000, params=_PARAMS)
    assert bool(res.converged) and int(res.health) == HEALTH_OK
    assert int(res.trip_iter) == -1
    assert np.array_equal(np.asarray(res.x), np.asarray(ref.x))


# ---------------------------------------------------------------------------
# Serving degradation (satellite b + tentpole serving piece)
# ---------------------------------------------------------------------------

def _service(**kw):
    import repro.launch.solver_serve as S

    csr, g, b = _sys()
    svc = S.SolverService(params=_PARAMS, maxiter=3000, **kw)
    svc.register("p", csr, k=8)
    return S, svc, csr, b


def test_submit_validates_shape_dtype_finiteness():
    S, svc, csr, b = _service(slots=2)
    n = csr.shape[0]
    with pytest.raises(KeyError):
        svc.submit("nope", b)
    with pytest.raises(ValueError):
        svc.submit("p", np.zeros(n - 1))
    with pytest.raises(ValueError):
        svc.submit("p", np.arange(n))  # integer dtype
    bad = np.zeros(n)
    bad[5] = np.inf
    with pytest.raises(ValueError):
        svc.submit("p", bad)
    with pytest.raises(ValueError):
        svc.submit("p", b, x0=np.full(n, np.nan))
    with pytest.raises(ValueError):
        svc.submit("p", b, deadline_s=0.0)
    assert svc._pending == []  # nothing slipped through
    rid = svc.submit("p", np.asarray(b).reshape(n, 1))  # (n,1) normalizes
    assert svc.flush()[rid].converged


def test_flush_clean_reports_health_ok():
    S, svc, csr, b = _service(slots=2)
    rid = svc.submit("p", b)
    r = svc.flush()[rid]
    assert r.converged and r.health == "ok" and r.retries == 0
    assert not r.deadline_exceeded
    assert _finite(svc.solution(rid))


def _nan_first_column(real):
    def wrapper(*args, **kw):
        res = real(*args, **kw)
        return res._replace(
            x=res.x.at[:, 0].set(jnp.nan),
            converged=res.converged.at[0].set(False),
            health=jnp.asarray(res.health).at[0].set(HEALTH_NONFINITE),
        )
    return wrapper


def test_degraded_column_recovers_via_tag3_retry(monkeypatch):
    S, svc, csr, b = _service(slots=2, max_retries=1)
    monkeypatch.setattr(S, "solve_cg_batched",
                        _nan_first_column(S.solve_cg_batched))
    rid = svc.submit("p", b)
    r = svc.flush()[rid]
    assert r.converged and r.health == "ok"
    assert r.retries == 1 and r.tag == 3
    assert _finite(svc.solution(rid))
    assert svc.stats["retries"] == 1


def test_lapsed_deadline_suppresses_retry(monkeypatch):
    S, svc, csr, b = _service(slots=2, max_retries=1)
    monkeypatch.setattr(S, "solve_cg_batched",
                        _nan_first_column(S.solve_cg_batched))
    rid = svc.submit("p", b, deadline_s=0.005)
    time.sleep(0.02)
    r = svc.flush()[rid]
    assert not r.converged and r.deadline_exceeded and r.retries == 0
    assert r.health == "nonfinite"  # flagged, not silently wrong
    assert svc.stats["deadline_exceeded"] == 1


def test_flush_never_raises_on_solver_error(monkeypatch):
    S, svc, csr, b = _service(slots=2)

    def boom(*args, **kw):
        raise RuntimeError("synthetic slot failure")

    monkeypatch.setattr(S, "solve_cg_batched", boom)
    rid = svc.submit("p", b)
    r = svc.flush()[rid]  # must not raise
    assert r.health == "error" and not r.converged
    assert svc.stats["errors"] == 1
    with pytest.raises(KeyError):
        svc.solution(rid)  # no solution is published for an errored slot


def test_no_unflagged_nonfinite_solution_ever(monkeypatch):
    """Even with retries exhausted the published solution is either
    finite or its report is flagged."""
    S, svc, csr, b = _service(slots=2, max_retries=0)
    monkeypatch.setattr(S, "solve_cg_batched",
                        _nan_first_column(S.solve_cg_batched))
    rid = svc.submit("p", b)
    r = svc.flush()[rid]
    assert not r.converged and r.health != "ok"
