"""Correctness of the §Perf hillclimb variants vs their baselines.

Optimizations must not change semantics: grouped MoE dispatch == sort
dispatch (same routing, up to capacity-drop boundary effects), chunked
attention == naive attention, gather-cast is numerically identical.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.models.config import ModelConfig


def _moe_cfg(**kw):
    base = dict(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, moe_d_ff=64, vocab_size=97,
        num_experts=8, experts_per_token=2, capacity_factor=4.0,
        moe_groups=4, compute_dtype=jnp.float32,
    )
    base.update(kw)
    return ModelConfig(**base)


def test_grouped_moe_matches_sort_dispatch():
    cfg = _moe_cfg()
    p, _ = MOE.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32), jnp.float32)
    y_sort, aux_s = MOE.moe_apply(p, x, cfg, dispatch="sort")
    y_grp, aux_g = MOE.moe_apply(p, x, cfg, dispatch="grouped")
    # capacity_factor=4 -> no drops in either path -> identical routing
    np.testing.assert_allclose(np.asarray(y_grp), np.asarray(y_sort),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_g), float(aux_s), rtol=1e-5)


def test_grouped_moe_matches_dense_reference():
    cfg = _moe_cfg()
    p, _ = MOE.moe_init(jax.random.key(2), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (2, 8, 32), jnp.float32)
    y_dense, _ = MOE.moe_apply(p, x, cfg, dispatch="dense")
    y_grp, _ = MOE.moe_apply(p, x, cfg, dispatch="grouped")
    np.testing.assert_allclose(np.asarray(y_grp), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-5)


def test_grouped_moe_grad_flows():
    cfg = _moe_cfg()
    p, _ = MOE.moe_init(jax.random.key(4), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(5), (2, 16, 32), jnp.float32)

    def loss(p):
        y, aux = MOE.moe_apply(p, x, cfg, dispatch="grouped")
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0


def test_chunked_attention_matches_naive():
    base = configs.get_config("qwen3_4b", smoke=True)
    cfg_n = dataclasses.replace(base, attn_impl="naive",
                                compute_dtype=jnp.float32)
    cfg_c = dataclasses.replace(base, attn_impl="chunked", attn_chunk=8,
                                compute_dtype=jnp.float32)
    params, _ = T.init_params(cfg_n, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 32), 0,
                                base.vocab_size)
    h_n, _ = T.forward(cfg_n, params, tokens)
    h_c, _ = T.forward(cfg_c, params, tokens)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_n),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_with_local_window():
    base = configs.get_config("recurrentgemma_2b", smoke=True)
    cfg_n = dataclasses.replace(base, attn_impl="naive",
                                compute_dtype=jnp.float32)
    cfg_c = dataclasses.replace(base, attn_impl="chunked", attn_chunk=8,
                                compute_dtype=jnp.float32)
    params, _ = T.init_params(cfg_n, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 32), 0,
                                base.vocab_size)
    h_n, _ = T.forward(cfg_n, params, tokens)
    h_c, _ = T.forward(cfg_c, params, tokens)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_n),
                               rtol=2e-4, atol=2e-4)


def test_gather_cast_identity_outside_mesh():
    base = configs.get_config("granite_3_2b", smoke=True)
    cfg_g = dataclasses.replace(base, cast_before_gather=True)
    params, _ = T.init_params(base, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                base.vocab_size)
    h0, _ = T.forward(base, params, tokens)
    h1, _ = T.forward(cfg_g, params, tokens)
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))


def test_chunked_attention_grad():
    base = configs.get_config("granite_3_2b", smoke=True)
    cfg_c = dataclasses.replace(base, attn_impl="chunked", attn_chunk=8,
                                compute_dtype=jnp.float32)
    params, _ = T.init_params(cfg_c, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 32), 0,
                                base.vocab_size)

    def loss(p):
        h, _ = T.forward(cfg_c, p, tokens)
        return jnp.sum(h.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(params)
    gnorm = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0


def test_gse_serve_weights_close_to_dense():
    """gse_serve=True: weights stored as u16 GSE-SEM segments; forward
    output must track the dense model (same random init values packed)."""
    base = configs.get_config("granite_3_2b", smoke=True)
    cfg_d = dataclasses.replace(base, compute_dtype=jnp.float32)
    cfg_q2 = dataclasses.replace(base, gse_serve=True, gse_tag=2,
                                 compute_dtype=jnp.float32)
    cfg_q1 = dataclasses.replace(base, gse_serve=True, gse_tag=1,
                                 compute_dtype=jnp.float32)
    params_d, _ = T.init_params(cfg_d, jax.random.key(0))
    params_q, specs_q = T.init_params(cfg_q2, jax.random.key(0))
    # segment dicts present for linear weights
    assert isinstance(params_q["layers"]["mlp"]["w_up"], dict)
    assert params_q["layers"]["mlp"]["w_up"]["head"].dtype == jnp.uint16
    assert specs_q["layers"]["mlp"]["w_up"]["head"] == ("layers", "embed",
                                                        "mlp")
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                base.vocab_size)
    h_d, _ = T.forward(cfg_d, params_d, tokens)
    h_q2, _ = T.forward(cfg_q2, params_q, tokens)
    h_q1, _ = T.forward(cfg_q1, params_q, tokens)
    err2 = float(jnp.abs(h_q2 - h_d).max() / jnp.abs(h_d).max())
    err1 = float(jnp.abs(h_q1 - h_d).max() / jnp.abs(h_d).max())
    assert err2 < 1e-4, err2       # tag2 ~ f32-grade
    assert err1 < 0.1, err1        # tag1: 12-bit mantissa quantization
    assert err2 < err1             # precision ladder


def test_gse_serve_decode_runs():
    base = configs.get_config("qwen3_4b", smoke=True)
    cfg = dataclasses.replace(base, gse_serve=True, gse_tag=1)
    params, _ = T.init_params(cfg, jax.random.key(0))
    state = T.decode_state_init(cfg, 2, max_len=8)
    logits, state = T.decode_step(cfg, params, state,
                                  jnp.zeros((2,), jnp.int32),
                                  jnp.asarray(0, jnp.int32))
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_kv_u8_roundtrip_error():
    from repro.models.attention import _kv_decode_u8, _kv_pack_u8

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 8, 2, 16)).astype(np.float32))
    u = _kv_pack_u8(x)
    assert u.dtype == jnp.uint8
    d = np.asarray(_kv_decode_u8(u, jnp.float32))
    rel = np.abs(d - np.asarray(x)) / np.maximum(np.abs(np.asarray(x)), 1e-6)
    # 4-bit mantissa + shared exponents: <= ~2^-4 relative for in-range.
    inr = np.abs(np.asarray(x)) > 2.0 ** -9
    assert np.median(rel[inr]) < 0.07
    assert np.sign(d[inr]).tolist() == np.sign(np.asarray(x)[inr]).tolist()


def test_kv_u8_decode_close_to_dense_cache():
    base = configs.get_config("qwen3_4b", smoke=True)
    cfg_d = dataclasses.replace(base, compute_dtype=jnp.float32)
    cfg_q = dataclasses.replace(base, compute_dtype=jnp.float32,
                                kv_cache_gse=True)
    params, _ = T.init_params(cfg_d, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 8), 0,
                                base.vocab_size)
    s_d = T.decode_state_init(cfg_d, 2, max_len=8)
    s_q = T.decode_state_init(cfg_q, 2, max_len=8)
    assert s_q["layers"]["k"].dtype == jnp.uint8
    errs = []
    for pos in range(8):
        l_d, s_d = T.decode_step(cfg_d, params, s_d, tokens[:, pos],
                                 jnp.asarray(pos, jnp.int32))
        l_q, s_q = T.decode_step(cfg_q, params, s_q, tokens[:, pos],
                                 jnp.asarray(pos, jnp.int32))
        errs.append(float(jnp.abs(
            jax.nn.softmax(l_q) - jax.nn.softmax(l_d)).max()))
    assert max(errs) < 0.15, errs  # 8-bit cache shifts probs mildly
