"""Test configuration.

NOTE: we deliberately do NOT set XLA_FLAGS / host device count here --
smoke tests and benchmarks must see the single real CPU device.  Only
launch/dryrun.py (and the distributed tests that spawn subprocesses) use
placeholder device counts.

float64 is enabled because the paper's reference arithmetic is FP64; model
code passes explicit dtypes everywhere so this does not perturb LM tests.
"""
import jax

jax.config.update("jax_enable_x64", True)
