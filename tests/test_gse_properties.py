"""Property tests (hypothesis): GSE-SEM format invariants.

Split out of test_gse.py and guarded with ``pytest.importorskip`` so tier-1
collection passes from a clean checkout (hypothesis is optional -- see
requirements.txt); the property tests still run wherever it is installed.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import gse  # noqa: E402

finite_floats = st.floats(
    allow_nan=False,
    allow_infinity=False,
    allow_subnormal=False,
    min_value=-1e100,
    max_value=1e100,
)


@settings(max_examples=60, deadline=None)
@given(
    st.lists(finite_floats, min_size=1, max_size=200),
    st.sampled_from([2, 4, 8, 16]),
)
def test_prop_decode_monotone_precision(vals, k):
    arr = np.asarray(vals, np.float64)
    p = gse.pack(arr, k)
    d1, d2, d3 = (gse.decode(p, t) for t in (1, 2, 3))
    e1 = np.abs(d1 - arr)
    e2 = np.abs(d2 - arr)
    e3 = np.abs(d3 - arr)
    assert (e2 <= e1 + 1e-300).all()
    assert (e3 <= e2 + 1e-300).all()


@settings(max_examples=60, deadline=None)
@given(st.lists(finite_floats, min_size=1, max_size=200))
def test_prop_full_precision_bounded_relative_error(vals):
    arr = np.asarray(vals, np.float64)
    p = gse.pack(arr, 8)
    dec = gse.decode(p, 3)
    nz = arr != 0
    if nz.any():
        # Packing rounds to nearest (RNE on the discarded shift bits), so
        # decode may overshoot by up to half an ulp -- but the error never
        # exceeds the value itself (flush-to-zero is the worst case) and
        # the sign never flips.
        assert (np.abs(dec[nz] - arr[nz]) <= np.abs(arr[nz]) * (1 + 1e-12)).all()
        assert ((np.sign(dec[nz]) == np.sign(arr[nz])) | (dec[nz] == 0)).all()


def _tag3_ulp(p: gse.GSEPacked) -> np.ndarray:
    """Per-element ulp of the W-bit stored mantissa: 2^(E_sh - W)."""
    table = np.asarray(p.table).astype(np.int64)
    h = np.asarray(p.head).astype(np.uint32)
    m_h = 15 - p.ei_bit
    exp_idx = (h >> m_h) & ((1 << p.ei_bit) - 1)
    e_sh = table[exp_idx] - 1023
    return np.ldexp(1.0, e_sh - p.width)


@settings(max_examples=80, deadline=None)
@given(
    st.lists(finite_floats, min_size=1, max_size=200),
    st.sampled_from([2, 4, 8, 16]),
)
def test_prop_pack_rounds_to_nearest_half_ulp(vals, k):
    """Packing performs round-to-nearest-even on the bits the mantissa
    shift discards, so the tag-3 decode error is <= 0.5 ulp of the W-bit
    mantissa (truncation would allow a full ulp).  The only exception is
    the saturated all-ones mantissa (carry past W), still within 1 ulp."""
    arr = np.asarray(vals, np.float64)
    p = gse.pack(arr, k)
    dec = gse.decode(p, 3)
    ulp = _tag3_ulp(p)
    err = np.abs(dec - arr)
    # Reconstruct the stored integer mantissa to spot the saturated case.
    m_h = 15 - p.ei_bit
    m = (
        ((np.asarray(p.head).astype(np.uint64) & ((1 << m_h) - 1)) << np.uint64(48))
        | (np.asarray(p.tail1).astype(np.uint64) << np.uint64(32))
        | np.asarray(p.tail2).astype(np.uint64)
    )
    saturated = m == (np.uint64(1) << np.uint64(p.width)) - np.uint64(1)
    bound = np.where(saturated, 1.0, 0.5) * ulp
    assert (err <= bound * (1 + 1e-12)).all()


@settings(max_examples=40, deadline=None)
@given(st.lists(finite_floats, min_size=1, max_size=100))
def test_prop_decode_jnp_equals_numpy(vals):
    arr = np.asarray(vals, np.float64)
    p = gse.pack(arr, 8)
    for tag in (1, 2, 3):
        np.testing.assert_array_equal(
            np.asarray(gse.decode_jnp(p, tag, jnp.float64)), gse.decode(p, tag)
        )
