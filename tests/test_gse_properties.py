"""Property tests (hypothesis): GSE-SEM format invariants.

Split out of test_gse.py and guarded with ``pytest.importorskip`` so tier-1
collection passes from a clean checkout (hypothesis is optional -- see
requirements.txt); the property tests still run wherever it is installed.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import gse  # noqa: E402

finite_floats = st.floats(
    allow_nan=False,
    allow_infinity=False,
    allow_subnormal=False,
    min_value=-1e100,
    max_value=1e100,
)


@settings(max_examples=60, deadline=None)
@given(
    st.lists(finite_floats, min_size=1, max_size=200),
    st.sampled_from([2, 4, 8, 16]),
)
def test_prop_decode_monotone_precision(vals, k):
    arr = np.asarray(vals, np.float64)
    p = gse.pack(arr, k)
    d1, d2, d3 = (gse.decode(p, t) for t in (1, 2, 3))
    e1 = np.abs(d1 - arr)
    e2 = np.abs(d2 - arr)
    e3 = np.abs(d3 - arr)
    assert (e2 <= e1 + 1e-300).all()
    assert (e3 <= e2 + 1e-300).all()


@settings(max_examples=60, deadline=None)
@given(st.lists(finite_floats, min_size=1, max_size=200))
def test_prop_full_precision_bounded_relative_error(vals):
    arr = np.asarray(vals, np.float64)
    p = gse.pack(arr, 8)
    dec = gse.decode(p, 3)
    nz = arr != 0
    if nz.any():
        rel = np.abs(dec[nz] - arr[nz]) / np.abs(arr[nz])
        # Worst case: value sits just below a table entry 2^52 away... but the
        # max-exponent entry guarantees minDiff <= (e_max+1 - e_min). Values
        # >= max/2^40 keep >= width-41 bits. We assert the universal bound:
        # decode never overshoots and never flips sign.
        assert (np.sign(dec[nz]) == np.sign(arr[nz])).sum() >= (
            (rel < 1.0).sum()
        )
        assert (np.abs(dec[nz]) <= np.abs(arr[nz]) * (1 + 1e-12)).all()


@settings(max_examples=40, deadline=None)
@given(st.lists(finite_floats, min_size=1, max_size=100))
def test_prop_decode_jnp_equals_numpy(vals):
    arr = np.asarray(vals, np.float64)
    p = gse.pack(arr, 8)
    for tag in (1, 2, 3):
        np.testing.assert_array_equal(
            np.asarray(gse.decode_jnp(p, tag, jnp.float64)), gse.decode(p, tag)
        )
