"""Launch-layer tests: HLO analyzer, mesh, shapes, fault-tolerant restart.

The 512-device dry-run itself runs via ``python -m repro.launch.dryrun``
(results in dryrun_results/); here we test the machinery at small scale --
including an 8-device subprocess that exercises the same sharding path.
"""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo as H

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
ENV.pop("XLA_FLAGS", None)


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

def test_analyze_scan_equals_unroll():
    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def f_unroll(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    a_s = H.analyze(jax.jit(f_scan).lower(x, w).compile().as_text())
    a_u = H.analyze(jax.jit(f_unroll).lower(x, w).compile().as_text())
    expected = 2 * 128 * 256 * 256 * 10
    assert a_s["flops"] == pytest.approx(expected, rel=0.05)
    assert a_u["flops"] == pytest.approx(expected, rel=0.05)
    assert a_s["bytes"] == pytest.approx(a_u["bytes"], rel=0.25)


def test_collective_wire_formulas():
    text = textwrap.dedent("""\
    ENTRY %main (p: f32[64,64]) -> f32[64,64] {
      %p = f32[64,64]{1,0} parameter(0)
      %ag = f32[64,64]{1,0} all-gather(%p), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={0}
      %ar = f32[64,64]{1,0} all-reduce(%ag), channel_id=2, replica_groups={{0,1,2,3}}, to_apply=%add
      ROOT %cp = f32[64,64]{1,0} collective-permute(%ar), channel_id=3, source_target_pairs={{0,1}}
    }
    """)
    total, by_kind, counts = H.collective_bytes(text)
    b = 64 * 64 * 4
    assert by_kind["all-gather"] == pytest.approx(b * 3 / 4)
    assert by_kind["all-reduce"] == pytest.approx(2 * b * 3 / 4)
    assert by_kind["collective-permute"] == pytest.approx(b)
    assert counts["all-gather"] == 1


def test_mesh_shapes():
    # make_mesh with 512 fake devices only works in the dryrun subprocess;
    # here just validate the requested shapes/axes.
    import inspect

    from repro.launch import mesh

    src = inspect.getsource(mesh.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src


def test_shapes_and_skips():
    from repro import configs
    from repro.launch import shapes as SHP

    cells = SHP.cells()
    assert len(cells) == 40  # 10 archs x 4 shapes
    skipped = [(a, s) for a, s, ok, _ in cells if not ok]
    assert len(skipped) == 8  # long_500k for the 8 full-attention archs
    assert all(s == "long_500k" for _, s in skipped)
    runnable = [(a, s) for a, s, ok, _ in cells if ok]
    assert ("rwkv6_1p6b", "long_500k") in runnable
    assert ("recurrentgemma_2b", "long_500k") in runnable

    cfg = configs.get_config("qwen3_4b")
    spec = SHP.input_specs(cfg, "train_4k")
    assert spec["tokens"].shape == (256, 4096)
    cfg_e = configs.get_config("seamless_m4t_large_v2")
    spec_e = SHP.input_specs(cfg_e, "prefill_32k")
    assert spec_e["enc_embeds"].shape == (32, 16384, 1024)
    assert spec_e["tokens"].shape == (32, 16384)


# ---------------------------------------------------------------------------
# 8-device subprocess: the dryrun sharding path at mini scale
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_mini_mesh_lower_compile():
    code = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec
    from repro import configs
    from repro.distributed import sharding as SH
    from repro.launch.dryrun import _ns
    from repro.launch import hlo as H
    from repro.models import stepfns, transformer as T
    from repro.optim import AdamW

    cfg = configs.get_config("granite_3_2b", smoke=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = configs.get_rules("granite_3_2b")
    with SH.axis_rules(rules, mesh):
        captured = {}
        def ip(k):
            p, s = T.init_params(cfg, k); captured["s"] = s; return p
        pshapes = jax.eval_shape(ip, jax.random.key(0))
        params_sh = _ns(mesh, captured["s"], rules, pshapes)
        opt = AdamW(total_steps=100)
        state_shapes = stepfns.TrainState(
            params=pshapes, opt_state=jax.eval_shape(opt.init, pshapes),
            step=jax.ShapeDtypeStruct((), jnp.int32))
        opt_sh = type(jax.eval_shape(opt.init, pshapes))(mu=params_sh, nu=params_sh)
        state_sh = stepfns.TrainState(params=params_sh, opt_state=opt_sh,
                                      step=NamedSharding(mesh, PartitionSpec()))
        batch = {
            "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
            "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((4, 32), jnp.float32),
        }
        batch_sh = _ns(mesh, {k: ("batch", "seq") for k in batch}, rules, batch)
        step = stepfns.make_train_step(cfg, opt)
        with mesh:
            compiled = jax.jit(step, in_shardings=(state_sh, batch_sh),
                               donate_argnums=(0,)).lower(state_shapes, batch).compile()
    text = compiled.as_text()
    total, kinds, counts = H.collective_bytes(text)
    assert total > 0, "sharded train step must contain collectives"
    assert "all-reduce" in kinds or "reduce-scatter" in kinds
    mem = compiled.memory_analysis()
    assert mem.argument_size_in_bytes > 0
    print("MINI_OK", int(total))
    """)
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MINI_OK" in r.stdout


# ---------------------------------------------------------------------------
# Fault tolerance: failure mid-run -> restart resumes from checkpoint
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_failure_restart_resume_exact():
    with tempfile.TemporaryDirectory() as d:
        base = [sys.executable, "-m", "repro.launch.train",
                "--arch", "granite_3_2b", "--smoke", "--steps", "14",
                "--batch", "2", "--seq", "32",
                "--ckpt-dir", d, "--ckpt-every", "5"]
        r1 = subprocess.run(base + ["--simulate-failure-at", "9"],
                            env=ENV, capture_output=True, text=True,
                            timeout=900)
        assert r1.returncode == 17, r1.stderr[-2000:]  # simulated crash
        r2 = subprocess.run(base, env=ENV, capture_output=True, text=True,
                            timeout=900)
        assert r2.returncode == 0, r2.stderr[-2000:]
        assert "resumed from step" in r2.stdout
        # a full uninterrupted run must produce the same final loss
        with tempfile.TemporaryDirectory() as d2:
            r3 = subprocess.run(
                [sys.executable, "-m", "repro.launch.train",
                 "--arch", "granite_3_2b", "--smoke", "--steps", "14",
                 "--batch", "2", "--seq", "32",
                 "--ckpt-dir", d2, "--ckpt-every", "50"],
                env=ENV, capture_output=True, text=True, timeout=900)
        last2 = [l for l in r2.stdout.splitlines() if l.startswith("step")][-1]
        last3 = [l for l in r3.stdout.splitlines() if l.startswith("step")][-1]
        loss2 = float(last2.split("loss")[1].split()[0])
        loss3 = float(last3.split("loss")[1].split()[0])
        assert loss2 == pytest.approx(loss3, rel=1e-4), (last2, last3)


@pytest.mark.slow
def test_elastic_restore_different_mesh():
    """Checkpoint saved under one mesh restores re-sharded onto another
    (elastic restart: pod count changes, training continues)."""
    code = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import ckpt

    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
    tree = {"w": jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, tree, step=1)
        target = {"w": NamedSharding(mesh_b, P("data", "model"))}
        restored, step, _ = ckpt.restore(d, 1, tree, target_sharding=target)
    assert restored["w"].sharding.mesh.devices.shape == (4, 2)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
    print("ELASTIC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC_OK" in r.stdout


@pytest.mark.slow
def test_compressed_psum_wire_u16():
    """shard_map GSE-SEM all-reduce: u16 payloads on the wire, result
    tracks the exact f32 psum (tag-2: ~f32-grade for clustered grads)."""
    code = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.wire import compressed_psum

    # jax.shard_map + check_vma are newer-jax spellings; fall back to
    # jax.experimental.shard_map / check_rep on the pinned 0.4.x.
    import inspect
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map
    _chk = ("check_vma" if "check_vma" in inspect.signature(shard_map).parameters
            else "check_rep")

    mesh = jax.make_mesh((8,), ("pod",))
    n = 8 * 1024
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(8, n)).astype(np.float32))

    def body(gs):
        return compressed_psum(gs[0], "pod")

    sm = shard_map(body, mesh=mesh, in_specs=P("pod"), out_specs=P(None),
                   **{_chk: False})
    out = jax.jit(sm)(g)
    exact = np.asarray(g).sum(0)
    rel = np.abs(np.asarray(out) - exact) / np.maximum(np.abs(exact), 1e-3)
    assert np.median(rel) < 1e-4, np.median(rel)

    # the wire really moves u16: collectives in HLO carry u16 operands
    txt = jax.jit(sm).lower(g).compile().as_text()
    import re
    coll = [l for l in txt.splitlines()
            if re.search(r"= \\S+ (all-to-all|all-gather)\\(", l)]
    assert any("u16" in l for l in coll), coll[:5]
    # no f32 all-to-all/all-gather of the payload size
    big_f32 = [l for l in coll if "f32[8,1024]" in l]
    assert not big_f32, big_f32
    print("WIRE_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "WIRE_OK" in r.stdout
