"""Sparse module tests: CSR construction, GSE-SEM CSR, SpMV operators."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import csr as C
from repro.sparse import generators as G
from repro.sparse import spmv as S


def _dense(a):
    rp = np.asarray(a.rowptr)
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    m, n = a.shape
    d = np.zeros((m, n))
    for i in range(m):
        for j in range(rp[i], rp[i + 1]):
            d[i, col[j]] += val[j]
    return d


def test_from_coo_sums_duplicates():
    a = C.from_coo([0, 0, 1], [1, 1, 0], [2.0, 3.0, 4.0], (2, 2))
    d = _dense(a)
    np.testing.assert_array_equal(d, [[0, 5], [4, 0]])


def test_poisson2d_spd_structure():
    a = G.poisson2d(8)
    d = _dense(a)
    np.testing.assert_array_equal(d, d.T)
    w = np.linalg.eigvalsh(d)
    assert w.min() > 0  # SPD


def test_convdiff_asymmetric():
    a = G.convection_diffusion_2d(8)
    d = _dense(a)
    assert not np.allclose(d, d.T)


def test_spmv_matches_dense():
    a = G.poisson2d(10)
    x = np.random.default_rng(0).normal(size=a.shape[1])
    y = np.asarray(S.spmv(a, jnp.asarray(x)))
    np.testing.assert_allclose(y, _dense(a) @ x, rtol=1e-12)


@pytest.mark.parametrize("fmt", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_spmv_low_precision_storage(fmt):
    a = G.poisson2d(10)
    x = np.ones(a.shape[1])
    y = np.asarray(S.spmv(a, jnp.asarray(x), store_dtype=fmt))
    ref = _dense(a) @ x
    # Stencil values (+-1, 4) are exact in all three formats.
    np.testing.assert_allclose(y, ref, rtol=1e-6)


@pytest.mark.parametrize("tag,rtol", [(1, 2e-4), (2, 2e-9), (3, 1e-14)])
def test_spmv_gse_precision_ladder(tag, rtol):
    a = G.random_spd(400, seed=1)
    g = C.pack_csr(a, k=8)
    x = np.random.default_rng(1).normal(size=a.shape[1])
    y = np.asarray(S.spmv_gse(g, jnp.asarray(x), tag=tag))
    ref = _dense(a) @ x
    np.testing.assert_allclose(y, ref, rtol=rtol, atol=rtol * np.abs(ref).max())


def test_gse_head_beats_fp16_bf16_on_clustered_values():
    """Paper Fig 6 claim: 16-bit GSE-SEM head error << FP16/BF16 error."""
    a = G.circuit_like(2000, seed=3)
    g = C.pack_csr(a, k=8)
    x = jnp.ones(a.shape[1], jnp.float64)  # paper sets x = 1
    ref = _dense(a) @ np.ones(a.shape[1])
    err_gse = np.abs(np.asarray(S.spmv_gse(g, x, tag=1)) - ref).max()
    err_bf16 = np.abs(np.asarray(S.spmv(a, x, store_dtype=jnp.bfloat16)) - ref).max()
    err_fp16 = np.abs(np.asarray(S.spmv(a, x, store_dtype=jnp.float16)) - ref).max()
    assert err_gse < err_bf16
    assert err_gse < err_fp16


def test_ell_roundtrip_and_spmv():
    a = G.convection_diffusion_2d(12)
    cols, vals, L = C.to_ell(a, lane=8)
    assert L % 8 == 0
    x = np.random.default_rng(2).normal(size=a.shape[1])
    y = np.asarray(S.spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x)))
    np.testing.assert_allclose(y, _dense(a) @ x, rtol=1e-12)


def test_colpak_roundtrip():
    a = G.random_spd(300, seed=5)
    g = C.pack_csr(a, k=8)
    _, col = S.decode_gsecsr(g, tag=3)
    np.testing.assert_array_equal(np.asarray(col), np.asarray(a.col))


def test_colpak_overflow_guard():
    # 2^29 columns would collide with EI bits for k=8 -> must raise.
    big = C.CSR(
        rowptr=jnp.asarray([0, 1], jnp.int32),
        col=jnp.asarray([1 << 29], jnp.int32),
        val=jnp.asarray([1.0]),
        row_ids=jnp.asarray([0], jnp.int32),
        shape=(1, 1 << 30),
    )
    with pytest.raises(ValueError):
        C.pack_csr(big, k=8)


def test_generated_suites_have_clustered_exponents():
    from repro.core.gse import exponent_stats

    for name, a in G.spmv_suite(small=True).items():
        st = exponent_stats(np.asarray(a.val))
        # rescaled (unequilibrated) members intentionally spread exponents
        thresh = 0.25 if "_rs" in name or "overflow" in name else 0.5
        assert st["top8"] > thresh, (name, st["top8"])
