"""Tests for the resilient async solve service (DESIGN.md §17).

Acceptance criteria covered:

  * chunked execution is BIT-IDENTICAL to unchunked for every solver
    family -- cg/pcg (fused and generic), batched, and IR -- across tag
    switches (chunk boundaries are pure extra exit conditions, never
    arithmetic);
  * a column joining a running batched solve at a chunk boundary is
    bit-identical to a solo solve, and the columns already in flight are
    unperturbed (continuous batching);
  * checkpoints round-trip solver state exactly; a CORRUPT checkpoint is
    detected (pytree CRC32) and the solve falls back to the previous
    good one, reproducing the exact trajectory;
  * the per-handle circuit breaker walks closed -> open -> half-open ->
    closed/open with seeded-jitter backoff;
  * a lapsed deadline returns the last checkpoint FLAGGED (never a
    silent drop), and admission control sheds with typed responses.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as CK
from repro.core import precision as P
from repro.robustness.faults import make_tag_fault_operator
from repro.robustness.guards import DEFAULT_GUARDS
from repro.serve import (
    Accepted,
    AsyncSolveService,
    BatchedChunks,
    BreakerParams,
    CircuitBreaker,
    IRChunks,
    Shed,
    SolveChunks,
)
from repro.solvers.ir import solve_ir
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.sparse.spmv import spmv
from repro.solvers import make_gse_operator, make_jacobi, solve_cg, solve_pcg


def _params():
    return P.MonitorParams(t=30, l=30, m=15, rsd_limit=0.5, reldec_limit=0.45)


def _rhs(a, seed):
    rng = np.random.default_rng(seed)
    return spmv(a, jnp.asarray(rng.normal(size=a.shape[1])))


class _Clock:
    """Injectable fake clock: deadline/breaker tests advance time
    explicitly instead of sleeping."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _run_chunked(driver, k, budget=500):
    for _ in range(budget):
        driver.run_chunk(k)
        if driver.done:
            break
    assert driver.done
    return driver


# ---------------------------------------------------------------------------
# Chunked == unchunked, bit for bit, per solver family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 7, 64])
def test_chunked_cg_fused_bit_identical(k):
    a = G.poisson2d(12)
    g = pack_csr(a, k=8)
    b = _rhs(a, 0)
    ref = solve_cg(g, b, tol=1e-10, maxiter=2000, params=_params(),
                   guards=DEFAULT_GUARDS)
    drv = _run_chunked(SolveChunks(g, b, tol=1e-10, maxiter=2000,
                                   params=_params(), guards=DEFAULT_GUARDS),
                       k)
    res = drv.res
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref.x))
    assert int(res.iters) == int(ref.iters)
    assert float(res.relres) == float(ref.relres)
    np.testing.assert_array_equal(np.asarray(res.switch_iters),
                                  np.asarray(ref.switch_iters))


def test_chunked_cg_across_tags_bit_identical():
    # SPD with eigenvalues down to 1e-6: tag-1 CG genuinely stalls, so
    # the monitor MUST step tags mid-solve -- chunk boundaries straddle
    # tag switches and the resumed run must replay the same schedule.
    rng = np.random.default_rng(7)
    n = 200
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    eigs = np.logspace(-6, 0, n)
    dense = (q * eigs) @ q.T
    dense = 0.5 * (dense + dense.T)
    rows, cols = np.nonzero(np.ones((n, n)))
    from repro.sparse.csr import from_coo

    a = from_coo(rows, cols, dense[rows, cols], (n, n))
    g = pack_csr(a, k=8)
    b = jnp.asarray(dense @ rng.normal(size=n))
    op = make_gse_operator(g)
    params = P.MonitorParams(t=60, l=60, m=30,
                             rsd_limit=0.5, reldec_limit=0.45)
    ref = solve_cg(op, b, tol=1e-8, maxiter=20000, params=params,
                   guards=DEFAULT_GUARDS)
    assert int(np.asarray(ref.switch_iters)[0]) > 0  # really switched
    drv = _run_chunked(SolveChunks(op, b, tol=1e-8, maxiter=20000,
                                   params=params, guards=DEFAULT_GUARDS),
                       k=97, budget=2000)
    res = drv.res
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref.x))
    assert int(res.iters) == int(ref.iters)
    np.testing.assert_array_equal(np.asarray(res.switch_iters),
                                  np.asarray(ref.switch_iters))


def test_chunked_cg_generic_bit_identical():
    a = G.poisson2d(12)
    g = pack_csr(a, k=8)
    op = make_gse_operator(g)
    b = _rhs(a, 1)
    ref = solve_cg(op, b, tol=1e-8, maxiter=2000, params=_params(),
                   guards=DEFAULT_GUARDS)
    drv = _run_chunked(SolveChunks(op, b, tol=1e-8, maxiter=2000,
                                   params=_params(), guards=DEFAULT_GUARDS),
                       5)
    np.testing.assert_array_equal(np.asarray(drv.res.x), np.asarray(ref.x))
    assert int(drv.res.iters) == int(ref.iters)


def test_chunked_pcg_bit_identical():
    a = G.poisson2d(12)
    g = pack_csr(a, k=8)
    m = make_jacobi(a, k=8)
    b = _rhs(a, 2)
    ref = solve_pcg(g, b, m, tol=1e-10, maxiter=2000, params=_params(),
                    guards=DEFAULT_GUARDS)
    drv = _run_chunked(SolveChunks(g, b, tol=1e-10, maxiter=2000,
                                   params=_params(), guards=DEFAULT_GUARDS,
                                   precond=m),
                       9)
    np.testing.assert_array_equal(np.asarray(drv.res.x), np.asarray(ref.x))
    assert int(drv.res.iters) == int(ref.iters)
    np.testing.assert_array_equal(np.asarray(drv.res.switch_iters),
                                  np.asarray(ref.switch_iters))


def test_chunked_batched_bit_identical():
    from repro.solvers import solve_cg_batched

    a = G.poisson2d(12)
    g = pack_csr(a, k=8)
    b = jnp.stack([_rhs(a, s) for s in range(3)], axis=1)
    ref = solve_cg_batched(g, b, tol=1e-8, maxiter=2000, params=_params(),
                           guards=DEFAULT_GUARDS)
    drv = _run_chunked(BatchedChunks(g, b, tol=1e-8, maxiter=2000,
                                     params=_params(),
                                     guards=DEFAULT_GUARDS),
                       6)
    np.testing.assert_array_equal(np.asarray(drv.res.x), np.asarray(ref.x))
    np.testing.assert_array_equal(np.asarray(drv.res.iters),
                                  np.asarray(ref.iters))
    np.testing.assert_array_equal(np.asarray(drv.res.switch_iters),
                                  np.asarray(ref.switch_iters))


def test_chunked_ir_bit_identical():
    a = G.poisson2d(10)
    g = pack_csr(a, k=8)
    b = _rhs(a, 3)
    ref = solve_ir(g, b, tol=1e-11, max_outer=8, inner_tol=1e-4,
                   params=_params(), guards=DEFAULT_GUARDS)
    drv = IRChunks(g, b, tol=1e-11, max_outer=8, inner_tol=1e-4,
                   params=_params(), guards=DEFAULT_GUARDS)
    while not drv.done:
        drv.run_chunk(1)  # one outer correction per chunk
    res = drv.result()
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref.x))
    assert res.outer_iters == ref.outer_iters
    assert res.inner_iters == ref.inner_iters
    assert res.relres == ref.relres
    np.testing.assert_array_equal(res.history, ref.history)


# ---------------------------------------------------------------------------
# Continuous batching: join at a chunk boundary
# ---------------------------------------------------------------------------

def test_join_at_boundary_column_parity():
    """A column joined mid-run matches a solo solve bitwise, and the
    original column's trajectory is untouched by the join."""
    a = G.poisson2d(12)
    g = pack_csr(a, k=8)
    b0, b1 = _rhs(a, 0), _rhs(a, 1)
    solo0 = solve_cg(g, b0, tol=1e-8, maxiter=2000, params=_params(),
                     guards=DEFAULT_GUARDS)
    solo1 = solve_cg(g, b1, tol=1e-8, maxiter=2000, params=_params(),
                     guards=DEFAULT_GUARDS)

    drv = BatchedChunks(g, b0[:, None], tol=1e-8, maxiter=2000,
                        params=_params(), guards=DEFAULT_GUARDS)
    drv.run_chunk(10)
    drv.run_chunk(10)
    j = drv.join(b1)  # joins 20 iterations into column 0's run
    assert j == 1
    _run_chunked(drv, 10)
    s0, s1 = drv.col_snapshot(0), drv.col_snapshot(1)
    np.testing.assert_array_equal(np.asarray(s0["x"]), np.asarray(solo0.x))
    assert s0["iters"] == int(solo0.iters)
    np.testing.assert_array_equal(np.asarray(s1["x"]), np.asarray(solo1.x))
    assert s1["iters"] == int(solo1.iters)
    np.testing.assert_array_equal(s1["switch_iters"],
                                  np.asarray(solo1.switch_iters))


# ---------------------------------------------------------------------------
# Checkpoint/resume: CRC round-trip, corrupt fallback
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip_resume_bit_identical(tmp_path):
    a = G.poisson2d(12)
    g = pack_csr(a, k=8)
    b = _rhs(a, 4)
    ref = solve_cg(g, b, tol=1e-8, maxiter=2000, params=_params(),
                   guards=DEFAULT_GUARDS)

    path = str(tmp_path / "ck")
    drv = SolveChunks(g, b, tol=1e-8, maxiter=2000, params=_params(),
                      guards=DEFAULT_GUARDS)
    drv.run_chunk(8)
    drv.save_state(path)
    drv.run_chunk(8)
    drv.save_state(path)

    # A fresh driver resumes from the newest checkpoint and finishes with
    # the exact unchunked trajectory.
    drv2 = SolveChunks(g, b, tol=1e-8, maxiter=2000, params=_params(),
                       guards=DEFAULT_GUARDS)
    skipped = drv2.restore_state(path)
    assert skipped == [] and drv2.chunks == 2
    _run_chunked(drv2, 8)
    np.testing.assert_array_equal(np.asarray(drv2.res.x), np.asarray(ref.x))
    assert int(drv2.res.iters) == int(ref.iters)


def test_ckpt_corrupt_falls_back_to_previous_good(tmp_path):
    a = G.poisson2d(12)
    g = pack_csr(a, k=8)
    b = _rhs(a, 5)
    ref = solve_cg(g, b, tol=1e-8, maxiter=2000, params=_params(),
                   guards=DEFAULT_GUARDS)

    path = str(tmp_path / "ck")
    drv = SolveChunks(g, b, tol=1e-8, maxiter=2000, params=_params(),
                      guards=DEFAULT_GUARDS)
    drv.run_chunk(8)
    drv.save_state(path)
    drv.run_chunk(8)
    drv.save_state(path)

    # Corrupt the NEWEST checkpoint's blob on disk.
    blob = os.path.join(path, "step_00000002", "ckpt.msgpack.zst")
    data = bytearray(open(blob, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(blob, "wb").write(bytes(data))

    drv2 = SolveChunks(g, b, tol=1e-8, maxiter=2000, params=_params(),
                       guards=DEFAULT_GUARDS)
    skipped = drv2.restore_state(path)
    assert skipped == [2] and drv2.chunks == 1  # previous good step
    _run_chunked(drv2, 8)
    # The lost chunk re-ran; the trajectory is still exact.
    np.testing.assert_array_equal(np.asarray(drv2.res.x), np.asarray(ref.x))
    assert int(drv2.res.iters) == int(ref.iters)


def test_ckpt_tree_crc_detects_content_tamper(tmp_path):
    """The satellite bugfix: a checkpoint whose DECODED contents drift
    from the stamped pytree CRC raises CheckpointCorrupt (the old code
    only hashed the compressed blob)."""
    import json

    tree = {"x": np.arange(8, dtype=np.float64), "it": np.int32(3)}
    path = str(tmp_path / "ck")
    CK.save(path, tree, step=1)
    meta_p = os.path.join(path, "step_00000001", "meta.json")
    meta = json.load(open(meta_p))
    assert "tree_crc32" in meta
    meta["tree_crc32"] ^= 1
    json.dump(meta, open(meta_p, "w"))
    with pytest.raises(CK.CheckpointCorrupt):
        CK.restore(path, 1, tree)
    assert CK.restore_latest_valid(path, tree) is None


# ---------------------------------------------------------------------------
# Circuit breaker state machine
# ---------------------------------------------------------------------------

def test_breaker_open_half_open_close():
    clk = _Clock()
    br = CircuitBreaker(BreakerParams(fail_threshold=3, backoff_s=1.0,
                                      backoff_mult=2.0, jitter=0.0),
                        clock=clk)
    assert br.state == "closed" and br.allow()
    br.record_failure()
    br.record_failure()
    assert br.state == "closed"
    br.record_failure()  # third consecutive failure -> open
    assert br.state == "open"
    assert not br.allow()
    assert br.retry_after() == pytest.approx(1.0)

    clk.t = 1.5  # backoff elapsed -> half-open, ONE probe
    assert br.allow()
    assert br.state == "half_open"
    assert not br.allow()  # second concurrent probe refused
    br.record_failure()    # probe failed -> re-open, backoff doubled
    assert br.state == "open"
    assert br.retry_after() == pytest.approx(2.0)

    clk.t = 4.0
    assert br.allow()
    br.record_success()    # probe healthy -> closed, backoff reset
    assert br.state == "closed"
    assert br.backoff == pytest.approx(1.0)


def test_breaker_jitter_is_seeded():
    clk = _Clock()
    waits = []
    for _ in range(2):
        br = CircuitBreaker(BreakerParams(fail_threshold=1, backoff_s=1.0,
                                          jitter=0.25),
                            clock=clk, seed=7)
        br.record_failure()
        waits.append(br.retry_after())
    assert waits[0] == waits[1]           # deterministic under one seed
    assert 0.75 <= waits[0] <= 1.25       # within the jitter band
    br2 = CircuitBreaker(BreakerParams(fail_threshold=1, backoff_s=1.0,
                                       jitter=0.25), clock=clk, seed=8)
    br2.record_failure()
    assert br2.retry_after() != waits[0]  # seeds decorrelate


# ---------------------------------------------------------------------------
# Service: sheds, breaker trips, deadlines, warm starts
# ---------------------------------------------------------------------------

def test_shed_queue_full():
    a = G.poisson2d(8)
    svc = AsyncSolveService(slots=2, params=_params(), queue_limit=2,
                            chunk_iters=16)
    svc.register("p", a, k=8)
    r1 = svc.submit("p", _rhs(a, 0))
    r2 = svc.submit("p", _rhs(a, 1))
    r3 = svc.submit("p", _rhs(a, 2))
    assert isinstance(r1, Accepted) and isinstance(r2, Accepted)
    assert isinstance(r3, Shed) and r3.reason == "queue_full"
    assert svc.sheds["queue_full"] == 1
    reports = svc.run_until_idle()
    assert set(reports) == {r1.id, r2.id}


def test_breaker_trips_then_sheds_then_recovers():
    """Repeated guard-tripped failures open the handle's breaker; while
    open, submissions shed with reason breaker_open and a retry hint;
    after backoff a probe closes it again."""
    a = G.poisson2d(8)
    g = pack_csr(a, k=8)
    clk = _Clock()
    svc = AsyncSolveService(
        slots=2, params=_params(), chunk_iters=32, queue_limit=8,
        max_retries=0, clock=clk,
        breaker=BreakerParams(fail_threshold=2, backoff_s=1.0, jitter=0.0))
    # Every tag fails (fail_tag=3): each request guard-trips.
    svc.register("bad", a, k=8,
                 operator=make_tag_fault_operator(g, mode="nan", fail_tag=3))

    for s in range(2):
        resp = svc.submit("bad", _rhs(a, s))
        assert isinstance(resp, Accepted)
        reports = svc.run_until_idle()
        assert not reports[resp.id].converged
        assert reports[resp.id].health != "ok"
    assert svc._breaker("bad").state == "open"

    shed = svc.submit("bad", _rhs(a, 9))
    assert isinstance(shed, Shed) and shed.reason == "breaker_open"
    assert shed.retry_after_s > 0
    assert svc.sheds["breaker_open"] == 1

    # After the backoff, one probe is admitted (half-open) -- and the
    # operand is still faulty, so it re-opens.
    clk.t = 1.5
    probe = svc.submit("bad", _rhs(a, 10))
    assert isinstance(probe, Accepted)
    svc.run_until_idle()
    assert svc._breaker("bad").state == "open"


def test_deadline_expiry_returns_flagged_checkpoint():
    """A request whose deadline lapses mid-solve comes back at the next
    chunk boundary with its current iterate, flagged -- never dropped."""
    a = G.poisson2d(16)
    clk = _Clock()

    def stall(svc, key, group):  # chaos: every chunk takes 1 s
        clk.t += 1.0

    svc = AsyncSolveService(slots=2, params=_params(), chunk_iters=4,
                            maxiter=20000, clock=clk, chunk_hook=stall)
    svc.register("p", a, k=8)
    resp = svc.submit("p", _rhs(a, 0), tol=1e-12, deadline_s=0.5)
    assert isinstance(resp, Accepted)
    reports = svc.run_until_idle()
    rep = reports[resp.id]
    assert rep.deadline_exceeded
    assert not rep.converged
    assert rep.health == "deadline"
    assert rep.iters > 0                      # it DID make progress
    x = svc.solution(resp.id)                 # last checkpoint, available
    assert bool(jnp.isfinite(jnp.vdot(x, x)))


def test_warm_start_lru_hits():
    a = G.poisson2d(12)
    svc = AsyncSolveService(slots=2, params=_params(), chunk_iters=32,
                            warm_capacity=4)
    svc.register("p", a, k=8)
    b = _rhs(a, 0)
    r1 = svc.submit("p", b, tol=1e-8)
    svc.run_until_idle()
    assert svc.warm["store"] == 1
    r2 = svc.submit("p", b, tol=1e-8)
    reports = svc.run_until_idle()
    assert svc.warm["hit"] == 1
    # Seeded with the converged solution, the repeat solve is instant.
    assert reports[r2.id].iters == 0
    assert reports[r2.id].converged


def test_pack_corruption_detected_and_repacked():
    """A pack whose bytes rot after registration is caught by the CRC
    verify before the next dispatch and repacked from the CSR."""
    from repro.robustness.faults import corrupt_gsecsr

    a = G.poisson2d(8)
    svc = AsyncSolveService(slots=2, params=_params(), chunk_iters=32)
    svc.register("p", a, k=8)
    svc._ops["p"].gse = corrupt_gsecsr(svc._ops["p"].gse, "table", seed=3)
    resp = svc.submit("p", _rhs(a, 0), tol=1e-8)
    reports = svc.run_until_idle()
    assert svc.pack_faults["detected"] == 1
    assert svc.pack_faults["repacked"] == 1
    assert reports[resp.id].converged  # served off the repacked operand


def test_dwell_class_buckets_requests():
    """Deadline classes map to distinct monitor dwells (and distinct
    groups), so one batch shares one static MonitorParams."""
    from repro.serve.service import _dwell_params

    p = _params()
    cls_t, pt = _dwell_params(p, 0.05, 0.2, 5.0)
    cls_n, pn = _dwell_params(p, 1.0, 0.2, 5.0)
    cls_l, pl = _dwell_params(p, 30.0, 0.2, 5.0)
    assert (cls_t, cls_n, cls_l) == ("tight", "normal", "loose")
    assert pt.t < pn.t < pl.t
    assert pn == p
    assert _dwell_params(p, None, 0.2, 5.0)[0] == "normal"
