"""Property-based kernel sweeps (hypothesis).

Split out of test_kernels.py and guarded with ``pytest.importorskip`` so
tier-1 collection passes from a clean checkout (hypothesis is optional --
see requirements.txt); the property tests still run wherever it is
installed.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import gse  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402


def _packed(shape, k=8, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.choice([-2, 0, 1], size=shape)
    vals = rng.uniform(1.0, 2.0, shape) * np.exp2(base)
    vals *= rng.choice([-1.0, 1.0], size=shape)
    return gse.pack(vals, k), vals


@settings(max_examples=12, deadline=None)
@given(
    rows=st.integers(1, 4).map(lambda m: m * 8),
    cols=st.integers(1, 3).map(lambda n: n * 128),
    k=st.sampled_from([2, 4, 8, 16]),
    tag=st.sampled_from([1, 2, 3]),
)
def test_prop_decode_kernel_matches_ref(rows, cols, k, tag):
    p, _ = _packed((rows, cols), k=k, seed=rows * cols + k)
    out = ops.gse_decode(p, tag=tag)
    want = ref.decode_ref(p.head, p.tail1, p.tail2, p.table, p.ei_bit, tag)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@settings(max_examples=8, deadline=None)
@given(
    m=st.integers(1, 2).map(lambda m: m * 8),
    kdim=st.integers(1, 2).map(lambda n: n * 128),
    n=st.integers(1, 2).map(lambda n: n * 128),
    tag=st.sampled_from([1, 2, 3]),
)
def test_prop_matmul_kernel_matches_ref(m, kdim, n, tag):
    rng = np.random.default_rng(m * kdim + n)
    x = jnp.asarray(rng.normal(size=(m, kdim)), jnp.float32)
    p, _ = _packed((kdim, n), seed=n + tag)
    out = ops.gse_matmul(x, p, tag=tag)
    want = ref.matmul_ref(x, p.head, p.tail1, p.tail2, p.table, p.ei_bit,
                          tag)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
