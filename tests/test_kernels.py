"""Pallas kernel sweeps: interpret-mode kernels vs pure-jnp ref oracles.

Per the kernel contract: sweep shapes/dtypes/tags and assert_allclose
against ref.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gse
from repro.kernels import ops, ref
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr


def _packed(shape, k=8, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.choice([-2, 0, 1], size=shape)
    vals = rng.uniform(1.0, 2.0, shape) * np.exp2(base)
    vals *= rng.choice([-1.0, 1.0], size=shape)
    return gse.pack(vals, k), vals


# ---------------------------------------------------------------------------
# gse_decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (16, 256), (24, 384), (64, 128)])
@pytest.mark.parametrize("tag", [1, 2, 3])
def test_decode_kernel_vs_ref(shape, tag):
    p, _ = _packed(shape, seed=hash(shape) % 1000)
    out = ops.gse_decode(p, tag=tag)
    want = ref.decode_ref(p.head, p.tail1, p.tail2, p.table, p.ei_bit, tag)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=0,
                               atol=0)


@pytest.mark.parametrize("k", [2, 4, 8, 16])
def test_decode_kernel_k_sweep(k):
    p, vals = _packed((16, 128), k=k, seed=k)
    out = np.asarray(ops.gse_decode(p, tag=3))
    rel = np.abs(out - vals) / np.abs(vals)
    assert rel.max() < 1e-6  # f32 decode of near-exact mantissas


def test_decode_kernel_unaligned_shape_pads():
    p, vals = _packed((10, 130), seed=5)
    out = np.asarray(ops.gse_decode(p, tag=3))
    assert out.shape == (10, 130)
    rel = np.abs(out - vals) / np.abs(vals)
    assert rel.max() < 1e-6


def test_decode_kernel_1d_input():
    p, vals = _packed((512,), seed=6)
    out = np.asarray(ops.gse_decode(p, tag=2))
    assert out.shape == (512,)


# ---------------------------------------------------------------------------
# gse_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mkn", [(8, 128, 128), (16, 256, 128), (8, 128, 256),
                                 (32, 384, 256)])
@pytest.mark.parametrize("tag", [1, 2, 3])
def test_matmul_kernel_vs_ref(mkn, tag):
    m, k_dim, n = mkn
    rng = np.random.default_rng(m + n)
    x = jnp.asarray(rng.normal(size=(m, k_dim)), jnp.float32)
    p, _ = _packed((k_dim, n), seed=n)
    out = ops.gse_matmul(x, p, tag=tag)
    want = ref.matmul_ref(x, p.head, p.tail1, p.tail2, p.table, p.ei_bit, tag)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_matmul_kernel_accuracy_vs_true_values():
    m, k_dim, n = 8, 256, 128
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k_dim)), jnp.float32)
    p, vals = _packed((k_dim, n), seed=1)
    out3 = np.asarray(ops.gse_matmul(x, p, tag=3))
    exact = np.asarray(x, np.float64) @ vals
    assert np.abs(out3 - exact).max() / np.abs(exact).max() < 1e-5
    out1 = np.asarray(ops.gse_matmul(x, p, tag=1))
    r1 = np.abs(out1 - exact).max() / np.abs(exact).max()
    assert 1e-6 < r1 < 1e-2  # head-only: quantized but useful


@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_input_dtypes(xdtype):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 128)), xdtype)
    p, _ = _packed((128, 128), seed=3)
    out = ops.gse_matmul(x, p, tag=1)
    want = ref.matmul_ref(x, p.head, p.tail1, p.tail2, p.table, p.ei_bit, 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# gse_spmv (blocked ELL)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gen,seed", [
    (lambda: G.poisson2d(16), 0),
    (lambda: G.convection_diffusion_2d(16), 1),
    (lambda: G.random_spd(600, seed=2), 2),
    (lambda: G.circuit_like(500, seed=3), 3),
])
@pytest.mark.parametrize("tag", [1, 2, 3])
def test_spmv_kernel_vs_ref(gen, seed, tag):
    a = gen()
    g = pack_csr(a, k=8)
    ell = ops.ell_pack_gsecsr(g, lane=128)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=a.shape[1]), jnp.float32)
    out = ops.gse_spmv_ell(ell, g.table, x, g.ei_bit, tag=tag)
    want = ref.spmv_ell_ref(*ell, g.table, x, g.ei_bit, tag)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=1e-4)


def test_spmv_kernel_matches_segment_sum_spmv():
    """Kernel agrees with the production jnp SpMV (f32 accumulate)."""
    import repro.sparse.spmv as S

    a = G.random_spd(400, seed=5)
    g = pack_csr(a, k=8)
    ell = ops.ell_pack_gsecsr(g, lane=128)
    x64 = np.random.default_rng(5).normal(size=a.shape[1])
    x = jnp.asarray(x64, jnp.float32)
    out = np.asarray(ops.gse_spmv_ell(ell, g.table, x, g.ei_bit, tag=3))
    want = np.asarray(S.spmv_gse(g, jnp.asarray(x64), tag=3))
    np.testing.assert_allclose(out, want, rtol=5e-5, atol=5e-4)


# Property-based sweeps (hypothesis) live in test_kernels_properties.py,
# guarded by pytest.importorskip so collection passes without hypothesis.


def test_kernel_block_shape_sweep():
    """Different BlockSpec tilings must not change results."""
    p, _ = _packed((32, 512), seed=99)
    ref_out = np.asarray(ops.gse_decode(p, tag=2, block=(8, 128)))
    for block in [(16, 128), (8, 256), (32, 512)]:
        out = np.asarray(ops.gse_decode(p, tag=2, block=block))
        np.testing.assert_array_equal(out, ref_out)


def test_matmul_kernel_block_sweep():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(16, 256)), jnp.float32)
    p, _ = _packed((256, 256), seed=7)
    ref_out = np.asarray(ops.gse_matmul(x, p, tag=1, blocks=(8, 128, 128)))
    for blocks in [(16, 128, 128), (8, 256, 128), (8, 128, 256),
                   (16, 256, 256)]:
        out = np.asarray(ops.gse_matmul(x, p, tag=1, blocks=blocks))
        # different BK splits change f32 accumulation order (~ulps)
        np.testing.assert_allclose(out, ref_out, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention (online softmax, VMEM-tiled)
# ---------------------------------------------------------------------------

from repro.kernels.flash_attn import flash_attention_pallas  # noqa: E402


@pytest.mark.parametrize("shape", [(2, 128, 64), (4, 256, 128), (1, 512, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(shape, causal):
    bh, s, hd = shape
    rng = np.random.default_rng(s + hd)
    q = jnp.asarray(rng.normal(size=(bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, s, hd)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal, blocks=(128, 128))
    want = ref.flash_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_block_sweep():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 256, 64)), jnp.float32)
    want = ref.flash_ref(q, k, v, causal=True)
    for blocks in [(128, 128), (64, 128), (128, 64), (256, 256), (64, 64)]:
        out = flash_attention_pallas(q, k, v, causal=True, blocks=blocks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, causal=True)
    want = ref.flash_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2)
