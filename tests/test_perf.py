"""Perf subsystem tests (PR 7, DESIGN.md §15).

Four families:

  * precision-table regression -- the centralized per-tag byte constants
    reproduce every pre-PR-7 ``bytes_touched`` figure exactly;
  * launch-plan bit-identity -- with an EMPTY tune cache, every kernel
    entry point resolves to the historical (8, 128) default and the
    plan-resolved outputs are BITWISE identical to explicit-blocks calls
    across tags x layouts x nrhs;
  * ledger cross-checks -- the byte model's ``pallas_segment_bytes``
    matches the jaxpr's integer ``pallas_call`` operands and the
    compiled HLO's u16/u32 entry parameters;
  * tune-cache discipline -- sweep once, hit forever (counter-asserted,
    the PR-4 ``PACK_STATS`` style), checksum-verified on every hit.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision_table as pt
from repro.kernels import ops
from repro.perf import ledger, plan as launch_plan, tunecache
from repro.sparse import generators as G
from repro.sparse.csr import ell_layout, pack_csr


@pytest.fixture()
def tmp_cache(tmp_path, monkeypatch):
    """Point the tune cache at an empty tmp file; restore after."""
    path = tmp_path / "tunecache.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    tunecache.clear_memory()
    tunecache.reset()
    yield path
    tunecache.clear_memory()


def _operand(n=12, k=8):
    a = G.poisson2d(n)
    return a, pack_csr(a, k=k)


# ---------------------------------------------------------------------------
# precision_table: centralized constants == pre-PR-7 byte figures
# ---------------------------------------------------------------------------

def test_precision_table_values():
    assert pt.TAG_VALUE_BYTES == {1: 2, 2: 4, 3: 8}
    assert pt.COLIDX_BYTES == 4
    assert pt.SLOT_BYTES == {1: 6, 2: 8, 3: 12}
    assert pt.WIRE_ENTRY_BYTES == pt.TAG_VALUE_BYTES
    assert pt.DTYPE_BYTES["u16"] == 2 and pt.DTYPE_BYTES["u32"] == 4
    assert pt.DTYPE_BYTES["f64"] == 8


def test_bytes_touched_regression():
    """Pinned pre-PR-7 figures on poisson2d(12): the table refactor must
    not move a single modeled byte."""
    a, g = _operand()
    assert (a.nnz, a.shape[0]) == (672, (144, 144)[0])
    assert [g.bytes_per_nnz(t) for t in (1, 2, 3)] == [6, 8, 12]
    assert [g.bytes_touched(t) for t in (1, 2, 3)] == [4644, 5988, 8676]
    assert a.bytes_touched() == 8644  # fp64 CSR: 12 B/nnz + rowptr
    lay = ell_layout(g)
    assert (lay.slots, lay.bytes_touched(1)) == (18432, 110624)
    sell = ops.sell_pack_gsecsr(g)
    assert (sell.slots, sell.bytes_touched(1)) == (18432, 111200)
    from repro.distributed.partition import WIRE_ENTRY_BYTES
    assert WIRE_ENTRY_BYTES is pt.WIRE_ENTRY_BYTES


# ---------------------------------------------------------------------------
# launch-plan resolution: empty cache == historical defaults, bitwise
# ---------------------------------------------------------------------------

def test_resolve_precedence(tmp_cache):
    assert launch_plan.resolve() is launch_plan.DEFAULT_PLAN
    assert launch_plan.resolve(blocks=(16, 128)).blocks == (16, 128)
    assert launch_plan.resolve(blocks=(16, 128)).source == "explicit"
    p = launch_plan.KernelPlan(blocks=(32, 128))
    assert launch_plan.resolve(plan=p).blocks == (32, 128)
    _, g = _operand(8)
    got = launch_plan.resolve(g, tag=1, layout="ell", nrhs=1)
    assert got == launch_plan.DEFAULT_PLAN and got.source == "default"


@pytest.mark.parametrize("tag", [1, 2, 3])
@pytest.mark.parametrize("layout", ["ell", "sell"])
@pytest.mark.parametrize("nrhs", [1, 4])
def test_empty_cache_bit_identity(tmp_cache, tag, layout, nrhs):
    """Plan-resolved dispatch (no explicit blocks, empty cache) is
    BITWISE identical to the pre-PR-7 explicit (8, 128) calls."""
    _, g = _operand(8)
    n = g.shape[1]
    rng = np.random.default_rng(tag * 10 + nrhs)
    if nrhs == 1:
        x = jnp.asarray(rng.normal(size=n), jnp.float32)
        got = ops.planned_spmv(g, x, tag=tag, layout=layout)
        if layout == "ell":
            ell = ops.ell_pack_gsecsr(g)
            want = ops.gse_spmv_ell(ell, g.table, x, g.ei_bit, tag=tag,
                                    blocks=(8, 128))
        else:
            want = ops.gse_spmv_sell(ops.sell_pack_gsecsr(g), x, tag=tag,
                                     blocks=(8, 128))
    else:
        x = jnp.asarray(rng.normal(size=(n, nrhs)), jnp.float32)
        got = ops.planned_spmm(g, x, tag=tag, layout=layout)
        if layout == "ell":
            ell = ops.ell_pack_gsecsr(g)
            want = ops.gse_spmm_ell(ell, g.table, x, g.ei_bit, tag=tag,
                                    blocks=(8, 128))
        else:
            want = ops.gse_spmm_sell(ops.sell_pack_gsecsr(g), x, tag=tag,
                                     blocks=(8, 128))
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_kernel_for_default_blocks_unchanged(tmp_cache):
    """blocks=None in every *_kernel_for/*_call resolves to (8, 128)."""
    from repro.kernels.gse_spmv import gse_spmv_call  # noqa: F401
    a = launch_plan.resolve(blocks=None)
    assert a.blocks == launch_plan.DEFAULT_BLOCKS == (8, 128)
    _, g = _operand(8)
    k_none = ops.spmv_kernel_for(1, g.ei_bit)
    k_expl = ops.spmv_kernel_for(1, g.ei_bit, blocks=(8, 128))
    assert k_none is k_expl  # same lru_cache entry -> same launch


# ---------------------------------------------------------------------------
# ledger: model == jaxpr operands == compiled HLO parameters
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tag", [1, 2, 3])
@pytest.mark.parametrize("layout", ["ell", "sell"])
@pytest.mark.parametrize("nrhs", [1, 4])
def test_ledger_matches_jaxpr(tag, layout, nrhs):
    """Predicted packed-segment bytes == the integer operand bytes of
    every ``pallas_call`` in the jaxpr (matrix segments are streamed once
    regardless of nrhs)."""
    _, g = _operand()
    n = g.shape[1]
    x = jnp.ones((n, nrhs) if nrhs > 1 else n, jnp.float32)
    if layout == "ell":
        src = g
        ell = ops.ell_pack_gsecsr(g)
        if nrhs == 1:
            fn = lambda x: ops.gse_spmv_ell(ell, g.table, x, g.ei_bit,
                                            tag=tag)
        else:
            fn = lambda x: ops.gse_spmm_ell(ell, g.table, x, g.ei_bit,
                                            tag=tag)
    else:
        src = ops.sell_pack_gsecsr(g)
        if nrhs == 1:
            fn = lambda x: ops.gse_spmv_sell(src, x, tag=tag)
        else:
            fn = lambda x: ops.gse_spmm_sell(src, x, tag=tag)
    want = ledger.pallas_segment_bytes(src, tag)
    assert ledger.jaxpr_pallas_int_bytes(fn, x) == want


@pytest.mark.parametrize("tag", [1, 3])
def test_ledger_matches_hlo(tag):
    """Compiled-HLO u16/u32 entry-parameter bytes == the model (the
    exponent table is s32 and the vectors are float, so the filter
    isolates exactly the packed segments; unused tails are dropped by
    jit, matching the tag-specialized operand lists)."""
    _, g = _operand()
    ell = ops.ell_pack_gsecsr(g)
    colpak, head, t1, t2 = ell
    x = jnp.ones((g.shape[1],), jnp.float32)

    def fn(colpak, head, t1, t2):
        return ops.gse_spmv_ell((colpak, head, t1, t2), g.table, x,
                                g.ei_bit, tag=tag)

    got = ledger.hlo_segment_bytes(fn, colpak, head, t1, t2)
    assert got == ledger.pallas_segment_bytes(g, tag)


def test_spmv_ledger_accounts():
    a, g = _operand()
    led = ledger.spmv_ledger(g, tag=1, layout="ell", nrhs=1)
    lay = ell_layout(g)
    assert led.flops == 2 * a.nnz
    assert led.matrix_bytes == lay.bytes_touched(1)
    assert led.bytes == led.matrix_bytes + led.vector_bytes
    # fp64-equivalent bytes price the SAME math on fp64 CSR streams.
    led64 = ledger.spmv_ledger(a)
    assert led.fp64_bytes == led64.matrix_bytes + led64.vector_bytes
    # SpMM streams the matrix once, vectors per column.
    led4 = ledger.spmv_ledger(g, tag=1, layout="ell", nrhs=4)
    assert led4.matrix_bytes == led.matrix_bytes
    assert led4.vector_bytes == 4 * led.vector_bytes
    assert led4.flops == 4 * led.flops


# ---------------------------------------------------------------------------
# tune cache: sweep once, hit forever, checksum-verified
# ---------------------------------------------------------------------------

def test_tune_persist_and_replay(tmp_cache):
    from repro.perf import autotune

    _, g = _operand(8)
    plan1, payload1, hit1 = autotune.get_or_tune(g, tag=1, layout="ell",
                                                 iters=1, warmup=1)
    assert not hit1
    assert tunecache.TUNE_STATS["sweeps"] == 1
    assert tunecache.TUNE_STATS["stores"] == 1
    assert payload1["default_us"] >= payload1["us"] > 0
    assert payload1["decode_bound"] == (g.nnz < autotune.DECODE_BOUND_NNZ)
    assert tmp_cache.exists()

    # Same-process replay: in-memory hit, zero re-sweeps.
    plan2, payload2, hit2 = autotune.get_or_tune(g, tag=1, layout="ell")
    assert hit2 and plan2 == plan1
    assert tunecache.TUNE_STATS["sweeps"] == 1

    # Fresh-process replay: drop the image, resolve from the FILE.
    tunecache.clear_memory()
    plan3, _, hit3 = autotune.get_or_tune(g, tag=1, layout="ell")
    assert hit3 and plan3 == plan1
    assert tunecache.TUNE_STATS["sweeps"] == 1

    # The dispatcher itself now resolves to the tuned plan.
    got = launch_plan.resolve(g, tag=1, layout="ell", nrhs=1)
    assert got.blocks == plan1.blocks and got.source == "tuned"

    # Tuned output stays numerically identical to the default plan's
    # (blocks change the launch grid, never the per-lane math).
    x = jnp.asarray(np.random.default_rng(0).normal(size=g.shape[1]),
                    jnp.float32)
    tuned = ops.planned_spmv(g, x, tag=1, layout="ell")
    ell = ops.ell_pack_gsecsr(g)
    default = ops.gse_spmv_ell(ell, g.table, x, g.ei_bit, tag=1,
                               blocks=(8, 128))
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(default),
                               rtol=2e-6, atol=0)


def test_tune_cache_corruption_detected(tmp_cache):
    from repro.perf import autotune

    _, g = _operand(8)
    autotune.get_or_tune(g, tag=1, layout="ell", iters=1, warmup=1)
    blob = json.loads(tmp_cache.read_text())
    key = next(iter(blob["plans"]))
    blob["plans"][key]["payload"]["us"] = -1.0  # flip payload, keep crc
    tmp_cache.write_text(json.dumps(blob))
    tunecache.clear_memory()
    assert tunecache.lookup(key) is None  # checksum mismatch -> miss
    assert tunecache.TUNE_STATS["corrupt"] == 1
    # get_or_tune recovers by re-sweeping and re-storing a clean entry.
    _, payload, hit = autotune.get_or_tune(g, tag=1, layout="ell",
                                           iters=1, warmup=1)
    assert not hit and payload["us"] > 0


def test_host_roofline_persisted(tmp_cache):
    from repro.perf import roofline as rl

    r1 = rl.host_roofline(quick=True)
    assert r1["probed"] and r1["stream_gbps"] > 0 and r1["peak_gflops"] > 0
    r2 = rl.host_roofline(quick=True)
    assert not r2["probed"]
    assert r2["stream_gbps"] == r1["stream_gbps"]
    att = rl.attainable_seconds(1e9, 1e9, r1)
    assert att > 0
    assert rl.fraction(1e9, 1e9, att, r1) == pytest.approx(1.0)
