"""Tests for the SELL-C-σ sliced-ELL SpMV/SpMM pipeline (DESIGN.md §12).

Covers the PR-4 acceptance criteria:

  * the packed layout is a faithful permutation of the GSE-SEM CSR store
    (segment round trip + row-permutation round trip, bitwise);
  * SELL SpMV/SpMM reference paths are BITWISE equal to the CSR
    reference, and the bucketed Pallas kernels are bitwise equal to the
    uniform-ELL kernels, across tags 1/2/3 and nrhs in {1, 4};
  * per-bucket pallas_calls keep the tag-specialized operand lists
    (jaxpr operand counts, one call per width-bucket);
  * padding-honest byte model: skewed matrices show the uniform-ELL
    blowup, near-uniform (Poisson) figures are unchanged within 1%, the
    nnz-only default is untouched;
  * the operand-pack cache: repeated solves/packs against one operator
    perform ZERO host-side re-packing;
  * solver trajectories through the new layout are bit-identical to the
    CSR reference (fused CG/PCG, batched, service).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import core as jcore

from repro.core import precision as P
from repro.kernels import ops
from repro.kernels.gse_spmv import gse_spmv_sell_call
from repro.sparse import generators as G
from repro.sparse.csr import (
    ELLLayout,
    GSESellC,
    ell_layout,
    iteration_stream_bytes,
    pack_csr,
    pack_sell,
    sell_slices,
    to_ell,
)
from repro.sparse.spmv import spmm_gse, spmv, spmv_gse
from repro.solvers import make_gse_operator, solve_cg, solve_pcg
from repro.solvers.batched import solve_cg_batched


def _params(**kw):
    d = dict(t=30, l=30, m=15, rsd_limit=0.5, reldec_limit=0.45)
    d.update(kw)
    return P.MonitorParams(**d)


def _skewed_small(n=320, seed=0):
    """Small skewed SPD: power-law rows + dense hubs, multiple buckets."""
    return G.skewed_spd(n, dense_rows=2, base_halfwidth=10, tail_scale=6.0,
                        seed=seed)


def _rand_skew_csr(n, seed):
    """Random row-skew (non-symmetric pattern): plain per-row degrees."""
    rng = np.random.default_rng(seed)
    deg = np.minimum((rng.pareto(1.2, n) * 4 + 1).astype(np.int64), n)
    rows = np.repeat(np.arange(n), deg)
    cols = rng.integers(0, n, size=deg.sum())
    bins = rng.choice([-2, -1, 0, 1], size=rows.size)
    vals = rng.uniform(1.0, 2.0, rows.size) * np.exp2(bins)
    vals *= rng.choice([-1.0, 1.0], size=vals.shape)
    from repro.sparse.csr import from_coo

    return from_coo(rows, cols, vals, (n, n))


# ---------------------------------------------------------------------------
# Layout round trip
# ---------------------------------------------------------------------------

def test_sell_pack_segment_round_trip():
    """Gathering the packed bucket arrays recovers every CSR-order segment
    bit-for-bit: the layout is a permutation, not a re-encoding."""
    g = pack_csr(_skewed_small(), k=8)
    s = pack_sell(g)
    gather = np.asarray(s.gather)
    for name in ("colpak", "head", "tail1", "tail2"):
        flat = np.concatenate(
            [np.asarray(b).reshape(-1) for b in getattr(s, name)]
        )
        np.testing.assert_array_equal(flat[gather],
                                      np.asarray(getattr(g, name)))


def test_sell_row_permutation_round_trip():
    g = pack_csr(_skewed_small(seed=3), k=8)
    for sigma in (None, 16, 64):
        s = pack_sell(g, sigma=sigma)
        perm = np.asarray(s.perm)
        unperm = np.asarray(s.unperm)
        m = g.shape[0]
        # Every real row appears exactly once; padding rows are -1.
        np.testing.assert_array_equal(np.sort(perm[perm >= 0]), np.arange(m))
        np.testing.assert_array_equal(perm[unperm], np.arange(m))
        assert perm.shape[0] == sum(s.bucket_rows)
        assert perm.shape[0] % s.c == 0


def test_sigma_window_sort_is_window_local():
    """σ bounds how far a row can move: the permutation stays inside its
    window, so locality (and recoverability) is controlled."""
    g = pack_csr(_rand_skew_csr(200, seed=5), k=8)
    sigma = 40
    order, _, sigma_eff = sell_slices(g.rowptr, c=8, sigma=sigma)
    assert sigma_eff == sigma
    order = np.asarray(order)
    real = order[order >= 0]
    for w0 in range(0, 200, sigma):
        win = real[(real >= w0) & (real < w0 + sigma)]
        assert win.size == min(sigma, 200 - w0)
        # rows of this window occupy contiguous positions in `order`
        pos = np.nonzero((order >= w0) & (order < w0 + sigma))[0]
        assert pos.max() - pos.min() + 1 == win.size


def test_pack_sell_rejects_bad_slice_height():
    g = pack_csr(G.poisson2d(8), k=8)
    with pytest.raises(ValueError, match="multiple of 8"):
        pack_sell(g, c=4)


# ---------------------------------------------------------------------------
# Bitwise parity: reference paths and kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tag", [1, 2, 3])
def test_sell_reference_spmv_bitwise_csr(tag):
    a = _skewed_small(seed=1)
    g = pack_csr(a, k=8)
    s = ops.sell_pack_gsecsr(g)
    x = jnp.asarray(np.random.default_rng(tag).normal(size=a.shape[1]))
    np.testing.assert_array_equal(np.asarray(spmv_gse(s, x, tag=tag)),
                                  np.asarray(spmv_gse(g, x, tag=tag)))


@pytest.mark.parametrize("nrhs", [1, 4])
@pytest.mark.parametrize("tag", [1, 2, 3])
def test_sell_reference_spmm_bitwise_csr(tag, nrhs):
    a = _rand_skew_csr(300, seed=2)
    g = pack_csr(a, k=8)
    s = ops.sell_pack_gsecsr(g)
    x = jnp.asarray(
        np.random.default_rng(10 * tag + nrhs).normal(size=(a.shape[1], nrhs))
    )
    np.testing.assert_array_equal(np.asarray(spmm_gse(s, x, tag=tag)),
                                  np.asarray(spmm_gse(g, x, tag=tag)))


@pytest.mark.parametrize("tag", [1, 2, 3])
def test_sell_kernel_bitwise_uniform_ell_kernel(tag):
    """The bucketed pallas path reproduces the uniform-ELL kernel output
    bit-for-bit: same in-row slots, same lane-group reduction order,
    trailing all-zero groups contribute exact zeros."""
    a = _skewed_small(seed=4)
    g = pack_csr(a, k=8)
    s = ops.sell_pack_gsecsr(g)
    assert s.n_buckets >= 2, "skewed case must exercise multiple buckets"
    ell = ops.ell_pack_gsecsr(g)
    x = jnp.asarray(np.random.default_rng(tag).normal(size=a.shape[1]),
                    jnp.float32)
    got = ops.gse_spmv_sell(s, x, tag=tag)
    want = ops.gse_spmv_ell(ell, g.table, x, g.ei_bit, tag=tag)
    assert got.shape == want.shape == (a.shape[0],)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("nrhs", [1, 4])
@pytest.mark.parametrize("tag", [1, 2, 3])
def test_sell_spmm_kernel_bitwise_uniform_ell_kernel(tag, nrhs):
    a = _skewed_small(seed=6)
    g = pack_csr(a, k=8)
    s = ops.sell_pack_gsecsr(g)
    ell = ops.ell_pack_gsecsr(g)
    x = jnp.asarray(
        np.random.default_rng(7 * tag + nrhs).normal(size=(a.shape[1], nrhs)),
        jnp.float32,
    )
    got = ops.gse_spmm_sell(s, x, tag=tag)
    want = ops.gse_spmm_ell(ell, g.table, x, g.ei_bit, tag=tag)
    assert got.shape == want.shape == (a.shape[0], nrhs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sell_kernel_rejects_incompatible_blocks():
    g = pack_csr(G.poisson2d(8), k=8)
    s = ops.sell_pack_gsecsr(g)
    x = jnp.zeros((g.shape[1],), jnp.float32)
    with pytest.raises(ValueError, match="multiple of the row block"):
        ops.gse_spmv_sell(s, x, tag=1, blocks=(16, 128))


# ---------------------------------------------------------------------------
# Jaxpr: one pallas_call per width-bucket, tag-specialized operand lists
# ---------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            if isinstance(v, jcore.ClosedJaxpr):
                yield from _iter_eqns(v.jaxpr)
            elif isinstance(v, jcore.Jaxpr):
                yield from _iter_eqns(v)


def _sell_pallas_eqns(s, tag):
    n = s.shape[1]
    x = jnp.zeros((n,), jnp.float32)
    scales = jnp.ones((1, int(s.table.size)), jnp.float32)
    if tag == 1:
        buckets = tuple((cp, hd, None, None)
                        for cp, hd in zip(s.colpak, s.head))
    elif tag == 2:
        buckets = tuple((cp, hd, t1, None) for cp, hd, t1 in
                        zip(s.colpak, s.head, s.tail1))
    else:
        buckets = tuple(zip(s.colpak, s.head, s.tail1, s.tail2))
    fn = functools.partial(gse_spmv_sell_call, buckets, s.unperm, x, scales,
                           ei_bit=s.ei_bit, tag=tag, interpret=True)
    jaxpr = jax.make_jaxpr(fn)()
    return [e for e in _iter_eqns(jaxpr.jaxpr)
            if e.primitive.name == "pallas_call"]


@pytest.mark.parametrize("tag,n_operands", [(1, 4), (2, 5), (3, 6)])
def test_sell_one_pallas_call_per_bucket_tag_specialized(tag, n_operands):
    """Exactly one pallas_call per width-bucket, each streaming ONLY the
    operands its tag reads (scales/colpak/head/x +tails) -- the uniform-
    ELL tag-specialization contract carried over per bucket."""
    g = pack_csr(_skewed_small(seed=8), k=8)
    s = ops.sell_pack_gsecsr(g)
    assert s.n_buckets >= 2
    eqns = _sell_pallas_eqns(s, tag)
    assert len(eqns) == s.n_buckets
    for eqn in eqns:
        assert len(eqn.invars) == n_operands


def test_sell_tag1_omits_tail_dtypes_per_bucket():
    """No u16 tail1 and no second u32 (tail2) operand in any tag-1 bucket
    call (segments are distinguishable by dtype, as in the uniform-ELL
    pipeline tests)."""
    g = pack_csr(_skewed_small(seed=8), k=8)
    s = ops.sell_pack_gsecsr(g)
    for eqn in _sell_pallas_eqns(s, 1):
        dtypes = sorted(str(v.aval.dtype) for v in eqn.invars)
        assert dtypes == ["float32", "float32", "uint16", "uint32"]


def test_sell_dispatch_cache_is_stable():
    k1 = ops.sell_kernel_for(1, 3, (8, 128), True)
    assert ops.sell_kernel_for(1, 3, (8, 128), True) is k1
    assert ops.sell_kernel_for(2, 3, (8, 128), True) is not k1
    m1 = ops.sell_spmm_kernel_for(1, 3, (8, 128), True)
    assert ops.sell_spmm_kernel_for(1, 3, (8, 128), True) is m1


# ---------------------------------------------------------------------------
# Padding-honest byte model
# ---------------------------------------------------------------------------

def test_skewed_padding_ratio_and_bytes():
    """The acceptance bar: on the skewed benchmark matrix, SELL wastes
    < 50% of uniform ELL's padded fraction and streams < 50% (actually
    ~13%) of its modeled tag-1 bytes, while staying within 10% of the
    6 B/nnz format promise."""
    a = G.skewed_spd(1024)
    g = pack_csr(a, k=8)
    s = ops.sell_pack_gsecsr(g)
    ell = ell_layout(g)
    assert ell.padding_ratio > 0.8          # the blowup is real
    assert s.padding_ratio < 0.5 * ell.padding_ratio
    assert s.bytes_touched(1) < 0.5 * ell.bytes_touched(1)
    assert abs(s.bytes_touched(1) / a.nnz - 6.0) / 6.0 <= 0.10
    # effective per-nnz ladder is still monotone in the tag
    assert (s.bytes_touched(1) < s.bytes_touched(2) < s.bytes_touched(3))


def test_poisson_layout_figures_unchanged_within_1pct():
    """Near-uniform rows: SELL and uniform ELL pad identically (all
    slices at one lane width), so switching layouts moves the modeled
    figures by < 1% -- the regression bar for the non-skewed suite."""
    g = pack_csr(G.poisson2d(32), k=8)
    s = ops.sell_pack_gsecsr(g)
    ell = ell_layout(g)
    assert s.widths == (128,)
    for tag in (1, 2, 3):
        rel = abs(s.bytes_touched(tag) - ell.bytes_touched(tag))
        assert rel / ell.bytes_touched(tag) < 0.01
    assert abs(s.padding_ratio - ell.padding_ratio) < 0.01


def test_nnz_only_mode_unchanged():
    """The default byte model (no layout) is exactly the seed formula --
    the format-comparison figures (fig6) are untouched."""
    g = pack_csr(G.poisson2d(16), k=8)
    for tag in (1, 2, 3):
        want = (g.nnz * g.bytes_per_nnz(tag) + g.rowptr.size * 4
                + g.table.size * 4)
        assert g.bytes_touched(tag) == want
        assert iteration_stream_bytes(g, tag) == want


def test_bytes_touched_layout_dispatch():
    g = pack_csr(_skewed_small(), k=8)
    s = ops.sell_pack_gsecsr(g)
    ell = ell_layout(g)
    for tag in (1, 2, 3):
        assert g.bytes_touched(tag, layout=s) == s.bytes_touched(tag)
        assert g.bytes_touched(tag, layout=ell) == ell.bytes_touched(tag)
        assert iteration_stream_bytes(g, tag, layout=s) == s.bytes_touched(tag)
        # nrhs columns still add vector streams on top of the layout bytes
        from repro.sparse.csr import vector_stream_bytes

        assert iteration_stream_bytes(g, tag, nrhs=3, layout=s) == (
            s.bytes_touched(tag) + 2 * vector_stream_bytes(g)
        )


def test_ell_layout_descriptor():
    g = pack_csr(_skewed_small(), k=8)
    lay = ell_layout(g)
    assert isinstance(lay, ELLLayout)
    per_row = np.diff(np.asarray(g.rowptr))
    L = -(-int(per_row.max()) // 128) * 128
    assert lay.slots == g.shape[0] * L
    assert 0.0 <= lay.padding_ratio < 1.0


# ---------------------------------------------------------------------------
# to_ell / ell_pack_gsecsr share one scatter (dedup satellite)
# ---------------------------------------------------------------------------

def test_to_ell_matches_ell_pack_layout():
    """The two packers ride one scatter helper: identical slot layout
    (ell cols == decoded colpak low bits), identical widths."""
    a = _rand_skew_csr(150, seed=9)
    g = pack_csr(a, k=8)
    cols, vals, L = to_ell(a)
    cp, hd, t1, t2 = ops.ell_pack_gsecsr(g)
    assert cp.shape == (a.shape[0], L) == cols.shape
    shift = 32 - g.ei_bit
    np.testing.assert_array_equal(
        (np.asarray(cp) & ((1 << shift) - 1)).astype(np.int64),
        cols.astype(np.int64),
    )
    # dtype discipline: cols int32, ELL segments keep their pack dtypes
    assert cols.dtype == np.int32 and vals.dtype == np.float64
    assert (cp.dtype, hd.dtype, t1.dtype, t2.dtype) == (
        jnp.uint32, jnp.uint16, jnp.uint16, jnp.uint32
    )


# ---------------------------------------------------------------------------
# Operand-pack cache
# ---------------------------------------------------------------------------

def test_pack_cache_hit_on_repeat():
    g = pack_csr(G.poisson2d(12), k=8)
    misses0 = ops.PACK_STATS["misses"]
    s1 = ops.sell_pack_gsecsr(g)
    assert ops.PACK_STATS["misses"] == misses0 + 1
    hits0 = ops.PACK_STATS["hits"]
    assert ops.sell_pack_gsecsr(g) is s1
    assert ops.PACK_STATS["hits"] == hits0 + 1
    assert ops.PACK_STATS["misses"] == misses0 + 1
    # different layout params are distinct cache entries
    s2 = ops.sell_pack_gsecsr(g, sigma=32)
    assert s2 is not s1
    # ELL packs ride the same per-instance cache
    e1 = ops.ell_pack_gsecsr(g)
    assert ops.ell_pack_gsecsr(g) is e1


def test_repeated_solves_zero_host_repacking():
    """The acceptance bar: repeated solve_cg calls on one packed operator
    perform ZERO host-side re-packing (and benchmarks sharing the
    operator reuse the same pack)."""
    a = G.poisson2d(12)
    g = pack_csr(a, k=8)
    s = ops.sell_pack_gsecsr(g)
    b = jnp.asarray(np.asarray(spmv(a, jnp.ones((a.shape[1],)))))
    misses0 = ops.PACK_STATS["misses"]
    r1 = solve_cg(s, b, tol=1e-8, maxiter=2000, params=_params())
    r2 = solve_cg(s, b, tol=1e-8, maxiter=2000, params=_params())
    assert ops.PACK_STATS["misses"] == misses0
    assert bool(r1.converged) and bool(r2.converged)
    np.testing.assert_array_equal(np.asarray(r1.x), np.asarray(r2.x))


# ---------------------------------------------------------------------------
# Solvers ride the layout bit-identically
# ---------------------------------------------------------------------------

def _b_for(a, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.asarray(spmv(a, jnp.asarray(
        rng.normal(size=a.shape[1])))))


def test_solve_cg_sell_bit_identical_to_csr():
    a = _skewed_small(seed=11)
    g = pack_csr(a, k=8)
    s = ops.sell_pack_gsecsr(g)
    b = _b_for(a, seed=1)
    kw = dict(tol=1e-9, maxiter=4000, params=_params())
    r_csr = solve_cg(g, b, **kw)
    r_sell = solve_cg(s, b, **kw)
    r_ref = solve_cg(make_gse_operator(g), b, **kw)
    assert int(r_sell.iters) == int(r_csr.iters) == int(r_ref.iters)
    assert float(r_sell.relres) == float(r_csr.relres)
    np.testing.assert_array_equal(np.asarray(r_sell.switch_iters),
                                  np.asarray(r_csr.switch_iters))
    np.testing.assert_array_equal(np.asarray(r_sell.x), np.asarray(r_csr.x))


def test_solve_pcg_sell_bit_identical_to_csr():
    from repro.solvers import make_jacobi

    a = G.ill_conditioned_spd(16, 8.0)
    g = pack_csr(a, k=8)
    s = ops.sell_pack_gsecsr(g)
    m = make_jacobi(a, k=8)
    b = _b_for(a, seed=2)
    kw = dict(tol=1e-8, maxiter=4000, params=_params())
    r_csr = solve_pcg(g, b, m, **kw)
    r_sell = solve_pcg(s, b, m, **kw)
    assert int(r_sell.iters) == int(r_csr.iters)
    np.testing.assert_array_equal(np.asarray(r_sell.x), np.asarray(r_csr.x))


def test_solve_cg_batched_sell_bit_identical():
    a = G.random_spd(300, seed=13)
    g = pack_csr(a, k=8)
    s = ops.sell_pack_gsecsr(g)
    b = jnp.stack([_b_for(a, seed=j) for j in range(3)], axis=1)
    kw = dict(tol=1e-8, maxiter=3000, params=_params())
    r_csr = solve_cg_batched(g, b, **kw)
    r_sell = solve_cg_batched(s, b, **kw)
    np.testing.assert_array_equal(np.asarray(r_sell.iters),
                                  np.asarray(r_csr.iters))
    np.testing.assert_array_equal(np.asarray(r_sell.x), np.asarray(r_csr.x))
    np.testing.assert_array_equal(np.asarray(r_sell.switch_iters),
                                  np.asarray(r_csr.switch_iters))


def test_service_sell_layout_matches_csr_and_repacks_nothing():
    from repro.launch.solver_serve import SolverService

    a = G.poisson2d(12)

    def rhs(seed):
        rng = np.random.default_rng(seed)
        return spmv(a, jnp.asarray(rng.normal(size=a.shape[1])))

    svc_csr = SolverService(slots=2, params=_params(), maxiter=20000)
    svc_csr.register("op", a, k=8)
    svc_sell = SolverService(slots=2, params=_params(), maxiter=20000)
    svc_sell.register("op", a, k=8, layout="sell")
    misses0 = ops.PACK_STATS["misses"]

    for flush in range(2):
        ids_c = [svc_csr.submit("op", rhs(s), tol=1e-8) for s in (0, 1)]
        ids_s = [svc_sell.submit("op", rhs(s), tol=1e-8) for s in (0, 1)]
        rep_c = svc_csr.flush()
        rep_s = svc_sell.flush()
        for rc, rs in zip(ids_c, ids_s):
            # Trajectories are layout-independent...
            assert rep_s[rs].iters == rep_c[rc].iters
            assert rep_s[rs].relres == rep_c[rc].relres
            np.testing.assert_array_equal(rep_s[rs].switch_iters,
                                          rep_c[rc].switch_iters)
            # ...but the SELL reports charge actual padded slots.
            assert rep_s[rs].est_bytes > rep_c[rc].est_bytes
    # Registration packed once; flush/solve cycles re-packed NOTHING.
    assert ops.PACK_STATS["misses"] == misses0

    with pytest.raises(ValueError, match="unknown layout"):
        svc_csr.register("op2", a, layout="coo")


def test_gsesellc_is_a_pytree():
    g = pack_csr(G.poisson2d(8), k=8)
    s = pack_sell(g)
    leaves, treedef = jax.tree_util.tree_flatten(s)
    s2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(s2, GSESellC)
    assert s2.widths == s.widths and s2.shape == s.shape
    np.testing.assert_array_equal(np.asarray(s2.gather), np.asarray(s.gather))
