"""Tests for the request-batching solve service (launch/solver_serve.py).

The serving front-end must: pack registered operators once, bucket and
pad requests into batch slots, return per-request reports that match the
direct solver exactly, and account the batch's modeled byte stream
(matrix bytes once per iteration, split across the requests sharing the
pass).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as P
from repro.launch.solver_serve import SolverService
from repro.sparse import generators as G
from repro.sparse.csr import pack_csr
from repro.sparse.spmv import spmv
from repro.solvers import make_jacobi, solve_cg, solve_pcg
from repro.solvers.batched import batched_run_bytes


def _params():
    return P.MonitorParams(t=30, l=30, m=15, rsd_limit=0.5, reldec_limit=0.45)


def _mk_service(a, slots=4, precond=None, maxiter=20000):
    svc = SolverService(slots=slots, params=_params(), maxiter=maxiter)
    svc.register("op", a, k=8, precond=precond)
    return svc


def _rhs(a, seed):
    rng = np.random.default_rng(seed)
    return spmv(a, jnp.asarray(rng.normal(size=a.shape[1])))


def test_reports_match_direct_solver():
    """A padded 3-request batch reports exactly what 3 direct solve_cg
    runs report (the batched solver's bit-identity surfaces end to end)."""
    a = G.poisson2d(12)
    g = pack_csr(a, k=8)
    svc = _mk_service(a, slots=4)
    ids = [svc.submit("op", _rhs(a, s), tol=1e-8) for s in range(3)]
    reports = svc.flush()
    assert set(reports) == set(ids)
    for s, rid in enumerate(ids):
        rep = reports[rid]
        direct = solve_cg(g, _rhs(a, s), tol=1e-8, maxiter=20000,
                          params=_params())
        assert rep.iters == int(direct.iters)
        assert rep.relres == float(direct.relres)
        assert rep.converged and bool(direct.converged)
        assert rep.tag == int(direct.tag)
        np.testing.assert_array_equal(rep.switch_iters,
                                      np.asarray(direct.switch_iters))
        assert rep.batch_size == 3
        assert rep.est_bytes > 0
        np.testing.assert_array_equal(np.asarray(svc.solution(rid)),
                                      np.asarray(direct.x))
    assert svc.stats["batches"] == 1
    assert svc.stats["padded_cols"] == 1
    with pytest.raises(KeyError, match="no flushed solution"):
        svc.solution(ids[0])  # popped above


def test_preconditioned_handle_matches_direct_pcg():
    ill = G.ill_conditioned_spd(24, 8.0)
    gi = pack_csr(ill, k=8)
    mi = make_jacobi(ill, k=8)
    svc = _mk_service(ill, slots=2, precond="jacobi")
    rid = svc.submit("op", _rhs(ill, 3), tol=1e-10)
    rep = svc.flush()[rid]
    direct = solve_pcg(gi, _rhs(ill, 3), mi, tol=1e-10, maxiter=20000,
                       params=_params())
    assert rep.iters == int(direct.iters)
    assert rep.relres == float(direct.relres)


def test_buckets_by_tolerance_and_overflow_slots():
    """Requests at different tolerances run in different batches; more
    requests than slots split into multiple slots."""
    a = G.poisson2d(10)
    svc = _mk_service(a, slots=2)
    ids_tight = [svc.submit("op", _rhs(a, s), tol=1e-10) for s in range(3)]
    ids_loose = [svc.submit("op", _rhs(a, s), tol=1e-4) for s in range(2)]
    reports = svc.flush()
    assert len(reports) == 5
    # 3 tight requests at 2 slots -> 2 batches; 2 loose -> 1 batch.
    assert svc.stats["batches"] == 3
    for rid in ids_tight:
        assert reports[rid].relres <= 1e-10
    for rid in ids_loose:
        assert reports[rid].converged
    # Looser requests stop earlier than the same RHS solved tightly.
    assert reports[ids_loose[0]].iters < reports[ids_tight[0]].iters


def test_byte_shares_sum_to_batch_total():
    """Per-request byte shares partition the batched_run_bytes total."""
    a = G.random_spd(400, seed=6)
    g = pack_csr(a, k=8)
    svc = _mk_service(a, slots=4)
    ids = [svc.submit("op", _rhs(a, s), tol=1e-8) for s in range(4)]
    reports = svc.flush()
    res_bytes = sum(reports[r].est_bytes for r in ids)
    # Shares are rounded per column, the total once: equal to within
    # one byte per column.
    assert svc.stats["modeled_bytes"] == pytest.approx(res_bytes,
                                                       abs=len(ids))
    # ... and the batch total is far below 4 independent runs' matrix cost.
    assert svc.stats["modeled_bytes"] < sum(
        reports[r].iters for r in ids
    ) * g.bytes_touched(3)


def test_submit_validation():
    a = G.poisson2d(8)
    svc = _mk_service(a)
    with pytest.raises(KeyError, match="unknown handle"):
        svc.submit("nope", jnp.zeros((a.shape[0],)))
    with pytest.raises(ValueError, match="b must be"):
        svc.submit("op", jnp.zeros((a.shape[0] + 1,)))
    with pytest.raises(ValueError, match="b must be"):
        svc.submit("op", jnp.zeros((a.shape[0], 2)))
    # (n, 1) b AND (n, 1) x0 are accepted (shape-normalization satellite).
    n = a.shape[0]
    rid = svc.submit("op", jnp.asarray(_rhs(a, 0))[:, None],
                     x0=jnp.zeros((n, 1)))
    assert rid in svc.flush()
    with pytest.raises(ValueError, match="x0 shape"):
        svc.submit("op", _rhs(a, 0), x0=jnp.zeros((n, 2)))
    with pytest.raises(ValueError, match="already registered"):
        svc.register("op", a)
    with pytest.raises(ValueError, match="unknown preconditioner"):
        svc.register("op2", a, precond="ilu")
    with pytest.raises(ValueError, match="slots"):
        SolverService(slots=0)


def test_padding_does_not_perturb_requests():
    """The same request reports identically whether its slot is full or
    mostly padding."""
    a = G.poisson2d(12)
    b = _rhs(a, 9)
    svc1 = _mk_service(a, slots=1)
    svc4 = _mk_service(a, slots=4)
    rid1 = svc1.submit("op", b, tol=1e-8)
    r1 = svc1.flush()[rid1]
    rid4 = svc4.submit("op", b, tol=1e-8)
    r4 = svc4.flush()[rid4]
    assert (r1.iters, r1.relres, r1.tag) == (r4.iters, r4.relres, r4.tag)
    # Padding columns converge at iteration 0: they add no iterations and
    # no vector traffic, so the matrix-stream share is identical too.
    assert r1.est_bytes == r4.est_bytes
