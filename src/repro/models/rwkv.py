"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent decay + channel-mix.

Per head (dim N), state S in R^{N x N}:

    out_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
    w_t   = exp(-exp(w_base + lora(x_t)))      data-dependent decay

Training/prefill runs ``lax.scan`` over time (linear in T); decode is an
O(1) state update -- the property that admits the 500k decode shape.
Token-shift mixing follows the RWKV-6 interpolation formulation (we use a
single learned mix per stream rather than the 5-way LoRA stack -- noted in
DESIGN.md as a simplification that preserves shapes and FLOPs structure).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import modules as M
from repro.models.config import ModelConfig

Params = Dict[str, Any]

_DECAY_LORA = 64


def rwkv_time_init(key, cfg: ModelConfig, dtype) -> Tuple[Params, Dict]:
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    ks = jax.random.split(key, 9)
    s = 1.0 / math.sqrt(d)
    p = {
        "mix_r": jnp.full((d,), 0.5, dtype),
        "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        "wr": M._normal(ks[0], (d, d), s, dtype),
        "wk": M._normal(ks[1], (d, d), s, dtype),
        "wv": M._normal(ks[2], (d, d), s, dtype),
        "wg": M._normal(ks[3], (d, d), s, dtype),
        "wo": M._normal(ks[4], (d, d), s, dtype),
        "w_base": jnp.asarray(
            jax.random.uniform(ks[5], (d,), jnp.float32, -2.0, 0.0)
        ),
        "w_lora_a": M._normal(ks[6], (d, _DECAY_LORA), s, jnp.float32),
        "w_lora_b": M._normal(
            ks[7], (_DECAY_LORA, d), 1.0 / math.sqrt(_DECAY_LORA), jnp.float32
        ),
        "bonus_u": M._normal(ks[8], (h, n), 0.1, jnp.float32),
    }
    spec = {
        "mix_r": ("embed",), "mix_k": ("embed",), "mix_v": ("embed",),
        "mix_w": ("embed",),
        "wr": ("embed", "embed_out"), "wk": ("embed", "embed_out"),
        "wv": ("embed", "embed_out"), "wg": ("embed", "embed_out"),
        "wo": ("embed", "embed_out"),
        "w_base": ("embed",),
        "w_lora_a": ("embed", "lora"),
        "w_lora_b": ("lora", "embed"),
        "bonus_u": ("rwkv_heads", "head_dim"),
    }
    return p, spec


def rwkv_channel_init(key, cfg: ModelConfig, dtype) -> Tuple[Params, Dict]:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "mix_k": jnp.full((d,), 0.5, dtype),
        "wk": M._normal(ks[0], (d, ff), 1.0 / math.sqrt(d), dtype),
        "wv": M._normal(ks[1], (ff, d), 1.0 / math.sqrt(ff), dtype),
        "wr": M._normal(ks[2], (d, d), 1.0 / math.sqrt(d), dtype),
    }
    spec = {
        "mix_k": ("embed",),
        "wk": ("embed", "mlp"),
        "wv": ("mlp", "embed"),
        "wr": ("embed", "embed_out"),
    }
    return p, spec


def _shift(x, prev=None):
    """Token shift: x_{t-1} stream. prev: (B, D) last token of prior chunk."""
    if prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xs, m):
    return x * m.astype(x.dtype) + xs * (1.0 - m.astype(x.dtype))


def _decay(p, xw):
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    return jnp.exp(-jnp.exp(p["w_base"] + lora))  # (B,S,D) in (0,1)


def rwkv_time_apply(p, x, cfg: ModelConfig, state=None):
    """x: (B,S,D).  state: {"S": (B,H,N,N) f32, "last": (B,D)} or None.
    Returns (out, new_state)."""
    dtype = cfg.compute_dtype
    b, s, d = x.shape
    n = cfg.rwkv_head_dim
    h = d // n
    prev = None if state is None else state["last"]
    xs = _shift(x, prev)
    r = jnp.dot(_mix(x, xs, p["mix_r"]).astype(dtype), p["wr"].astype(dtype))
    k = jnp.dot(_mix(x, xs, p["mix_k"]).astype(dtype), p["wk"].astype(dtype))
    v = jnp.dot(_mix(x, xs, p["mix_v"]).astype(dtype), p["wv"].astype(dtype))
    g = jax.nn.silu(
        jnp.dot(_mix(x, xs, p["mix_w"]).astype(dtype), p["wg"].astype(dtype))
    )
    w = _decay(p, _mix(x, xs, p["mix_w"]))                    # (B,S,D) f32

    rh = r.reshape(b, s, h, n).astype(jnp.float32)
    kh = k.reshape(b, s, h, n).astype(jnp.float32)
    vh = v.reshape(b, s, h, n).astype(jnp.float32)
    wh = w.reshape(b, s, h, n)
    u = p["bonus_u"]                                          # (H,N)

    s0 = (
        jnp.zeros((b, h, n, n), jnp.float32)
        if state is None
        else state["S"]
    )

    def step(S, inp):
        rt, kt, vt, wt = inp                                  # (B,H,N) each
        kv = kt[..., :, None] * vt[..., None, :]              # (B,H,N,N)
        out = jnp.einsum(
            "bhn,bhnm->bhm", rt, S + u[None, :, :, None] * kv
        )
        S = wt[..., :, None] * S + kv
        return S, out

    xs_seq = (
        rh.transpose(1, 0, 2, 3),
        kh.transpose(1, 0, 2, 3),
        vh.transpose(1, 0, 2, 3),
        wh.transpose(1, 0, 2, 3),
    )
    S_fin, outs = jax.lax.scan(step, s0, xs_seq)
    out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)         # (B,S,D) f32
    out = (out.astype(dtype) * g)
    y = jnp.dot(out, p["wo"].astype(dtype))
    return y, {"S": S_fin, "last": x[:, -1, :]}


def rwkv_channel_apply(p, x, cfg: ModelConfig, prev=None):
    dtype = cfg.compute_dtype
    xs = _shift(x, prev)
    xk = _mix(x, xs, p["mix_k"]).astype(dtype)
    xr = _mix(x, xs, p["mix_k"]).astype(dtype)
    k = jnp.square(jax.nn.relu(jnp.dot(xk, p["wk"].astype(dtype))))
    kv = jnp.dot(k, p["wv"].astype(dtype))
    r = jax.nn.sigmoid(jnp.dot(xr, p["wr"].astype(dtype)))
    return r * kv, x[:, -1, :]


def rwkv_state_init(cfg: ModelConfig, batch: int) -> Dict:
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    return {
        "S": jnp.zeros((batch, h, n, n), jnp.float32),
        "last_t": jnp.zeros((batch, d), jnp.float32),
        "last_c": jnp.zeros((batch, d), jnp.float32),
    }
