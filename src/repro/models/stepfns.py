"""Train / serve step factories.

The LM loss is computed in sequence chunks (scan + checkpoint) so the
(B, S, V) logits tensor never materializes -- at vocab 256k and S=4k this
is the difference between ~65 MB and ~2 GB per device of live activations.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig

Params = Dict[str, Any]

AUX_LOSS_WEIGHT = 0.01


def lm_loss(cfg: ModelConfig, params: Params, hidden: jnp.ndarray,
            labels: jnp.ndarray, mask: jnp.ndarray,
            chunk: Optional[int] = None) -> jnp.ndarray:
    """Mean masked cross-entropy, chunked over the sequence axis."""
    b, s, d = hidden.shape
    if chunk is None:
        chunk = s
        for c in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
            if s % c == 0 and c <= s:
                chunk = c
                break
    nch = s // chunk
    h = hidden.reshape(b, nch, chunk, d).transpose(1, 0, 2, 3)
    l = labels.reshape(b, nch, chunk).transpose(1, 0, 2)
    m = mask.reshape(b, nch, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        h_c, l_c, m_c = xs
        logits = T.logits_from_hidden(cfg, params, h_c)      # (B, c, V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        loss = jnp.sum((logz - ll) * m_c)
        return carry + loss, None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (h, l, m))
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return total / denom


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Params
    opt_state: Any
    step: jnp.ndarray

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def make_loss_fn(cfg: ModelConfig) -> Callable:
    def loss_fn(params, batch):
        h, aux = T.forward(
            cfg, params, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            enc_embeds=batch.get("enc_embeds"),
        )
        if "prefix_embeds" in batch and batch["prefix_embeds"] is not None:
            # prefix positions carry no LM loss; hidden includes them.
            p = batch["prefix_embeds"].shape[1]
            h = h[:, p:, :]
        loss = lm_loss(cfg, params, h, batch["labels"], batch["loss_mask"])
        total = loss + AUX_LOSS_WEIGHT * aux
        return total, {"loss": loss, "aux_loss": aux}

    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer,
                    grad_transform: Optional[Callable] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_transform(grads) -> grads`` is the hook where the distributed
    layer installs GSE-SEM gradient compression (DESIGN.md §3.3).
    """
    loss_fn = make_loss_fn(cfg)

    def train_step(state: TrainState, batch):
        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch
        )
        if grad_transform is not None:
            grads = grad_transform(grads)
        updates, opt_state = optimizer.update(
            grads, state.opt_state, state.params, state.step
        )
        params = jax.tree.map(lambda p, u: p + u, state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        gnorm = jnp.sqrt(
            sum(jnp.vdot(g, g).real for g in jax.tree.leaves(grads))
        )
        metrics = dict(metrics, total_loss=total, grad_norm=gnorm)
        return new_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step(params, state, tokens, pos[, enc_out]) -> (next, state).

    One new token per request with a filled KV cache -- the exact
    computation the decode_* dry-run shapes lower.
    """

    def serve_step(params, state, tokens, pos, enc_out=None):
        logits, state = T.decode_step(cfg, params, state, tokens, pos,
                                      enc_out=enc_out)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, state

    return serve_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """Full-sequence forward returning last-position logits (prefill)."""

    def prefill(params, tokens, prefix_embeds=None, enc_embeds=None):
        h, _ = T.forward(cfg, params, tokens, prefix_embeds=prefix_embeds,
                         enc_embeds=enc_embeds)
        return T.logits_from_hidden(cfg, params, h[:, -1:, :])[:, 0, :]

    return prefill
