"""Attention: MHA/GQA/MQA with qk-norm, QKV bias, local windows, KV cache,
cross-attention -- covering all attention flavours in the assigned archs.

GQA uses the grouped einsum formulation (no materialized KV repeat):
  q: (B, S, KV, G, hd)  k: (B, T, KV, hd)  ->  scores (B, KV, G, S, T)

Decode uses a ring buffer for local-window layers (RecurrentGemma): the
cache holds only ``window`` positions, which is what makes the 500k-token
decode shape feasible (DESIGN.md §6).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import modules as M
from repro.models.config import ModelConfig

Params = Dict[str, Any]

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, dtype) -> Tuple[Params, Dict]:
    """Fused projection layout: wq (d, H*hd) etc.

    The fused width H*hd divides the 16-way model axis for ALL 10 assigned
    archs (raw head counts like 40 or 10 do not) -- so TP shards the fused
    dim evenly and GSPMD is free to pick (padded) internal shardings for
    the per-head reshape (DESIGN.md §5).
    """
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    p, spec = {}, {}
    p["wq"], spec["wq"] = M.linear_weight_init(
        ks[0], (d, h * hd), s, cfg, ("embed", "qkv"))
    p["wk"], spec["wk"] = M.linear_weight_init(
        ks[1], (d, kv * hd), s, cfg, ("embed", "qkv"))
    p["wv"], spec["wv"] = M.linear_weight_init(
        ks[2], (d, kv * hd), s, cfg, ("embed", "qkv"))
    p["wo"], spec["wo"] = M.linear_weight_init(
        ks[3], (h * hd, d), 1.0 / math.sqrt(h * hd), cfg, ("qkv", "embed"))
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
        spec["bq"] = ("qkv",)
        spec["bk"] = ("qkv",)
        spec["bv"] = ("qkv",)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
        spec["q_norm"] = ("head_dim",)
        spec["k_norm"] = ("head_dim",)
    return p, spec


def cross_attn_init(key, cfg: ModelConfig, dtype) -> Tuple[Params, Dict]:
    return attn_init(key, cfg, dtype)


def _qk_normalize(p, q, k):
    def rn(x, scale):
        x32 = x.astype(jnp.float32)
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        return (x32 * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)
                ).astype(x.dtype)

    return rn(q, p["q_norm"]), rn(k, p["k_norm"])


def _project_qkv(p, x, cfg: ModelConfig, dtype):
    xc = x.astype(dtype)
    b, s = x.shape[:2]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = jnp.dot(xc, M.take_weight(p["wq"], cfg, dtype, (None, "qkv")))
    k = jnp.dot(xc, M.take_weight(p["wk"], cfg, dtype, (None, "qkv")))
    v = jnp.dot(xc, M.take_weight(p["wv"], cfg, dtype, (None, "qkv")))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q, k = _qk_normalize(p, q, k)
    return q, k, v


def _attend(q, k, v, mask, cfg: ModelConfig, dtype):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd); mask broadcastable (B,1,1,S,T)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bskge,btke->bkgst", qg, k).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = jnp.einsum("bkgst,btke->bskge", probs, v)
    return out.reshape(b, s, h, hd)


def _attend_q_chunked(q, k, v, positions, cfg: ModelConfig, dtype,
                      window: int, chunk: int):
    """Query-chunked causal attention (O3 hillclimb lever).

    Processes queries in chunks of ``chunk``: live score buffers shrink
    from (B,H,S,T) to (B,H,chunk,T), and jax.checkpoint on the chunk body
    keeps the backward pass at the same footprint (recompute-per-chunk)
    instead of stashing full score matrices.
    """
    b, s, h, hd = q.shape
    nc = s // chunk
    qc = q.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pc = positions.reshape(b, nc, chunk).transpose(1, 0, 2)
    jpos = positions[:, None, :]  # (B,1,T)

    @jax.checkpoint
    def body(carry, xs):
        q_i, p_i = xs                            # (B,chunk,H,hd), (B,chunk)
        mask = jpos <= p_i[:, :, None]
        if window:
            mask &= jpos > p_i[:, :, None] - window
        out_i = _attend(q_i, k, v, mask[:, None, None, :, :], cfg, dtype)
        return carry, out_i

    _, outs = jax.lax.scan(body, jnp.zeros((), dtype), (qc, pc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def attn_apply(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    window: int = 0,
    use_rope: bool = True,
) -> jnp.ndarray:
    """Full-sequence (train / prefill) self-attention, causal."""
    dtype = cfg.compute_dtype
    q, k, v = _project_qkv(p, x, cfg, dtype)
    if use_rope:
        q = M.rope(q, positions, cfg.rope_theta)
        k = M.rope(k, positions, cfg.rope_theta)
    s = x.shape[1]
    if (cfg.attn_impl == "chunked" and s > cfg.attn_chunk
            and s % cfg.attn_chunk == 0):
        out = _attend_q_chunked(q, k, v, positions, cfg, dtype, window,
                                cfg.attn_chunk)
    else:
        i = positions[:, :, None]  # (B,S,1)
        j = positions[:, None, :]  # (B,1,S)
        mask = j <= i
        if window:
            mask &= j > i - window
        mask = mask[:, None, None, :, :]  # (B,1,1,S,T)
        out = _attend(q, k, v, mask, cfg, dtype)
    b2, s2 = out.shape[:2]
    wo = M.take_weight(p["wo"], cfg, dtype, ("qkv", None))
    return jnp.dot(out.reshape(b2, s2, -1), wo)


def encoder_attn_apply(p, x, cfg: ModelConfig, positions) -> jnp.ndarray:
    """Bidirectional (encoder) self-attention."""
    dtype = cfg.compute_dtype
    q, k, v = _project_qkv(p, x, cfg, dtype)
    b, s = x.shape[:2]
    mask = jnp.ones((b, 1, 1, s, s), bool)
    out = _attend(q, k, v, mask, cfg, dtype)
    b2, s2 = out.shape[:2]
    wo = M.take_weight(p["wo"], cfg, dtype, ("qkv", None))
    return jnp.dot(out.reshape(b2, s2, -1), wo)


def cross_attn_apply(p, x, enc_kv, cfg: ModelConfig) -> jnp.ndarray:
    """Decoder cross-attention; ``enc_kv = (k, v)`` precomputed once."""
    dtype = cfg.compute_dtype
    xc = x.astype(dtype)
    q = jnp.dot(xc, M.take_weight(p["wq"], cfg, dtype, (None, "qkv")))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
    q = q.reshape(x.shape[0], x.shape[1], cfg.num_heads, cfg.hd)
    k, v = enc_kv
    b, s = x.shape[:2]
    t = k.shape[1]
    mask = jnp.ones((b, 1, 1, s, t), bool)
    out = _attend(q, k, v, mask, cfg, dtype)
    b2, s2 = out.shape[:2]
    wo = M.take_weight(p["wo"], cfg, dtype, ("qkv", None))
    return jnp.dot(out.reshape(b2, s2, -1), wo)


def cross_kv(p, enc_out, cfg: ModelConfig):
    dtype = cfg.compute_dtype
    xc = enc_out.astype(dtype)
    k = jnp.dot(xc, M.take_weight(p["wk"], cfg, dtype, (None, "qkv")))
    v = jnp.dot(xc, M.take_weight(p["wv"], cfg, dtype, (None, "qkv")))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    b, s = enc_out.shape[:2]
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.hd)
    return k, v


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

# 8-bit GSE-SEM cache entry: sign(1) | expIdx(3) | mantissa(4).  The shared
# exponent table is a compile-time constant covering the activation range
# (unbiased exponents; paper III.B with k=8, one-byte SEM).  One stored
# copy at 1 byte/value -- 2x below bf16, 4x below f32 -- the paper's
# segmented-precision idea applied to the KV stream.
_KV_TABLE = (5, 3, 1, -1, -3, -5, -7, -9)
_KV_MBITS = 4


def _kv_pack_u8(x: jnp.ndarray) -> jnp.ndarray:
    a = jnp.abs(x.astype(jnp.float32))
    sign = (x < 0).astype(jnp.uint8)
    best_idx = jnp.zeros(x.shape, jnp.uint8)
    best_mant = jnp.zeros(x.shape, jnp.uint8)
    found = jnp.zeros(x.shape, bool)
    for j, e in reversed(list(enumerate(_KV_TABLE))):
        # ascending exponents: the first fit is the TIGHTEST binade.
        mant = a * jnp.float32(2.0 ** (_KV_MBITS - e))
        fits = (mant < 15.5) & ~found
        best_idx = jnp.where(fits, jnp.uint8(j), best_idx)
        best_mant = jnp.where(
            fits,
            jnp.clip(jnp.round(mant), 0, 15).astype(jnp.uint8),
            best_mant,
        )
        found = found | fits
    # Values above the largest binade saturate to max magnitude.
    best_mant = jnp.where(found, best_mant, jnp.uint8(15))
    return (sign << 7) | (best_idx << 4) | best_mant


def _kv_decode_u8(u: jnp.ndarray, dtype) -> jnp.ndarray:
    sgn = 1.0 - 2.0 * ((u >> 7) & 0x1).astype(jnp.float32)
    idx = ((u >> 4) & 0x7).astype(jnp.int32)
    mant = (u & 0xF).astype(jnp.float32)
    scales = jnp.asarray(
        [2.0 ** (e - _KV_MBITS) for e in _KV_TABLE], jnp.float32
    )
    return (sgn * mant * scales[idx]).astype(dtype)


def cache_init(cfg: ModelConfig, batch: int, max_len: int, window: int = 0,
               dtype=None) -> Dict:
    """Per-layer cache. Local-window layers use a ring of size ``window``."""
    dtype = dtype or cfg.compute_dtype
    if cfg.kv_cache_gse:
        dtype = jnp.uint8
    size = min(window, max_len) if window else max_len
    kv, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, size, kv, hd), dtype),
        "v": jnp.zeros((batch, size, kv, hd), dtype),
    }


def decode_attn_apply(
    p: Params,
    x: jnp.ndarray,           # (B, 1, D)
    cache: Dict,
    pos: jnp.ndarray,         # () int32 -- current position
    cfg: ModelConfig,
    window: int = 0,
    use_rope: bool = True,
) -> Tuple[jnp.ndarray, Dict]:
    dtype = cfg.compute_dtype
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, dtype)
    if use_rope:
        q = M.rope(q, positions, cfg.rope_theta)
        k_new = M.rope(k_new, positions, cfg.rope_theta)
    size = cache["k"].shape[1]
    slot = pos % size if window else jnp.minimum(pos, size - 1)
    if cfg.kv_cache_gse:
        k_new = _kv_pack_u8(k_new)
        v_new = _kv_pack_u8(v_new)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    new_cache = {"k": k, "v": v}
    if cfg.kv_cache_gse:
        k = _kv_decode_u8(k, dtype)
        v = _kv_decode_u8(v, dtype)
    # Valid positions: ring semantics for windows, prefix otherwise.
    idx = jnp.arange(size)
    if window:
        valid = (idx <= slot) | (pos >= size)  # full ring once wrapped
        true_pos = jnp.where(idx <= slot, pos - (slot - idx),
                             pos - (slot + size - idx))
        valid &= true_pos >= 0
    else:
        valid = idx <= pos
    mask = valid[None, None, None, None, :]
    out = _attend(q, k, v, mask, cfg, dtype)
    b2, s2 = out.shape[:2]
    wo = M.take_weight(p["wo"], cfg, dtype, ("qkv", None))
    y = jnp.dot(out.reshape(b2, s2, -1), wo)
    return y, new_cache
