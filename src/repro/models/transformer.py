"""Model assembly for all 10 assigned architectures.

Families:
  dense   -- pre-norm decoder-only (qwen1.5-32b, qwen3-4b, granite-34b,
             granite-3-2b; also the LM backbone of internvl2 [vlm])
  moe     -- dense skeleton with MoE FFN (qwen3-moe-235b, grok-1-314b)
  hybrid  -- RecurrentGemma: RG-LRU blocks with every ``hybrid_period``-th
             layer a local-window MQA (Python-loop layers, heterogeneous)
  ssm     -- RWKV-6: time-mix + channel-mix (attention-free)
  encdec  -- Seamless-M4T: bidirectional encoder (frontend stub supplies
             frame embeddings) + causal decoder with cross-attention

Homogeneous stacks scan over layers (keeps the dry-run HLO small and lets
the XLA scheduler overlap per-layer collectives with compute); the hybrid
family uses a Python loop over its 26 heterogeneous layers.

Activation sharding constraints are inserted at block boundaries through
``repro.distributed.sharding.shard`` (no-op outside a rules context).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention as A
from repro.models import modules as M
from repro.models import moe as MOE
from repro.models import rglru as R
from repro.models import rwkv as W
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, kind: str, dtype):
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["norm1"], s["norm1"] = M.rmsnorm_init(cfg.d_model, dtype)
    if kind in ("attn", "enc_attn", "local_attn"):
        p["attn"], s["attn"] = A.attn_init(ks[0], cfg, dtype)
        p["norm2"], s["norm2"] = M.rmsnorm_init(cfg.d_model, dtype)
        p["mlp"], s["mlp"] = M.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                        cfg.mlp_act, dtype, cfg=cfg)
    elif kind == "dec_attn":  # decoder layer with cross-attention
        p["attn"], s["attn"] = A.attn_init(ks[0], cfg, dtype)
        p["norm_x"], s["norm_x"] = M.rmsnorm_init(cfg.d_model, dtype)
        p["xattn"], s["xattn"] = A.cross_attn_init(ks[2], cfg, dtype)
        p["norm2"], s["norm2"] = M.rmsnorm_init(cfg.d_model, dtype)
        p["mlp"], s["mlp"] = M.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                        cfg.mlp_act, dtype, cfg=cfg)
    elif kind == "moe":
        p["attn"], s["attn"] = A.attn_init(ks[0], cfg, dtype)
        p["norm2"], s["norm2"] = M.rmsnorm_init(cfg.d_model, dtype)
        p["moe"], s["moe"] = MOE.moe_init(ks[1], cfg, dtype)
    elif kind == "rglru":
        p["rglru"], s["rglru"] = R.rglru_init(ks[0], cfg, dtype)
        p["norm2"], s["norm2"] = M.rmsnorm_init(cfg.d_model, dtype)
        p["mlp"], s["mlp"] = M.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                        cfg.mlp_act, dtype, cfg=cfg)
    elif kind == "rwkv":
        p["time"], s["time"] = W.rwkv_time_init(ks[0], cfg, dtype)
        p["norm2"], s["norm2"] = M.rmsnorm_init(cfg.d_model, dtype)
        p["chan"], s["chan"] = W.rwkv_channel_init(ks[1], cfg, dtype)
    else:
        raise ValueError(kind)
    return p, s


def _layer_kinds(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.family == "hybrid":
        attn_ids = set(cfg.attn_layer_ids())
        return tuple(
            "local_attn" if i in attn_ids else "rglru"
            for i in range(cfg.num_layers)
        )
    if cfg.family == "ssm":
        return ("rwkv",) * cfg.num_layers
    if cfg.family == "moe":
        return ("moe",) * cfg.num_layers
    return ("attn",) * cfg.num_layers


def _stackable(cfg: ModelConfig) -> bool:
    kinds = _layer_kinds(cfg)
    return cfg.scan_layers and len(set(kinds)) == 1


def init_params(cfg: ModelConfig, key) -> Tuple[Params, Dict]:
    dtype = cfg.param_dtype
    ks = jax.random.split(key, 8)
    params: Params = {}
    specs: Dict = {}

    # Tables are built at padded_vocab so they shard evenly over the
    # 16-way model axis; logits are sliced back to the true vocab.
    params["embed"], specs["embed"] = M.embed_init(
        ks[0], cfg.padded_vocab, cfg.d_model, dtype
    )
    params["final_norm"], specs["final_norm"] = M.rmsnorm_init(
        cfg.d_model, dtype
    )
    if not cfg.tie_embeddings:
        params["unembed"], specs["unembed"] = M.unembed_init(
            ks[1], cfg.padded_vocab, cfg.d_model, dtype, cfg=cfg
        )

    if cfg.family == "encdec":
        enc_keys = jax.random.split(ks[2], cfg.encoder_layers)
        dec_keys = jax.random.split(ks[3], cfg.num_layers)
        params["encoder"] = jax.vmap(
            lambda k: _layer_init(k, cfg, "enc_attn", dtype)[0]
        )(enc_keys)
        _, s1 = _layer_init(ks[2], cfg, "enc_attn", dtype)
        specs["encoder"] = jax.tree.map(
            lambda ax: ("layers",) + ax, s1, is_leaf=lambda x: isinstance(x, tuple)
        )
        params["decoder"] = jax.vmap(
            lambda k: _layer_init(k, cfg, "dec_attn", dtype)[0]
        )(dec_keys)
        _, s2 = _layer_init(ks[3], cfg, "dec_attn", dtype)
        specs["decoder"] = jax.tree.map(
            lambda ax: ("layers",) + ax, s2, is_leaf=lambda x: isinstance(x, tuple)
        )
        return params, specs

    kinds = _layer_kinds(cfg)
    if _stackable(cfg):
        layer_keys = jax.random.split(ks[2], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, kinds[0], dtype)[0]
        )(layer_keys)
        _, s1 = _layer_init(ks[2], cfg, kinds[0], dtype)
        specs["layers"] = jax.tree.map(
            lambda ax: ("layers",) + ax, s1, is_leaf=lambda x: isinstance(x, tuple)
        )
    else:
        layer_keys = jax.random.split(ks[2], cfg.num_layers)
        ps, ss = [], []
        for i, kind in enumerate(kinds):
            p, s = _layer_init(layer_keys[i], cfg, kind, dtype)
            ps.append(p)
            ss.append(s)
        params["layers"] = ps
        specs["layers"] = ss
    return params, specs


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_apply(cfg: ModelConfig, p, x, positions, kind: str, enc_kv=None):
    """Returns (y, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = M.rmsnorm(p["norm1"], x)
    if kind == "attn":
        y = A.attn_apply(p["attn"], h, cfg, positions)
    elif kind == "enc_attn":
        y = A.encoder_attn_apply(p["attn"], h, cfg, positions)
    elif kind == "local_attn":
        y = A.attn_apply(p["attn"], h, cfg, positions,
                         window=cfg.local_window)
    elif kind == "dec_attn":
        y = A.attn_apply(p["attn"], h, cfg, positions)
    elif kind == "moe":
        y = A.attn_apply(p["attn"], h, cfg, positions)
    elif kind == "rglru":
        y = R.rglru_apply(p["rglru"], h, cfg)
    elif kind == "rwkv":
        y, _ = W.rwkv_time_apply(p["time"], h, cfg)
    else:
        raise ValueError(kind)
    x = x + y.astype(x.dtype)
    x = shard(x, "batch", "seq", "act_embed")

    if kind == "dec_attn":
        hx = M.rmsnorm(p["norm_x"], x)
        x = x + A.cross_attn_apply(p["xattn"], hx, enc_kv, cfg).astype(x.dtype)

    h2 = M.rmsnorm(p["norm2"], x)
    if kind == "moe":
        y2, aux = MOE.moe_apply(p["moe"], h2, cfg)
    elif kind == "rwkv":
        y2, _ = W.rwkv_channel_apply(p["chan"], h2, cfg)
    else:
        y2 = M.mlp(p["mlp"], h2, cfg.mlp_act, cfg.compute_dtype, cfg=cfg)
    x = x + y2.astype(x.dtype)
    x = shard(x, "batch", "seq", "act_embed")
    return x, aux


def _maybe_remat(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        # Save matmul outputs; recompute only cheap elementwise in bwd:
        # ~-30% recompute FLOPs/traffic vs "full" for ~2x live activations.
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return fn


def _run_stack(cfg: ModelConfig, layers, x, positions, kinds, enc_kv=None):
    if _stackable(cfg) and cfg.family != "encdec":
        body_fn = _maybe_remat(
            cfg,
            lambda carry, lp: (
                lambda r: ((r[0], carry[1] + r[1]), None)
            )(_block_apply(cfg, lp, carry[0], positions, kinds[0])),
        )
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                   layers)
        return x, aux
    aux = jnp.zeros((), jnp.float32)
    for p, kind in zip(layers, kinds):
        fn = _maybe_remat(
            cfg, lambda xx, pp=p, kk=kind: _block_apply(cfg, pp, xx, positions,
                                                        kk, enc_kv)
        )
        x, a = fn(x)
        aux = aux + a
    return x, aux


def _scan_encdec(cfg: ModelConfig, layers, x, positions, kind, enc_kv=None):
    def body(carry, lp):
        y, _ = _block_apply(cfg, lp, carry, positions, kind, enc_kv)
        return y, None

    body = _maybe_remat(cfg, body)
    x, _ = jax.lax.scan(body, x, layers)
    return x


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,                       # (B, S_text)
    prefix_embeds: Optional[jnp.ndarray] = None,   # vlm: (B, P, D)
    enc_embeds: Optional[jnp.ndarray] = None,      # encdec: (B, S_enc, D)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (final_hidden (B,S,D), aux_loss)."""
    dtype = cfg.compute_dtype
    x = M.embed(params["embed"], tokens, dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = shard(x, "batch", "seq", "act_embed")

    if cfg.family == "encdec":
        assert enc_embeds is not None, "encdec needs encoder-side embeddings"
        e = enc_embeds.astype(dtype)
        be, se = e.shape[:2]
        e = e + M.sinusoidal(
            jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32), (be, se)),
            cfg.d_model,
        ).astype(dtype)
        e = shard(e, "batch", "seq", "act_embed")
        enc_pos = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32), (be, se))
        enc_out = _scan_encdec(cfg, params["encoder"], e, enc_pos, "enc_attn")

        # Cross K/V computed per layer inside the scan: carry enc_out.
        def dec_body(carry, lp):
            xx = carry
            kv = A.cross_kv(lp["xattn"], enc_out, cfg)
            y, _ = _block_apply(cfg, lp, xx, positions, "dec_attn", kv)
            return y, None

        x, _ = jax.lax.scan(_maybe_remat(cfg, dec_body), x, params["decoder"])
        aux = jnp.zeros((), jnp.float32)
    else:
        kinds = _layer_kinds(cfg)
        x, aux = _run_stack(cfg, params["layers"], x, positions, kinds)

    x = M.rmsnorm(params["final_norm"], x)
    return x, aux


def logits_from_hidden(cfg: ModelConfig, params: Params,
                       h: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(cfg.compute_dtype)
        logits = jnp.dot(h.astype(cfg.compute_dtype), w.T,
                         preferred_element_type=jnp.float32)
    else:
        logits = M.unembed(params["unembed"], h, cfg.compute_dtype,
                           cfg=cfg)
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., : cfg.vocab_size]
    return logits


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def decode_state_init(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Stacked (scan-compatible) per-layer decode state."""
    kinds = _layer_kinds(cfg)

    def one(kind):
        if kind in ("attn", "moe", "dec_attn"):
            return A.cache_init(cfg, batch, max_len)
        if kind == "local_attn":
            return A.cache_init(cfg, batch, max_len, window=cfg.local_window)
        if kind == "rglru":
            return R.rglru_state_init(cfg, batch, cfg.compute_dtype)
        if kind == "rwkv":
            return W.rwkv_state_init(cfg, batch)
        raise ValueError(kind)

    if cfg.family == "encdec":
        caches = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[A.cache_init(cfg, batch, max_len) for _ in range(cfg.num_layers)],
        )
        return {"self": caches}
    if _stackable(cfg):
        return {
            "layers": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[one(kinds[0]) for _ in range(cfg.num_layers)],
            )
        }
    return {"layers": [one(k) for k in kinds]}


def decode_state_specs(cfg: ModelConfig) -> Dict:
    """Logical-axis specs mirroring ``decode_state_init`` (for shardings)."""
    kinds = _layer_kinds(cfg)
    attn_spec = {
        "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    }

    def one(kind, stacked=True):
        lead = ("layers",) if stacked else ()
        if kind in ("attn", "moe", "dec_attn", "local_attn"):
            return {
                "k": lead + ("batch", "kv_seq", "kv_heads", "head_dim"),
                "v": lead + ("batch", "kv_seq", "kv_heads", "head_dim"),
            }
        if kind == "rglru":
            return {
                "h": lead + ("batch", "lru"),
                "conv": lead + ("batch", "conv_w", "lru"),
            }
        if kind == "rwkv":
            return {
                "S": lead + ("batch", "rwkv_heads", "head_dim", "head_dim2"),
                "last_t": lead + ("batch", "act_embed"),
                "last_c": lead + ("batch", "act_embed"),
            }
        raise ValueError(kind)

    if cfg.family == "encdec":
        return {"self": attn_spec}
    if _stackable(cfg):
        return {"layers": one(kinds[0])}
    return {"layers": [one(k, stacked=False) for k in kinds]}


def _block_decode(cfg, p, x, cache, pos, kind, enc_kv=None):
    h = M.rmsnorm(p["norm1"], x)
    if kind in ("attn", "moe", "dec_attn"):
        y, cache2 = A.decode_attn_apply(p["attn"], h, cache, pos, cfg)
    elif kind == "local_attn":
        y, cache2 = A.decode_attn_apply(p["attn"], h, cache, pos, cfg,
                                        window=cfg.local_window)
    elif kind == "rglru":
        y, cache2 = R.rglru_step(p["rglru"], h, cache, cfg)
    elif kind == "rwkv":
        y, st = W.rwkv_time_apply(
            p["time"], h, cfg, state={"S": cache["S"], "last": cache["last_t"]}
        )
        cache2 = dict(cache, S=st["S"], last_t=st["last"])
    else:
        raise ValueError(kind)
    x = x + y.astype(x.dtype)

    if kind == "dec_attn":
        hx = M.rmsnorm(p["norm_x"], x)
        x = x + A.cross_attn_apply(p["xattn"], hx, enc_kv, cfg).astype(x.dtype)

    h2 = M.rmsnorm(p["norm2"], x)
    if kind == "moe":
        y2, _ = MOE.moe_apply(p["moe"], h2, cfg)
    elif kind == "rwkv":
        y2, last_c = W.rwkv_channel_apply(p["chan"], h2, cfg,
                                          prev=cache["last_c"])
        cache2 = dict(cache2, last_c=last_c)
    else:
        y2 = M.mlp(p["mlp"], h2, cfg.mlp_act, cfg.compute_dtype, cfg=cfg)
    x = x + y2.astype(x.dtype)
    return x, cache2


def decode_step(
    cfg: ModelConfig,
    params: Params,
    state: Dict,
    tokens: jnp.ndarray,          # (B,) current tokens
    pos: jnp.ndarray,             # () int32 position
    enc_out: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict]:
    """One decode step: returns (logits (B, V), new state)."""
    dtype = cfg.compute_dtype
    x = M.embed(params["embed"], tokens[:, None], dtype)   # (B,1,D)
    x = shard(x, "batch", None, "act_embed")
    kinds = _layer_kinds(cfg)

    if cfg.family == "encdec":
        def body(carry, xs):
            lp, cache = xs
            kv = A.cross_kv(lp["xattn"], enc_out, cfg)
            y, c2 = _block_decode(cfg, lp, carry, cache, pos, "dec_attn", kv)
            return y, c2

        x, new_cache = jax.lax.scan(body, x, (params["decoder"],
                                              state["self"]))
        state = {"self": new_cache}
    elif _stackable(cfg):
        def body(carry, xs):
            lp, cache = xs
            y, c2 = _block_decode(cfg, lp, carry, cache, pos, kinds[0])
            return y, c2

        x, new_cache = jax.lax.scan(body, x, (params["layers"],
                                              state["layers"]))
        state = {"layers": new_cache}
    else:
        new_caches = []
        for p, kind, cache in zip(params["layers"], kinds, state["layers"]):
            x, c2 = _block_decode(cfg, p, x, cache, pos, kind)
            new_caches.append(c2)
        state = {"layers": new_caches}

    h = M.rmsnorm(params["final_norm"], x)
    logits = logits_from_hidden(cfg, params, h)[:, 0, :]
    return logits, state
