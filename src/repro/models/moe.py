"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Two dispatch strategies (the roofline hillclimb lever, DESIGN.md §9):

  * ``sort``  (default): tokens are sorted by expert assignment and packed
    into an (E, C, d) buffer -- compute is ``E*C = cf * k/E-active`` FLOPs,
    i.e. proportional to *active* experts, like MaxText's dropless path.
    Over-capacity tokens are dropped (standard GShard/Switch semantics).
  * ``dense`` (naive baseline): every expert computes every token, masked
    after the fact.  E/k x more FLOPs -- kept as the anti-baseline the
    roofline table exposes.

EP sharding: the (E, ...) leading dims of both the token buffer and the
expert weight stacks carry the ``experts`` logical axis.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import modules as M
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def moe_init(key, cfg: ModelConfig, dtype) -> Tuple[Params, Dict]:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.expert_ff
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    p = {
        "router": M._normal(ks[0], (d, e), s_in, jnp.float32),
        "w_gate": M._normal(ks[1], (e, d, ff), s_in, dtype),
        "w_up": M._normal(ks[2], (e, d, ff), s_in, dtype),
        "w_down": M._normal(ks[3], (e, ff, d), s_out, dtype),
    }
    spec = {
        "router": ("embed", "experts_router"),
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_up": ("experts", "embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed"),
    }
    return p, spec


def _capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(
        math.ceil(
            cfg.capacity_factor * num_tokens * cfg.experts_per_token
            / cfg.num_experts
        )
    )
    return max(8, ((c + 7) // 8) * 8)  # lane-friendly


def moe_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
              dispatch: str | None = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss)."""
    if dispatch is None:
        dispatch = cfg.moe_dispatch
    if dispatch == "grouped":
        return _moe_grouped(p, x, cfg)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    dtype = cfg.compute_dtype
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.dot(xt.astype(jnp.float32), p["router"])  # (T, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)        # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style load-balance auxiliary loss.
    me = jnp.mean(probs, axis=0)                            # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=1),
        axis=0,
    )
    aux = e * jnp.sum(me * ce)

    if dispatch == "dense":
        # Anti-baseline: all experts on all tokens.
        xc = xt.astype(dtype)
        g = jnp.einsum("td,edf->etf", xc, p["w_gate"].astype(dtype))
        u = jnp.einsum("td,edf->etf", xc, p["w_up"].astype(dtype))
        h = jax.nn.silu(g) * u
        y_all = jnp.einsum("etf,efd->etd", h, p["w_down"].astype(dtype))
        gates_full = jnp.zeros((t, e), jnp.float32)
        gates_full = gates_full.at[
            jnp.arange(t)[:, None], expert_ids
        ].set(gate_vals)
        y = jnp.einsum("etd,te->td", y_all.astype(jnp.float32), gates_full)
        return y.reshape(b, s, d).astype(x.dtype), aux

    # ---- sort-based capacity dispatch ----
    cap = _capacity(cfg, t)
    flat_expert = expert_ids.reshape(-1)                    # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)                        # stable
    se, stok, sg = flat_expert[order], flat_token[order], flat_gate[order]
    counts = jnp.bincount(se, length=e)
    starts = jnp.cumsum(counts) - counts                    # (E,)
    pos_in_e = jnp.arange(t * k) - starts[se]
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)    # drop -> spill row

    buf = jnp.zeros((e * cap + 1, d), dtype)
    buf = buf.at[slot].set(xt[stok].astype(dtype), mode="drop")
    xe = buf[: e * cap].reshape(e, cap, d)
    xe = shard(xe, "experts", "capacity", "act_embed")

    gb = cfg.cast_before_gather
    wg = M.gather_cast(p["w_gate"], dtype, ("experts", None, "expert_mlp"), gb)
    wu = M.gather_cast(p["w_up"], dtype, ("experts", None, "expert_mlp"), gb)
    wd = M.gather_cast(p["w_down"], dtype, ("experts", "expert_mlp", None), gb)
    g = jnp.einsum("ecd,edf->ecf", xe, wg)
    u = jnp.einsum("ecd,edf->ecf", xe, wu)
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, wd)
    ye = shard(ye, "experts", "capacity", "act_embed")

    ye_flat = jnp.concatenate(
        [ye.reshape(e * cap, d), jnp.zeros((1, d), dtype)], axis=0
    )
    contrib = ye_flat[slot].astype(jnp.float32) * (
        sg * keep.astype(jnp.float32)
    )[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[stok].add(contrib)
    return y.reshape(b, s, d).astype(x.dtype), aux


def _moe_grouped(p: Params, x: jnp.ndarray,
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Shard-local dispatch: tokens reshaped into G groups pinned to the
    data-parallel shards; sort/scatter/gather happen WITHIN a group (no
    cross-shard sort -> the global-argsort collectives of the ``sort``
    baseline disappear).  The only cross-device traffic left is the
    EP boundary where the model-sharded expert outputs meet the
    token-sharded combine (partial-sum all-reduce of (G, Tg, d)).

    Over-capacity tokens drop per-group (same GShard semantics; capacity
    is per group so worst-case imbalance behaves like per-shard MoE in
    production systems).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    dtype = cfg.compute_dtype
    t = b * s
    g = min(cfg.moe_groups, t)
    while t % g:
        g -= 1
    tg = t // g

    xg = x.reshape(g, tg, d)
    xg = shard(xg, "capacity", None, "act_embed")  # groups on (pod, data)

    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"]
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (G, Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    cap = _capacity(cfg, tg)
    fe = expert_ids.reshape(g, tg * k)                        # (G, Tgk)
    ftok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), k)[None, :], (g, tg * k)
    )
    fgate = gate_vals.reshape(g, tg * k)

    order = jnp.argsort(fe, axis=1)                           # local sort
    se = jnp.take_along_axis(fe, order, axis=1)
    stok = jnp.take_along_axis(ftok, order, axis=1)
    sg = jnp.take_along_axis(fgate, order, axis=1)

    # Per-group expert counts from the SORTED ids (no (G,Tgk,E) one-hot):
    # starts[e] = first index of expert e in the sorted row.
    bounds = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(e + 1))
    )(se)                                                     # (G, E+1)
    starts = bounds[:, :-1]
    counts = bounds[:, 1:] - bounds[:, :-1]

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.sum(counts, axis=0).astype(jnp.float32) / t      # (E,)
    aux = e * jnp.sum(me * ce)
    pos_in_e = jnp.arange(tg * k)[None, :] - jnp.take_along_axis(
        starts, se, axis=1
    )
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)      # (G, Tgk)

    # SCATTER-FREE dispatch (A2, EXPERIMENTS §Perf): after the sort the
    # tokens of expert ee occupy sorted rows [starts[ee], starts[ee]+cnt);
    # buffer slot (ee, c) is therefore a GATHER at starts[ee]+c.  XLA SPMD
    # partitions batched gathers cleanly where the equivalent scatter
    # forces replication of the (G, E*C, d) buffer.
    xsel = jnp.take_along_axis(
        xg.astype(dtype), stok[..., None], axis=1
    )                                                         # (G, Tgk, d)
    cpos = jnp.arange(cap)[None, None, :]                     # (1,1,C)
    src = jnp.clip(starts[:, :, None] + cpos, 0, tg * k - 1)  # (G,E,C)
    valid = cpos < counts[:, :, None]
    xe = jnp.take_along_axis(
        xsel, src.reshape(g, e * cap)[..., None], axis=1
    ).reshape(g, e, cap, d)
    xe = jnp.where(valid[..., None], xe, 0)
    xe = shard(xe, "capacity", "experts", None, "act_embed")

    gb = cfg.cast_before_gather
    wg = M.gather_cast(p["w_gate"], dtype, ("experts", None, "expert_mlp"), gb)
    wu = M.gather_cast(p["w_up"], dtype, ("experts", None, "expert_mlp"), gb)
    wd = M.gather_cast(p["w_down"], dtype, ("experts", "expert_mlp", None), gb)
    gmm = jnp.einsum("gecd,edf->gecf", xe, wg)
    umm = jnp.einsum("gecd,edf->gecf", xe, wu)
    h = jax.nn.silu(gmm) * umm
    ye = jnp.einsum("gecf,efd->gecd", h, wd)
    ye = shard(ye, "capacity", "experts", None, "act_embed")

    # Combine, also scatter-free: gather each sorted row's expert output,
    # un-sort with the inverse permutation, reduce the k copies per token.
    ye_flat = jnp.concatenate(
        [ye.reshape(g, e * cap, d), jnp.zeros((g, 1, d), dtype)], axis=1
    )
    contrib = jnp.take_along_axis(ye_flat, slot[..., None], axis=1)
    contrib = contrib.astype(jnp.float32) * (
        sg * keep.astype(jnp.float32)
    )[..., None]                                              # (G,Tgk,d)
    inv_order = jnp.argsort(order, axis=1)                    # local unsort
    contrib = jnp.take_along_axis(contrib, inv_order[..., None], axis=1)
    y = jnp.sum(contrib.reshape(g, tg, k, d), axis=2)
    y = shard(y, "capacity", None, "act_embed")
    return y.reshape(b, s, d).astype(x.dtype), aux
