"""Shared model building blocks (pure-JAX, functional params).

Every init returns ``(params, specs)`` where ``specs`` mirrors the params
tree with tuples of *logical axis names* (resolved to PartitionSpecs by
``repro.distributed.sharding``).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

Params = Dict[str, Any]
Specs = Dict[str, Any]


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def linear_weight_init(key, shape, scale, cfg, axes):
    """Dense f32/bf16 weight -- or GSE-SEM segments when cfg.gse_serve.

    GSE-SEM layout (paper III.B, dense-tensor variant): per-tensor shared
    exponent table (k entries, biased+1), head u16 (sign | expIdx |
    mantissa), tail1 u16; tail2 u32 only when the serving tag is 3.  One
    stored copy; the serving tag picks how many segment streams the
    matmul reads (2/4/8 bytes per weight).
    """
    if not getattr(cfg, "gse_serve", False):
        return _normal(key, shape, scale, cfg.param_dtype), axes
    from repro.core import gse as G

    # Same sampling recipe as _normal (default-dtype normal, then cast) so
    # dense and GSE-packed inits see identical values under any x64 mode.
    vals = (scale * jax.random.normal(key, shape)).astype(jnp.float32)
    table = G.extract_shared_exponents_jnp(vals, cfg.gse_k)
    head, tail1 = G.pack32_jnp(vals, table, cfg.gse_k)
    w = {"head": head, "tail1": tail1, "table": table}
    spec = {"head": axes, "tail1": axes, "table": (None,)}
    if cfg.gse_tag >= 3:
        w["tail2"] = jnp.zeros(shape, jnp.uint32)
        spec["tail2"] = axes
    return w, spec


def take_weight(w, cfg, dtype, gathered_axes):
    """Materialize a weight for compute: decode GSE-SEM segments and/or
    cast + pin the FSDP-gathered layout (cast/decode happens BEFORE the
    all-gather so the wire moves the small representation)."""
    if isinstance(w, dict) and "head" in w:
        ei = max(1, int(np.ceil(np.log2(cfg.gse_k))))
        m_h = 15 - ei
        h = w["head"].astype(jnp.uint32)
        sgn = (1.0 - 2.0 * ((h >> 15) & 0x1).astype(jnp.float32))
        idx = ((h >> m_h) & ((1 << ei) - 1)).astype(jnp.int32)
        mant = (h & ((1 << m_h) - 1)).astype(jnp.float32)
        bits = m_h
        if cfg.gse_tag >= 2:
            mant = mant * jnp.float32(65536.0) + w["tail1"].astype(jnp.float32)
            bits += 16
        if cfg.gse_tag >= 3 and "tail2" in w:
            mant = mant * jnp.float32(2.0**32) + w["tail2"].astype(jnp.float32)
            bits += 32
        from repro.kernels.ref import make_scales

        scales = make_scales(w["table"], bits, bias=127)
        out = (sgn * mant * scales[idx]).astype(dtype)
        if cfg.cast_before_gather:
            out = shard(out, *gathered_axes)
        return out
    return gather_cast(w, dtype, gathered_axes, cfg.cast_before_gather)


import numpy as np  # noqa: E402  (used by take_weight)


def gather_cast(w: jnp.ndarray, dtype, axes, on: bool) -> jnp.ndarray:
    """Cast an FSDP-sharded master weight to compute dtype and (optionally)
    pin the *gathered* layout.

    With ``on=True`` the with_sharding_constraint sits AFTER the cast, so
    GSPMD's FSDP all-gather moves bf16 (2 bytes) instead of the f32 master
    (4 bytes): halves the gather wire bytes AND the HBM read
    (EXPERIMENTS.md §Perf hypothesis O2).
    """
    wc = w.astype(dtype)
    if on:
        wc = shard(wc, *axes)
    return wc


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Tuple[Params, Specs]:
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype) -> Tuple[Params, Specs]:
    p = {"table": _normal(key, (vocab, d), 1.0 / math.sqrt(d), dtype)}
    return p, {"table": ("vocab", "embed")}


def embed(p: Params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return p["table"].astype(dtype)[tokens]


def unembed_init(key, vocab: int, d: int, dtype,
                 cfg=None) -> Tuple[Params, Specs]:
    class _Dense:
        gse_serve = False
        param_dtype = dtype

    w, s = linear_weight_init(key, (d, vocab), 1.0 / math.sqrt(d),
                              cfg or _Dense(), ("embed", "vocab"))
    return {"w": w}, {"w": s}


def unembed(p: Params, x: jnp.ndarray, dtype, cfg=None,
            gather_bf16: bool = False) -> jnp.ndarray:
    # Logits in f32: the vocab matmul feeds softmax-xent directly.
    class _Plain:
        gse_serve = False
        cast_before_gather = gather_bf16
        gse_k = 8
        gse_tag = 2

    w = take_weight(p["w"], cfg or _Plain(), dtype, (None, "vocab"))
    return jnp.dot(x.astype(dtype), w,
                   preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd) rotated pairwise; positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freqs = jnp.exp(
        -math.log(10000.0) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU-2mat)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, ff: int, act: str, dtype,
             cfg=None) -> Tuple[Params, Specs]:
    ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)

    class _Dense:  # fallback when no cfg passed (plain dense init)
        gse_serve = False
        param_dtype = dtype

    c = cfg or _Dense()
    p, s = {}, {}
    if act == "swiglu":
        p["w_gate"], s["w_gate"] = linear_weight_init(
            ks[0], (d, ff), s_in, c, ("embed", "mlp"))
        p["w_up"], s["w_up"] = linear_weight_init(
            ks[1], (d, ff), s_in, c, ("embed", "mlp"))
        p["w_down"], s["w_down"] = linear_weight_init(
            ks[2], (ff, d), s_out, c, ("mlp", "embed"))
    else:
        p["w_up"], s["w_up"] = linear_weight_init(
            ks[0], (d, ff), s_in, c, ("embed", "mlp"))
        p["w_down"], s["w_down"] = linear_weight_init(
            ks[1], (ff, d), s_out, c, ("mlp", "embed"))
    return p, s


def mlp(p: Params, x: jnp.ndarray, act: str, dtype,
        cfg=None, gather_bf16: bool = False) -> jnp.ndarray:
    xc = x.astype(dtype)

    class _Plain:
        gse_serve = False
        cast_before_gather = gather_bf16
        gse_k = 8
        gse_tag = 2

    c = cfg or _Plain()
    if act == "swiglu":
        g = jnp.dot(xc, take_weight(p["w_gate"], c, dtype, (None, "mlp")))
        u = jnp.dot(xc, take_weight(p["w_up"], c, dtype, (None, "mlp")))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(
            jnp.dot(xc, take_weight(p["w_up"], c, dtype, (None, "mlp")))
        )
    return jnp.dot(h, take_weight(p["w_down"], c, dtype, ("mlp", None)))
