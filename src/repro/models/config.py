"""Model configuration: one dataclass covers all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0               # per-expert FFN width (0 -> use d_ff)
    capacity_factor: float = 1.25

    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mlp_act: str = "swiglu"         # swiglu | gelu (2-matmul)

    # hybrid (RecurrentGemma / Griffin): layer i is local-attn if
    # i % hybrid_period == hybrid_period - 1, else RG-LRU.
    hybrid_period: int = 0
    local_window: int = 0
    lru_width: int = 0

    # SSM (RWKV-6)
    rwkv_head_dim: int = 64

    # encoder-decoder (Seamless)
    encoder_layers: int = 0

    # modality frontend stub: number of prefix embeddings supplied
    frontend: Optional[str] = None  # None | "vision_stub" | "audio_stub"
    num_prefix_tokens: int = 0      # vlm: patch embeddings prepended

    # numerics / execution
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    scan_layers: bool = True
    remat: str = "none"             # none | full
    tie_embeddings: bool = False

    # GSE-SEM integration (the paper's technique, LM-scale)
    gse_serve: bool = False         # serve weights from GSE-SEM segments
    gse_tag: int = 2                # serving precision tag
    gse_k: int = 8

    # ---- perf hillclimb levers (EXPERIMENTS.md §Perf); baselines keep
    # the defaults ----
    kv_cache_gse: bool = False      # store decode KV cache as 8-bit GSE-SEM
    moe_dispatch: str = "sort"      # sort (global) | grouped (shard-local)
    moe_groups: int = 32            # token groups for grouped dispatch
    cast_before_gather: bool = False  # FSDP all-gathers in bf16, not f32
    attn_impl: str = "naive"        # naive | chunked (online softmax)
    attn_chunk: int = 1024

    def kv_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 16 so the embedding/unembedding
        tables shard evenly over the 16-way model axis (logits are sliced
        back to the true vocab before loss/sampling)."""
        return ((self.vocab_size + 15) // 16) * 16

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def attn_layer_ids(self) -> Tuple[int, ...]:
        if self.family != "hybrid":
            return tuple(range(self.num_layers))
        p = self.hybrid_period
        return tuple(i for i in range(self.num_layers) if i % p == p - 1)

    def supports_long_context(self) -> bool:
        """sub-quadratic archs: SSM / hybrid (bounded local-attn window)."""
        return self.family in ("ssm", "hybrid")

    def has_decode(self) -> bool:
        return True  # all 10 assigned archs have a decoder
