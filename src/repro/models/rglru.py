"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block = linear-in -> temporal conv1d(4) -> RG-LRU recurrence -> gated out.
Recurrence (per channel):

    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(L) * r_t)       data-dependent decay, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` over the affine maps
(h -> a*h + b composes associatively), giving O(log T) depth -- the
sub-quadratic property that makes the 500k decode shape feasible; decode
is an O(1) state update.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import modules as M
from repro.models.config import ModelConfig

Params = Dict[str, Any]

_C = 8.0
_CONV_W = 4


def rglru_init(key, cfg: ModelConfig, dtype) -> Tuple[Params, Dict]:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    p = {
        "w_in": M._normal(ks[0], (d, w), s, dtype),
        "w_gate_branch": M._normal(ks[1], (d, w), s, dtype),
        "conv": M._normal(ks[2], (_CONV_W, w), 0.1, dtype),
        "wa": M._normal(ks[3], (w, w), 1.0 / math.sqrt(w), dtype),
        "wx": M._normal(ks[4], (w, w), 1.0 / math.sqrt(w), dtype),
        "lam": jnp.asarray(
            jax.random.uniform(ks[5], (w,), jnp.float32, 2.0, 5.0)
        ),
        "w_out": M._normal(ks[6], (w, d), 1.0 / math.sqrt(w), dtype),
    }
    spec = {
        "w_in": ("embed", "lru"),
        "w_gate_branch": ("embed", "lru"),
        "conv": ("conv_w", "lru"),
        "wa": ("lru", "lru_in"),
        "wx": ("lru", "lru_in"),
        "lam": ("lru",),
        "w_out": ("lru", "embed"),
    }
    return p, spec


def _conv1d(p, x, state=None):
    """Causal depthwise conv, width 4. state: (B, 3, W) trailing inputs."""
    w = p["conv"].astype(x.dtype)  # (4, W)
    if state is None:
        pads = jnp.zeros((x.shape[0], _CONV_W - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pads, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i] for i in range(_CONV_W)
    )
    new_state = xp[:, -(_CONV_W - 1):, :]
    return out, new_state


def _gates(p, u):
    r = jax.nn.sigmoid(u.astype(jnp.float32) @ p["wa"].astype(jnp.float32))
    i = jax.nn.sigmoid(u.astype(jnp.float32) @ p["wx"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # (B,S,W) f32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, gated


def rglru_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence apply (train / prefill). x: (B, S, D)."""
    dtype = cfg.compute_dtype
    u = jnp.dot(x.astype(dtype), p["w_in"].astype(dtype))
    gate = jax.nn.gelu(
        jnp.dot(x.astype(dtype), p["w_gate_branch"].astype(dtype))
    )
    u, _ = _conv1d(p, u)
    a, b = _gates(p, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(dtype) * gate)
    return jnp.dot(y, p["w_out"].astype(dtype))


def rglru_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_W - 1, w), dtype),
    }


def rglru_step(p: Params, x: jnp.ndarray, state: Dict,
               cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """Single-token decode. x: (B, 1, D) -> (B, 1, D), O(1) state."""
    dtype = cfg.compute_dtype
    u = jnp.dot(x.astype(dtype), p["w_in"].astype(dtype))
    gate = jax.nn.gelu(
        jnp.dot(x.astype(dtype), p["w_gate_branch"].astype(dtype))
    )
    u, conv_state = _conv1d(p, u, state["conv"])
    a, b = _gates(p, u)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = (h[:, None, :].astype(dtype) * gate)
    out = jnp.dot(y, p["w_out"].astype(dtype))
    return out, {"h": h, "conv": conv_state}
