"""Chunked solver execution: bounded segments, bit-identical trajectories.

The serving layer needs solves to be *preemptible* (deadline checks,
fair scheduling across requests), *joinable* (continuous batching), and
*resumable* (checkpoint/restore across faults).  All three reduce to one
primitive: run the existing solver ``while_loop`` for at most K more
iterations and hand back the raw loop state.

The solver entry points grew three hooks for this (DESIGN.md §17):

  * ``stop_at`` -- an extra iteration bound ANDed into the loop
    condition.  Conditions never touch the update arithmetic, so a
    chunked trajectory is bit-identical to the unchunked one BY
    CONSTRUCTION, not by tolerance.
  * ``resume`` -- a previous chunk's loop-state pytree, carried verbatim
    (device arrays; the init section is skipped entirely).
  * ``return_state`` -- return that raw state alongside the result.

The drivers here wrap those hooks per solver family:

  * :class:`SolveChunks` -- single-RHS CG/PCG (fused, generic, or the
    row-sharded operator via the generic body).
  * :class:`BatchedChunks` -- the batched multi-RHS loop, plus
    ``join``/``drop``: a column added at a chunk boundary starts from
    the exact init a solo solve would run, and runs the exact per-column
    op sequence from there (the batched loop's per-column bit-identity
    contract, DESIGN.md §11) -- continuous batching without perturbing
    the columns already in flight.
  * :class:`IRChunks` -- iterative refinement at outer-correction
    granularity (the host loop of ``solve_ir`` re-cut; every line of
    per-correction arithmetic is shared with the unchunked driver).

Checkpointing: ``save_state``/``restore_state`` round-trip the loop
state through ``checkpoint.ckpt`` (CRC-stamped; a corrupted latest
checkpoint falls back to the previous good step -- the chunk in between
simply re-runs, which by the bit-identity contract reproduces the exact
trajectory).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as CK
from repro.core import precision as P
from repro.robustness.guards import HEALTH_OK, GuardParams
from repro.sparse.csr import GSECSR, GSESellC
from repro.solvers.batched import (
    _maybe_sharded,
    _normalize_block,
    _solve_cg_batched,
    _solve_cg_batched_fused,
    _solve_pcg_batched,
    _solve_pcg_batched_fused,
)
from repro.solvers.cg import (
    _gsecsr_operator,
    _solve_cg,
    _solve_cg_fused,
    _solve_pcg,
    _solve_pcg_fused,
)
from repro.solvers.ir import _ir_active, _ir_result, _ir_setup, _ir_step

__all__ = ["SolveChunks", "BatchedChunks", "IRChunks"]


def _chunk_bound(it, k):
    """The stop_at bound for "k more iterations from it" as a traced
    scalar -- dynamic, so chunk advances never retrace the loop."""
    return it + jnp.int32(k)


class SolveChunks:
    """Single-RHS CG/PCG driven K iterations at a time.

    ``run_chunk(k)`` advances the solve by at most ``k`` iterations and
    returns the current ``CGResult`` snapshot; ``done`` is True when the
    unchunked loop would have exited (converged, budget exhausted, or a
    guard tripped).  The concatenation of chunks is bit-identical to one
    unchunked call with the same arguments.
    """

    def __init__(self, op, b, tol: float, maxiter: int,
                 params: P.MonitorParams,
                 guards: GuardParams | None = None,
                 x0=None, precond=None, wire: str = "exact",
                 init_tag: int = 1):
        b = jnp.asarray(b)
        if b.ndim == 2 and b.shape[1] == 1:
            b = b[:, 0]
        self.b = b
        self.x0 = jnp.zeros_like(b) if x0 is None else jnp.asarray(x0)
        self.tol = jnp.asarray(tol, b.dtype)
        self.maxiter = maxiter
        self.params = params
        self.guards = guards
        self.init_tag = init_tag
        op = _maybe_sharded(op, wire)
        fused = isinstance(op, (GSECSR, GSESellC))
        if precond is None:
            entry = _solve_cg_fused if fused else _solve_cg
            self._call = lambda **kw: entry(
                op, self.b, self.x0, self.tol, self.maxiter, self.params,
                init_tag=self.init_tag, guards=self.guards, **kw)
        elif fused and hasattr(precond, "apply_at"):
            self._call = lambda **kw: _solve_pcg_fused(
                op, precond, self.b, self.x0, self.tol, self.maxiter,
                self.params, init_tag=self.init_tag, guards=self.guards,
                **kw)
        else:
            apply_m = precond if callable(precond) else precond.apply
            apply_a = _gsecsr_operator(op) if fused else op
            self._call = lambda **kw: _solve_pcg(
                apply_a, apply_m, self.b, self.x0, self.tol, self.maxiter,
                self.params, init_tag=self.init_tag, guards=self.guards,
                **kw)
        self._state = None
        self.res = None
        self.ckpt = None
        self.chunks = 0

    def run_chunk(self, k: int):
        """Advance at most ``k`` iterations; returns the CGResult so far."""
        if self._state is None:
            stop = jnp.int32(int(k))
            res, ckpt, st = self._call(stop_at=stop, return_state=True)
        else:
            stop = _chunk_bound(self._state["it"], int(k))
            res, ckpt, st = self._call(resume=self._state, stop_at=stop,
                                       return_state=True)
        self._state, self.res, self.ckpt = st, res, ckpt
        self.chunks += 1
        return res

    @property
    def iters(self) -> int:
        return 0 if self._state is None else int(self._state["it"])

    @property
    def done(self) -> bool:
        """True when the UNCHUNKED loop condition is false: another chunk
        would execute zero iterations."""
        if self.res is None:
            return False
        if bool(self.res.converged) or self.iters >= self.maxiter:
            return True
        if self.guards is not None and \
                int(self._state["g"]["health"]) != HEALTH_OK:
            return True
        return False

    # -- checkpoint/resume (DESIGN.md §17) --------------------------------

    def init_state(self):
        """An initialized loop state without iterating (``stop_at=0``):
        the ``like`` template restores unflatten into."""
        _, _, st = self._call(stop_at=jnp.int32(0), return_state=True)
        return st

    def save_state(self, path: str) -> str:
        """CRC-stamped checkpoint of the current loop state (one per
        chunk boundary; step = chunk index)."""
        if self._state is None:
            raise RuntimeError("no chunk has run yet; nothing to save")
        return CK.save(path, self._state, step=self.chunks,
                       extra={"iters": self.iters})

    def restore_state(self, path: str) -> list:
        """Resume from the newest VALID checkpoint under ``path``.

        Corrupt checkpoints are skipped (``ckpt.CheckpointCorrupt``) and
        the previous good one is used -- the skipped chunk re-runs from
        there, reproducing the exact trajectory.  Returns the list of
        corrupt steps passed over; raises ``FileNotFoundError`` when no
        valid checkpoint exists.
        """
        got = CK.restore_latest_valid(path, self.init_state())
        if got is None:
            raise FileNotFoundError(f"no valid checkpoint under {path}")
        st, step, _, skipped = got
        self._state = st
        self.chunks = step
        return skipped


class BatchedChunks:
    """The batched multi-RHS loop driven K iterations at a time, with
    continuous batching: ``join`` adds a column at a chunk boundary
    (its init is exactly a solo solve's init, so its trajectory matches
    a solo solve started then), ``drop`` removes one (remaining columns
    are independent per-column states -- untouched).

    ``stop_at`` is per-column (columns join at different global chunk
    counts, so each advances from its OWN iteration count).  Width
    changes retrace the loop -- the price of continuous batching; the
    service bounds width by its slot count so the retrace set is small.
    """

    def __init__(self, op, b, tol: float, maxiter: int,
                 params: P.MonitorParams,
                 guards: GuardParams | None = None,
                 x0=None, precond=None, wire: str = "exact",
                 init_tag: int = 1, tags=None):
        b, x0 = _normalize_block(b, x0)
        if tags is not None:
            # The batched precision axis (PR 10, DESIGN.md §18) resolves
            # BEFORE chunking exactly as in solve_cg_batched: an int or
            # uniform map overrides init_tag (same jaxpr), a non-uniform
            # map swaps in the masked operand and pins the monitor -- so
            # the chunked trajectory stays bit-identical to the unchunked
            # tags= call by the same construction as everything else here.
            from repro.solvers.batched import _batched_tag_axis

            init_tag, op, params = _batched_tag_axis(
                tags, op, int(b.shape[0]), params)
        self.b = b
        self.tol = jnp.asarray(tol, b.dtype)
        self.maxiter = maxiter
        self.params = params
        self.guards = guards
        self.init_tag = init_tag
        self.precond = precond
        op = _maybe_sharded(op, wire)
        fused = isinstance(op, (GSECSR, GSESellC))
        if precond is None:
            entry = _solve_cg_batched_fused if fused else _solve_cg_batched
            self._call = lambda b_, x0_, **kw: entry(
                op, b_, x0_, self.tol, self.maxiter, self.params,
                init_tag=self.init_tag, guards=self.guards, **kw)
        elif fused and hasattr(precond, "apply_at"):
            self._call = lambda b_, x0_, **kw: _solve_pcg_batched_fused(
                op, precond, b_, x0_, self.tol, self.maxiter, self.params,
                init_tag=self.init_tag, guards=self.guards, **kw)
        else:
            apply_m = precond if callable(precond) else precond.apply
            apply_a = _gsecsr_operator(op) if fused else op
            self._call = lambda b_, x0_, **kw: _solve_pcg_batched(
                apply_a, apply_m, b_, x0_, self.tol, self.maxiter,
                self.params, init_tag=self.init_tag, guards=self.guards,
                **kw)
        # Initialize every column WITHOUT iterating (per-column stop_at=0):
        # the same trick join uses, so first-wave and joined columns get
        # identical init treatment.
        res, cols = self._call(
            b, x0, stop_at=tuple(jnp.int32(0) for _ in range(b.shape[1])),
            return_state=True)
        self.cols = tuple(cols)
        self.res = res
        self.chunks = 0

    @property
    def nrhs(self) -> int:
        return len(self.cols)

    def run_chunk(self, k: int):
        """Advance every column by at most ``k`` iterations (from each
        column's OWN count); returns the BatchedCGResult snapshot."""
        stop = tuple(_chunk_bound(c["it"], int(k)) for c in self.cols)
        # x0 is dead under resume (the init section is skipped); any
        # shape-matching placeholder keeps the traced signature stable.
        res, cols = self._call(self.b, jnp.zeros_like(self.b),
                               resume=self.cols, stop_at=stop,
                               return_state=True)
        self.cols, self.res = tuple(cols), res
        self.chunks += 1
        return res

    def join(self, b_new, x0=None) -> int:
        """Add one column at the current chunk boundary; returns its
        index.  The column's state is the exact solo-solve init (one
        operator application at ``init_tag``), so from here on it runs
        the same op sequence as a solve submitted alone."""
        b1, x01 = _normalize_block(jnp.asarray(b_new), x0)
        _, cols1 = self._call(b1, x01, stop_at=(jnp.int32(0),),
                              return_state=True)
        self.cols = self.cols + tuple(cols1)
        self.b = jnp.concatenate([self.b, b1], axis=1)
        return self.nrhs - 1

    def drop(self, j: int) -> dict:
        """Remove column ``j`` (finished or expired), returning its final
        snapshot.  Other columns' states are untouched -- per-column
        independence is the batched loop's core contract."""
        snap = self.col_snapshot(j)
        self.cols = self.cols[:j] + self.cols[j + 1:]
        self.b = jnp.delete(self.b, j, axis=1)
        return snap

    def col_snapshot(self, j: int) -> dict:
        """One column's current report fields + its last-healthy x
        (``ckpt`` under guards -- what a deadline expiry returns).

        Health comes from the column's OWN guard state (finalized the
        same way the batched result does), not the cached batch result,
        which goes stale across joins/drops.
        """
        from repro.robustness.guards import finalize_health

        c = self.cols[j]
        bn = jnp.linalg.norm(self.b[:, j])
        bn = jnp.where(bn == 0, 1.0, bn)
        relres = float(jnp.sqrt(jnp.abs(c["rr"])) / bn)
        finite = bool(jnp.isfinite(jnp.vdot(c["x"], c["x"])))
        converged = relres <= float(self.tol) and finite
        h, t = finalize_health(c.get("g"), converged, relres,
                               x_finite=finite)
        g = c.get("g")
        return dict(
            x=c["x"],
            ckpt=c.get("ckpt", c["x"]),
            iters=int(c["it"]),
            relres=relres,
            tag=int(c["mon"].tag),
            switch_iters=np.asarray(c["sw"]),
            converged=converged,
            health=int(h),
            # Raw in-loop guard health: a column still iterating is OK
            # here even though finalize_health would call it "stalled"
            # (deadline expiry must not masquerade as a guard trip).
            guard_health=int(g["health"]) if g is not None else 0,
            trip_iter=int(t),
        )

    def col_done(self, j: int) -> bool:
        """Column ``j`` would execute zero further iterations."""
        c = self.cols[j]
        bn = jnp.linalg.norm(self.b[:, j])
        bn = jnp.where(bn == 0, 1.0, bn)
        relres = float(jnp.sqrt(jnp.abs(c["rr"])) / bn)
        if relres <= float(self.tol) or int(c["it"]) >= self.maxiter:
            return True
        if self.guards is not None and \
                int(c["g"]["health"]) != HEALTH_OK:
            return True
        return False

    @property
    def done(self) -> bool:
        return all(self.col_done(j) for j in range(self.nrhs))


class IRChunks:
    """Iterative refinement driven K outer corrections at a time.

    Chunk boundaries fall between corrections -- the natural restart
    point the Carson-Khan structure gives for free (each correction
    restarts the inner monitor anyway), so chunked IR shares every line
    of per-correction arithmetic with ``solve_ir`` and is trivially
    bit-identical to it.
    """

    def __init__(self, op, b, tol: float = 1e-10, max_outer: int = 10,
                 inner: str = "cg", inner_tol: float = 1e-4,
                 inner_maxiter: int = 2000,
                 params: P.MonitorParams | None = None,
                 precond=None, restart: int = 30, wire: str = "exact",
                 guards: GuardParams | None = None, flight=None):
        self.st = _ir_setup(op, jnp.asarray(b), tol=tol, max_outer=max_outer,
                            inner=inner, inner_tol=inner_tol,
                            inner_maxiter=inner_maxiter, params=params,
                            precond=precond, restart=restart, wire=wire,
                            guards=guards, flight=flight)
        self.chunks = 0

    def run_chunk(self, k: int):
        """Run at most ``k`` outer corrections; returns the IRResult so
        far (its ``converged``/``health`` reflect the current state)."""
        for _ in range(int(k)):
            if not _ir_active(self.st):
                break
            _ir_step(self.st)
        self.chunks += 1
        return _ir_result(self.st)

    @property
    def done(self) -> bool:
        return not _ir_active(self.st)

    @property
    def outer_iters(self) -> int:
        return self.st["outer"]

    def result(self):
        return _ir_result(self.st)

    # -- checkpoint/resume ------------------------------------------------

    # The IR state is host-side (closures + scalars), so checkpoints
    # carry the array leaves explicitly and the scalars in ``extra``.

    def save_state(self, path: str) -> str:
        st = self.st
        return CK.save(path, {"x": st["x"], "r": st["r"]}, step=self.chunks,
                       extra={
                           "outer": st["outer"],
                           "total_inner": st["total_inner"],
                           "relres": st["relres"],
                           "history": [float(h) for h in st["history"]],
                           "inner_health": st["inner_health"],
                           "stopped": st["stopped"],
                       })

    def restore_state(self, path: str) -> list:
        like = {"x": self.st["x"], "r": self.st["r"]}
        got = CK.restore_latest_valid(path, like)
        if got is None:
            raise FileNotFoundError(f"no valid checkpoint under {path}")
        tree, step, extra, skipped = got
        self.st["x"] = jnp.asarray(tree["x"])
        self.st["r"] = jnp.asarray(tree["r"])
        self.st["outer"] = int(extra["outer"])
        self.st["total_inner"] = int(extra["total_inner"])
        self.st["relres"] = float(extra["relres"])
        self.st["history"] = [float(h) for h in extra["history"]]
        self.st["inner_health"] = int(extra["inner_health"])
        self.st["stopped"] = bool(extra["stopped"])
        self.chunks = step
        return skipped
