"""Resilient async solve serving (DESIGN.md §17).

Chunked solver execution (``chunked``: run any solver family in bounded
segments of K iterations, bit-identical to the unchunked run), a
per-handle circuit breaker (``breaker``), and the admission/dispatch
service on top (``service``: bounded intake, typed shed responses,
continuous batching at chunk boundaries, mid-solve deadline enforcement,
warm-start reuse, checkpoint/resume).
"""
from repro.serve.breaker import BreakerParams, CircuitBreaker
from repro.serve.chunked import BatchedChunks, IRChunks, SolveChunks
from repro.serve.service import (
    Accepted,
    AsyncSolveService,
    Shed,
)

__all__ = [
    "Accepted",
    "AsyncSolveService",
    "BatchedChunks",
    "BreakerParams",
    "CircuitBreaker",
    "IRChunks",
    "Shed",
    "SolveChunks",
]
