"""Resilient async solve service: chunked dispatch with admission control.

``launch.solver_serve.SolverService`` buckets requests and flushes
synchronously: a flush runs each batched solve TO COMPLETION, so a new
request waits for the whole previous batch, a straggler column holds
every report hostage, and a deadline can only be checked after the
fact.  :class:`AsyncSolveService` re-bases the same registration /
validation / byte-accounting machinery (it subclasses the sync service)
on **chunked solves** (``serve.chunked``, DESIGN.md §17):

  * **continuous batching** -- a request joins a RUNNING batched solve
    at the next chunk boundary (``BatchedChunks.join``); already-running
    columns are bit-identical to an uninterrupted run, and the joined
    column is bit-identical to a solo solve started at its join point.
  * **admission control / backpressure** -- a bounded intake queue and a
    per-handle circuit breaker (``serve.breaker``); over-capacity or
    open-breaker submissions return a typed :class:`Shed` (reason +
    ``retry_after_s``) instead of queueing unboundedly or raising.
  * **deadline enforcement mid-solve** -- ``deadline_s`` is checked at
    every chunk boundary; an expired request returns its current
    iterate (the last checkpoint) FLAGGED (``deadline_exceeded=True``,
    ``health="deadline"``) -- never silently dropped.  The deadline also
    picks the monitor's dwell class at admission: a loose deadline dwells
    longer at the cheap tags, a tight one escalates sooner.
  * **warm starts** -- a small LRU of converged solutions keyed by
    (handle, CRC32 of ``b``) seeds ``x0`` for repeat right-hand sides.
  * **pack integrity** -- each handle's packed segments are CRC-stamped
    at registration and re-verified before a new group dispatches
    against them; a corrupted pack is detected, counted, and repacked
    from the registration CSR (the PR-6 fault surfaces, closed at the
    serving layer).

Execution model: a cooperative single-threaded pump.  ``pump()`` runs
ONE chunk of every active group then handles boundaries (admissions,
joins, deadlines, completions); ``run_until_idle()`` pumps until the
queue and groups drain.  Deterministic by construction -- the chaos
replay harness and the tests drive it step by step with a fake clock.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core import precision as P
from repro.launch.solver_serve import (
    SolveReport,
    SolveRequest,
    SolverService,
    _tags_token,
)
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.robustness import faults as F
from repro.robustness.guards import (
    DEFAULT_GUARDS,
    HEALTH_OK,
    GuardParams,
    health_name,
)
from repro.serve.breaker import OPEN, BreakerParams, CircuitBreaker
from repro.serve.chunked import BatchedChunks
from repro.solvers.cg import solve_cg, solve_pcg
from repro.sparse.csr import GSECSR, pack_csr

__all__ = ["Accepted", "Shed", "AsyncSolveService"]


@dataclasses.dataclass(frozen=True)
class Accepted:
    """Admission success: the request is queued under ``id``."""
    id: int


@dataclasses.dataclass(frozen=True)
class Shed:
    """Typed backpressure response: the request was NOT queued.

    ``reason`` is ``"queue_full"`` or ``"breaker_open"``;
    ``retry_after_s`` is the client's backoff hint (the breaker's
    remaining open window, or one chunk's worth of grace for a full
    queue).
    """
    reason: str
    retry_after_s: float


@dataclasses.dataclass
class _Group:
    """One running batched solve: the chunk driver + its live members
    (``members[j]`` owns column ``j`` of ``chunks``)."""
    chunks: BatchedChunks
    members: List[SolveRequest]


def _dwell_params(params: P.MonitorParams, deadline_s: Optional[float],
                  tight_s: float, loose_s: float) -> tuple:
    """Map a deadline to a dwell class: how long the monitor sits at the
    cheap tags before escalating (DESIGN.md §17).

    Loose deadlines double the monitor's decision windows (more time at
    6-8 B/nnz); tight ones halve them (escalate to the exact tag
    sooner -- finish *within budget* beats finishing *cheap*).  The
    class is part of the bucket key, so requests in one batched group
    share one (static) MonitorParams.
    """
    if deadline_s is None or loose_s > deadline_s >= tight_s:
        return "normal", params
    if deadline_s < tight_s:
        return "tight", dataclasses.replace(
            params, t=max(2, params.t // 2), l=max(2, params.l // 2),
            m=max(1, params.m // 2))
    return "loose", dataclasses.replace(
        params, t=params.t * 2, l=params.l * 2, m=params.m * 2)


class AsyncSolveService(SolverService):
    """Chunked, deadline-aware, backpressured solve service.

    Parameters beyond :class:`SolverService`:

    ``chunk_iters``: iterations per chunk (the scheduling quantum --
    deadline checks, joins, and shed decisions all happen at chunk
    boundaries).  ``queue_limit`` bounds the intake queue.  ``breaker``
    parameterizes the per-handle circuit breaker.  ``warm_capacity``
    sizes the warm-start LRU.  ``clock`` is injectable for tests and
    replay.  ``chunk_hook(service, key, group)`` runs after every chunk
    -- the chaos harness's stall-injection point.
    """

    def __init__(self, slots: int = 4,
                 params: P.MonitorParams | None = None,
                 maxiter: int = 5000,
                 guards: GuardParams | None = DEFAULT_GUARDS,
                 max_retries: int = 1,
                 chunk_iters: int = 64,
                 queue_limit: int = 32,
                 breaker: BreakerParams | None = None,
                 warm_capacity: int = 16,
                 tight_deadline_s: float = 0.2,
                 loose_deadline_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic,
                 seed: int = 0,
                 chunk_hook=None):
        super().__init__(slots=slots, params=params, maxiter=maxiter,
                         guards=guards, max_retries=max_retries)
        if chunk_iters < 1:
            raise ValueError(f"chunk_iters must be >= 1, got {chunk_iters}")
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        self.chunk_iters = chunk_iters
        self.queue_limit = queue_limit
        self.breaker_params = breaker or BreakerParams()
        self.warm_capacity = warm_capacity
        self.tight_deadline_s = tight_deadline_s
        self.loose_deadline_s = loose_deadline_s
        self.clock = clock
        self.seed = seed
        self.chunk_hook = chunk_hook
        self._groups: Dict[tuple, _Group] = {}
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._warm: OrderedDict = OrderedDict()
        self._pack_crcs: Dict[str, dict] = {}
        self._pack_k: Dict[str, int] = {}
        self._operators: Dict[str, Callable] = {}
        self._deadlines: Dict[int, tuple] = {}
        self._adaptive_done: Dict[int, SolveReport] = {}
        self.reports: Dict[int, SolveReport] = {}

        const = {"service": self.service_id}
        self.sheds = OM.stats_view(
            "repro_serve_shed_total", ("queue_full", "breaker_open"),
            help="Submissions shed by admission control, by reason.",
            label="reason", const=const)
        self.warm = OM.stats_view(
            "repro_serve_warm_total", ("hit", "miss", "store"),
            help="Warm-start LRU events.", const=const)
        self.pack_faults = OM.stats_view(
            "repro_serve_pack_faults_total", ("detected", "repacked"),
            help="Pack-integrity CRC mismatches caught before dispatch.",
            const=const)
        self.chunk_counter = OM.REGISTRY.counter(
            "repro_serve_chunks_total",
            "Solver chunks executed across all groups.",
            labelnames=("service",)).labels(**const)
        self.queue_wait = OM.REGISTRY.histogram(
            "repro_serve_queue_wait_seconds",
            "Submit-to-dispatch wait per admitted request.",
            labelnames=("service",)).labels(**const)
        self.solve_latency = OM.REGISTRY.histogram(
            "repro_serve_solve_latency_seconds",
            "Submit-to-report wall clock per request.",
            labelnames=("service",)).labels(**const)
        self._breaker_gauge = OM.REGISTRY.gauge(
            "repro_serve_breaker_open",
            "1 while the handle's circuit breaker is open.",
            labelnames=("service", "handle"))

    # -- registration ------------------------------------------------------

    def register(self, name: str, a, k: int = 8, operator=None,
                 **kw) -> str:
        """As :meth:`SolverService.register`, plus pack CRC stamping and
        an optional ``operator`` override: a tag-dispatched callable
        served INSTEAD of the packed matrix (fault injectors in the
        chaos harness ride this; byte reports still model the pack)."""
        handle = super().register(name, a, k=k, **kw)
        op = self._ops[handle]
        self._pack_k[handle] = k
        if isinstance(op.gse, GSECSR):
            self._pack_crcs[handle] = F.gsecsr_checksums(op.gse)
        if operator is not None:
            self._operators[handle] = operator
        return handle

    def _breaker(self, handle: str) -> CircuitBreaker:
        br = self._breakers.get(handle)
        if br is None:
            br = CircuitBreaker(self.breaker_params, clock=self.clock,
                                seed=self.seed + len(self._breakers))
            self._breakers[handle] = br
        return br

    def _verify_pack(self, handle: str) -> None:
        """Pre-dispatch integrity check: a pack whose CRC drifted since
        registration is detected and repacked from the registration CSR
        before any solve reads it (fault model: host-memory corruption
        of the shared packed operand)."""
        crcs = self._pack_crcs.get(handle)
        if crcs is None:
            return
        op = self._ops[handle]
        bad = F.verify_gsecsr(op.gse, crcs)
        if not bad:
            return
        self.pack_faults["detected"] += 1
        op.gse = pack_csr(op.csr, k=self._pack_k[handle])
        self._pack_crcs[handle] = F.gsecsr_checksums(op.gse)
        self.pack_faults["repacked"] += 1

    # -- admission ---------------------------------------------------------

    def submit(self, handle: str, b, tol: float = 1e-8, x0=None,
               deadline_s: float | None = None, tags=None
               ) -> Union[Accepted, Shed]:
        """Admission-controlled intake.

        Malformed requests still raise (``ValueError``/``KeyError`` --
        client bugs, as in the sync service); a WELL-FORMED request the
        service cannot take right now comes back as a typed
        :class:`Shed` instead.  Accepted requests return
        :class:`Accepted` and will be dispatched at a chunk boundary.

        ``tags`` is the per-request precision axis override (PR 10, same
        values as the sync service).  Int/map requests ride the chunked
        groups as usual (bucketed by their effective axis);
        ``tags="adaptive"`` requests run the host-looped adaptive driver
        TO COMPLETION at their admission boundary -- the driver's replan
        loop is not chunk-preemptible, so an adaptive request occupies
        its pump turn entirely (deadline still suppresses retries).
        """
        # Queue bound FIRST: a queue_full shed must not consume a
        # half-open breaker's single probe admission.
        if len(self._pending) >= self.queue_limit:
            self.sheds["queue_full"] += 1
            return Shed("queue_full", retry_after_s=0.05)
        br = self._breaker(handle)
        if not br.allow():
            self.sheds["breaker_open"] += 1
            self._breaker_gauge.labels(
                service=self.service_id, handle=handle).set(1)
            return Shed("breaker_open", retry_after_s=br.retry_after())
        try:
            rid = super().submit(handle, b, tol=tol, x0=x0,
                                 deadline_s=deadline_s, tags=tags)
        except Exception:
            br.release()  # the admission never dispatched
            raise
        # The parent stamps time.monotonic(); re-stamp with the service
        # clock so fake-clock tests and the replay harness measure
        # deadlines and queue waits on one timeline.
        self._pending[-1].t_submit = self.clock()
        return Accepted(rid)

    # -- the pump ----------------------------------------------------------

    def pump(self) -> Dict[int, SolveReport]:
        """One cooperative scheduling round: admit queued requests into
        groups (joining running solves at this chunk boundary), advance
        every group one chunk, then settle boundaries (completions,
        deadline expiries, degraded columns).  Returns the reports
        finalized THIS round (also accumulated on ``self.reports``).

        Degradation contract: ``pump`` never raises out of a group -- a
        group whose chunk throws degrades to error reports for its
        members, exactly like the sync ``flush``.
        """
        t0 = time.perf_counter()
        finalized: Dict[int, SolveReport] = {}
        with OT.span("serve.pump", service=self.service_id,
                     groups=len(self._groups),
                     queued=len(self._pending)):
            self._admit()
            if self._adaptive_done:
                finalized.update(self._adaptive_done)
                self._adaptive_done = {}
            for key in list(self._groups):
                group = self._groups[key]
                try:
                    group.chunks.run_chunk(self.chunk_iters)
                    self.chunk_counter.inc()
                    if self.chunk_hook is not None:
                        self.chunk_hook(self, key, group)
                    finalized.update(self._settle(key, group))
                except Exception:  # degraded, never propagated
                    self.stats["errors"] += 1
                    finalized.update(self._fail_group(key, group))
        self.queue_depth.set(len(self._pending))
        self.flush_latency.observe(time.perf_counter() - t0)
        self.reports.update(finalized)
        return finalized

    def run_until_idle(self, max_pumps: int = 10_000
                       ) -> Dict[int, SolveReport]:
        """Pump until the queue and all groups drain; returns every
        report finalized along the way."""
        out: Dict[int, SolveReport] = {}
        pumps = 0
        while (self._pending or self._groups) and pumps < max_pumps:
            out.update(self.pump())
            pumps += 1
        return out

    # -- internals ---------------------------------------------------------

    def _bucket(self, req: SolveRequest) -> tuple:
        cls, _ = _dwell_params(self.params, req.deadline_s,
                               self.tight_deadline_s, self.loose_deadline_s)
        return (req.handle, req.tol, cls, _tags_token(self._eff_tags(req)))

    def _eff_tags(self, req: SolveRequest):
        """The request's effective precision axis: its own override,
        else the handle default."""
        return req.tags if req.tags is not None \
            else self._ops[req.handle].tags

    def _warm_key(self, handle: str, b) -> tuple:
        return (handle, zlib.crc32(np.ascontiguousarray(
            np.asarray(b)).tobytes()))

    def _warm_lookup(self, req: SolveRequest):
        key = self._warm_key(req.handle, req.b)
        hit = self._warm.get(key)
        if hit is None:
            self.warm["miss"] += 1
            return None
        self._warm.move_to_end(key)
        self.warm["hit"] += 1
        return jnp.asarray(hit)

    def _warm_store(self, req: SolveRequest, x) -> None:
        key = self._warm_key(req.handle, req.b)
        self._warm[key] = np.asarray(x)
        self._warm.move_to_end(key)
        while len(self._warm) > self.warm_capacity:
            self._warm.popitem(last=False)
        self.warm["store"] += 1

    def _admit(self) -> None:
        """Move queued requests into groups: join a running group in the
        same bucket when it has a free column, else start a new group.
        FIFO; requests whose bucket is full stay queued for the next
        boundary."""
        still: List[SolveRequest] = []
        for req in self._pending:
            if self._eff_tags(req) == "adaptive":
                self._admit_adaptive(req)
                continue
            key = self._bucket(req)
            group = self._groups.get(key)
            if group is not None and group.chunks.nrhs >= self.slots:
                still.append(req)
                continue
            x0 = req.x0
            if x0 is None:
                x0 = self._warm_lookup(req)
            now = self.clock()
            self.queue_wait.observe(max(0.0, now - req.t_submit))
            if group is None:
                self._verify_pack(req.handle)
                op = self._ops[req.handle]
                _, dwell = _dwell_params(
                    self.params, req.deadline_s,
                    self.tight_deadline_s, self.loose_deadline_s)
                solve_op = self._operators.get(req.handle, op.solve_op)
                chunks = BatchedChunks(
                    solve_op, req.b[:, None],
                    x0=None if x0 is None else x0[:, None],
                    tol=req.tol, maxiter=self.maxiter, params=dwell,
                    guards=self.guards, precond=op.precond, wire=op.wire,
                    tags=self._eff_tags(req))
                self._groups[key] = _Group(chunks=chunks, members=[req])
            else:
                group.chunks.join(req.b, x0=None if x0 is None
                                  else x0[:, None])
                group.members.append(req)
        self._pending = still
        self.queue_depth.set(len(self._pending))

    def _admit_adaptive(self, req: SolveRequest) -> None:
        """Dispatch one ``tags="adaptive"`` request at its admission
        boundary: the adaptive driver's host replan loop runs to
        completion here (not chunk-preemptible), with the same breaker /
        warm-cache / degradation bookkeeping as a finalized column."""
        self.queue_wait.observe(max(0.0, self.clock() - req.t_submit))
        self._verify_pack(req.handle)
        op = self._ops[req.handle]
        br = self._breaker(req.handle)
        try:
            reps = self._run_adaptive(op, req.tol, [req])
            rep = reps[req.id]
        except Exception:  # degraded, never propagated (pump contract)
            self.stats["errors"] += 1
            self._solutions.pop(req.id, None)
            br.record_failure()
            reps = {req.id: SolveReport(
                id=req.id, handle=req.handle, iters=0,
                relres=float("inf"), converged=False, tag=0,
                switch_iters=np.full(2, -1, np.int64),
                est_bytes=0, batch_size=1, health="error",
            )}
        else:
            if rep.converged and rep.health == "ok":
                br.record_success()
                self._warm_store(req, self._solutions[req.id])
            else:
                br.record_failure()
            self.request_bytes.observe(rep.est_bytes)
        self._breaker_gauge.labels(
            service=self.service_id, handle=req.handle
        ).set(1 if br.state == OPEN else 0)
        self.solve_latency.observe(max(0.0, self.clock() - req.t_submit))
        self._adaptive_done.update(reps)

    def _expired(self, req: SolveRequest) -> bool:
        return (req.deadline_s is not None
                and self.clock() - req.t_submit > req.deadline_s)

    def _settle(self, key: tuple, group: _Group) -> Dict[int, SolveReport]:
        """Boundary processing after a chunk: finalize finished columns,
        expire lapsed deadlines (flagged last checkpoint -- never
        silently dropped), drop their columns, retire empty groups."""
        out: Dict[int, SolveReport] = {}
        width = group.chunks.nrhs
        for j in reversed(range(group.chunks.nrhs)):
            req = group.members[j]
            done = group.chunks.col_done(j)
            expired = not done and self._expired(req)
            if not done and not expired:
                continue
            snap = group.chunks.drop(j)
            snap["batch"] = width
            group.members.pop(j)
            if expired:
                out[req.id] = self._finalize_expired(req, snap, key)
            else:
                out[req.id] = self._finalize(req, snap, key)
        if group.chunks.nrhs == 0:
            del self._groups[key]
        return out

    def _finalize(self, req: SolveRequest, snap: dict,
                  key: tuple) -> SolveReport:
        """A column that ran to its natural exit: bounded tag-3 retries
        for degraded columns (as in the sync service), breaker and
        warm-cache bookkeeping, per-request byte share."""
        op = self._ops[req.handle]
        x = snap["x"]
        it = snap["iters"]
        relres = snap["relres"]
        conv = snap["converged"]
        tag = snap["tag"]
        h = snap["health"]
        trip = snap["trip_iter"]
        retries = 0
        deadline_hit = False
        x_finite = bool(jnp.isfinite(jnp.vdot(x, x)))
        shares, total = self._byte_shares(
            op, np.asarray([it]), np.asarray(snap["switch_iters"]
                                             ).reshape(1, -1),
            tags=self._eff_tags(req))
        est_bytes = int(shares[0])
        self.stats["modeled_bytes"] += total
        solve_op = self._operators.get(req.handle, op.solve_op)
        while (not conv or not x_finite) and retries < self.max_retries:
            if self._expired(req):
                deadline_hit = True
                self.stats["deadline_exceeded"] += 1
                break
            retries += 1
            self.stats["retries"] += 1
            warm = x if x_finite else req.x0
            if op.precond is not None:
                r2 = solve_pcg(solve_op, req.b, op.precond, x0=warm,
                               tol=req.tol, maxiter=self.maxiter,
                               params=self.params, wire=op.wire,
                               guards=self.guards, init_tag=3)
            else:
                r2 = solve_cg(solve_op, req.b, x0=warm, tol=req.tol,
                              maxiter=self.maxiter, params=self.params,
                              wire=op.wire, guards=self.guards, init_tag=3)
            rx_finite = bool(jnp.isfinite(jnp.vdot(r2.x, r2.x)))
            r2_trip = int(getattr(r2, "trip_iter", -1))
            if trip < 0 and r2_trip >= 0:
                trip = it + r2_trip
            it += int(r2.iters)
            relres = float(r2.relres)
            conv = bool(r2.converged)
            tag = int(r2.tag)
            h = int(getattr(r2, "health", HEALTH_OK))
            if rx_finite:
                x = r2.x
            x_finite = x_finite or rx_finite
            sh2, tot2 = self._byte_shares(
                op, np.asarray([int(r2.iters)]),
                np.asarray(r2.switch_iters).reshape(1, -1))
            est_bytes += int(sh2[0])
            self.stats["modeled_bytes"] += tot2
        # The PR-6 invariant, upheld at this layer too: a non-finite x
        # NEVER leaves the service unflagged.
        if not x_finite and h == HEALTH_OK:
            from repro.robustness.guards import HEALTH_NONFINITE

            h = HEALTH_NONFINITE
            conv = False
        br = self._breaker(req.handle)
        if conv and h == HEALTH_OK:
            br.record_success()
            self._warm_store(req, x)
        else:
            br.record_failure()
        self._breaker_gauge.labels(
            service=self.service_id, handle=req.handle
        ).set(1 if br.state == OPEN else 0)
        self._solutions[req.id] = x
        self.stats["requests"] += 1
        self.solve_latency.observe(max(0.0, self.clock() - req.t_submit))
        self.request_bytes.observe(est_bytes)
        return SolveReport(
            id=req.id, handle=req.handle, iters=it, relres=relres,
            converged=conv, tag=tag,
            switch_iters=np.asarray(snap["switch_iters"]),
            est_bytes=est_bytes, batch_size=snap.get("batch", 1),
            health=health_name(h), trip_iter=trip, retries=retries,
            deadline_exceeded=deadline_hit,
        )

    def _finalize_expired(self, req: SolveRequest, snap: dict,
                          key: tuple) -> SolveReport:
        """Deadline lapsed mid-solve: the report carries the last
        checkpoint (the column's current -- last healthy -- iterate),
        flagged ``deadline_exceeded`` with ``health="deadline"`` when no
        guard already flagged it.  Never silently dropped."""
        x = snap["ckpt"]
        x_finite = bool(jnp.isfinite(jnp.vdot(x, x)))
        # The RAW guard health, not the finalized one: a mid-solve column
        # is unconverged by definition, so finalize_health would report
        # every expiry as "stalled" -- only a genuine in-loop guard trip
        # should shadow the "deadline" flag.
        h = snap.get("guard_health", HEALTH_OK)
        self.stats["deadline_exceeded"] += 1
        self.stats["requests"] += 1
        # A deadline expiry is a capacity signal, not an operand fault:
        # it does not trip the breaker, but it does not close it either.
        self._solutions[req.id] = x
        self.solve_latency.observe(max(0.0, self.clock() - req.t_submit))
        if h != HEALTH_OK:
            health = health_name(h)
        elif not x_finite:
            health = "nonfinite"
        else:
            health = "deadline"
        return SolveReport(
            id=req.id, handle=req.handle, iters=snap["iters"],
            relres=snap["relres"], converged=False, tag=snap["tag"],
            switch_iters=np.asarray(snap["switch_iters"]),
            est_bytes=0, batch_size=snap.get("batch", 1), health=health,
            trip_iter=snap["trip_iter"], retries=0,
            deadline_exceeded=True,
        )

    def _fail_group(self, key: tuple, group: _Group
                    ) -> Dict[int, SolveReport]:
        """A group whose chunk raised: degrade every member to an error
        report (sync-service contract), record breaker failures."""
        out: Dict[int, SolveReport] = {}
        for req in group.members:
            self._solutions.pop(req.id, None)
            self._breaker(req.handle).record_failure()
            out[req.id] = SolveReport(
                id=req.id, handle=req.handle, iters=0,
                relres=float("inf"), converged=False, tag=0,
                switch_iters=np.full(2, -1, np.int64),
                est_bytes=0, batch_size=len(group.members),
                health="error",
            )
        self._groups.pop(key, None)
        return out
