"""Per-handle circuit breaker for the async solve service (DESIGN.md §17).

A handle whose solves keep guard-tripping (a poisoned operand, a fault
injector, an operator that NaNs at its serving tag) should stop burning
batch slots: after ``fail_threshold`` consecutive guard-tripped
failures the breaker OPENS and the service sheds submissions against
the handle with a typed response carrying ``retry_after_s``.  After a
backoff the breaker HALF-OPENS: exactly one probe request is admitted;
its outcome closes the breaker (success) or re-opens it with the
backoff doubled (failure), up to ``max_backoff_s``.

The backoff carries seeded jitter (``numpy.random.default_rng``) so a
fleet of clients shedding against the same handle doesn't re-probe in
lockstep, while replays stay deterministic.  The clock is injectable --
tests and the chaos harness drive transitions with a fake clock instead
of sleeping.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

__all__ = ["BreakerParams", "CircuitBreaker"]

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


@dataclasses.dataclass(frozen=True)
class BreakerParams:
    fail_threshold: int = 3     # consecutive failures before opening
    backoff_s: float = 0.5      # first open -> half-open delay
    backoff_mult: float = 2.0   # growth per re-open from half-open
    max_backoff_s: float = 30.0
    jitter: float = 0.1         # +- fraction of the backoff, seeded


class CircuitBreaker:
    """CLOSED -> OPEN -> HALF_OPEN state machine, one per handle."""

    def __init__(self, params: BreakerParams | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 seed: int = 0):
        self.params = params or BreakerParams()
        self.clock = clock
        self._rng = np.random.default_rng(seed)
        self.state = CLOSED
        self.fails = 0          # consecutive failures while closed
        self.opened_at = 0.0
        self.backoff = self.params.backoff_s
        self._wait = 0.0        # jittered backoff for the current open
        self._probing = False   # half-open: one probe in flight
        self.transitions = []   # (state, t) log for tests/telemetry

    def _jittered(self, base: float) -> float:
        j = self.params.jitter
        return base * float(1.0 + self._rng.uniform(-j, j)) if j else base

    def _to(self, state: str) -> None:
        self.state = state
        self.transitions.append((state, self.clock()))

    def allow(self) -> bool:
        """May a request against this handle be admitted right now?

        While OPEN, flips to HALF_OPEN once the jittered backoff has
        elapsed and admits exactly ONE probe; further calls return False
        until that probe's outcome is recorded.
        """
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            if self.clock() - self.opened_at >= self._wait:
                self._to(HALF_OPEN)
                self._probing = True
                return True
            return False
        # HALF_OPEN: one probe at a time.
        if not self._probing:
            self._probing = True
            return True
        return False

    def retry_after(self) -> float:
        """Seconds until the next admission attempt could succeed
        (0 when not OPEN) -- what the shed response carries."""
        if self.state != OPEN:
            return 0.0
        return max(0.0, self._wait - (self.clock() - self.opened_at))

    def release(self) -> None:
        """Give back an ``allow()`` admission that never dispatched (the
        request was rejected downstream) -- without this a half-open
        breaker's single probe slot would leak and jam the handle."""
        self._probing = False

    def record_success(self) -> None:
        """A request against the handle finished healthy."""
        self.fails = 0
        if self.state != CLOSED:
            self.backoff = self.params.backoff_s  # full reset on recovery
            self._to(CLOSED)
        self._probing = False

    def record_failure(self) -> None:
        """A request against the handle guard-tripped (health != ok)."""
        self._probing = False
        if self.state == HALF_OPEN:
            # The probe failed: re-open with the backoff escalated.
            self.backoff = min(self.backoff * self.params.backoff_mult,
                               self.params.max_backoff_s)
            self._open()
            return
        if self.state == OPEN:
            return
        self.fails += 1
        if self.fails >= self.params.fail_threshold:
            self._open()

    def _open(self) -> None:
        self.opened_at = self.clock()
        self._wait = self._jittered(self.backoff)
        self.fails = 0
        self._to(OPEN)
