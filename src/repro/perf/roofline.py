"""Host roofline probes: stream bandwidth + peak FLOP rate (PR 7).

CI gates on *fraction of roofline* instead of absolute microseconds: the
host's attainable rates are measured once per machine (a STREAM-triad
bandwidth probe and an f32 matmul FLOP probe), persisted in the tune
cache (``perf.tunecache``, checksum-verified like every other entry), and
every benchmarked kernel reports

    roofline_fraction = max(bytes / BW, flops / peak) / measured_seconds

i.e. attainable-time over measured-time.  This is the stable currency
across heterogeneous CI hosts -- a slow runner lowers the roof and the
measurement together (DESIGN.md section 15).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.perf import timing, tunecache

__all__ = ["probe_stream_gbps", "probe_peak_gflops", "host_roofline",
           "attainable_seconds", "fraction"]


def probe_stream_gbps(n: int = 1 << 23, iters: int = 5) -> float:
    """STREAM-triad bandwidth: ``y = 2x + b`` over f64 arrays sized past
    LLC (default 64 MiB per array, 3 streams)."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=n))
    b = jnp.asarray(np.random.default_rng(1).normal(size=n))
    triad = jax.jit(lambda x, b: 2.0 * x + b)
    _, sec = timing.measure(triad, x, b, iters=iters, warmup=2)
    return 3 * 8 * n / sec / 1e9


def probe_peak_gflops(n: int = 1024, iters: int = 5) -> float:
    """Peak-ish FLOP rate: f32 (n, n) matmul, 2n^3 FLOPs per call."""
    a = jnp.asarray(np.random.default_rng(2).normal(size=(n, n)), jnp.float32)
    b = jnp.asarray(np.random.default_rng(3).normal(size=(n, n)), jnp.float32)
    mm = jax.jit(lambda a, b: a @ b)
    _, sec = timing.measure(mm, a, b, iters=iters, warmup=2)
    return 2 * n**3 / sec / 1e9


def host_roofline(refresh: bool = False, quick: bool = False) -> dict:
    """{stream_gbps, peak_gflops, probed} for this host.

    Persisted in the tune cache so repeat benchmark runs re-probe nothing
    (``probed=False`` on a cache hit); ``refresh=True`` forces a
    re-measure.  ``quick`` shrinks the probe sizes for smoke jobs."""
    if not refresh:
        hit = tunecache.host_entry()
        if hit is not None:
            return {**hit, "probed": False}
    payload = {
        "stream_gbps": probe_stream_gbps(n=1 << 21 if quick else 1 << 23,
                                         iters=3 if quick else 5),
        "peak_gflops": probe_peak_gflops(n=512 if quick else 1024,
                                         iters=3 if quick else 5),
    }
    tunecache.store_host(payload)
    return {**payload, "probed": True}


def attainable_seconds(flops: float, bytes_: float, roof: dict) -> float:
    """Roofline lower bound on wall time for (flops, bytes) on ``roof``."""
    return max(bytes_ / (roof["stream_gbps"] * 1e9),
               flops / (roof["peak_gflops"] * 1e9))


def fraction(flops: float, bytes_: float, seconds: float,
             roof: dict) -> float:
    """Attainable-time / measured-time (1.0 == at the roofline; >1 means
    the working set sat in cache above the streamed-bandwidth roof)."""
    return attainable_seconds(flops, bytes_, roof) / seconds
