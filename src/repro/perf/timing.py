"""Best-of-k wall timing with ``block_until_ready`` on every output.

The single timing primitive every benchmark routes through (PR 7
satellite: ``fig89_solver_time.py`` and ``robust_bench.py`` used to
hand-roll ``perf_counter`` loops while ``benchmarks/common.time_fn``
reported a median).  Minimum-of-k is the standard noise-robust estimator
for a deterministic computation on a shared host: every source of
variance (scheduler, turbo, page faults) only ever ADDS time, so the min
converges on the true cost while median/mean track the noise floor --
exactly the artifact that made ``gse_h`` look slower than fp64 in the
pre-PR-7 BENCH_spmv.json (DESIGN.md section 15).
"""
from __future__ import annotations

import time

import jax

__all__ = ["measure", "measure_split", "best_seconds"]


def measure(fn, *args, iters: int = 10, warmup: int = 2, **kwargs):
    """Run ``fn(*args, **kwargs)`` ``warmup + iters`` times; return
    ``(last_output, best_seconds)`` with every output blocked on."""
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    out = None
    for _ in range(max(warmup, 0)):
        out = jax.block_until_ready(fn(*args, **kwargs))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return out, best


def measure_split(fn, *args, iters: int = 10, warmup: int = 2, **kwargs):
    """Like :func:`measure`, but also times the very first call separately.

    Returns ``(last_output, first_seconds, best_seconds)``.  The first call
    of a jitted ``fn`` pays trace + compile; steady-state calls replay the
    executable.  ``first - best`` is therefore a cheap compile-time
    estimate with no profiler dependency (clamp at 0: on a cache hit the
    first call can land inside run-to-run noise).  Observability callers
    (``benchmarks.common.timed``, ``run.py --obs``) record both sides as
    registry metrics (DESIGN.md §16).
    """
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args, **kwargs))
    first = time.perf_counter() - t0
    for _ in range(max(warmup - 1, 0)):
        out = jax.block_until_ready(fn(*args, **kwargs))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return out, first, best


def best_seconds(fn, *args, iters: int = 10, warmup: int = 2,
                 **kwargs) -> float:
    """Best-of-k seconds only (drops the output)."""
    return measure(fn, *args, iters=iters, warmup=warmup, **kwargs)[1]
