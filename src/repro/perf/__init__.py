"""Performance subsystem: launch plans, FLOP/byte ledger, roofline, autotune.

PR 7 (DESIGN.md section 15).  Import layering, bottom-up:

  ``tunecache``  -- persisted tuned-plan store (stdlib only);
  ``plan``       -- :class:`KernelPlan` + the single ``resolve`` dispatcher
                    every kernel entry point routes its block defaults
                    through (imports tunecache);
  ``timing``     -- best-of-k ``block_until_ready`` wall timing;
  ``ledger``     -- per-kernel FLOP + byte ledger, cross-validated against
                    the byte models in ``sparse/csr.py``, jaxpr operand
                    lists, and the HLO estimator in ``launch/hlo.py``;
  ``roofline``   -- host stream-bandwidth / peak-FLOP probes and
                    achieved-vs-roofline fractions;
  ``autotune``   -- sweeps (BM, lane block, SELL C/sigma, width-bucket
                    granularity) per matrix class and persists winners
                    (imports ``kernels/ops`` -- keep it OUT of this
                    module's eager imports so ``kernels/ops`` can import
                    ``perf.plan`` without a cycle).
"""
from __future__ import annotations

from repro.perf.plan import (  # noqa: F401
    DEFAULT_BLOCKS,
    DEFAULT_PLAN,
    KernelPlan,
    plan_key,
    resolve,
    shape_class,
)
from repro.perf.tunecache import TUNE_STATS  # noqa: F401

__all__ = [
    "KernelPlan",
    "DEFAULT_PLAN",
    "DEFAULT_BLOCKS",
    "resolve",
    "plan_key",
    "shape_class",
    "TUNE_STATS",
]


def __getattr__(name):
    # autotune / ledger / roofline / timing import jax (and autotune imports
    # kernels.ops); load them lazily so `import repro.perf` stays cheap and
    # cycle-free.
    if name in ("autotune", "ledger", "roofline", "timing", "tunecache",
                "plan"):
        import importlib

        return importlib.import_module(f"repro.perf.{name}")
    raise AttributeError(f"module 'repro.perf' has no attribute {name!r}")
