"""Launch-plan autotuner: sweep, pick, persist (PR 7, DESIGN.md §15).

Sweeps the launch axes the ISSUE names -- row block BM, lane block BL,
SELL slice height C / sort window sigma, and width-bucket granularity --
per (shape-class, tag, layout, nrhs), times each candidate best-of-k
(``perf.timing``), and persists the winner in ``perf.tunecache`` so every
later run (and every ``perf.plan.resolve`` dispatch) reuses it with ZERO
re-sweeps (asserted via ``TUNE_STATS`` in tests/test_perf.py).

The candidate lists always contain the default plan, so a tuned winner is
never slower than untuned *on the sweep's own measurements*; the sweep
report keeps both times for the roofline benchmark's tuned-vs-untuned
gate.

Decode-overhead crossover (satellite 6): on the jnp reference path the
GSE decode adds per-nnz integer work, and below ``DECODE_BOUND_NNZ``
entries wall time is launch/latency-bound -- byte savings cannot show up
in microseconds even though the stream model halves (measured in
DESIGN.md §15).  ``decode_bound(a)`` encodes that point; the tuner stores
it with each winner so benchmark gates can pick the honest axis
(wall-clock parity below the crossover, bandwidth dominance above).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.obs import trace as OT
from repro.perf import timing, tunecache
from repro.perf.plan import (
    DEFAULT_PLAN,
    KernelPlan,
    plan_key,
    shape_class,
)

__all__ = ["candidates", "tune", "get_or_tune", "decode_bound",
           "DECODE_BOUND_NNZ"]

# Measured on the dev host (DESIGN.md §15): below ~2e5 nnz the jnp-path
# SpMV wall time is flat in the streamed bytes (launch/decode-bound);
# above it the tag ladder's byte savings start tracking wall time.
DECODE_BOUND_NNZ = 200_000


def decode_bound(a) -> bool:
    """True when ``a`` sits below the measured decode-overhead crossover
    (format choice is latency-neutral there; gate on parity, not GB/s)."""
    return int(a.nnz) < DECODE_BOUND_NNZ


def candidates(layout: str) -> tuple:
    """Candidate plans per layout; the default plan always leads.

    ELL sweeps (BM, BL); BL is the lane block -- widening it pads the pack
    (the tuner prices that in wall time).  SELL sweeps C/sigma/bucket with
    BM tied to C (``c % bm == 0`` is a hard kernel constraint) and BL at
    the lane width (bucket widths are lane multiples, wider BL would not
    tile them).
    """
    if layout == "ell":
        return (
            DEFAULT_PLAN,
            KernelPlan(blocks=(16, 128)),
            KernelPlan(blocks=(32, 128)),
            KernelPlan(blocks=(8, 256)),
        )
    if layout == "sell":
        return (
            DEFAULT_PLAN,
            KernelPlan(blocks=(16, 128), sell_c=16),
            KernelPlan(blocks=(16, 128), sell_c=16, sell_sigma=64),
            KernelPlan(blocks=(8, 128), sell_c=8, sell_sigma=32),
            KernelPlan(blocks=(8, 128), sell_bucket="exact"),
        )
    raise ValueError(f"layout must be 'ell' or 'sell', got {layout!r}")


def _runner(a, x, tag: int, layout: str, plan: KernelPlan,
            interpret: bool | None):
    """Pack with the candidate's layout parameters and return a thunk
    running the planned kernel (pack time excluded: packs are memoized
    for the life of the operator, the steady state solvers see)."""
    if layout == "sell":
        sell = ops.sell_pack_gsecsr(a, plan=plan)
        if not plan.compatible_with_sell(sell):
            return None
        if x.ndim == 1:
            return lambda: ops.gse_spmv_sell(sell, x, tag=tag,
                                             blocks=plan.blocks,
                                             interpret=interpret)
        return lambda: ops.gse_spmm_sell(sell, x, tag=tag,
                                         blocks=plan.blocks,
                                         interpret=interpret)
    ell = ops.ell_pack_gsecsr(a, plan=plan)
    if x.ndim == 1:
        return lambda: ops.gse_spmv_ell(ell, a.table, x, a.ei_bit, tag=tag,
                                        blocks=plan.blocks,
                                        interpret=interpret)
    return lambda: ops.gse_spmm_ell(ell, a.table, x, a.ei_bit, tag=tag,
                                    blocks=plan.blocks, interpret=interpret)


def tune(a, tag: int = 1, layout: str = "ell", nrhs: int = 1,
         iters: int = 3, warmup: int = 1,
         interpret: bool | None = None) -> dict:
    """Sweep candidates for ``a`` at (tag, layout, nrhs); persist the
    winner.  Returns the stored payload: ``{plan, us, default_us, sweep,
    decode_bound}``."""
    key = plan_key(shape_class(a), tag, layout, nrhs)
    rng = np.random.default_rng(0)
    n = a.shape[1]
    x = jnp.asarray(rng.normal(size=(n, nrhs) if nrhs > 1 else n),
                    jnp.float32)
    sweep = []
    best = None
    with OT.span("tune.sweep", key=key, layout=layout, tag=tag,
                 nrhs=nrhs) as attrs:
        for cand in candidates(layout):
            run = _runner(a, x, tag, layout, cand, interpret)
            if run is None:
                continue
            _, sec = timing.measure(run, iters=iters, warmup=warmup)
            row = {"plan": cand.to_dict(), "us": sec * 1e6}
            sweep.append(row)
            if best is None or row["us"] < best[1]["us"]:
                best = (cand, row)
        attrs["candidates"] = len(sweep)
    tunecache.TUNE_STATS["sweeps"] += 1
    plan, row = best
    payload = {
        "plan": plan.to_dict(),
        "us": row["us"],
        "default_us": sweep[0]["us"],  # candidates() leads with the default
        "sweep": sweep,
        "decode_bound": decode_bound(a),
    }
    tunecache.store(key, payload)
    return payload


def get_or_tune(a, tag: int = 1, layout: str = "ell", nrhs: int = 1,
                **kwargs):
    """Tuned plan for ``a``, sweeping only on a cache miss.

    Returns ``(plan, payload, hit)``; on a hit the payload is the stored
    sweep report and no kernel runs at all (the zero-re-sweep discipline
    the CI roofline job asserts)."""
    key = plan_key(shape_class(a), tag, layout, nrhs)
    payload = tunecache.lookup(key)
    hit = payload is not None
    if not hit:
        payload = tune(a, tag=tag, layout=layout, nrhs=nrhs, **kwargs)
    plan = KernelPlan.from_dict(payload["plan"], source="tuned")
    return plan, payload, hit
