"""Kernel launch plans and the single resolution dispatcher (PR 7).

Every SpMV/SpMM kernel entry point used to hardcode ``blocks=(8, 128)``.
They now resolve their launch configuration through :func:`resolve`, with
a fixed precedence:

  1. explicit ``blocks=`` argument        (today's call sites, unchanged)
  2. explicit ``plan=KernelPlan(...)``    (caller-owned plan)
  3. tuned cache entry                    (``perf.tunecache``, keyed by
                                           ``(shape-class | tag | layout |
                                           nrhs)``)
  4. :data:`DEFAULT_PLAN`                 (bit-identical to pre-PR-7
                                           behavior -- blocks (8, 128),
                                           lane 128, SELL C=8 / full-sort
                                           sigma / pow2 width buckets)

so with an empty tune cache and no explicit arguments every kernel runs
exactly as before (asserted in tests/test_perf.py).

The shape class buckets operators by power-of-two row count and mean
row length -- coarse on purpose: a tuned winner should transfer across
same-family matrices, and the class must be derivable identically from a
``GSECSR``/``CSR`` (rowptr) and from an already-packed ``GSESellC``
(shape + nnz), so dispatch-time lookups hit the keys the autotuner stored.
"""
from __future__ import annotations

import dataclasses

from repro.perf import tunecache

__all__ = ["KernelPlan", "DEFAULT_PLAN", "DEFAULT_BLOCKS", "resolve",
           "shape_class", "plan_key", "tag_token"]

DEFAULT_BLOCKS = (8, 128)


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """One kernel launch configuration (DESIGN.md section 15).

    ``blocks``      -- (BM, BL) Pallas grid tile: BM rows x BL lanes;
    ``lane``        -- pack lane alignment (ELL width / SELL slice widths
                       round up to multiples of this);
    ``sell_c``      -- SELL slice height C (multiple of 8, and BM must
                       divide it);
    ``sell_sigma``  -- SELL sort-window sigma (None = full sort);
    ``sell_bucket`` -- SELL width-bucket granularity: "pow2" bins slice
                       widths into power-of-two lane multiples (bounded
                       kernel-call count), "exact" keeps each distinct
                       lane-aligned width (zero bucket padding, more
                       calls);
    ``source``      -- provenance ("default" / "explicit" / "tuned"),
                       excluded from equality so a tuned plan that picks
                       the default configuration compares equal to it.
    """

    blocks: tuple = DEFAULT_BLOCKS
    lane: int = 128
    sell_c: int = 8
    sell_sigma: int | None = None
    sell_bucket: str = "pow2"
    source: str = dataclasses.field(default="default", compare=False)

    def to_dict(self) -> dict:
        return {
            "blocks": list(self.blocks),
            "lane": self.lane,
            "sell_c": self.sell_c,
            "sell_sigma": self.sell_sigma,
            "sell_bucket": self.sell_bucket,
        }

    @classmethod
    def from_dict(cls, d: dict, source: str = "tuned") -> "KernelPlan":
        return cls(
            blocks=tuple(d.get("blocks", DEFAULT_BLOCKS)),
            lane=int(d.get("lane", 128)),
            sell_c=int(d.get("sell_c", 8)),
            sell_sigma=(None if d.get("sell_sigma") is None
                        else int(d["sell_sigma"])),
            sell_bucket=str(d.get("sell_bucket", "pow2")),
            source=source,
        )

    def compatible_with_sell(self, sell) -> bool:
        """Can ``blocks`` drive an ALREADY-packed ``GSESellC``?  (The pack
        fixes C and the bucket widths; a tuned plan recorded for a
        different pack must fall back instead of raising.)"""
        bm, bl = self.blocks
        return (sell.c % bm == 0
                and all(w % bl == 0 for w in sell.widths))


DEFAULT_PLAN = KernelPlan()


def _p2(x: float) -> int:
    n = 1
    while n < x:
        n *= 2
    return n


def shape_class(obj) -> str:
    """Coarse matrix class: pow2-bucketed rows x pow2-bucketed mean row
    length.  Works for any container exposing ``shape`` and ``nnz``
    (``CSR``, ``GSECSR``, ``GSESellC``, ``ELLLayout`` ducks in too)."""
    rows = int(obj.shape[0])
    nnz = int(obj.nnz)
    mean_row = max(1, -(-nnz // max(rows, 1)))
    return f"m{_p2(rows)}r{_p2(mean_row)}"


def tag_token(tag) -> str:
    """Cache-key token of a precision axis value.

    Scalar tags keep the pre-PR-10 ``tag{t}`` token (existing tune-cache
    entries stay resolvable); a per-group :class:`~repro.core.tagmap.
    TagMap` keys under its CRC32 -- ``map{crc:08x}`` -- so a promoted map
    can never resolve a plan tuned for a different (stale) map.
    """
    crc = getattr(tag, "crc32", None)
    if crc is not None:
        return f"map{crc:08x}"
    return f"tag{tag}"


def plan_key(shape_cls: str, tag, layout: str, nrhs: int = 1) -> str:
    """Tune-cache key: ``shape-class | tag-token | layout | nrhs``."""
    return f"{shape_cls}|{tag_token(tag)}|{layout}|nrhs{int(nrhs)}"


def resolve(source=None, *, tag=None, layout: str | None = None,
            nrhs: int = 1, plan: KernelPlan | None = None,
            blocks=None) -> KernelPlan:
    """The single launch-plan dispatcher (precedence documented above).

    ``source`` is an optional operand container (``GSECSR``/``GSESellC``/
    ...) enabling the tuned-cache lookup; without it (or without ``tag``/
    ``layout``) resolution goes straight to the default plan, which keeps
    bare array-level entry points (``gse_spmv_ell`` on raw segment
    tuples) bit-identical to their pre-PR-7 behavior.
    """
    if blocks is not None:
        base = plan if plan is not None else DEFAULT_PLAN
        return dataclasses.replace(base, blocks=tuple(blocks),
                                   source="explicit")
    if plan is not None:
        if plan.source == "default":
            plan = dataclasses.replace(plan, source="explicit")
        return plan
    if source is not None and tag is not None and layout is not None:
        payload = tunecache.lookup(plan_key(shape_class(source), tag,
                                            layout, nrhs))
        if payload is not None:
            return KernelPlan.from_dict(payload.get("plan", payload),
                                        source="tuned")
    return DEFAULT_PLAN
