"""Persisted tuned-plan store with checksum-on-hit discipline.

The autotuner's winners survive the process in ONE JSON file (default
``~/.cache/repro/tunecache.json``, override with ``REPRO_TUNE_CACHE``)
keyed by the launch-plan key ``(shape-class | tag | layout | nrhs)``.
Every entry carries a CRC32 over its canonical JSON payload, verified on
every lookup exactly like the PR-4 pack cache (``kernels/ops.PACK_STATS``):
a corrupted entry is dropped, counted in ``TUNE_STATS['corrupt']``, and
the caller re-sweeps instead of launching a garbage plan.

``TUNE_STATS`` is module-global so benchmarks and tests can assert that a
repeat run re-sweeps NOTHING (``sweeps`` stays flat while ``hits`` grows).
"""
from __future__ import annotations

import json
import os
import tempfile
import zlib

from repro.obs import metrics as OM

__all__ = ["TUNE_STATS", "cache_path", "lookup", "store", "host_entry",
           "store_host", "reset", "clear_memory"]

# Dict-shaped registry view (DESIGN.md §16): historical ``TUNE_STATS[k]``
# call sites and test assertions work unchanged, exposition goes through
# ``obs.metrics.REGISTRY``.
TUNE_STATS = OM.stats_view(
    "repro_tune_cache_events_total",
    ("hits", "misses", "corrupt", "sweeps", "stores"),
    help="Tuned-plan store events by outcome.",
)

# In-memory image of the cache file: {"plans": {key: entry}, "host": entry}
# where entry = {"payload": <jsonable>, "crc": int}.  Reloaded whenever the
# resolved path changes (tests point REPRO_TUNE_CACHE at tmp files).
_MEM: dict | None = None
_MEM_PATH: str | None = None


def cache_path() -> str:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "tunecache.json")


def _crc(payload) -> int:
    return zlib.crc32(json.dumps(payload, sort_keys=True).encode())


def _image() -> dict:
    global _MEM, _MEM_PATH
    path = cache_path()
    if _MEM is None or _MEM_PATH != path:
        try:
            with open(path) as fh:
                _MEM = json.load(fh)
        except (OSError, ValueError):
            _MEM = {"plans": {}, "host": None}
        _MEM.setdefault("plans", {})
        _MEM.setdefault("host", None)
        _MEM_PATH = path
    return _MEM


def _flush() -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               prefix=".tunecache.")
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(_MEM, fh, indent=1, sort_keys=True)
        os.replace(tmp, path)  # atomic: readers never see a torn file
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


def _verify(entry) -> bool:
    return (isinstance(entry, dict) and "payload" in entry
            and _crc(entry["payload"]) == entry.get("crc"))


def lookup(key: str):
    """Tuned payload for ``key`` or None; checksum-verified on every hit."""
    img = _image()
    entry = img["plans"].get(key)
    if entry is None:
        TUNE_STATS["misses"] += 1
        return None
    if not _verify(entry):
        TUNE_STATS["corrupt"] += 1
        del img["plans"][key]
        _flush()
        return None
    TUNE_STATS["hits"] += 1
    return entry["payload"]


def store(key: str, payload) -> None:
    """Persist a tuned payload under ``key`` (atomic rewrite)."""
    img = _image()
    img["plans"][key] = {"payload": payload, "crc": _crc(payload)}
    TUNE_STATS["stores"] += 1
    _flush()


def host_entry():
    """Persisted host roofline probe ({stream_gbps, peak_gflops}) or None."""
    entry = _image()["host"]
    if entry is None or not _verify(entry):
        return None
    return entry["payload"]


def store_host(payload) -> None:
    img = _image()
    img["host"] = {"payload": payload, "crc": _crc(payload)}
    _flush()


def reset() -> None:
    """Zero the counters (tests)."""
    for k in TUNE_STATS:
        TUNE_STATS[k] = 0


def clear_memory() -> None:
    """Drop the in-memory image so the next access re-reads the file."""
    global _MEM, _MEM_PATH
    _MEM = None
    _MEM_PATH = None
