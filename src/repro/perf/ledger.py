"""Per-kernel FLOP + byte ledger (PR 7, DESIGN.md section 15).

One :class:`KernelLedger` per (kernel, tag, layout, nrhs) records what a
single SpMV/SpMM application *should* move and compute, derived from the
same tag-specialized operand lists the kernels stream:

  * ``flops``         -- useful work: ``2 * nnz * nrhs`` (multiply + add
                         per stored entry per column; padded slots
                         multiply exact zeros and are NOT credited);
  * ``matrix_bytes``  -- the slot-honest matrix-stream model
                         (``GSECSR.bytes_touched`` / ``ELLLayout`` /
                         ``GSESellC.bytes_touched``);
  * ``vector_bytes``  -- x read + y write per column;
  * ``fp64_bytes``    -- what an fp64 CSR SpMV streams for the SAME math
                         (12 B/nnz + rowptr): dividing by wall time gives
                         the *effective* bandwidth, the fair cross-format
                         axis (a tag-1 kernel at equal wall time delivers
                         the same effective GB/s while reading half the
                         physical bytes).

Three independent cross-checks pin the model (tests/test_perf.py):
``pallas_segment_bytes`` predicts the exact padded operand bytes of a
kernel launch, validated against (a) the jaxpr's integer ``pallas_call``
operands (:func:`jaxpr_pallas_int_bytes`, the PR-1/PR-4 assertion style)
and (b) the compiled HLO's entry parameters
(:func:`launch.hlo.parameter_bytes`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision_table import COLIDX_BYTES, SLOT_BYTES
from repro.perf.plan import DEFAULT_BLOCKS
from repro.sparse.csr import (
    CSR,
    GSECSR,
    GSESellC,
    ELLLayout,
    ell_layout,
    vector_stream_bytes,
)

__all__ = ["KernelLedger", "spmv_ledger", "pallas_segment_bytes",
           "jaxpr_pallas_int_bytes", "hlo_segment_bytes", "achieved"]


@dataclasses.dataclass(frozen=True)
class KernelLedger:
    kernel: str          # "spmv_ell" / "spmm_sell" / "spmv_csr" / ...
    tag: object          # GSE tag 1/2/3, or a store dtype name for CSR
    layout: str          # "csr" / "ell" / "sell"
    nrhs: int
    nnz: int
    slots: int           # padded slots streamed (== nnz for raw CSR)
    flops: int           # useful FLOPs: 2 * nnz * nrhs
    matrix_bytes: int    # modeled matrix-stream bytes (slot-honest)
    vector_bytes: int    # per-column x/y traffic * nrhs
    fp64_bytes: int      # fp64-CSR-equivalent matrix bytes for same math

    @property
    def bytes(self) -> int:
        return self.matrix_bytes + self.vector_bytes


def _fp64_equiv(a) -> int:
    # fp64 CSR matrix streams: 8 B value + 4 B colidx per nnz + rowptr.
    m = int(a.shape[0])
    return int(a.nnz) * (8 + COLIDX_BYTES) + (m + 1) * 4


def spmv_ledger(a, tag=None, layout=None, nrhs: int = 1,
                vec_dtype=jnp.float64, store_dtype=None,
                jnp_path: bool = False) -> KernelLedger:
    """Ledger for one SpMV/SpMM application of ``a``.

    ``a`` is a ``GSECSR`` (give ``tag``) or a plain ``CSR`` (give
    ``store_dtype``).  ``layout`` selects the byte account: ``None`` (raw
    CSR nnz model), ``"ell"`` (uniform lane-padded), or an
    ``ELLLayout``/``GSESellC`` instance for the exact pack in hand.
    ``jnp_path=True`` charges the reference decode's extra ``row_ids``
    stream (nnz * 4 B -- the Pallas kernels derive rows from the grid and
    do not pay this).
    """
    if nrhs < 1:
        raise ValueError(f"nrhs must be >= 1, got {nrhs}")
    slots = int(a.nnz)
    if isinstance(a, GSESellC) or isinstance(layout, GSESellC):
        lay = a if isinstance(a, GSESellC) else layout
        mat = lay.bytes_touched(tag)
        slots = lay.slots
        layout_name = "sell"
    elif isinstance(layout, ELLLayout):
        mat = layout.bytes_touched(tag)
        slots = layout.slots
        layout_name = "ell"
    elif layout == "ell":
        lay = ell_layout(a)
        mat = lay.bytes_touched(tag)
        slots = lay.slots
        layout_name = "ell"
    elif layout in (None, "csr"):
        if isinstance(a, CSR) or store_dtype is not None:
            dt = store_dtype or jnp.float64
            mat = a.bytes_touched(dt)
            tag = np.dtype(dt).name
        else:
            mat = a.bytes_touched(tag)
        layout_name = "csr"
    else:
        raise ValueError(f"unknown layout {layout!r}")
    if jnp_path:
        mat += int(a.nnz) * 4  # row_ids stream of the segment-sum decode
    kernel = ("spmv" if nrhs == 1 else "spmm") + "_" + layout_name
    return KernelLedger(
        kernel=kernel, tag=tag, layout=layout_name, nrhs=nrhs,
        nnz=int(a.nnz), slots=slots, flops=2 * int(a.nnz) * nrhs,
        matrix_bytes=int(mat),
        vector_bytes=nrhs * vector_stream_bytes(a, dtype=vec_dtype),
        fp64_bytes=_fp64_equiv(a) + nrhs * vector_stream_bytes(a,
                                                               vec_dtype),
    )


def _pad(x: int, b: int) -> int:
    return -(-x // b) * b


def pallas_segment_bytes(src, tag: int, blocks=DEFAULT_BLOCKS,
                         lane: int = 128) -> int:
    """EXACT packed-segment bytes a kernel launch takes as operands.

    For a ``GSECSR`` (uniform-ELL path) this is the (rows, L) pack padded
    to the (BM, BL) grid -- ``ell_pack_gsecsr`` + ``_pad2`` reproduced
    arithmetically; for a ``GSESellC`` it is the per-bucket slot sum
    (buckets are already grid-aligned; incompatible blocks raise, same as
    the dispatcher).  Cross-validated against the jaxpr operand list and
    the compiled HLO parameters in tests/test_perf.py.
    """
    bm, bl = blocks
    if isinstance(src, GSESellC):
        if src.c % bm != 0 or any(w % bl != 0 for w in src.widths):
            raise ValueError(f"blocks {blocks} incompatible with SELL pack "
                             f"(c={src.c}, widths={src.widths})")
        return src.slots * SLOT_BYTES[tag]
    per_row = np.diff(np.asarray(src.rowptr, np.int64))
    L = _pad(int(max(1, per_row.max(initial=0))), lane)
    rows = _pad(int(src.shape[0]), bm)
    return rows * _pad(L, bl) * SLOT_BYTES[tag]


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            leaves = v if isinstance(v, (list, tuple)) else (v,)
            for leaf in leaves:
                inner = getattr(leaf, "jaxpr", None)
                if inner is not None:
                    yield from _iter_eqns(inner)


def jaxpr_pallas_int_bytes(fn, *args) -> int:
    """Sum of integer-dtype operand bytes across every ``pallas_call`` in
    ``fn``'s jaxpr: exactly the packed GSE segments (colpak/head/tails),
    since x/scales are float and row indexing comes from the grid."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    total = 0
    for eqn in _iter_eqns(jaxpr.jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        for var in eqn.invars:
            aval = var.aval
            if jnp.issubdtype(aval.dtype, jnp.integer):
                total += int(np.prod(aval.shape)) * aval.dtype.itemsize
    return total


def hlo_segment_bytes(fn, *args) -> int:
    """u16/u32 entry-parameter bytes of the COMPILED lowering of ``fn`` --
    the HLO-level twin of :func:`jaxpr_pallas_int_bytes`, via
    ``launch.hlo.parameter_bytes``."""
    from repro.launch import hlo

    text = jax.jit(fn).lower(*args).compile().as_text()
    return hlo.parameter_bytes(text, dtypes={"u16", "u32"})


def achieved(ledger: KernelLedger, seconds: float, roof=None) -> dict:
    """Wall-time-derived rates for one measured kernel, ledger-priced.

    ``achieved_gbps`` divides the PHYSICAL modeled bytes by time;
    ``effective_gbps`` divides the fp64-equivalent bytes (same math) by
    time -- the fair cross-format axis.  With a ``roofline.host_roofline``
    dict, ``roofline_fraction`` = attainable-time / measured-time where
    attainable = max(bytes/BW, flops/peak): 1.0 means the kernel runs at
    the host's measured roofline, >1 signals cache residency (the smoke
    matrices fit in LLC -- documented, not clipped)."""
    out = {
        "flops": ledger.flops,
        "bytes": ledger.bytes,
        "us": seconds * 1e6,
        "achieved_gbps": ledger.bytes / seconds / 1e9,
        "achieved_gflops": ledger.flops / seconds / 1e9,
        "effective_gbps": ledger.fp64_bytes / seconds / 1e9,
    }
    if roof is not None:
        from repro.perf import roofline as _r

        out["roofline_fraction"] = _r.fraction(
            ledger.flops, ledger.bytes, seconds, roof)
    return out
