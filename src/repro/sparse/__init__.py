"""Sparse substrate: CSR/ELL containers, generators, SpMV operators."""
from repro.sparse import csr, generators, spmv
from repro.sparse.csr import CSR, GSECSR, from_coo, pack_csr, to_ell
from repro.sparse.spmv import spmv as spmv_csr
from repro.sparse.spmv import spmv_ell, spmv_gse

__all__ = [
    "csr",
    "generators",
    "spmv",
    "CSR",
    "GSECSR",
    "from_coo",
    "pack_csr",
    "to_ell",
    "spmv_csr",
    "spmv_ell",
    "spmv_gse",
]
