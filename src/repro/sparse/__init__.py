"""Sparse substrate: CSR/ELL/SELL-C-sigma containers, generators, SpMV ops."""
from repro.sparse import csr, generators, spmv
from repro.sparse.csr import (
    CSR,
    ELLLayout,
    GSECSR,
    GSESellC,
    ell_layout,
    from_coo,
    pack_csr,
    pack_sell,
    to_ell,
)
from repro.sparse.spmv import spmv as spmv_csr
from repro.sparse.spmv import spmv_ell, spmv_gse

__all__ = [
    "csr",
    "generators",
    "spmv",
    "CSR",
    "ELLLayout",
    "GSECSR",
    "GSESellC",
    "ell_layout",
    "from_coo",
    "pack_csr",
    "pack_sell",
    "to_ell",
    "spmv_csr",
    "spmv_ell",
    "spmv_gse",
]
