"""Synthetic sparse-matrix suite (SuiteSparse stand-in; DESIGN.md section 7).

The container has no network access, so the paper's 312 SuiteSparse
matrices are replaced by generators that reproduce the *roles* of the
paper's test sets:

  CG set (Table II left):  symmetric positive definite -- Poisson stencils,
      mass-like diagonal matrices, random SPD with controlled conditioning.
  GMRES set (Table II right): asymmetric -- convection-diffusion, circuit
      -like power-law, randomly perturbed stencils.

Value distributions are drawn with clustered exponents so Fig-1 statistics
(top-8 exponent coverage ~90%) hold on the synthetic suite too.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.sparse.csr import CSR, from_coo

__all__ = [
    "poisson2d",
    "poisson3d",
    "convection_diffusion_2d",
    "random_spd",
    "circuit_like",
    "skewed_spd",
    "diag_rescale",
    "ill_conditioned_spd",
    "mass_diagonal",
    "cg_suite",
    "gmres_suite",
    "spmv_suite",
]


def poisson2d(n: int) -> CSR:
    """5-point Laplacian on an n x n grid (SPD, like af_shell/thermal2 role)."""
    N = n * n
    idx = np.arange(N).reshape(n, n)
    rows, cols, vals = [], [], []

    def add(r, c, v):
        rows.append(r.ravel())
        cols.append(c.ravel())
        vals.append(np.full(r.size, v))

    add(idx, idx, 4.0)
    add(idx[1:, :], idx[:-1, :], -1.0)
    add(idx[:-1, :], idx[1:, :], -1.0)
    add(idx[:, 1:], idx[:, :-1], -1.0)
    add(idx[:, :-1], idx[:, 1:], -1.0)
    return from_coo(
        np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), (N, N)
    )


def poisson3d(n: int) -> CSR:
    """7-point Laplacian on an n^3 grid (SPD, bone010/Queen role)."""
    N = n ** 3
    idx = np.arange(N).reshape(n, n, n)
    rows, cols, vals = [], [], []

    def add(r, c, v):
        rows.append(r.ravel())
        cols.append(c.ravel())
        vals.append(np.full(r.size, v))

    add(idx, idx, 6.0)
    for axis in range(3):
        sl_lo = [slice(None)] * 3
        sl_hi = [slice(None)] * 3
        sl_lo[axis] = slice(1, None)
        sl_hi[axis] = slice(None, -1)
        add(idx[tuple(sl_lo)], idx[tuple(sl_hi)], -1.0)
        add(idx[tuple(sl_hi)], idx[tuple(sl_lo)], -1.0)
    return from_coo(
        np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), (N, N)
    )


def convection_diffusion_2d(n: int, beta: float = 20.0) -> CSR:
    """Upwind convection-diffusion (asymmetric; GMRES wang3/epb2 role)."""
    N = n * n
    h = 1.0 / (n + 1)
    idx = np.arange(N).reshape(n, n)
    rows, cols, vals = [], [], []

    def add(r, c, v):
        rows.append(np.asarray(r).ravel())
        cols.append(np.asarray(c).ravel())
        vals.append(np.broadcast_to(v, np.asarray(r).ravel().shape).copy())

    add(idx, idx, 4.0 + beta * h)
    add(idx[1:, :], idx[:-1, :], -(1.0 + beta * h))  # upwind
    add(idx[:-1, :], idx[1:, :], -1.0)
    add(idx[:, 1:], idx[:, :-1], -(1.0 + 0.5 * beta * h))
    add(idx[:, :-1], idx[:, 1:], -1.0)
    return from_coo(
        np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), (N, N)
    )


def random_spd(n: int, nnz_per_row: int = 8, cond_decades: float = 3.0,
               seed: int = 0) -> CSR:
    """Random SPD: A = B + B^T + shift*I with clustered-exponent values."""
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n), nnz_per_row)
    cols = rng.integers(0, n, size=n * nnz_per_row)
    # Clustered exponents: magnitudes 2^U with U from a few discrete bins.
    bins = rng.choice([-2, -1, 0, 1], size=n * nnz_per_row, p=[0.1, 0.2, 0.5, 0.2])
    vals = rng.uniform(1.0, 2.0, n * nnz_per_row) * np.exp2(bins)
    vals *= rng.choice([-1.0, 1.0], size=vals.shape)
    # Symmetrize + diagonal dominance (guarantees SPD).
    r = np.concatenate([rows, cols, np.arange(n)])
    c = np.concatenate([cols, rows, np.arange(n)])
    shift = 4.0 * nnz_per_row * np.exp2(1)
    diag = np.full(n, shift) * np.exp2(
        rng.uniform(0, cond_decades, n)  # spread the diagonal exponents
    )
    v = np.concatenate([vals, vals, diag])
    return from_coo(r, c, v, (n, n))


def circuit_like(n: int, seed: int = 0) -> CSR:
    """Power-law degree, wildly varying conductances (adder_dcop role)."""
    rng = np.random.default_rng(seed)
    deg = np.minimum((rng.pareto(1.5, n) + 1).astype(np.int64) * 2, 64)
    rows = np.repeat(np.arange(n), deg)
    cols = rng.integers(0, n, size=deg.sum())
    expo = rng.choice([-6, -3, 0, 0, 0, 3], size=deg.sum())
    vals = rng.uniform(1.0, 2.0, deg.sum()) * np.exp2(expo)
    vals *= rng.choice([-1.0, 1.0], size=vals.shape)
    r = np.concatenate([rows, np.arange(n)])
    c = np.concatenate([cols, np.arange(n)])
    v = np.concatenate([vals, np.full(n, 70.0)])  # dominant diagonal
    return from_coo(r, c, v, (n, n))


def skewed_spd(n: int = 2048, dense_rows: int = 4, base_halfwidth: int = 58,
               tail_scale: float = 3.0, seed: int = 0) -> CSR:
    """SPD with power-law row-length skew and a few DENSE rows -- the
    uniform-ELL worst case the SELL-C-σ layout exists for (DESIGN.md §12).

    Construction (R-MAT-flavored heavy hitters on a banded base):

      * a symmetric PERIODIC band whose per-row halfwidth is
        ``base_halfwidth`` plus a truncated Pareto tail -- entry
        ``(i, (i+j) mod n)`` exists iff ``j <= min(h_i, h_{(i+j) mod n})``
        (the min rule keeps the pattern symmetric without rescans; the
        wrap keeps boundary rows full-width);
      * ``dense_rows`` hub rows/columns touching EVERY column (the
        heavy-hitter tail of a power-law degree distribution);
      * clustered-exponent values + a diagonally dominant diagonal
        (strict dominance -> SPD).

    The base halfwidth keeps typical rows just under one 128-lane tile,
    so both layouts pay the same lane-quantization padding and the
    benchmark isolates the SKEW cost: uniform ELL pads every row to the
    dense rows' width (padding_ratio ~0.94 at the defaults) while
    SELL-C-σ quarantines the hubs in their own wide slice
    (padding_ratio < 0.1) -- the ``run.py --quick`` CI gate asserts the
    gap and that tag-1 modeled bytes stay within 10% of 6 B/nnz.
    """
    rng = np.random.default_rng(seed)
    tail = np.minimum((rng.pareto(1.8, n) * tail_scale).astype(np.int64),
                      n // 2)
    h = np.minimum(base_halfwidth + tail, (n - 1) // 2)
    # Periodic-band entries (positive offsets) under the min rule,
    # vectorized; the transpose below supplies the negative offsets.
    rows = np.repeat(np.arange(n), h)
    offs = np.arange(h.sum()) - np.repeat(np.cumsum(h) - h, h) + 1
    cols = (rows + offs) % n
    keep = offs <= h[cols]
    rows, cols = rows[keep], cols[keep]
    # Dense hub rows (heavy hitters); off-diagonal only.
    hubs = rng.choice(n, size=dense_rows, replace=False)
    hr = np.repeat(hubs, n)
    hc = np.tile(np.arange(n), dense_rows)
    keep = hr != hc
    rows = np.concatenate([rows, hr[keep]])
    cols = np.concatenate([cols, hc[keep]])
    # Clustered-exponent values (Fig-1 statistics hold here too).
    bins = rng.choice([-2, -1, 0, 1], size=rows.size, p=[0.1, 0.2, 0.5, 0.2])
    vals = rng.uniform(1.0, 2.0, rows.size) * np.exp2(bins)
    vals *= rng.choice([-1.0, 1.0], size=vals.shape)
    r = np.concatenate([rows, cols])
    c = np.concatenate([cols, rows])
    v = np.concatenate([vals, vals])
    # Strictly dominant diagonal -> SPD.  Band/hub duplicates are summed
    # by from_coo; add.at counts them twice, which only strengthens the
    # dominance bound.
    abssum = np.zeros(n)
    np.add.at(abssum, r, np.abs(v))
    diag = 2.0 * abssum + 1.0
    r = np.concatenate([r, np.arange(n)])
    c = np.concatenate([c, np.arange(n)])
    v = np.concatenate([v, diag])
    return from_coo(r, c, v, (n, n))


def diag_rescale(a: CSR, decades: float = 6.0, seed: int = 0) -> CSR:
    """Symmetric diagonal rescale D A D, D = 2^U(-d/2, d/2).

    Spreads per-row/col exponents over ~``decades`` binades -- mirrors the
    *unequilibrated* matrices in SuiteSparse where the shared-exponent
    count k visibly controls the GSE-SEM truncation error (paper Fig 4b).
    SPD is preserved (congruence transform).
    """
    rng = np.random.default_rng(seed)
    n = a.shape[0]
    d = np.exp2(rng.uniform(-decades / 2, decades / 2, n))
    rows = np.asarray(a.row_ids)
    cols = np.asarray(a.col)
    vals = np.asarray(a.val) * d[rows] * d[cols]
    return from_coo(rows, cols, vals, a.shape)


def ill_conditioned_spd(n: int = 32, decades: float = 14.0, seed: int = 0) -> CSR:
    """SPD with condition number >= 1e6: 2-D Poisson congruence-rescaled.

    ``D A D`` with ``D = diag(2^U)``, ``U ~ Uniform(-decades/2, decades/2)``:
    SPD is preserved (congruence) and the Rayleigh bounds
    ``lambda_max >= max_i (DAD)_ii``, ``lambda_min <= min_i (DAD)_ii`` give
    ``cond >= (D_max/D_min)^2 ~ 2^(2*decades)`` realized spread -- ``>= 1e6``
    for ``decades >= 10`` with wide margin at the default 14.

    This is the workload where unpreconditioned stepped CG stalls for
    thousands of iterations but diagonal (Jacobi/SPAI-0) preconditioning
    undoes ``D`` exactly, restoring the stencil's conditioning -- the
    target case for the GSE-packed preconditioners (DESIGN.md §10).
    """
    return diag_rescale(poisson2d(n), decades, seed)


def mass_diagonal(n: int, seed: int = 0) -> CSR:
    """Diagonal mass matrix (bcsstm24 role)."""
    rng = np.random.default_rng(seed)
    vals = rng.uniform(0.5, 4.0, n)
    i = np.arange(n)
    return from_coo(i, i, vals, (n, n))


def cg_suite(small: bool = True) -> Dict[str, CSR]:
    """SPD suite mirroring Table II (left).  small=True keeps CI fast.

    The ``*_rs*`` members are diag-rescaled (unequilibrated, like most
    SuiteSparse matrices): exponents spread over many binades, which is
    where FP16 overflows ('/' rows in paper Table IV) and BF16's 8-bit
    significand stalls, while GSE-SEM's adaptive shared exponents cover
    the range.
    """
    s = 1 if small else 4
    return {
        "mass_diag_3k": mass_diagonal(3562 // s, seed=1),
        "poisson2d_32": poisson2d(32 * s),
        "poisson2d_64": poisson2d(64 * s),
        "poisson3d_12": poisson3d(12 * s),
        "random_spd_5k": random_spd(5000 // s, seed=2),
        "random_spd_wide_2k": random_spd(2000 // s, cond_decades=6.0, seed=3),
        "spd_rs8_2k": diag_rescale(random_spd(2000 // s, seed=21), 8.0, 21),
        "spd_overflow_2k": diag_rescale(
            random_spd(2000 // s, cond_decades=2.0, seed=22), 24.0, 22),
        "circuit_spd_4k": None,  # filled below (symmetrized circuit)
    }


def gmres_suite(small: bool = True) -> Dict[str, CSR]:
    """Asymmetric suite mirroring Table II (right)."""
    s = 1 if small else 4
    return {
        "convdiff_32": convection_diffusion_2d(32 * s),
        "convdiff_48_b50": convection_diffusion_2d(48 * s, beta=50.0),
        "circuit_2k": circuit_like(1813 if small else 8000, seed=4),
        "circuit_5k": circuit_like(4960 if small else 20000, seed=5),
        "convdiff_64": convection_diffusion_2d(64 * s, beta=5.0),
        "convdiff_rs4_32": diag_rescale(
            convection_diffusion_2d(32 * s, beta=5.0), 4.0, 23),
        "circuit_rs12_2k": diag_rescale(
            circuit_like(2000 // s, seed=24), 24.0, 24),
    }


def _symmetrize(a: CSR) -> CSR:
    import numpy as np

    rp = np.asarray(a.rowptr)
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    rows = np.asarray(a.row_ids)
    r = np.concatenate([rows, col])
    c = np.concatenate([col, rows])
    v = np.concatenate([val, val]) * 0.5
    return from_coo(r, c, v, a.shape)


def spmv_suite(small: bool = True) -> Dict[str, CSR]:
    """Matrices for the SpMV-level experiments (Figs 4-6 role)."""
    cg = cg_suite(small)
    cg["circuit_spd_4k"] = _symmetrize(circuit_like(4000 if small else 16000, 6))
    out = dict(cg)
    out.update(gmres_suite(small))
    return out
