"""SpMV operators (paper Section III.C.2): FP64/FP32/BF16/FP16 + 3 GSE-SEM tags.

All variants follow the paper's compute discipline: values are *stored* at
the target precision but multiply-accumulate happens at high precision
(f64 on CPU; f32 or two-float on TPU -- ``acc_dtype``).

The jnp implementations use ``segment_sum`` over precomputed row ids, which
XLA lowers to a scatter-add; the Pallas blocked-ELL kernel
(``repro.kernels.gse_spmv``) is the TPU-tiled version of the same math.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import gse
from repro.sparse.csr import CSR, GSECSR, GSESellC

__all__ = ["spmv", "spmv_gse", "spmv_ell", "spmm", "spmm_gse",
           "decode_gsecsr", "decode_operand"]


@partial(jax.jit, static_argnames=("store_dtype", "acc_dtype", "num_rows"))
def _spmv_cast(row_ids, col, val, x, store_dtype, acc_dtype, num_rows):
    v = val.astype(store_dtype).astype(acc_dtype)  # storage round-trip
    prod = v * x.astype(acc_dtype)[col]
    return jax.ops.segment_sum(prod, row_ids, num_segments=num_rows)


def spmv(a: CSR, x: jnp.ndarray, store_dtype=jnp.float64, acc_dtype=jnp.float64):
    """y = A @ x with values stored at ``store_dtype`` (paper's baselines)."""
    return _spmv_cast(
        a.row_ids, a.col, a.val, x, store_dtype, acc_dtype, a.shape[0]
    )


@partial(jax.jit, static_argnames=("ei_bit", "tag", "acc_dtype", "num_rows"))
def _decode_gsecsr(colpak, head, tail1, tail2, table, ei_bit, tag, acc_dtype,
                   num_rows=None):
    """Decode GSE-SEM CSR values to ``acc_dtype`` (15-bit-head layout)."""
    shift = 32 - ei_bit
    exp_idx = (colpak >> shift).astype(jnp.int32)
    h = head.astype(jnp.uint32)
    sign = (h >> 15) & 0x1
    m_head = h & 0x7FFF  # all 15 bits are mantissa (expIdx is in colpak)
    if tag == 1:
        mant = m_head.astype(acc_dtype)
        bits_used = 15
    elif tag == 2:
        mant = m_head.astype(acc_dtype) * jnp.asarray(65536.0, acc_dtype) + (
            tail1.astype(acc_dtype)
        )
        bits_used = 31
    else:
        mant = (
            m_head.astype(acc_dtype) * jnp.asarray(2.0**48, acc_dtype)
            + tail1.astype(acc_dtype) * jnp.asarray(2.0**32, acc_dtype)
            + tail2.astype(acc_dtype)
        )
        bits_used = 63
    e_sh = table[exp_idx].astype(jnp.int32) - 1023
    pow_ = e_sh - bits_used
    half = pow_ // 2
    sgn = 1.0 - 2.0 * sign.astype(acc_dtype)
    val = sgn * (
        (mant * gse._pow2_exact(half, acc_dtype))
        * gse._pow2_exact(pow_ - half, acc_dtype)
    )
    return val, (colpak & ((1 << shift) - 1)).astype(jnp.int32)


def decode_gsecsr(a: GSECSR, tag: int, acc_dtype=jnp.float64):
    """(values, columns) decoded from a GSE-SEM CSR at precision ``tag``."""
    return _decode_gsecsr(
        a.colpak, a.head, a.tail1, a.tail2, a.table, a.ei_bit, tag, acc_dtype
    )


def _sell_csr_segments(a: GSESellC):
    """CSR-order (colpak, head, tail1, tail2) gathered out of the packed
    SELL-C-σ bucket arrays.

    The packed layout IS the value store: ``gather`` addresses every real
    entry inside the flattened width-buckets, so the recovered segments
    are bit-for-bit the ``GSECSR`` arrays and everything downstream of
    this gather (decode, segment reduction, solver iterations) is exactly
    the CSR reference arithmetic (DESIGN.md §12).
    """
    def take(parts):
        return jnp.concatenate([p.reshape(-1) for p in parts])[a.gather]

    return take(a.colpak), take(a.head), take(a.tail1), take(a.tail2)


def decode_operand(a, tag: int, acc_dtype=jnp.float64):
    """CSR-order ``(values, columns)`` decode of a ``GSECSR`` OR a packed
    ``GSESellC`` at precision ``tag`` -- the one dispatch point the fused
    solver steps and the reference SpMV/SpMM share, so every solver path
    rides whichever layout the caller packed, bit-identically."""
    if isinstance(a, GSESellC):
        cp, hd, t1, t2 = _sell_csr_segments(a)
        return _decode_gsecsr(cp, hd, t1, t2, a.table, a.ei_bit, tag,
                              acc_dtype)
    return _decode_gsecsr(
        a.colpak, a.head, a.tail1, a.tail2, a.table, a.ei_bit, tag, acc_dtype
    )


@partial(jax.jit, static_argnames=("tag", "acc_dtype", "num_rows", "ei_bit"))
def _spmv_gse(colpak, head, tail1, tail2, table, row_ids, x, ei_bit, tag,
              acc_dtype, num_rows):
    val, col = _decode_gsecsr(
        colpak, head, tail1, tail2, table, ei_bit, tag, acc_dtype
    )
    prod = val * x.astype(acc_dtype)[col]
    return jax.ops.segment_sum(prod, row_ids, num_segments=num_rows)


@partial(jax.jit, static_argnames=("tag", "acc_dtype"))
def _spmv_gse_sell(a: GSESellC, x, tag, acc_dtype):
    val, col = decode_operand(a, tag, acc_dtype)
    prod = val * x.astype(acc_dtype)[col]
    return jax.ops.segment_sum(prod, a.row_ids, num_segments=a.shape[0])


def spmv_gse(a, x: jnp.ndarray, tag: int = 1, acc_dtype=jnp.float64):
    """Paper Algorithm 2 (+tails): GSE-SEM SpMV at precision ``tag`` 1/2/3.

    ``a`` is a ``GSECSR`` or a SELL-C-σ packed ``GSESellC``; the two are
    bit-identical here (the SELL path gathers the SAME segment bits back
    to CSR order before the shared decode + segment reduction), they
    differ only in what the kernels stream and what the byte model
    charges (``a.bytes_touched(tag)``: nnz-only for ``GSECSR``, actual
    padded slots for ``GSESellC``; DESIGN.md §12).

    Bytes touched for the value stream: 2/4/8 per nnz for tags 1/2/3 plus
    4 per nnz of packed colidx -- vs 8+4 for FP64 CSR.  The TPU-tiled
    equivalents (``kernels/ops.gse_spmv_ell`` / ``gse_spmv_sell``)
    dispatch to tag-specialized Pallas kernels that provably stream only
    those segments (DESIGN.md §2.4).  Inside CG prefer passing the
    operand straight to ``solvers.solve_cg`` -- the fused iteration path
    decodes the values once per step and folds the vector ops around this
    SpMV (DESIGN.md §4).
    """
    if isinstance(a, GSESellC):
        return _spmv_gse_sell(a, x, tag, acc_dtype)
    return _spmv_gse(
        a.colpak, a.head, a.tail1, a.tail2, a.table, a.row_ids, x,
        a.ei_bit, tag, acc_dtype, a.shape[0]
    )


@partial(jax.jit, static_argnames=("acc_dtype",))
def spmv_ell(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray,
             acc_dtype=jnp.float64):
    """Padded-ELL SpMV: dense (rows, L) tiles -- the TPU-shaped reference."""
    prod = vals.astype(acc_dtype) * x.astype(acc_dtype)[cols]
    return jnp.sum(prod, axis=1)


@partial(jax.jit, static_argnames=("store_dtype", "acc_dtype", "num_rows"))
def _spmm_cast(row_ids, col, val, x, store_dtype, acc_dtype, num_rows):
    v = val.astype(store_dtype).astype(acc_dtype)  # storage round-trip
    prod = v[:, None] * x.astype(acc_dtype)[col]   # (nnz, nrhs)
    return jax.ops.segment_sum(prod, row_ids, num_segments=num_rows)


def spmm(a: CSR, x: jnp.ndarray, store_dtype=jnp.float64,
         acc_dtype=jnp.float64):
    """Y = A @ X for a dense (n, nrhs) right-hand-side block.

    Multi-RHS twin of :func:`spmv` (fixed-format baselines): the value and
    colidx streams are read ONCE and amortized across all ``nrhs`` columns
    -- the memory-bound win the batched solvers build on (DESIGN.md §11).
    Column ``j`` of the result is numerically the column-by-column
    ``spmv(a, x[:, j])`` (same gather, same segment reduction order).
    """
    if x.ndim != 2:
        raise ValueError(f"spmm wants a (n, nrhs) block; got {x.shape}")
    return _spmm_cast(
        a.row_ids, a.col, a.val, x, store_dtype, acc_dtype, a.shape[0]
    )


@partial(jax.jit, static_argnames=("ei_bit", "tag", "acc_dtype", "num_rows"))
def _spmm_gse(colpak, head, tail1, tail2, table, row_ids, x, ei_bit, tag,
              acc_dtype, num_rows):
    val, col = _decode_gsecsr(
        colpak, head, tail1, tail2, table, ei_bit, tag, acc_dtype
    )
    prod = val[:, None] * x.astype(acc_dtype)[col]  # decode once, nrhs uses
    return jax.ops.segment_sum(prod, row_ids, num_segments=num_rows)


@partial(jax.jit, static_argnames=("tag", "acc_dtype"))
def _spmm_gse_sell(a: GSESellC, x, tag, acc_dtype):
    val, col = decode_operand(a, tag, acc_dtype)
    prod = val[:, None] * x.astype(acc_dtype)[col]  # decode once, nrhs uses
    return jax.ops.segment_sum(prod, a.row_ids, num_segments=a.shape[0])


def spmm_gse(a, x: jnp.ndarray, tag: int = 1, acc_dtype=jnp.float64):
    """GSE-SEM SpMM at precision ``tag``: Y = A @ X, X dense (n, nrhs).

    ``a`` is a ``GSECSR`` or a SELL-C-σ packed ``GSESellC`` (bit-identical
    results; the layouts differ only in streamed bytes -- DESIGN.md §12).
    One decoded-value pass feeds every column, so the modeled matrix
    traffic is ``a.bytes_touched(tag)`` ONCE per call however many
    right-hand sides ride along -- ``csr.iteration_stream_bytes(...,
    nrhs=nrhs)`` is the per-iteration account (DESIGN.md §11).  The
    TPU-tiled equivalents (``kernels/ops.gse_spmm_ell`` /
    ``gse_spmm_sell``) dispatch to tag-specialized Pallas kernels that
    provably stream only the segments ``tag`` reads, exactly like the
    SpMV pipeline.
    """
    if x.ndim != 2:
        raise ValueError(f"spmm_gse wants a (n, nrhs) block; got {x.shape}")
    if isinstance(a, GSESellC):
        return _spmm_gse_sell(a, x, tag, acc_dtype)
    return _spmm_gse(
        a.colpak, a.head, a.tail1, a.tail2, a.table, a.row_ids, x,
        a.ei_bit, tag, acc_dtype, a.shape[0]
    )
