"""Sparse matrix containers: CSR, GSE-SEM CSR, and TPU-friendly blocked-ELL.

Paper Section III.C.1: shared-exponent *indices* are encoded into the top
``EI_BIT`` bits of the 32-bit CSR column indices (the largest SuiteSparse
column count needs only 28 bits), so the SEM head keeps all 15 non-sign
bits for mantissa... except the head must still carry the index for the
dense-tensor path; for the CSR path we free those bits.  We keep both
layouts:

  * ``GSECSR``   -- expIdx packed in ``col``; head's EI field is repurposed
                    as extra mantissa bits (M_H + EI_BIT usable bits).
  * ``GSEPacked``-- self-describing dense tensors (quant / LM path).

TPU adaptation: ``to_ell`` pads rows to a lane-aligned width so SpMV maps
onto dense (rows x lanes) tiles (DESIGN.md section 2).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gse, precision_table
from repro.core.tagmap import TagMap

__all__ = [
    "CSR",
    "GSECSR",
    "GSESellC",
    "ELLLayout",
    "from_coo",
    "pack_csr",
    "to_ell",
    "scatter_rows",
    "sell_slices",
    "pack_sell",
    "ell_layout",
    "iteration_stream_bytes",
    "vector_stream_bytes",
]

# Matrix-stream bytes one padded slot (or one nnz) costs at each GSE tag:
# 2/4/8 value-segment bytes + 4 packed-colidx bytes (DESIGN.md §8).
# Canonical table lives in core/precision_table.py; this is the historical
# alias other modules import.
_SLOT_BYTES = precision_table.SLOT_BYTES


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CSR:
    rowptr: jnp.ndarray  # (m+1,) int32
    col: jnp.ndarray     # (nnz,) int32
    val: jnp.ndarray     # (nnz,) float
    row_ids: jnp.ndarray  # (nnz,) int32 -- precomputed for segment_sum
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.col.shape[0]

    def bytes_per_nnz(self, store_dtype=jnp.float64) -> int:
        """Modeled bytes streamed per nonzero by one SpMV: value + colidx."""
        return jnp.dtype(store_dtype).itemsize + 4

    def bytes_touched(self, store_dtype=jnp.float64) -> int:
        """Modeled HBM bytes one SpMV touches in the matrix streams.

        Value + colidx per nnz plus the rowptr stream; the dense x/y vector
        traffic is format-independent and excluded so formats compare on
        what the encoding actually changes.
        """
        return self.nnz * self.bytes_per_nnz(store_dtype) + self.rowptr.size * 4

    def tree_flatten(self):
        return (self.rowptr, self.col, self.val, self.row_ids), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, shape=aux[0])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GSECSR:
    """CSR with GSE-SEM values; expIdx lives in the top bits of ``col``."""

    rowptr: jnp.ndarray   # (m+1,) int32
    colpak: jnp.ndarray   # (nnz,) uint32: [expIdx : EI_BIT][col : 32-EI_BIT]
    head: jnp.ndarray     # (nnz,) uint16: sign(1) | mantissa(15)
    tail1: jnp.ndarray    # (nnz,) uint16
    tail2: jnp.ndarray    # (nnz,) uint32
    table: jnp.ndarray    # (k,) int32 biased+1
    row_ids: jnp.ndarray  # (nnz,) int32
    ei_bit: int
    shape: Tuple[int, int]

    @property
    def m_h(self) -> int:
        # col carries the index -> the head spends only the sign bit.
        return 15

    @property
    def width(self) -> int:
        return self.m_h + 48

    @property
    def nnz(self) -> int:
        return self.colpak.shape[0]

    def nbytes(self, tag: int) -> int:
        n = self.colpak.shape[0]
        per = precision_table.TAG_VALUE_BYTES[tag]
        return n * per + self.table.size * 4

    def bytes_per_nnz(self, tag: int) -> int:
        """Modeled bytes streamed per nonzero by a tag-``tag`` SpMV.

        Only the segments the tag reads count (the tag-specialized kernels
        provably omit the rest): 2/4/8 value bytes + 4 packed-colidx bytes
        -> 6/8/12 for tags 1/2/3, vs 12 for FP64 CSR.
        """
        pt = precision_table
        return pt.TAG_VALUE_BYTES[tag] + pt.COLIDX_BYTES

    def bytes_touched(self, tag: int, layout=None) -> int:
        """Modeled HBM bytes one tag-``tag`` SpMV touches in the matrix
        streams.  Dense x/y traffic is format-independent and excluded.

        ``layout=None`` is the nnz-only mode (per-nnz segments + rowptr +
        the shared-exponent table) used by the format-comparison figures:
        it charges what the *encoding* costs, independent of how rows are
        padded onto tiles.  Passing a packed layout (``GSESellC`` or
        ``ELLLayout``) charges the ACTUAL padded slots that layout streams
        -- ``layout.bytes_touched(tag)`` -- so skewed matrices stop
        under-reporting traffic (DESIGN.md §12).

        ``tag`` may be a per-group :class:`~repro.core.tagmap.TagMap`
        (DESIGN.md §18): the nnz-only mode then charges EACH entry at its
        symmetric induced tag (max of row/column group tags -- what the
        masked operand actually streams) -- the blended byte model the
        adaptive schedule is gated on.  A uniform map reproduces the
        scalar figure exactly.
        """
        if layout is not None:
            return layout.bytes_touched(tag)
        fixed = self.rowptr.size * 4 + self.table.size * 4
        if isinstance(tag, TagMap):
            cols = (np.asarray(self.colpak, np.uint32)
                    & np.uint32((1 << (32 - self.ei_bit)) - 1))
            et = tag.entry_tags(np.asarray(self.row_ids), cols)
            counts = np.bincount(et, minlength=4)
            return fixed + int(sum(
                int(counts[t]) * self.bytes_per_nnz(t) for t in (1, 2, 3)
            ))
        return self.nnz * self.bytes_per_nnz(tag) + fixed

    def tree_flatten(self):
        return (
            self.rowptr, self.colpak, self.head, self.tail1, self.tail2,
            self.table, self.row_ids,
        ), (self.ei_bit, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, ei_bit=aux[0], shape=aux[1])


@dataclasses.dataclass(frozen=True)
class ELLLayout:
    """Padding descriptor of the uniform blocked-ELL pack (DESIGN.md §12).

    Uniform ELL pads EVERY row to the longest row's lane-aligned width, so
    one dense row on a skewed matrix multiplies the streamed slots for the
    whole matrix.  This descriptor makes that cost explicit:
    ``bytes_touched(tag)`` charges every padded slot the kernels actually
    stream (value segment + packed colidx per slot, plus the shared-
    exponent table); ``padding_ratio`` is the wasted fraction.
    """

    rows: int           # padded row count the kernel grid covers
    width: int          # lane-aligned uniform row width L
    nnz: int            # real stored entries
    table_entries: int  # shared-exponent table length

    @property
    def slots(self) -> int:
        return self.rows * self.width

    @property
    def padding_ratio(self) -> float:
        """Fraction of streamed slots that are padding, in [0, 1)."""
        return 1.0 - self.nnz / max(self.slots, 1)

    def bytes_touched(self, tag) -> int:
        """``tag`` may be a :class:`~repro.core.tagmap.TagMap`: each row's
        padded slots are then charged at the ROW's group tag (the default
        group size equals the kernels' 8-row grid block, so a per-row-
        block operand choice is physically realizable -- DESIGN.md §18).
        This is the idealized row-side model: entries promoted only via
        their COLUMN's group (symmetric induced tags) are charged at the
        row tag, so it lower-bounds the blended nnz model slightly.
        A uniform map reproduces the scalar figure exactly."""
        if isinstance(tag, TagMap):
            rt = tag.row_tags(self.rows)
            per = np.array([0] + [_SLOT_BYTES[t] for t in (1, 2, 3)],
                           np.int64)
            return (int(per[rt].sum()) * self.width
                    + self.table_entries * 4)
        return self.slots * _SLOT_BYTES[tag] + self.table_entries * 4


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GSESellC:
    """Sliced-ELL (SELL-C-σ) view of a :class:`GSECSR` (DESIGN.md §12).

    Rows are sorted by descending length inside windows of ``sigma`` rows
    (σ-window sort -- the permutation is recoverable and locality-bounded),
    grouped into slices of ``c`` rows, and each slice is padded only to its
    OWN lane-aligned width instead of the global maximum.  Slices are then
    binned by width into a handful of power-of-two width-buckets; each
    bucket stores its slices' segment arrays as one dense
    ``(slices*c, width)`` block, so the SpMV/SpMM kernels run one
    ``pallas_call`` per bucket with exactly the tag-specialized operand
    list of the uniform-ELL kernels.

    Leaves (per width-bucket tuples + flat metadata):

      * ``colpak/head/tail1/tail2`` -- tuples of ``(rows_b, w_b)`` segment
        arrays, one entry per width-bucket (ascending widths);
      * ``gather``  -- (nnz,) flat index of every CSR-order entry inside the
        concatenation of the row-major bucket arrays (the packed store IS
        the value store: the reference/solver paths decode through this
        gather, bit-identical to the CSR decode);
      * ``perm``    -- (rows_padded,) original row id of each concatenated
        bucket row (-1 for slice-padding rows);
      * ``unperm``  -- (m,) position of each original row in that
        concatenation (``perm[unperm[i]] == i``);
      * ``row_ids`` -- (nnz,) CSR-order row ids (segment reduction);
      * ``table``   -- shared-exponent table.

    Static: per-bucket ``widths``, ``c``, ``sigma``, ``lane``, ``ei_bit``,
    ``shape``.  The byte model charges ACTUAL padded slots
    (``bytes_touched``); ``padding_ratio`` reports the wasted fraction.
    """

    colpak: tuple   # per-bucket (rows_b, w_b) uint32
    head: tuple     # per-bucket (rows_b, w_b) uint16
    tail1: tuple    # per-bucket (rows_b, w_b) uint16
    tail2: tuple    # per-bucket (rows_b, w_b) uint32
    gather: jnp.ndarray   # (nnz,) int32
    perm: jnp.ndarray     # (rows_padded,) int32, -1 for padding rows
    unperm: jnp.ndarray   # (m,) int32
    row_ids: jnp.ndarray  # (nnz,) int32
    table: jnp.ndarray    # (k,) int32 biased+1
    widths: Tuple[int, ...]
    c: int
    sigma: int
    lane: int
    ei_bit: int
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.gather.shape[0]

    @property
    def n_buckets(self) -> int:
        return len(self.widths)

    @property
    def bucket_rows(self) -> Tuple[int, ...]:
        return tuple(cp.shape[0] for cp in self.colpak)

    @property
    def slots(self) -> int:
        """Padded slots actually stored/streamed, across all buckets."""
        return sum(r * w for r, w in zip(self.bucket_rows, self.widths))

    @property
    def padding_ratio(self) -> float:
        """Fraction of streamed slots that are padding, in [0, 1)."""
        return 1.0 - self.nnz / max(self.slots, 1)

    def bytes_per_nnz(self, tag: int) -> float:
        """EFFECTIVE bytes streamed per nonzero: padded slots amortized
        over the real entries (the honest twin of
        ``GSECSR.bytes_per_nnz``, which charges nnz only)."""
        return _SLOT_BYTES[tag] * self.slots / max(self.nnz, 1)

    def bucket_tags(self, tm: "TagMap") -> Tuple[int, ...]:
        """Per-width-bucket max INDUCED entry tag (max of row/column group
        tags over the bucket's real entries) -- the coarse unit the SELL
        kernels dispatch a per-group map at (DESIGN.md §18).  A bucket
        with no real entries charges tag 1."""
        cp_flat = np.concatenate(
            [np.asarray(cp, np.uint32).reshape(-1) for cp in self.colpak]
        ) if self.colpak else np.zeros(0, np.uint32)
        gather = np.asarray(self.gather, np.int64)
        cols = cp_flat[gather] & np.uint32((1 << (32 - self.ei_bit)) - 1)
        et = tm.entry_tags(np.asarray(self.row_ids), cols)
        sizes = np.array([cp.size for cp in self.colpak], np.int64)
        offs = np.concatenate([[0], np.cumsum(sizes)])
        bidx = np.searchsorted(offs, gather, side="right") - 1
        tags = np.ones(len(self.colpak), np.int64)
        np.maximum.at(tags, bidx, et.astype(np.int64))
        return tuple(int(t) for t in tags)

    def bytes_touched(self, tag) -> int:
        """Modeled HBM bytes one tag-``tag`` SpMV streams through this
        layout: every padded slot's value segment + packed colidx, the
        output row permutation, and the shared-exponent table.

        ``tag`` may be a :class:`~repro.core.tagmap.TagMap`: each width-
        bucket's slots are then charged at the bucket's MAX group tag --
        exactly what the per-bucket kernel dispatch streams (an all-tag-1
        bucket never touches tails), so this blended figure is the
        PHYSICAL model, not an optimistic nnz blend (DESIGN.md §18)."""
        fixed = self.perm.shape[0] * 4 + self.table.size * 4
        if isinstance(tag, TagMap):
            return fixed + int(sum(
                r * w * _SLOT_BYTES[t]
                for r, w, t in zip(self.bucket_rows, self.widths,
                                   self.bucket_tags(tag))
            ))
        return self.slots * _SLOT_BYTES[tag] + fixed

    def tree_flatten(self):
        leaves = (
            self.colpak, self.head, self.tail1, self.tail2,
            self.gather, self.perm, self.unperm, self.row_ids, self.table,
        )
        aux = (self.widths, self.c, self.sigma, self.lane, self.ei_bit,
               self.shape)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)


def from_coo(rows, cols, vals, shape) -> CSR:
    """Build CSR from COO triplets (duplicates summed), no scipy."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals, np.float64)
    m, n = shape
    key = rows * n + cols
    order = np.argsort(key, kind="stable")
    key, rows, cols, vals = key[order], rows[order], cols[order], vals[order]
    # Sum duplicates.
    uniq, idx = np.unique(key, return_index=True)
    sums = np.add.reduceat(vals, idx)
    rows = rows[idx]
    cols = cols[idx]
    rowptr = np.zeros(m + 1, np.int64)
    np.add.at(rowptr, rows + 1, 1)
    rowptr = np.cumsum(rowptr)
    return CSR(
        rowptr=jnp.asarray(rowptr, jnp.int32),
        col=jnp.asarray(cols, jnp.int32),
        val=jnp.asarray(sums),
        row_ids=jnp.asarray(rows, jnp.int32),
        shape=(int(m), int(n)),
    )


def pack_csr(a: CSR, k: int = 8) -> GSECSR:
    """CSR -> GSE-SEM CSR (paper Algorithm 1 + Section III.C.1).

    The head's 15 non-sign bits are ALL mantissa: with expIdx in colpak the
    head-only precision gains ``EI_BIT`` bits over the dense-tensor layout
    (a paper-faithful benefit of the colidx trick).
    """
    vals = np.asarray(a.val, np.float64)
    table = gse.extract_shared_exponents(vals, k)
    ei = gse._ei_bit(k)
    # Pack with EI_BIT=0-equivalent layout: emulate by calling the core
    # packer with a custom head split. We reuse the generic machinery by
    # packing with k but then re-deriving a 15-bit head from (tag3) M.
    p = gse.pack_with_table(vals, table, k)
    # Recover full-width mantissa M (width = (15-ei)+48) and expIdx:
    head = np.asarray(p.head).astype(np.uint64)
    m_h_dense = 15 - ei
    sign = (head >> np.uint64(15)) & np.uint64(1)
    exp_idx = (head >> np.uint64(m_h_dense)) & np.uint64((1 << ei) - 1)
    m_dense = (
        ((head & np.uint64((1 << m_h_dense) - 1)) << np.uint64(48))
        | (np.asarray(p.tail1).astype(np.uint64) << np.uint64(32))
        | np.asarray(p.tail2).astype(np.uint64)
    )  # width m_h_dense + 48
    # Widen to 15 + 48 = 63 bits: shift left by ei.
    m_wide = m_dense << np.uint64(ei)
    w = 15 + 48
    new_head = ((sign << np.uint64(15)) | (m_wide >> np.uint64(48))).astype(np.uint16)
    new_tail1 = ((m_wide >> np.uint64(32)) & np.uint64(0xFFFF)).astype(np.uint16)
    new_tail2 = (m_wide & np.uint64(0xFFFFFFFF)).astype(np.uint32)

    col = np.asarray(a.col).astype(np.uint32)
    shift = np.uint32(32 - ei)
    max_col = int(col.max()) if col.size else 0
    if max_col >= (1 << (32 - ei)):
        raise ValueError(
            f"column count {max_col} needs > {32 - ei} bits; "
            "use the value-array encoding variant (paper III.C.1)"
        )
    colpak = (exp_idx.astype(np.uint32) << shift) | col
    return GSECSR(
        rowptr=a.rowptr,
        colpak=jnp.asarray(colpak),
        head=jnp.asarray(new_head),
        tail1=jnp.asarray(new_tail1),
        tail2=jnp.asarray(new_tail2),
        table=jnp.asarray(table, jnp.int32),
        row_ids=a.row_ids,
        ei_bit=ei,
        shape=a.shape,
    )


def vector_stream_bytes(op, dtype=jnp.float64) -> int:
    """Modeled HBM bytes ONE dense operand/result column streams: the x
    gather read plus the y write of a single SpMV/SpMM column at
    ``dtype`` (the solver vectors' precision, f64 by default)."""
    m, n = op.shape
    return (m + n) * jnp.dtype(dtype).itemsize


def iteration_stream_bytes(op, tag, precond=None, nrhs: int = 1,
                           layout=None) -> int:
    """Modeled HBM bytes ONE stepped solver iteration streams at ``tag``.

    Sums the operator's matrix streams (``op.bytes_touched``) with the
    preconditioner's stored streams at the SAME tag: in the
    preconditioned stepped solvers both reads follow the monitor's
    schedule, so a tag-1 iteration pays 2 B per stored preconditioner
    entry, not 8 (DESIGN.md §10).  Without a preconditioner ``tag`` may
    also be a ``CSR`` store dtype; charging a preconditioner requires a
    GSE tag in {1, 2, 3} (the preconditioner is always GSE-packed).

    ``nrhs`` is the number of ACTIVE right-hand-side columns the batched
    SpMM iteration feeds (DESIGN.md §11): the matrix (+preconditioner)
    segments are charged ONCE per iteration -- one streaming pass over
    the packed bytes serves every column -- while each column beyond the
    first charges its own dense x/y stream (``vector_stream_bytes``).
    The first column's vector traffic stays excluded exactly as before
    (it is format-independent and cancels in format comparisons), so
    ``nrhs=1`` reproduces the single-RHS figure identically.

    ``layout`` selects the padding-honest account (DESIGN.md §12): a
    ``GSESellC`` or ``ELLLayout`` charges the operator's ACTUAL padded
    slots instead of nnz only.  Passing a ``GSESellC`` as ``op`` itself is
    equivalent -- its ``bytes_touched`` is already slot-honest.  The
    default (``layout=None``) keeps the nnz-only mode the format-
    comparison figures use, unchanged.
    """
    if nrhs < 1:
        raise ValueError(f"nrhs must be >= 1, got {nrhs}")
    if layout is not None:
        total = layout.bytes_touched(tag)
    else:
        total = op.bytes_touched(tag)
    if precond is not None:
        # A per-group TagMap charges the preconditioner at the map's MAX
        # tag: the stepped preconditioners follow one scalar schedule, so
        # this is the conservative (never-optimistic) account.
        ptag = tag.max_tag if isinstance(tag, TagMap) else tag
        if ptag not in (1, 2, 3):
            raise ValueError(
                f"preconditioner streams need a GSE tag in {{1, 2, 3}}, "
                f"got {tag!r}"
            )
        total += precond.bytes_touched(ptag)
    total += (nrhs - 1) * vector_stream_bytes(op)
    return total


def scatter_rows(rowptr, sources, width: int, row_subset=None):
    """Scatter CSR-ordered entry streams into zero-padded (rows, width)
    arrays -- the ONE owner of the row-scatter (``to_ell``,
    ``ops.ell_pack_gsecsr`` and the SELL-C-σ bucket packer all call this;
    they used to carry drifting copies).

    ``sources`` is a sequence of ``(array, dtype)`` pairs sharing the CSR
    entry order; each comes back as its own padded array at the requested
    dtype (padding slots are zero).  ``row_subset`` selects AND orders the
    rows to scatter (a SELL bucket's permuted slice rows); ``-1`` entries
    are empty padding rows.  Default: all rows in natural order.

    Returns ``(outs, csr_pos, dest)`` where ``csr_pos`` are the CSR entry
    indices scattered (in scatter order) and ``dest`` their flat slots in
    the padded array -- packed layouts record these to recover entries
    without a rescan.
    """
    rowptr = np.asarray(rowptr, np.int64)
    per_row = np.diff(rowptr)
    if row_subset is None:
        row_subset = np.arange(per_row.size)
    row_subset = np.asarray(row_subset, np.int64)
    valid = row_subset >= 0
    safe = np.where(valid, row_subset, 0)
    lens = np.where(valid, per_row[safe], 0)
    if lens.size and int(lens.max(initial=0)) > width:
        raise ValueError(
            f"row of {int(lens.max())} entries does not fit width {width}"
        )
    total = int(lens.sum())
    starts = np.where(valid, rowptr[safe], 0)
    # Slot-within-row for every scattered entry, vectorized over rows.
    offs = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(lens) - lens, lens
    )
    csr_pos = np.repeat(starts, lens) + offs
    dest = np.repeat(np.arange(row_subset.size, dtype=np.int64) * width,
                     lens) + offs
    outs = []
    for src, dtype in sources:
        out = np.zeros(row_subset.size * width, dtype)
        out[dest] = np.asarray(src)[csr_pos]
        outs.append(out.reshape(row_subset.size, width))
    return outs, csr_pos, dest


def to_ell(a: CSR, lane: int = 128) -> Tuple[np.ndarray, np.ndarray, int]:
    """CSR -> padded ELL (cols[m, L], vals[m, L]); L rounded up to ``lane``.

    Padded entries have col=0, val=0 (contribute nothing).  Returns
    (cols, vals, L).  TPU kernels want lane-aligned dense tiles.
    """
    rowptr = np.asarray(a.rowptr, np.int64)
    L = int(max(1, np.diff(rowptr).max(initial=0)))
    L = ((L + lane - 1) // lane) * lane
    (cols, vals), _, _ = scatter_rows(
        rowptr, [(a.col, np.int32), (a.val, np.float64)], L
    )
    return cols, vals, L


def ell_layout(a, lane: int = 128) -> ELLLayout:
    """Padding descriptor of the uniform-ELL pack of ``a`` (a ``GSECSR``
    or ``CSR``): every row padded to the longest row's lane-aligned width.
    ``ell_layout(g).padding_ratio`` vs ``pack_sell(g).padding_ratio`` is
    the skew cost the SELL-C-σ layout removes (DESIGN.md §12)."""
    per_row = np.diff(np.asarray(a.rowptr, np.int64))
    L = int(max(1, per_row.max(initial=0)))
    L = ((L + lane - 1) // lane) * lane
    table = getattr(a, "table", None)
    return ELLLayout(
        rows=a.shape[0], width=L, nnz=a.nnz,
        table_entries=int(table.size) if table is not None else 0,
    )


def sell_slices(rowptr, c: int = 8, sigma: int | None = None,
                lane: int = 128, bucket: str = "pow2"):
    """σ-window sort + slice/bucket plan (host-side static metadata).

    Rows are sorted by DESCENDING length inside windows of ``sigma`` rows
    (stable, so equal-length rows keep their order and the permutation
    stays window-local); consecutive runs of ``c`` sorted rows form
    slices.  Each slice's width is its longest row rounded up to ``lane``;
    slices are binned into power-of-two multiples of ``lane`` so a
    pathological width spread still dispatches a handful of kernel calls.

    Returns ``(order, slice_bucket_w, sigma)``: the padded row
    permutation (length ``ceil(m/c)*c``, ``-1`` marks padding rows),
    each slice's bucket width, and the EFFECTIVE window size actually
    sorted with (``None`` -> full sort, floor ``c``) -- the one value
    callers should record.
    """
    per_row = np.diff(np.asarray(rowptr, np.int64))
    m = per_row.size
    if c < 1:
        raise ValueError(f"slice height c must be >= 1, got {c}")
    sigma = m if sigma is None else max(int(sigma), c)
    order = np.arange(m, dtype=np.int64)
    for w0 in range(0, m, sigma):
        win = order[w0:w0 + sigma]
        order[w0:w0 + sigma] = win[
            np.argsort(-per_row[win], kind="stable")
        ]
    rows_pad = -(-max(m, 1) // c) * c
    order = np.concatenate(
        [order, np.full(rows_pad - m, -1, np.int64)]
    )
    lens = np.where(order >= 0, per_row[np.clip(order, 0, None)], 0)
    slice_max = lens.reshape(-1, c).max(axis=1)
    slice_w = np.maximum(-(-slice_max // lane) * lane, lane).astype(np.int64)
    # Width-bucket granularity (plan-tunable, DESIGN.md §15): "pow2" bins
    # slice widths into power-of-two lane multiples -- bounded bucket count
    # however the widths spread, at worst <2x extra padding inside a
    # bucket; "exact" keeps every distinct lane-aligned width -- zero
    # bucket padding at the cost of one kernel call per distinct width.
    if bucket == "pow2":
        bucket_w = lane * (
            2 ** np.ceil(np.log2(slice_w / lane)).astype(np.int64)
        )
    elif bucket == "exact":
        bucket_w = slice_w
    else:
        raise ValueError(
            f"bucket must be 'pow2' or 'exact', got {bucket!r}")
    return order, bucket_w, sigma


def pack_sell(a: GSECSR, c: int = 8, sigma: int | None = None,
              lane: int = 128, bucket: str = "pow2") -> GSESellC:
    """GSE-SEM CSR -> SELL-C-σ packed layout (DESIGN.md §12).

    ``c`` must divide into the kernels' sublane block (a multiple of 8) so
    every width-bucket's row count tiles the (8, 128) grid exactly.
    Prefer :func:`repro.kernels.ops.sell_pack_gsecsr`, which memoizes the
    pack on the operator instance (solvers repack nothing per call).
    """
    if c % 8 != 0:
        raise ValueError(f"slice height c must be a multiple of 8, got {c}")
    m = a.shape[0]
    order, bucket_w, sigma_eff = sell_slices(a.rowptr, c=c, sigma=sigma,
                                             lane=lane, bucket=bucket)
    widths = tuple(int(w) for w in sorted(set(bucket_w.tolist())))
    segs = [
        (a.colpak, np.uint32),
        (a.head, np.uint16),
        (a.tail1, np.uint16),
        (a.tail2, np.uint32),
    ]
    gather = np.zeros(a.nnz, np.int64)
    perm_parts, flat_off = [], 0
    outs = {w: None for w in widths}
    for w in widths:
        slice_ids = np.nonzero(bucket_w == w)[0]
        rows_sel = np.concatenate(
            [order[s * c:(s + 1) * c] for s in slice_ids]
        ) if slice_ids.size else np.zeros(0, np.int64)
        arrs, csr_pos, dest = scatter_rows(a.rowptr, segs, int(w), rows_sel)
        outs[w] = arrs
        gather[csr_pos] = flat_off + dest
        perm_parts.append(rows_sel)
        flat_off += rows_sel.size * int(w)
    perm = (np.concatenate(perm_parts) if perm_parts
            else np.zeros(0, np.int64))
    unperm = np.zeros(m, np.int64)
    unperm[perm[perm >= 0]] = np.nonzero(perm >= 0)[0]
    return GSESellC(
        colpak=tuple(jnp.asarray(outs[w][0]) for w in widths),
        head=tuple(jnp.asarray(outs[w][1]) for w in widths),
        tail1=tuple(jnp.asarray(outs[w][2]) for w in widths),
        tail2=tuple(jnp.asarray(outs[w][3]) for w in widths),
        gather=jnp.asarray(gather, jnp.int32),
        perm=jnp.asarray(perm, jnp.int32),
        unperm=jnp.asarray(unperm, jnp.int32),
        row_ids=a.row_ids,
        table=a.table,
        widths=widths,
        c=c,
        sigma=int(sigma_eff),
        lane=lane,
        ei_bit=a.ei_bit,
        shape=a.shape,
    )
