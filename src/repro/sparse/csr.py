"""Sparse matrix containers: CSR, GSE-SEM CSR, and TPU-friendly blocked-ELL.

Paper Section III.C.1: shared-exponent *indices* are encoded into the top
``EI_BIT`` bits of the 32-bit CSR column indices (the largest SuiteSparse
column count needs only 28 bits), so the SEM head keeps all 15 non-sign
bits for mantissa... except the head must still carry the index for the
dense-tensor path; for the CSR path we free those bits.  We keep both
layouts:

  * ``GSECSR``   -- expIdx packed in ``col``; head's EI field is repurposed
                    as extra mantissa bits (M_H + EI_BIT usable bits).
  * ``GSEPacked``-- self-describing dense tensors (quant / LM path).

TPU adaptation: ``to_ell`` pads rows to a lane-aligned width so SpMV maps
onto dense (rows x lanes) tiles (DESIGN.md section 2).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gse

__all__ = [
    "CSR",
    "GSECSR",
    "from_coo",
    "pack_csr",
    "to_ell",
    "iteration_stream_bytes",
    "vector_stream_bytes",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CSR:
    rowptr: jnp.ndarray  # (m+1,) int32
    col: jnp.ndarray     # (nnz,) int32
    val: jnp.ndarray     # (nnz,) float
    row_ids: jnp.ndarray  # (nnz,) int32 -- precomputed for segment_sum
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.col.shape[0]

    def bytes_per_nnz(self, store_dtype=jnp.float64) -> int:
        """Modeled bytes streamed per nonzero by one SpMV: value + colidx."""
        return jnp.dtype(store_dtype).itemsize + 4

    def bytes_touched(self, store_dtype=jnp.float64) -> int:
        """Modeled HBM bytes one SpMV touches in the matrix streams.

        Value + colidx per nnz plus the rowptr stream; the dense x/y vector
        traffic is format-independent and excluded so formats compare on
        what the encoding actually changes.
        """
        return self.nnz * self.bytes_per_nnz(store_dtype) + self.rowptr.size * 4

    def tree_flatten(self):
        return (self.rowptr, self.col, self.val, self.row_ids), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, shape=aux[0])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GSECSR:
    """CSR with GSE-SEM values; expIdx lives in the top bits of ``col``."""

    rowptr: jnp.ndarray   # (m+1,) int32
    colpak: jnp.ndarray   # (nnz,) uint32: [expIdx : EI_BIT][col : 32-EI_BIT]
    head: jnp.ndarray     # (nnz,) uint16: sign(1) | mantissa(15)
    tail1: jnp.ndarray    # (nnz,) uint16
    tail2: jnp.ndarray    # (nnz,) uint32
    table: jnp.ndarray    # (k,) int32 biased+1
    row_ids: jnp.ndarray  # (nnz,) int32
    ei_bit: int
    shape: Tuple[int, int]

    @property
    def m_h(self) -> int:
        # col carries the index -> the head spends only the sign bit.
        return 15

    @property
    def width(self) -> int:
        return self.m_h + 48

    @property
    def nnz(self) -> int:
        return self.colpak.shape[0]

    def nbytes(self, tag: int) -> int:
        n = self.colpak.shape[0]
        per = {1: 2, 2: 4, 3: 8}[tag]
        return n * per + self.table.size * 4

    def bytes_per_nnz(self, tag: int) -> int:
        """Modeled bytes streamed per nonzero by a tag-``tag`` SpMV.

        Only the segments the tag reads count (the tag-specialized kernels
        provably omit the rest): 2/4/8 value bytes + 4 packed-colidx bytes
        -> 6/8/12 for tags 1/2/3, vs 12 for FP64 CSR.
        """
        return {1: 2, 2: 4, 3: 8}[tag] + 4

    def bytes_touched(self, tag: int) -> int:
        """Modeled HBM bytes one tag-``tag`` SpMV touches in the matrix
        streams: per-nnz segments + rowptr + the shared-exponent table.
        Dense x/y traffic is format-independent and excluded."""
        return (
            self.nnz * self.bytes_per_nnz(tag)
            + self.rowptr.size * 4
            + self.table.size * 4
        )

    def tree_flatten(self):
        return (
            self.rowptr, self.colpak, self.head, self.tail1, self.tail2,
            self.table, self.row_ids,
        ), (self.ei_bit, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, ei_bit=aux[0], shape=aux[1])


def from_coo(rows, cols, vals, shape) -> CSR:
    """Build CSR from COO triplets (duplicates summed), no scipy."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals, np.float64)
    m, n = shape
    key = rows * n + cols
    order = np.argsort(key, kind="stable")
    key, rows, cols, vals = key[order], rows[order], cols[order], vals[order]
    # Sum duplicates.
    uniq, idx = np.unique(key, return_index=True)
    sums = np.add.reduceat(vals, idx)
    rows = rows[idx]
    cols = cols[idx]
    rowptr = np.zeros(m + 1, np.int64)
    np.add.at(rowptr, rows + 1, 1)
    rowptr = np.cumsum(rowptr)
    return CSR(
        rowptr=jnp.asarray(rowptr, jnp.int32),
        col=jnp.asarray(cols, jnp.int32),
        val=jnp.asarray(sums),
        row_ids=jnp.asarray(rows, jnp.int32),
        shape=(int(m), int(n)),
    )


def pack_csr(a: CSR, k: int = 8) -> GSECSR:
    """CSR -> GSE-SEM CSR (paper Algorithm 1 + Section III.C.1).

    The head's 15 non-sign bits are ALL mantissa: with expIdx in colpak the
    head-only precision gains ``EI_BIT`` bits over the dense-tensor layout
    (a paper-faithful benefit of the colidx trick).
    """
    vals = np.asarray(a.val, np.float64)
    table = gse.extract_shared_exponents(vals, k)
    ei = gse._ei_bit(k)
    # Pack with EI_BIT=0-equivalent layout: emulate by calling the core
    # packer with a custom head split. We reuse the generic machinery by
    # packing with k but then re-deriving a 15-bit head from (tag3) M.
    p = gse.pack_with_table(vals, table, k)
    # Recover full-width mantissa M (width = (15-ei)+48) and expIdx:
    head = np.asarray(p.head).astype(np.uint64)
    m_h_dense = 15 - ei
    sign = (head >> np.uint64(15)) & np.uint64(1)
    exp_idx = (head >> np.uint64(m_h_dense)) & np.uint64((1 << ei) - 1)
    m_dense = (
        ((head & np.uint64((1 << m_h_dense) - 1)) << np.uint64(48))
        | (np.asarray(p.tail1).astype(np.uint64) << np.uint64(32))
        | np.asarray(p.tail2).astype(np.uint64)
    )  # width m_h_dense + 48
    # Widen to 15 + 48 = 63 bits: shift left by ei.
    m_wide = m_dense << np.uint64(ei)
    w = 15 + 48
    new_head = ((sign << np.uint64(15)) | (m_wide >> np.uint64(48))).astype(np.uint16)
    new_tail1 = ((m_wide >> np.uint64(32)) & np.uint64(0xFFFF)).astype(np.uint16)
    new_tail2 = (m_wide & np.uint64(0xFFFFFFFF)).astype(np.uint32)

    col = np.asarray(a.col).astype(np.uint32)
    shift = np.uint32(32 - ei)
    max_col = int(col.max()) if col.size else 0
    if max_col >= (1 << (32 - ei)):
        raise ValueError(
            f"column count {max_col} needs > {32 - ei} bits; "
            "use the value-array encoding variant (paper III.C.1)"
        )
    colpak = (exp_idx.astype(np.uint32) << shift) | col
    return GSECSR(
        rowptr=a.rowptr,
        colpak=jnp.asarray(colpak),
        head=jnp.asarray(new_head),
        tail1=jnp.asarray(new_tail1),
        tail2=jnp.asarray(new_tail2),
        table=jnp.asarray(table, jnp.int32),
        row_ids=a.row_ids,
        ei_bit=ei,
        shape=a.shape,
    )


def vector_stream_bytes(op, dtype=jnp.float64) -> int:
    """Modeled HBM bytes ONE dense operand/result column streams: the x
    gather read plus the y write of a single SpMV/SpMM column at
    ``dtype`` (the solver vectors' precision, f64 by default)."""
    m, n = op.shape
    return (m + n) * jnp.dtype(dtype).itemsize


def iteration_stream_bytes(op, tag, precond=None, nrhs: int = 1) -> int:
    """Modeled HBM bytes ONE stepped solver iteration streams at ``tag``.

    Sums the operator's matrix streams (``op.bytes_touched``) with the
    preconditioner's stored streams at the SAME tag: in the
    preconditioned stepped solvers both reads follow the monitor's
    schedule, so a tag-1 iteration pays 2 B per stored preconditioner
    entry, not 8 (DESIGN.md §10).  Without a preconditioner ``tag`` may
    also be a ``CSR`` store dtype; charging a preconditioner requires a
    GSE tag in {1, 2, 3} (the preconditioner is always GSE-packed).

    ``nrhs`` is the number of ACTIVE right-hand-side columns the batched
    SpMM iteration feeds (DESIGN.md §11): the matrix (+preconditioner)
    segments are charged ONCE per iteration -- one streaming pass over
    the packed bytes serves every column -- while each column beyond the
    first charges its own dense x/y stream (``vector_stream_bytes``).
    The first column's vector traffic stays excluded exactly as before
    (it is format-independent and cancels in format comparisons), so
    ``nrhs=1`` reproduces the single-RHS figure identically.
    """
    if nrhs < 1:
        raise ValueError(f"nrhs must be >= 1, got {nrhs}")
    total = op.bytes_touched(tag)
    if precond is not None:
        if tag not in (1, 2, 3):
            raise ValueError(
                f"preconditioner streams need a GSE tag in {{1, 2, 3}}, "
                f"got {tag!r}"
            )
        total += precond.bytes_touched(tag)
    total += (nrhs - 1) * vector_stream_bytes(op)
    return total


def to_ell(a: CSR, lane: int = 128) -> Tuple[np.ndarray, np.ndarray, int]:
    """CSR -> padded ELL (cols[m, L], vals[m, L]); L rounded up to ``lane``.

    Padded entries have col=0, val=0 (contribute nothing).  Returns
    (cols, vals, L).  TPU kernels want lane-aligned dense tiles.
    """
    rowptr = np.asarray(a.rowptr, np.int64)
    col = np.asarray(a.col, np.int64)
    val = np.asarray(a.val, np.float64)
    m = a.shape[0]
    per_row = np.diff(rowptr)
    L = int(max(1, per_row.max()))
    L = ((L + lane - 1) // lane) * lane
    cols = np.zeros((m, L), np.int32)
    vals = np.zeros((m, L), np.float64)
    # Scatter each row's entries into its padded slots.
    idx_in_row = np.arange(col.shape[0]) - np.repeat(rowptr[:-1], per_row)
    rows = np.repeat(np.arange(m), per_row)
    cols[rows, idx_in_row] = col
    vals[rows, idx_in_row] = val
    return cols, vals, L
