"""Logical-axis sharding rules (MaxText-style) -> PartitionSpecs.

Tensors declare *logical* axes (("embed","mlp"), ("batch","seq",...)); a
per-arch rule table maps logical names to mesh axes.  Swapping a sharding
strategy = swapping one dict -- this is the primary perf-hillclimb lever
(DESIGN.md §5).

Default rules target the production mesh (pod, data, model):
  * weights: FSDP over ``data`` on the embed dim, TP over ``model`` on
    heads / mlp / vocab / experts;
  * activations: batch over (pod, data), model-parallel dims over model.

``shard()`` inserts with_sharding_constraint only inside an active rules
context (so single-device tests and benchmarks never touch meshes).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec

_STATE = threading.local()


DEFAULT_RULES: Dict[str, Any] = {
    # -- weight dims --
    "embed": "data",          # FSDP shard
    "embed_out": "model",
    "vocab": "model",
    "qkv": "model",           # fused attention projections (H*hd)
    "capacity": ("pod", "data"),  # MoE dispatch buffer token slots
    "mlp": "model",
    "expert_mlp": None,       # per-expert ff usually small; EP carries it
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "experts": "model",       # EP
    "experts_router": None,
    "layers": None,           # scan-stacked dim never sharded
    "lru": "model",
    "lru_in": None,
    "conv_w": None,
    "lora": None,
    "rwkv_heads": "model",
    # -- activation dims --
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "act_mlp": "model",
    "act_vocab": "model",
    "kv": None,
}


def _mesh_axes() -> Tuple[str, ...]:
    m = getattr(_STATE, "mesh", None)
    return tuple(m.axis_names) if m is not None else ()


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Any], mesh=None):
    """Activate a logical->mesh rule table (and optionally pin the mesh)."""
    prev_r = getattr(_STATE, "rules", None)
    prev_m = getattr(_STATE, "mesh", None)
    _STATE.rules = rules
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.rules = prev_r
        _STATE.mesh = prev_m


def active_rules() -> Optional[Dict[str, Any]]:
    return getattr(_STATE, "rules", None)


def logical_to_pspec(axes: Tuple[Optional[str], ...],
                     rules: Optional[Dict[str, Any]] = None) -> PartitionSpec:
    """Map a tuple of logical axis names to a PartitionSpec.

    A mesh axis may appear at most once in the result: later logical axes
    that resolve to an already-used mesh axis fall back to replication
    (standard MaxText conflict rule).
    """
    rules = rules if rules is not None else (active_rules() or DEFAULT_RULES)
    mesh_axes = _mesh_axes()
    used = set()
    out = []
    for name in axes:
        r = rules.get(name) if name is not None else None
        if r is None:
            out.append(None)
            continue
        cand = (r,) if isinstance(r, str) else tuple(r)
        # Keep only axes that exist on the current mesh (if known) and are
        # not yet used by an earlier dim.
        keep = tuple(
            a for a in cand
            if a not in used and (not mesh_axes or a in mesh_axes)
        )
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(keep)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def specs_to_pspecs(spec_tree, rules=None):
    """Map a tree of logical-axis tuples to a tree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: logical_to_pspec(axes, rules),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, str) or a is None for a in x),
    )


def shard(x, *axes):
    """Constrain ``x`` to the PartitionSpec its logical ``axes`` resolve to.

    No-op when no rules context is active (single-device tests, benches).
    """
    rules = active_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_to_pspec(axes, rules))
