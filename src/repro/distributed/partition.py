"""Row-sharded partitioning of a GSE-SEM CSR operator (DESIGN.md §13).

The paper's lever is bandwidth: a tag-1 iteration streams 6 B/nnz instead
of 12.  On one device PRs 1-4 exhausted that lever; the next one is to
split the byte stream across devices.  ``partition_gsecsr`` cuts a
:class:`~repro.sparse.csr.GSECSR` into ``n_shards`` contiguous row blocks:

  * each shard keeps its row slice of the packed segment streams
    (``colpak/head/tail1/tail2``), padded to the max per-shard nnz so the
    shards stack into ``(n_shards, E)`` device arrays for ``shard_map``;
  * column indices are REMAPPED to index the shard's local x window
    ``concat(x_shard, x_halo)`` -- columns owned by the shard index the
    local block directly, remote columns go through a compact halo map;
  * the halo map is the classic boundary/halo split: shard ``i`` packs the
    x entries that ANY other shard reads into a ``(B,)`` boundary buffer
    (``bnd_idx``), the buffers are ``all_gather``-ed into a ``(s*B,)``
    pool, and ``halo_idx`` gathers each shard's remote entries out of the
    pool.  Only boundary entries cross the wire -- never the full vector.

Tag-aware wire format (the GSE segmentation applied to the interconnect,
cf. Loe et al., arXiv:2109.01232 -- communication, not flops, dominates
mixed-precision Krylov on accelerators): with ``wire="gse"`` the boundary
buffer is packed through the GSE head/tail segments at the iteration's
precision tag, so a tag-1 halo exchange ships 2-byte heads (plus the
per-shard shared-exponent table), tag 2 ships head+tail1 (4 B), and tag 3
ships exact IEEE float64 (8 B -- the segmented 63-bit mantissa costs the
same bytes but loses dynamic range, so full precision rides raw bits).
``wire="exact"`` ships float64 at every tag: zero perturbation, used for
the bit/trajectory-parity contracts.

Byte model (mirrors ``csr.iteration_stream_bytes`` exactly):

  ``shard_stream_bytes(tag)[i] = nnz_i * bytes_per_nnz(tag) + rows_i * 4``
  ``shared_stream_bytes()     = 4 + table_entries * 4``

and the identity ``sum(shard_stream_bytes(tag)) + shared_stream_bytes()
== iteration_stream_bytes(gsecsr, tag)`` holds EXACTLY (asserted in
tests/test_distributed.py): sharding redistributes the single-device
matrix stream, it does not change it -- what it ADDS is the halo wire
traffic, ``halo_wire_bytes(tag, wire)``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import precision_table
from repro.core.tagmap import TagMap, normalize_tags
from repro.sparse.csr import (
    _SLOT_BYTES,
    GSECSR,
    iteration_stream_bytes,
    vector_stream_bytes,
)

__all__ = [
    "PartitionedGSECSR",
    "partition_gsecsr",
    "unshard",
    "WIRE_ENTRY_BYTES",
]

# Bytes ONE boundary x-entry costs on the wire at each tag (DESIGN.md §13):
# tag 1 ships the u16 GSE head, tag 2 head+tail1, tag 3 raw float64.
# Canonical table lives in core/precision_table.py.
WIRE_ENTRY_BYTES = precision_table.WIRE_ENTRY_BYTES


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PartitionedGSECSR:
    """Row-sharded view of a ``GSECSR``: stacked per-shard blocks + halo map.

    All per-shard arrays carry a leading ``n_shards`` axis and are padded
    to uniform extents (max nnz ``E``, max boundary ``B``, max halo ``H``)
    so ``shard_map`` can split them along the mesh axis.  Padding matrix
    entries decode to +0.0 and scatter into a dummy row (``row_ids == R``),
    so they perturb nothing; padded boundary slots (``bnd_idx == -1``) are
    masked to zero before the wire pack, and padded halo slots are never
    read by real matrix entries.
    """

    # -- stacked per-shard matrix blocks (leading dim n_shards) ------------
    colpak: jnp.ndarray    # (s, E) uint32: [expIdx][LOCAL col in x_shard++halo]
    head: jnp.ndarray      # (s, E) uint16
    tail1: jnp.ndarray     # (s, E) uint16
    tail2: jnp.ndarray     # (s, E) uint32
    row_ids: jnp.ndarray   # (s, E) int32 LOCAL row ids; padding -> R (dummy)
    # -- halo exchange plan ------------------------------------------------
    bnd_idx: jnp.ndarray   # (s, B) int32 local x indices this shard sends
    #                        (-1 marks padded slots: masked to 0 on the wire)
    halo_idx: jnp.ndarray  # (s, H) int32 positions in the (s*B,) gathered pool
    # -- shared -----------------------------------------------------------
    table: jnp.ndarray     # (k,) int32 shared-exponent table (replicated)
    # -- static metadata ---------------------------------------------------
    ei_bit: int
    shape: Tuple[int, int]
    n_shards: int
    rows_per_shard: int              # R: padded uniform row-block height
    nnz_per_shard: Tuple[int, ...]   # real (unpadded) nnz of each shard
    rows_real: Tuple[int, ...]       # real rows owned by each shard
    bnd_counts: Tuple[int, ...]      # real boundary entries each shard sends
    halo_counts: Tuple[int, ...]     # real halo entries each shard gathers

    # -- sizes -------------------------------------------------------------

    @property
    def nnz(self) -> int:
        return int(sum(self.nnz_per_shard))

    @property
    def n_padded(self) -> int:
        """Global padded row count ``n_shards * rows_per_shard``."""
        return self.n_shards * self.rows_per_shard

    @property
    def bnd_width(self) -> int:
        """Padded per-shard boundary-buffer width B (the all_gather slot
        count each shard broadcasts)."""
        return int(self.bnd_idx.shape[1])

    @property
    def halo_entries(self) -> int:
        """Total real remote entries gathered per SpMV, across shards."""
        return int(sum(self.halo_counts))

    # -- byte model (DESIGN.md §13) ---------------------------------------

    def bytes_per_nnz(self, tag: int) -> int:
        # The shards stream the same encoding as the unsharded container:
        # value segment + packed colidx per nnz (csr._SLOT_BYTES).
        return _SLOT_BYTES[tag]

    def _global_entries(self):
        """Per-shard (global_rows, global_cols) of the REAL entries, int64.

        Reconstructed once from the local blocks (the inverse of the
        column remap, same walk as :func:`unshard`) and memoized -- the
        per-group byte model needs global coordinates to induce entry
        tags."""
        cached = self.__dict__.get("_global_entries_memo")
        if cached is not None:
            return cached
        ei = self.ei_bit
        shift = np.uint32(32 - ei)
        r_blk = self.rows_per_shard
        s_colpak = np.asarray(self.colpak)
        s_rows = np.asarray(self.row_ids)
        halo = np.asarray(self.halo_idx)
        bnd = np.asarray(self.bnd_idx)
        out = []
        for i in range(self.n_shards):
            nz = self.nnz_per_shard[i]
            loc = (s_colpak[i, :nz]
                   & np.uint32((1 << (32 - ei)) - 1)).astype(np.int64)
            is_halo = loc >= r_blk
            pool = halo[i]
            owners = pool // max(self.bnd_width, 1)
            owner_slot = pool % max(self.bnd_width, 1)
            halo_global = (owners * r_blk + bnd[owners, owner_slot]
                           if pool.size else np.zeros(0, np.int64))
            gcol = np.where(
                is_halo,
                halo_global[np.clip(loc - r_blk, 0, None)]
                if pool.size else 0,
                loc + i * r_blk,
            )
            grow = s_rows[i, :nz].astype(np.int64) + i * r_blk
            out.append((grow, gcol))
        self.__dict__["_global_entries_memo"] = out
        return out

    def shard_stream_bytes(self, tag) -> Tuple[int, ...]:
        """Modeled HBM bytes EACH shard streams for its matrix block in one
        tag-``tag`` SpMV: real nnz at the tag's segment bytes + packed
        colidx, plus the shard's slice of the rowptr stream.  Real (not
        padded) extents are charged so the shards sum exactly to the
        single-device figure.

        ``tag`` may be a per-group :class:`~repro.core.tagmap.TagMap`:
        each entry is then charged at its SYMMETRIC induced tag (max of
        row/column group tags, global coordinates -- the same blend as
        ``GSECSR.bytes_touched(tagmap)``, so the redistribution identity
        still holds exactly)."""
        tag = normalize_tags(tag)
        if isinstance(tag, TagMap):
            per = np.array([0] + [_SLOT_BYTES[t] for t in (1, 2, 3)],
                           np.int64)
            return tuple(
                int(per[tag.entry_tags(grow, gcol)].sum()) + rr * 4
                for (grow, gcol), rr in zip(self._global_entries(),
                                            self.rows_real)
            )
        return tuple(
            nz * self.bytes_per_nnz(tag) + rr * 4
            for nz, rr in zip(self.nnz_per_shard, self.rows_real)
        )

    def shared_stream_bytes(self) -> int:
        """Once-per-iteration global terms: the rowptr terminal entry and
        the shared-exponent table (replicated on every shard but charged
        once -- it is the same single-device stream redistributed)."""
        return 4 + int(self.table.size) * 4

    def bnd_slot_tags(self, tags) -> np.ndarray:
        """(s, B) uint8 per-slot wire tags under a tag map.

        A boundary x-entry belongs to ONE row group (the row-only
        ``entry_tags`` form -- vector streams have no column partner), so
        each real slot carries its entry's group tag; padded slots
        (``bnd_idx == -1``) carry the map's MAX tag -- they ride the
        payload anyway and are charged honestly, like the SELL padding
        account.  Feed the shard's row to ``wire.halo_all_gather``'s
        ``slot_tags`` so tag-1 slots drop their tail segment on the wire.
        """
        tm = normalize_tags(tags)
        if not isinstance(tm, TagMap):
            return np.full((self.n_shards, self.bnd_width), tm, np.uint8)
        bnd = np.asarray(self.bnd_idx)
        out = np.full(bnd.shape, tm.max_tag, np.uint8)
        for i in range(self.n_shards):
            real = bnd[i] >= 0
            if real.any():
                gcol = bnd[i][real].astype(np.int64) \
                    + i * self.rows_per_shard
                out[i, real] = tm.entry_tags(gcol)
        return out

    def halo_wire_bytes(self, tag, wire: str = "exact",
                        nrhs: int = 1) -> int:
        """Modeled interconnect bytes ONE distributed SpMV/SpMM moves.

        Each shard broadcasts its padded ``B``-slot boundary buffer to the
        other ``s - 1`` shards (the all_gather payload -- padded slots are
        charged, honestly, like the SELL padding account).  With
        ``wire="gse"`` a tag-1/2 entry ships its head (+tail1) segment and
        each shard's per-iteration shared-exponent table rides along; at
        tag 3 (and for ``wire="exact"`` at every tag) entries ship raw
        float64.  ``nrhs`` columns each ship their own boundary entries
        AND (tags 1/2) their own per-shard table -- the per-column apply
        path the batched solvers run; the block ``dist_spmm`` path packs
        one table per call and is strictly cheaper than modeled.  The
        default wire matches the solvers' default (``"exact"``).
        """
        if wire not in ("exact", "gse"):
            raise ValueError(f"unknown wire mode {wire!r}; 'exact' or 'gse'")
        if self.n_shards == 1 or self.bnd_width == 0:
            return 0  # nothing remote: no collective at all
        s, b = self.n_shards, self.bnd_width
        tag = normalize_tags(tag)
        if isinstance(tag, TagMap):
            if wire == "exact":
                return (s - 1) * s * b * 8 * nrhs
            # Blended per-slot wire: each slot at its own group's entry
            # bytes; a shard's shared-exponent table rides only if ANY of
            # its slots ships a head-segmented (tag 1/2) payload.
            st = self.bnd_slot_tags(tag)
            per = np.array([0] + [WIRE_ENTRY_BYTES[t] for t in (1, 2, 3)],
                           np.int64)
            total = (s - 1) * int(per[st].sum()) * nrhs
            senders = int((st <= 2).any(axis=1).sum())
            total += (s - 1) * senders * int(self.table.size) * 4 * nrhs
            return total
        per_entry = 8 if wire == "exact" else WIRE_ENTRY_BYTES[tag]
        total = (s - 1) * s * b * per_entry * nrhs
        if wire == "gse" and tag in (1, 2):
            total += (s - 1) * s * int(self.table.size) * 4 * nrhs
        return total

    def iteration_stream_bytes(self, tag: int, wire: str = "exact",
                               nrhs: int = 1) -> int:
        """Modeled bytes one distributed stepped iteration streams: the
        exact single-device matrix stream (redistributed across shards)
        plus the halo wire traffic plus the extra columns' vector streams
        -- i.e. ``csr.iteration_stream_bytes(op, tag, nrhs=nrhs) +
        halo_wire_bytes(tag, wire, nrhs)`` (identity asserted in tests)."""
        total = sum(self.shard_stream_bytes(tag)) + self.shared_stream_bytes()
        total += (nrhs - 1) * vector_stream_bytes(self)
        return total + self.halo_wire_bytes(tag, wire, nrhs)

    # -- pytree ------------------------------------------------------------

    def tree_flatten(self):
        leaves = (self.colpak, self.head, self.tail1, self.tail2,
                  self.row_ids, self.bnd_idx, self.halo_idx, self.table)
        aux = (self.ei_bit, self.shape, self.n_shards, self.rows_per_shard,
               self.nnz_per_shard, self.rows_real, self.bnd_counts,
               self.halo_counts)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)


def partition_gsecsr(a: GSECSR, n_shards: int) -> PartitionedGSECSR:
    """Split a ``GSECSR`` into ``n_shards`` row blocks with a halo plan.

    Rows are cut into contiguous blocks of ``R = ceil(n / n_shards)``
    (trailing shards may own fewer real rows; the blocks are padded to
    ``R`` with empty rows).  Entry order inside every row is preserved, so
    each shard's local segment reduction reproduces the single-device
    per-row sums bit-for-bit -- the basis of the 1-shard bit-identity and
    k-shard trajectory contracts (tests/test_distributed.py).
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    n = a.shape[0]
    if a.shape[0] != a.shape[1]:
        raise ValueError(
            f"row sharding wants a square operator, got {a.shape}"
        )
    rowptr = np.asarray(a.rowptr, np.int64)
    colpak = np.asarray(a.colpak, np.uint32)
    head = np.asarray(a.head, np.uint16)
    tail1 = np.asarray(a.tail1, np.uint16)
    tail2 = np.asarray(a.tail2, np.uint32)
    ei = a.ei_bit
    shift = np.uint32(32 - ei)
    col = (colpak & np.uint32((1 << (32 - ei)) - 1)).astype(np.int64)
    exp_idx = (colpak >> shift).astype(np.uint32)

    r_blk = -(-n // n_shards)  # ceil
    starts = [min(i * r_blk, n) for i in range(n_shards + 1)]

    # Pass 1: per-shard remote column sets -> per-owner boundary sets.
    shard_of = lambda c: np.minimum(c // r_blk, n_shards - 1)
    remote_cols = []           # per shard: sorted unique remote global cols
    send_sets = [set() for _ in range(n_shards)]
    for i in range(n_shards):
        lo, hi = starts[i], starts[i + 1]
        cols_i = col[rowptr[lo]:rowptr[hi]]
        rem = np.unique(cols_i[(cols_i < lo) | (cols_i >= hi)])
        remote_cols.append(rem)
        for c in rem:
            send_sets[int(shard_of(c))].add(int(c))
    bnd_cols = [np.array(sorted(s), np.int64) for s in send_sets]
    bnd_counts = tuple(len(b) for b in bnd_cols)
    # B == 0 (block-diagonal operators, or 1 shard): no exchange at all --
    # the matvec skips the collective and the wire model charges nothing.
    B = max([0] + [len(b) for b in bnd_cols]) if n_shards > 1 else 0
    # Global col -> (owner, slot in owner's boundary buffer) -> pool index.
    pool_pos = {}
    for i, cols_i in enumerate(bnd_cols):
        for slot, c in enumerate(cols_i):
            pool_pos[int(c)] = i * B + slot

    # Pass 2: per-shard blocks with locally remapped columns.
    E = max(1, max(
        int(rowptr[starts[i + 1]] - rowptr[starts[i]])
        for i in range(n_shards)
    ))
    H = max([0] + [len(r) for r in remote_cols]) if n_shards > 1 else 0
    s_colpak = np.zeros((n_shards, E), np.uint32)
    s_head = np.zeros((n_shards, E), np.uint16)
    s_tail1 = np.zeros((n_shards, E), np.uint16)
    s_tail2 = np.zeros((n_shards, E), np.uint32)
    s_rows = np.full((n_shards, E), r_blk, np.int32)  # padding -> dummy row
    # Boundary padding is -1: the matvec masks those slots to ZERO before
    # the wire pack, so a shard with fewer real boundary entries than B
    # cannot leak x values into its shared-exponent table (zeros are
    # excluded from the exponent histogram entirely).
    s_bnd = np.full((n_shards, B), -1, np.int32)
    s_halo = np.zeros((n_shards, H), np.int32)
    nnz_per_shard = []
    halo_counts = []
    max_local = r_blk + (H if n_shards > 1 else 0)
    if max_local >= (1 << (32 - ei)):
        raise ValueError(
            f"local window {max_local} needs > {32 - ei} bits; "
            "reduce shard size or halo width"
        )
    for i in range(n_shards):
        lo, hi = starts[i], starts[i + 1]
        e0, e1 = int(rowptr[lo]), int(rowptr[hi])
        nz = e1 - e0
        nnz_per_shard.append(nz)
        cols_i = col[e0:e1]
        local = (cols_i >= lo) & (cols_i < hi)
        # Remote columns -> slot in this shard's halo window [R, R + h).
        rem = remote_cols[i]
        halo_counts.append(len(rem))
        loc_col = np.where(local, cols_i - lo, 0)
        if len(rem):
            rank = np.searchsorted(rem, cols_i)
            loc_col = np.where(local, loc_col, r_blk + rank)
            s_halo[i, :len(rem)] = [pool_pos[int(c)] for c in rem]
        s_colpak[i, :nz] = (exp_idx[e0:e1] << shift) | loc_col.astype(
            np.uint32)
        s_head[i, :nz] = head[e0:e1]
        s_tail1[i, :nz] = tail1[e0:e1]
        s_tail2[i, :nz] = tail2[e0:e1]
        # Local row ids (0-based within the block), preserved entry order.
        s_rows[i, :nz] = (
            np.repeat(np.arange(hi - lo), np.diff(rowptr[lo:hi + 1])).astype(
                np.int32)
        )
        if n_shards > 1 and len(bnd_cols[i]):
            s_bnd[i, :len(bnd_cols[i])] = bnd_cols[i] - lo
    return PartitionedGSECSR(
        colpak=jnp.asarray(s_colpak),
        head=jnp.asarray(s_head),
        tail1=jnp.asarray(s_tail1),
        tail2=jnp.asarray(s_tail2),
        row_ids=jnp.asarray(s_rows),
        bnd_idx=jnp.asarray(s_bnd),
        halo_idx=jnp.asarray(s_halo),
        table=a.table,
        ei_bit=ei,
        shape=a.shape,
        n_shards=n_shards,
        rows_per_shard=r_blk,
        nnz_per_shard=tuple(nnz_per_shard),
        rows_real=tuple(starts[i + 1] - starts[i] for i in range(n_shards)),
        bnd_counts=bnd_counts if n_shards > 1 else (0,),
        halo_counts=tuple(halo_counts) if n_shards > 1 else (0,),
    )


def unshard(part: PartitionedGSECSR, a_template: GSECSR) -> GSECSR:
    """Reassemble the original ``GSECSR`` segment arrays from a partition
    (round-trip check: partitioning is a pure redistribution).

    ``a_template`` supplies the global ``rowptr``/``row_ids`` (the
    partition keeps only local forms); the returned container's packed
    segments are reconstructed from the shard blocks and must be
    bit-identical to the original's (tests/test_distributed.py).
    """
    n = part.shape[0]
    ei = part.ei_bit
    shift = np.uint32(32 - ei)
    r_blk = part.rows_per_shard
    colpak_parts, head_parts, t1_parts, t2_parts = [], [], [], []
    s_colpak = np.asarray(part.colpak)
    s_head = np.asarray(part.head)
    s_t1 = np.asarray(part.tail1)
    s_t2 = np.asarray(part.tail2)
    halo = np.asarray(part.halo_idx)
    bnd = np.asarray(part.bnd_idx)
    for i in range(part.n_shards):
        nz = part.nnz_per_shard[i]
        cp = s_colpak[i, :nz]
        loc = (cp & np.uint32((1 << (32 - ei)) - 1)).astype(np.int64)
        exp_idx = cp >> shift
        lo = i * r_blk
        is_halo = loc >= r_blk
        # Halo slot -> pool position -> (owner, owner-local idx) -> global.
        pool = halo[i]
        owners = pool // max(part.bnd_width, 1)
        owner_slot = pool % max(part.bnd_width, 1)
        halo_global = owners * r_blk + bnd[owners, owner_slot]
        gcol = np.where(is_halo,
                        halo_global[np.clip(loc - r_blk, 0, None)]
                        if pool.size else 0,
                        loc + lo)
        colpak_parts.append((exp_idx << shift) | gcol.astype(np.uint32))
        head_parts.append(s_head[i, :nz])
        t1_parts.append(s_t1[i, :nz])
        t2_parts.append(s_t2[i, :nz])
    return GSECSR(
        rowptr=a_template.rowptr,
        colpak=jnp.asarray(np.concatenate(colpak_parts)),
        head=jnp.asarray(np.concatenate(head_parts)),
        tail1=jnp.asarray(np.concatenate(t1_parts)),
        tail2=jnp.asarray(np.concatenate(t2_parts)),
        table=part.table,
        row_ids=a_template.row_ids,
        ei_bit=ei,
        shape=part.shape,
    )
