"""GSE-SEM gradient compression for cross-pod all-reduce (DESIGN.md §3.3).

The paper's storage/compute decoupling applied to the wire: gradients are
packed to the 16-bit GSE-SEM head (shared-exponent table per tensor,
value-adaptive -- unlike bf16, zero bits are spent on per-element
exponents), summed, decoded, with an error-feedback buffer keeping the
optimizer asymptotically unbiased (Karimireddy et al. 2019 semantics).

Wire bytes on the pod axis: 2/elem instead of 4 (f32): the collective
roofline term for cross-pod gradient reduction halves.

Implementation note: packing/decoding is jittable (pack32_jnp); the actual
cross-pod psum stays a normal XLA all-reduce over the decoded values when
run under pjit (GSPMD inserts it).  Under shard_map the compressed u16
payload itself can be all-to-all'd; both entry points are provided.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import gse

__all__ = ["compress_decompress", "make_error_feedback_transform"]


@partial(jax.jit, static_argnames=("k", "tag"))
def compress_decompress(g: jnp.ndarray, k: int = 8, tag: int = 1):
    """Round-trip a gradient tensor through the GSE-SEM wire format.

    Returns (g_hat, err) with err = g - g_hat (for error feedback).
    """
    orig_shape = g.shape
    orig_dtype = g.dtype
    flat = g.astype(jnp.float32).reshape(-1)
    table = gse.extract_shared_exponents_jnp(flat, k)
    head, tail1 = gse.pack32_jnp(flat, table, k)
    g_hat = gse.decode32_jnp(table, head, tail1, k, tag, jnp.float32)
    g_hat = g_hat.reshape(orig_shape)
    err = g.astype(jnp.float32) - g_hat
    return g_hat.astype(orig_dtype), err.astype(orig_dtype)


def make_error_feedback_transform(k: int = 8, tag: int = 1,
                                  min_size: int = 65536) -> Tuple[Callable,
                                                                  Callable]:
    """Returns (init_buf, transform).

    transform(grads, buf) -> (compressed_grads, new_buf): adds the carried
    quantization error before compressing (error feedback), skips small
    leaves (wire savings negligible; keeps norms/bias grads exact).
    """

    def init_buf(grads):
        return jax.tree.map(jnp.zeros_like, grads)

    def transform(grads, buf):
        def one(g, e):
            if g.size < min_size:
                return g, jnp.zeros_like(g)
            g_hat, err = compress_decompress(g + e, k=k, tag=tag)
            return g_hat, err

        pairs = jax.tree.map(one, grads, buf)
        g_hat = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_buf = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return g_hat, new_buf

    return init_buf, transform
