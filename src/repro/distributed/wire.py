"""True-wire GSE-SEM compressed all-reduce (shard_map, manual collectives).

pjit/GSPMD cannot express "compress, move u16, decompress" -- the
partitioner sees only the decoded values.  With shard_map the payload that
crosses the interconnect IS the 16-bit head segment:

    per-shard grad -> pack32 (u16 head) -> all_to_all (u16 on the wire)
    -> decode -> psum_scatter-equivalent local sum -> repack -> all_gather
    (u16 on the wire) -> decode

Wire bytes: 2/elem in each phase vs 4 (f32 ring AR) -- the paper's
storage/compute decoupling applied to the interconnect, for the cross-pod
gradient reduction (DESIGN.md §3.3).  Error feedback lives one level up
(distributed.compress).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import gse

__all__ = ["compressed_psum", "halo_all_gather"]


def halo_all_gather(bnd: jnp.ndarray, axis_name: str, *, tag: int,
                    wire: str = "gse", k: int = 8) -> jnp.ndarray:
    """All-gather each shard's boundary buffer at the iteration's tag.

    Must be called INSIDE shard_map with ``axis_name`` manual.  ``bnd`` is
    this shard's packed boundary x-entries, shape ``(B,)`` or ``(B, nrhs)``
    (padded slots are zero).  Returns the gathered pool with a leading
    shard axis, ``(s, B[, nrhs])``, decoded back to ``bnd.dtype``.

    This is the halo-exchange twin of :func:`compressed_psum` -- the GSE
    segmentation applied to the SpMV's wire traffic (DESIGN.md §13):

      * ``wire="gse"``, tag 1: the u16 HEAD segments cross the wire
        (2 B/entry) plus each shard's tiny shared-exponent table;
      * ``wire="gse"``, tag 2: head + tail1 (4 B/entry) + table;
      * tag 3 or ``wire="exact"``: raw IEEE float64 (8 B/entry) -- at full
        precision the segmented 63-bit mantissa costs the same bytes but
        loses dynamic range, so exact bits ride the wire.

    The modeled payload is ``PartitionedGSECSR.halo_wire_bytes``.
    """
    if wire not in ("gse", "exact"):
        raise ValueError(f"unknown wire mode {wire!r}; 'gse' or 'exact'")
    if wire == "exact" or tag == 3:
        return jax.lax.all_gather(bnd, axis_name)
    b32 = bnd.astype(jnp.float32)
    table = gse.extract_shared_exponents_jnp(b32, k)
    head, tail1 = gse.pack32_jnp(b32, table, k)
    h_all = jax.lax.all_gather(head, axis_name)
    tb_all = jax.lax.all_gather(table, axis_name)
    if tag == 1:
        dec = jax.vmap(
            lambda h, tb: gse.decode32_jnp(
                tb, h, jnp.zeros(h.shape, jnp.uint16), k, 1, jnp.float32
            )
        )(h_all, tb_all)
    else:
        t_all = jax.lax.all_gather(tail1, axis_name)
        dec = jax.vmap(
            lambda h, t, tb: gse.decode32_jnp(tb, h, t, k, 2, jnp.float32)
        )(h_all, t_all, tb_all)
    return dec.astype(bnd.dtype)


def compressed_psum(grads: jnp.ndarray, axis_name: str, k: int = 8):
    """All-reduce ``grads`` over ``axis_name`` moving u16 GSE-SEM heads.

    Must be called INSIDE shard_map with ``axis_name`` manual.  grads:
    (N,) with N divisible by the axis size.  Returns the (approximately)
    summed gradient, decoded to f32.
    """
    # jax.lax.axis_size is a newer-jax spelling; psum(1) is the portable
    # axis-size query on the pinned 0.4.x.
    if hasattr(jax.lax, "axis_size"):
        n_dev = jax.lax.axis_size(axis_name)
    else:
        n_dev = jax.lax.psum(1, axis_name)
    n = grads.shape[0]
    assert n % n_dev == 0, (n, n_dev)

    # reduce-scatter phase: ship each chunk's u16 head to its owner
    chunks = grads.reshape(n_dev, n // n_dev)
    table = gse.extract_shared_exponents_jnp(grads, k)
    head, tail1 = gse.pack32_jnp(chunks, table, k)
    head_x = jax.lax.all_to_all(head, axis_name, 0, 0, tiled=False)
    tail_x = jax.lax.all_to_all(tail1, axis_name, 0, 0, tiled=False)
    table_x = jax.lax.all_gather(table, axis_name)  # (n_dev, k) tiny
    dec = jax.vmap(
        lambda h, t, tb: gse.decode32_jnp(tb, h, t, k, 2, jnp.float32)
    )(head_x, tail_x, table_x)
    local_sum = jnp.sum(dec, axis=0)  # this shard's reduced chunk

    # all-gather phase: ship the reduced chunk's u16 head back out
    table2 = gse.extract_shared_exponents_jnp(local_sum, k)
    h2, t2 = gse.pack32_jnp(local_sum, table2, k)
    h_all = jax.lax.all_gather(h2, axis_name)
    t_all = jax.lax.all_gather(t2, axis_name)
    tb_all = jax.lax.all_gather(table2, axis_name)
    out = jax.vmap(
        lambda h, t, tb: gse.decode32_jnp(tb, h, t, k, 2, jnp.float32)
    )(h_all, t_all, tb_all)
    return out.reshape(n)
