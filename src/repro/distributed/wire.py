"""True-wire GSE-SEM compressed all-reduce (shard_map, manual collectives).

pjit/GSPMD cannot express "compress, move u16, decompress" -- the
partitioner sees only the decoded values.  With shard_map the payload that
crosses the interconnect IS the 16-bit head segment:

    per-shard grad -> pack32 (u16 head) -> all_to_all (u16 on the wire)
    -> decode -> psum_scatter-equivalent local sum -> repack -> all_gather
    (u16 on the wire) -> decode

Wire bytes: 2/elem in each phase vs 4 (f32 ring AR) -- the paper's
storage/compute decoupling applied to the interconnect, for the cross-pod
gradient reduction (DESIGN.md §3.3).  Error feedback lives one level up
(distributed.compress).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import gse

__all__ = ["compressed_psum"]


def compressed_psum(grads: jnp.ndarray, axis_name: str, k: int = 8):
    """All-reduce ``grads`` over ``axis_name`` moving u16 GSE-SEM heads.

    Must be called INSIDE shard_map with ``axis_name`` manual.  grads:
    (N,) with N divisible by the axis size.  Returns the (approximately)
    summed gradient, decoded to f32.
    """
    # jax.lax.axis_size is a newer-jax spelling; psum(1) is the portable
    # axis-size query on the pinned 0.4.x.
    if hasattr(jax.lax, "axis_size"):
        n_dev = jax.lax.axis_size(axis_name)
    else:
        n_dev = jax.lax.psum(1, axis_name)
    n = grads.shape[0]
    assert n % n_dev == 0, (n, n_dev)

    # reduce-scatter phase: ship each chunk's u16 head to its owner
    chunks = grads.reshape(n_dev, n // n_dev)
    table = gse.extract_shared_exponents_jnp(grads, k)
    head, tail1 = gse.pack32_jnp(chunks, table, k)
    head_x = jax.lax.all_to_all(head, axis_name, 0, 0, tiled=False)
    tail_x = jax.lax.all_to_all(tail1, axis_name, 0, 0, tiled=False)
    table_x = jax.lax.all_gather(table, axis_name)  # (n_dev, k) tiny
    dec = jax.vmap(
        lambda h, t, tb: gse.decode32_jnp(tb, h, t, k, 2, jnp.float32)
    )(head_x, tail_x, table_x)
    local_sum = jnp.sum(dec, axis=0)  # this shard's reduced chunk

    # all-gather phase: ship the reduced chunk's u16 head back out
    table2 = gse.extract_shared_exponents_jnp(local_sum, k)
    h2, t2 = gse.pack32_jnp(local_sum, table2, k)
    h_all = jax.lax.all_gather(h2, axis_name)
    t_all = jax.lax.all_gather(t2, axis_name)
    tb_all = jax.lax.all_gather(table2, axis_name)
    out = jax.vmap(
        lambda h, t, tb: gse.decode32_jnp(tb, h, t, k, 2, jnp.float32)
    )(h_all, t_all, tb_all)
    return out.reshape(n)
