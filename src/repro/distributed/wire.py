"""True-wire GSE-SEM compressed all-reduce (shard_map, manual collectives).

pjit/GSPMD cannot express "compress, move u16, decompress" -- the
partitioner sees only the decoded values.  With shard_map the payload that
crosses the interconnect IS the 16-bit head segment:

    per-shard grad -> pack32 (u16 head) -> all_to_all (u16 on the wire)
    -> decode -> psum_scatter-equivalent local sum -> repack -> all_gather
    (u16 on the wire) -> decode

Wire bytes: 2/elem in each phase vs 4 (f32 ring AR) -- the paper's
storage/compute decoupling applied to the interconnect, for the cross-pod
gradient reduction (DESIGN.md §3.3).  Error feedback lives one level up
(distributed.compress).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import gse
from repro.core.tagmap import TagMap, normalize_tags

__all__ = ["compressed_psum", "halo_all_gather", "set_wire_fault",
           "wire_checksum"]


# Wire fault-injection hook (robustness harness, DESIGN.md §14).  When
# set, every halo payload passes through ``hook(name, arr)`` AFTER its
# integrity checksum is computed and BEFORE the collective -- i.e. the
# corruption happens "on the wire", which is exactly what the checksum
# side-channel is meant to catch.  ``name`` is the wire segment
# ("raw" for the exact/tag-3 float buffer; "head"/"tail1"/"table" for the
# GSE-segmented payloads).  Production never sets this.
_WIRE_FAULT = None


def set_wire_fault(hook) -> None:
    """Install (or clear, with ``None``) the wire fault-injection hook."""
    global _WIRE_FAULT
    _WIRE_FAULT = hook


def _send(name: str, arr: jnp.ndarray) -> jnp.ndarray:
    return arr if _WIRE_FAULT is None else _WIRE_FAULT(name, arr)


def wire_checksum(arr: jnp.ndarray) -> jnp.ndarray:
    """Traceable position-weighted uint32 checksum of a wire buffer.

    Floats are bitcast to the same-width unsigned integers first, so the
    checksum covers the exact bit pattern on the wire.  Each element is
    weighted by a Knuth-hash of its flat position before summing --
    a plain sum would miss swapped or permuted elements.
    """
    a = jnp.asarray(arr)
    if jnp.issubdtype(a.dtype, jnp.floating):
        bits = {2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}[a.dtype.itemsize]
        a = jax.lax.bitcast_convert_type(a, bits)
    a = a.astype(jnp.uint64).ravel()
    # Fold the high half into the low 32 bits BEFORE weighting: the final
    # mod-2^32 mask would otherwise erase any flip in bits 32-63 of a
    # 64-bit element (2^b * w === 0 mod 2^32 for b >= 32).
    a = a ^ (a >> jnp.uint64(32))
    w = jnp.arange(a.shape[0], dtype=jnp.uint64) * jnp.uint64(2654435761) \
        + jnp.uint64(1)
    return ((a * w).sum() & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)


def halo_all_gather(bnd: jnp.ndarray, axis_name: str, *, tag,
                    wire: str = "gse", k: int = 8, check: bool = False,
                    slot_tags: jnp.ndarray | None = None):
    """All-gather each shard's boundary buffer at the iteration's tag.

    Must be called INSIDE shard_map with ``axis_name`` manual.  ``bnd`` is
    this shard's packed boundary x-entries, shape ``(B,)`` or ``(B, nrhs)``
    (padded slots are zero).  Returns the gathered pool with a leading
    shard axis, ``(s, B[, nrhs])``, decoded back to ``bnd.dtype``.

    This is the halo-exchange twin of :func:`compressed_psum` -- the GSE
    segmentation applied to the SpMV's wire traffic (DESIGN.md §13):

      * ``wire="gse"``, tag 1: the u16 HEAD segments cross the wire
        (2 B/entry) plus each shard's tiny shared-exponent table;
      * ``wire="gse"``, tag 2: head + tail1 (4 B/entry) + table;
      * tag 3 or ``wire="exact"``: raw IEEE float64 (8 B/entry) -- at full
        precision the segmented 63-bit mantissa costs the same bytes but
        loses dynamic range, so exact bits ride the wire.

    The modeled payload is ``PartitionedGSECSR.halo_wire_bytes``.

    With ``check=True`` returns ``(gathered, ok)``: each sender computes
    a :func:`wire_checksum` of every payload segment before it leaves,
    the tiny u32 checksums ride alongside, and every receiver recomputes
    them on the gathered buffers -- ``ok`` is a replicated bool that goes
    False if ANY shard's payload was corrupted in flight (DESIGN.md §14).

    ``tag`` accepts the full tags axis: a legacy int, or a
    :class:`~repro.core.tagmap.TagMap` (uniform maps normalize to the
    same int path -- bit-identical; non-uniform maps ride at the map's
    MAX tag, since one collective has one payload width).  With a
    non-uniform map pass ``slot_tags`` -- this shard's ``(B,)`` per-slot
    tags (the boundary entry's ROW-group tag,
    ``PartitionedGSECSR.bnd_slot_tags``) -- and a tag-2 wire zeroes the
    tail1 segment of tag-1 slots before it leaves: the wire twin of
    ``kernels.ops.masked_for_tagmap``, so the decoded pool is bitwise
    what per-slot shipping would produce while the blended payload model
    (``halo_wire_bytes(tagmap)``) charges each slot at its own tag.  A
    tag-3 wire ships raw floats for every slot (exact bits never
    perturb); ``slot_tags`` then only informs the byte model.
    """
    if wire not in ("gse", "exact"):
        raise ValueError(f"unknown wire mode {wire!r}; 'gse' or 'exact'")
    tag = normalize_tags(tag)
    if isinstance(tag, TagMap):
        tag = tag.max_tag
    # Device-side attribution (DESIGN.md §16): the scope name lands in
    # profiler traces for every halo exchange this call site emits.
    scope = jax.named_scope(f"halo_all_gather.{wire}.tag{tag}")
    if wire == "exact" or tag == 3:
        with scope:
            if not check:
                return jax.lax.all_gather(_send("raw", bnd), axis_name)
            ref = jax.lax.all_gather(wire_checksum(bnd), axis_name)
            out = jax.lax.all_gather(_send("raw", bnd), axis_name)
            got = jax.vmap(wire_checksum)(out)
            return out, (got == ref).all()
    with scope:
        b32 = bnd.astype(jnp.float32)
        table = gse.extract_shared_exponents_jnp(b32, k)
        head, tail1 = gse.pack32_jnp(b32, table, k)
        if slot_tags is not None and tag != 1:
            # Per-slot wire precision: tag-1 slots drop their tail1 bits
            # before the payload leaves, exactly as the masked HBM
            # operand drops sub-tag tail segments.
            keep = jnp.asarray(slot_tags) >= 2
            if tail1.ndim > keep.ndim:
                keep = keep[:, None]
            tail1 = jnp.where(keep, tail1, jnp.zeros_like(tail1))
        sums, refs = [], []
        if check:
            sums = [wire_checksum(head), wire_checksum(table)]
            if tag != 1:
                sums.append(wire_checksum(tail1))
            refs = [jax.lax.all_gather(c, axis_name) for c in sums]
        h_all = jax.lax.all_gather(_send("head", head), axis_name)
        tb_all = jax.lax.all_gather(_send("table", table), axis_name)
        if tag == 1:
            dec = jax.vmap(
                lambda h, tb: gse.decode32_jnp(
                    tb, h, jnp.zeros(h.shape, jnp.uint16), k, 1, jnp.float32
                )
            )(h_all, tb_all)
            gathered = (h_all, tb_all)
        else:
            t_all = jax.lax.all_gather(_send("tail1", tail1), axis_name)
            dec = jax.vmap(
                lambda h, t, tb: gse.decode32_jnp(tb, h, t, k, 2, jnp.float32)
            )(h_all, t_all, tb_all)
            gathered = (h_all, tb_all, t_all)
        dec = dec.astype(bnd.dtype)
        if not check:
            return dec
        ok = jnp.bool_(True)
        for buf, ref in zip(gathered, refs):
            ok = ok & (jax.vmap(wire_checksum)(buf) == ref).all()
        return dec, ok


def compressed_psum(grads: jnp.ndarray, axis_name: str, k: int = 8):
    """All-reduce ``grads`` over ``axis_name`` moving u16 GSE-SEM heads.

    Must be called INSIDE shard_map with ``axis_name`` manual.  grads:
    (N,) with N divisible by the axis size.  Returns the (approximately)
    summed gradient, decoded to f32.
    """
    # jax.lax.axis_size is a newer-jax spelling; psum(1) is the portable
    # axis-size query on the pinned 0.4.x.
    if hasattr(jax.lax, "axis_size"):
        n_dev = jax.lax.axis_size(axis_name)
    else:
        n_dev = jax.lax.psum(1, axis_name)
    n = grads.shape[0]
    assert n % n_dev == 0, (n, n_dev)

    # reduce-scatter phase: ship each chunk's u16 head to its owner
    chunks = grads.reshape(n_dev, n // n_dev)
    table = gse.extract_shared_exponents_jnp(grads, k)
    head, tail1 = gse.pack32_jnp(chunks, table, k)
    head_x = jax.lax.all_to_all(head, axis_name, 0, 0, tiled=False)
    tail_x = jax.lax.all_to_all(tail1, axis_name, 0, 0, tiled=False)
    table_x = jax.lax.all_gather(table, axis_name)  # (n_dev, k) tiny
    dec = jax.vmap(
        lambda h, t, tb: gse.decode32_jnp(tb, h, t, k, 2, jnp.float32)
    )(head_x, tail_x, table_x)
    local_sum = jnp.sum(dec, axis=0)  # this shard's reduced chunk

    # all-gather phase: ship the reduced chunk's u16 head back out
    table2 = gse.extract_shared_exponents_jnp(local_sum, k)
    h2, t2 = gse.pack32_jnp(local_sum, table2, k)
    h_all = jax.lax.all_gather(h2, axis_name)
    t_all = jax.lax.all_gather(t2, axis_name)
    tb_all = jax.lax.all_gather(table2, axis_name)
    out = jax.vmap(
        lambda h, t, tb: gse.decode32_jnp(tb, h, t, k, 2, jnp.float32)
    )(h_all, t_all, tb_all)
    return out.reshape(n)
