"""seamless-m4t-large-v2 [audio, enc-dec]  (arXiv:2308.11596; hf).

24L encoder + 24L decoder, d_model=1024, 16H (GQA kv=16), d_ff=8192,
vocab=256206.  The speech frontend is a STUB: ``input_specs`` supplies
precomputed frame embeddings to the encoder (per assignment).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless_m4t_large_v2",
        family="encdec",
        num_layers=24,
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=256206,
        mlp_act="gelu",
        frontend="audio_stub",
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless_smoke",
        family="encdec",
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=503,
        mlp_act="gelu",
        frontend="audio_stub",
    )


RULES = {}  # heads=16, kv=16, vocab, ff all divide the 16-way model axis
