"""internvl2-2b [vlm]  (arXiv:2404.16821; hf).

InternLM2-backbone: 24L, d_model=2048, 16H (GQA kv=8), d_ff=8192,
vocab=92553.  InternViT frontend is a STUB supplying 256 patch embeddings
prepended to the text sequence (per assignment).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2_2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=92553,
        frontend="vision_stub",
        num_prefix_tokens=256,
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2_smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=251,
        frontend="vision_stub",
        num_prefix_tokens=8,
    )


RULES = {}
