"""qwen3-moe-235b-a22b [moe]  (hf:Qwen/Qwen3-30B-A3B family; hf).

94L, d_model=4096, 64H (GQA kv=4), per-expert d_ff=1536, vocab=151936,
MoE 128 experts top-8, qk-norm.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3_moe_235b_a22b",
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=1536,
        moe_d_ff=1536,
        vocab_size=151936,
        num_experts=128,
        experts_per_token=8,
        qk_norm=True,
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3_moe_smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        moe_d_ff=96,
        vocab_size=211,
        num_experts=4,
        experts_per_token=2,
        qk_norm=True,
    )


RULES = {
    "experts": "model",      # 128 experts / 16 = 8 per shard (EP)
    "expert_mlp": None,
}
