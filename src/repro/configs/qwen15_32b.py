"""qwen1.5-32b [dense]  (hf:Qwen/Qwen1.5 family; hf).

64L, d_model=5120, 40H (full MHA kv=40), d_ff=27392, vocab=152064,
QKV bias.  40 heads on the 16-way model axis shard unevenly (GSPMD pads
40->48); documented in the roofline table.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen15_32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        d_ff=27392,
        vocab_size=152064,
        qkv_bias=True,
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen15_smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=160,
        vocab_size=223,
        qkv_bias=True,
    )


RULES = {}
