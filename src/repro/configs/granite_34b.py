"""granite-34b [dense, code]  (arXiv:2405.04324; hf).

88L, d_model=6144, 48H (MQA kv=1), d_ff=24576, vocab=49152, llama-arch.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite_34b",
        family="dense",
        num_layers=88,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        d_ff=24576,
        vocab_size=49152,
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite34_smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        d_ff=128,
        vocab_size=193,
    )


RULES = {}
