"""The paper's own workload: stepped mixed-precision CG / GMRES solving
synthetic sparse systems (the 'architecture' of the paper itself).

Not an LM config -- exposes solver entry points used by examples and
benchmarks; kept in the registry so ``--arch paper_solver`` selects the
paper-native path in drivers.
"""
from repro.core.precision import MonitorParams
from repro.sparse import generators


def cg_setup(name: str = "poisson2d_64", small: bool = True):
    suite = generators.cg_suite(small)
    a = suite.get(name) or generators.poisson2d(64)
    return a, MonitorParams.for_cg()


def gmres_setup(name: str = "convdiff_32", small: bool = True):
    suite = generators.gmres_suite(small)
    a = suite.get(name) or generators.convection_diffusion_2d(32)
    return a, MonitorParams.for_gmres()


def pcg_setup(precond: str = "jacobi", n: int = 32, decades: float = 8.0):
    """Preconditioned stepped-CG workload (DESIGN.md §10): the
    ill-conditioned SPD system where unpreconditioned stepped CG stalls
    but a GSE-packed diagonal/block preconditioner -- applied at the
    monitor's current tag -- restores stencil conditioning.

    Returns ``(a, m, params)``; solve with
    ``solve_pcg(pack_csr(a, 8), b, m, params=params)``.
    """
    from repro.solvers import make_block_jacobi, make_jacobi, make_spai0

    factory = {
        "jacobi": make_jacobi,
        "spai0": make_spai0,
        "block_jacobi": make_block_jacobi,
    }[precond]
    a = generators.ill_conditioned_spd(n, decades)
    return a, factory(a), MonitorParams.for_cg()


def ir_setup(n: int = 32, decades: float = 8.0):
    """Stepped iterative-refinement workload (Carson-Khan shape): outer
    tag-3 residual/correction, inner stepped PCG.  Returns
    ``(a, m, params)``; solve with ``solve_ir(pack_csr(a, 8), b,
    precond=m, params=params)``."""
    from repro.solvers import make_jacobi

    a = generators.ill_conditioned_spd(n, decades)
    return a, make_jacobi(a), MonitorParams.for_cg()
