"""The paper's own workload: stepped mixed-precision CG / GMRES solving
synthetic sparse systems (the 'architecture' of the paper itself).

Not an LM config -- exposes solver entry points used by examples and
benchmarks; kept in the registry so ``--arch paper_solver`` selects the
paper-native path in drivers.
"""
from repro.core.precision import MonitorParams
from repro.sparse import generators


def cg_setup(name: str = "poisson2d_64", small: bool = True):
    suite = generators.cg_suite(small)
    a = suite.get(name) or generators.poisson2d(64)
    return a, MonitorParams.for_cg()


def gmres_setup(name: str = "convdiff_32", small: bool = True):
    suite = generators.gmres_suite(small)
    a = suite.get(name) or generators.convection_diffusion_2d(32)
    return a, MonitorParams.for_gmres()
