"""qwen3-4b [dense]  (hf:Qwen/Qwen3 family; hf).

36L, d_model=2560, 32H (GQA kv=8, head_dim=128), d_ff=9728, vocab=151936,
qk-norm.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3_4b",
        family="dense",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3_4b_smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=241,
        qk_norm=True,
    )


RULES = {}
