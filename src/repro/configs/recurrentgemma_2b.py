"""recurrentgemma-2b [hybrid]  (arXiv:2402.19427; hf).

26L Griffin pattern (2x RG-LRU : 1x local-attention, window 2048),
d_model=2560, 10H (MQA kv=1, head_dim=256), d_ff=7680, lru_width=2560,
vocab=256000.  Sub-quadratic: runs the long_500k decode shape.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma_2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        hybrid_period=3,
        local_window=2048,
        lru_width=2560,
        mlp_act="swiglu",
        scan_layers=False,      # heterogeneous layers -> python loop
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma_smoke",
        family="hybrid",
        num_layers=3,
        d_model=64,
        num_heads=2,
        num_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab_size=307,
        hybrid_period=3,
        local_window=16,
        lru_width=64,
        scan_layers=False,
    )


RULES = {}  # fused qkv layout shards evenly; lru width 2560/16 ok
