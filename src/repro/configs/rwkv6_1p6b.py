"""rwkv6-1.6b "Finch" [ssm, attention-free]  (arXiv:2404.05892; unverified).

24L, d_model=2048, d_ff=7168, vocab=65536, data-dependent decay,
head_dim 64 (32 rwkv heads).  O(1)-state decode: runs long_500k.

Paper-technique note (DESIGN.md section 6): the CSR expIdx-in-colidx trick
is sparse-specific and N/A here; the dense GSE-SEM tensor path (weight
serving / gradient compression) fully applies.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6_1p6b",
        family="ssm",
        num_layers=24,
        d_model=2048,
        num_heads=32,            # d_model / rwkv_head_dim
        num_kv_heads=32,
        d_ff=7168,
        vocab_size=65536,
        rwkv_head_dim=64,
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6_smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=181,
        rwkv_head_dim=16,
    )


RULES = {}
