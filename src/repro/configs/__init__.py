"""Architecture registry: --arch <id> resolves here.

Each module defines ``config()`` (the exact assigned configuration),
``smoke_config()`` (a reduced same-family config for CPU tests), and
optionally ``RULES`` (per-arch logical->mesh sharding rule overrides).
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional

from repro.distributed.sharding import DEFAULT_RULES
from repro.models.config import ModelConfig

ARCH_IDS = (
    "seamless_m4t_large_v2",
    "internvl2_2b",
    "qwen3_moe_235b_a22b",
    "grok1_314b",
    "recurrentgemma_2b",
    "qwen15_32b",
    "qwen3_4b",
    "granite_34b",
    "granite_3_2b",
    "rwkv6_1p6b",
)

# accept dashed aliases from the assignment text
ALIASES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "internvl2-2b": "internvl2_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "grok-1-314b": "grok1_314b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen1.5-32b": "qwen15_32b",
    "qwen3-4b": "qwen3_4b",
    "granite-34b": "granite_34b",
    "granite-3-2b": "granite_3_2b",
    "rwkv6-1.6b": "rwkv6_1p6b",
}


def _module(arch: str):
    arch = ALIASES.get(arch, arch).replace("-", "_")
    if arch not in ARCH_IDS and arch != "paper_solver":
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = _module(arch)
    return mod.smoke_config() if smoke else mod.config()


def get_rules(arch: str) -> Dict:
    mod = _module(arch)
    rules = dict(DEFAULT_RULES)
    rules.update(getattr(mod, "RULES", {}))
    return rules
