"""grok-1-314b [moe]  (hf:xai-org/grok-1; unverified).

64L, d_model=6144, 48H (GQA kv=8), d_ff=32768, vocab=131072,
MoE 8 experts top-2.

Sharding note (DESIGN.md / EXPERIMENTS.md): 8 experts do not divide the
16-way model axis; the EP dim pads 8->16 (2x waste on expert weights),
while the expert embed dim FSDP-shards over ``data``.  This padding is a
recorded hillclimb target.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok1_314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=32768,
        moe_d_ff=32768,
        vocab_size=131072,
        num_experts=8,
        experts_per_token=2,
        remat="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok1_smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=160,
        moe_d_ff=160,
        vocab_size=199,
        num_experts=4,
        experts_per_token=2,
    )


RULES = {
    "experts": None,         # 8 experts don't divide the 16-way axis:
    "expert_mlp": "model",   # TP inside each expert instead (no padding)
}
