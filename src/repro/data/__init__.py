"""data subpackage."""
