"""Deterministic, shardable, resume-exact synthetic token pipeline.

Production posture (DESIGN.md §5): every batch is a pure function of
(seed, step, shard), so

  * restart-from-checkpoint replays the exact stream (resume-exact);
  * each data-parallel shard generates only its slice (no host fan-out);
  * no filesystem dependency (the container has no corpora) -- synthetic
    "documents" follow a Zipfian unigram mix with induced bigram structure
    so the LM loss has learnable signal.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_prefix_tokens: int = 0   # vlm
    enc_len: int = 0             # encdec
    d_model: int = 0             # for frontend stub embeddings


def _zipf_logits(vocab: int) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks
    return np.log(p / p.sum())


class TokenPipeline:
    """Stateless batch generator: ``batch_at(step[, shard, num_shards])``."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._logits = jnp.asarray(_zipf_logits(cfg.vocab_size), jnp.float32)

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1
                 ) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        b = cfg.global_batch // num_shards
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(cfg.seed), step), shard
        )
        ks = jax.random.split(key, 4)
        # Zipfian unigrams + deterministic bigram twist: label[t] follows
        # token[t] via a fixed affine map half the time -> learnable signal.
        base = jax.random.categorical(
            ks[0], self._logits, shape=(b, cfg.seq_len + 1)
        )
        tokens = base[:, :-1]
        perm_shift = 7919  # prime; x -> (x*k+1) % V is a fixed map
        follow = (tokens * perm_shift + 1) % cfg.vocab_size
        gate = jax.random.bernoulli(ks[1], 0.5, follow.shape)
        labels = jnp.where(gate, follow, base[:, 1:])
        batch = {
            "tokens": tokens.astype(jnp.int32),
            "labels": labels.astype(jnp.int32),
            "loss_mask": jnp.ones((b, cfg.seq_len), jnp.float32),
        }
        if cfg.num_prefix_tokens:
            batch["prefix_embeds"] = jax.random.normal(
                ks[2], (b, cfg.num_prefix_tokens, cfg.d_model), jnp.float32
            )
        if cfg.enc_len:
            batch["enc_embeds"] = jax.random.normal(
                ks[3], (b, cfg.enc_len, cfg.d_model), jnp.float32
            )
        return batch

    def iterate(self, start_step: int = 0, shard: int = 0,
                num_shards: int = 1) -> Iterator[Dict[str, jnp.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step, shard, num_shards)
            step += 1
