"""AdamW + cosine schedule + global-norm clipping (pure JAX, pytree-based)."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1

    def schedule(self, step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum((step + 1.0) / max(self.warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - self.warmup_steps)
            / max(self.total_steps - self.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = 0.5 * (1 + jnp.cos(math.pi * prog))
        frac = self.min_lr_frac + (1 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(
            mu=jax.tree.map(z, params), nu=jax.tree.map(z, params)
        )

    def update(self, grads, state: AdamWState, params,
               step) -> Tuple[Any, AdamWState]:
        gnorm = jnp.sqrt(
            sum(jnp.vdot(g, g).real for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: (g * scale).astype(jnp.float32), grads)

        mu = jax.tree.map(
            lambda m, g: self.b1 * m + (1 - self.b1) * g, state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: self.b2 * v + (1 - self.b2) * g * g, state.nu, grads
        )
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1 - self.b1 ** t
        bc2 = 1 - self.b2 ** t
        lr = self.schedule(step)

        def upd(m, v, p):
            mh = m / bc1
            vh = v / bc2
            u = -lr * (mh / (jnp.sqrt(vh) + self.eps)
                       + self.weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(mu=mu, nu=nu)
