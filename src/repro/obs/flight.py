"""Device-side solver flight recorder (DESIGN.md section 16).

A fixed-size ring buffer carried through the solver ``lax.while_loop``
state.  Each iteration appends one row — iteration index, recursive
relative residual, the precision tag the iteration RAN at, the guard
health code after the update, and three solver-specific auxiliaries
(CG/PCG: alpha, beta, the curvature ``p.Ap``; GMRES: the Givens magnitude
``d``, the subdiagonal ``H[j+1,j]``, 0).  For sharded runs the recorded
scalars are the psum'd (replicated) dots, so every shard carries an
identical buffer.

Contracts:

* **Zero host syncs in-loop** — recording is pure ``Array.at[].set`` on
  buffer rows; nothing is pulled to the host until the post-solve decode.
* **Bit-identity** — the recorder only *observes* values the iteration
  already computed (same discipline as the PR 6 guards, which observe
  after the update arithmetic); recorder-on trajectories and solutions
  are bit-identical to recorder-off.
* **Ring semantics** — row ``i`` lands at slot ``count % capacity``;
  once ``count > capacity`` the oldest rows are overwritten and the
  decode reports them as ``dropped``.

Post-solve, :meth:`FlightLog.from_state` decodes the buffer on the host
and :func:`assert_consistent` checks the telemetry against the ground
truth the solver already reports (``switch_iters``, ``trip_iter``,
``tag``, ``iters``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.robustness.guards import HEALTH_OK, health_name

__all__ = [
    "FlightLog",
    "FlightParams",
    "DEFAULT_FLIGHT",
    "assert_consistent",
    "flight_init",
    "flight_record",
    "pack_state_tags",
    "pack_tag_pair",
    "split_batched",
    "unpack_tag_pair",
]


@dataclasses.dataclass(frozen=True)
class FlightParams:
    """Static (hashable) recorder configuration — a jit static arg, like
    ``MonitorParams`` and ``GuardParams``.

    ``capacity`` is the ring size in rows; a row is 1 int32 iter index,
    2 int32 tag/health codes and 4 residual-dtype scalars (40 B/row at
    f64), so the default 1024-row buffer costs 40 KiB of device memory
    per solve.
    """
    capacity: int = 1024

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")


DEFAULT_FLIGHT = FlightParams()

# Per-row columns, in decode order.  "it" is -1 on never-written slots.
COLUMNS = ("it", "relres", "tag", "health", "a0", "a1", "a2")

# On-device layout: the ring is TWO row-major buffers -- ``ibuf`` (cap, 3)
# int32 [it, tag, health] and ``fbuf`` (cap, 4) residual-dtype [relres,
# a0, a1, a2] -- so appending a row is two dynamic-update-slices total,
# not one per column (the per-column layout's 7 updates per iteration
# dominated the recorder's cost on small operands).
_ICOLS = ("it", "tag", "health")
_FCOLS = ("relres", "a0", "a1", "a2")


def flight_init(params: FlightParams, dtype):
    """Fresh recorder state: empty ring buffer + row counter (a pytree of
    arrays, carried through the while_loop like the monitor state)."""
    import jax.numpy as jnp

    cap = params.capacity
    return {
        # it = -1 marks never-written slots; tag/health start at 0.
        "ibuf": jnp.tile(jnp.array([[-1, 0, 0]], jnp.int32), (cap, 1)),
        "fbuf": jnp.zeros((cap, len(_FCOLS)), dtype),
        "count": jnp.int32(0),
    }


def flight_record(fs, *, it, relres, tag, health=None, a0=None, a1=None,
                  a2=None):
    """Append one row; pure array ops, no host syncs, no data dependence
    back into the solver state (bit-identity)."""
    import jax.numpy as jnp

    cap = fs["ibuf"].shape[0]
    idx = fs["count"] % cap
    dtype = fs["fbuf"].dtype
    zero = jnp.zeros((), dtype)
    if health is None:
        health = jnp.int32(HEALTH_OK)
    irow = jnp.stack([jnp.asarray(it, jnp.int32),
                      jnp.asarray(tag, jnp.int32),
                      jnp.asarray(health, jnp.int32)])
    frow = jnp.stack([jnp.asarray(relres, dtype),
                      zero if a0 is None else jnp.asarray(a0, dtype),
                      zero if a1 is None else jnp.asarray(a1, dtype),
                      zero if a2 is None else jnp.asarray(a2, dtype)])
    return {
        "ibuf": fs["ibuf"].at[idx].set(irow),
        "fbuf": fs["fbuf"].at[idx].set(frow),
        "count": fs["count"] + 1,
    }


# -- per-group tag pairs (PR 10, DESIGN.md §18) ---------------------------
#
# A per-group TagMap run has no single "the tag"; the int32 tag cell
# instead carries the ACTIVE (min, max) pair, bit-packed.  Uniform pairs
# (lo == hi) store the plain tag, so the schema is byte-identical to the
# pre-PR recording for every uniform map; non-uniform pairs store
# ``lo | (hi << 4)`` which is >= 33 -- disjoint from plain tags (<= 3),
# so the decode threshold ``_TAG_PACK_BASE`` is unambiguous.
_TAG_PACK_BASE = 8


def pack_tag_pair(lo: int, hi: int) -> int:
    """Bit-pack an active (min, max) tag pair into one int32 tag cell."""
    lo, hi = int(lo), int(hi)
    if not (1 <= lo <= hi <= 3):
        raise ValueError(f"tag pair must satisfy 1 <= lo <= hi <= 3, "
                         f"got ({lo}, {hi})")
    return lo if lo == hi else (lo | (hi << 4))


def unpack_tag_pair(v):
    """Inverse of :func:`pack_tag_pair`, vectorized: ``(lo, hi)`` arrays."""
    v = np.asarray(v)
    packed = v >= _TAG_PACK_BASE
    hi = np.where(packed, v >> 4, v)
    lo = np.where(packed, v & 0xF, v)
    return lo, hi


def pack_state_tags(fs, lo: int, hi: int):
    """Host-side epilogue for per-group (TagMap) runs: rewrite the written
    rows' tag cells to the packed (min, max) pair.

    The in-loop recorder wrote the masked-operand DECODE tag (the map's
    max) -- correct but lossy; this restamps the full pair once, after
    the solve, with zero in-loop cost.  Unwritten slots (it == -1) are
    left untouched so ring semantics survive.
    """
    packed = pack_tag_pair(lo, hi)
    ibuf = np.array(fs["ibuf"])
    ibuf[ibuf[:, 0] >= 0, 1] = packed
    return {"ibuf": ibuf, "fbuf": np.asarray(fs["fbuf"]),
            "count": np.asarray(fs["count"])}


def split_batched(fs) -> list[dict]:
    """Split a stacked per-column flight state (leading nrhs axis, as the
    batched solvers return it) into one state dict per column."""
    nrhs = int(np.asarray(fs["count"]).shape[0])
    return [{k: fs[k][j] for k in ("ibuf", "fbuf", "count")}
            for j in range(nrhs)]


@dataclasses.dataclass
class FlightLog:
    """Host-side decoded flight recording, rows ordered oldest -> newest."""

    it: np.ndarray
    relres: np.ndarray
    tag: np.ndarray
    health: np.ndarray
    a0: np.ndarray
    a1: np.ndarray
    a2: np.ndarray
    capacity: int
    recorded: int   # total rows ever written (may exceed capacity)
    dropped: int    # rows overwritten by the ring
    # Per-group runs (PR 10): the min tag of the active (min, max) pair;
    # equals ``tag`` on uniform recordings.  Defaulted so older pickled /
    # hand-built logs keep constructing.
    tag_min: np.ndarray | None = None

    @classmethod
    def from_state(cls, fs) -> "FlightLog":
        """Decode a recorder state (single host sync, after the solve).

        Tag cells may carry a bit-packed (min, max) pair (per-group runs;
        see :func:`pack_tag_pair`): ``tag`` decodes to the pair's MAX --
        the tag every pre-existing consumer (switch derivation,
        monotonicity, :func:`assert_consistent`) reasons about -- and the
        min lands on :attr:`tag_min`.
        """
        ibuf, fbuf = np.asarray(fs["ibuf"]), np.asarray(fs["fbuf"])
        count = int(np.asarray(fs["count"]))
        cap = ibuf.shape[0]
        if count <= cap:
            ibuf, fbuf = ibuf[:count], fbuf[:count]
        else:
            # Ring wrapped: slot (count % cap) holds the oldest row.
            shift = count % cap
            ibuf = np.roll(ibuf, -shift, axis=0)
            fbuf = np.roll(fbuf, -shift, axis=0)
        cols = {c: ibuf[:, i].copy() for i, c in enumerate(_ICOLS)}
        cols.update({c: fbuf[:, i].copy() for i, c in enumerate(_FCOLS)})
        lo, hi = unpack_tag_pair(cols["tag"])
        cols["tag"] = hi.astype(np.int32)
        return cls(**cols, capacity=cap, recorded=count,
                   dropped=max(count - cap, 0),
                   tag_min=lo.astype(np.int32))

    def __len__(self) -> int:
        return int(self.it.shape[0])

    def to_rows(self) -> list[dict]:
        return [
            {col: getattr(self, col)[i].item() for col in COLUMNS}
            for i in range(len(self))
        ]

    def switch_iters(self) -> np.ndarray:
        """Derive the (2,) switch-iteration vector from the tag column.

        The monitor records a step to tag ``k`` at iteration ``s`` meaning
        "iteration ``s`` is the first to RUN at tag ``k``" — so the first
        row whose tag equals ``k`` carries exactly ``it == s``.  A slot is
        -1 when the tag never appears; when the ring dropped rows and the
        first *visible* row already runs at tag >= k the true switch may
        predate the window (see :meth:`switch_visible`).
        """
        out = np.full((2,), -1, np.int64)
        for slot, k in ((0, 2), (1, 3)):
            hits = np.nonzero(self.tag == k)[0]
            if hits.size:
                out[slot] = int(self.it[hits[0]])
        return out

    def switch_visible(self, k: int) -> bool:
        """True when the window provably contains the switch TO tag ``k``:
        either no rows were dropped, or a row at tag < ``k`` precedes the
        first tag-``k`` row inside the window."""
        hits = np.nonzero(self.tag == k)[0]
        if not hits.size:
            return self.dropped == 0
        if self.dropped == 0:
            return True
        return bool(np.any(self.tag[: hits[0]] < k))

    def first_unhealthy(self) -> int:
        """Iteration of the first row with health != ok (-1: none)."""
        bad = np.nonzero(self.health != HEALTH_OK)[0]
        return int(self.it[bad[0]]) if bad.size else -1

    def summary(self) -> dict:
        last = len(self) - 1
        return {
            "rows": len(self),
            "recorded": self.recorded,
            "dropped": self.dropped,
            "first_it": int(self.it[0]) if len(self) else -1,
            "last_it": int(self.it[last]) if len(self) else -1,
            "last_relres": float(self.relres[last]) if len(self) else None,
            "last_tag": int(self.tag[last]) if len(self) else 0,
            "last_tag_min": (int(self.tag_min[last])
                             if len(self) and self.tag_min is not None
                             else (int(self.tag[last]) if len(self) else 0)),
            "switch_iters": self.switch_iters().tolist(),
            "first_unhealthy": self.first_unhealthy(),
            "health_counts": {
                health_name(code): int(n)
                for code, n in zip(*np.unique(self.health,
                                              return_counts=True))
            } if len(self) else {},
        }

    def pretty(self, max_rows: int = 12) -> str:
        """Human-readable table (head + tail when the log is long)."""
        header = f"{'it':>6} {'tag':>3} {'health':>9} {'relres':>12}  a0/a1/a2"
        lines = [header]
        n = len(self)
        idx = (list(range(n)) if n <= max_rows
               else list(range(max_rows // 2)) + [None]
               + list(range(n - max_rows // 2, n)))
        for i in idx:
            if i is None:
                lines.append(f"{'...':>6}")
                continue
            lines.append(
                f"{int(self.it[i]):>6} {int(self.tag[i]):>3} "
                f"{health_name(self.health[i]):>9} "
                f"{float(self.relres[i]):>12.3e}  "
                f"{float(self.a0[i]):.3e}/{float(self.a1[i]):.3e}/"
                f"{float(self.a2[i]):.3e}"
            )
        if self.dropped:
            lines.append(f"({self.dropped} older rows dropped by the ring)")
        return "\n".join(lines)


def assert_consistent(log: FlightLog, res, *, is_recovered: bool = False):
    """Assert the flight telemetry matches the solver's own report.

    ``res`` is any result NamedTuple carrying ``iters`` / ``tag`` /
    ``switch_iters`` / ``health`` / ``trip_iter``.  Applies to a
    single-run result (``recover=False`` or a run with no recovery
    restart); after a host-side recovery restart the buffer only covers
    the final segment, so pass ``is_recovered=True`` to skip the
    whole-trajectory checks.

    Raises ``AssertionError`` with a description on any mismatch.
    """
    iters = int(np.asarray(res.iters))
    if iters == 0:
        assert len(log) == 0, (
            f"flight: {len(log)} rows recorded for a 0-iteration solve"
        )
        return

    assert len(log) > 0, "flight: no rows recorded for a non-trivial solve"
    assert log.recorded >= len(log)

    # Row indices: one row per iteration, 0-based, contiguous.
    its = log.it.astype(np.int64)
    assert np.all(np.diff(its) == 1), (
        f"flight: iteration column not contiguous: {its[:8]}..."
    )

    if not is_recovered:
        assert log.recorded == iters, (
            f"flight: recorded {log.recorded} rows, solver ran {iters}"
        )
        assert int(its[-1]) == iters - 1, (
            f"flight: last row it={int(its[-1])}, expected {iters - 1}"
        )

        # Switch consistency: first row at tag k sits exactly at the
        # monitor's recorded switch iteration.
        sw = np.asarray(res.switch_iters, dtype=np.int64)
        derived = log.switch_iters()
        for slot, k in ((0, 2), (1, 3)):
            if not log.switch_visible(k):
                continue  # ring dropped the switch row; nothing provable
            if sw[slot] < 0:
                # Monitor says "never switched to k" -- for k == 2 an
                # init_tag >= 2 start legitimately shows tag-k rows from
                # iteration 0 without a switch event.
                if derived[slot] >= 0:
                    assert int(its[0]) == derived[slot] and log.tag[0] >= k, (
                        f"flight: tag {k} appears at it={derived[slot]} but "
                        f"monitor never recorded the switch"
                    )
            else:
                assert derived[slot] == sw[slot], (
                    f"flight: first tag-{k} row at it={derived[slot]}, "
                    f"monitor switch_iters[{slot}]={sw[slot]}"
                )

        # Trip consistency: the first unhealthy row is the guard's trip.
        trip = int(np.asarray(res.trip_iter))
        first_bad = log.first_unhealthy()
        if trip >= 0 and int(np.asarray(res.health)) != HEALTH_OK:
            assert first_bad == trip, (
                f"flight: first unhealthy row at it={first_bad}, guard "
                f"trip_iter={trip}"
            )
        if first_bad < 0 and log.dropped == 0:
            assert trip < 0 or int(np.asarray(res.health)) == HEALTH_OK, (
                f"flight: all rows healthy but trip_iter={trip}"
            )

    # Final tag: the last row carries the tag the final iteration RAN at;
    # res.tag is the monitor's tag AFTER that iteration's update, so it is
    # one step ahead iff the final iteration itself triggered a switch.
    final_tag = int(np.asarray(res.tag))
    last_tag = int(log.tag[-1])
    sw = np.asarray(res.switch_iters, dtype=np.int64)
    stepped_at_exit = bool(np.any(sw == int(np.asarray(res.iters))))
    if not is_recovered:
        expect = last_tag + (1 if stepped_at_exit else 0)
        assert final_tag == expect, (
            f"flight: last row tag={last_tag} (switch-at-exit="
            f"{stepped_at_exit}), solver final tag={final_tag}"
        )

    # Monotone tags within the window, always (tags only step up).
    assert np.all(np.diff(log.tag) >= 0), "flight: tag column decreased"
