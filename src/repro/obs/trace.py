"""Span tracer: nested wall-clock spans emitted as schema-versioned JSONL.

Host-side observability companion to the device-side flight recorder.  A
:class:`Tracer` records a tree of named spans (pack, tune-sweep, decode,
solve, halo-exchange, service flush) with free-form attribute dicts — byte
and flop annotations come from the perf ledger at the call sites.  One JSON
object per line; every event carries ``"v": SCHEMA_VERSION`` so downstream
consumers can reject what they don't understand, and
:func:`validate_jsonl` is the schema check CI runs on the emitted file.

When no tracer is installed, :func:`span` is a near-zero-cost no-op, so
instrumented call sites cost nothing on the clean path.  Spans that wrap
code inside a jit trace measure trace/compile-time cost (they run once per
compilation); device-side time is attributed through the
``jax.named_scope`` names the kernels carry (see DESIGN.md section 16).
"""

from __future__ import annotations

import contextlib
import json
import threading
import time

__all__ = [
    "SCHEMA_VERSION",
    "Tracer",
    "active",
    "annotate",
    "capture",
    "current",
    "event",
    "install",
    "span",
    "uninstall",
    "validate_event",
    "validate_jsonl",
]

SCHEMA_VERSION = 1

# jax.profiler.TraceAnnotation forwards span names into device profiles when
# a profiler session is running; it is a cheap no-op otherwise.  Imported
# lazily so obs.trace itself never forces jax in.
_PROFILER_ANNOTATION = None


def _profiler_annotation():
    global _PROFILER_ANNOTATION
    if _PROFILER_ANNOTATION is None:
        try:
            from jax.profiler import TraceAnnotation
            _PROFILER_ANNOTATION = TraceAnnotation
        except Exception:  # pragma: no cover - profiler unavailable
            _PROFILER_ANNOTATION = False
    return _PROFILER_ANNOTATION or None


class Tracer:
    """Collects span/event records; thread-safe append, per-thread nesting."""

    def __init__(self):
        self._lock = threading.Lock()
        self._local = threading.local()
        self._next_id = 0
        self.events: list[dict] = []

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _new_id(self) -> int:
        with self._lock:
            self._next_id += 1
            return self._next_id

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Record a nested span; yields the attrs dict for late annotation."""
        stack = self._stack()
        rec = {
            "v": SCHEMA_VERSION,
            "kind": "span",
            "name": str(name),
            "id": self._new_id(),
            "parent": stack[-1]["id"] if stack else None,
            "depth": len(stack),
            "t0": time.time(),
            "dur_s": 0.0,
            "attrs": dict(attrs),
        }
        stack.append(rec)
        annotation_cls = _profiler_annotation()
        ctx = annotation_cls(rec["name"]) if annotation_cls else None
        start = time.perf_counter()
        try:
            if ctx is not None:
                with ctx:
                    yield rec["attrs"]
            else:
                yield rec["attrs"]
        finally:
            rec["dur_s"] = time.perf_counter() - start
            stack.pop()
            with self._lock:
                self.events.append(rec)

    def event(self, name: str, **attrs):
        """Record an instantaneous (zero-duration) event."""
        stack = self._stack()
        rec = {
            "v": SCHEMA_VERSION,
            "kind": "event",
            "name": str(name),
            "id": self._new_id(),
            "parent": stack[-1]["id"] if stack else None,
            "depth": len(stack),
            "t0": time.time(),
            "dur_s": 0.0,
            "attrs": dict(attrs),
        }
        with self._lock:
            self.events.append(rec)
        return rec

    def annotate(self, **attrs):
        """Merge attrs into the innermost open span (no-op at top level)."""
        stack = self._stack()
        if stack:
            stack[-1]["attrs"].update(attrs)

    def write_jsonl(self, path) -> int:
        """Write one event per line, oldest first; returns the line count."""
        with self._lock:
            events = list(self.events)
        events.sort(key=lambda e: e["id"])
        with open(path, "w") as fh:
            for rec in events:
                fh.write(json.dumps(rec, sort_keys=False) + "\n")
        return len(events)


# -- module-level installed tracer --------------------------------------

_INSTALLED: Tracer | None = None


def install(tracer: Tracer) -> Tracer:
    global _INSTALLED
    _INSTALLED = tracer
    return tracer


def uninstall() -> None:
    global _INSTALLED
    _INSTALLED = None


def current() -> Tracer | None:
    return _INSTALLED


def active() -> bool:
    return _INSTALLED is not None


_NULL_ATTRS: dict = {}


@contextlib.contextmanager
def span(name: str, **attrs):
    """Span on the installed tracer; near-free no-op when none is active."""
    tracer = _INSTALLED
    if tracer is None:
        yield _NULL_ATTRS
        return
    with tracer.span(name, **attrs) as a:
        yield a


def event(name: str, **attrs):
    tracer = _INSTALLED
    if tracer is not None:
        tracer.event(name, **attrs)


def annotate(**attrs):
    tracer = _INSTALLED
    if tracer is not None:
        tracer.annotate(**attrs)


@contextlib.contextmanager
def capture(path=None):
    """Install a fresh tracer for the block; optionally write JSONL after."""
    tracer = Tracer()
    prev = _INSTALLED
    install(tracer)
    try:
        yield tracer
    finally:
        install(prev) if prev is not None else uninstall()
        if path is not None:
            tracer.write_jsonl(path)


# -- schema validation ---------------------------------------------------

_REQUIRED_FIELDS = {
    "v": int,
    "kind": str,
    "name": str,
    "id": int,
    "depth": int,
    "t0": (int, float),
    "dur_s": (int, float),
    "attrs": dict,
}
_KINDS = ("span", "event")


def validate_event(rec) -> None:
    """Raise ValueError if ``rec`` is not a valid v1 trace event."""
    if not isinstance(rec, dict):
        raise ValueError(f"event must be an object, got {type(rec).__name__}")
    for field, types in _REQUIRED_FIELDS.items():
        if field not in rec:
            raise ValueError(f"missing field {field!r}")
        if not isinstance(rec[field], types):
            raise ValueError(
                f"field {field!r} has type {type(rec[field]).__name__}"
            )
        if field in ("v", "id", "depth") and isinstance(rec[field], bool):
            raise ValueError(f"field {field!r} must be an int, got bool")
    if rec["v"] != SCHEMA_VERSION:
        raise ValueError(f"unsupported schema version {rec['v']}")
    if rec["kind"] not in _KINDS:
        raise ValueError(f"unknown kind {rec['kind']!r}")
    if "parent" not in rec:
        raise ValueError("missing field 'parent'")
    if rec["parent"] is not None and not isinstance(rec["parent"], int):
        raise ValueError("field 'parent' must be int or null")
    if rec["dur_s"] < 0:
        raise ValueError("negative dur_s")
    if rec["depth"] < 0:
        raise ValueError("negative depth")
    for key in rec["attrs"]:
        if not isinstance(key, str):
            raise ValueError("attrs keys must be strings")


def validate_jsonl(path) -> int:
    """Validate every line of a JSONL trace; returns the event count.

    Also checks referential integrity: a span's ``parent`` (when set) must
    be the id of some event in the file.
    """
    count = 0
    ids: set[int] = set()
    parents: list[tuple[int, int]] = []
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as exc:
                raise ValueError(f"{path}:{lineno}: bad JSON: {exc}") from exc
            try:
                validate_event(rec)
            except ValueError as exc:
                raise ValueError(f"{path}:{lineno}: {exc}") from exc
            ids.add(rec["id"])
            if rec["parent"] is not None:
                parents.append((lineno, rec["parent"]))
            count += 1
    for lineno, parent in parents:
        if parent not in ids:
            raise ValueError(f"{path}:{lineno}: dangling parent id {parent}")
    return count
