"""Unified metrics registry: counters, gauges, histograms with labels.

One counter implementation for the whole repo.  ``PACK_STATS`` (kernels/ops),
``TUNE_STATS`` (perf/tunecache) and ``SolverService.stats`` are thin
dict-shaped views (:func:`stats_view`) over labeled counters registered here,
so every number the system produces is visible through one exposition
surface: :meth:`Registry.to_prometheus` (Prometheus text format) and
:meth:`Registry.to_json`.

Pure Python, no jax imports — safe to import from anywhere in the tree
without creating cycles.  All mutation goes through a registry-wide lock so
the serving path can update counters from worker threads.
"""

from __future__ import annotations

import json
import math
import threading
from collections import OrderedDict, deque
from collections.abc import MutableMapping
from typing import Iterator, Sequence

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "REGISTRY",
    "Registry",
    "StatsView",
    "DEFAULT_TIME_BUCKETS",
    "DEFAULT_BYTE_BUCKETS",
    "stats_view",
]

# Seconds-scale buckets: microseconds (fast kernels) through tens of seconds
# (first-call compiles on CPU interpret mode).
DEFAULT_TIME_BUCKETS = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
    float("inf"),
)

# Bytes-scale buckets: a single packed group through multi-GB operands.
DEFAULT_BYTE_BUCKETS = (
    64.0, 256.0, 1e3, 4e3, 16e3, 64e3, 256e3, 1e6, 4e6, 16e6, 64e6,
    256e6, 1e9, float("inf"),
)

# Histograms keep a bounded reservoir of recent observations so quantiles
# (p50/p95/p99) come from real samples rather than bucket interpolation.
_SAMPLE_WINDOW = 4096


def _check_label_values(labelnames: tuple[str, ...], kw: dict) -> tuple:
    if set(kw) != set(labelnames):
        raise ValueError(
            f"expected labels {labelnames}, got {tuple(sorted(kw))}"
        )
    return tuple(str(kw[name]) for name in labelnames)


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_value(v) -> str:
    if isinstance(v, float):
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        if v == int(v) and abs(v) < 1e15:
            return str(int(v))
    return str(v)


class _Child:
    """One labeled series of a metric family."""

    def __init__(self, metric: "_Metric", labelvalues: tuple):
        self._metric = metric
        self._lock = metric._registry._lock
        self.labelvalues = labelvalues

    @property
    def labels_dict(self) -> dict:
        return dict(zip(self._metric.labelnames, self.labelvalues))


class Counter(_Child):
    def __init__(self, metric, labelvalues):
        super().__init__(metric, labelvalues)
        self.value = 0

    def inc(self, amount=1):
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self.value += amount

    def set(self, value):
        """Back-compat escape hatch for dict-view assignment (e.g. the tune
        cache's ``reset()`` zeroing its stats); not part of the Prometheus
        counter contract."""
        with self._lock:
            self.value = value

    def _zero(self):
        self.value = 0


class Gauge(_Child):
    def __init__(self, metric, labelvalues):
        super().__init__(metric, labelvalues)
        self.value = 0

    def set(self, value):
        with self._lock:
            self.value = value

    def inc(self, amount=1):
        with self._lock:
            self.value += amount

    def dec(self, amount=1):
        self.inc(-amount)

    def _zero(self):
        self.value = 0


class Histogram(_Child):
    def __init__(self, metric, labelvalues):
        super().__init__(metric, labelvalues)
        self.buckets = metric.buckets
        self._zero()

    def _zero(self):
        self.counts = [0] * len(self.buckets)
        self.sum = 0.0
        self.count = 0
        self.samples = deque(maxlen=_SAMPLE_WINDOW)

    def observe(self, value):
        value = float(value)
        with self._lock:
            for i, bound in enumerate(self.buckets):
                if value <= bound:
                    self.counts[i] += 1
            self.sum += value
            self.count += 1
            self.samples.append(value)

    def quantile(self, q: float):
        """Quantile over the recent-sample reservoir; None when empty."""
        with self._lock:
            ordered = sorted(self.samples)
        if not ordered:
            return None
        idx = max(0, min(len(ordered) - 1, math.ceil(q * len(ordered)) - 1))
        return ordered[idx]

    def summary(self) -> dict:
        with self._lock:
            n, s = self.count, self.sum
            ordered = sorted(self.samples)
        out = {"count": n, "sum": s}
        out["mean"] = (s / n) if n else None
        for name, q in (("p50", 0.50), ("p95", 0.95), ("p99", 0.99)):
            if ordered:
                idx = max(0, min(len(ordered) - 1,
                                 math.ceil(q * len(ordered)) - 1))
                out[name] = ordered[idx]
            else:
                out[name] = None
        out["min"] = ordered[0] if ordered else None
        out["max"] = ordered[-1] if ordered else None
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Metric:
    """A named family of series sharing a kind, help string and label set."""

    def __init__(self, registry, kind, name, help, labelnames,
                 buckets=None):
        self._registry = registry
        self.kind = kind
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(buckets) if buckets is not None else None
        self._children: OrderedDict[tuple, _Child] = OrderedDict()

    def labels(self, **kw) -> _Child:
        values = _check_label_values(self.labelnames, kw)
        with self._registry._lock:
            child = self._children.get(values)
            if child is None:
                child = _KINDS[self.kind](self, values)
                self._children[values] = child
        return child

    def _default(self) -> _Child:
        if self.labelnames:
            raise ValueError(
                f"metric {self.name!r} has labels {self.labelnames}; "
                "use .labels(...)"
            )
        return self.labels()

    # Convenience passthroughs for unlabeled metrics.
    def inc(self, amount=1):
        self._default().inc(amount)

    def set(self, value):
        self._default().set(value)

    def dec(self, amount=1):
        self._default().dec(amount)

    def observe(self, value):
        self._default().observe(value)

    @property
    def value(self):
        return self._default().value

    def summary(self):
        return self._default().summary()


class Registry:
    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: OrderedDict[str, _Metric] = OrderedDict()

    def _register(self, kind, name, help, labelnames, buckets=None):
        labelnames = tuple(labelnames)
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if existing.kind != kind or existing.labelnames != labelnames:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{existing.kind}{existing.labelnames}"
                    )
                if help and not existing.help:
                    existing.help = help
                return existing
            metric = _Metric(self, kind, name, help, labelnames, buckets)
            self._metrics[name] = metric
            return metric

    def counter(self, name, help="", labelnames=()):
        return self._register("counter", name, help, labelnames)

    def gauge(self, name, help="", labelnames=()):
        return self._register("gauge", name, help, labelnames)

    def histogram(self, name, help="", labelnames=(),
                  buckets=DEFAULT_TIME_BUCKETS):
        return self._register("histogram", name, help, labelnames, buckets)

    def get(self, name) -> _Metric | None:
        return self._metrics.get(name)

    def reset(self):
        """Zero every series; registrations (and dict views) stay alive."""
        with self._lock:
            for metric in self._metrics.values():
                for child in metric._children.values():
                    child._zero()

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        with self._lock:
            metrics = [
                (m, list(m._children.items()))
                for m in self._metrics.values()
            ]
        for metric, children in metrics:
            if not children:
                continue
            if metric.help:
                lines.append(f"# HELP {metric.name} {metric.help}")
            lines.append(f"# TYPE {metric.name} {metric.kind}")
            for values, child in children:
                pairs = [
                    f'{k}="{_escape_label(v)}"'
                    for k, v in zip(metric.labelnames, values)
                ]
                if metric.kind == "histogram":
                    for bound, count in zip(child.buckets, child.counts):
                        bpairs = pairs + [f'le="{_fmt_value(float(bound))}"']
                        lines.append(
                            f"{metric.name}_bucket{{{','.join(bpairs)}}} "
                            f"{count}"
                        )
                    label = f"{{{','.join(pairs)}}}" if pairs else ""
                    lines.append(
                        f"{metric.name}_sum{label} {_fmt_value(child.sum)}"
                    )
                    lines.append(f"{metric.name}_count{label} {child.count}")
                else:
                    label = f"{{{','.join(pairs)}}}" if pairs else ""
                    lines.append(
                        f"{metric.name}{label} {_fmt_value(child.value)}"
                    )
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> dict:
        """JSON exposition: one object per family, one entry per series."""
        out = {"schema": 1, "metrics": []}
        with self._lock:
            metrics = [
                (m, list(m._children.items()))
                for m in self._metrics.values()
            ]
        for metric, children in metrics:
            fam = {
                "name": metric.name,
                "type": metric.kind,
                "help": metric.help,
                "series": [],
            }
            for values, child in children:
                labels = dict(zip(metric.labelnames, values))
                if metric.kind == "histogram":
                    entry = {"labels": labels, **child.summary()}
                else:
                    entry = {"labels": labels, "value": child.value}
                fam["series"].append(entry)
            out["metrics"].append(fam)
        return out

    def to_json_text(self) -> str:
        return json.dumps(self.to_json(), indent=2, sort_keys=False)


REGISTRY = Registry()


class StatsView(MutableMapping):
    """Dict-shaped view over one family of labeled counters.

    Keeps the historical ``STATS["hits"] += 1`` call sites (and the tests
    that read them) working unchanged while the storage lives in the
    registry.  ``dict(view)``, iteration, ``len``, item assignment (used by
    cache ``reset()`` helpers) and membership all behave like the plain
    dicts they replace.
    """

    def __init__(self, metric: _Metric, keys: Sequence[str],
                 label: str, const: dict | None = None):
        self._metric = metric
        self._label = label
        self._const = dict(const or {})
        self._children: "OrderedDict[str, Counter]" = OrderedDict()
        for key in keys:
            self._children[key] = metric.labels(**self._const,
                                                **{label: key})

    def _child(self, key: str) -> Counter:
        child = self._children.get(key)
        if child is None:
            child = self._metric.labels(**self._const, **{self._label: key})
            self._children[key] = child
        return child

    def __getitem__(self, key):
        if key not in self._children:
            raise KeyError(key)
        return self._children[key].value

    def __setitem__(self, key, value):
        self._child(key).set(value)

    def __delitem__(self, key):
        raise TypeError("StatsView keys are fixed; set the value to 0")

    def __iter__(self) -> Iterator[str]:
        return iter(self._children)

    def __len__(self) -> int:
        return len(self._children)

    def __contains__(self, key) -> bool:
        return key in self._children

    def __repr__(self) -> str:
        return repr({k: c.value for k, c in self._children.items()})


def stats_view(name, keys, help="", label="event", const=None,
               registry=None) -> StatsView:
    """Register (idempotently) a counter family and return a dict view.

    ``const`` adds fixed labels to every series in the view — e.g. a
    per-service-instance id so two ``SolverService`` objects don't share
    counters.
    """
    registry = registry or REGISTRY
    labelnames = tuple(const or ()) + (label,)
    metric = registry.counter(name, help, labelnames=labelnames)
    return StatsView(metric, keys, label, const)
