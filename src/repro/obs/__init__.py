"""Observability layer: metrics registry, span tracer, solver flight recorder.

Three parts (DESIGN.md section 16):

- ``obs.metrics``: the single counter/gauge/histogram implementation behind
  ``PACK_STATS``, ``TUNE_STATS`` and ``SolverService`` stats, with
  Prometheus-text and JSON exposition.
- ``obs.trace``: nested wall-clock spans with byte/flop annotations written
  as schema-versioned JSONL, plus ``jax.named_scope`` names on kernel call
  sites so device profiles carry the same vocabulary.
- ``obs.flight``: a fixed-size device-side ring buffer carried through the
  solver ``lax.while_loop`` recording one row per iteration with zero
  host syncs; decoded post-solve into a ``FlightLog``.
"""

from repro.obs import flight, metrics, trace
from repro.obs.flight import FlightLog, FlightParams, flight_init, flight_record
from repro.obs.metrics import REGISTRY, Registry, stats_view
from repro.obs.trace import Tracer, capture, span, validate_jsonl

__all__ = [
    "FlightLog",
    "FlightParams",
    "REGISTRY",
    "Registry",
    "Tracer",
    "capture",
    "flight",
    "flight_init",
    "flight_record",
    "metrics",
    "span",
    "stats_view",
    "trace",
    "validate_jsonl",
]
