"""Adaptive per-group precision driver (PR 10, DESIGN.md §18).

The stepped monitor (paper Alg. 3) promotes the WHOLE operator when
convergence stalls; this driver plans and maintains a per-group map so
only the groups that actually limit the attainable residual stream
extra tail segments.  On the congruence-rescaled generators the
convergence RATE is tag-independent -- the tags separate on the TRUE
residual floor ``||(A~_t - A) x*|| / ||b||``, whose per-group
contributions the planner bounds column-wise as
``sum_j (||E_t[:, j]|| |x*_j|)^2`` (a cancellation-free upper bound, so
a map planned under budget is SAFE even when signed cancellation makes
the realized floor lower).  The default schedule is explore-then-plan:

1. **Explore.**  Run plain CG/PCG at uniform tag 1 -- the cheapest
   stream there is, and (because the column model ignores cancellation)
   also the schedule whose realized floor no partial promotion is
   guaranteed to beat.  Every ``chunk`` iterations the host measures
   the TRUE tag-3 residual (billed), which doubles as the convergence
   test: the solve stops the moment the real residual fits ``tol``,
   recursive lag notwithstanding.
2. **Plan.**  The first time the recursive residual crosses
   ``beta * tol`` the iterate's magnitudes ARE a solution profile
   resolved to about its own error scale: trim below ``rel * rms``,
   feed ``core.precision.decode_error_scores``, and let
   ``plan_tagmap`` greedily promote the largest-contribution groups
   until the modeled floor fits ``theta * tol * ||b||``.  Restart from
   the current ``x`` at the planned map -- restart, not in-place
   switch: a per-group operand change invalidates the Krylov
   recurrence far harder than the paper's scalar tag step, and an
   in-place per-group switch can diverge outright.
3. **Finish + verify.**  Run the planned map to the true-residual stop.
   Every segment's recursive target is the quadrature complement
   ``tol * sqrt(1 - theta^2)`` of the planned floor budget -- deep
   enough that recurrence + floor still lands the true residual inside
   ``tol``, and no deeper, because grinding the recurrence below what
   the floor admits burns real iterations.  If the recurrence exhausts
   while the true residual still misses -- the model underpredicted --
   a reactive replan from the now-sharper iterate promotes the worst
   remaining contributors and restarts; with the column upper bound
   this terminates after at most a couple of short tail segments.

Byte accounting is blended and complete: every chunk bills the map it
ran under (``GSECSR.bytes_touched(tm)`` per iteration), each restart
bills its fresh initial SpMV, the optional probe bills its tag-1
iterations, and each true-residual check bills one tag-3 pass -- the
figure the ``BENCH_adaptive.json`` gate compares against the best
uniform schedule.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import precision as P
from repro.core.tagmap import GROUP_SIZE, TagMap, normalize_tags
from repro.obs import trace as OT
from repro.sparse.csr import GSECSR

__all__ = ["AdaptiveResult", "Promotion", "solve_adaptive"]


class Promotion(NamedTuple):
    """One promotion event in an adaptive solve (telemetry)."""

    it: int          # global iteration the promotion took effect at
    n_promoted: int  # groups whose tag stepped up
    min_tag: int     # new map's min tag
    max_tag: int     # new map's max tag
    crc32: int       # new map's cache-key token


class AdaptiveResult(NamedTuple):
    x: jnp.ndarray
    iters: int
    relres: float        # final recursive relative residual
    true_relres: float   # final TRUE tag-3 residual vs the UNMASKED operand
    converged: bool      # true_relres <= tol
    tagmap: TagMap       # final per-group map
    promotions: tuple    # Promotion events, in order (it=0: an upfront plan)
    spmv_bytes: int      # blended matrix-stream bytes, whole solve
    chunks: int          # host chunks executed
    probe_iters: int = 0  # tag-1 probe iterations billed into spmv_bytes

    @property
    def tag(self) -> int:
        """Max active tag -- rough ``CGResult.tag`` compatibility."""
        return self.tagmap.max_tag


def _init_map(tags0, m: int, group_size: int) -> TagMap:
    """Seed map from the caller's ``tags0`` (int floor or map)."""
    norm = normalize_tags(tags0, m)
    if isinstance(norm, int):
        return TagMap.for_rows(m, norm, group_size)
    return norm


def _inv_diag(a: GSECSR) -> np.ndarray:
    """Inverse absolute diagonal read host-side from the packed tag-3
    decode (no CSR needed -- ``a`` is all the driver gets)."""
    from repro.kernels import ref

    rows = np.asarray(a.row_ids, np.int64)
    cols = (np.asarray(a.colpak, np.uint32)
            & np.uint32((1 << (32 - a.ei_bit)) - 1)).astype(np.int64)
    v3 = np.asarray(ref.decode_csr_ref(a.colpak, a.head, a.tail1, a.tail2,
                                       a.table, a.ei_bit, 3), np.float64)
    diag = np.zeros(int(a.shape[0]), np.float64)
    dmask = rows == cols
    diag[rows[dmask]] = np.abs(v3[dmask])
    return np.where(diag > 0,
                    1.0 / np.maximum(diag, np.finfo(np.float64).tiny), 1.0)


def _probe_jacobi(a: GSECSR):
    """Diagonal preconditioner for the optional tag-1 planning probe."""
    inv_j = jnp.asarray(_inv_diag(a))

    def apply_m(r, tag):
        return r * inv_j.astype(r.dtype)

    return apply_m


def _trim(xh: np.ndarray, rel: float) -> np.ndarray:
    """Zero the components of a solution-profile estimate that sit below
    its own error scale.  A CG iterate with true relative residual
    ``rel`` has error ``A^{-1} r`` spread across all components at the
    ``~rel * rms(x)`` scale, so components under ``rel * rms`` are
    indistinguishable from zero -- leaving that junk in inflates the
    floor scores of groups ``x*`` never touches, diluting exactly the
    concentration the planner exploits.  Conservative under-promotion
    instead; the reactive replan repairs it from a better iterate."""
    if not np.isfinite(rel) or xh.size == 0:
        return xh
    rms = float(np.linalg.norm(xh)) / np.sqrt(xh.size)
    return np.where(xh > min(rel, 1.0) * rms, xh, 0.0)


def _abs_neumann_profile(a: GSECSR, b: np.ndarray, hops: int = 1) -> np.ndarray:
    """Solution-magnitude seed profile: truncated absolute-value Neumann
    series ``sum_k (D^{-1}|offdiag|)^k D^{-1}|b|``, host-side from the
    packed tag-3 decode.  Zero solve cost; the zeroth term is exact for
    a diagonal operator, and each hop spreads mass along the actual
    coupling pattern (hub rows, point-load neighborhoods) -- unlike a
    signed Jacobi sweep it cannot oscillate or cancel, and truncation
    keeps it finite even where Jacobi iteration diverges.  Reliable on
    diagonally-structured operators (the skewed/hub generators); on
    globally coupled ill-conditioned spectra ``A^{-1}`` is non-local
    and the explore phase's live iterate is the only sound profile."""
    from repro.kernels import ref

    rows = np.asarray(a.row_ids, np.int64)
    cols = (np.asarray(a.colpak, np.uint32)
            & np.uint32((1 << (32 - a.ei_bit)) - 1)).astype(np.int64)
    v3 = np.abs(np.asarray(ref.decode_csr_ref(a.colpak, a.head, a.tail1,
                                              a.tail2, a.table, a.ei_bit, 3),
                           np.float64))
    m = int(a.shape[0])
    d = np.zeros(m, np.float64)
    dmask = rows == cols
    d[rows[dmask]] = v3[dmask]
    d = np.where(d > 0, d, 1.0)
    x = np.abs(np.asarray(b, np.float64)).reshape(-1) / d
    acc = x.copy()
    off = np.where(dmask, 0.0, v3)
    for _ in range(hops):
        y = np.zeros(m, np.float64)
        np.add.at(y, rows, off * x[cols])
        x = y / d
        acc += x
    return acc


def solve_adaptive(
    a: GSECSR,
    b: jnp.ndarray,
    precond=None,
    x0: jnp.ndarray | None = None,
    tol: float = 1e-6,
    maxiter: int = 5000,
    params: P.MonitorParams | None = None,
    chunk: int | None = None,
    promote_frac: float = 0.1,
    tags0=None,
    group_size: int = GROUP_SIZE,
    profile: str = "explore",
    probe_iters: int = 0,
    theta: float = 0.25,
    beta: float = 2.0,
) -> AdaptiveResult:
    """Data-driven per-group precision CG/PCG (``tags="adaptive"``).

    ``a`` must be a packed ``GSECSR`` (the floor model reads the flat
    packed segments; pass the CSR pack even if you normally solve
    through a SELL view -- the masked operand rides the same fused
    iteration).  ``precond`` selects PCG for the MAIN solve: a
    ``solvers.precond`` object (fused path) or any callable
    ``apply_m(r, tag)``; the optional planning probe always uses its
    own host-built Jacobi regardless.

    ``profile`` picks where the planner's solution-magnitude estimate
    comes from:

    - ``"explore"`` (default): no upfront plan -- run uniform tag 1 and
      plan ONCE from the live iterate when its recursive residual first
      crosses ``beta * tol`` (i.e. near recursive exhaustion, where the
      iterate is sharp and the restarted tail is short; an EARLY
      restart re-pays the Krylov plateau on clustered spectra).
    - ``"neumann"``: plan upfront from the free one-hop absolute
      Neumann profile (good on diagonally-dominant / hub structure).
    - ``"probe"``: plan upfront from a billed Jacobi-preconditioned
      tag-1 probe of ``probe_iters`` iterations.

    ``theta`` is the planner's headroom -- the planned map's modeled
    floor must fit in ``theta * tol * ||b||``.  ``tags0`` (a map or
    int) BYPASSES profiling and seeds the solve directly -- the escape
    hatch for callers that planned externally.  ``chunk`` is the host
    true-residual cadence in iterations; ``promote_frac`` the fraction
    of groups promoted when a reactive replan finds its own model
    already under budget.  Whatever the profile, a solve whose
    recurrence exhausts while the true residual misses ``tol`` replans
    reactively from the current iterate and restarts.
    """
    from repro.kernels.ops import masked_for_tagmap
    from repro.solvers.cg import (_gsecsr_operator, _normalize_b_x0,
                                  _pin_params, _solve_cg_fused, _solve_pcg,
                                  _solve_pcg_fused)
    from repro.solvers.fused_cg import gse_matvec

    if not isinstance(a, GSECSR):
        raise TypeError(
            "solve_adaptive needs a packed GSECSR operand (the floor "
            f"model reads its flat segments); got {type(a).__name__}")
    if profile not in ("explore", "neumann", "probe"):
        raise ValueError(f"unknown profile {profile!r}")
    b, x0, orig_shape = _normalize_b_x0(b, x0)
    x = jnp.zeros_like(b) if x0 is None else x0
    if params is None:
        params = P.MonitorParams.for_cg()
    if chunk is None:
        # The per-chunk TRUE-residual check costs one tag-3 pass
        # (~2 iterations' worth of the cheapest stream), so a cadence of
        # ~100 iterations keeps the overhead under ~2% while stopping
        # the solve the moment the real residual fits.
        chunk = max(1, min(params.m, 100, maxiter))
    m = int(a.shape[0])
    # Segment recurrence target: the quadrature complement of the
    # planned floor budget, sqrt(tol^2 - (theta*tol)^2).  A planned map
    # carries a modeled floor <= theta * tol * ||b||, so stopping the
    # recurrence there still lands the TRUE residual inside tol; any
    # deeper recursive target burns real iterations grinding below what
    # the floor admits.  The explore segment uses the same target: if
    # the uniform tag-1 floor is tiny the boundary true-check accepts
    # right there, and otherwise the replan only needs the iterate as a
    # PROFILE, whose trim plateaus in quality well above this depth.
    seg_tol = tol * float(np.sqrt(max(1.0 - theta * theta, 0.25)))
    bnorm = float(jnp.linalg.norm(b))
    bnorm = 1.0 if bnorm == 0 else bnorm
    promotions: list[Promotion] = []
    bytes_ = 0
    probe_done = 0

    with OT.span("solve.adaptive", n=m, tol=float(tol), chunk=int(chunk)):
        planned = True  # an upfront plan / explicit seed disables beta-replan
        if tags0 is not None:
            tm = _init_map(tags0, m, group_size)
        elif profile == "neumann":
            xh = _abs_neumann_profile(a, np.asarray(b))
            tm = P.plan_tagmap(P.decode_error_scores(a, xh, group_size),
                               theta * tol * bnorm, group_size=group_size)
            promotions.append(Promotion(
                0, int((tm.tags > 1).sum()), tm.min_tag, tm.max_tag,
                tm.crc32))
        elif profile == "probe":
            pr = _solve_pcg(_gsecsr_operator(a), _probe_jacobi(a), b, x,
                            jnp.asarray(0.0, b.dtype), max(int(probe_iters), 1),
                            _pin_params(params, 1), init_tag=1,
                            guards=None, flight=None)
            probe_done = int(pr.iters)
            bytes_ += (probe_done + 1) * a.bytes_touched(1)
            xh = np.abs(np.asarray(pr.x))
            if not np.isfinite(xh).all() or xh.max() == 0:
                xh = np.abs(np.asarray(b))
            else:
                xh = _trim(xh, float(pr.relres))
            tm = P.plan_tagmap(P.decode_error_scores(a, xh, group_size),
                               theta * tol * bnorm, group_size=group_size)
            promotions.append(Promotion(
                0, int((tm.tags > 1).sum()), tm.min_tag, tm.max_tag,
                tm.crc32))
        else:
            tm = TagMap.for_rows(m, 1, group_size)
            planned = False

        if precond is None:
            def run_chunk(a_eff, x_start, state, stop, pinned, itag, st):
                return _solve_cg_fused(a_eff, b, x_start, st, maxiter,
                                       pinned, init_tag=itag, guards=None,
                                       flight=None, resume=state,
                                       stop_at=stop, return_state=True)
        elif hasattr(precond, "apply_at"):
            def run_chunk(a_eff, x_start, state, stop, pinned, itag, st):
                return _solve_pcg_fused(a_eff, precond, b, x_start, st,
                                        maxiter, pinned, init_tag=itag,
                                        guards=None, flight=None,
                                        resume=state, stop_at=stop,
                                        return_state=True)
        else:
            apply_m = precond if callable(precond) else precond.apply

            def run_chunk(a_eff, x_start, state, stop, pinned, itag, st):
                return _solve_pcg(_gsecsr_operator(a_eff), apply_m, b,
                                  x_start, st, maxiter, pinned,
                                  init_tag=itag, guards=None, flight=None,
                                  resume=state, stop_at=stop,
                                  return_state=True)

        def true_relres(xv) -> float:
            return float(jnp.linalg.norm(b - gse_matvec(a, xv, jnp.int32(3)))
                         / bnorm)

        def replan(tm, xv, rel, glob, force):
            """(Re)plan from the live iterate: its magnitudes ARE the
            solution profile any seed could only approximate, resolved
            to about its own true-residual scale.  ``force`` (the
            recurrence-exhausted path) escalates the worst still-open
            contributors even when the model thinks the map already
            fits the budget -- the model underpredicted, so escalation
            must make progress unconditionally."""
            sc = P.decode_error_scores(
                a, _trim(np.abs(np.asarray(xv)), rel), group_size)
            tm2 = P.plan_tagmap(sc, theta * tol * bnorm, tags0=tm,
                                group_size=group_size)
            if force and tm2 == tm:
                tm2 = P.promote_groups(
                    tm, P.map_floor_contrib(sc, tm.tags), frac=promote_frac)
            if tm2 != tm:
                promotions.append(Promotion(
                    glob, int((tm2.tags != tm.tags).sum()),
                    tm2.min_tag, tm2.max_tag, tm2.crc32))
            return tm2

        # ``res.iters`` counts from the start of the current SEGMENT (a
        # restart re-enters the jitted init); ``seg_off`` accumulates the
        # prior segments so every reported/billed iteration is global.
        # Every chunk boundary measures the TRUE tag-3 residual (billed):
        # it is simultaneously the convergence test (stop the moment the
        # real residual fits, even while the recursive one lags), the
        # explore-phase plan trigger, and the final verify.  There is NO
        # rate-based stall heuristic -- on slow spectra the true and
        # recursive residuals plateau TOGETHER mid-run (measured: 3% per
        # 100 iterations with true/rec ratio 1.00), so any plateau
        # detector either false-fires there or is subsumed by the
        # recurrence-exhausted condition below.
        state = None
        seg_off = 0
        seg_it = 0
        chunks = 0
        exhausted = False
        demoted = False
        res = None
        tr = np.inf

        while True:
            a_eff = masked_for_tagmap(a, tm)
            pinned = _pin_params(params, tm.max_tag)
            if state is None:
                bytes_ += a.bytes_touched(tm)  # fresh initial residual SpMV
            stop = min(seg_it + chunk, max(maxiter - seg_off, 1))
            res, _, state = run_chunk(a_eff, x, state, jnp.int32(stop),
                                      pinned, tm.max_tag,
                                      jnp.asarray(seg_tol, b.dtype))
            chunks += 1
            new_seg_it = int(res.iters)
            bytes_ += (new_seg_it - seg_it) * a.bytes_touched(tm)
            glob = seg_off + new_seg_it
            relres = float(res.relres)
            tr = true_relres(res.x)
            bytes_ += a.bytes_touched(3)

            if tr <= tol or glob >= maxiter:
                break

            rec_done = np.isfinite(relres) and relres <= seg_tol
            plan_now = (not planned and np.isfinite(relres)
                        and relres <= beta * tol)

            if (planned and not demoted and not rec_done
                    and np.isfinite(relres) and tr > 3.0 * tol):
                # Demote pass (at most one adoption per solve): an
                # upfront plan came from an approximate profile and may
                # over-promote; once the live iterate has sharpened --
                # but while there is still enough distance to tol to
                # amortize a restart -- re-plan from scratch and adopt
                # a strictly cheaper map if the model finds one.
                tmf = P.plan_tagmap(
                    P.decode_error_scores(
                        a, _trim(np.abs(np.asarray(res.x)), tr), group_size),
                    theta * tol * bnorm, group_size=group_size)
                if (tmf != tm
                        and a.bytes_touched(tmf) < 0.93 * a.bytes_touched(tm)):
                    demoted = True
                    promotions.append(Promotion(
                        glob, int((tmf.tags != tm.tags).sum()),
                        tmf.min_tag, tmf.max_tag, tmf.crc32))
                    tm = tmf
                    x = res.x
                    state = None
                    seg_off = glob
                    seg_it = 0
                    continue

            if rec_done or plan_now or not np.isfinite(relres):
                tm2 = replan(tm, res.x, tr, glob, force=rec_done)
                planned = True
                if tm2 == tm:
                    if rec_done:
                        if exhausted:
                            break  # fully promoted and restarted once
                        exhausted = tm.min_tag == 3
                    else:
                        # Explore-phase plan kept the uniform map: no
                        # operand change, keep the recurrence running.
                        seg_it = new_seg_it
                        continue
                tm = tm2
                x = res.x
                state = None
                seg_off = glob
                seg_it = 0
                continue

            seg_it = new_seg_it

    res_x = res.x.reshape(orig_shape) if res.x.shape != orig_shape else res.x
    return AdaptiveResult(
        x=res_x,
        iters=seg_off + int(res.iters),
        relres=float(res.relres),
        true_relres=float(tr) if np.isfinite(tr) else true_relres(res.x),
        converged=bool(np.isfinite(tr) and tr <= tol),
        tagmap=tm,
        promotions=tuple(promotions),
        spmv_bytes=int(bytes_),
        chunks=chunks,
        probe_iters=probe_done,
    )
