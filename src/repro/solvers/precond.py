"""GSE-packed preconditioners that ride the operator's tag schedule.

Carson & Khan (arXiv:2307.03914) and Loe et al. (arXiv:2109.01232) both
find that the preconditioner application is where mixed precision pays
off most in Krylov solvers.  GSE-SEM's one-copy/three-precision storage
is a perfect fit: the preconditioner entries are packed ONCE and every
apply streams them at the residual monitor's *current* tag -- the same
``lax.switch`` discipline as ``make_gse_operator``, so a tag-1 apply
streams 2 bytes per stored entry (DESIGN.md §10).

Two application paths, both through the existing tag-specialized decode:

  * Diagonal preconditioners (Jacobi, SPAI-0) store ``M^{-1}``'s diagonal
    as a dense ``GSEPacked`` vector and apply via the dense decode
    (``core.gse._decode_jnp``, DESIGN.md §2.1): tag-1/-2 branches never
    reference the tail segments.
  * Block-Jacobi stores the block-diagonal inverse as a ``GSECSR`` and
    applies via ``spmv_gse`` (``sparse.spmv._decode_gsecsr``) -- exactly
    the operator's own SpMV decode path.

Every preconditioner answers ``bytes_touched(tag)`` (modeled HBM bytes
one apply streams) so the solver benchmarks can charge the preconditioner
stream at the per-iteration tag actually run (``benchmarks/fig89``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gse
from repro.sparse.csr import CSR, GSECSR, from_coo, pack_csr
from repro.sparse.spmv import spmv_gse

__all__ = [
    "DiagGSEPrecond",
    "BlockJacobiGSEPrecond",
    "make_jacobi",
    "make_spai0",
    "make_block_jacobi",
]


class _TagDispatchPrecond:
    """Shared traced-tag dispatch: ``lax.switch`` over the three
    static-tag ``apply_at`` branches -- the preconditioner-side twin of
    ``make_gse_operator``.  The single implementation keeps the branch
    order / tag clipping identical across preconditioner kinds."""

    def apply(self, r: jnp.ndarray, tag, acc_dtype=jnp.float64):
        """``z = M^{-1} r`` with a traced tag in {1, 2, 3}."""
        return jax.lax.switch(
            jnp.clip(tag - 1, 0, 2),
            [partial(self.apply_at, tag=t, acc_dtype=acc_dtype) for t in (1, 2, 3)],
            r,
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(eq=False)            # identity hash: bound methods
class DiagGSEPrecond(_TagDispatchPrecond):  # are usable as static jit args
    """Diagonal ``M^{-1}`` stored as a dense GSE-SEM vector (one copy,
    three apply precisions)."""

    packed: gse.GSEPacked  # (n,) packed entries of M^{-1}'s diagonal
    kind: str              # static: "jacobi" | "spai0"

    def apply_at(self, r: jnp.ndarray, tag: int, acc_dtype=jnp.float64):
        """``z = M^{-1} r`` at a *static* tag (dense decode path, §2.1)."""
        d = gse.decode_jnp(self.packed, tag, acc_dtype)
        return d * r.astype(acc_dtype)

    def nbytes(self, tag: int) -> int:
        return self.packed.nbytes(tag)

    def bytes_touched(self, tag: int) -> int:
        """Modeled HBM bytes one tag-``tag`` apply streams for the stored
        preconditioner (the dense r/z traffic is format-independent)."""
        return self.packed.nbytes(tag)

    def tree_flatten(self):
        return (self.packed,), (self.kind,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], kind=aux[0])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(eq=False)
class BlockJacobiGSEPrecond(_TagDispatchPrecond):
    """Block-diagonal ``M^{-1}`` stored as a GSE-SEM CSR; applies through
    the operator's own tag-specialized SpMV decode path (§2.4)."""

    mat: GSECSR  # block-diagonal inverse, GSE-packed
    block: int   # static

    kind = "block_jacobi"

    def apply_at(self, r: jnp.ndarray, tag: int, acc_dtype=jnp.float64):
        return spmv_gse(self.mat, r, tag=tag, acc_dtype=acc_dtype)

    def nbytes(self, tag: int) -> int:
        return self.mat.nbytes(tag)

    def bytes_touched(self, tag: int) -> int:
        return self.mat.bytes_touched(tag)

    def tree_flatten(self):
        return (self.mat,), (self.block,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], block=aux[0])


def _csr_diag(a: CSR) -> np.ndarray:
    """Diagonal of a CSR (missing entries -> 0)."""
    rows = np.asarray(a.row_ids)
    cols = np.asarray(a.col)
    vals = np.asarray(a.val, np.float64)
    d = np.zeros(a.shape[0], np.float64)
    hit = rows == cols
    d[rows[hit]] = vals[hit]
    return d


def make_jacobi(a: CSR, k: int = 8) -> DiagGSEPrecond:
    """Jacobi: ``M^{-1} = diag(A)^{-1}``, packed once against ``k`` shared
    exponents.  Zero diagonal entries fall back to 1 (identity row)."""
    d = _csr_diag(a)
    d_inv = np.where(d != 0, 1.0 / np.where(d == 0, 1.0, d), 1.0)
    return DiagGSEPrecond(packed=gse.pack(d_inv, k), kind="jacobi")


def make_spai0(a: CSR, k: int = 8) -> DiagGSEPrecond:
    """SPAI-0: the diagonal ``M`` minimizing ``||I - M A||_F`` --
    ``m_i = a_ii / ||A_{i,:}||^2`` (Carson-Khan's static-pattern sparse
    approximate inverse restricted to the diagonal pattern)."""
    rows = np.asarray(a.row_ids)
    vals = np.asarray(a.val, np.float64)
    row_sq = np.zeros(a.shape[0], np.float64)
    np.add.at(row_sq, rows, vals * vals)
    d = _csr_diag(a)
    m = np.where(row_sq != 0, d / np.where(row_sq == 0, 1.0, row_sq), 1.0)
    m = np.where(m == 0, 1.0, m)
    return DiagGSEPrecond(packed=gse.pack(m, k), kind="spai0")


def make_block_jacobi(a: CSR, block: int = 4, k: int = 8) -> BlockJacobiGSEPrecond:
    """Block-Jacobi: invert each ``block x block`` diagonal block of A and
    pack the block-diagonal inverse as a ``GSECSR``.

    The trailing partial block is padded with identity rows before the
    batched inverse, then the padding is dropped.  Blocks must be
    nonsingular (guaranteed for SPD / strictly diagonally dominant A).
    """
    n = a.shape[0]
    nb = (n + block - 1) // block
    rows = np.asarray(a.row_ids)
    cols = np.asarray(a.col)
    vals = np.asarray(a.val, np.float64)

    dense = np.zeros((nb, block, block), np.float64)
    same = rows // block == cols // block
    br, bc, bv = rows[same], cols[same], vals[same]
    dense[br // block, br % block, bc % block] = bv
    # Identity-pad rows beyond n so every block inverts cleanly.
    pad = np.arange(nb * block)[n:]
    dense[pad // block, pad % block, pad % block] = 1.0

    inv = np.linalg.inv(dense)
    bi, ri, ci = np.meshgrid(
        np.arange(nb), np.arange(block), np.arange(block), indexing="ij"
    )
    out_r = (bi * block + ri).ravel()
    out_c = (bi * block + ci).ravel()
    out_v = inv.ravel()
    keep = (out_r < n) & (out_c < n) & (out_v != 0)
    m = from_coo(out_r[keep], out_c[keep], out_v[keep], (n, n))
    return BlockJacobiGSEPrecond(mat=pack_csr(m, k), block=block)
