"""Batched multi-RHS stepped solvers: per-column precision schedules over
one shared operand (DESIGN.md §11).

The paper's case is that SpMV is memory-bound, so GSE-SEM wins by
streaming fewer matrix bytes per iteration; with ``nrhs`` right-hand
sides the SAME packed segments serve every column in one pass, so the
matrix stream is charged once per iteration however wide the batch is
(``csr.iteration_stream_bytes(..., nrhs=...)``).  Loe et al.
(arXiv:2109.01232) show precision schedules must adapt per solve --
different right-hand sides converge at different rates -- so each column
here carries its OWN residual monitor, its own tag schedule, and its own
switch-iteration log, and deactivates independently on convergence.

Bit-identity contract (the subsystem's acceptance bar): column ``j`` of a
batched solve runs EXACTLY the op sequence of an independent
``solve_cg``/``solve_pcg`` on ``b[:, j]`` -- the batch body unrolls the
same per-column ``fused_cg_step``/``fused_pcg_step`` (or generic-body
ops) at each column's own traced tag via the same ``lax.switch``
dispatch, and converged columns are frozen behind a per-column
``lax.cond`` -- they skip their SpMV/decode entirely instead of being
dragged further.  Columns that share a tag share one decoded-value pass
under XLA CSE (the in-jaxpr form of "tag-bucketed sub-batches"); columns
at different tags split into their own branches.  The kernels-path twin
(``kernels/ops.gse_spmm_ell``) streams the union pass explicitly.

``batched_run_bytes`` is the fig89-style account of a whole batched run:
per iteration the matrix (+preconditioner) segments are charged ONCE at
the widest tag any active column runs, and each active column beyond the
first charges its dense x/y stream.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import precision as P
from repro.obs import flight as OF
from repro.obs import trace as OT
from repro.robustness.guards import (
    DEFAULT_GUARDS,
    GuardParams,
    HEALTH_NONFINITE,
    HEALTH_OK,
    HEALTH_STALLED,
    finalize_health,
    guard_init,
    guard_step,
)
from repro.sparse.csr import GSECSR, GSESellC, iteration_stream_bytes
from repro.solvers.cg import _record_switch

__all__ = [
    "BatchedCGResult",
    "BatchedIRResult",
    "solve_cg_batched",
    "solve_pcg_batched",
    "solve_ir_batched",
    "batched_run_bytes",
    "column_tags_at",
]


def _batched_tag_axis(tags, apply_a, m, params):
    """Normalize the batched wrappers' ``tags=`` axis (PR 10).

    Returns ``(init_tag, apply_a, params)``: ints and uniform maps
    override the starting tag on the untouched operand (same jaxpr --
    the uniform fast path); a non-uniform map swaps in the MASKED
    operand decoded at its max tag with the monitor pinned there, the
    same static-schedule semantics as single-RHS ``solve_cg(tags=tm)``.
    There is no per-group recovery ladder in-batch -- flagged columns
    go through the serving layer's tag-3 retry exactly as before.
    """
    if isinstance(tags, str):
        raise ValueError(
            "the batched solvers take an int tag or a TagMap; the "
            "'adaptive' driver is single-RHS (repro.solvers.adaptive)")
    from repro.solvers.cg import _normalize_tag_axis, _pin_params

    t, tm = _normalize_tag_axis(tags, apply_a, m)
    if tm is None:
        return (1 if t is None else t), apply_a, params
    from repro.kernels.ops import masked_for_tagmap

    return tm.max_tag, masked_for_tagmap(apply_a, tm), _pin_params(
        params, tm.max_tag)


class BatchedCGResult(NamedTuple):
    x: jnp.ndarray             # (n, nrhs) solutions
    iters: jnp.ndarray         # (nrhs,) iterations executed per column
    relres: jnp.ndarray        # (nrhs,) final recursive relative residuals
    tag: jnp.ndarray           # (nrhs,) final precision tag per column
    switch_iters: jnp.ndarray  # (nrhs, 2) iteration of tag->2 / tag->3 (-1: never)
    converged: jnp.ndarray     # (nrhs,) bool
    # Robustness (DESIGN.md §14): per-column health codes
    # (robustness.guards.HEALTH_*) and first guard-trip iteration (-1:
    # never).  A tripped column freezes (stops iterating) immediately;
    # recovery for batched requests is the SERVING layer's bounded
    # tag-3 retry (launch.solver_serve), not an in-batch escalation.
    health: jnp.ndarray = HEALTH_OK    # (nrhs,) int32
    trip_iter: jnp.ndarray = -1        # (nrhs,) int32
    # Observability (DESIGN.md §16): stacked per-column flight-recorder
    # states (leading nrhs axis; None when recording is off).  Split with
    # ``obs.flight.split_batched`` and decode each column with
    # ``FlightLog.from_state``.
    flight: object = None


class BatchedIRResult(NamedTuple):
    x: jnp.ndarray             # (n, nrhs)
    outer_iters: np.ndarray    # (nrhs,) correction steps per column
    inner_iters: np.ndarray    # (nrhs,) total inner iterations per column
    relres: np.ndarray         # (nrhs,) final TRUE (tag-3) relative residuals
    converged: np.ndarray      # (nrhs,) bool
    history: list              # nrhs lists of outer residual trajectories
    # Per-column health codes, derived as in solvers.ir.IRResult.
    health: np.ndarray = None  # (nrhs,) int
    # Observability (DESIGN.md §16): list of stacked per-correction flight
    # states (one per inner batched solve), as in BatchedCGResult.flight.
    flight: object = None


def _maybe_sharded(apply_a, wire: str):
    """Swap a ``PartitionedGSECSR`` operand for its memoized distributed
    operator closure (generic-path callable); anything else passes
    through untouched."""
    from repro.distributed.partition import PartitionedGSECSR

    if isinstance(apply_a, PartitionedGSECSR):
        from repro.kernels.dist_spmv import make_sharded_operator

        return make_sharded_operator(apply_a, wire)
    return apply_a


def _normalize_block(b, x0):
    """Accept ``b``/``x0`` as ``(n,)`` or ``(n, nrhs)`` blocks."""
    b = jnp.asarray(b)
    if b.ndim == 1:
        b = b[:, None]
    if b.ndim != 2:
        raise ValueError(f"b must be (n,) or (n, nrhs); got {b.shape}")
    if x0 is None:
        x0 = jnp.zeros_like(b)
    else:
        x0 = jnp.asarray(x0)
        if x0.ndim == 1:
            x0 = x0[:, None]
        if x0.shape != b.shape:
            raise ValueError(
                f"x0/b shape mismatch: {x0.shape} vs {b.shape}"
            )
        if x0.dtype != b.dtype:
            raise ValueError(f"x0/b dtype mismatch: {x0.dtype} vs {b.dtype}")
    return b, x0


def _batched_krylov_loop(b, x0, tol, maxiter, params, init_tag,
                         init_col, step_col, guards=None, flight=None,
                         resume=None, stop_at=None, return_state=False):
    """Shared batched while_loop: per-column monitors, masking, switches.

    ``init_col(b_j, x0_j, tag) -> dict`` builds one column's Krylov state
    (must contain ``rr`` = squared residual norm driving the monitor);
    ``step_col(col_state, tag) -> dict`` runs ONE iteration of the
    single-RHS solver body at a traced per-column tag.  Everything else
    (monitor record/update, switch logging, convergence masking, per-
    column iteration counts) is identical across CG and PCG.

    With ``guards`` (a ``GuardParams``), each column also carries its own
    guard state (DESIGN.md §14): ``step_col`` surfaces the curvature
    ``denom = p.Ap`` under key ``"denom"`` (popped before the carry so the
    loop state stays fixed-shape across the guarded/unguarded cond
    branches), PCG columns flag ``z.r < 0`` via their ``"rz"`` entry, and
    a tripped column freezes exactly like a converged one.  Guards run
    AFTER the iteration ops on scalars those ops already produced, so the
    per-column bit-identity contract with single-RHS solves is untouched.

    With ``flight`` (a ``FlightParams``), each column also carries its own
    flight-recorder ring (DESIGN.md §16) -- same observation-after-update
    discipline, recorder-on stays per-column bit-identical -- and the
    result stacks the per-column states along a leading nrhs axis.

    ``resume`` (DESIGN.md §17) carries a previous chunk's cols tuple
    verbatim (the init section is skipped); ``stop_at`` is a per-column
    ``(nrhs,)`` iteration bound ANDed into each column's liveness --
    a pure extra exit condition, so chunked == unchunked bitwise.
    ``return_state`` additionally returns the raw cols tuple.
    """
    nrhs = b.shape[1]
    bnorms = []
    for j in range(nrhs):
        bn = jnp.linalg.norm(b[:, j])
        bn = jnp.where(bn == 0, 1.0, bn)
        bnorms.append(bn)
    if resume is not None:
        cols = resume
    else:
        cols = []
        for j in range(nrhs):
            mon = P.init(params, dtype=b.dtype, tag=init_tag)
            c = init_col(b[:, j], x0[:, j], mon.tag)
            c.pop("denom", None)
            if guards is not None:
                c["g"] = guard_init(jnp.sqrt(jnp.abs(c["rr"])) / bnorms[j])
            if flight is not None:
                c["fl"] = OF.flight_init(flight, b.dtype)
            c.update(
                it=jnp.int32(0),
                mon=mon,
                sw=jnp.full((2,), -1, jnp.int32),
            )
            cols.append(c)
        cols = tuple(cols)

    def col_relres(c, j):
        return jnp.sqrt(jnp.abs(c["rr"])) / bnorms[j]

    def col_active(c, j):
        alive = (col_relres(c, j) > tol) & (c["it"] < maxiter)
        if stop_at is not None:
            alive = alive & (c["it"] < stop_at[j])
        if guards is not None:
            alive = alive & (c["g"]["health"] == HEALTH_OK)
        return alive

    def cond(cols):
        alive = [col_active(c, j) for j, c in enumerate(cols)]
        return jnp.stack(alive).any()

    def step_one(j):
        def run(c):
            stepped = step_col(c, c["mon"].tag)
            denom = stepped.pop("denom", None)
            relres_new = jnp.sqrt(jnp.abs(stepped["rr"])) / bnorms[j]
            if guards is not None:
                breakdown = False
                finite_aux = ()
                if "rz" in stepped:
                    breakdown = stepped["rz"] < 0
                    finite_aux = (stepped["rz"],)
                stepped["g"] = guard_step(
                    c["g"], c["it"], relres_new, guards,
                    denom=denom, breakdown=breakdown, finite_aux=finite_aux,
                )
            mon1 = P.record(c["mon"], relres_new)
            mon2 = P.update_tag(mon1, params)
            sw = _record_switch(c["sw"], mon1, mon2, c["it"])
            if flight is not None:
                # Observation-only alpha/beta from the scalars the step
                # already produced (rz-recurrence under PCG, rr under CG).
                old = c["rz"] if "rz" in c else c["rr"]
                new = stepped["rz"] if "rz" in stepped else stepped["rr"]
                alpha = old / jnp.where(denom == 0, 1.0, denom)
                beta = new / jnp.where(old == 0, 1.0, old)
                g = stepped.get("g")
                stepped["fl"] = OF.flight_record(
                    c["fl"], it=c["it"], relres=relres_new,
                    tag=c["mon"].tag,
                    health=g["health"] if g is not None else None,
                    a0=alpha, a1=beta, a2=denom,
                )
            stepped.update(it=c["it"] + 1, mon=mon2, sw=sw)
            return stepped

        return run

    def body(cols):
        # lax.cond (scalar predicate -> real branch, not a select): a
        # frozen column skips its SpMV/decode entirely instead of
        # computing a result that masking would discard -- the service's
        # padding columns cost nothing while real requests iterate.
        return tuple(
            jax.lax.cond(col_active(c, j), step_one(j), lambda c: c, c)
            for j, c in enumerate(cols)
        )

    cols = jax.lax.while_loop(cond, body, cols)
    relres = jnp.stack([col_relres(c, j) for j, c in enumerate(cols)])
    if guards is not None:
        per_col = [
            finalize_health(
                c["g"],
                col_relres(c, j) <= tol,
                col_relres(c, j),
                x_finite=jnp.isfinite(jnp.vdot(c["x"], c["x"])),
            )
            for j, c in enumerate(cols)
        ]
        health = jnp.stack([h for h, _ in per_col])
        trip_iter = jnp.stack([t for _, t in per_col])
        converged = (relres <= tol) & jnp.stack(
            [jnp.isfinite(jnp.vdot(c["x"], c["x"])) for c in cols]
        )
    else:
        health = jnp.full((nrhs,), HEALTH_OK, jnp.int32)
        trip_iter = jnp.full((nrhs,), -1, jnp.int32)
        converged = relres <= tol
    res = BatchedCGResult(
        x=jnp.stack([c["x"] for c in cols], axis=1),
        iters=jnp.stack([c["it"] for c in cols]),
        relres=relres,
        tag=jnp.stack([c["mon"].tag for c in cols]),
        switch_iters=jnp.stack([c["sw"] for c in cols]),
        converged=converged,
        health=health,
        trip_iter=trip_iter,
        flight=(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                       *[c["fl"] for c in cols])
                if flight is not None else None),
    )
    return (res, cols) if return_state else res


# ---------------------------------------------------------------------------
# Batched CG
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("maxiter", "params", "init_tag", "guards",
                                   "flight", "return_state"))
def _solve_cg_batched_fused(a, b, x0, tol, maxiter, params, init_tag=1,
                            guards=None, flight=None, resume=None,
                            stop_at=None, return_state=False):
    from repro.solvers.fused_cg import (fused_cg_step, fused_cg_step_g,
                                        gse_matvec)

    def init_col(bj, xj, tag):
        r0 = bj - gse_matvec(a, xj, tag)
        rs = jnp.vdot(r0, r0)
        return dict(x=xj, r=r0, p=r0, rr=rs)

    def step_col(c, tag):
        if guards is None and flight is None:
            x, r, p, rs = fused_cg_step(a, c["x"], c["r"], c["p"],
                                        c["rr"], tag)
            return dict(x=x, r=r, p=p, rr=rs)
        x, r, p, rs, denom = fused_cg_step_g(a, c["x"], c["r"], c["p"],
                                             c["rr"], tag)
        return dict(x=x, r=r, p=p, rr=rs, denom=denom)

    return _batched_krylov_loop(b, x0, tol, maxiter, params, init_tag,
                                init_col, step_col, guards, flight,
                                resume=resume, stop_at=stop_at,
                                return_state=return_state)


@partial(jax.jit, static_argnames=("apply_a", "maxiter", "params", "init_tag",
                                   "guards", "flight", "return_state"))
def _solve_cg_batched(apply_a, b, x0, tol, maxiter, params, init_tag=1,
                      guards=None, flight=None, resume=None, stop_at=None,
                      return_state=False):
    def init_col(bj, xj, tag):
        r0 = bj - apply_a(xj, tag)
        rs = jnp.vdot(r0, r0)
        return dict(x=xj, r=r0, p=r0, rr=rs)

    def step_col(c, tag):
        # EXACTLY the _solve_cg body ops, in order (bit-identity contract).
        ap = apply_a(c["p"], tag)
        denom = jnp.vdot(c["p"], ap)
        alpha = c["rr"] / jnp.where(denom == 0, 1.0, denom)
        x = c["x"] + alpha * c["p"]
        r = c["r"] - alpha * ap
        rs_new = jnp.vdot(r, r)
        beta = rs_new / jnp.where(c["rr"] == 0, 1.0, c["rr"])
        p = r + beta * c["p"]
        out = dict(x=x, r=r, p=p, rr=rs_new)
        if guards is not None or flight is not None:
            out["denom"] = denom
        return out

    return _batched_krylov_loop(b, x0, tol, maxiter, params, init_tag,
                                init_col, step_col, guards, flight,
                                resume=resume, stop_at=stop_at,
                                return_state=return_state)


def solve_cg_batched(
    apply_a: Union[Callable, GSECSR],
    b: jnp.ndarray,
    x0: jnp.ndarray | None = None,
    tol: float = 1e-6,
    maxiter: int = 5000,
    params: P.MonitorParams | None = None,
    wire: str = "exact",
    guards: GuardParams | None = DEFAULT_GUARDS,
    flight: OF.FlightParams | None = None,
    tags=None,
) -> BatchedCGResult:
    """Stepped CG over an (n, nrhs) right-hand-side block.

    One shared operand, ``nrhs`` independent per-column precision
    schedules: each column carries its own residual monitor and steps its
    own tag, deactivating when it converges.  Column ``j``'s trajectory is
    bit-identical to ``solve_cg(apply_a, b[:, j], ...)`` with the same
    parameters -- same iterates, same iteration count, same switch
    iterations (tested in tests/test_batched.py).

    Passing a ``GSECSR`` selects the fused per-column iteration
    (``fused_cg_step``), exactly as in single-RHS ``solve_cg``.  Passing a
    ``PartitionedGSECSR`` rides the row-sharded distributed operator
    (``kernels.dist_spmv.make_sharded_operator``; ``wire`` picks the halo
    wire format, DESIGN.md §13) through the generic per-column body --
    column ``j`` stays bit-identical to the sharded single-RHS solve's
    operator applications.  The modeled per-iteration traffic of the
    batch is ``iteration_stream_bytes(a, tag, nrhs=n_active)`` -- matrix
    bytes once, vector bytes per active column; ``batched_run_bytes``
    accounts a whole run from the per-column results.

    ``guards`` attaches per-column breakdown/divergence/non-finite/stall
    detection (DESIGN.md §14); a tripped column freezes and reports its
    health code.  There is no in-batch tag escalation -- the serving
    layer retries flagged columns at tag 3 (``launch.solver_serve``).
    ``guards=None`` compiles the pre-guard loop.

    ``tags`` (PR 10, DESIGN.md §18): an int or uniform
    :class:`~repro.core.tagmap.TagMap` starts every column's monitor at
    that tag (same jaxpr, bit-identical); a NON-uniform map runs the
    static masked-operand schedule for the whole batch -- per-column
    in-loop stepping is pinned off, exactly as in single-RHS
    ``solve_cg(tags=tm)``.
    """
    b, x0 = _normalize_block(b, x0)
    if params is None:
        params = P.MonitorParams.for_cg()
    tol_ = jnp.asarray(tol, b.dtype)
    init_tag, apply_a, params = _batched_tag_axis(
        tags, apply_a, int(b.shape[0]), params)
    apply_a = _maybe_sharded(apply_a, wire)
    with OT.span("solve.cg_batched", n=int(b.shape[0]),
                 nrhs=int(b.shape[1]), tol=float(tol)):
        if isinstance(apply_a, (GSECSR, GSESellC)):
            return _solve_cg_batched_fused(apply_a, b, x0, tol_, maxiter,
                                           params, init_tag=init_tag,
                                           guards=guards, flight=flight)
        return _solve_cg_batched(apply_a, b, x0, tol_, maxiter, params,
                                 init_tag=init_tag, guards=guards,
                                 flight=flight)


# ---------------------------------------------------------------------------
# Batched PCG
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("maxiter", "params", "init_tag", "guards",
                                   "flight", "return_state"))
def _solve_pcg_batched_fused(a, m, b, x0, tol, maxiter, params, init_tag=1,
                             guards=None, flight=None, resume=None,
                             stop_at=None, return_state=False):
    from repro.solvers.fused_cg import (fused_pcg_step, fused_pcg_step_g,
                                        gse_matvec)

    def init_col(bj, xj, tag):
        r0 = bj - gse_matvec(a, xj, tag)
        z0 = m.apply(r0, tag)
        return dict(x=xj, r=r0, p=z0, rz=jnp.vdot(r0, z0),
                    rr=jnp.vdot(r0, r0))

    def step_col(c, tag):
        if guards is None and flight is None:
            x, r, p, rz, rr = fused_pcg_step(
                a, m, c["x"], c["r"], c["p"], c["rz"], tag
            )
            return dict(x=x, r=r, p=p, rz=rz, rr=rr)
        x, r, p, rz, rr, denom = fused_pcg_step_g(
            a, m, c["x"], c["r"], c["p"], c["rz"], tag
        )
        return dict(x=x, r=r, p=p, rz=rz, rr=rr, denom=denom)

    return _batched_krylov_loop(b, x0, tol, maxiter, params, init_tag,
                                init_col, step_col, guards, flight,
                                resume=resume, stop_at=stop_at,
                                return_state=return_state)


@partial(jax.jit, static_argnames=("apply_a", "apply_m", "maxiter", "params",
                                   "init_tag", "guards", "flight",
                                   "return_state"))
def _solve_pcg_batched(apply_a, apply_m, b, x0, tol, maxiter, params,
                       init_tag=1, guards=None, flight=None, resume=None,
                       stop_at=None, return_state=False):
    def init_col(bj, xj, tag):
        r0 = bj - apply_a(xj, tag)
        z0 = apply_m(r0, tag)
        return dict(x=xj, r=r0, p=z0, rz=jnp.vdot(r0, z0),
                    rr=jnp.vdot(r0, r0))

    def step_col(c, tag):
        # EXACTLY the _solve_pcg body ops, in order (bit-identity contract).
        ap = apply_a(c["p"], tag)
        denom = jnp.vdot(c["p"], ap)
        alpha = c["rz"] / jnp.where(denom == 0, 1.0, denom)
        x = c["x"] + alpha * c["p"]
        r = c["r"] - alpha * ap
        z = apply_m(r, tag)
        rz_new = jnp.vdot(r, z)
        rr_new = jnp.vdot(r, r)
        beta = rz_new / jnp.where(c["rz"] == 0, 1.0, c["rz"])
        p = z + beta * c["p"]
        out = dict(x=x, r=r, p=p, rz=rz_new, rr=rr_new)
        if guards is not None or flight is not None:
            out["denom"] = denom
        return out

    return _batched_krylov_loop(b, x0, tol, maxiter, params, init_tag,
                                init_col, step_col, guards, flight,
                                resume=resume, stop_at=stop_at,
                                return_state=return_state)


def solve_pcg_batched(
    apply_a: Union[Callable, GSECSR],
    b: jnp.ndarray,
    precond,
    x0: jnp.ndarray | None = None,
    tol: float = 1e-6,
    maxiter: int = 5000,
    params: P.MonitorParams | None = None,
    wire: str = "exact",
    guards: GuardParams | None = DEFAULT_GUARDS,
    flight: OF.FlightParams | None = None,
    tags=None,
) -> BatchedCGResult:
    """Stepped preconditioned CG over an (n, nrhs) block.

    Both the operator and the GSE-packed preconditioner follow each
    column's OWN tag schedule; the stored segments of both are charged
    once per iteration however many columns ride along.  Column ``j`` is
    bit-identical to ``solve_pcg(apply_a, b[:, j], precond, ...)``.
    ``PartitionedGSECSR`` operands ride the distributed operator exactly
    as in :func:`solve_cg_batched`.  ``guards`` works as in
    :func:`solve_cg_batched`, additionally flagging ``z.r < 0``
    (indefinite-preconditioner breakdown) per column.
    ``tags`` works as in :func:`solve_cg_batched`; with a non-uniform map
    the preconditioner stream rides the map's MAX tag (the conservative
    charge ``iteration_stream_bytes`` models).
    """
    b, x0 = _normalize_block(b, x0)
    if params is None:
        params = P.MonitorParams.for_cg()
    tol_ = jnp.asarray(tol, b.dtype)
    init_tag, apply_a, params = _batched_tag_axis(
        tags, apply_a, int(b.shape[0]), params)
    apply_a = _maybe_sharded(apply_a, wire)
    with OT.span("solve.pcg_batched", n=int(b.shape[0]),
                 nrhs=int(b.shape[1]), tol=float(tol)):
        if isinstance(apply_a, (GSECSR, GSESellC)) and hasattr(precond,
                                                               "apply_at"):
            return _solve_pcg_batched_fused(apply_a, precond, b, x0, tol_,
                                            maxiter, params,
                                            init_tag=init_tag,
                                            guards=guards, flight=flight)
        apply_m = precond if callable(precond) else precond.apply
        if isinstance(apply_a, (GSECSR, GSESellC)):
            from repro.solvers.cg import _gsecsr_operator

            apply_a = _gsecsr_operator(apply_a)
        return _solve_pcg_batched(apply_a, apply_m, b, x0, tol_, maxiter,
                                  params, init_tag=init_tag, guards=guards,
                                  flight=flight)


# ---------------------------------------------------------------------------
# Batched iterative refinement (outer loop from solvers/ir.py)
# ---------------------------------------------------------------------------

def solve_ir_batched(
    apply_a: Union[Callable, GSECSR],
    b: jnp.ndarray,
    tol: float = 1e-10,
    max_outer: int = 10,
    inner_tol: float = 1e-4,
    inner_maxiter: int = 2000,
    params: P.MonitorParams | None = None,
    precond=None,
    wire: str = "exact",
    guards: GuardParams | None = DEFAULT_GUARDS,
    flight: OF.FlightParams | None = None,
    tags=None,
) -> BatchedIRResult:
    """Batched stepped iterative refinement (the ``solve_ir`` outer loop
    over an (n, nrhs) block, inner solves batched).

    Outer loop at tag 3 per column (the one-copy high-precision read),
    inner batched stepped CG/PCG starting every correction back at tag 1.
    Each column refines until ITS true residual meets ``tol`` and then
    drops out of the correction updates; the inner batch keeps streaming
    one matrix pass for whichever columns remain.  Active columns'
    trajectories match the single-RHS ``solve_ir`` exactly (the batched
    inner solve is per-column bit-identical and the outer ops are
    per-column).

    ``tags`` threads to the INNER batched solves only (ints/uniform maps
    start the inner monitors there; a non-uniform map runs the masked
    static schedule) -- the outer tag-3 residual always reads the
    UNMASKED operand, so the refinement target stays the true operator.
    """
    b = jnp.asarray(b)
    if b.ndim == 1:
        b = b[:, None]
    if params is None:
        params = P.MonitorParams.for_cg()
    nrhs = b.shape[1]

    apply_a = _maybe_sharded(apply_a, wire)
    if isinstance(apply_a, (GSECSR, GSESellC)):
        from repro.solvers.cg import _gsecsr_operator

        apply_tagged = _gsecsr_operator(apply_a)
    else:
        apply_tagged = apply_a

    def apply3_block(x_block):
        # Per-column tag-3 reads: identical arithmetic to solve_ir's apply3.
        return jnp.stack(
            [apply_tagged(x_block[:, j], jnp.int32(3)) for j in range(nrhs)],
            axis=1,
        )

    def col_norms(block):
        # Per-column 1-D norms, NOT an axis reduction: solve_ir's scalar
        # norm and jnp.linalg.norm(..., axis=0) can differ in the last
        # ulp, and the bit-identity contract extends to the history.
        return np.asarray(
            [float(jnp.linalg.norm(block[:, j])) for j in range(nrhs)]
        )

    bnorms = col_norms(b)
    bnorms = np.where(bnorms == 0, 1.0, bnorms)

    x = jnp.zeros_like(b)
    total_inner = np.zeros(nrhs, np.int64)
    outer = np.zeros(nrhs, np.int64)
    inner_health = np.zeros(nrhs, np.int64)
    r = b - apply3_block(x)
    relres = col_norms(r) / bnorms
    history = [[float(v)] for v in relres]
    flights = [] if flight is not None else None
    active = (relres > tol) & np.isfinite(relres) & (outer < max_outer)
    while active.any():
        mask = jnp.asarray(active)
        # Converged columns drop out of the inner batch NOW: zeroing their
        # residual column makes them converge at inner iteration 0 (the
        # ||b||=0 path, same trick as the service's padding columns), so
        # they stop burning inner iterations on corrections the mask
        # below would discard anyway.
        r_in = jnp.where(mask[None, :], r, 0.0)
        if precond is not None:
            res = solve_pcg_batched(apply_a, r_in, precond, tol=inner_tol,
                                    maxiter=inner_maxiter, params=params,
                                    guards=guards, flight=flight,
                                    tags=tags)
        else:
            res = solve_cg_batched(apply_a, r_in, tol=inner_tol,
                                   maxiter=inner_maxiter, params=params,
                                   guards=guards, flight=flight,
                                   tags=tags)
        if flights is not None and res.flight is not None:
            flights.append(res.flight)
        inner_health[active] = np.asarray(res.health)[active]
        # A non-finite correction column is never folded into x -- that
        # column deactivates carrying its inner health code.
        col_fin = np.asarray(jnp.isfinite(res.x).all(axis=0))
        take = mask & jnp.asarray(col_fin)
        x = jnp.where(take[None, :], x + res.x, x)
        iters = np.asarray(res.iters)
        conv = np.asarray(res.converged)
        total_inner[active] += iters[active]
        outer[active & col_fin] += 1
        r = b - apply3_block(x)
        relres = col_norms(r) / bnorms
        for j in range(nrhs):
            if active[j] and col_fin[j]:
                history[j].append(float(relres[j]))
        stalled = (~conv) & (iters == 0)  # no-progress guard, per column
        active = (active & (relres > tol) & np.isfinite(relres) & ~stalled
                  & col_fin & (outer < max_outer))
    converged = (relres <= tol) & np.isfinite(relres)
    health = np.where(
        converged, HEALTH_OK,
        np.where(~np.isfinite(relres), HEALTH_NONFINITE,
                 np.where(inner_health != HEALTH_OK, inner_health,
                          HEALTH_STALLED)),
    ).astype(np.int64)
    return BatchedIRResult(
        x=x,
        outer_iters=outer,
        inner_iters=total_inner,
        relres=relres,
        converged=converged,
        history=[np.asarray(h) for h in history],
        health=health,
        flight=flights,
    )


# ---------------------------------------------------------------------------
# Byte accounting for a whole batched run (fig89-style)
# ---------------------------------------------------------------------------

def column_tags_at(iters, switch_iters, it: int) -> np.ndarray:
    """Per-column tag at 0-based iteration ``it`` (0 for finished columns).

    Uses the ``switch_iters`` semantics of the single-RHS byte model
    (``benchmarks.fig89``): iterations ``[0, sw0)`` run at tag 1,
    ``[sw0, sw1)`` at tag 2, ``[sw1, iters)`` at tag 3; ``-1`` means the
    step never happened.
    """
    iters = np.asarray(iters)
    sw = np.asarray(switch_iters)
    nrhs = iters.shape[0]
    tags = np.zeros(nrhs, np.int64)
    for j in range(nrhs):
        if it >= iters[j]:
            continue  # column already converged: streams nothing
        t2 = sw[j, 0] if sw[j, 0] >= 0 else iters[j]
        t3 = sw[j, 1] if sw[j, 1] >= 0 else iters[j]
        tags[j] = 1 if it < t2 else (2 if it < t3 else 3)
    return tags


def batched_run_bytes(op, iters, switch_iters, precond=None) -> int:
    """Modeled HBM bytes a whole batched stepped run streams.

    Per iteration, the matrix (+preconditioner) segments are charged ONCE
    at the WIDEST tag any active column runs -- the shared streaming pass
    must read the union of the segments its columns need -- and every
    active column beyond the first charges its dense x/y stream
    (``iteration_stream_bytes(..., nrhs=n_active)``).  Converged columns
    stream nothing.  With ``nrhs == 1`` this reduces exactly to the
    single-RHS trajectory account of ``benchmarks.fig89``.
    """
    iters = np.asarray(iters)
    total = 0
    for it in range(int(iters.max(initial=0))):
        tags = column_tags_at(iters, switch_iters, it)
        n_active = int((tags > 0).sum())
        if n_active == 0:
            continue
        total += iteration_stream_bytes(
            op, int(tags.max()), precond, nrhs=n_active
        )
    return total
