"""Fully-sharded stepped CG/PCG: the whole Krylov loop inside shard_map.

The production posture for the distributed operator (DESIGN.md §13): the
vector state (x, r, p, z) lives row-sharded on the devices for the WHOLE
solve -- per-iteration traffic is the tag-aware halo exchange plus three
scalar ``psum`` reductions (the CG dots), never a full-vector gather.
The residual monitor (``core.precision``) runs replicated from the
psum'd residual norm, so every shard steps the SAME tag at the same
iteration -- one ``MonitorParams`` schedule drives all shards, exactly as
it drives the single-device fused path.

Contracts (tests/test_distributed.py):

  * 1 shard, ``wire="exact"``: bit-identical to ``solve_cg``/``solve_pcg``
    on the unsharded ``GSECSR`` (same decode, same op order, psum over one
    device is the identity);
  * k shards, ``wire="exact"``: the SpMV blocks are bitwise equal and only
    the dot-product summation ORDER changes (psum of per-shard partials),
    so trajectories track single-device to ~machine precision;
  * ``wire="gse"``: tag-1/2 halo payloads are head(+tail1) segments --
    lossy on boundary entries only; the recursive residual still converges
    (the monitor sees a slightly stronger low-tag perturbation, which is
    exactly the regime the stepped controller is built for).

``solve_cg``/``solve_pcg``/``solve_cg_batched``/``solve_pcg_batched``
dispatch here when handed a ``PartitionedGSECSR``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import gse
from repro.core import precision as Prec
from repro.distributed.partition import PartitionedGSECSR
from repro.kernels.dist_spmv import (
    AXIS,
    _blk,
    local_matvec,
    make_sharded_operator,
    shard_mesh,
)
from repro.obs import flight as OF
from repro.obs import trace as OT
from repro.robustness.guards import (
    DEFAULT_GUARDS,
    GuardParams,
    finalize_health,
    run_with_recovery,
)
from repro.solvers.cg import (
    CGResult,
    _finish_with_correction,
    _guarded_body,
    _guarded_cond,
    _guarded_init,
    _normalize_b_x0,
    _record_switch,
    _restore_shape,
)

__all__ = ["solve_cg_sharded", "solve_pcg_sharded"]


def _pdot(u, v):
    """Distributed dot: per-shard partial + psum (the ONE place sharded
    trajectories differ from single-device -- summation order)."""
    return jax.lax.psum(jnp.vdot(u, v), AXIS)


def _pad_to(x, n_padded):
    pad = n_padded - x.shape[0]
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1)) if pad else x


def _matvec_dispatch(blk, wire, k, rows, ei):
    """Traced-tag distributed matvec for use inside the sharded loop --
    same ``lax.switch`` discipline as ``fused_cg_step``, with the halo
    exchange and decode both inside each static-tag branch."""
    branches = [
        partial(local_matvec, blk, tag=t, wire=wire, k=k, rows=rows,
                ei_bit=ei)
        for t in (1, 2, 3)
    ]

    def matvec(v, tag):
        return jax.lax.switch(jnp.clip(tag - 1, 0, 2), branches, v)

    return matvec


def _diag_apply_dispatch(m_parts, ei_bit_m, frac_bits_m):
    """Traced-tag diagonal-preconditioner apply on this shard's slice of
    the packed ``M^{-1}`` diagonal -- elementwise, so the sliced decode is
    bitwise the slice of the full-vector decode (``DiagGSEPrecond``)."""
    m_head, m_tail1, m_tail2, m_table = m_parts

    def apply_at(r, tag: int, acc_dtype=jnp.float64):
        d = gse._decode_jnp(m_table, m_head, m_tail1, m_tail2, ei_bit_m,
                            frac_bits_m, tag, acc_dtype)
        return d * r.astype(acc_dtype)

    def apply(r, tag):
        return jax.lax.switch(
            jnp.clip(tag - 1, 0, 2),
            [partial(apply_at, tag=t) for t in (1, 2, 3)],
            r,
        )

    return apply, apply_at


def _sharded_loop_fn(part: PartitionedGSECSR, kind: str, wire: str,
                     maxiter: int, params, init_tag: int,
                     precond_meta=None, guards=None, flight=None):
    """Build (and memoize on the partition) the jitted shard_map solver.

    The per-device body mirrors ``_solve_cg_fused``/``_solve_pcg_fused``
    op for op; only the dots go through ``psum`` and the operator is the
    shard's local block + halo.  The guard state (DESIGN.md §14) runs on
    the psum'd replicated scalars -- every shard latches the SAME health
    code at the same iteration -- while the last-finite checkpoint stays
    row-sharded alongside x.
    """
    key = ("_sharded_solve", kind, wire, maxiter, params, init_tag,
           precond_meta, guards, flight)
    fn = part.__dict__.get(key)
    if fn is not None:
        return fn
    mesh = shard_mesh(part)
    rows, ei, k = part.rows_per_shard, part.ei_bit, int(part.table.size)

    def run(colpak, head, tail1, tail2, row_ids, bnd_idx, halo_idx, table,
            m_head, m_tail1, m_tail2, m_table, b, x0, tol, bnorm):
        blk = _blk(colpak, head, tail1, tail2, row_ids, bnd_idx, halo_idx,
                   table)
        matvec = _matvec_dispatch(blk, wire, k, rows, ei)
        mon = Prec.init(params, dtype=b.dtype, tag=init_tag)

        def relres(rs):
            return jnp.sqrt(jnp.abs(rs)) / bnorm

        if kind == "cg":
            r0 = b - matvec(x0, mon.tag)
            state = dict(x=x0, r=r0, p=r0, rs=_pdot(r0, r0),
                         it=jnp.int32(0), mon=mon,
                         switches=jnp.full((2,), -1, jnp.int32))
            state = _guarded_init(state, relres(state["rs"]), guards)
            if flight is not None:
                state["fl"] = OF.flight_init(flight, b.dtype)

            def body(s):
                # EXACTLY fused_cg_step's op order, dots psum'd.
                tag = s["mon"].tag
                ap = matvec(s["p"], tag)
                denom = _pdot(s["p"], ap)
                alpha = s["rs"] / jnp.where(denom == 0, 1.0, denom)
                x = s["x"] + alpha * s["p"]
                r = s["r"] - alpha * ap
                rs2 = _pdot(r, r)
                mon1 = Prec.record(s["mon"], relres(rs2))
                mon2 = Prec.update_tag(mon1, params)
                sw = _record_switch(s["switches"], mon1, mon2, s["it"])
                beta = rs2 / jnp.where(s["rs"] == 0, 1.0, s["rs"])
                p = r + beta * s["p"]
                out = dict(x=x, r=r, p=p, rs=rs2, it=s["it"] + 1,
                           mon=mon2, switches=sw)
                out = _guarded_body(s, out, relres(rs2), guards,
                                    denom=denom)
                if flight is not None:
                    # The recorded scalars are all psum'd/replicated, so
                    # every shard writes the SAME ring (out_spec P()).
                    g = out.get("g")
                    out["fl"] = OF.flight_record(
                        s["fl"], it=s["it"], relres=relres(rs2), tag=tag,
                        health=g["health"] if g is not None else None,
                        a0=alpha, a1=beta, a2=denom)
                return out

            def cond(s):
                return _guarded_cond(
                    s, (relres(s["rs"]) > tol) & (s["it"] < maxiter), guards
                )

            out = jax.lax.while_loop(cond, body, state)
            final_rel = relres(out["rs"])
        else:  # pcg
            m_apply, m_apply_at = _diag_apply_dispatch(
                (m_head, m_tail1, m_tail2, m_table), *precond_meta
            )
            r0 = b - matvec(x0, mon.tag)
            z0 = m_apply(r0, mon.tag)
            state = dict(x=x0, r=r0, p=z0, rz=_pdot(r0, z0),
                         rr=_pdot(r0, r0), it=jnp.int32(0), mon=mon,
                         switches=jnp.full((2,), -1, jnp.int32))
            state = _guarded_init(state, relres(state["rr"]), guards)
            if flight is not None:
                state["fl"] = OF.flight_init(flight, b.dtype)

            def step_at(s, tag: int):
                # EXACTLY _pcg_step_at_tag's op order, dots psum'd; the
                # operator decode, halo exchange and preconditioner apply
                # all ride the same static-tag branch.
                ap = local_matvec(blk, s["p"], tag=tag, wire=wire, k=k,
                                  rows=rows, ei_bit=ei)
                denom = _pdot(s["p"], ap)
                alpha = s["rz"] / jnp.where(denom == 0, 1.0, denom)
                x = s["x"] + alpha * s["p"]
                r = s["r"] - alpha * ap
                z = m_apply_at(r, tag)
                rz2 = _pdot(r, z)
                rr2 = _pdot(r, r)
                beta = rz2 / jnp.where(s["rz"] == 0, 1.0, s["rz"])
                p = z + beta * s["p"]
                stepped = dict(x=x, r=r, p=p, rz=rz2, rr=rr2)
                if guards is not None or flight is not None:
                    stepped["denom"] = denom
                return stepped

            def body(s):
                krylov = {k_: s[k_] for k_ in ("x", "r", "p", "rz", "rr")}
                stepped = jax.lax.switch(
                    jnp.clip(s["mon"].tag - 1, 0, 2),
                    [partial(step_at, tag=t) for t in (1, 2, 3)],
                    krylov,
                )
                denom = stepped.pop("denom", None)
                mon1 = Prec.record(s["mon"], relres(stepped["rr"]))
                mon2 = Prec.update_tag(mon1, params)
                sw = _record_switch(s["switches"], mon1, mon2, s["it"])
                rz2 = stepped["rz"]
                stepped.update(it=s["it"] + 1, mon=mon2, switches=sw)
                out = _guarded_body(s, stepped, relres(stepped["rr"]),
                                    guards, denom=denom,
                                    breakdown=rz2 < 0, finite_aux=(rz2,))
                if flight is not None:
                    # Observation-only recompute (bit-identity contract).
                    alpha = s["rz"] / jnp.where(denom == 0, 1.0, denom)
                    beta = rz2 / jnp.where(s["rz"] == 0, 1.0, s["rz"])
                    g = out.get("g")
                    out["fl"] = OF.flight_record(
                        s["fl"], it=s["it"], relres=relres(stepped["rr"]),
                        tag=s["mon"].tag,
                        health=g["health"] if g is not None else None,
                        a0=alpha, a1=beta, a2=denom)
                return out

            def cond(s):
                return _guarded_cond(
                    s, (relres(s["rr"]) > tol) & (s["it"] < maxiter), guards
                )

            out = jax.lax.while_loop(cond, body, state)
            final_rel = relres(out["rr"])

        conv = final_rel <= tol
        g = out.get("g") if guards is not None else None
        health, trip = finalize_health(g, conv, final_rel)
        ckpt = out["ckpt"] if guards is not None else out["x"]
        outs = (out["x"], out["it"], final_rel, out["mon"].tag,
                out["switches"], conv, health, trip, ckpt)
        if flight is not None:
            outs = outs + (out["fl"],)
        return outs

    sharded = P(AXIS)
    out_specs = (sharded, P(), P(), P(), P(), P(), P(), P(), sharded)
    if flight is not None:
        # The flight ring is replicated: every recorded column derives
        # from psum'd scalars or the replicated monitor state.
        out_specs = out_specs + (P(),)
    fn = jax.jit(shard_map(
        run, mesh=mesh,
        in_specs=(sharded,) * 7 + (P(),) + (sharded,) * 3 + (P(),)
        + (sharded, sharded, P(), P()),
        out_specs=out_specs,
        check_rep=False,
    ))
    part.__dict__[key] = fn
    return fn


def _empty_diag(part):
    z = jnp.zeros((part.n_padded,), jnp.uint16)
    return z, z, jnp.zeros((part.n_padded,), jnp.uint32), part.table


def _run_sharded(part, kind, b, x0, tol, maxiter, params, init_tag, wire,
                 precond=None, guards=None, flight=None, return_ckpt=False):
    n = part.shape[0]
    if precond is None:
        m_head, m_tail1, m_tail2, m_table = _empty_diag(part)
        precond_meta = None
    else:
        pk = precond.packed
        if pk.frac_bits != 52 or pk.tail2.size != pk.head.size:
            # Mirror gse.decode_jnp's guard: an f32-source pack (pack32,
            # no tail2) supports tags 1/2 only -- the single-device fused
            # path raises at trace time, and the sharded tag-3 branch
            # would otherwise decode garbage silently.
            raise ValueError(
                "sharded PCG needs an f64-source packed diagonal "
                "(head+tail1+tail2, tags 1-3); f32-source packs support "
                "tags 1 and 2 only"
            )
        m_head = _pad_to(pk.head, part.n_padded)
        m_tail1 = _pad_to(pk.tail1, part.n_padded)
        m_tail2 = _pad_to(pk.tail2, part.n_padded)
        m_table = pk.table
        precond_meta = (pk.ei_bit, pk.frac_bits)
    fn = _sharded_loop_fn(part, kind, wire, maxiter, params, init_tag,
                          precond_meta, guards, flight)
    bnorm = jnp.linalg.norm(b)           # computed on the FULL vector so
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)  # it matches single-device
    outs = fn(
        part.colpak, part.head, part.tail1, part.tail2, part.row_ids,
        part.bnd_idx, part.halo_idx, part.table,
        m_head, m_tail1, m_tail2, m_table,
        _pad_to(b, part.n_padded), _pad_to(x0, part.n_padded),
        jnp.asarray(tol, b.dtype), bnorm,
    )
    x, it, rel, tag, sw, conv, health, trip, ckpt = outs[:9]
    fl = outs[9] if flight is not None else None
    res = CGResult(x=x[:n], iters=it, relres=rel, tag=tag,
                   switch_iters=sw, converged=conv, health=health,
                   trip_iter=trip, flight=fl)
    return (res, ckpt[:n]) if return_ckpt else res


def solve_cg_sharded(
    part: PartitionedGSECSR,
    b: jnp.ndarray,
    x0: jnp.ndarray | None = None,
    tol: float = 1e-6,
    maxiter: int = 5000,
    params: Prec.MonitorParams | None = None,
    wire: str = "exact",
    final_correction: bool = False,
    guards: GuardParams | None = DEFAULT_GUARDS,
    recover: bool = True,
    init_tag: int = 1,
    flight: OF.FlightParams | None = None,
) -> CGResult:
    """Distributed stepped CG over a row-sharded operator (DESIGN.md §13).

    The whole loop runs inside one ``shard_map``: vectors stay sharded,
    each iteration moves only the tag-aware halo payload plus three psum
    scalars.  ``wire`` selects the halo wire format (``"exact"``: f64 at
    every tag -- the parity-contract mode; ``"gse"``: tag-1/2 halos ship
    head(+tail1) segments, shrinking wire bytes with the SAME monitor
    schedule that shrinks HBM bytes).

    ``guards``/``recover``/``init_tag`` mirror :func:`repro.solvers.cg.
    solve_cg` (DESIGN.md §14): the guard runs on the psum'd replicated
    scalars inside the shard_map, the checkpoint stays row-sharded, and
    escalation restarts the whole sharded loop from the gathered
    checkpoint at the promoted tag.
    """
    b, x0, orig_shape = _normalize_b_x0(b, x0)
    if x0 is None:
        x0 = jnp.zeros_like(b)
    if params is None:
        params = Prec.MonitorParams.for_cg()

    def run(x_start, budget, tag):
        return _run_sharded(part, "cg", b, x_start, tol, budget, params,
                            tag, wire, guards=guards, flight=flight,
                            return_ckpt=True)

    with OT.span("solve.cg_sharded", n=int(b.shape[0]), tol=float(tol),
                 wire=wire, shards=int(part.n_shards)):
        res = run_with_recovery(run, x0, maxiter, init_tag=init_tag,
                                recover=recover and guards is not None)
    if not final_correction:
        return _restore_shape(res, orig_shape)
    op = make_sharded_operator(part, wire)

    def apply3(v):
        return op(v, jnp.int32(3))

    def resume(xr, budget):
        return run(xr, budget, 3)[0]

    return _restore_shape(
        _finish_with_correction(res, b, tol, maxiter, apply3, resume),
        orig_shape,
    )


def solve_pcg_sharded(
    part: PartitionedGSECSR,
    b: jnp.ndarray,
    precond,
    x0: jnp.ndarray | None = None,
    tol: float = 1e-6,
    maxiter: int = 5000,
    params: Prec.MonitorParams | None = None,
    wire: str = "exact",
    final_correction: bool = False,
    guards: GuardParams | None = DEFAULT_GUARDS,
    recover: bool = True,
    init_tag: int = 1,
    flight: OF.FlightParams | None = None,
) -> CGResult:
    """Distributed stepped PCG.  Diagonal GSE preconditioners (Jacobi /
    SPAI-0) shard with the operator -- each device decodes its slice of
    the packed ``M^{-1}`` diagonal at the monitor's tag, inside the same
    branch as the operator decode (the sharded twin of
    ``fused_pcg_step``).  Non-diagonal preconditioners fall back to the
    generic path over ``make_sharded_operator`` (full-vector apply).
    ``guards``/``recover``/``init_tag``: see :func:`solve_cg_sharded`.
    """
    from repro.solvers.precond import DiagGSEPrecond

    b, x0, orig_shape = _normalize_b_x0(b, x0)
    if x0 is None:
        x0 = jnp.zeros_like(b)
    if params is None:
        params = Prec.MonitorParams.for_cg()
    if not isinstance(precond, DiagGSEPrecond):
        from repro.solvers.cg import solve_pcg

        op = make_sharded_operator(part, wire)
        return solve_pcg(op, b.reshape(orig_shape), precond, x0=x0, tol=tol,
                         maxiter=maxiter, params=params,
                         final_correction=final_correction, guards=guards,
                         recover=recover, init_tag=init_tag, flight=flight)

    def run(x_start, budget, tag):
        return _run_sharded(part, "pcg", b, x_start, tol, budget, params,
                            tag, wire, precond=precond, guards=guards,
                            flight=flight, return_ckpt=True)

    with OT.span("solve.pcg_sharded", n=int(b.shape[0]), tol=float(tol),
                 wire=wire, shards=int(part.n_shards)):
        res = run_with_recovery(run, x0, maxiter, init_tag=init_tag,
                                recover=recover and guards is not None)
    if not final_correction:
        return _restore_shape(res, orig_shape)
    op = make_sharded_operator(part, wire)

    def apply3(v):
        return op(v, jnp.int32(3))

    def resume(xr, budget):
        return run(xr, budget, 3)[0]

    return _restore_shape(
        _finish_with_correction(res, b, tol, maxiter, apply3, resume),
        orig_shape,
    )
