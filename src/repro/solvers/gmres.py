"""Restarted GMRES with stepped mixed precision (paper Alg. 3, Sec IV).

GMRES(restart) with iterated classical Gram-Schmidt (CGS2 -- vectorizes on
TPU, numerically equivalent to MGS in practice) and Givens-rotation least
squares.  The residual monitor sees ``|g[j+1]|`` every inner iteration --
exactly the quantity the paper monitors -- and steps the SpMV precision tag
in place.  Tag and residual history persist across restarts.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import precision as P
from repro.obs import flight as OF
from repro.obs import trace as OT
from repro.robustness.guards import (
    DEFAULT_GUARDS,
    GuardParams,
    HEALTH_OK,
    finalize_health,
    guard_init,
    guard_step,
    run_with_recovery,
)
from repro.solvers.cg import _record_switch

__all__ = ["GMRESResult", "solve_gmres"]


class GMRESResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray        # total inner iterations (matvecs in Arnoldi)
    relres: jnp.ndarray
    tag: jnp.ndarray
    switch_iters: jnp.ndarray  # (2,) inner-iteration of tag->2 / tag->3
    converged: jnp.ndarray
    # Robustness (DESIGN.md §14): health code (robustness.guards.HEALTH_*)
    # and first guard-trip inner iteration (-1: never).
    health: jnp.ndarray = HEALTH_OK
    trip_iter: jnp.ndarray = -1
    # Observability (DESIGN.md §16): raw flight-recorder ring state (None
    # when recording is off); rows are inner iterations with a0 = the
    # Givens magnitude d, a1 = the Arnoldi subdiagonal H[j+1, j].
    flight: object = None


def _givens(a, b):
    """Rotation (c, s, d) with d = hypot(a, b), overflow/underflow-safe.

    The naive ``sqrt(a*a + b*b)`` overflows to inf for |a| or |b| above
    ~sqrt(max_float) (1e154 in f64, 1e19 in f32 -- guaranteed territory
    for float32 sharded runs) and underflows to 0 below ~sqrt(tiny),
    poisoning c/s and every later rotation.  Scale by max(|a|, |b|) first
    so the squared terms stay in [0, 1]; c and s come from the SCALED
    quotients (never touching the possibly-overflowing product d).
    """
    m = jnp.maximum(jnp.abs(a), jnp.abs(b))
    safe = m > 0
    scale = jnp.where(safe, m, 1.0)
    an = a / scale
    bn = b / scale
    dn = jnp.sqrt(an * an + bn * bn)  # in [1, sqrt(2)]: exact-safe range
    c = jnp.where(safe, an / dn, 1.0)
    s = jnp.where(safe, bn / dn, 0.0)
    return c, s, dn * scale


@partial(jax.jit, static_argnames=("apply_a", "apply_m", "restart", "maxiter",
                                   "params", "init_tag", "return_monitor",
                                   "guards", "flight", "return_ckpt"))
def _solve_gmres(apply_a, b, x0, tol, restart, maxiter,
                 params: P.MonitorParams, init_tag: int = 1, apply_m=None,
                 return_monitor: bool = False,
                 guards: GuardParams | None = None,
                 flight: OF.FlightParams | None = None,
                 return_ckpt: bool = False):
    """``apply_m`` (optional) right-preconditions: Arnoldi runs on
    ``A M^{-1}`` and the Krylov correction is mapped back through
    ``M^{-1}`` at the end of each cycle.  In exact arithmetic right
    preconditioning keeps ``|g[j+1]|`` equal to the residual norm of the
    original system, so the stepped monitor watches the same quantity as
    in the plain solver -- but under low-tag operator/preconditioner
    perturbation it remains a RECURSIVE residual (paper semantics, same
    as unpreconditioned stepped GMRES): use ``final_correction`` to
    certify the TRUE tag-3 residual.  Both applications run at the
    monitor's current tag; a mid-cycle tag step therefore mixes decode
    precisions inside one Krylov cycle (for ``M^{-1}`` exactly as
    Algorithm 3 already accepts for ``A`` -- the in-place switch, no
    FGMRES-style Z storage); the next restart's explicit
    ``r = b - A x`` re-anchors the cycle."""
    n = b.shape[0]
    dtype = b.dtype
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)
    abstol = tol * bnorm

    def cycle(x, it0, mon, switches, gd, ckpt, fs):
        r = b - apply_a(x, mon.tag)
        beta = jnp.linalg.norm(r)
        if guards is not None:
            # The recomputed restart residual is the one TRUE residual per
            # cycle: a previous cycle whose back-substitution went
            # non-finite (huge y through a near-singular triangle) shows
            # up here even though the recursive |g[j+1]| looked fine.
            gd = guard_step(gd, it0, beta / bnorm, guards)
        # Record the explicitly recomputed restart residual: it is the one
        # TRUE residual per cycle, and skipping it hands the switch
        # metrics a gapped window (RSD/nDec/relDec computed as if the
        # restart re-anchor never happened).  Guarded on ``it0 > 0``: the
        # first cycle's beta is the INITIAL residual, which precedes
        # iteration 0 -- recording it would misalign the window with the
        # per-iteration residual stream the paper's monitor watches.
        mon = jax.lax.cond(
            it0 > 0,
            lambda m: P.record(m, beta / bnorm),
            lambda m: m,
            mon,
        )
        v0 = r / jnp.where(beta == 0, 1.0, beta)
        V = jnp.zeros((restart + 1, n), dtype).at[0].set(v0)
        H = jnp.zeros((restart + 1, restart), dtype)
        cs = jnp.zeros((restart,), dtype)
        sn = jnp.zeros((restart,), dtype)
        g = jnp.zeros((restart + 1,), dtype).at[0].set(beta)

        def inner_cond(c):
            j, resid = c[0], c[6]
            ok = (j < restart) & (resid > abstol) & (it0 + j < maxiter)
            if guards is not None:
                ok = ok & (c[9]["health"] == HEALTH_OK)
            return ok

        def inner_body(c):
            j, V, H, cs, sn, g, resid, mon, switches = c[:9]
            if apply_m is None:
                w = apply_a(V[j], mon.tag)
            else:
                w = apply_a(apply_m(V[j], mon.tag), mon.tag)
            # CGS2: two passes of classical Gram-Schmidt vs rows 0..j.
            mask = (jnp.arange(restart + 1) <= j).astype(dtype)
            h = jnp.zeros((restart + 1,), dtype)
            for _ in range(2):
                corr = (V @ w) * mask
                w = w - corr @ V
                h = h + corr
            hj1 = jnp.linalg.norm(w)
            V = V.at[j + 1].set(w / jnp.where(hj1 == 0, 1.0, hj1))
            col = h.at[j + 1].set(hj1)

            # Apply previous rotations 0..j-1 (sequential recurrence).
            def rot(i, col):
                on = (i < j).astype(dtype)
                t1 = cs[i] * col[i] + sn[i] * col[i + 1]
                t2 = -sn[i] * col[i] + cs[i] * col[i + 1]
                col = col.at[i].set(on * t1 + (1 - on) * col[i])
                col = col.at[i + 1].set(on * t2 + (1 - on) * col[i + 1])
                return col

            col = jax.lax.fori_loop(0, restart, rot, col)
            c_new, s_new, d = _givens(col[j], col[j + 1])
            col = col.at[j].set(d).at[j + 1].set(0.0)
            cs = cs.at[j].set(c_new)
            sn = sn.at[j].set(s_new)
            g = g.at[j + 1].set(-s_new * g[j])
            g = g.at[j].set(c_new * g[j])
            resid = jnp.abs(g[j + 1])
            H = H.at[:, j].set(col)

            mon1 = P.record(mon, resid / bnorm)
            mon2 = P.update_tag(mon1, params)
            switches = _record_switch(switches, mon1, mon2, it0 + j)
            out = (j + 1, V, H, cs, sn, g, resid, mon2, switches)
            gd_new = None
            if guards is not None:
                # Unhappy breakdown: the Krylov space closed (hj1 == 0)
                # with the residual still above tolerance.  (hj1 == 0 AND
                # resid <= abstol is the HAPPY breakdown -- converged.)
                gd_new = guard_step(
                    c[9], it0 + j, resid / bnorm, guards,
                    breakdown=(hj1 == 0) & (resid > abstol),
                    finite_aux=(hj1,),
                )
                out = out + (gd_new,)
            if flight is not None:
                # Observation only (DESIGN.md §16): the flight state is the
                # LAST carry element, after the optional guard state.
                out = out + (OF.flight_record(
                    c[-1], it=it0 + j, relres=resid / bnorm, tag=mon.tag,
                    health=gd_new["health"] if gd_new is not None else None,
                    a0=d, a1=hj1,
                ),)
            return out

        carry = (jnp.int32(0), V, H, cs, sn, g, beta, mon, switches)
        if guards is not None:
            carry = carry + (gd,)
        if flight is not None:
            carry = carry + (fs,)
        outc = jax.lax.while_loop(inner_cond, inner_body, carry)
        j, V, H, cs, sn, g, resid, mon, switches = outc[:9]
        if guards is not None:
            gd = outc[9]
        if flight is not None:
            fs = outc[-1]

        # Back substitution on the leading j x j triangle (padded to full
        # size with identity rows so a single static solve works).
        R = H[:restart, :restart]
        eye = jnp.eye(restart, dtype=dtype)
        live = jnp.arange(restart) < j
        Rm = jnp.where(live[:, None] & live[None, :], R, eye)
        diag = jnp.diagonal(Rm)
        Rm = Rm + jnp.diag(jnp.where(diag == 0, 1.0, 0.0).astype(dtype))
        gm = jnp.where(live, g[:restart], 0.0)
        y = jax.scipy.linalg.solve_triangular(Rm, gm, lower=False)
        u = y @ V[:restart]
        if apply_m is not None:  # x = x0 + M^{-1} (V y), right precond
            u = apply_m(u, mon.tag)
        x_new = x + u
        out = (x_new, it0 + j, mon, switches, resid / bnorm)
        if guards is not None:
            fin = jnp.isfinite(jnp.vdot(x_new, x_new))
            ckpt = jnp.where((gd["health"] == HEALTH_OK) & fin, x_new, ckpt)
            out = out + (gd, ckpt)
        if flight is not None:
            out = out + (fs,)
        return out

    def outer_cond(s):
        ok = (s[4] > tol) & (s[1] < maxiter)
        if guards is not None:
            ok = ok & (s[5]["health"] == HEALTH_OK)
        return ok

    def outer_body(s):
        x, it, mon, switches = s[:4]
        gd = s[5] if guards is not None else None
        ckpt = s[6] if guards is not None else None
        fs = s[-1] if flight is not None else None
        return cycle(x, it, mon, switches, gd, ckpt, fs)

    mon0 = P.init(params, dtype=dtype, tag=init_tag)
    r0 = b - apply_a(x0, mon0.tag)
    relres0 = jnp.linalg.norm(r0) / bnorm
    state = (x0, jnp.int32(0), mon0, jnp.full((2,), -1, jnp.int32), relres0)
    if guards is not None:
        state = state + (guard_init(relres0), x0)
    if flight is not None:
        state = state + (OF.flight_init(flight, dtype),)
    outs = jax.lax.while_loop(outer_cond, outer_body, state)
    x, it, mon, switches, relres = outs[:5]
    gd = outs[5] if guards is not None else None
    ckpt = outs[6] if guards is not None else x
    x_fin = jnp.isfinite(jnp.vdot(x, x))
    conv = (relres <= tol) & x_fin
    health, trip = finalize_health(gd, conv, relres, x_finite=x_fin)
    res = GMRESResult(
        x=x,
        iters=it,
        relres=relres,
        tag=mon.tag,
        switch_iters=switches,
        converged=conv,
        health=health,
        trip_iter=trip,
        flight=outs[-1] if flight is not None else None,
    )
    if return_monitor:  # debug/test hook: expose the residual window
        return res, mon
    if return_ckpt:
        return res, ckpt
    return res


def solve_gmres(
    apply_a: Callable,
    b: jnp.ndarray,
    x0: jnp.ndarray | None = None,
    tol: float = 1e-6,
    restart: int = 30,
    maxiter: int = 15000,
    params: P.MonitorParams | None = None,
    final_correction: bool = False,
    precond=None,
    guards: GuardParams | None = DEFAULT_GUARDS,
    recover: bool = True,
    init_tag: int = 1,
    flight: OF.FlightParams | None = None,
) -> GMRESResult:
    """Restarted GMRES; ``apply_a(x, tag)`` and ``final_correction`` as in
    :func:`repro.solvers.cg.solve_cg`.

    ``precond`` (optional) right-preconditions the iteration: a
    preconditioner object from :mod:`repro.solvers.precond` or a callable
    ``apply_m(r, tag)``.  The preconditioner rides the monitor's tag
    schedule exactly like the operator (DESIGN.md §10).

    ``guards``/``recover``/``init_tag``: in-loop guardrails plus
    checkpoint-rollback tag-escalation recovery, as in
    :func:`repro.solvers.cg.solve_cg` (DESIGN.md §14).  GMRES checkpoints
    at restart-cycle granularity (x only changes at cycle ends).

    ``b``/``x0`` may be ``(n,)`` or ``(n, 1)``; the solution comes back in
    ``b``'s layout.
    """
    from repro.solvers.cg import _normalize_b_x0, _restore_shape

    b, x0, orig_shape = _normalize_b_x0(b, x0)
    if x0 is None:
        x0 = jnp.zeros_like(b)
    if params is None:
        params = P.MonitorParams.for_gmres()
    apply_m = None
    if precond is not None:
        apply_m = precond if callable(precond) else precond.apply
    tol_ = jnp.asarray(tol, b.dtype)

    def run(x_start, budget, tag):
        return _solve_gmres(apply_a, b, x_start, tol_, restart, budget,
                            params, init_tag=tag, apply_m=apply_m,
                            guards=guards, flight=flight, return_ckpt=True)

    with OT.span("solve.gmres", n=int(b.shape[0]), tol=float(tol),
                 restart=restart, init_tag=init_tag):
        res = run_with_recovery(run, x0, maxiter, init_tag=init_tag,
                                recover=recover and guards is not None)
    if not final_correction:
        return _restore_shape(res, orig_shape)
    from repro.solvers.cg import _finish_with_correction

    def apply3(v):
        return apply_a(v, jnp.int32(3))

    def resume(xr, budget):
        return run(xr, budget, 3)[0]

    return _restore_shape(
        _finish_with_correction(res, b, tol, maxiter, apply3, resume),
        orig_shape,
    )
