"""Stepped mixed-precision iterative refinement (Carson-Khan shape).

Outer loop at full precision, inner solves at stepped low precision:

    repeat:
        r = b - A x          # tag-3 residual (the TRUE residual)
        d ~= A^{-1} r        # stepped inner solve, starts at tag 1
        x = x + d            # full-precision correction

This is the classic three-precision iterative-refinement structure
(Carson & Higham; Carson & Khan arXiv:2307.03914 for the preconditioned
variant) mapped onto GSE-SEM's one-copy/three-precision storage: the
inner solver reads the SAME packed operand at whatever tag its residual
monitor has stepped to, and the outer loop needs no second matrix copy
for the high-precision residual -- it is a tag-3 read.

The inner solve is deliberately loose (``inner_tol``): IR converges as
long as each correction gains a constant factor, so the inner monitor
usually never needs to leave tag 1/2 -- most of the run streams 6-8
bytes/nnz instead of 12 (DESIGN.md §10).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core import precision as P
from repro.obs import flight as OF
from repro.obs import trace as OT
from repro.robustness.guards import (
    DEFAULT_GUARDS,
    GuardParams,
    HEALTH_NONFINITE,
    HEALTH_OK,
    HEALTH_STALLED,
)
from repro.sparse.csr import GSECSR, GSESellC
from repro.solvers.cg import solve_cg, solve_pcg
from repro.solvers.gmres import solve_gmres

__all__ = ["IRResult", "solve_ir"]

# NOTE: the serve layer (repro.serve.chunked) drives the same refinement
# loop one correction at a time via the private _ir_setup/_ir_step/
# _ir_result helpers below; solve_ir and the chunked driver share every
# line of per-correction arithmetic, so re-cutting the host loop at
# correction boundaries cannot perturb the trajectory.


class IRResult(NamedTuple):
    x: jnp.ndarray
    outer_iters: int          # correction steps taken
    inner_iters: int          # total inner-solver iterations
    relres: float             # final TRUE (tag-3) relative residual
    converged: bool
    history: np.ndarray       # (outer_iters+1,) outer residual trajectory
    # Robustness (DESIGN.md §14): HEALTH_OK when converged; otherwise the
    # failing inner solve's health code, HEALTH_NONFINITE if the outer
    # tag-3 residual itself went non-finite, or HEALTH_STALLED on plain
    # max_outer exhaustion.
    health: int = HEALTH_OK
    # Observability (DESIGN.md §16): list of per-correction flight-recorder
    # states (one per inner solve, in outer-iteration order) when a
    # ``flight`` was requested; decode each with ``FlightLog.from_state``.
    flight: object = None


def solve_ir(
    apply_a: Union[Callable, GSECSR],
    b: jnp.ndarray,
    tol: float = 1e-10,
    max_outer: int = 10,
    inner: str = "cg",
    inner_tol: float = 1e-4,
    inner_maxiter: int = 2000,
    params: P.MonitorParams | None = None,
    precond=None,
    restart: int = 30,
    wire: str = "exact",
    guards: GuardParams | None = DEFAULT_GUARDS,
    flight: OF.FlightParams | None = None,
    tags=None,
) -> IRResult:
    """Iterative refinement with a stepped inner solver.

    ``apply_a`` is a tag-dispatched operator or a ``GSECSR`` (the inner CG
    then takes the fused path).  ``inner`` selects ``"cg"`` or ``"gmres"``;
    ``precond`` (a :mod:`repro.solvers.precond` object or callable) turns
    the inner solve into PCG / right-preconditioned GMRES.  ``params``
    parameterizes the inner residual monitor (``MonitorParams``); each
    correction restarts the monitor at tag 1, so late corrections --
    whose right-hand sides are tiny -- get the cheap tags again.

    ``guards`` threads the in-loop guardrails (DESIGN.md §14) into every
    inner solve; a non-finite correction is never folded into ``x`` and
    the report's ``health`` names the failing stage.

    ``tags`` (PR 10, DESIGN.md §18) threads to the INNER CG/PCG solves:
    an int or uniform :class:`~repro.core.tagmap.TagMap` starts every
    correction's monitor there; a non-uniform map runs each correction
    on the masked per-group operand.  The OUTER tag-3 residual always
    reads the UNMASKED operand, so the refinement target stays the true
    operator.  Requires ``inner="cg"`` (GMRES keeps its scalar axis).
    """
    if tags is not None and inner != "cg":
        raise ValueError("tags= requires inner='cg' (the GMRES inner "
                         "solve keeps the legacy scalar tag axis)")
    st = _ir_setup(apply_a, b, tol=tol, max_outer=max_outer, inner=inner,
                   inner_tol=inner_tol, inner_maxiter=inner_maxiter,
                   params=params, precond=precond, restart=restart,
                   wire=wire, guards=guards, flight=flight, tags=tags)
    with OT.span("solve.ir", n=int(b.shape[0]), tol=float(tol), inner=inner):
        while _ir_active(st):
            _ir_step(st)
    return _ir_result(st)


def _ir_setup(apply_a, b, *, tol, max_outer, inner, inner_tol, inner_maxiter,
              params, precond, restart, wire, guards, flight,
              tags=None) -> dict:
    """Build the host-side refinement state for ``solve_ir``/chunked IR.

    Returns a mutable dict advanced one correction at a time by
    ``_ir_step``; ``_ir_active`` is the loop condition and ``_ir_result``
    materializes the final ``IRResult``.  The dict is host state (Python
    scalars + device arrays), not a pytree -- checkpointing extracts the
    array leaves explicitly (``repro.serve.chunked``).
    """
    if params is None:
        params = (P.MonitorParams.for_cg() if inner == "cg"
                  else P.MonitorParams.for_gmres())
    if inner not in ("cg", "gmres"):
        raise ValueError(f"inner must be 'cg' or 'gmres', got {inner}")

    from repro.solvers.batched import _maybe_sharded

    # Row-sharded operands ride the distributed operator (DESIGN.md §13):
    # the outer tag-3 residual reads and the inner solves all go through
    # the memoized sharded apply; ``wire`` picks the halo wire format
    # (ignored for non-partitioned operands, like the batched solvers).
    apply_a = _maybe_sharded(apply_a, wire)
    if isinstance(apply_a, (GSECSR, GSESellC)):
        from repro.solvers.cg import _gsecsr_operator

        # Memoized on the GSECSR instance: GMRES treats the operator as a
        # static jit arg, so a fresh closure per call would retrace.
        apply_tagged = _gsecsr_operator(apply_a)
    else:
        apply_tagged = apply_a

    def apply3(v):
        return apply_tagged(v, jnp.int32(3))

    bnorm = float(jnp.linalg.norm(b))
    bnorm = bnorm if bnorm != 0 else 1.0

    x = jnp.zeros_like(b)
    # One tag-3 residual per correction: r doubles as convergence check
    # and next inner right-hand side (the module's whole point is to
    # minimize full-precision reads).
    r = b - apply3(x)
    relres = float(jnp.linalg.norm(r)) / bnorm
    return dict(
        apply_a=apply_a, apply_tagged=apply_tagged, apply3=apply3,
        b=b, bnorm=bnorm, tol=tol, max_outer=max_outer, inner=inner,
        inner_tol=inner_tol, inner_maxiter=inner_maxiter, params=params,
        precond=precond, restart=restart, guards=guards, flight=flight,
        tags=tags, x=x, r=r, relres=relres, history=[relres], total_inner=0, outer=0,
        inner_health=HEALTH_OK, stopped=False,
        flights=[] if flight is not None else None,
    )


def _ir_active(st: dict) -> bool:
    """True while another correction step would run (solve_ir loop cond)."""
    return (not st["stopped"] and st["relres"] > st["tol"]
            and np.isfinite(st["relres"]) and st["outer"] < st["max_outer"])


def _ir_step(st: dict) -> dict:
    """One outer correction: inner solve at stepped precision, fold, re-residual.

    Exactly the body of the original ``solve_ir`` while-loop; ``stopped``
    records the early breaks (non-finite correction, zero-progress inner)
    so a re-cut host loop stops at the same correction.
    """
    if st["inner"] == "cg":
        if st["precond"] is not None:
            res = solve_pcg(st["apply_a"], st["r"], st["precond"],
                            tol=st["inner_tol"], maxiter=st["inner_maxiter"],
                            params=st["params"], guards=st["guards"],
                            flight=st["flight"], tags=st.get("tags"))
        else:
            res = solve_cg(st["apply_a"], st["r"], tol=st["inner_tol"],
                           maxiter=st["inner_maxiter"], params=st["params"],
                           guards=st["guards"], flight=st["flight"],
                           tags=st.get("tags"))
    else:
        res = solve_gmres(st["apply_tagged"], st["r"], tol=st["inner_tol"],
                          restart=st["restart"], maxiter=st["inner_maxiter"],
                          params=st["params"], precond=st["precond"],
                          guards=st["guards"], flight=st["flight"])
    st["inner_health"] = int(getattr(res, "health", HEALTH_OK))
    st["total_inner"] += int(res.iters)
    res_flight = getattr(res, "flight", None)  # adaptive results carry none
    if st["flights"] is not None and res_flight is not None:
        st["flights"].append(res_flight)
    if not bool(jnp.isfinite(jnp.vdot(res.x, res.x))):
        st["stopped"] = True  # never fold a non-finite correction into x
        return st
    st["x"] = st["x"] + res.x      # full-precision correction
    st["outer"] += 1
    # Tag-3 residual: the one-copy high read.
    st["r"] = st["b"] - st["apply3"](st["x"])
    st["relres"] = float(jnp.linalg.norm(st["r"])) / st["bnorm"]
    st["history"].append(st["relres"])
    if not bool(res.converged) and int(res.iters) == 0:
        st["stopped"] = True  # inner made no progress; avoid spinning
    return st


def _ir_result(st: dict) -> IRResult:
    """Materialize the final report from the host refinement state."""
    relres = st["relres"]
    converged = relres <= st["tol"]
    if converged:
        health = HEALTH_OK
    elif not np.isfinite(relres):
        health = HEALTH_NONFINITE
    elif st["inner_health"] != HEALTH_OK:
        health = st["inner_health"]
    else:
        health = HEALTH_STALLED
    return IRResult(
        x=st["x"],
        outer_iters=st["outer"],
        inner_iters=st["total_inner"],
        relres=relres,
        converged=converged,
        history=np.asarray(st["history"]),
        health=health,
        flight=st["flights"],
    )
