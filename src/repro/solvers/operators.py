"""Linear-operator factories with precision-tag dispatch (paper Alg. 3).

An *operator* is ``apply(x, tag) -> A @ x`` where ``tag`` is a traced int32
in {1,2,3}.  GSE-SEM operators dispatch via ``lax.switch`` to the three
SpMV precisions; fixed-format baselines ignore the tag.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.sparse.csr import CSR, GSECSR
from repro.sparse.spmv import spmv, spmv_gse

__all__ = [
    "make_gse_operator",
    "make_fixed_operator",
    "make_dense_operator",
    "make_precond_operator",
]


def make_gse_operator(a, acc_dtype=jnp.float64) -> Callable:
    """Three-precision operator over one stored copy (the paper's A1/A2/A3).

    ``a`` is a ``GSECSR`` or a SELL-C-σ packed ``GSESellC``;
    ``spmv_gse`` dispatches on the layout and the two are bit-identical
    (DESIGN.md §12)."""

    def apply(x, tag):
        return jax.lax.switch(
            jnp.clip(tag - 1, 0, 2),
            [
                lambda v: spmv_gse(a, v, tag=1, acc_dtype=acc_dtype),
                lambda v: spmv_gse(a, v, tag=2, acc_dtype=acc_dtype),
                lambda v: spmv_gse(a, v, tag=3, acc_dtype=acc_dtype),
            ],
            x,
        )

    return apply


def make_fixed_operator(a: CSR, store_dtype=jnp.float64, acc_dtype=jnp.float64):
    """FP64/FP32/BF16/FP16 baseline: storage precision fixed, acc high."""

    def apply(x, tag):
        del tag
        return spmv(a, x, store_dtype=store_dtype, acc_dtype=acc_dtype)

    return apply


def make_dense_operator(mat: jnp.ndarray):
    def apply(x, tag):
        del tag
        return mat @ x

    return apply


def make_precond_operator(m, acc_dtype=jnp.float64) -> Callable:
    """``apply_m(r, tag) = M^{-1} r`` over a :mod:`repro.solvers.precond`
    preconditioner -- the preconditioner-side twin of ``make_gse_operator``.
    Delegates to the preconditioner's shared tag dispatch (one stored
    copy, three apply precisions, ``lax.switch`` over the tag-specialized
    decode branches)."""

    def apply(r, tag):
        return m.apply(r, tag, acc_dtype)

    return apply
