"""Fused stepped-CG iteration over a GSE-SEM CSR operand (DESIGN.md §4).

One CG iteration is a SpMV plus five vector ops (two dots, two axpys, one
xpby).  Run unfused, each op is its own pass over the vectors and the SpMV
re-decodes the GSE-SEM values; on a bandwidth-bound machine those extra
passes (and kernel launches) erase part of the format's byte savings.

``fused_cg_step`` folds the whole iteration around a single decoded-value
pass:

  * the GSE-SEM values are decoded ONCE per iteration, at the precision the
    monitor's current tag selects (``lax.switch`` over three tag-specialized
    branches, so the tag-1/-2 branches never touch the tail segments);
  * ``p . Ap`` is formed in the same sweep that produces ``Ap``;
  * the x/r axpys, the new residual norm ``r'.r'``, and the search-direction
    update ride the same fused jaxpr -- one kernel program per iteration
    instead of six.

The arithmetic is EXACTLY the sequence of the unfused ``solve_cg`` body
(same ops, same order, same ``acc_dtype``), so fused and unfused runs
produce bit-identical iterate trajectories -- asserted by
tests/test_spmv_pipeline.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.sparse.spmv import decode_operand

__all__ = ["fused_cg_step", "fused_cg_step_g", "fused_pcg_step",
           "fused_pcg_step_g", "gse_matvec"]


def _step_at_tag(a, x, r, p, rs, *, tag: int, acc_dtype, with_denom=False):
    """One fused CG iteration at a fixed precision tag.

    ``a`` is a ``GSECSR`` or a SELL-C-σ packed ``GSESellC`` --
    ``decode_operand`` recovers the same CSR-order values either way, so
    the layouts share one bit-identical iteration body (DESIGN.md §12).
    Single decoded-value pass: ``val`` is materialized once and feeds both
    the matvec and (via ``ap``) the direction dot; everything downstream of
    the decode fuses into the same program under jit.
    """
    val, col = decode_operand(a, tag, acc_dtype)
    ap = jax.ops.segment_sum(
        val * p.astype(acc_dtype)[col], a.row_ids, num_segments=a.shape[0]
    )
    denom = jnp.vdot(p, ap)                     # same sweep as the matvec
    alpha = rs / jnp.where(denom == 0, 1.0, denom)
    x2 = x + alpha * p
    r2 = r - alpha * ap
    rs2 = jnp.vdot(r2, r2)                      # residual norm, same sweep
    beta = rs2 / jnp.where(rs == 0, 1.0, rs)
    p2 = r2 + beta * p
    if with_denom:
        return x2, r2, p2, rs2, denom
    return x2, r2, p2, rs2


def fused_cg_step(a, x, r, p, rs, tag, acc_dtype=jnp.float64):
    """Fused CG iteration with traced precision ``tag`` in {1, 2, 3}.

    ``a`` is a ``GSECSR`` or ``GSESellC`` operand.  Returns
    ``(x', r', p', rs')`` where ``rs' = r'.r'`` is the squared
    recursive residual norm (the monitor records ``sqrt(rs')/||b||``).
    """
    return jax.lax.switch(
        jnp.clip(tag - 1, 0, 2),
        [
            partial(_step_at_tag, a, tag=1, acc_dtype=acc_dtype),
            partial(_step_at_tag, a, tag=2, acc_dtype=acc_dtype),
            partial(_step_at_tag, a, tag=3, acc_dtype=acc_dtype),
        ],
        x, r, p, rs,
    )


def fused_cg_step_g(a, x, r, p, rs, tag, acc_dtype=jnp.float64):
    """``fused_cg_step`` that ALSO returns the curvature ``denom = p.Ap``.

    Same branch bodies, same op order -- the extra output is the scalar the
    fused sweep already computed, exposed so the robustness guards
    (DESIGN.md §14) can check the breakdown condition ``p.Ap <= 0``
    without a second operator application (which would break the
    fused/unfused bit-identity contract).
    """
    return jax.lax.switch(
        jnp.clip(tag - 1, 0, 2),
        [
            partial(_step_at_tag, a, tag=1, acc_dtype=acc_dtype,
                    with_denom=True),
            partial(_step_at_tag, a, tag=2, acc_dtype=acc_dtype,
                    with_denom=True),
            partial(_step_at_tag, a, tag=3, acc_dtype=acc_dtype,
                    with_denom=True),
        ],
        x, r, p, rs,
    )


def _pcg_step_at_tag(a, m, x, r, p, rz, *, tag: int, acc_dtype,
                     with_denom=False):
    """One fused preconditioned-CG iteration at a fixed precision tag.

    The operator decode AND the preconditioner apply run at the same
    static ``tag`` inside one branch, so both streams follow the monitor's
    schedule and neither low-tag branch references its tail segments
    (DESIGN.md §10).  ``a`` may be a ``GSECSR`` or ``GSESellC`` (shared
    ``decode_operand``).  The arithmetic is the exact op sequence of the
    unfused ``_solve_pcg`` body -- bit-identical trajectories.
    """
    val, col = decode_operand(a, tag, acc_dtype)
    ap = jax.ops.segment_sum(
        val * p.astype(acc_dtype)[col], a.row_ids, num_segments=a.shape[0]
    )
    denom = jnp.vdot(p, ap)
    alpha = rz / jnp.where(denom == 0, 1.0, denom)
    x2 = x + alpha * p
    r2 = r - alpha * ap
    z2 = m.apply_at(r2, tag, acc_dtype)        # same tag as the SpMV
    rz2 = jnp.vdot(r2, z2)
    rr2 = jnp.vdot(r2, r2)                     # monitor sees sqrt(rr)/||b||
    beta = rz2 / jnp.where(rz == 0, 1.0, rz)
    p2 = z2 + beta * p
    if with_denom:
        return x2, r2, p2, rz2, rr2, denom
    return x2, r2, p2, rz2, rr2


def fused_pcg_step(a, m, x, r, p, rz, tag, acc_dtype=jnp.float64):
    """Fused PCG iteration with traced precision ``tag`` in {1, 2, 3}.

    ``m`` is a preconditioner from ``solvers.precond`` (anything exposing
    ``apply_at(r, tag, acc_dtype)`` with a static tag).  Returns
    ``(x', r', p', rz', rr')`` where ``rz' = r'.z'`` drives the recurrence
    and ``rr' = r'.r'`` feeds the residual monitor.
    """
    return jax.lax.switch(
        jnp.clip(tag - 1, 0, 2),
        [
            partial(_pcg_step_at_tag, a, m, tag=1, acc_dtype=acc_dtype),
            partial(_pcg_step_at_tag, a, m, tag=2, acc_dtype=acc_dtype),
            partial(_pcg_step_at_tag, a, m, tag=3, acc_dtype=acc_dtype),
        ],
        x, r, p, rz,
    )


def fused_pcg_step_g(a, m, x, r, p, rz, tag, acc_dtype=jnp.float64):
    """``fused_pcg_step`` that also returns ``denom = p.Ap`` (the guards'
    breakdown predicate) -- same branch bodies, same op order."""
    return jax.lax.switch(
        jnp.clip(tag - 1, 0, 2),
        [
            partial(_pcg_step_at_tag, a, m, tag=1, acc_dtype=acc_dtype,
                    with_denom=True),
            partial(_pcg_step_at_tag, a, m, tag=2, acc_dtype=acc_dtype,
                    with_denom=True),
            partial(_pcg_step_at_tag, a, m, tag=3, acc_dtype=acc_dtype,
                    with_denom=True),
        ],
        x, r, p, rz,
    )


def gse_matvec(a, x, tag, acc_dtype=jnp.float64):
    """Tag-dispatched ``A @ x`` over a ``GSECSR`` or ``GSESellC`` operand
    (initial residual / checks); ``spmv_gse`` dispatches on the layout."""
    from repro.sparse.spmv import spmv_gse

    return jax.lax.switch(
        jnp.clip(tag - 1, 0, 2),
        [
            lambda v: spmv_gse(a, v, tag=1, acc_dtype=acc_dtype),
            lambda v: spmv_gse(a, v, tag=2, acc_dtype=acc_dtype),
            lambda v: spmv_gse(a, v, tag=3, acc_dtype=acc_dtype),
        ],
        x,
    )
