"""Iterative solvers with stepped mixed precision (paper Section III.D).

Beyond-paper subsystem (DESIGN.md §10): GSE-packed preconditioners that
ride the operator's tag schedule (``precond``), preconditioned CG
(``solve_pcg``, with a fused iteration path) and right-preconditioned
GMRES (``solve_gmres(..., precond=...)``), plus a stepped
iterative-refinement driver (``solve_ir``).

Batched multi-RHS subsystem (DESIGN.md §11): ``solve_cg_batched`` /
``solve_pcg_batched`` / ``solve_ir_batched`` run per-column precision
schedules over one shared operand (matrix bytes charged once per
iteration, ``batched_run_bytes``); ``launch.solver_serve`` is the
request-batching front-end.

Distributed subsystem (DESIGN.md §13): ``solve_cg_sharded`` /
``solve_pcg_sharded`` run the whole stepped loop row-sharded under
``shard_map`` with a tag-aware GSE halo exchange; ``solve_cg`` /
``solve_pcg`` / the batched solvers dispatch there automatically when
handed a ``distributed.partition.PartitionedGSECSR``.

Robustness subsystem (DESIGN.md §14): every solver result carries a
structured ``health`` status (``health_name`` renders it), the in-loop
guardrails are tuned via ``GuardParams`` (``guards=None`` disables), and
low-tag breakdowns recover by tag escalation on the same packed operand.
"""
from repro.robustness.guards import (
    DEFAULT_GUARDS,
    GuardParams,
    health_name,
)
from repro.solvers.batched import (
    BatchedCGResult,
    BatchedIRResult,
    batched_run_bytes,
    solve_cg_batched,
    solve_ir_batched,
    solve_pcg_batched,
)
from repro.solvers.adaptive import AdaptiveResult, solve_adaptive
from repro.solvers.cg import CGResult, solve_cg, solve_pcg
from repro.solvers.fused_cg import fused_cg_step, fused_pcg_step, gse_matvec
from repro.solvers.gmres import GMRESResult, solve_gmres
from repro.solvers.ir import IRResult, solve_ir
from repro.solvers.operators import (
    make_dense_operator,
    make_fixed_operator,
    make_gse_operator,
    make_precond_operator,
)
from repro.solvers.sharded import solve_cg_sharded, solve_pcg_sharded
from repro.solvers.precond import (
    BlockJacobiGSEPrecond,
    DiagGSEPrecond,
    make_block_jacobi,
    make_jacobi,
    make_spai0,
)

__all__ = [
    "DEFAULT_GUARDS",
    "GuardParams",
    "health_name",
    "AdaptiveResult",
    "solve_adaptive",
    "CGResult",
    "BatchedCGResult",
    "BatchedIRResult",
    "batched_run_bytes",
    "solve_cg",
    "solve_pcg",
    "solve_cg_batched",
    "solve_pcg_batched",
    "solve_ir_batched",
    "solve_cg_sharded",
    "solve_pcg_sharded",
    "fused_cg_step",
    "fused_pcg_step",
    "gse_matvec",
    "GMRESResult",
    "solve_gmres",
    "IRResult",
    "solve_ir",
    "make_dense_operator",
    "make_fixed_operator",
    "make_gse_operator",
    "make_precond_operator",
    "BlockJacobiGSEPrecond",
    "DiagGSEPrecond",
    "make_block_jacobi",
    "make_jacobi",
    "make_spai0",
]
