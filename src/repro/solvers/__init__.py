"""Iterative solvers with stepped mixed precision (paper Section III.D)."""
from repro.solvers.cg import CGResult, solve_cg
from repro.solvers.fused_cg import fused_cg_step, gse_matvec
from repro.solvers.gmres import GMRESResult, solve_gmres
from repro.solvers.operators import (
    make_dense_operator,
    make_fixed_operator,
    make_gse_operator,
)

__all__ = [
    "CGResult",
    "solve_cg",
    "fused_cg_step",
    "gse_matvec",
    "GMRESResult",
    "solve_gmres",
    "make_dense_operator",
    "make_fixed_operator",
    "make_gse_operator",
]
