"""Conjugate Gradient with stepped mixed precision (paper Alg. 3 + Sec IV).

Pure ``lax.while_loop``; the operator is called with the current precision
tag each iteration, and the residual monitor (core.precision) steps the tag
up when convergence stalls.  Faithful to the paper: the switch happens
in-place (no restart, no residual recomputation at the switch), matching
Algorithm 3.

Two equivalent hot paths (bit-identical trajectories):

  * generic: ``apply_a(x, tag)`` is any callable (fixed-precision
    baselines, dense operators, preconditioned wrappers);
  * fused:   pass a ``GSECSR`` directly as the operator and each iteration
    runs ``solvers.fused_cg.fused_cg_step`` -- one decoded-value pass with
    the dots/axpys folded around the SpMV (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.core import precision as P
from repro.sparse.csr import GSECSR

__all__ = ["CGResult", "solve_cg"]


class CGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray       # iterations executed
    relres: jnp.ndarray      # final recursive relative residual
    tag: jnp.ndarray         # final precision tag
    switch_iters: jnp.ndarray  # (2,) iteration of tag->2 and tag->3 (-1: never)
    converged: jnp.ndarray


@partial(jax.jit, static_argnames=("apply_a", "maxiter", "params", "init_tag"))
def _solve_cg(apply_a, b, x0, tol, maxiter, params: P.MonitorParams,
              init_tag: int = 1):
    dtype = b.dtype
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    mon = P.init(params, dtype=dtype, tag=init_tag)
    r0 = b - apply_a(x0, mon.tag)
    state = dict(
        x=x0,
        r=r0,
        p=r0,
        rs=jnp.vdot(r0, r0),
        it=jnp.int32(0),
        mon=mon,
        switches=jnp.full((2,), -1, jnp.int32),
    )

    def relres(s):
        return jnp.sqrt(jnp.abs(s["rs"])) / bnorm

    def cond(s):
        return (relres(s) > tol) & (s["it"] < maxiter)

    def body(s):
        tag = s["mon"].tag
        ap = apply_a(s["p"], tag)
        denom = jnp.vdot(s["p"], ap)
        alpha = s["rs"] / jnp.where(denom == 0, 1.0, denom)
        x = s["x"] + alpha * s["p"]
        r = s["r"] - alpha * ap
        rs_new = jnp.vdot(r, r)
        mon = P.record(s["mon"], jnp.sqrt(jnp.abs(rs_new)) / bnorm)
        mon2 = P.update_tag(mon, params)
        switches = _record_switch(s["switches"], mon, mon2, s["it"])
        beta = rs_new / jnp.where(s["rs"] == 0, 1.0, s["rs"])
        p = r + beta * s["p"]
        return dict(
            x=x, r=r, p=p, rs=rs_new, it=s["it"] + 1, mon=mon2, switches=switches
        )

    out = jax.lax.while_loop(cond, body, state)
    return CGResult(
        x=out["x"],
        iters=out["it"],
        relres=relres(out),
        tag=out["mon"].tag,
        switch_iters=out["switches"],
        converged=relres(out) <= tol,
    )


def _record_switch(switches, mon, mon2, it):
    """Log the iteration of a tag step-up into its slot (0: ->2, 1: ->3).

    The slot write happens ONLY when a step actually occurred; writing
    unconditionally would re-target slot 1 with a self-assignment on every
    post-switch tag-3 iteration (and corrupt it if the slot indexing ever
    drifts from the tag clip).
    """
    stepped = mon2.tag > mon.tag
    slot = jnp.clip(mon.tag - 1, 0, 1)
    return jnp.where(stepped, switches.at[slot].set(it + 1), switches)


@partial(jax.jit, static_argnames=("maxiter", "params", "init_tag"))
def _solve_cg_fused(a, b, x0, tol, maxiter, params: P.MonitorParams,
                    init_tag: int = 1):
    """Fused-path CG over a ``GSECSR`` operand (DESIGN.md §4).

    Same trajectory as ``_solve_cg`` with the GSE operator -- each
    iteration is one ``fused_cg_step``: the values are decoded once at the
    monitor's current tag and the dots/axpys/residual norm ride the same
    sweep as the SpMV.
    """
    from repro.solvers.fused_cg import fused_cg_step, gse_matvec

    dtype = b.dtype
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    mon = P.init(params, dtype=dtype, tag=init_tag)
    r0 = b - gse_matvec(a, x0, mon.tag)
    state = dict(
        x=x0,
        r=r0,
        p=r0,
        rs=jnp.vdot(r0, r0),
        it=jnp.int32(0),
        mon=mon,
        switches=jnp.full((2,), -1, jnp.int32),
    )

    def relres(s):
        return jnp.sqrt(jnp.abs(s["rs"])) / bnorm

    def cond(s):
        return (relres(s) > tol) & (s["it"] < maxiter)

    def body(s):
        x, r, p, rs_new = fused_cg_step(
            a, s["x"], s["r"], s["p"], s["rs"], s["mon"].tag
        )
        mon = P.record(s["mon"], jnp.sqrt(jnp.abs(rs_new)) / bnorm)
        mon2 = P.update_tag(mon, params)
        switches = _record_switch(s["switches"], mon, mon2, s["it"])
        return dict(
            x=x, r=r, p=p, rs=rs_new, it=s["it"] + 1, mon=mon2, switches=switches
        )

    out = jax.lax.while_loop(cond, body, state)
    return CGResult(
        x=out["x"],
        iters=out["it"],
        relres=relres(out),
        tag=out["mon"].tag,
        switch_iters=out["switches"],
        converged=relres(out) <= tol,
    )


def solve_cg(
    apply_a: Union[Callable, GSECSR],
    b: jnp.ndarray,
    x0: jnp.ndarray | None = None,
    tol: float = 1e-6,
    maxiter: int = 5000,
    params: P.MonitorParams | None = None,
    final_correction: bool = False,
) -> CGResult:
    """CG for SPD systems.  ``apply_a(x, tag)`` is the (possibly multi-
    precision) operator; fixed-precision baselines ignore ``tag``.

    Passing a ``GSECSR`` directly as ``apply_a`` selects the fused
    iteration path (``fused_cg_step``): one decoded-value pass per
    iteration with the vector ops folded around the SpMV.  Trajectories
    are bit-identical to ``solve_cg(make_gse_operator(a), ...)``; only the
    kernel-launch structure differs.

    ``final_correction`` (beyond-paper safeguard): the recursive residual of
    a stepped run converges against the *perturbed* low-precision operator;
    the true residual can sit above ``tol``.  When enabled, the driver
    verifies the tag-3 residual after convergence and, if needed, resumes
    at full precision until the TRUE residual meets ``tol``.
    """
    if x0 is None:
        x0 = jnp.zeros_like(b)
    if params is None:
        params = P.MonitorParams.for_cg()
    tol_ = jnp.asarray(tol, b.dtype)
    fused = isinstance(apply_a, GSECSR)
    solve = _solve_cg_fused if fused else _solve_cg
    res = solve(apply_a, b, x0, tol_, maxiter, params)
    if not final_correction:
        return res
    if fused:
        from repro.solvers.fused_cg import gse_matvec

        def apply3(v):
            return gse_matvec(apply_a, v, jnp.int32(3))
    else:
        def apply3(v):
            return apply_a(v, jnp.int32(3))
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)
    true_rel = jnp.linalg.norm(b - apply3(res.x)) / bnorm
    if bool(res.converged) and float(true_rel) > tol:
        res2 = solve(
            apply_a, b, res.x, tol_, maxiter - int(res.iters), params,
            init_tag=3,
        )
        return CGResult(
            x=res2.x,
            iters=res.iters + res2.iters,
            relres=res2.relres,
            tag=res2.tag,
            switch_iters=res.switch_iters,
            converged=res2.converged,
        )
    return res
