"""Conjugate Gradient with stepped mixed precision (paper Alg. 3 + Sec IV).

Pure ``lax.while_loop``; the operator is called with the current precision
tag each iteration, and the residual monitor (core.precision) steps the tag
up when convergence stalls.  Faithful to the paper: the switch happens
in-place (no restart, no residual recomputation at the switch), matching
Algorithm 3.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import precision as P

__all__ = ["CGResult", "solve_cg"]


class CGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray       # iterations executed
    relres: jnp.ndarray      # final recursive relative residual
    tag: jnp.ndarray         # final precision tag
    switch_iters: jnp.ndarray  # (2,) iteration of tag->2 and tag->3 (-1: never)
    converged: jnp.ndarray


@partial(jax.jit, static_argnames=("apply_a", "maxiter", "params", "init_tag"))
def _solve_cg(apply_a, b, x0, tol, maxiter, params: P.MonitorParams,
              init_tag: int = 1):
    dtype = b.dtype
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    mon = P.init(params, dtype=dtype, tag=init_tag)
    r0 = b - apply_a(x0, mon.tag)
    state = dict(
        x=x0,
        r=r0,
        p=r0,
        rs=jnp.vdot(r0, r0),
        it=jnp.int32(0),
        mon=mon,
        switches=jnp.full((2,), -1, jnp.int32),
    )

    def relres(s):
        return jnp.sqrt(jnp.abs(s["rs"])) / bnorm

    def cond(s):
        return (relres(s) > tol) & (s["it"] < maxiter)

    def body(s):
        tag = s["mon"].tag
        ap = apply_a(s["p"], tag)
        denom = jnp.vdot(s["p"], ap)
        alpha = s["rs"] / jnp.where(denom == 0, 1.0, denom)
        x = s["x"] + alpha * s["p"]
        r = s["r"] - alpha * ap
        rs_new = jnp.vdot(r, r)
        mon = P.record(s["mon"], jnp.sqrt(jnp.abs(rs_new)) / bnorm)
        mon2 = P.update_tag(mon, params)
        stepped = mon2.tag > mon.tag
        switches = s["switches"]
        switches = switches.at[jnp.clip(mon.tag - 1, 0, 1)].set(
            jnp.where(stepped, s["it"] + 1, switches[jnp.clip(mon.tag - 1, 0, 1)])
        )
        beta = rs_new / jnp.where(s["rs"] == 0, 1.0, s["rs"])
        p = r + beta * s["p"]
        return dict(
            x=x, r=r, p=p, rs=rs_new, it=s["it"] + 1, mon=mon2, switches=switches
        )

    out = jax.lax.while_loop(cond, body, state)
    return CGResult(
        x=out["x"],
        iters=out["it"],
        relres=relres(out),
        tag=out["mon"].tag,
        switch_iters=out["switches"],
        converged=relres(out) <= tol,
    )


def solve_cg(
    apply_a: Callable,
    b: jnp.ndarray,
    x0: jnp.ndarray | None = None,
    tol: float = 1e-6,
    maxiter: int = 5000,
    params: P.MonitorParams | None = None,
    final_correction: bool = False,
) -> CGResult:
    """CG for SPD systems.  ``apply_a(x, tag)`` is the (possibly multi-
    precision) operator; fixed-precision baselines ignore ``tag``.

    ``final_correction`` (beyond-paper safeguard): the recursive residual of
    a stepped run converges against the *perturbed* low-precision operator;
    the true residual can sit above ``tol``.  When enabled, the driver
    verifies the tag-3 residual after convergence and, if needed, resumes
    at full precision until the TRUE residual meets ``tol``.
    """
    if x0 is None:
        x0 = jnp.zeros_like(b)
    if params is None:
        params = P.MonitorParams.for_cg()
    tol_ = jnp.asarray(tol, b.dtype)
    res = _solve_cg(apply_a, b, x0, tol_, maxiter, params)
    if not final_correction:
        return res
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)
    true_rel = jnp.linalg.norm(b - apply_a(res.x, jnp.int32(3))) / bnorm
    if bool(res.converged) and float(true_rel) > tol:
        res2 = _solve_cg(
            apply_a, b, res.x, tol_, maxiter - int(res.iters), params,
            init_tag=3,
        )
        return CGResult(
            x=res2.x,
            iters=res.iters + res2.iters,
            relres=res2.relres,
            tag=res2.tag,
            switch_iters=res.switch_iters,
            converged=res2.converged,
        )
    return res
