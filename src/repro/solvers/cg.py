"""Conjugate Gradient with stepped mixed precision (paper Alg. 3 + Sec IV).

Pure ``lax.while_loop``; the operator is called with the current precision
tag each iteration, and the residual monitor (core.precision) steps the tag
up when convergence stalls.  Faithful to the paper: the switch happens
in-place (no restart, no residual recomputation at the switch), matching
Algorithm 3.

Two equivalent hot paths (bit-identical trajectories):

  * generic: ``apply_a(x, tag)`` is any callable (fixed-precision
    baselines, dense operators, preconditioned wrappers);
  * fused:   pass a ``GSECSR`` directly as the operator and each iteration
    runs ``solvers.fused_cg.fused_cg_step`` -- one decoded-value pass with
    the dots/axpys folded around the SpMV (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.core import precision as P
from repro.core.tagmap import TagMap, normalize_tags
from repro.obs import flight as OF
from repro.obs import trace as OT
from repro.robustness.guards import (
    DEFAULT_GUARDS,
    GuardParams,
    HEALTH_OK,
    finalize_health,
    guard_init,
    guard_step,
    run_with_recovery,
    run_with_recovery_map,
)
from repro.sparse.csr import GSECSR, GSESellC

__all__ = ["CGResult", "solve_cg", "solve_pcg"]


def _normalize_tag_axis(tags, apply_a, m):
    """Normalize the public ``tags=`` axis (PR 10, DESIGN.md §18).

    Returns ``(init_tag_override, tm)`` -- at most one non-None:

      * ``None``            -> ``(None, None)``: legacy ``init_tag`` path;
      * int / uniform map   -> ``(tag, None)``: the SAME jaxpr as today's
        scalar ``tag=int`` API (the uniform fast path the bit-identity
        acceptance criterion pins);
      * non-uniform map     -> ``(None, tm)``: masked-operand path --
        requires a packed GSE operand whose tail segments can be zeroed.
    """
    norm = normalize_tags(tags, m)
    if norm is None or isinstance(norm, int):
        return norm, None
    tm = norm
    from repro.distributed.partition import PartitionedGSECSR

    if isinstance(apply_a, PartitionedGSECSR):
        raise NotImplementedError(
            "non-uniform TagMap schedules on sharded (PartitionedGSECSR) "
            "operands are not supported yet; int tags and uniform maps are"
        )
    if not isinstance(apply_a, (GSECSR, GSESellC)):
        raise ValueError(
            "a non-uniform TagMap needs a packed GSE operand (GSECSR/"
            "GSESellC) whose tail segments it can mask; got a generic "
            f"apply_a of type {type(apply_a).__name__}"
        )
    return None, tm


def _normalize_b_x0(b, x0):
    """Accept ``b``/``x0`` as ``(n,)`` or ``(n, 1)``; reject anything else.

    Returns ``(b_1d, x0_1d_or_None, orig_shape)`` -- the solvers run on the
    1-D view and reshape the solution back to the caller's layout, so the
    batched wrappers (``solvers.batched``) can delegate single columns
    without special cases.  Mismatched shapes or dtypes between ``b`` and
    ``x0`` raise a ``ValueError`` up front instead of a shape error deep
    inside a jitted ``while_loop``.
    """
    b = jnp.asarray(b)
    orig_shape = b.shape
    if b.ndim == 2 and b.shape[1] == 1:
        b = b[:, 0]
    elif b.ndim != 1:
        raise ValueError(
            f"b must be (n,) or (n, 1); got {orig_shape} -- for multi-RHS "
            "blocks use repro.solvers.batched"
        )
    if x0 is not None:
        x0 = jnp.asarray(x0)
        x0_shape = x0.shape
        if x0.ndim == 2 and x0.shape[1] == 1:
            x0 = x0[:, 0]
        elif x0.ndim != 1:
            raise ValueError(f"x0 must be (n,) or (n, 1); got {x0_shape}")
        if x0.shape[0] != b.shape[0]:
            raise ValueError(
                f"x0/b shape mismatch: x0 has {x0.shape[0]} rows, "
                f"b has {b.shape[0]}"
            )
        if x0.dtype != b.dtype:
            raise ValueError(
                f"x0/b dtype mismatch: {x0.dtype} vs {b.dtype}"
            )
    return b, x0, orig_shape


def _restore_shape(res, orig_shape):
    """Reshape the solution back to the caller's ``b`` layout."""
    if res.x.shape != orig_shape:
        res = res._replace(x=res.x.reshape(orig_shape))
    return res


class CGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray       # iterations executed
    relres: jnp.ndarray      # final recursive relative residual
    tag: jnp.ndarray         # final precision tag
    switch_iters: jnp.ndarray  # (2,) iteration of tag->2 and tag->3 (-1: never)
    converged: jnp.ndarray
    # Robustness (DESIGN.md §14): structured health code
    # (robustness.guards.HEALTH_*, name via ``health_name``) and the first
    # iteration a guard tripped (-1: never; >= 0 with health == ok means
    # "tripped, then recovered via tag escalation").
    health: jnp.ndarray = HEALTH_OK
    trip_iter: jnp.ndarray = -1
    # Observability (DESIGN.md §16): raw flight-recorder ring state (None
    # when recording is off); decode with ``obs.flight.FlightLog.from_state``.
    flight: object = None


def _guarded_init(state, relres0, guards):
    """Attach guard state + last-finite checkpoint to a loop state dict."""
    if guards is not None:
        state["g"] = guard_init(relres0)
        state["ckpt"] = state["x"]
    return state


def _guarded_cond(s, ok, guards):
    """AND the guard's health into a loop condition (no-op with guards off)."""
    if guards is not None:
        ok = ok & (s["g"]["health"] == HEALTH_OK)
    return ok


def _guarded_body(s, out, relres_new, guards, *, denom=None, breakdown=False,
                  finite_aux=()):
    """Run the guard over an iteration's new state and roll the checkpoint.

    Called AFTER the update arithmetic (which is identical with guards on
    or off -- the bit-identity contracts); records health/trip and keeps
    ``ckpt`` at the last state the guard judged healthy, which is what
    tag-escalation recovery rolls back to.
    """
    if guards is None:
        return out
    g = guard_step(s["g"], s["it"], relres_new, guards, denom=denom,
                   breakdown=breakdown, finite_aux=finite_aux)
    out["g"] = g
    out["ckpt"] = jnp.where(g["health"] == HEALTH_OK, out["x"], s["ckpt"])
    return out


def _guarded_result(out, relres, tol, guards, make):
    """Finalize health/trip and build ``(result, ckpt)`` from a loop exit."""
    conv = relres <= tol
    g = out.get("g") if guards is not None else None
    health, trip = finalize_health(g, conv, relres)
    res = make(conv, health, trip)
    ckpt = out["ckpt"] if guards is not None else out["x"]
    return res, ckpt


def _flight_init(state, flight, dtype):
    """Attach a flight-recorder ring buffer to a loop state dict."""
    if flight is not None:
        state["fl"] = OF.flight_init(flight, dtype)
    return state


def _flight_body(s, out, relres_new, flight, a0=None, a1=None, a2=None):
    """Append this iteration's flight row (pure observation, after the
    guard ran so the row carries the guard's verdict on this iteration).

    Same discipline as ``_guarded_body``: nothing here feeds back into the
    solver recurrence, so recorder-on stays bit-identical to recorder-off.
    """
    if flight is None:
        return out
    g = out.get("g")
    out["fl"] = OF.flight_record(
        s["fl"],
        it=s["it"],
        relres=relres_new,
        tag=s["mon"].tag,
        health=g["health"] if g is not None else None,
        a0=a0, a1=a1, a2=a2,
    )
    return out


@partial(jax.jit, static_argnames=("apply_a", "maxiter", "params", "init_tag",
                                   "guards", "flight", "return_ckpt",
                                   "return_state"))
def _solve_cg(apply_a, b, x0, tol, maxiter, params: P.MonitorParams,
              init_tag: int = 1, guards: GuardParams | None = None,
              flight: OF.FlightParams | None = None,
              return_ckpt: bool = False, resume=None, stop_at=None,
              return_state: bool = False):
    dtype = b.dtype
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    def relres(s):
        return jnp.sqrt(jnp.abs(s["rs"])) / bnorm

    # ``resume`` (DESIGN.md §17) carries a previous chunk's loop state
    # verbatim: the init section is skipped entirely, so a resumed loop
    # continues the EXACT op sequence the unchunked loop would have run.
    if resume is not None:
        state = resume
    else:
        mon = P.init(params, dtype=dtype, tag=init_tag)
        r0 = b - apply_a(x0, mon.tag)
        state = dict(
            x=x0,
            r=r0,
            p=r0,
            rs=jnp.vdot(r0, r0),
            it=jnp.int32(0),
            mon=mon,
            switches=jnp.full((2,), -1, jnp.int32),
        )
        state = _guarded_init(state, relres(state), guards)
        state = _flight_init(state, flight, dtype)

    def cond(s):
        ok = (relres(s) > tol) & (s["it"] < maxiter)
        if stop_at is not None:
            # Chunk boundary: a pure extra exit condition -- the body
            # arithmetic is untouched, so chunked == unchunked bitwise.
            ok = ok & (s["it"] < stop_at)
        return _guarded_cond(s, ok, guards)

    def body(s):
        tag = s["mon"].tag
        ap = apply_a(s["p"], tag)
        denom = jnp.vdot(s["p"], ap)
        alpha = s["rs"] / jnp.where(denom == 0, 1.0, denom)
        x = s["x"] + alpha * s["p"]
        r = s["r"] - alpha * ap
        rs_new = jnp.vdot(r, r)
        mon = P.record(s["mon"], jnp.sqrt(jnp.abs(rs_new)) / bnorm)
        mon2 = P.update_tag(mon, params)
        switches = _record_switch(s["switches"], mon, mon2, s["it"])
        beta = rs_new / jnp.where(s["rs"] == 0, 1.0, s["rs"])
        p = r + beta * s["p"]
        out = dict(
            x=x, r=r, p=p, rs=rs_new, it=s["it"] + 1, mon=mon2, switches=switches
        )
        out = _guarded_body(s, out, jnp.sqrt(jnp.abs(rs_new)) / bnorm,
                            guards, denom=denom)
        return _flight_body(s, out, jnp.sqrt(jnp.abs(rs_new)) / bnorm,
                            flight, a0=alpha, a1=beta, a2=denom)

    out = jax.lax.while_loop(cond, body, state)
    res, ckpt = _guarded_result(
        out, relres(out), tol, guards,
        lambda conv, health, trip: CGResult(
            x=out["x"],
            iters=out["it"],
            relres=relres(out),
            tag=out["mon"].tag,
            switch_iters=out["switches"],
            converged=conv,
            health=health,
            trip_iter=trip,
            flight=out.get("fl"),
        ),
    )
    if return_state:
        return res, ckpt, out
    return (res, ckpt) if return_ckpt else res


def _record_switch(switches, mon, mon2, it):
    """Log the iteration of a tag step-up into its slot (0: ->2, 1: ->3).

    The slot write happens ONLY when a step actually occurred; writing
    unconditionally would re-target slot 1 with a self-assignment on every
    post-switch tag-3 iteration (and corrupt it if the slot indexing ever
    drifts from the tag clip).
    """
    stepped = mon2.tag > mon.tag
    slot = jnp.clip(mon.tag - 1, 0, 1)
    return jnp.where(stepped, switches.at[slot].set(it + 1), switches)


@partial(jax.jit, static_argnames=("maxiter", "params", "init_tag", "guards",
                                   "flight", "return_ckpt", "return_state"))
def _solve_cg_fused(a, b, x0, tol, maxiter, params: P.MonitorParams,
                    init_tag: int = 1, guards: GuardParams | None = None,
                    flight: OF.FlightParams | None = None,
                    return_ckpt: bool = False, resume=None, stop_at=None,
                    return_state: bool = False):
    """Fused-path CG over a ``GSECSR`` operand (DESIGN.md §4).

    Same trajectory as ``_solve_cg`` with the GSE operator -- each
    iteration is one ``fused_cg_step``: the values are decoded once at the
    monitor's current tag and the dots/axpys/residual norm ride the same
    sweep as the SpMV.  With guards or the flight recorder the step also
    surfaces the curvature ``p.Ap`` it already computed
    (``fused_cg_step_g``) -- the update arithmetic is unchanged either way.

    ``resume``/``stop_at``/``return_state``: chunked execution hooks
    (DESIGN.md §17), as in :func:`_solve_cg`.
    """
    from repro.solvers.fused_cg import fused_cg_step, fused_cg_step_g, gse_matvec

    dtype = b.dtype
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    def relres(s):
        return jnp.sqrt(jnp.abs(s["rs"])) / bnorm

    if resume is not None:
        state = resume
    else:
        mon = P.init(params, dtype=dtype, tag=init_tag)
        r0 = b - gse_matvec(a, x0, mon.tag)
        state = dict(
            x=x0,
            r=r0,
            p=r0,
            rs=jnp.vdot(r0, r0),
            it=jnp.int32(0),
            mon=mon,
            switches=jnp.full((2,), -1, jnp.int32),
        )
        state = _guarded_init(state, relres(state), guards)
        state = _flight_init(state, flight, dtype)

    def cond(s):
        ok = (relres(s) > tol) & (s["it"] < maxiter)
        if stop_at is not None:
            ok = ok & (s["it"] < stop_at)
        return _guarded_cond(s, ok, guards)

    def body(s):
        if guards is None and flight is None:
            x, r, p, rs_new = fused_cg_step(
                a, s["x"], s["r"], s["p"], s["rs"], s["mon"].tag
            )
            denom = None
        else:
            x, r, p, rs_new, denom = fused_cg_step_g(
                a, s["x"], s["r"], s["p"], s["rs"], s["mon"].tag
            )
        mon = P.record(s["mon"], jnp.sqrt(jnp.abs(rs_new)) / bnorm)
        mon2 = P.update_tag(mon, params)
        switches = _record_switch(s["switches"], mon, mon2, s["it"])
        out = dict(
            x=x, r=r, p=p, rs=rs_new, it=s["it"] + 1, mon=mon2, switches=switches
        )
        out = _guarded_body(s, out, jnp.sqrt(jnp.abs(rs_new)) / bnorm,
                            guards, denom=denom)
        if flight is not None:
            # Observation-only recomputation of the step scalars from the
            # surfaced curvature (the fused step consumed them internally).
            alpha = s["rs"] / jnp.where(denom == 0, 1.0, denom)
            beta = rs_new / jnp.where(s["rs"] == 0, 1.0, s["rs"])
            out = _flight_body(s, out, jnp.sqrt(jnp.abs(rs_new)) / bnorm,
                               flight, a0=alpha, a1=beta, a2=denom)
        return out

    out = jax.lax.while_loop(cond, body, state)
    res, ckpt = _guarded_result(
        out, relres(out), tol, guards,
        lambda conv, health, trip: CGResult(
            x=out["x"],
            iters=out["it"],
            relres=relres(out),
            tag=out["mon"].tag,
            switch_iters=out["switches"],
            converged=conv,
            health=health,
            trip_iter=trip,
            flight=out.get("fl"),
        ),
    )
    if return_state:
        return res, ckpt, out
    return (res, ckpt) if return_ckpt else res


@partial(jax.jit, static_argnames=("apply_a", "apply_m", "maxiter", "params",
                                   "init_tag", "guards", "flight",
                                   "return_ckpt", "return_state"))
def _solve_pcg(apply_a, apply_m, b, x0, tol, maxiter, params: P.MonitorParams,
               init_tag: int = 1, guards: GuardParams | None = None,
               flight: OF.FlightParams | None = None,
               return_ckpt: bool = False, resume=None, stop_at=None,
               return_state: bool = False):
    """Preconditioned CG: ``z = M^{-1} r`` at the monitor's current tag.

    The recurrence runs on ``rz = r.z``; the monitor sees the plain
    residual norm ``sqrt(r.r)/||b||`` -- the same quantity the paper's
    controller watches in unpreconditioned CG.
    """
    dtype = b.dtype
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    def relres(s):
        return jnp.sqrt(jnp.abs(s["rr"])) / bnorm

    if resume is not None:
        state = resume
    else:
        mon = P.init(params, dtype=dtype, tag=init_tag)
        r0 = b - apply_a(x0, mon.tag)
        z0 = apply_m(r0, mon.tag)
        state = dict(
            x=x0,
            r=r0,
            p=z0,
            rz=jnp.vdot(r0, z0),
            rr=jnp.vdot(r0, r0),
            it=jnp.int32(0),
            mon=mon,
            switches=jnp.full((2,), -1, jnp.int32),
        )
        state = _guarded_init(state, relres(state), guards)
        state = _flight_init(state, flight, dtype)

    def cond(s):
        ok = (relres(s) > tol) & (s["it"] < maxiter)
        if stop_at is not None:
            ok = ok & (s["it"] < stop_at)
        return _guarded_cond(s, ok, guards)

    def body(s):
        tag = s["mon"].tag
        ap = apply_a(s["p"], tag)
        denom = jnp.vdot(s["p"], ap)
        alpha = s["rz"] / jnp.where(denom == 0, 1.0, denom)
        x = s["x"] + alpha * s["p"]
        r = s["r"] - alpha * ap
        z = apply_m(r, tag)
        rz_new = jnp.vdot(r, z)
        rr_new = jnp.vdot(r, r)
        mon = P.record(s["mon"], jnp.sqrt(jnp.abs(rr_new)) / bnorm)
        mon2 = P.update_tag(mon, params)
        switches = _record_switch(s["switches"], mon, mon2, s["it"])
        beta = rz_new / jnp.where(s["rz"] == 0, 1.0, s["rz"])
        p = z + beta * s["p"]
        out = dict(
            x=x, r=r, p=p, rz=rz_new, rr=rr_new, it=s["it"] + 1, mon=mon2,
            switches=switches,
        )
        # z.r < 0 breaks PCG's M-SPD contract: an extra breakdown predicate.
        out = _guarded_body(s, out, jnp.sqrt(jnp.abs(rr_new)) / bnorm,
                            guards, denom=denom, breakdown=rz_new < 0,
                            finite_aux=(rz_new,))
        return _flight_body(s, out, jnp.sqrt(jnp.abs(rr_new)) / bnorm,
                            flight, a0=alpha, a1=beta, a2=denom)

    out = jax.lax.while_loop(cond, body, state)
    res, ckpt = _guarded_result(
        out, relres(out), tol, guards,
        lambda conv, health, trip: CGResult(
            x=out["x"],
            iters=out["it"],
            relres=relres(out),
            tag=out["mon"].tag,
            switch_iters=out["switches"],
            converged=conv,
            health=health,
            trip_iter=trip,
            flight=out.get("fl"),
        ),
    )
    if return_state:
        return res, ckpt, out
    return (res, ckpt) if return_ckpt else res


@partial(jax.jit, static_argnames=("maxiter", "params", "init_tag", "guards",
                                   "flight", "return_ckpt", "return_state"))
def _solve_pcg_fused(a, m, b, x0, tol, maxiter, params: P.MonitorParams,
                     init_tag: int = 1, guards: GuardParams | None = None,
                     flight: OF.FlightParams | None = None,
                     return_ckpt: bool = False, resume=None, stop_at=None,
                     return_state: bool = False):
    """Fused-path PCG over a ``GSECSR`` operand and a pytree preconditioner.

    Each iteration is one ``fused_pcg_step``: operator decode and
    preconditioner apply ride the same tag branch (DESIGN.md §10), with
    the exact arithmetic of ``_solve_pcg`` -- bit-identical trajectories.
    """
    from repro.solvers.fused_cg import (fused_pcg_step, fused_pcg_step_g,
                                        gse_matvec)

    dtype = b.dtype
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)

    def relres(s):
        return jnp.sqrt(jnp.abs(s["rr"])) / bnorm

    if resume is not None:
        state = resume
    else:
        mon = P.init(params, dtype=dtype, tag=init_tag)
        r0 = b - gse_matvec(a, x0, mon.tag)
        z0 = m.apply(r0, mon.tag)
        state = dict(
            x=x0,
            r=r0,
            p=z0,
            rz=jnp.vdot(r0, z0),
            rr=jnp.vdot(r0, r0),
            it=jnp.int32(0),
            mon=mon,
            switches=jnp.full((2,), -1, jnp.int32),
        )
        state = _guarded_init(state, relres(state), guards)
        state = _flight_init(state, flight, dtype)

    def cond(s):
        ok = (relres(s) > tol) & (s["it"] < maxiter)
        if stop_at is not None:
            ok = ok & (s["it"] < stop_at)
        return _guarded_cond(s, ok, guards)

    def body(s):
        if guards is None and flight is None:
            x, r, p, rz_new, rr_new = fused_pcg_step(
                a, m, s["x"], s["r"], s["p"], s["rz"], s["mon"].tag
            )
            denom = None
        else:
            x, r, p, rz_new, rr_new, denom = fused_pcg_step_g(
                a, m, s["x"], s["r"], s["p"], s["rz"], s["mon"].tag
            )
        mon = P.record(s["mon"], jnp.sqrt(jnp.abs(rr_new)) / bnorm)
        mon2 = P.update_tag(mon, params)
        switches = _record_switch(s["switches"], mon, mon2, s["it"])
        out = dict(
            x=x, r=r, p=p, rz=rz_new, rr=rr_new, it=s["it"] + 1, mon=mon2,
            switches=switches,
        )
        out = _guarded_body(s, out, jnp.sqrt(jnp.abs(rr_new)) / bnorm,
                            guards, denom=denom, breakdown=rz_new < 0,
                            finite_aux=(rz_new,))
        if flight is not None:
            alpha = s["rz"] / jnp.where(denom == 0, 1.0, denom)
            beta = rz_new / jnp.where(s["rz"] == 0, 1.0, s["rz"])
            out = _flight_body(s, out, jnp.sqrt(jnp.abs(rr_new)) / bnorm,
                               flight, a0=alpha, a1=beta, a2=denom)
        return out

    out = jax.lax.while_loop(cond, body, state)
    res, ckpt = _guarded_result(
        out, relres(out), tol, guards,
        lambda conv, health, trip: CGResult(
            x=out["x"],
            iters=out["it"],
            relres=relres(out),
            tag=out["mon"].tag,
            switch_iters=out["switches"],
            converged=conv,
            health=health,
            trip_iter=trip,
            flight=out.get("fl"),
        ),
    )
    if return_state:
        return res, ckpt, out
    return (res, ckpt) if return_ckpt else res


def _finish_with_correction(res, b, tol, maxiter, apply3, resume):
    """Shared final-correction epilogue (``solve_cg`` / ``solve_pcg`` /
    ``solve_gmres`` -- ``CGResult`` and ``GMRESResult`` share fields):
    verify the TRUE tag-3 residual and, when the recursive convergence was
    optimistic, resume at full precision.  The resume budget is clamped to
    >= 1 -- the first solve may have exhausted ``maxiter`` exactly at
    tolerance, and a non-positive budget would run zero iterations and
    report a stale result."""
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)
    true_rel = jnp.linalg.norm(b - apply3(res.x)) / bnorm
    if not (bool(res.converged) and float(true_rel) > tol):
        return res
    res2 = resume(res.x, max(maxiter - int(res.iters), 1))
    return type(res)(
        x=res2.x,
        iters=res.iters + res2.iters,
        relres=res2.relres,
        tag=res2.tag,
        switch_iters=res.switch_iters,
        converged=res2.converged,
        health=res2.health,
        trip_iter=jnp.where(res2.trip_iter >= 0,
                            res2.trip_iter + res.iters, res.trip_iter),
        # The resumed segment's recording (its `it` restarts at 0); fall
        # back to the first run's when the resume didn't record.
        flight=res2.flight if res2.flight is not None else res.flight,
    )


def _pin_params(params: P.MonitorParams, max_tag: int) -> P.MonitorParams:
    """Pin the in-loop monitor at the map's max tag: with
    ``init_tag == max_tag`` the step predicate (``tag < max_tag``) is
    statically false, so a static TagMap IS the schedule -- no in-loop
    whole-operator stepping underneath a per-group map."""
    if params.max_tag == max_tag:
        return params
    return dataclasses.replace(params, max_tag=max_tag)


def _pack_map_flight(res, tme: TagMap):
    """Restamp a TagMap segment's flight rows with the packed (min, max)
    active tag pair (obs.flight satellite; schema unchanged for uniform
    maps)."""
    if res.flight is None:
        return res
    return res._replace(flight=OF.pack_state_tags(
        res.flight, tme.min_tag, tme.max_tag))


def _tagmap_run_cg(a, b, tol_, params, guards, flight, tm: TagMap):
    """Build the ``run(x_start, budget, floor)`` closure the per-group
    recovery ladder drives for CG: mask the operand at the floored map,
    decode at its max tag, monitor pinned (DESIGN.md §18)."""
    from repro.kernels.ops import masked_for_tagmap

    def run(x_start, budget, floor):
        tme = tm.floored(floor)
        res, ckpt = _solve_cg_fused(
            masked_for_tagmap(a, tme), b, x_start, tol_, budget,
            _pin_params(params, tme.max_tag), init_tag=tme.max_tag,
            guards=guards, flight=flight, return_ckpt=True)
        return _pack_map_flight(res, tme), ckpt

    return run


def _tagmap_run_pcg(a, precond, b, tol_, params, guards, flight,
                    fused: bool, tm: TagMap):
    """PCG twin of :func:`_tagmap_run_cg` -- the preconditioner stream
    runs at the map's MAX tag (the conservative charge
    ``iteration_stream_bytes`` models)."""
    from repro.kernels.ops import masked_for_tagmap

    if fused:
        def run(x_start, budget, floor):
            tme = tm.floored(floor)
            res, ckpt = _solve_pcg_fused(
                masked_for_tagmap(a, tme), precond, b, x_start, tol_,
                budget, _pin_params(params, tme.max_tag),
                init_tag=tme.max_tag, guards=guards, flight=flight,
                return_ckpt=True)
            return _pack_map_flight(res, tme), ckpt
    else:
        apply_m = precond if callable(precond) else precond.apply

        def run(x_start, budget, floor):
            tme = tm.floored(floor)
            res, ckpt = _solve_pcg(
                _gsecsr_operator(masked_for_tagmap(a, tme)), apply_m, b,
                x_start, tol_, budget, _pin_params(params, tme.max_tag),
                init_tag=tme.max_tag, guards=guards, flight=flight,
                return_ckpt=True)
            return _pack_map_flight(res, tme), ckpt

    return run


def _gsecsr_operator(a) -> Callable:
    """Tag-dispatched operator view of a GSECSR/GSESellC, memoized on the instance
    so repeated solves reuse one closure (the closure is a static jit
    argument -- a fresh one per call would retrace the whole solver)."""
    op = a.__dict__.get("_tag_operator")
    if op is None:
        from repro.solvers.fused_cg import gse_matvec

        def op(v, tag):
            return gse_matvec(a, v, tag)

        a.__dict__["_tag_operator"] = op
    return op


def solve_pcg(
    apply_a: Union[Callable, GSECSR],
    b: jnp.ndarray,
    precond,
    x0: jnp.ndarray | None = None,
    tol: float = 1e-6,
    maxiter: int = 5000,
    params: P.MonitorParams | None = None,
    final_correction: bool = False,
    wire: str = "exact",
    guards: GuardParams | None = DEFAULT_GUARDS,
    recover: bool = True,
    init_tag: int = 1,
    flight: OF.FlightParams | None = None,
    tags=None,
) -> CGResult:
    """Preconditioned CG for SPD systems with stepped mixed precision.

    ``precond`` is a preconditioner from :mod:`repro.solvers.precond`
    (exposing ``apply``/``apply_at``) or any callable ``apply_m(r, tag)``.
    Both the operator and the preconditioner are applied at the monitor's
    current tag, so the preconditioner stream follows the same precision
    schedule without a second stored copy.

    Passing a ``GSECSR`` as ``apply_a`` together with a precond *object*
    selects the fused iteration path (``fused_pcg_step``) -- bit-identical
    to the generic path, fewer kernel launches.  Passing a
    ``PartitionedGSECSR`` selects the fully-sharded distributed loop
    (``solvers.sharded``; ``wire`` picks the halo wire format and is
    ignored otherwise).

    ``guards`` (a :class:`repro.robustness.GuardParams`, default on; pass
    ``None`` to compile the pre-guard loop) adds in-loop breakdown/
    divergence/non-finite/stall detection; with ``recover`` a trip at
    tag < 3 rolls back to the last finite checkpoint and escalates the
    tag (DESIGN.md §14).  ``init_tag`` starts the monitor above tag 1
    (e.g. 3 = the exact path -- the serving layer's fallback).

    ``flight`` (a :class:`repro.obs.FlightParams`; default off) carries a
    device-side per-iteration flight recorder through the loop, returned
    raw on ``CGResult.flight`` -- decode with
    ``obs.flight.FlightLog.from_state``.  Bit-identical trajectories
    either way (DESIGN.md §16).

    ``tags`` (PR 10, DESIGN.md §18) selects the precision axis: an int or
    a uniform :class:`~repro.core.tagmap.TagMap` overrides ``init_tag``
    (same jaxpr, bit-identical); a NON-uniform map runs the masked-operand
    per-group schedule (the map IS the schedule -- the in-loop monitor is
    pinned, and recovery escalates the map's FLOOR instead of the whole
    operator); ``"adaptive"`` hands off to
    :func:`repro.solvers.adaptive.solve_adaptive`.

    ``b``/``x0`` may be ``(n,)`` or ``(n, 1)``; the solution comes back in
    ``b``'s layout.
    """
    from repro.distributed.partition import PartitionedGSECSR

    if isinstance(tags, str):
        if tags != "adaptive":
            raise ValueError(
                f"tags= accepts an int tag, a TagMap, or 'adaptive'; "
                f"got {tags!r}")
        from repro.solvers.adaptive import solve_adaptive

        return solve_adaptive(apply_a, b, precond=precond, x0=x0, tol=tol,
                              maxiter=maxiter, params=params)
    t_override, tm = _normalize_tag_axis(tags, apply_a,
                                         int(jnp.asarray(b).shape[0]))
    if t_override is not None:
        init_tag = t_override

    if isinstance(apply_a, PartitionedGSECSR):
        from repro.solvers.sharded import solve_pcg_sharded

        return solve_pcg_sharded(apply_a, b, precond, x0=x0, tol=tol,
                                 maxiter=maxiter, params=params, wire=wire,
                                 final_correction=final_correction,
                                 guards=guards, recover=recover,
                                 init_tag=init_tag, flight=flight)
    b, x0, orig_shape = _normalize_b_x0(b, x0)
    if x0 is None:
        x0 = jnp.zeros_like(b)
    if params is None:
        params = P.MonitorParams.for_cg()
    tol_ = jnp.asarray(tol, b.dtype)
    fused = (isinstance(apply_a, (GSECSR, GSESellC))
             and hasattr(precond, "apply_at"))

    if tm is not None:
        run = _tagmap_run_pcg(apply_a, precond, b, tol_, params, guards,
                              flight, fused, tm)
        with OT.span("solve.pcg", n=int(b.shape[0]), tol=float(tol),
                     init_tag=tm.max_tag, fused=fused):
            res = run_with_recovery_map(
                run, x0, maxiter, tm,
                recover=recover and guards is not None)
        if not final_correction:
            return _restore_shape(res, orig_shape)
        apply3_op = _gsecsr_operator(apply_a)

        def apply3(v):
            return apply3_op(v, jnp.int32(3))

        def resume(xr, budget):
            return run(xr, budget, 3)[0]

        return _restore_shape(
            _finish_with_correction(res, b, tol, maxiter, apply3, resume),
            orig_shape,
        )

    if fused:
        def run(x_start, budget, tag):
            return _solve_pcg_fused(apply_a, precond, b, x_start, tol_,
                                    budget, params, init_tag=tag,
                                    guards=guards, flight=flight,
                                    return_ckpt=True)
    else:
        apply_m = precond if callable(precond) else precond.apply
        if isinstance(apply_a, (GSECSR, GSESellC)):
            apply_a = _gsecsr_operator(apply_a)

        def run(x_start, budget, tag):
            return _solve_pcg(apply_a, apply_m, b, x_start, tol_, budget,
                              params, init_tag=tag, guards=guards,
                              flight=flight, return_ckpt=True)

    with OT.span("solve.pcg", n=int(b.shape[0]), tol=float(tol),
                 init_tag=init_tag, fused=fused):
        res = run_with_recovery(run, x0, maxiter, init_tag=init_tag,
                                recover=recover and guards is not None)
    if not final_correction:
        return _restore_shape(res, orig_shape)
    apply3_op = _gsecsr_operator(apply_a) if fused else apply_a

    def apply3(v):
        return apply3_op(v, jnp.int32(3))

    def resume(xr, budget):
        return run(xr, budget, 3)[0]

    return _restore_shape(
        _finish_with_correction(res, b, tol, maxiter, apply3, resume),
        orig_shape,
    )


def solve_cg(
    apply_a: Union[Callable, GSECSR],
    b: jnp.ndarray,
    x0: jnp.ndarray | None = None,
    tol: float = 1e-6,
    maxiter: int = 5000,
    params: P.MonitorParams | None = None,
    final_correction: bool = False,
    wire: str = "exact",
    guards: GuardParams | None = DEFAULT_GUARDS,
    recover: bool = True,
    init_tag: int = 1,
    flight: OF.FlightParams | None = None,
    tags=None,
) -> CGResult:
    """CG for SPD systems.  ``apply_a(x, tag)`` is the (possibly multi-
    precision) operator; fixed-precision baselines ignore ``tag``.

    Passing a ``GSECSR`` directly as ``apply_a`` selects the fused
    iteration path (``fused_cg_step``): one decoded-value pass per
    iteration with the vector ops folded around the SpMV.  Trajectories
    are bit-identical to ``solve_cg(make_gse_operator(a), ...)``; only the
    kernel-launch structure differs.  Passing a ``PartitionedGSECSR``
    selects the fully-sharded distributed loop (``solvers.sharded``;
    ``wire`` picks the halo wire format and is ignored otherwise).

    ``final_correction`` (beyond-paper safeguard): the recursive residual of
    a stepped run converges against the *perturbed* low-precision operator;
    the true residual can sit above ``tol``.  When enabled, the driver
    verifies the tag-3 residual after convergence and, if needed, resumes
    at full precision until the TRUE residual meets ``tol``.

    ``guards``/``recover``/``init_tag``/``flight``: see :func:`solve_pcg`
    -- in-loop guardrails plus checkpoint-rollback tag-escalation recovery
    (DESIGN.md §14) and the per-iteration flight recorder (DESIGN.md §16).
    ``tags``: the per-group precision axis (PR 10) -- also documented
    there.

    ``b``/``x0`` may be ``(n,)`` or ``(n, 1)``; the solution comes back in
    ``b``'s layout.
    """
    from repro.distributed.partition import PartitionedGSECSR

    if isinstance(tags, str):
        if tags != "adaptive":
            raise ValueError(
                f"tags= accepts an int tag, a TagMap, or 'adaptive'; "
                f"got {tags!r}")
        from repro.solvers.adaptive import solve_adaptive

        return solve_adaptive(apply_a, b, x0=x0, tol=tol, maxiter=maxiter,
                              params=params)
    t_override, tm = _normalize_tag_axis(tags, apply_a,
                                         int(jnp.asarray(b).shape[0]))
    if t_override is not None:
        init_tag = t_override

    if isinstance(apply_a, PartitionedGSECSR):
        from repro.solvers.sharded import solve_cg_sharded

        return solve_cg_sharded(apply_a, b, x0=x0, tol=tol, maxiter=maxiter,
                                params=params, wire=wire,
                                final_correction=final_correction,
                                guards=guards, recover=recover,
                                init_tag=init_tag, flight=flight)
    b, x0, orig_shape = _normalize_b_x0(b, x0)
    if x0 is None:
        x0 = jnp.zeros_like(b)
    if params is None:
        params = P.MonitorParams.for_cg()
    tol_ = jnp.asarray(tol, b.dtype)
    fused = isinstance(apply_a, (GSECSR, GSESellC))
    solve = _solve_cg_fused if fused else _solve_cg

    if tm is not None:
        run = _tagmap_run_cg(apply_a, b, tol_, params, guards, flight, tm)
        with OT.span("solve.cg", n=int(b.shape[0]), tol=float(tol),
                     init_tag=tm.max_tag, fused=True):
            res = run_with_recovery_map(
                run, x0, maxiter, tm,
                recover=recover and guards is not None)
        if not final_correction:
            return _restore_shape(res, orig_shape)
        apply3_op = _gsecsr_operator(apply_a)

        def apply3(v):
            return apply3_op(v, jnp.int32(3))

        def resume(xr, budget):
            return run(xr, budget, 3)[0]

        return _restore_shape(
            _finish_with_correction(res, b, tol, maxiter, apply3, resume),
            orig_shape,
        )

    def run(x_start, budget, tag):
        return solve(apply_a, b, x_start, tol_, budget, params,
                     init_tag=tag, guards=guards, flight=flight,
                     return_ckpt=True)

    with OT.span("solve.cg", n=int(b.shape[0]), tol=float(tol),
                 init_tag=init_tag, fused=fused):
        res = run_with_recovery(run, x0, maxiter, init_tag=init_tag,
                                recover=recover and guards is not None)
    if not final_correction:
        return _restore_shape(res, orig_shape)
    apply3_op = _gsecsr_operator(apply_a) if fused else apply_a

    def apply3(v):
        return apply3_op(v, jnp.int32(3))

    def resume(xr, budget):
        return run(xr, budget, 3)[0]

    return _restore_shape(
        _finish_with_correction(res, b, tol, maxiter, apply3, resume),
        orig_shape,
    )
