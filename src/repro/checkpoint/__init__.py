"""checkpoint subpackage."""
