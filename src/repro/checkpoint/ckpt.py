"""Checkpointing: msgpack+zstd, async double-buffered, hash-verified,
elastic re-sharding on restore.

Fault-tolerance contract (DESIGN.md §5):
  * ``save`` writes to a temp dir, fsyncs, verifies a content hash, then
    atomically renames -- a crash mid-write never corrupts the latest
    checkpoint (the previous one survives; ``latest_step`` skips partials).
  * ``save_async`` does the serialization off-thread (double-buffered:
    at most one outstanding write; the train loop never blocks on I/O
    beyond the device->host copy).
  * ``restore(..., target_sharding=...)`` re-shards arrays onto a
    different mesh than they were saved from (elastic restart).
  * ``save`` stamps a canonical pytree CRC32 (``tree_crc32``: keypath +
    dtype + shape + bytes per leaf, sorted key order) into ``meta.json``
    and ``restore`` re-derives it from the decoded arrays -- a checkpoint
    whose *contents* were corrupted (not just the compressed blob) raises
    ``CheckpointCorrupt``, and ``restore_latest_valid`` walks back to the
    previous good step so a chunked solve re-runs from there instead of
    resuming from garbage (DESIGN.md §17).
"""
from __future__ import annotations

import concurrent.futures as cf
import hashlib
import json
import os
import shutil
import struct
import threading
from typing import Any, Dict, Optional

import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional dep: fall back to zlib where the wheel is absent
    import zstandard as zstd
except ImportError:  # pragma: no cover - depends on environment
    zstd = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstd is not None:
        return zstd.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(comp: bytes) -> bytes:
    if comp[:4] == _ZSTD_MAGIC:
        if zstd is None:
            raise ImportError(
                "checkpoint is zstd-compressed but zstandard is not installed"
            )
        return zstd.ZstdDecompressor().decompress(comp)
    return zlib.decompress(comp)


_EXEC = cf.ThreadPoolExecutor(max_workers=1)
_PENDING: Dict[str, cf.Future] = {}
_LOCK = threading.Lock()


class CheckpointCorrupt(IOError):
    """A checkpoint failed integrity verification (blob hash or tree CRC).

    Subclasses ``IOError`` so pre-existing ``except IOError`` handlers
    keep working; new code should catch this and fall back to the
    previous good step (``restore_latest_valid``).
    """


def _flat_crc32(flat: Dict[str, np.ndarray]) -> int:
    """Canonical CRC32 of a flattened pytree: keypath, dtype, shape, bytes
    per leaf, folded in sorted-key order so the digest is independent of
    dict insertion order."""
    crc = 0
    for key in sorted(flat):
        a = np.ascontiguousarray(flat[key])
        head = f"{key}|{a.dtype.str}|{a.shape}|".encode()
        crc = zlib.crc32(a.tobytes(), zlib.crc32(head, crc))
    return crc & 0xFFFFFFFF


def tree_crc32(tree: Any) -> int:
    """Canonical content CRC32 of a pytree of arrays (host copy implied)."""
    return _flat_crc32(_flatten(tree))


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = np.asarray(leaf)
    return flat


def _pack_array(a: np.ndarray) -> Dict:
    return {
        "dtype": str(a.dtype),
        "shape": list(a.shape),
        "data": a.tobytes(),
    }


def _unpack_array(d: Dict) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(
        d["shape"]
    )


def save(path: str, tree: Any, step: int, extra: Optional[Dict] = None
         ) -> str:
    """Synchronous atomic save. Returns the final checkpoint dir."""
    flat = _flatten(tree)
    crc = _flat_crc32(flat)
    payload = {
        "step": step,
        "extra": extra or {},
        "arrays": {k: _pack_array(v) for k, v in flat.items()},
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    comp = _compress(raw)
    digest = hashlib.sha256(comp).hexdigest()

    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    with open(os.path.join(tmp, "ckpt.msgpack.zst"), "wb") as f:
        f.write(comp)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "sha256": digest, "bytes": len(comp),
                   "tree_crc32": crc}, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_async(path: str, tree: Any, step: int,
               extra: Optional[Dict] = None) -> cf.Future:
    """Double-buffered async save: waits for the previous write first
    (bounded memory), then snapshots to host and hands off to a thread."""
    with _LOCK:
        prev = _PENDING.get(path)
    if prev is not None:
        prev.result()  # at most one outstanding write per path
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # D2H now
    fut = _EXEC.submit(save, path, host_tree, step, extra)
    with _LOCK:
        _PENDING[path] = fut
    return fut


def wait_pending(path: str) -> None:
    with _LOCK:
        fut = _PENDING.get(path)
    if fut is not None:
        fut.result()


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = []
    for name in os.listdir(path):
        if name.startswith("step_") and not name.endswith(".tmp"):
            meta = os.path.join(path, name, "meta.json")
            if os.path.exists(meta):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(path: str, step: int, like: Any,
            target_sharding: Any = None) -> Any:
    """Restore into the structure of ``like``.

    ``target_sharding``: optional pytree of jax.sharding.Sharding matching
    ``like`` -- arrays are placed (re-sharded) accordingly, enabling
    elastic restarts onto a different mesh.
    """
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    with open(os.path.join(d, "ckpt.msgpack.zst"), "rb") as f:
        comp = f.read()
    if hashlib.sha256(comp).hexdigest() != meta["sha256"]:
        raise CheckpointCorrupt(f"checkpoint {d} failed integrity check")
    payload = msgpack.unpackb(_decompress(comp), raw=False)
    arrays = {k: _unpack_array(v) for k, v in payload["arrays"].items()}
    # End-to-end content check: re-derive the canonical pytree CRC from the
    # DECODED leaves and compare against the one stamped at save time.  The
    # sha256 above only covers the compressed blob; this catches anything
    # that slipped between serialization and decode (and checkpoints whose
    # meta was re-stamped to match a tampered blob fail here too).  Old
    # checkpoints without the stamp skip the check.
    if "tree_crc32" in meta and _flat_crc32(arrays) != meta["tree_crc32"]:
        raise CheckpointCorrupt(f"checkpoint {d} failed tree CRC32 check")

    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, leaf in flat_like[0]:
        key = jax.tree_util.keystr(path_keys)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        a = arrays[key]
        if tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {a.shape} vs {leaf.shape}"
            )
        leaves.append(a.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    if target_sharding is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, target_sharding
        )
    return tree, payload["step"], payload["extra"]


def list_steps(path: str) -> list:
    """All completed checkpoint steps under ``path``, ascending."""
    if not os.path.isdir(path):
        return []
    steps = []
    for name in os.listdir(path):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(path, name, "meta.json")):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def restore_latest_valid(path: str, like: Any, target_sharding: Any = None):
    """Restore the newest checkpoint that passes integrity verification.

    Walks steps newest-first, skipping any that raise ``CheckpointCorrupt``
    (or are unreadable/mismatched) -- the resilience contract for chunked
    solves: a corrupted latest checkpoint costs one re-run from the
    previous good one, never a crash and never silent garbage.  Returns
    ``(tree, step, extra, skipped)`` where ``skipped`` lists the corrupt
    steps passed over, or ``None`` when no valid checkpoint exists.
    """
    skipped = []
    for step in reversed(list_steps(path)):
        try:
            tree, got, extra = restore(path, step, like,
                                       target_sharding=target_sharding)
        except (CheckpointCorrupt, OSError, KeyError, ValueError,
                zlib.error, msgpack.exceptions.ExtraData,
                msgpack.exceptions.UnpackException):
            skipped.append(step)
            continue
        return tree, got, extra, skipped
    return None
