"""quant subpackage."""
