"""GSE-SEM quantization of LM weights (the paper's format at LM scale).

``quantize_tree`` packs every 2-D+ float leaf of a params tree into
``GSEPacked`` segments (per-tensor shared-exponent table, paper III.B);
``QuantLinear`` materializes the requested precision tag on the fly --
one stored copy, three serving precisions, exactly the storage/compute
decoupling the paper builds for sparse matrices.

Bytes per element: tag1 = 2, tag2 = 4, tag3 = 8 (vs f32 4 / bf16 2 with
fixed exponent bits).  At tag1 the 15-bit-mantissa head is ~16x more
precise than bf16's 8-bit significand for exponent-clustered weights
(LM weight tensors are strongly clustered -- see bench lm_gse_serving).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gse
from repro.kernels import ref as kref

__all__ = ["quantize_tree", "dequantize_tree", "gse_linear", "tree_bytes"]


def quantize_tree(params: Any, k: int = 8, min_size: int = 4096) -> Any:
    """Pack float leaves (>= min_size elems) to GSEPacked; keep the rest."""

    def q(leaf):
        if (
            hasattr(leaf, "dtype")
            and jnp.issubdtype(leaf.dtype, jnp.floating)
            and leaf.size >= min_size
        ):
            return gse.pack(np.asarray(leaf, np.float64), k)
        return leaf

    return jax.tree.map(q, params)


def dequantize_tree(packed: Any, tag: int = 2, dtype=jnp.bfloat16) -> Any:
    def dq(leaf):
        if isinstance(leaf, gse.GSEPacked):
            return gse.decode_jnp(leaf, tag, jnp.float32).astype(dtype)
        return leaf

    return jax.tree.map(
        dq, packed, is_leaf=lambda x: isinstance(x, gse.GSEPacked)
    )


def gse_linear(x: jnp.ndarray, w: Any, tag: int = 2,
               dtype=jnp.bfloat16) -> jnp.ndarray:
    """x @ W for dense or GSEPacked W (jnp decode path; the Pallas kernel
    ``repro.kernels.ops.gse_matmul`` is the TPU-fused equivalent)."""
    if isinstance(w, gse.GSEPacked):
        wd = gse.decode_jnp(w, tag, jnp.float32).astype(dtype)
        return jnp.dot(x.astype(dtype), wd)
    return jnp.dot(x.astype(dtype), w.astype(dtype))


def tree_bytes(tree: Any, tag: int = 2) -> int:
    """Bytes the parameter stream reads at serving precision ``tag``."""
    total = 0
    for leaf in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, gse.GSEPacked)
    ):
        if isinstance(leaf, gse.GSEPacked):
            total += leaf.nbytes(tag)
        elif hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total
