"""Jit'd public wrappers around the Pallas kernels.

Handles padding to tile multiples, scale-LUT precomputation, and
interpret-mode selection (interpret=True on CPU, compiled on TPU).
"""
from __future__ import annotations

import dataclasses
import functools
import zlib
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gse import GSEPacked
from repro.core.precision_table import TAG_BITS_USED, TAG_SEGMENTS
from repro.core.tagmap import TagMap
from repro.kernels import ref
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.kernels.gse_decode import decode_pallas
from repro.kernels.gse_matmul import gse_matmul_pallas
from repro.kernels.gse_spmm import gse_spmm_pallas, gse_spmm_sell_call
from repro.kernels.gse_spmv import gse_spmv_pallas, gse_spmv_sell_call
from repro.perf import plan as launch_plan
from repro.perf.plan import KernelPlan
from repro.sparse.csr import GSECSR, GSESellC, pack_sell, scatter_rows

__all__ = ["gse_decode", "gse_matmul", "gse_spmv_ell", "gse_spmm_ell",
           "gse_spmv_sell", "gse_spmm_sell", "ell_pack_gsecsr",
           "sell_pack_gsecsr", "spmv_kernel_for", "spmm_kernel_for",
           "sell_kernel_for", "sell_spmm_kernel_for", "PACK_STATS",
           "planned_spmv", "planned_spmm", "masked_for_tagmap",
           "sell_bucket_tags"]

# Operand-pack cache accounting: one entry per (operator instance, layout
# key).  ``hits``/``misses`` are module-global so tests (and the solve
# service) can assert that repeated solves against one registered operator
# perform ZERO host-side re-packing; ``evictions`` counts LRU drops and
# ``corrupt`` counts checksum-mismatch detect-and-repack events
# (DESIGN.md §14).  Storage lives in the metrics registry (DESIGN.md §16)
# -- this dict-shaped view keeps every historical call site working.
PACK_STATS = OM.stats_view(
    "repro_pack_cache_events_total",
    ("hits", "misses", "evictions", "corrupt"),
    help="Operand pack-cache events by outcome.",
)

# Per-operator-instance LRU bound.  Layout keys are few (one per
# (layout, lane/c/sigma) combination a caller sweeps), but a long-lived
# solve service re-registering layouts must not grow host memory without
# limit; exceeding the bound evicts least-recently-used entries.
PACK_CACHE_MAX = 8


def _entry_checksum(entry) -> int:
    """CRC32 over every array leaf of a packed-operand entry.

    Computed once at build time and re-verified on every cache hit: a
    silently corrupted pack (the fault model of DESIGN.md §14 -- host
    memory bit-flips in long-lived service processes) is detected and
    rebuilt instead of feeding garbage segments to every future solve.
    """
    ck = 0
    for leaf in jax.tree_util.tree_leaves(entry):
        ck = zlib.crc32(np.ascontiguousarray(np.asarray(leaf)).tobytes(), ck)
    return ck


def _cached_pack(a, key, build):
    """Memoize a packed-operand build on the operator instance itself.

    Keyed on identity (the instance's ``__dict__``, same idiom as the
    solvers' ``_tag_operator`` memo) + the layout parameters: the packed
    arrays live exactly as long as the operator, and every solver/benchmark
    path asking for the same layout gets the same arrays back without a
    numpy rescatter.

    Entries are ``(packed, crc32)`` in an LRU ``OrderedDict`` bounded by
    :data:`PACK_CACHE_MAX`; a hit re-verifies the checksum and a mismatch
    counts in ``PACK_STATS['corrupt']`` and triggers a repack.
    """
    cache = a.__dict__.setdefault("_pack_cache", OrderedDict())
    hit = key in cache
    if hit:
        entry, ck = cache[key]
        if _entry_checksum(entry) != ck:
            PACK_STATS["corrupt"] += 1
            hit = False  # detected corruption: fall through to repack
        else:
            PACK_STATS["hits"] += 1
            cache.move_to_end(key)
    if not hit:
        PACK_STATS["misses"] += 1
        with OT.span("pack.build", key=str(key)):
            entry = build()
        cache[key] = (entry, _entry_checksum(entry))
        cache.move_to_end(key)
        while len(cache) > PACK_CACHE_MAX:
            cache.popitem(last=False)
            PACK_STATS["evictions"] += 1
    return entry


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad2(a, bm, bn):
    m, n = a.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    return a


def gse_decode(packed: GSEPacked, tag: int = 1, block=(8, 128),
               interpret: bool | None = None) -> jnp.ndarray:
    """Decode a dense GSE-SEM tensor to f32 via the Pallas kernel."""
    if interpret is None:
        interpret = _interpret_default()
    shape = packed.head.shape
    head2 = packed.head.reshape(1, -1) if packed.head.ndim == 1 else packed.head
    t1 = packed.tail1.reshape(head2.shape)
    t2 = packed.tail2.reshape(head2.shape)
    bm, bn = block
    m0, n0 = head2.shape
    head2, t1, t2 = _pad2(head2, bm, bn), _pad2(t1, bm, bn), _pad2(t2, bm, bn)
    # Dense path: expIdx steals ei_bit head bits (TAG_BITS_USED assumes
    # the sparse layout's full 15-bit head).
    bits_used = TAG_BITS_USED[tag] - packed.ei_bit
    scales = ref.make_scales(packed.table, bits_used).reshape(1, -1)
    with jax.named_scope(f"gse_decode.tag{tag}"):
        out = decode_pallas(head2, t1, t2, scales, ei_bit=packed.ei_bit,
                            tag=tag, block=block, interpret=interpret)
    return out[:m0, :n0].reshape(shape)


def gse_matmul(x: jnp.ndarray, packed: GSEPacked, tag: int = 1,
               blocks=(8, 128, 128), interpret: bool | None = None):
    """x @ decode(W) with fused in-VMEM dequantization.

    x: (M, K) float; packed: GSE-SEM weights of logical shape (K, N).
    """
    if interpret is None:
        interpret = _interpret_default()
    bm, bn, bk = blocks
    kk, n = packed.head.shape
    m = x.shape[0]
    x2 = _pad2(x, bm, bk)
    head = _pad2(packed.head, bk, bn)
    t1 = _pad2(packed.tail1, bk, bn)
    t2 = _pad2(packed.tail2, bk, bn)
    bits_used = TAG_BITS_USED[tag] - packed.ei_bit
    scales = ref.make_scales(packed.table, bits_used).reshape(1, -1)
    out = gse_matmul_pallas(x2, head, t1, t2, scales, ei_bit=packed.ei_bit,
                            tag=tag, blocks=blocks, interpret=interpret)
    return out[:m, :n]


_SEGMENT_DTYPES = (
    ("colpak", np.uint32),
    ("head", np.uint16),
    ("tail1", np.uint16),
    ("tail2", np.uint32),
)


def ell_pack_gsecsr(a: GSECSR, lane: int | None = None,
                    plan: KernelPlan | None = None):
    """GSE-SEM CSR -> padded uniform-ELL segment arrays for the SpMV kernel.

    Returns (colpak, head, tail1, tail2) each (rows, L) with L lane-aligned.
    Padded slots: colpak=0, head=0 (mantissa 0 -> decodes to +0.0).  The
    scatter is ``csr.scatter_rows`` (shared with ``to_ell`` and the SELL
    packer) and the result is memoized on the operator instance -- repeat
    callers re-scatter nothing.  ``lane`` resolves explicit arg > ``plan``
    > the default 128 (DESIGN.md §15).
    """
    if lane is None:
        lane = (plan or launch_plan.DEFAULT_PLAN).lane

    def build():
        rowptr = np.asarray(a.rowptr, np.int64)
        L = int(max(1, np.diff(rowptr).max(initial=0)))
        L = ((L + lane - 1) // lane) * lane
        outs, _, _ = scatter_rows(
            rowptr, [(getattr(a, n), d) for n, d in _SEGMENT_DTYPES], L
        )
        return tuple(jnp.asarray(o) for o in outs)

    return _cached_pack(a, ("ell", lane), build)


def sell_pack_gsecsr(a: GSECSR, c: int | None = None,
                     sigma: int | None = None, lane: int | None = None,
                     bucket: str | None = None,
                     plan: KernelPlan | None = None) -> GSESellC:
    """GSE-SEM CSR -> SELL-C-σ packed layout, memoized on the operator
    instance (DESIGN.md §12).

    Layout parameters resolve explicit args > ``plan`` > the pre-PR-7
    defaults (C=8, full-sort σ, lane 128, pow2 width buckets).  The cache
    key is the resolved parameters; repeated solves, benchmark sweeps, and
    the solve service all share ONE host-side pack per operator --
    asserted via :data:`PACK_STATS` in tests/test_sell.py.
    """
    base = plan or launch_plan.DEFAULT_PLAN
    c = base.sell_c if c is None else c
    sigma = base.sell_sigma if sigma is None else sigma
    lane = base.lane if lane is None else lane
    bucket = base.sell_bucket if bucket is None else bucket
    return _cached_pack(
        a, ("sell", c, sigma, lane, bucket),
        lambda: pack_sell(a, c=c, sigma=sigma, lane=lane, bucket=bucket),
    )


def _masked_sell_for_tagmap(sell: GSESellC, tm: TagMap) -> GSESellC:
    """GSESellC twin of :func:`masked_for_tagmap`: per-bucket tail arrays
    masked slot-wise at the symmetric induced tag (max of the slot row's
    and column's group tags; padding slots are already all zero, so their
    nominal tag is irrelevant)."""

    def build():
        perm = np.asarray(sell.perm, np.int64)
        n = sell.shape[0]
        row_tags = tm.row_tags(n)
        cmask = np.uint32((1 << (32 - sell.ei_bit)) - 1)
        t1s, t2s, off = [], [], 0
        for cp, t1, t2 in zip(sell.colpak, sell.tail1, sell.tail2):
            rows = perm[off:off + t1.shape[0]]
            rt = np.where(rows >= 0, row_tags[np.maximum(rows, 0)], 1)
            cols = (np.asarray(cp, np.uint32) & cmask).astype(np.int64)
            ct = row_tags[np.minimum(cols, n - 1)]
            et = np.maximum(rt[:, None], ct)
            t1s.append(jnp.asarray(
                np.where(et >= 2, np.asarray(t1), 0).astype(np.uint16)))
            t2s.append(jnp.asarray(
                np.where(et >= 3, np.asarray(t2), 0).astype(np.uint32)))
            off += t1.shape[0]
        return dataclasses.replace(sell, tail1=tuple(t1s), tail2=tuple(t2s))

    return _cached_pack(sell, ("tagmap", tm.crc32, tm.group_size), build)


def masked_for_tagmap(a, tm: TagMap):
    """Per-group-precision view of ``a``: tail segments below each entry's
    INDUCED tag -- the max of its row's and its column's group tags, so a
    masked SPD operand stays exactly symmetric (CG's contract; see
    ``TagMap.entry_tags``) -- are zeroed (DESIGN.md §18).  ``a`` may be a
    ``GSECSR`` or an already-packed ``GSESellC`` (masked per slot).

    Decoding the masked operand with the map's MAX-tag formula is bitwise
    identical to decoding each entry at its own group tag: the zeroed
    splices contribute exactly 0 and the surviving partial mantissa times
    the max-tag power-of-two scale equals the lower-tag decode exactly
    (``m_head * 2^48 * 2^(e_sh-63) == m_head * 2^(e_sh-15)``; both
    factors are exact powers of two and every partial mantissa fits f64).
    So every existing tag-specialized pipeline -- fused solver steps, ELL
    and SELL kernels, the reference decode -- applies a non-uniform map
    with NO new kernel bodies.

    The result is memoized under the map's CRC32 (satellite 1: a promoted
    map can never hit a stale masked pack), shares the untouched segment
    arrays with ``a``, and carries its own ``_pack_cache`` so ELL/SELL
    packs of the masked view never collide with packs of ``a`` itself.
    """
    if isinstance(a, GSESellC):
        return _masked_sell_for_tagmap(a, tm)

    def build():
        cols = (np.asarray(a.colpak, np.uint32)
                & np.uint32((1 << (32 - a.ei_bit)) - 1))
        et = tm.entry_tags(np.asarray(a.row_ids), cols)
        t1 = np.where(et >= 2, np.asarray(a.tail1), 0).astype(np.uint16)
        t2 = np.where(et >= 3, np.asarray(a.tail2), 0).astype(np.uint32)
        return GSECSR(
            rowptr=a.rowptr, colpak=a.colpak, head=a.head,
            tail1=jnp.asarray(t1), tail2=jnp.asarray(t2),
            table=a.table, row_ids=a.row_ids, ei_bit=a.ei_bit,
            shape=a.shape,
        )

    return _cached_pack(a, ("tagmap", tm.crc32, tm.group_size), build)


def sell_bucket_tags(sell: GSESellC, tm: TagMap) -> tuple:
    """Per-width-bucket max group tag: the COARSE map unit the SELL kernels
    dispatch at (DESIGN.md §18).

    Each bucket runs one ``pallas_call`` whose operand list matches the
    bucket's max tag, so the lists stay static (jaxpr-checkable) and an
    all-tag-1 bucket genuinely never streams tails.  Entries inside a
    mixed bucket whose group demands less carry zeroed tails (the operand
    must come from :func:`masked_for_tagmap`), so the higher bucket tag
    changes streamed bytes, never values.
    """
    return sell.bucket_tags(tm)


@functools.lru_cache(maxsize=None)
def _sell_mixed_cached(bucket_tags: tuple, ei_bit: int, blocks,
                       interpret: bool, spmm: bool):
    """One jitted per-bucket dispatcher per (bucket-tag tuple, ei_bit,
    blocks): bucket ``i`` runs the tag-``bucket_tags[i]``-specialized
    kernel body, so each bucket's jaxpr operand list matches ITS tag."""
    from repro.kernels.gse_spmm import gse_spmm_call
    from repro.kernels.gse_spmv import gse_spmv_call

    base = gse_spmm_call if spmm else gse_spmv_call

    def run(buckets, unperm, x, scales_by_tag):
        outs = [
            base(cp, hd, t1, t2, x, scales_by_tag[t - 1], ei_bit=ei_bit,
                 tag=t, blocks=blocks, interpret=interpret)
            for (cp, hd, t1, t2), t in zip(buckets, bucket_tags)
        ]
        y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
        return y[unperm]

    return jax.jit(run)


def _gse_sell_tagmap(sell: GSESellC, x, tm: TagMap, blocks, interpret,
                     spmm: bool):
    """Shared TagMap body of ``gse_spmv_sell``/``gse_spmm_sell``: per-
    bucket max-tag dispatch over a masked pack."""
    btags = sell_bucket_tags(sell, tm)
    scales_by_tag = tuple(
        ref.make_scales(sell.table, TAG_BITS_USED[t]).reshape(1, -1)
        for t in (1, 2, 3)
    )
    buckets = tuple(
        (sell.colpak[i], sell.head[i],
         sell.tail1[i] if t >= 2 else None,
         sell.tail2[i] if t == 3 else None)
        for i, t in enumerate(btags)
    )
    kernel = _sell_mixed_cached(btags, sell.ei_bit, blocks, interpret, spmm)
    name = "gse_spmm_sell" if spmm else "gse_spmv_sell"
    with jax.named_scope(f"{name}.map{tm.crc32:08x}"):
        return kernel(buckets, sell.unperm, x, scales_by_tag)


def spmv_kernel_for(tag: int, ei_bit: int, blocks=None,
                    interpret: bool = True):
    """Tag-specialized SpMV dispatch: one cached ``pallas_call`` wrapper per
    ``(tag, ei_bit, blocks)`` (DESIGN.md §2.4).

    ``blocks=None`` resolves through the launch-plan dispatcher
    (``perf.plan.resolve``) to today's (8, 128) default; the returned
    callable takes exactly the operands that ``tag`` streams --
    ``(colpak, head, x, scales)`` for tag 1, ``+ tail1`` for tag 2,
    ``+ tail2`` for tag 3 -- so the tag-1/-2 kernels provably never touch
    the tail arrays (6/8/12 bytes per nnz of HBM traffic for tags 1/2/3).
    """
    blocks = launch_plan.resolve(blocks=blocks).blocks
    return _spmv_kernel_cached(tag, ei_bit, blocks, interpret)


@functools.lru_cache(maxsize=None)
def _spmv_kernel_cached(tag: int, ei_bit: int, blocks, interpret: bool):
    if tag == 1:
        def call(colpak, head, x, scales):
            return gse_spmv_pallas(colpak, head, None, None, x, scales,
                                   ei_bit=ei_bit, tag=1, blocks=blocks,
                                   interpret=interpret)
    elif tag == 2:
        def call(colpak, head, tail1, x, scales):
            return gse_spmv_pallas(colpak, head, tail1, None, x, scales,
                                   ei_bit=ei_bit, tag=2, blocks=blocks,
                                   interpret=interpret)
    elif tag == 3:
        def call(colpak, head, tail1, tail2, x, scales):
            return gse_spmv_pallas(colpak, head, tail1, tail2, x, scales,
                                   ei_bit=ei_bit, tag=3, blocks=blocks,
                                   interpret=interpret)
    else:
        raise ValueError(f"tag must be 1, 2 or 3, got {tag}")
    return call


def spmm_kernel_for(tag: int, ei_bit: int, blocks=None,
                    interpret: bool = True):
    """Tag-specialized SpMM dispatch: one cached ``pallas_call`` wrapper per
    ``(tag, ei_bit, blocks)`` -- the multi-RHS twin of ``spmv_kernel_for``
    (DESIGN.md §11).

    ``blocks=None`` resolves through the launch-plan dispatcher to
    today's (8, 128) default.  The returned callable takes exactly the
    operands ``tag`` streams -- ``(colpak, head, x, scales)`` for tag 1,
    ``+ tail1`` for tag 2, ``+ tail2`` for tag 3 -- with ``x`` a dense
    (n, nrhs) block.  The matrix segments are streamed ONCE per call
    however many right-hand sides ride along; the tag-1/-2 kernels
    provably never touch the tail arrays.
    """
    blocks = launch_plan.resolve(blocks=blocks).blocks
    return _spmm_kernel_cached(tag, ei_bit, blocks, interpret)


@functools.lru_cache(maxsize=None)
def _spmm_kernel_cached(tag: int, ei_bit: int, blocks, interpret: bool):
    if tag == 1:
        def call(colpak, head, x, scales):
            return gse_spmm_pallas(colpak, head, None, None, x, scales,
                                   ei_bit=ei_bit, tag=1, blocks=blocks,
                                   interpret=interpret)
    elif tag == 2:
        def call(colpak, head, tail1, x, scales):
            return gse_spmm_pallas(colpak, head, tail1, None, x, scales,
                                   ei_bit=ei_bit, tag=2, blocks=blocks,
                                   interpret=interpret)
    elif tag == 3:
        def call(colpak, head, tail1, tail2, x, scales):
            return gse_spmm_pallas(colpak, head, tail1, tail2, x, scales,
                                   ei_bit=ei_bit, tag=3, blocks=blocks,
                                   interpret=interpret)
    else:
        raise ValueError(f"tag must be 1, 2 or 3, got {tag}")
    return call


def gse_spmm_ell(ell, table, x: jnp.ndarray, ei_bit: int, tag: int = 1,
                 blocks=None, interpret: bool | None = None,
                 plan: KernelPlan | None = None):
    """Y = A @ X from ELL-packed GSE-SEM segments (Pallas SpMM kernel).

    ``x`` is a dense (n, nrhs) right-hand-side block.  Dispatches to the
    tag-specialized kernel (``spmm_kernel_for``): only the segment arrays
    ``tag`` reads are padded, passed, and streamed -- and they are
    streamed ONCE for all ``nrhs`` columns, so the modeled per-iteration
    traffic is ``iteration_stream_bytes(a, tag, nrhs=nrhs)`` instead of
    ``nrhs`` full SpMV passes (DESIGN.md §11).  Launch blocks resolve
    explicit ``blocks`` > ``plan`` > the (8, 128) default (§15).
    """
    if interpret is None:
        interpret = _interpret_default()
    blocks = launch_plan.resolve(blocks=blocks, plan=plan).blocks
    colpak, head, t1, t2 = ell
    bm, bl = blocks
    m0 = colpak.shape[0]
    scales = ref.make_scales(table, TAG_BITS_USED[tag]).reshape(1, -1)
    kernel = spmm_kernel_for(tag, ei_bit, blocks, interpret)
    operands = [_pad2(colpak, bm, bl), _pad2(head, bm, bl)]
    if tag >= 2:
        operands.append(_pad2(t1, bm, bl))
    if tag == 3:
        operands.append(_pad2(t2, bm, bl))
    with jax.named_scope(f"gse_spmm_ell.tag{tag}"):
        out = kernel(*operands, x, scales)
    return out[:m0]


def planned_spmv(a: GSECSR, x: jnp.ndarray, tag: int = 1,
                 layout: str = "ell", plan: KernelPlan | None = None,
                 interpret: bool | None = None):
    """Operator-level SpMV with full launch-plan resolution (DESIGN.md §15).

    Resolves ``plan`` (explicit > tuned cache keyed on the operator's
    shape class > default), packs ``a`` with the plan's layout parameters
    (memoized, :func:`ell_pack_gsecsr`/:func:`sell_pack_gsecsr`), and
    dispatches the tag-specialized kernel with the plan's blocks.  This is
    the entry point the autotuner sweeps and the solve service registers.

    ``tag`` may be a :class:`~repro.core.tagmap.TagMap` (DESIGN.md §18):
    the operand is rebuilt through :func:`masked_for_tagmap` (memoized
    under the map's CRC) and the ELL path decodes at the map's max tag
    while the SELL path dispatches each width-bucket at ITS max group
    tag.  Plan resolution keys carry the map CRC, never a scalar tag.
    """
    plan = launch_plan.resolve(a, tag=tag, layout=layout, nrhs=1,
                               plan=plan)
    if isinstance(tag, TagMap):
        a = masked_for_tagmap(a, tag)
        if layout == "ell":
            tag = tag.max_tag  # masked tails: max-tag decode IS the map
    if layout == "sell":
        sell = sell_pack_gsecsr(a, plan=plan)
        blocks = (plan.blocks if plan.compatible_with_sell(sell)
                  else launch_plan.DEFAULT_BLOCKS)
        return gse_spmv_sell(sell, x, tag=tag, blocks=blocks,
                             interpret=interpret)
    if layout != "ell":
        raise ValueError(f"layout must be 'ell' or 'sell', got {layout!r}")
    ell = ell_pack_gsecsr(a, plan=plan)
    return gse_spmv_ell(ell, a.table, x, a.ei_bit, tag=tag,
                        blocks=plan.blocks, interpret=interpret)


def planned_spmm(a: GSECSR, x: jnp.ndarray, tag: int = 1,
                 layout: str = "ell", plan: KernelPlan | None = None,
                 interpret: bool | None = None):
    """Multi-RHS twin of :func:`planned_spmv` (X dense (n, nrhs))."""
    nrhs = x.shape[1]
    plan = launch_plan.resolve(a, tag=tag, layout=layout, nrhs=nrhs,
                               plan=plan)
    if isinstance(tag, TagMap):
        a = masked_for_tagmap(a, tag)
        if layout == "ell":
            tag = tag.max_tag  # masked tails: max-tag decode IS the map
    if layout == "sell":
        sell = sell_pack_gsecsr(a, plan=plan)
        blocks = (plan.blocks if plan.compatible_with_sell(sell)
                  else launch_plan.DEFAULT_BLOCKS)
        return gse_spmm_sell(sell, x, tag=tag, blocks=blocks,
                             interpret=interpret)
    if layout != "ell":
        raise ValueError(f"layout must be 'ell' or 'sell', got {layout!r}")
    ell = ell_pack_gsecsr(a, plan=plan)
    return gse_spmm_ell(ell, a.table, x, a.ei_bit, tag=tag,
                        blocks=plan.blocks, interpret=interpret)


def _sell_dispatch(sell_call, tag: int, ei_bit: int, blocks, interpret):
    """Shared body of ``sell_kernel_for``/``sell_spmm_kernel_for``: pad
    each bucket's tag-specialized operand tuple back to the full
    ``(colpak, head, tail1, tail2)`` signature (absent tails stay
    ``None`` and never enter the jaxpr) and jit one wrapper around the
    per-bucket ``sell_call``."""
    if tag not in (1, 2, 3):
        raise ValueError(f"tag must be 1, 2 or 3, got {tag}")

    def call(buckets, unperm, x, scales):
        full = tuple(b + (None,) * (4 - len(b)) for b in buckets)
        return sell_call(full, unperm, x, scales, ei_bit=ei_bit, tag=tag,
                         blocks=blocks, interpret=interpret)

    return jax.jit(call)


def sell_kernel_for(tag: int, ei_bit: int, blocks=None,
                    interpret: bool = True):
    """Tag-specialized SELL-C-σ SpMV dispatch: one cached jitted wrapper
    per ``(tag, ei_bit, blocks)`` -- the sliced-layout twin of
    ``spmv_kernel_for`` (DESIGN.md §12).  ``blocks=None`` resolves
    through the launch-plan dispatcher to today's (8, 128) default.

    The returned callable takes ``(buckets, unperm, x, scales)`` where
    ``buckets`` holds per-width-bucket segment tuples containing exactly
    the operands ``tag`` streams -- ``(colpak, head)`` for tag 1,
    ``+ tail1`` for tag 2, ``+ tail2`` for tag 3.  Each bucket becomes its
    own ``pallas_call`` with the same tag-specialized operand list as the
    uniform-ELL kernel, so tag-1/-2 still provably never touch the tails.
    """
    blocks = launch_plan.resolve(blocks=blocks).blocks
    return _sell_kernel_cached(tag, ei_bit, blocks, interpret)


@functools.lru_cache(maxsize=None)
def _sell_kernel_cached(tag: int, ei_bit: int, blocks, interpret: bool):
    return _sell_dispatch(gse_spmv_sell_call, tag, ei_bit, blocks, interpret)


def sell_spmm_kernel_for(tag: int, ei_bit: int, blocks=None,
                         interpret: bool = True):
    """Multi-RHS twin of ``sell_kernel_for``: per-width-bucket SpMM
    dispatch with the same tag-specialized bucket operand lists."""
    blocks = launch_plan.resolve(blocks=blocks).blocks
    return _sell_spmm_kernel_cached(tag, ei_bit, blocks, interpret)


@functools.lru_cache(maxsize=None)
def _sell_spmm_kernel_cached(tag: int, ei_bit: int, blocks,
                             interpret: bool):
    return _sell_dispatch(gse_spmm_sell_call, tag, ei_bit, blocks, interpret)


def _sell_buckets(sell: GSESellC, tag: int):
    """Per-bucket operand tuples holding ONLY the segments ``tag`` reads
    (``TAG_SEGMENTS`` is the one source of truth for the tail list)."""
    segs = (sell.colpak, sell.head) + tuple(
        getattr(sell, name) for name in TAG_SEGMENTS[tag])
    return tuple(zip(*segs))


def _check_sell_blocks(sell: GSESellC, blocks) -> None:
    bm, bl = blocks
    if sell.c % bm != 0:
        raise ValueError(
            f"slice height {sell.c} must be a multiple of the row block "
            f"{bm} (bucket rows are not re-padded: that would desync the "
            "row permutation)"
        )
    if any(w % bl != 0 for w in sell.widths):
        raise ValueError(
            f"bucket widths {sell.widths} must be multiples of the lane "
            f"block {bl}"
        )


def _resolve_sell_blocks(sell: GSESellC, tag: int, nrhs: int, blocks,
                         plan: KernelPlan | None):
    """SELL launch-block resolution (DESIGN.md §15): explicit args keep
    today's validate-and-raise contract; a TUNED plan recorded for a
    different pack (its C/widths don't tile this one) silently falls back
    to the default blocks instead of raising."""
    if blocks is not None or plan is not None:
        resolved = launch_plan.resolve(blocks=blocks, plan=plan)
        _check_sell_blocks(sell, resolved.blocks)
        return resolved.blocks
    resolved = launch_plan.resolve(sell, tag=tag, layout="sell", nrhs=nrhs)
    if (resolved.source == "tuned"
            and not resolved.compatible_with_sell(sell)):
        resolved = launch_plan.DEFAULT_PLAN
    _check_sell_blocks(sell, resolved.blocks)
    return resolved.blocks


def gse_spmv_sell(sell: GSESellC, x: jnp.ndarray, tag: int = 1,
                  blocks=None, interpret: bool | None = None,
                  plan: KernelPlan | None = None):
    """y = A @ x from a SELL-C-σ packed GSE-SEM operand (Pallas kernels).

    One tag-specialized ``pallas_call`` per width-bucket; each slice
    streams only ITS lane-aligned width, so the modeled traffic is
    ``sell.bytes_touched(tag)`` -- actual padded slots, not the uniform-
    ELL max-width blowup (DESIGN.md §12).  Output is bitwise identical to
    ``gse_spmv_ell`` on the same operator (tests/test_sell.py).  Launch
    blocks resolve explicit ``blocks`` > ``plan`` > tuned cache entry >
    the (8, 128) default (§15).
    """
    if interpret is None:
        interpret = _interpret_default()
    blocks = _resolve_sell_blocks(sell, tag, 1, blocks, plan)
    if isinstance(tag, TagMap):
        return _gse_sell_tagmap(sell, x, tag, blocks, interpret, spmm=False)
    scales = ref.make_scales(sell.table, TAG_BITS_USED[tag]).reshape(1, -1)
    kernel = sell_kernel_for(tag, sell.ei_bit, blocks, interpret)
    with jax.named_scope(f"gse_spmv_sell.tag{tag}"):
        return kernel(_sell_buckets(sell, tag), sell.unperm, x, scales)


def gse_spmm_sell(sell: GSESellC, x: jnp.ndarray, tag: int = 1,
                  blocks=None, interpret: bool | None = None,
                  plan: KernelPlan | None = None):
    """Y = A @ X from a SELL-C-σ packed GSE-SEM operand, X dense (n, nrhs).

    The multi-RHS twin of ``gse_spmv_sell``: each width-bucket's matrix
    segments are streamed ONCE for all ``nrhs`` columns (DESIGN.md §11 +
    §12); bitwise identical to ``gse_spmm_ell`` on the same operator.
    Launch blocks resolve explicit ``blocks`` > ``plan`` > tuned cache
    entry > the (8, 128) default (§15).
    """
    if interpret is None:
        interpret = _interpret_default()
    blocks = _resolve_sell_blocks(sell, tag, x.shape[1] if x.ndim > 1
                                  else 1, blocks, plan)
    if isinstance(tag, TagMap):
        return _gse_sell_tagmap(sell, x, tag, blocks, interpret, spmm=True)
    scales = ref.make_scales(sell.table, TAG_BITS_USED[tag]).reshape(1, -1)
    kernel = sell_spmm_kernel_for(tag, sell.ei_bit, blocks, interpret)
    with jax.named_scope(f"gse_spmm_sell.tag{tag}"):
        return kernel(_sell_buckets(sell, tag), sell.unperm, x, scales)


def gse_spmv_ell(ell, table, x: jnp.ndarray, ei_bit: int, tag: int = 1,
                 blocks=None, interpret: bool | None = None,
                 plan: KernelPlan | None = None):
    """y = A @ x from ELL-packed GSE-SEM segments (Pallas kernel).

    Dispatches to the tag-specialized kernel (``spmv_kernel_for``): only the
    segment arrays ``tag`` reads are padded, passed, and streamed.  Modeled
    HBM traffic is bandwidth-proportional -- ``GSECSR.bytes_touched(tag)``
    gives the per-call byte count (6/8/12 bytes per nnz for tags 1/2/3
    vs 12 for FP64 CSR).  Launch blocks resolve explicit ``blocks`` >
    ``plan`` > the (8, 128) default (DESIGN.md §15).
    """
    if interpret is None:
        interpret = _interpret_default()
    blocks = launch_plan.resolve(blocks=blocks, plan=plan).blocks
    colpak, head, t1, t2 = ell
    bm, bl = blocks
    m0 = colpak.shape[0]
    scales = ref.make_scales(table, TAG_BITS_USED[tag]).reshape(1, -1)
    kernel = spmv_kernel_for(tag, ei_bit, blocks, interpret)
    operands = [_pad2(colpak, bm, bl), _pad2(head, bm, bl)]
    if tag >= 2:
        operands.append(_pad2(t1, bm, bl))
    if tag == 3:
        operands.append(_pad2(t2, bm, bl))
    with jax.named_scope(f"gse_spmv_ell.tag{tag}"):
        out = kernel(*operands, x, scales)
    return out[:m0]
