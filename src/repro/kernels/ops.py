"""Jit'd public wrappers around the Pallas kernels.

Handles padding to tile multiples, scale-LUT precomputation, and
interpret-mode selection (interpret=True on CPU, compiled on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gse import GSEPacked
from repro.kernels import ref
from repro.kernels.gse_decode import decode_pallas
from repro.kernels.gse_matmul import gse_matmul_pallas
from repro.kernels.gse_spmv import gse_spmv_pallas
from repro.sparse.csr import GSECSR

__all__ = ["gse_decode", "gse_matmul", "gse_spmv_ell", "ell_pack_gsecsr"]


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad2(a, bm, bn):
    m, n = a.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    return a


def gse_decode(packed: GSEPacked, tag: int = 1, block=(8, 128),
               interpret: bool | None = None) -> jnp.ndarray:
    """Decode a dense GSE-SEM tensor to f32 via the Pallas kernel."""
    if interpret is None:
        interpret = _interpret_default()
    shape = packed.head.shape
    head2 = packed.head.reshape(1, -1) if packed.head.ndim == 1 else packed.head
    t1 = packed.tail1.reshape(head2.shape)
    t2 = packed.tail2.reshape(head2.shape)
    bm, bn = block
    m0, n0 = head2.shape
    head2, t1, t2 = _pad2(head2, bm, bn), _pad2(t1, bm, bn), _pad2(t2, bm, bn)
    m_h = 15 - packed.ei_bit
    bits_used = {1: m_h, 2: m_h + 16, 3: m_h + 48}[tag]
    scales = ref.make_scales(packed.table, bits_used).reshape(1, -1)
    out = decode_pallas(head2, t1, t2, scales, ei_bit=packed.ei_bit, tag=tag,
                        block=block, interpret=interpret)
    return out[:m0, :n0].reshape(shape)


def gse_matmul(x: jnp.ndarray, packed: GSEPacked, tag: int = 1,
               blocks=(8, 128, 128), interpret: bool | None = None):
    """x @ decode(W) with fused in-VMEM dequantization.

    x: (M, K) float; packed: GSE-SEM weights of logical shape (K, N).
    """
    if interpret is None:
        interpret = _interpret_default()
    bm, bn, bk = blocks
    kk, n = packed.head.shape
    m = x.shape[0]
    x2 = _pad2(x, bm, bk)
    head = _pad2(packed.head, bk, bn)
    t1 = _pad2(packed.tail1, bk, bn)
    t2 = _pad2(packed.tail2, bk, bn)
    m_h = 15 - packed.ei_bit
    bits_used = {1: m_h, 2: m_h + 16, 3: m_h + 48}[tag]
    scales = ref.make_scales(packed.table, bits_used).reshape(1, -1)
    out = gse_matmul_pallas(x2, head, t1, t2, scales, ei_bit=packed.ei_bit,
                            tag=tag, blocks=blocks, interpret=interpret)
    return out[:m, :n]


def ell_pack_gsecsr(a: GSECSR, lane: int = 128):
    """GSE-SEM CSR -> padded ELL segment arrays for the SpMV kernel.

    Returns (colpak, head, tail1, tail2) each (rows, L) with L lane-aligned.
    Padded slots: colpak=0, head=0 (mantissa 0 -> decodes to +0.0).
    """
    rowptr = np.asarray(a.rowptr, np.int64)
    m = a.shape[0]
    per_row = np.diff(rowptr)
    L = int(max(1, per_row.max()))
    L = ((L + lane - 1) // lane) * lane
    rows = np.repeat(np.arange(m), per_row)
    slot = np.arange(rowptr[-1]) - np.repeat(rowptr[:-1], per_row)

    def scatter(src, dtype):
        out = np.zeros((m, L), dtype)
        out[rows, slot] = np.asarray(src)
        return jnp.asarray(out)

    return (
        scatter(a.colpak, np.uint32),
        scatter(a.head, np.uint16),
        scatter(a.tail1, np.uint16),
        scatter(a.tail2, np.uint32),
    )


def gse_spmv_ell(ell, table, x: jnp.ndarray, ei_bit: int, tag: int = 1,
                 blocks=(8, 128), interpret: bool | None = None):
    """y = A @ x from ELL-packed GSE-SEM segments (Pallas kernel)."""
    if interpret is None:
        interpret = _interpret_default()
    colpak, head, t1, t2 = ell
    bm, bl = blocks
    m0 = colpak.shape[0]
    colpak, head = _pad2(colpak, bm, bl), _pad2(head, bm, bl)
    t1, t2 = _pad2(t1, bm, bl), _pad2(t2, bm, bl)
    bits_used = {1: 15, 2: 31, 3: 63}[tag]
    scales = ref.make_scales(table, bits_used).reshape(1, -1)
    out = gse_spmv_pallas(colpak, head, t1, t2, x, scales, ei_bit=ei_bit,
                          tag=tag, blocks=blocks, interpret=interpret)
    return out[:m0, 0]
