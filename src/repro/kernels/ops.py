"""Jit'd public wrappers around the Pallas kernels.

Handles padding to tile multiples, scale-LUT precomputation, and
interpret-mode selection (interpret=True on CPU, compiled on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gse import GSEPacked
from repro.kernels import ref
from repro.kernels.gse_decode import decode_pallas
from repro.kernels.gse_matmul import gse_matmul_pallas
from repro.kernels.gse_spmm import gse_spmm_pallas
from repro.kernels.gse_spmv import gse_spmv_pallas
from repro.sparse.csr import GSECSR

__all__ = ["gse_decode", "gse_matmul", "gse_spmv_ell", "gse_spmm_ell",
           "ell_pack_gsecsr", "spmv_kernel_for", "spmm_kernel_for"]


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad2(a, bm, bn):
    m, n = a.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    return a


def gse_decode(packed: GSEPacked, tag: int = 1, block=(8, 128),
               interpret: bool | None = None) -> jnp.ndarray:
    """Decode a dense GSE-SEM tensor to f32 via the Pallas kernel."""
    if interpret is None:
        interpret = _interpret_default()
    shape = packed.head.shape
    head2 = packed.head.reshape(1, -1) if packed.head.ndim == 1 else packed.head
    t1 = packed.tail1.reshape(head2.shape)
    t2 = packed.tail2.reshape(head2.shape)
    bm, bn = block
    m0, n0 = head2.shape
    head2, t1, t2 = _pad2(head2, bm, bn), _pad2(t1, bm, bn), _pad2(t2, bm, bn)
    m_h = 15 - packed.ei_bit
    bits_used = {1: m_h, 2: m_h + 16, 3: m_h + 48}[tag]
    scales = ref.make_scales(packed.table, bits_used).reshape(1, -1)
    out = decode_pallas(head2, t1, t2, scales, ei_bit=packed.ei_bit, tag=tag,
                        block=block, interpret=interpret)
    return out[:m0, :n0].reshape(shape)


def gse_matmul(x: jnp.ndarray, packed: GSEPacked, tag: int = 1,
               blocks=(8, 128, 128), interpret: bool | None = None):
    """x @ decode(W) with fused in-VMEM dequantization.

    x: (M, K) float; packed: GSE-SEM weights of logical shape (K, N).
    """
    if interpret is None:
        interpret = _interpret_default()
    bm, bn, bk = blocks
    kk, n = packed.head.shape
    m = x.shape[0]
    x2 = _pad2(x, bm, bk)
    head = _pad2(packed.head, bk, bn)
    t1 = _pad2(packed.tail1, bk, bn)
    t2 = _pad2(packed.tail2, bk, bn)
    m_h = 15 - packed.ei_bit
    bits_used = {1: m_h, 2: m_h + 16, 3: m_h + 48}[tag]
    scales = ref.make_scales(packed.table, bits_used).reshape(1, -1)
    out = gse_matmul_pallas(x2, head, t1, t2, scales, ei_bit=packed.ei_bit,
                            tag=tag, blocks=blocks, interpret=interpret)
    return out[:m, :n]


def ell_pack_gsecsr(a: GSECSR, lane: int = 128):
    """GSE-SEM CSR -> padded ELL segment arrays for the SpMV kernel.

    Returns (colpak, head, tail1, tail2) each (rows, L) with L lane-aligned.
    Padded slots: colpak=0, head=0 (mantissa 0 -> decodes to +0.0).
    """
    rowptr = np.asarray(a.rowptr, np.int64)
    m = a.shape[0]
    per_row = np.diff(rowptr)
    L = int(max(1, per_row.max()))
    L = ((L + lane - 1) // lane) * lane
    rows = np.repeat(np.arange(m), per_row)
    slot = np.arange(rowptr[-1]) - np.repeat(rowptr[:-1], per_row)

    def scatter(src, dtype):
        out = np.zeros((m, L), dtype)
        out[rows, slot] = np.asarray(src)
        return jnp.asarray(out)

    return (
        scatter(a.colpak, np.uint32),
        scatter(a.head, np.uint16),
        scatter(a.tail1, np.uint16),
        scatter(a.tail2, np.uint32),
    )


@functools.lru_cache(maxsize=None)
def spmv_kernel_for(tag: int, ei_bit: int, blocks=(8, 128),
                    interpret: bool = True):
    """Tag-specialized SpMV dispatch: one cached ``pallas_call`` wrapper per
    ``(tag, ei_bit, blocks)`` (DESIGN.md §2.4).

    The returned callable takes exactly the operands that ``tag`` streams --
    ``(colpak, head, x, scales)`` for tag 1, ``+ tail1`` for tag 2,
    ``+ tail2`` for tag 3 -- so the tag-1/-2 kernels provably never touch
    the tail arrays (6/8/12 bytes per nnz of HBM traffic for tags 1/2/3).
    """
    if tag == 1:
        def call(colpak, head, x, scales):
            return gse_spmv_pallas(colpak, head, None, None, x, scales,
                                   ei_bit=ei_bit, tag=1, blocks=blocks,
                                   interpret=interpret)
    elif tag == 2:
        def call(colpak, head, tail1, x, scales):
            return gse_spmv_pallas(colpak, head, tail1, None, x, scales,
                                   ei_bit=ei_bit, tag=2, blocks=blocks,
                                   interpret=interpret)
    elif tag == 3:
        def call(colpak, head, tail1, tail2, x, scales):
            return gse_spmv_pallas(colpak, head, tail1, tail2, x, scales,
                                   ei_bit=ei_bit, tag=3, blocks=blocks,
                                   interpret=interpret)
    else:
        raise ValueError(f"tag must be 1, 2 or 3, got {tag}")
    return call


@functools.lru_cache(maxsize=None)
def spmm_kernel_for(tag: int, ei_bit: int, blocks=(8, 128),
                    interpret: bool = True):
    """Tag-specialized SpMM dispatch: one cached ``pallas_call`` wrapper per
    ``(tag, ei_bit, blocks)`` -- the multi-RHS twin of ``spmv_kernel_for``
    (DESIGN.md §11).

    The returned callable takes exactly the operands ``tag`` streams --
    ``(colpak, head, x, scales)`` for tag 1, ``+ tail1`` for tag 2,
    ``+ tail2`` for tag 3 -- with ``x`` a dense (n, nrhs) block.  The
    matrix segments are streamed ONCE per call however many right-hand
    sides ride along; the tag-1/-2 kernels provably never touch the tail
    arrays.
    """
    if tag == 1:
        def call(colpak, head, x, scales):
            return gse_spmm_pallas(colpak, head, None, None, x, scales,
                                   ei_bit=ei_bit, tag=1, blocks=blocks,
                                   interpret=interpret)
    elif tag == 2:
        def call(colpak, head, tail1, x, scales):
            return gse_spmm_pallas(colpak, head, tail1, None, x, scales,
                                   ei_bit=ei_bit, tag=2, blocks=blocks,
                                   interpret=interpret)
    elif tag == 3:
        def call(colpak, head, tail1, tail2, x, scales):
            return gse_spmm_pallas(colpak, head, tail1, tail2, x, scales,
                                   ei_bit=ei_bit, tag=3, blocks=blocks,
                                   interpret=interpret)
    else:
        raise ValueError(f"tag must be 1, 2 or 3, got {tag}")
    return call


def gse_spmm_ell(ell, table, x: jnp.ndarray, ei_bit: int, tag: int = 1,
                 blocks=(8, 128), interpret: bool | None = None):
    """Y = A @ X from ELL-packed GSE-SEM segments (Pallas SpMM kernel).

    ``x`` is a dense (n, nrhs) right-hand-side block.  Dispatches to the
    tag-specialized kernel (``spmm_kernel_for``): only the segment arrays
    ``tag`` reads are padded, passed, and streamed -- and they are
    streamed ONCE for all ``nrhs`` columns, so the modeled per-iteration
    traffic is ``iteration_stream_bytes(a, tag, nrhs=nrhs)`` instead of
    ``nrhs`` full SpMV passes (DESIGN.md §11).
    """
    if interpret is None:
        interpret = _interpret_default()
    colpak, head, t1, t2 = ell
    bm, bl = blocks
    m0 = colpak.shape[0]
    bits_used = {1: 15, 2: 31, 3: 63}[tag]
    scales = ref.make_scales(table, bits_used).reshape(1, -1)
    kernel = spmm_kernel_for(tag, ei_bit, blocks, interpret)
    operands = [_pad2(colpak, bm, bl), _pad2(head, bm, bl)]
    if tag >= 2:
        operands.append(_pad2(t1, bm, bl))
    if tag == 3:
        operands.append(_pad2(t2, bm, bl))
    out = kernel(*operands, x, scales)
    return out[:m0]


def gse_spmv_ell(ell, table, x: jnp.ndarray, ei_bit: int, tag: int = 1,
                 blocks=(8, 128), interpret: bool | None = None):
    """y = A @ x from ELL-packed GSE-SEM segments (Pallas kernel).

    Dispatches to the tag-specialized kernel (``spmv_kernel_for``): only the
    segment arrays ``tag`` reads are padded, passed, and streamed.  Modeled
    HBM traffic is bandwidth-proportional -- ``GSECSR.bytes_touched(tag)``
    gives the per-call byte count (6/8/12 bytes per nnz for tags 1/2/3
    vs 12 for FP64 CSR).
    """
    if interpret is None:
        interpret = _interpret_default()
    colpak, head, t1, t2 = ell
    bm, bl = blocks
    m0 = colpak.shape[0]
    bits_used = {1: 15, 2: 31, 3: 63}[tag]
    scales = ref.make_scales(table, bits_used).reshape(1, -1)
    kernel = spmv_kernel_for(tag, ei_bit, blocks, interpret)
    operands = [_pad2(colpak, bm, bl), _pad2(head, bm, bl)]
    if tag >= 2:
        operands.append(_pad2(t1, bm, bl))
    if tag == 3:
        operands.append(_pad2(t2, bm, bl))
    out = kernel(*operands, x, scales)
    return out[:m0]
