"""Pallas TPU kernel: blocked-ELL SpMV with fused GSE-SEM decode.

The paper's SpMV (Algorithm 2) re-thought for TPU (DESIGN.md §2):

  * rows padded to lane-aligned ELL width L -> dense (BM, BL) tiles;
  * expIdx rides the top EI_BIT bits of colpak (paper III.C.1), leaving
    all 15 non-sign head bits as mantissa;
  * decode = int->f32 convert * LUT scale (no __fns bit scan);
  * x is pinned in VMEM per block (single-chip kernel; the distributed
    layer shards rows across chips so each shard's x-slice fits VMEM).

Tag specialization (DESIGN.md §2.4): the whole point of GSE-SEM is that a
memory-bound SpMV touches only the bytes the current precision needs --
2/4/8 value bytes per nnz for tags 1/2/3.  One generic kernel that streams
all four segment arrays would make tag-1 pay tag-3 bandwidth, so each tag
gets its own kernel body whose ``pallas_call`` operand list contains ONLY
the segments that tag reads:

    tag 1   scales, colpak, head, x                   (6  B/nnz streamed)
    tag 2   scales, colpak, head, tail1, x            (8  B/nnz)
    tag 3   scales, colpak, head, tail1, tail2, x     (12 B/nnz)

Callers pass ``tail1=None`` / ``tail2=None`` for tags that do not read
them; the unused arrays never enter the jaxpr, never get a BlockSpec, and
never get DMA'd into VMEM.

Output layout (DESIGN.md §2.3): the kernel accumulates per-lane partial
sums into a lane-aligned (BM, 128) VMEM tile -- a (BM, BL) product tile is
reduced only across its BL/128 sublane groups, so every vector store fills
all 128 lanes instead of 1/128 of them.  A cheap reduction epilogue
(``acc.sum(axis=1)``) collapses the 128 partials per row after the grid
finishes.

Grid: (M/BM, L/BL); the L axis accumulates sequentially into the output
rows.  Padded slots carry col=0, head=0 -> mantissa 0 -> contribute 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision_table import tag_operand_names
from repro.kernels.gse_decode import _select_scale
from repro.perf import plan as launch_plan

__all__ = ["gse_spmv_pallas", "gse_spmv_call", "gse_spmv_sell_call",
           "spmv_operand_names", "decode_tile", "LANE"]

LANE = 128  # TPU vector-lane count; output accumulator minor dim


def spmv_operand_names(tag: int) -> tuple:
    """The pallas_call operand list the tag-specialized kernel streams
    (one source of truth: ``core.precision_table.TAG_SEGMENTS``)."""
    return tag_operand_names(tag)


def decode_tile(scales_ref, colpak_ref, head_ref, tail1_ref, tail2_ref, *,
                ei_bit: int, tag: int, k: int):
    """Decode one (BM, BL) tile of GSE-SEM segments -> (vals, col).

    The single in-kernel owner of the bit-level layout (expIdx in
    colpak's top ``ei_bit`` bits, 15-bit head mantissa, tail splices):
    the SpMV and SpMM kernel bodies both call this, so the decode cannot
    drift between the single- and multi-RHS pipelines.  Tail refs are
    ``None`` for the tags that skip them.
    """
    cp = colpak_ref[...].astype(jnp.uint32)
    shift = 32 - ei_bit
    exp_idx = (cp >> shift).astype(jnp.int32)
    col = (cp & ((1 << shift) - 1)).astype(jnp.int32)

    h = head_ref[...].astype(jnp.uint32)
    sgn = 1.0 - 2.0 * ((h >> 15) & 0x1).astype(jnp.float32)
    mant = (h & 0x7FFF).astype(jnp.float32)
    if tag >= 2:
        mant = mant * jnp.float32(65536.0) + tail1_ref[...].astype(jnp.float32)
    if tag == 3:
        mant = mant * jnp.float32(2.0**32) + tail2_ref[...].astype(jnp.float32)
    vals = sgn * mant * _select_scale(exp_idx, scales_ref, k)
    return vals, col


def _accumulate(scales_ref, colpak_ref, head_ref, tail1_ref, tail2_ref,
                x_ref, out_ref, *, ei_bit: int, tag: int, k: int):
    """Shared tile math; tail refs are ``None`` for the tags that skip them."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals, col = decode_tile(scales_ref, colpak_ref, head_ref, tail1_ref,
                            tail2_ref, ei_bit=ei_bit, tag=tag, k=k)

    xv = x_ref[0, :]                      # (N,) in VMEM
    xg = xv[col.reshape(-1)].reshape(col.shape)
    prod = vals * xg                      # (BM, BL)
    bm, bl = prod.shape
    # Lane-aligned partial sums: reduce only across the BL/LANE sublane
    # groups so the store fills all LANE lanes (DESIGN.md §2.3).
    out_ref[...] += jnp.sum(prod.reshape(bm, bl // LANE, LANE), axis=1)


def _spmv_body_tag1(scales_ref, colpak_ref, head_ref, x_ref, out_ref, *,
                    ei_bit: int, k: int):
    _accumulate(scales_ref, colpak_ref, head_ref, None, None, x_ref, out_ref,
                ei_bit=ei_bit, tag=1, k=k)


def _spmv_body_tag2(scales_ref, colpak_ref, head_ref, tail1_ref, x_ref,
                    out_ref, *, ei_bit: int, k: int):
    _accumulate(scales_ref, colpak_ref, head_ref, tail1_ref, None, x_ref,
                out_ref, ei_bit=ei_bit, tag=2, k=k)


def _spmv_body_tag3(scales_ref, colpak_ref, head_ref, tail1_ref, tail2_ref,
                    x_ref, out_ref, *, ei_bit: int, k: int):
    _accumulate(scales_ref, colpak_ref, head_ref, tail1_ref, tail2_ref, x_ref,
                out_ref, ei_bit=ei_bit, tag=3, k=k)


_BODIES = {1: _spmv_body_tag1, 2: _spmv_body_tag2, 3: _spmv_body_tag3}


def gse_spmv_call(colpak, head, tail1, tail2, x, scales, *, ei_bit: int,
                  tag: int, blocks=None, interpret: bool = True):
    """Unjitted tag-specialized SpMV (exported for jaxpr inspection).

    colpak/head (+tails the tag reads): (M, L); x: (N,); scales: (1, k).
    ``tail1``/``tail2`` may be ``None`` when ``tag`` does not read them;
    arrays passed for unread segments are ignored (not streamed).
    ``blocks=None`` resolves through ``perf.plan.resolve`` to the (8, 128)
    default (DESIGN.md §15).  Returns y = A @ x as a (M,) f32 vector.
    """
    blocks = launch_plan.resolve(blocks=blocks).blocks
    m, L = colpak.shape
    bm, bl = blocks
    assert m % bm == 0 and L % bl == 0, (colpak.shape, blocks)
    assert bl % LANE == 0, f"BL must be lane-aligned (multiple of {LANE})"
    n = x.shape[0]
    nk = scales.shape[1]
    grid = (m // bm, L // bl)
    tile = pl.BlockSpec((bm, bl), lambda i, l: (i, l))

    operands = [scales, colpak, head]
    in_specs = [pl.BlockSpec((1, nk), lambda i, l: (0, 0)), tile, tile]
    if tag >= 2:
        assert tail1 is not None, "tag>=2 reads tail1"
        operands.append(tail1)
        in_specs.append(tile)
    if tag == 3:
        assert tail2 is not None, "tag==3 reads tail2"
        operands.append(tail2)
        in_specs.append(tile)
    operands.append(x.reshape(1, n))
    in_specs.append(pl.BlockSpec((1, n), lambda i, l: (0, 0)))  # x pinned

    acc = pl.pallas_call(
        functools.partial(_BODIES[tag], ei_bit=ei_bit, k=nk),
        out_shape=jax.ShapeDtypeStruct((m, LANE), jnp.float32),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, LANE), lambda i, l: (i, 0)),
        interpret=interpret,
    )(*operands)
    # Reduction epilogue: collapse the LANE per-row partials.
    return jnp.sum(acc, axis=1)


gse_spmv_pallas = functools.partial(
    jax.jit,
    static_argnames=("ei_bit", "tag", "blocks", "interpret"),
)(gse_spmv_call)


def gse_spmv_sell_call(buckets, unperm, x, scales, *, ei_bit: int, tag: int,
                       blocks=None, interpret: bool = True):
    """Sliced-ELL SpMV: one tag-specialized ``pallas_call`` per width-bucket
    (DESIGN.md §12), reusing the uniform-ELL kernel body (``decode_tile``)
    unchanged.

    ``buckets`` is a tuple of per-bucket ``(colpak, head, tail1, tail2)``
    segment tuples, each ``(rows_b, w_b)`` with ``rows_b`` a multiple of
    ``blocks[0]`` and ``w_b`` of ``blocks[1]``; tails are ``None`` for the
    tags that skip them, exactly as in :func:`gse_spmv_call` -- the per-
    bucket operand lists stay tag-specialized and jaxpr-checkable.  Bucket
    rows are σ-sorted slice rows; ``unperm`` maps each ORIGINAL row to its
    position in the bucket concatenation, so the epilogue gather restores
    row order.

    Per-row arithmetic is IDENTICAL to the uniform-ELL kernel: a row's
    entries occupy the same in-row slots, the lane-group partial sums run
    over the same ascending slot groups, and trailing all-zero groups the
    uniform layout would add contribute exact zeros -- so SELL and uniform
    ELL outputs are equal (asserted bitwise in tests/test_sell.py).
    """
    outs = [
        gse_spmv_call(colpak, head, tail1, tail2, x, scales, ei_bit=ei_bit,
                      tag=tag, blocks=blocks, interpret=interpret)
        for colpak, head, tail1, tail2 in buckets
    ]
    y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    return y[unperm]
