"""Pallas TPU kernel: blocked-ELL SpMV with fused GSE-SEM decode.

The paper's SpMV (Algorithm 2) re-thought for TPU (DESIGN.md §2):

  * rows padded to lane-aligned ELL width L -> dense (BM, BL) tiles;
  * expIdx rides the top EI_BIT bits of colpak (paper III.C.1), leaving
    all 15 non-sign head bits as mantissa;
  * decode = int->f32 convert * LUT scale (no __fns bit scan);
  * x is pinned in VMEM per block (single-chip kernel; the distributed
    layer shards rows across chips so each shard's x-slice fits VMEM).

Grid: (M/BM, L/BL); the L axis accumulates sequentially into the output
rows.  Padded slots carry col=0, head=0 -> mantissa 0 -> contribute 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gse_decode import _select_scale

__all__ = ["gse_spmv_pallas"]


def _spmv_body(scales_ref, colpak_ref, head_ref, tail1_ref, tail2_ref, x_ref,
               out_ref, *, ei_bit: int, tag: int, k: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cp = colpak_ref[...].astype(jnp.uint32)
    shift = 32 - ei_bit
    exp_idx = (cp >> shift).astype(jnp.int32)
    col = (cp & ((1 << shift) - 1)).astype(jnp.int32)

    h = head_ref[...].astype(jnp.uint32)
    sgn = 1.0 - 2.0 * ((h >> 15) & 0x1).astype(jnp.float32)
    mant = (h & 0x7FFF).astype(jnp.float32)
    if tag >= 2:
        mant = mant * jnp.float32(65536.0) + tail1_ref[...].astype(jnp.float32)
    if tag == 3:
        mant = mant * jnp.float32(2.0**32) + tail2_ref[...].astype(jnp.float32)
    vals = sgn * mant * _select_scale(exp_idx, scales_ref, k)

    xv = x_ref[0, :]                      # (N,) in VMEM
    xg = xv[col.reshape(-1)].reshape(col.shape)
    out_ref[...] += jnp.sum(vals * xg, axis=1, keepdims=True)


@functools.partial(
    jax.jit,
    static_argnames=("ei_bit", "tag", "blocks", "interpret"),
)
def gse_spmv_pallas(colpak, head, tail1, tail2, x, scales, *, ei_bit: int,
                    tag: int, blocks=(8, 128), interpret: bool = True):
    """colpak/head/tail1/tail2: (M, L); x: (N,); scales: (1, k)."""
    m, L = colpak.shape
    bm, bl = blocks
    assert m % bm == 0 and L % bl == 0, (colpak.shape, blocks)
    n = x.shape[0]
    nk = scales.shape[1]
    grid = (m // bm, L // bl)
    tile = pl.BlockSpec((bm, bl), lambda i, l: (i, l))
    return pl.pallas_call(
        functools.partial(_spmv_body, ei_bit=ei_bit, tag=tag, k=nk),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nk), lambda i, l: (0, 0)),
            tile, tile, tile, tile,
            pl.BlockSpec((1, n), lambda i, l: (0, 0)),  # x pinned in VMEM
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, l: (i, 0)),
        interpret=interpret,
    )(scales, colpak, head, tail1, tail2, x.reshape(1, n))
