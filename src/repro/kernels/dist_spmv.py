"""shard_map distributed SpMV/SpMM over a row-sharded GSE-SEM operator.

Each shard streams ITS slice of the packed segment arrays through the
SAME tag-specialized decode the single-device solvers use
(``sparse.spmv._decode_gsecsr`` -- the fused CG/PCG steps' decode), then
reduces locally with a segment sum over local row ids.  What crosses the
interconnect is only the boundary x-entries, through the tag-aware halo
exchange (``distributed.wire.halo_all_gather``): a tag-1 iteration ships
2-byte GSE heads, tag 2 head+tail1, tag 3 exact float64 (DESIGN.md §13).

Entry points:

  * ``dist_spmv(part, x, tag)`` / ``dist_spmm(part, x, tag)`` -- one
    distributed y = A @ x over a full replicated ``x`` (``(n,)`` or
    ``(n, nrhs)``), returned gathered.  Output is BITWISE identical to
    ``spmv_gse``/``spmm_gse`` on the unsharded operator when
    ``wire="exact"`` (rows do not span shards, entry order is preserved,
    the decode is shared) -- asserted in tests/test_distributed.py.
  * ``make_sharded_operator(part)`` -- memoized ``apply(v, tag)`` closure
    (traced tag via ``lax.switch``) usable anywhere the solvers accept an
    operator callable: generic CG/PCG, GMRES, batched, IR.
  * ``local_matvec``/``shard_mesh`` -- building blocks the fully-sharded
    solver loop (``solvers.sharded``) reuses inside its own shard_map.

Everything runs on forced host CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) exactly as on a
real multi-device backend; the collectives are the same primitives.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.tagmap import TagMap, normalize_tags
from repro.distributed.partition import PartitionedGSECSR
from repro.distributed.wire import halo_all_gather
from repro.perf import plan as launch_plan
from repro.perf.plan import KernelPlan
from repro.sparse.spmv import _decode_gsecsr

__all__ = ["shard_mesh", "local_matvec", "dist_spmv", "dist_spmm",
           "make_sharded_operator"]

AXIS = "shards"


def shard_mesh(part: PartitionedGSECSR) -> Mesh:
    """A 1-D device mesh over the partition's shard count (memoized on the
    partition instance; requires ``jax.device_count() >= n_shards``)."""
    mesh = part.__dict__.get("_mesh")
    if mesh is None:
        devs = jax.devices()
        if len(devs) < part.n_shards:
            raise ValueError(
                f"partition wants {part.n_shards} shards but only "
                f"{len(devs)} devices are visible -- run under "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N"
            )
        mesh = Mesh(np.array(devs[:part.n_shards]), (AXIS,))
        part.__dict__["_mesh"] = mesh
    return mesh


def local_matvec(blk: dict, x_sh: jnp.ndarray, *, tag: int, wire: str,
                 k: int, rows: int, ei_bit: int,
                 acc_dtype=jnp.float64,
                 slot_tags: jnp.ndarray | None = None) -> jnp.ndarray:
    """One shard's y-block at a STATIC tag, called inside shard_map.

    ``blk`` holds this shard's slices (leading axis already dropped):
    ``colpak/head/tail1/tail2/row_ids/bnd_idx/halo_idx/table``.  The halo
    exchange gathers only boundary entries; the decode is the exact
    single-device ``_decode_gsecsr`` on the shard's segments, and the
    segment sum scatters into ``rows + 1`` slots so padding entries land
    in a dummy row (bit-identical local row sums).
    """
    if blk["bnd_idx"].shape[0] == 0:
        xcat = x_sh  # single shard: every column is local
    else:
        # Padded boundary slots (bnd_idx == -1) are masked to ZERO before
        # the wire pack: zeros are excluded from the shared-exponent
        # histogram, so a shard with fewer real boundary entries than the
        # padded width B cannot skew its wire table (the padded pool
        # slots are never gathered by halo_idx).
        idx = blk["bnd_idx"]
        valid = idx >= 0
        bnd = x_sh[jnp.clip(idx, 0, None)]
        mask = valid if x_sh.ndim == 1 else valid[:, None]
        bnd = jnp.where(mask, bnd, 0.0)
        pool = halo_all_gather(bnd, AXIS, tag=tag, wire=wire, k=k,
                               slot_tags=slot_tags)
        flat = pool.reshape((-1,) + pool.shape[2:])
        xcat = jnp.concatenate([x_sh, flat[blk["halo_idx"]]], axis=0)
    val, col = _decode_gsecsr(
        blk["colpak"], blk["head"], blk["tail1"], blk["tail2"],
        blk["table"], ei_bit, tag, acc_dtype,
    )
    xg = xcat.astype(acc_dtype)[col]
    prod = val * xg if x_sh.ndim == 1 else val[:, None] * xg
    return jax.ops.segment_sum(
        prod, blk["row_ids"], num_segments=rows + 1
    )[:rows]


def _blk(colpak, head, tail1, tail2, row_ids, bnd_idx, halo_idx, table):
    """Drop the leading per-device axis shard_map leaves on stacked
    operands and bundle the shard's block for ``local_matvec``."""
    return dict(
        colpak=colpak[0], head=head[0], tail1=tail1[0], tail2=tail2[0],
        row_ids=row_ids[0], bnd_idx=bnd_idx[0], halo_idx=halo_idx[0],
        table=table,
    )


def _dist_matvec_fn(part: PartitionedGSECSR, wire: str, ndim: int,
                    acc_dtype):
    """Jitted shard_map matvec over the stacked partition arrays, memoized
    on the partition instance (same idiom as the solvers' operator memo:
    a fresh closure per call would retrace everything)."""
    key = ("_dist_matvec", wire, ndim, jnp.dtype(acc_dtype).name)
    fn = part.__dict__.get(key)
    if fn is not None:
        return fn
    mesh = shard_mesh(part)
    rows, ei, k = part.rows_per_shard, part.ei_bit, int(part.table.size)

    def run(colpak, head, tail1, tail2, row_ids, bnd_idx, halo_idx, table,
            x, tag):
        blk = _blk(colpak, head, tail1, tail2, row_ids, bnd_idx, halo_idx,
                   table)
        branches = [
            partial(local_matvec, blk, tag=t, wire=wire, k=k, rows=rows,
                    ei_bit=ei, acc_dtype=acc_dtype)
            for t in (1, 2, 3)
        ]
        return jax.lax.switch(jnp.clip(tag - 1, 0, 2), branches, x)

    sharded = P(AXIS)
    fn = jax.jit(shard_map(
        run, mesh=mesh,
        in_specs=(sharded,) * 7 + (P(), sharded, P()),
        out_specs=sharded,
        check_rep=False,
    ))
    part.__dict__[key] = fn
    return fn


def _dist_matvec_map_fn(part: PartitionedGSECSR, tm: TagMap, wire: str,
                        ndim: int, acc_dtype):
    """shard_map matvec for a NON-UNIFORM tag map: the decode rides the
    map's static MAX tag (one collective, one payload width -- exactly the
    masked-operand contract ``kernels.ops.masked_for_tagmap`` documents)
    and the per-slot boundary tags ride as an extra sharded operand so
    tag-1 slots drop their tail segment on the wire.  Memoized per map
    ``crc32`` -- a promoted map can never reuse a stale trace."""
    key = ("_dist_matvec_map", tm.crc32, wire, ndim,
           jnp.dtype(acc_dtype).name)
    fn = part.__dict__.get(key)
    if fn is not None:
        return fn
    mesh = shard_mesh(part)
    rows, ei, k = part.rows_per_shard, part.ei_bit, int(part.table.size)
    tag = tm.max_tag

    def run(colpak, head, tail1, tail2, row_ids, bnd_idx, halo_idx, table,
            slot_tags, x):
        blk = _blk(colpak, head, tail1, tail2, row_ids, bnd_idx, halo_idx,
                   table)
        return local_matvec(blk, x, tag=tag, wire=wire, k=k, rows=rows,
                            ei_bit=ei, acc_dtype=acc_dtype,
                            slot_tags=slot_tags[0])

    sharded = P(AXIS)
    fn = jax.jit(shard_map(
        run, mesh=mesh,
        in_specs=(sharded,) * 7 + (P(), sharded, sharded),
        out_specs=sharded,
        check_rep=False,
    ))
    part.__dict__[key] = fn
    return fn


def _apply_padded(part: PartitionedGSECSR, x: jnp.ndarray, tag,
                  wire: str, acc_dtype) -> jnp.ndarray:
    n = part.shape[0]
    pad = part.n_padded - n
    if x.shape[0] != n:
        raise ValueError(f"operand wants x with {n} rows, got {x.shape}")
    xp = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1)) if pad else x
    if isinstance(tag, TagMap):
        fn = _dist_matvec_map_fn(part, tag, wire, x.ndim, acc_dtype)
        st = jnp.asarray(part.bnd_slot_tags(tag).astype(np.int32))
        y = fn(part.colpak, part.head, part.tail1, part.tail2,
               part.row_ids, part.bnd_idx, part.halo_idx, part.table,
               st, xp)
        return y[:n]
    fn = _dist_matvec_fn(part, wire, x.ndim, acc_dtype)
    y = fn(part.colpak, part.head, part.tail1, part.tail2, part.row_ids,
           part.bnd_idx, part.halo_idx, part.table, xp,
           jnp.asarray(tag, jnp.int32))
    return y[:n]


def _resolve_dist_plan(part, tag, nrhs, plan) -> KernelPlan:
    """Uniform launch-plan resolution for the distributed path (DESIGN.md
    §15): explicit plan > tuned cache (layout key "dist") > default.  The
    shard-local matvec rides the jnp segment-sum decode -- there is no
    Pallas block knob here yet -- so the resolved plan records provenance
    and reserves the slot a shard-local kernel will take its blocks from.
    Resolution is skipped for traced tags (the solvers' escalation path
    passes ``tag`` as a traced value).  A ``TagMap`` is static and keys
    the lookup under its CRC32 (``perf.plan.tag_token``)."""
    static_tag = isinstance(tag, (int, np.integer, TagMap))
    if static_tag and not isinstance(tag, TagMap):
        tag = int(tag)
    return launch_plan.resolve(
        part if static_tag else None,
        tag=tag if static_tag else None,
        layout="dist", nrhs=nrhs, plan=plan)


def dist_spmv(part: PartitionedGSECSR, x: jnp.ndarray, tag=1,
              wire: str = "exact", acc_dtype=jnp.float64,
              plan: KernelPlan | None = None) -> jnp.ndarray:
    """Distributed y = A @ x at precision ``tag`` (traced or static).

    ``x`` is the full ``(n,)`` operand; each shard computes its row block
    from its local x window plus the tag-aware halo, and the blocks come
    back gathered.  ``wire="exact"`` is bitwise equal to
    ``spmv_gse(a, x, tag)`` on the unsharded operator; ``wire="gse"``
    additionally compresses the tag-1/2 halo payloads (lossy on the
    boundary entries only -- the monitor's recursive residual still
    converges, it simply sees a slightly stronger low-tag perturbation).

    ``tag`` accepts the full tags axis: a uniform ``TagMap`` normalizes
    to the identical int path; a NON-uniform map decodes at its max tag
    with per-slot wire masking -- per-group semantics then require the
    caller to have partitioned the MASKED operand
    (``partition_gsecsr(kernels.ops.masked_for_tagmap(a, tm), s)``),
    exactly the single-device masked-segment contract.
    """
    if x.ndim != 1:
        raise ValueError(f"dist_spmv wants (n,); got {x.shape}")
    if isinstance(tag, TagMap):
        tag = normalize_tags(tag, part.shape[0])
    _resolve_dist_plan(part, tag, 1, plan)
    return _apply_padded(part, x, tag, wire, acc_dtype)


def dist_spmm(part: PartitionedGSECSR, x: jnp.ndarray, tag=1,
              wire: str = "exact", acc_dtype=jnp.float64,
              plan: KernelPlan | None = None) -> jnp.ndarray:
    """Distributed Y = A @ X over a dense ``(n, nrhs)`` block: the matrix
    segments stream once per shard and every column rides one shared halo
    exchange (boundary entries ship per column; this block path packs ONE
    wire table per call, strictly cheaper than the per-column apply path
    ``halo_wire_bytes(tag, wire, nrhs)`` models)."""
    if x.ndim != 2:
        raise ValueError(f"dist_spmm wants (n, nrhs); got {x.shape}")
    if isinstance(tag, TagMap):
        tag = normalize_tags(tag, part.shape[0])
    _resolve_dist_plan(part, tag, x.shape[1], plan)
    return _apply_padded(part, x, tag, wire, acc_dtype)


def make_sharded_operator(part: PartitionedGSECSR, wire: str = "exact",
                          acc_dtype=jnp.float64,
                          plan: KernelPlan | None = None):
    """Tag-dispatched ``apply(v, tag)`` over the partition, memoized on the
    instance (the closure is a static jit argument in the solvers -- the
    sharded twin of ``solvers.cg._gsecsr_operator``).  Accepts ``(n,)``
    vectors and ``(n, nrhs)`` blocks; usable as the operator callable in
    every solver path (generic CG/PCG, GMRES, batched, IR)."""
    key = ("_sharded_operator", wire, jnp.dtype(acc_dtype).name, plan)
    op = part.__dict__.get(key)
    if op is None:
        def op(v, tag):
            return _apply_padded(part, v, tag, wire, acc_dtype)

        part.__dict__[key] = op
    return op
