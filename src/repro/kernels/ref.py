"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the interpret-mode kernels are swept against.
All decode math matches the kernels' f32 discipline: mantissa segments are
combined in f32 (tag-2/3 mantissas round to 24 bits -- inherent to an f32
output) and scales come from a per-tag power-of-two LUT.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.gse import _pow2_exact

__all__ = ["make_scales", "decode_ref", "decode_csr_ref", "spmv_ell_ref",
           "matmul_ref"]


def make_scales(table: jnp.ndarray, bits_used: int, bias: int = 1023,
                dtype=jnp.float32) -> jnp.ndarray:
    """Per-exponent-index decode scales: 2^(E_sh - bits_used), exact."""
    pow_ = table.astype(jnp.int32) - bias - bits_used
    half = pow_ // 2
    return _pow2_exact(half, dtype) * _pow2_exact(pow_ - half, dtype)


def _split_head(head: jnp.ndarray, ei_bit: int):
    h = head.astype(jnp.uint32)
    sign = (h >> 15) & 0x1
    m_h = 15 - ei_bit
    exp_idx = ((h >> m_h) & ((1 << ei_bit) - 1)).astype(jnp.int32)
    m_head = (h & ((1 << m_h) - 1)).astype(jnp.float32)
    sgn = (1.0 - 2.0 * sign.astype(jnp.float32))
    return sgn, exp_idx, m_head


def _mant(m_head, tail1, tail2, tag):
    if tag == 1:
        return m_head
    if tag == 2:
        return m_head * jnp.float32(65536.0) + tail1.astype(jnp.float32)
    return (
        m_head * jnp.float32(2.0**48)
        + tail1.astype(jnp.float32) * jnp.float32(2.0**32)
        + tail2.astype(jnp.float32)
    )


def _bits_used(ei_bit: int, tag: int) -> int:
    from repro.core.precision_table import TAG_BITS_USED

    return TAG_BITS_USED[tag] - ei_bit


@partial(jax.jit, static_argnames=("ei_bit", "tag"))
def decode_ref(head, tail1, tail2, table, ei_bit: int, tag: int):
    """Oracle for the gse_decode kernel: packed segments -> f32 values."""
    sgn, exp_idx, m_head = _split_head(head, ei_bit)
    mant = _mant(m_head, tail1, tail2, tag)
    scales = make_scales(table, _bits_used(ei_bit, tag))
    return sgn * mant * scales[exp_idx]


@partial(jax.jit, static_argnames=("ei_bit", "tag"))
def decode_csr_ref(colpak, head, tail1, tail2, table, ei_bit: int, tag: int):
    """Per-entry decode oracle for the FLAT sparse layout (``GSECSR`` /
    SELL slots).  In sparse packs the expIdx rides the top ``ei_bit``
    bits of ``colpak`` (paper III.C.1) and the head keeps the full
    15-bit mantissa, so ``decode_ref``'s head-split formula does NOT
    apply -- splitting the head of a sparse pack silently misreads the
    top mantissa bits as an exponent index and decodes garbage.  This
    mirrors the per-entry math of ``spmv_ell_ref`` exactly.
    """
    shift = 32 - ei_bit
    exp_idx = (colpak.astype(jnp.uint32) >> shift).astype(jnp.int32)
    h = head.astype(jnp.uint32)
    sgn = 1.0 - 2.0 * ((h >> 15) & 0x1).astype(jnp.float32)
    m_head = (h & 0x7FFF).astype(jnp.float32)
    mant = _mant(m_head, tail1, tail2, tag)
    scales = make_scales(table, _bits_used(0, tag))
    return sgn * mant * scales[exp_idx]


@partial(jax.jit, static_argnames=("ei_bit", "tag"))
def spmv_ell_ref(colpak, head, tail1, tail2, table, x, ei_bit: int, tag: int):
    """Oracle for gse_spmv: blocked-ELL y = A @ x with fused decode.

    ELL layout: (rows, L) arrays; expIdx sits in the top bits of colpak
    (paper III.C.1) so the head keeps 15 mantissa bits.
    """
    shift = 32 - ei_bit
    exp_idx = (colpak.astype(jnp.uint32) >> shift).astype(jnp.int32)
    col = (colpak.astype(jnp.uint32) & ((1 << shift) - 1)).astype(jnp.int32)
    h = head.astype(jnp.uint32)
    sgn = 1.0 - 2.0 * ((h >> 15) & 0x1).astype(jnp.float32)
    m_head = (h & 0x7FFF).astype(jnp.float32)
    mant = _mant(m_head, tail1, tail2, tag)
    bits_used = _bits_used(0, tag)  # sparse path: expIdx rides colpak
    scales = make_scales(table, bits_used)
    vals = sgn * mant * scales[exp_idx]
    return jnp.sum(vals * x.astype(jnp.float32)[col], axis=1)


@partial(jax.jit, static_argnames=("ei_bit", "tag"))
def matmul_ref(x, head, tail1, tail2, table, ei_bit: int, tag: int):
    """Oracle for gse_matmul: x @ decode(W); f32 accumulate."""
    w = decode_ref(head, tail1, tail2, table, ei_bit, tag)
    return jnp.dot(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )


@partial(jax.jit, static_argnames=("causal",))
def flash_ref(q, k, v, causal: bool = True):
    """Oracle for flash_attention_pallas: plain softmax attention."""
    import math

    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        i = jnp.arange(q.shape[1])[:, None]
        j = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(j <= i, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
