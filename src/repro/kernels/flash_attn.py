"""Pallas TPU kernel: flash attention (online softmax, tiled in VMEM).

The fix for the score-traffic wall identified in EXPERIMENTS.md §Perf
cell C: scores (bq, bk) tiles and the running (m, l, acc) state live in
VMEM scratch; the (S, T) score matrix never exists in HBM.

Grid: (B*H, S/bq, T/bk) -- the kv axis is innermost and accumulates into
scratch; output is written on the last kv step.  Causal masking uses
global indices so arbitrary (bq, bk) tilings are correct.

Forward-only (serving / prefill); training backward would pair this with
a custom_vjp twin (standard flash-attention construction) -- the forward
here is the pattern proof, interpret-validated against ref.flash_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_body(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                scale: float, causal: bool, bq: int, bk: int, nk: int):
    i_q = pl.program_id(1)
    i_k = pl.program_id(2)

    @pl.when(i_k == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)           # (bq, hd)
    k = k_ref[0].astype(jnp.float32)           # (bk, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                   # (bq, bk)
    if causal:
        qi = i_q * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = i_k * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kj <= qi, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])             # (bq, bk)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (
        acc_ref[...] * corr[:, None]
        + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    )
    m_ref[...] = m_new

    @pl.when(i_k == nk - 1)
    def _fin():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "blocks", "interpret"),
)
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           blocks=(128, 128), interpret: bool = True):
    """q: (BH, S, hd); k, v: (BH, T, hd) -> (BH, S, hd).

    S % bq == 0 and T % bk == 0 (pad upstream); hd MXU-aligned preferred.
    """
    bh, s_len, hd = q.shape
    t_len = k.shape[1]
    bq, bk = blocks
    assert s_len % bq == 0 and t_len % bk == 0, (q.shape, k.shape, blocks)
    nq, nk = s_len // bq, t_len // bk
    scale = 1.0 / math.sqrt(hd)

    return pl.pallas_call(
        functools.partial(_flash_body, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk),
        out_shape=jax.ShapeDtypeStruct((bh, s_len, hd), q.dtype),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, iq, ik: (b, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),     # running max m
            pltpu.VMEM((bq,), jnp.float32),     # running sum l
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
