"""Pallas TPU kernel: GSE-SEM segment decode -> f32 tiles.

Target: TPU VPU. 8x128-aligned VMEM tiles; the shared-exponent table is a
pre-decoded (1, k) f32 scale LUT (2^(E_sh - bits_used)) selected with an
unrolled k-way ``where`` chain -- no gather, no bit-scan (DESIGN.md §2).

Validated on CPU via ``interpret=True`` against ``ref.decode_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["decode_kernel_body", "decode_pallas"]


def _select_scale(exp_idx, scales_ref, k: int):
    """Unrolled k-way select: TPU-friendly replacement for a VMEM gather."""
    acc = jnp.zeros(exp_idx.shape, jnp.float32)
    for j in range(k):
        acc = jnp.where(exp_idx == j, scales_ref[0, j], acc)
    return acc


def decode_kernel_body(scales_ref, head_ref, tail1_ref, tail2_ref, out_ref, *,
                       ei_bit: int, tag: int, k: int):
    h = head_ref[...].astype(jnp.uint32)
    m_h = 15 - ei_bit
    sgn = 1.0 - 2.0 * ((h >> 15) & 0x1).astype(jnp.float32)
    exp_idx = ((h >> m_h) & ((1 << ei_bit) - 1)).astype(jnp.int32)
    mant = (h & ((1 << m_h) - 1)).astype(jnp.float32)
    if tag >= 2:
        mant = mant * jnp.float32(65536.0) + tail1_ref[...].astype(jnp.float32)
    if tag == 3:
        mant = (
            mant * jnp.float32(2.0**32)
            + tail2_ref[...].astype(jnp.float32)
        )
    scale = _select_scale(exp_idx, scales_ref, k)
    out_ref[...] = sgn * mant * scale


@functools.partial(
    jax.jit,
    static_argnames=("ei_bit", "tag", "block", "interpret"),
)
def decode_pallas(head, tail1, tail2, scales, *, ei_bit: int, tag: int,
                  block=(8, 128), interpret: bool = True):
    """head/tail1: (M, N) u16; tail2: (M, N) u32; scales: (1, k) f32."""
    m, n = head.shape
    bm, bn = block
    assert m % bm == 0 and n % bn == 0, (m, n, block)
    k = scales.shape[1]
    grid = (m // bm, n // bn)
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(decode_kernel_body, ei_bit=ei_bit, tag=tag, k=k),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda i, j: (0, 0)),  # scale LUT, pinned
            tile, tile, tile,
        ],
        out_specs=tile,
        interpret=interpret,
    )(scales, head, tail1, tail2)
