"""Pallas TPU kernels for the GSE-SEM hot spots (+ jnp oracles in ref.py).

  gse_decode  -- segment decode -> f32 tiles (VPU)
  gse_spmv    -- blocked-ELL SpMV with fused decode (paper Algorithm 2)
  gse_matmul  -- dense matmul with GSE-SEM packed weights (LM serving)

All validated in interpret mode against ref.py; ops.py holds the jit'd
public wrappers (padding, scale LUTs, interpret-mode selection).
"""
from repro.kernels import ops, ref
from repro.kernels.ops import ell_pack_gsecsr, gse_decode, gse_matmul, gse_spmv_ell

__all__ = ["ops", "ref", "gse_decode", "gse_matmul", "gse_spmv_ell",
           "ell_pack_gsecsr"]
