"""Pallas TPU kernel: x @ W with W stored in GSE-SEM segments.

The LM-serving hot spot (DESIGN.md §3.1): weights live in HBM as
head/tail1/tail2 streams; each (BK, BN) tile is decoded to f32 *in VMEM*
and fed straight to the MXU -- the dequantized matrix never exists in HBM.
At tag=1 the weight stream reads 2 bytes/element instead of 4 (f32) or
8 (f64 master): the memory roofline term for memory-bound decode drops
proportionally.

Grid: (M/BM, N/BN, K/BK), K innermost (sequential accumulation into the
output tile).  MXU alignment: BM,BN,BK multiples of 128 on real hardware
(tests use smaller interpret-mode tiles where noted).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gse_decode import _select_scale

__all__ = ["gse_matmul_pallas"]


def _matmul_body(scales_ref, x_ref, head_ref, tail1_ref, tail2_ref, out_ref, *,
                 ei_bit: int, tag: int, k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    h = head_ref[...].astype(jnp.uint32)
    m_h = 15 - ei_bit
    sgn = 1.0 - 2.0 * ((h >> 15) & 0x1).astype(jnp.float32)
    exp_idx = ((h >> m_h) & ((1 << ei_bit) - 1)).astype(jnp.int32)
    mant = (h & ((1 << m_h) - 1)).astype(jnp.float32)
    if tag >= 2:
        mant = mant * jnp.float32(65536.0) + tail1_ref[...].astype(jnp.float32)
    if tag == 3:
        mant = mant * jnp.float32(2.0**32) + tail2_ref[...].astype(jnp.float32)
    w = sgn * mant * _select_scale(exp_idx, scales_ref, k)
    out_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit,
    static_argnames=("ei_bit", "tag", "blocks", "interpret"),
)
def gse_matmul_pallas(x, head, tail1, tail2, scales, *, ei_bit: int, tag: int,
                      blocks=(8, 128, 128), interpret: bool = True):
    """x: (M, K); head/tail1: (K, N) u16; tail2: (K, N) u32; scales (1, k)."""
    m, kk = x.shape
    kk2, n = head.shape
    assert kk == kk2
    bm, bn, bk = blocks
    assert m % bm == 0 and n % bn == 0 and kk % bk == 0, (x.shape, head.shape, blocks)
    nk = scales.shape[1]
    grid = (m // bm, n // bn, kk // bk)
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, l: (l, j))
    return pl.pallas_call(
        functools.partial(_matmul_body, ei_bit=ei_bit, tag=tag, k=nk),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nk), lambda i, j, l: (0, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            w_spec, w_spec, w_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        interpret=interpret,
    )(scales, x, head, tail1, tail2)
