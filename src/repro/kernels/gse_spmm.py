"""Pallas TPU kernel: blocked-ELL SpMM with fused GSE-SEM decode.

Multi-RHS extension of ``kernels/gse_spmv.py`` (DESIGN.md §11): the paper's
whole case is that SpMV is memory-bound, so the GSE-SEM format wins by
streaming fewer *matrix* bytes per iteration.  With ``nrhs`` right-hand
sides the same packed segments are decoded ONCE per tile and amortized
across all columns of a dense (n, nrhs) operand -- one streaming pass over
the head/tail segments feeds every RHS, multiplying the byte win by the
batch width.

Tag specialization is identical to the SpMV kernel: each tag gets its own
kernel body whose ``pallas_call`` operand list contains ONLY the segments
that tag reads (tail arrays for tags that skip them never enter the jaxpr,
never get a BlockSpec, never get DMA'd):

    tag 1   scales, colpak, head, x                   (6  B/nnz streamed)
    tag 2   scales, colpak, head, tail1, x            (8  B/nnz)
    tag 3   scales, colpak, head, tail1, tail2, x     (12 B/nnz)

Output layout (DESIGN.md §2.3 generalized): each RHS column owns its own
lane-aligned (BM, LANE) accumulator strip inside a (BM, nrhs*LANE) VMEM
tile -- a (BM, BL) product tile is reduced only across its BL/128 sublane
groups per column, so every store fills all 128 lanes.  The reduction
epilogue collapses the LANE partials per (row, column) to the final
(M, nrhs) result.

The dense operand rides the kernel as a (nrhs, n) VMEM-pinned block (the
transpose keeps each column's gather a contiguous minor-dim read); padded
matrix slots carry col=0, head=0 -> mantissa 0 -> contribute 0 to every
column.

Grid: (M/BM, L/BL); the L axis accumulates sequentially into the output
rows, exactly like the SpMV kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gse_spmv import LANE, decode_tile, spmv_operand_names
from repro.perf import plan as launch_plan

__all__ = ["gse_spmm_pallas", "gse_spmm_call", "gse_spmm_sell_call",
           "spmm_operand_names", "LANE"]

# The multi-RHS kernel streams the SAME matrix segment list as the SpMV,
# whatever nrhs is -- one name owns the layout (asserted in tests).
spmm_operand_names = spmv_operand_names


def _accumulate(scales_ref, colpak_ref, head_ref, tail1_ref, tail2_ref,
                x_ref, out_ref, *, ei_bit: int, tag: int, k: int, nrhs: int):
    """Shared tile math; tail refs are ``None`` for the tags that skip them.

    The decode runs ONCE per (BM, BL) tile (``decode_tile``, shared with
    the SpMV kernel body); the per-column gathers and lane-group
    reductions reuse the same decoded ``vals`` -- the in-VMEM twin of the
    byte model's "matrix bytes once, vector bytes per column".
    """
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals, col = decode_tile(scales_ref, colpak_ref, head_ref, tail1_ref,
                            tail2_ref, ei_bit=ei_bit, tag=tag, k=k)

    bm, bl = vals.shape
    flat_col = col.reshape(-1)
    for j in range(nrhs):                 # static unroll over RHS columns
        xj = x_ref[j, :]                  # (N,) in VMEM
        xg = xj[flat_col].reshape(col.shape)
        prod = vals * xg                  # (BM, BL) -- decoded vals reused
        out_ref[:, j * LANE:(j + 1) * LANE] += jnp.sum(
            prod.reshape(bm, bl // LANE, LANE), axis=1
        )


def _spmm_body_tag1(scales_ref, colpak_ref, head_ref, x_ref, out_ref, *,
                    ei_bit: int, k: int, nrhs: int):
    _accumulate(scales_ref, colpak_ref, head_ref, None, None, x_ref, out_ref,
                ei_bit=ei_bit, tag=1, k=k, nrhs=nrhs)


def _spmm_body_tag2(scales_ref, colpak_ref, head_ref, tail1_ref, x_ref,
                    out_ref, *, ei_bit: int, k: int, nrhs: int):
    _accumulate(scales_ref, colpak_ref, head_ref, tail1_ref, None, x_ref,
                out_ref, ei_bit=ei_bit, tag=2, k=k, nrhs=nrhs)


def _spmm_body_tag3(scales_ref, colpak_ref, head_ref, tail1_ref, tail2_ref,
                    x_ref, out_ref, *, ei_bit: int, k: int, nrhs: int):
    _accumulate(scales_ref, colpak_ref, head_ref, tail1_ref, tail2_ref, x_ref,
                out_ref, ei_bit=ei_bit, tag=3, k=k, nrhs=nrhs)


_BODIES = {1: _spmm_body_tag1, 2: _spmm_body_tag2, 3: _spmm_body_tag3}


def gse_spmm_call(colpak, head, tail1, tail2, x, scales, *, ei_bit: int,
                  tag: int, blocks=None, interpret: bool = True):
    """Unjitted tag-specialized SpMM (exported for jaxpr inspection).

    colpak/head (+tails the tag reads): (M, L); x: (N, nrhs) dense
    right-hand sides; scales: (1, k).  ``tail1``/``tail2`` may be ``None``
    when ``tag`` does not read them; arrays passed for unread segments are
    ignored (not streamed).  ``blocks=None`` resolves through
    ``perf.plan.resolve`` to the (8, 128) default (DESIGN.md §15).
    Returns Y = A @ X as a (M, nrhs) f32 array.
    """
    blocks = launch_plan.resolve(blocks=blocks).blocks
    m, L = colpak.shape
    bm, bl = blocks
    assert m % bm == 0 and L % bl == 0, (colpak.shape, blocks)
    assert bl % LANE == 0, f"BL must be lane-aligned (multiple of {LANE})"
    assert x.ndim == 2, f"x must be (n, nrhs); got {x.shape}"
    n, nrhs = x.shape
    nk = scales.shape[1]
    grid = (m // bm, L // bl)
    tile = pl.BlockSpec((bm, bl), lambda i, l: (i, l))

    operands = [scales, colpak, head]
    in_specs = [pl.BlockSpec((1, nk), lambda i, l: (0, 0)), tile, tile]
    if tag >= 2:
        assert tail1 is not None, "tag>=2 reads tail1"
        operands.append(tail1)
        in_specs.append(tile)
    if tag == 3:
        assert tail2 is not None, "tag==3 reads tail2"
        operands.append(tail2)
        in_specs.append(tile)
    operands.append(x.T.reshape(nrhs, n))  # columns contiguous for gathers
    in_specs.append(pl.BlockSpec((nrhs, n), lambda i, l: (0, 0)))  # pinned

    acc = pl.pallas_call(
        functools.partial(_BODIES[tag], ei_bit=ei_bit, k=nk, nrhs=nrhs),
        out_shape=jax.ShapeDtypeStruct((m, nrhs * LANE), jnp.float32),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, nrhs * LANE), lambda i, l: (i, 0)),
        interpret=interpret,
    )(*operands)
    # Reduction epilogue: collapse each column's LANE per-row partials.
    return jnp.sum(acc.reshape(m, nrhs, LANE), axis=2)


gse_spmm_pallas = functools.partial(
    jax.jit,
    static_argnames=("ei_bit", "tag", "blocks", "interpret"),
)(gse_spmm_call)


def gse_spmm_sell_call(buckets, unperm, x, scales, *, ei_bit: int, tag: int,
                       blocks=None, interpret: bool = True):
    """Sliced-ELL SpMM: the multi-RHS twin of
    :func:`repro.kernels.gse_spmv.gse_spmv_sell_call` -- one tag-
    specialized ``pallas_call`` per width-bucket, same per-bucket operand
    lists, matrix segments streamed once for all ``nrhs`` columns, row
    order restored by the ``unperm`` gather (DESIGN.md §12)."""
    outs = [
        gse_spmm_call(colpak, head, tail1, tail2, x, scales, ei_bit=ei_bit,
                      tag=tag, blocks=blocks, interpret=interpret)
        for colpak, head, tail1, tail2 in buckets
    ]
    y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    return y[unperm]
