"""Stepped mixed-precision controller (paper Section III.D, Eq. 3-6).

Pure-functional residual monitor usable inside ``jax.lax.while_loop``:
state is a fixed-size ring buffer of recent residuals plus counters.

Metrics over the trailing window of ``t`` residuals (paper Eq. 3-6):

  RSD     relative standard deviation of the window
  nDec    number of strict decreases resid[i] > resid[i+1]
  relDec  (resid[j-t] - resid[j-1]) / resid[j-t]

Switch-up conditions (any one fires => precision tag += 1):

  C1:  RSD > rsd_limit  and  nDec < ndec_limit     (stall with oscillation)
  C2:  nDec >= ndec_limit and relDec < reldec_limit (decreasing, too slowly)
  C3:  nDec == 0                                    (no decrease at all)

NOTE on paper fidelity: the paper's Condition-2 text is elliptical
("nDec >= t/2 && relDec_limit"); its parameter list names an explicit
``nDec_limit`` (80 for GMRES with t=300; 130 for CG with t=250).  We
therefore use a configurable ``ndec_limit`` defaulting to ``t // 2`` and
read C2 as ``relDec < reldec_limit``, which matches the prose ("the rate of
residual decrease ... was slower").
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tagmap import GROUP_SIZE, TagMap

__all__ = ["MonitorParams", "MonitorState", "init", "record", "metrics",
           "update_tag", "group_sensitivity", "decode_error_scores",
           "map_floor_contrib", "plan_tagmap", "promote_groups",
           "stalled"]


@dataclasses.dataclass(frozen=True)
class MonitorParams:
    """Static controller parameters (paper Section IV.D.1)."""

    t: int = 250              # trailing window length
    l: int = 3000             # iterations before first possible switch
    m: int = 500              # check cadence
    rsd_limit: float = 0.50
    reldec_limit: float = 0.45
    ndec_limit: int | None = None  # default: t // 2
    max_tag: int = 3

    @property
    def ndec(self) -> int:
        return self.t // 2 if self.ndec_limit is None else self.ndec_limit

    @classmethod
    def for_gmres(cls) -> "MonitorParams":
        return cls(t=300, l=9000, m=1500, rsd_limit=0.03, reldec_limit=0.08,
                   ndec_limit=80)

    @classmethod
    def for_cg(cls) -> "MonitorParams":
        return cls(t=250, l=3000, m=500, rsd_limit=0.50, reldec_limit=0.45,
                   ndec_limit=130)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MonitorState:
    hist: jnp.ndarray   # (t,) f64/f32 ring buffer of residuals
    count: jnp.ndarray  # () int32 residuals recorded so far
    tag: jnp.ndarray    # () int32 current precision tag (1..3)

    def tree_flatten(self):
        return (self.hist, self.count, self.tag), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def init(params: MonitorParams, dtype=jnp.float64, tag: int = 1) -> MonitorState:
    return MonitorState(
        hist=jnp.full((params.t,), jnp.inf, dtype=dtype),
        count=jnp.zeros((), jnp.int32),
        tag=jnp.full((), tag, jnp.int32),
    )


def record(state: MonitorState, resid: jnp.ndarray) -> MonitorState:
    """Push one residual into the ring buffer.

    Non-finite residuals are clamped to a huge finite sentinel before
    entering the window: a single NaN would otherwise propagate through
    mean/RSD and return NaN metrics FOREVER (every comparison in
    C1/C2/C3 goes False), silently disabling switching for the rest of
    the run -- the one regime where stepping the tag up is the fix
    (DESIGN.md §14).  The sentinel is ``finfo.max ** 0.25`` (~1e77 in
    f64): astronomically above any real relative residual, yet small
    enough that the window mean and the squared deviations in RSD cannot
    overflow to inf.  A breakdown iteration therefore reads as a huge
    residual spike, which is exactly what C1 (stall-with-oscillation)
    keys on.
    """
    t = state.hist.shape[0]
    idx = state.count % t
    r = resid.astype(state.hist.dtype)
    big = jnp.asarray(jnp.finfo(state.hist.dtype).max ** 0.25,
                      state.hist.dtype)
    r = jnp.where(jnp.isfinite(r), r, big)
    return MonitorState(
        hist=state.hist.at[idx].set(r),
        count=state.count + 1,
        tag=state.tag,
    )


def _ordered(state: MonitorState) -> jnp.ndarray:
    """Window ordered oldest -> newest (resid[j-t] ... resid[j-1])."""
    t = state.hist.shape[0]
    return jnp.roll(state.hist, -(state.count % t))


def metrics(state: MonitorState) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(RSD, nDec, relDec) over the trailing window (paper Eq. 3-6)."""
    w = _ordered(state)
    avg = jnp.mean(w)
    # Division guard in the WINDOW's dtype: the literal 1e-300 underflows
    # to 0 in a float32 history buffer, so an all-equal (or tiny) residual
    # window divides 0/0 -> NaN RSD and silently disables condition C1.
    rsd = jnp.sqrt(jnp.mean((w - avg) ** 2)) / jnp.maximum(
        avg, jnp.finfo(w.dtype).tiny
    )
    ndec = jnp.sum((w[:-1] > w[1:]).astype(jnp.int32))
    reldec = (w[0] - w[-1]) / jnp.where(w[0] == 0, 1.0, w[0])
    return rsd, ndec, reldec


def update_tag(state: MonitorState, params: MonitorParams) -> MonitorState:
    """Evaluate the switch conditions; returns state with (possibly) tag+1.

    Only acts when the window is full, ``count >= l``, and ``count % m == 0``
    -- safe to call every iteration inside ``lax.while_loop``.
    """
    t = state.hist.shape[0]
    due = (
        (state.count >= params.l)
        & (state.count >= t)
        & (state.count % params.m == 0)
        & (state.tag < params.max_tag)
    )
    rsd, ndec, reldec = metrics(state)
    c1 = (rsd > params.rsd_limit) & (ndec < params.ndec)
    c2 = (ndec >= params.ndec) & (reldec < params.reldec_limit)
    c3 = ndec == 0
    step = due & (c1 | c2 | c3)
    new_tag = jnp.where(step, state.tag + 1, state.tag)
    return MonitorState(hist=state.hist, count=state.count, tag=new_tag)


# -- per-group sensitivity and promotion (PR 10, DESIGN.md §18) -----------

def group_sensitivity(g, group_size: int = GROUP_SIZE) -> np.ndarray:
    """Per-row-group sensitivity scores from the PACKED magnitudes.

    A low-tag solve plateaus at a true residual ~ ``||(A~ - A) x~||``;
    the decode error is RELATIVE, so the plateau is dominated by the
    largest-magnitude entries.  Carson-Khan's adaptive SPAI (arXiv
    2307.03914) stores entries at precision proportional to magnitude for
    exactly this reason -- the groups holding the biggest entries are the
    ones limiting convergence, and promoting them first buys the most
    plateau for the fewest bytes.

    The score is the max head-only decoded |value| in each group of
    ``group_size`` rows, computed straight from the packed segments
    (head mantissa x shared-exponent scale; no unpack, no tails -- tails
    only refine magnitude below the 15th bit).  Returns an
    ``(n_groups,)`` f64 array aligned with ``TagMap.tags``.
    """
    head = np.asarray(g.head).astype(np.uint32)
    mant = (head & 0x7FFF).astype(np.float64)
    exp_idx = (np.asarray(g.colpak).astype(np.uint64)
               >> np.uint64(32 - g.ei_bit)).astype(np.int64)
    e_sh = np.asarray(g.table, np.int64)[exp_idx] - 1023
    mag = np.ldexp(mant, e_sh - 15)  # |head-only decode|, exact
    groups = np.asarray(g.row_ids, np.int64) // group_size
    n_groups = -(-int(g.shape[0]) // group_size)
    score = np.zeros(n_groups, np.float64)
    np.maximum.at(score, groups, mag)
    return score


def decode_error_scores(g, xhat, group_size: int = GROUP_SIZE) -> np.ndarray:
    """Per-group squared floor contributions at candidate tags 1 and 2.

    A tag-``t`` solve converges (recursively) against the perturbed
    operator ``A~_t`` and plateaus at a TRUE residual
    ``||(A~_t - A) x*|| / ||b||``.  Writing ``E_t = A~_t - A``, the
    plateau decomposes over columns: ``||E_t x*||^2 <= sum_j
    (||E_t[:, j]|| |x*_j|)^2``, and promoting a COLUMN group to tag 3
    zeroes its columns' share exactly (the symmetric induced entry tag
    also zeroes the transposed row-side entries -- free extra margin the
    model conservatively ignores).  The returned ``(2, n_groups)`` array
    holds, per group ``g``, ``sum_{entries e: col(e) in g}
    ((v_t(e) - v3(e)) * xhat[col(e)])^2`` for ``t = 1`` (row 0) and
    ``t = 2`` (row 1); tag 3 contributes 0 by construction.  ``xhat``
    is a per-row solution-magnitude proxy (see ``solvers.adaptive``'s
    preconditioned probe); scores are exact decode errors straight from
    the packed segments.
    """
    from repro.kernels import ref

    xh = np.abs(np.asarray(xhat, np.float64)).reshape(-1)
    cols = (np.asarray(g.colpak, np.uint32)
            & np.uint32((1 << (32 - g.ei_bit)) - 1)).astype(np.int64)
    v3 = np.asarray(ref.decode_csr_ref(g.colpak, g.head, g.tail1, g.tail2,
                                       g.table, g.ei_bit, 3), np.float64)
    n_groups = -(-int(g.shape[0]) // group_size)
    gc = np.minimum(cols // group_size, n_groups - 1)
    scores = np.zeros((2, n_groups), np.float64)
    for k, t in enumerate((1, 2)):
        vt = np.asarray(ref.decode_csr_ref(g.colpak, g.head, g.tail1,
                                           g.tail2, g.table, g.ei_bit, t),
                        np.float64)
        c = (vt - v3) * xh[cols]
        np.add.at(scores[k], gc, c * c)
    return scores


def map_floor_contrib(scores: np.ndarray, tags: np.ndarray) -> np.ndarray:
    """Per-group floor contribution of a map under ``decode_error_scores``:
    ``scores[tag-1, g]`` for tags 1/2, exactly 0 for tag-3 groups."""
    tags = np.asarray(tags)
    cur = np.zeros(scores.shape[1], np.float64)
    for t in (1, 2):
        sel = tags == t
        cur[sel] = scores[t - 1][sel]
    return cur


def plan_tagmap(scores: np.ndarray, budget: float, tags0=None,
                group_size: int = GROUP_SIZE) -> TagMap:
    """Greedy budget descent over :func:`decode_error_scores`.

    Starting from all-tag-1 (or ``tags0``), repeatedly promote the group
    with the LARGEST current floor contribution one rung until the
    predicted floor ``sqrt(sum_g contrib_g)`` fits inside ``budget``
    (an absolute residual-norm budget, e.g. ``theta * tol * ||b||``).
    The sum is recomputed from scratch each step -- incremental
    subtraction leaves FP rounding residue that can keep a fully
    promoted (provably zero-floor) map "over budget" forever.
    """
    G = np.asarray(scores, np.float64)
    ng = G.shape[1]
    if tags0 is None:
        tags = np.ones(ng, np.uint8)
    else:
        src = tags0.tags if isinstance(tags0, TagMap) else tags0
        tags = np.asarray(src, np.uint8).copy()
        if tags.shape[0] != ng:
            raise ValueError(f"{tags.shape[0]} seed tags for {ng} groups")
    b2 = float(budget) ** 2
    cur = map_floor_contrib(G, tags)
    while cur.sum() > b2:
        open_ = tags < 3
        if not open_.any():
            break
        idx = int(np.argmax(np.where(open_, cur, -np.inf)))
        tags[idx] += 1
        cur = map_floor_contrib(G, tags)
    return TagMap(tags, group_size)


def promote_groups(tm: TagMap, scores: np.ndarray, frac: float = 0.25,
                   step: int = 1) -> TagMap:
    """Promote the top-``frac`` highest-sensitivity UNSATURATED groups.

    The per-group twin of :func:`update_tag`'s whole-operator step: when
    the monitor (or the host driver's stall check) says the current
    precision is limiting convergence, only the groups most responsible
    -- highest :func:`group_sensitivity` score, tag < 3 -- step up.
    Returns a NEW map (at least one group promotes if any is
    unsaturated, so escalation always makes progress).
    """
    scores = np.asarray(scores, np.float64)
    if scores.shape[0] != tm.n_groups:
        raise ValueError(
            f"{scores.shape[0]} scores for {tm.n_groups} groups"
        )
    open_idx = np.nonzero(tm.tags < 3)[0]
    if open_idx.size == 0:
        return tm
    n = max(1, int(round(frac * tm.n_groups)))
    n = min(n, open_idx.size)
    top = open_idx[np.argsort(-scores[open_idx], kind="stable")[:n]]
    return tm.promoted(top, step=step)


def stalled(prev_relres: float, relres: float, iters: int,
            reldec_limit: float = 0.45) -> bool:
    """Host-side chunk-granularity stall test: the driver's mirror of
    condition C2 (decreasing, but too slowly).

    ``prev_relres`` -> ``relres`` over ``iters`` iterations is a stall
    when the per-chunk relative decrease misses ``reldec_limit`` --
    including the non-finite and non-decreasing cases C1/C3 subsume.
    """
    if iters <= 0:
        return False
    if not np.isfinite(relres):
        return True
    if not np.isfinite(prev_relres) or prev_relres <= 0:
        return False
    return (prev_relres - relres) / prev_relres < reldec_limit
