"""Stepped mixed-precision controller (paper Section III.D, Eq. 3-6).

Pure-functional residual monitor usable inside ``jax.lax.while_loop``:
state is a fixed-size ring buffer of recent residuals plus counters.

Metrics over the trailing window of ``t`` residuals (paper Eq. 3-6):

  RSD     relative standard deviation of the window
  nDec    number of strict decreases resid[i] > resid[i+1]
  relDec  (resid[j-t] - resid[j-1]) / resid[j-t]

Switch-up conditions (any one fires => precision tag += 1):

  C1:  RSD > rsd_limit  and  nDec < ndec_limit     (stall with oscillation)
  C2:  nDec >= ndec_limit and relDec < reldec_limit (decreasing, too slowly)
  C3:  nDec == 0                                    (no decrease at all)

NOTE on paper fidelity: the paper's Condition-2 text is elliptical
("nDec >= t/2 && relDec_limit"); its parameter list names an explicit
``nDec_limit`` (80 for GMRES with t=300; 130 for CG with t=250).  We
therefore use a configurable ``ndec_limit`` defaulting to ``t // 2`` and
read C2 as ``relDec < reldec_limit``, which matches the prose ("the rate of
residual decrease ... was slower").
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["MonitorParams", "MonitorState", "init", "record", "metrics", "update_tag"]


@dataclasses.dataclass(frozen=True)
class MonitorParams:
    """Static controller parameters (paper Section IV.D.1)."""

    t: int = 250              # trailing window length
    l: int = 3000             # iterations before first possible switch
    m: int = 500              # check cadence
    rsd_limit: float = 0.50
    reldec_limit: float = 0.45
    ndec_limit: int | None = None  # default: t // 2
    max_tag: int = 3

    @property
    def ndec(self) -> int:
        return self.t // 2 if self.ndec_limit is None else self.ndec_limit

    @classmethod
    def for_gmres(cls) -> "MonitorParams":
        return cls(t=300, l=9000, m=1500, rsd_limit=0.03, reldec_limit=0.08,
                   ndec_limit=80)

    @classmethod
    def for_cg(cls) -> "MonitorParams":
        return cls(t=250, l=3000, m=500, rsd_limit=0.50, reldec_limit=0.45,
                   ndec_limit=130)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MonitorState:
    hist: jnp.ndarray   # (t,) f64/f32 ring buffer of residuals
    count: jnp.ndarray  # () int32 residuals recorded so far
    tag: jnp.ndarray    # () int32 current precision tag (1..3)

    def tree_flatten(self):
        return (self.hist, self.count, self.tag), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def init(params: MonitorParams, dtype=jnp.float64, tag: int = 1) -> MonitorState:
    return MonitorState(
        hist=jnp.full((params.t,), jnp.inf, dtype=dtype),
        count=jnp.zeros((), jnp.int32),
        tag=jnp.full((), tag, jnp.int32),
    )


def record(state: MonitorState, resid: jnp.ndarray) -> MonitorState:
    """Push one residual into the ring buffer.

    Non-finite residuals are clamped to a huge finite sentinel before
    entering the window: a single NaN would otherwise propagate through
    mean/RSD and return NaN metrics FOREVER (every comparison in
    C1/C2/C3 goes False), silently disabling switching for the rest of
    the run -- the one regime where stepping the tag up is the fix
    (DESIGN.md §14).  The sentinel is ``finfo.max ** 0.25`` (~1e77 in
    f64): astronomically above any real relative residual, yet small
    enough that the window mean and the squared deviations in RSD cannot
    overflow to inf.  A breakdown iteration therefore reads as a huge
    residual spike, which is exactly what C1 (stall-with-oscillation)
    keys on.
    """
    t = state.hist.shape[0]
    idx = state.count % t
    r = resid.astype(state.hist.dtype)
    big = jnp.asarray(jnp.finfo(state.hist.dtype).max ** 0.25,
                      state.hist.dtype)
    r = jnp.where(jnp.isfinite(r), r, big)
    return MonitorState(
        hist=state.hist.at[idx].set(r),
        count=state.count + 1,
        tag=state.tag,
    )


def _ordered(state: MonitorState) -> jnp.ndarray:
    """Window ordered oldest -> newest (resid[j-t] ... resid[j-1])."""
    t = state.hist.shape[0]
    return jnp.roll(state.hist, -(state.count % t))


def metrics(state: MonitorState) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(RSD, nDec, relDec) over the trailing window (paper Eq. 3-6)."""
    w = _ordered(state)
    avg = jnp.mean(w)
    # Division guard in the WINDOW's dtype: the literal 1e-300 underflows
    # to 0 in a float32 history buffer, so an all-equal (or tiny) residual
    # window divides 0/0 -> NaN RSD and silently disables condition C1.
    rsd = jnp.sqrt(jnp.mean((w - avg) ** 2)) / jnp.maximum(
        avg, jnp.finfo(w.dtype).tiny
    )
    ndec = jnp.sum((w[:-1] > w[1:]).astype(jnp.int32))
    reldec = (w[0] - w[-1]) / jnp.where(w[0] == 0, 1.0, w[0])
    return rsd, ndec, reldec


def update_tag(state: MonitorState, params: MonitorParams) -> MonitorState:
    """Evaluate the switch conditions; returns state with (possibly) tag+1.

    Only acts when the window is full, ``count >= l``, and ``count % m == 0``
    -- safe to call every iteration inside ``lax.while_loop``.
    """
    t = state.hist.shape[0]
    due = (
        (state.count >= params.l)
        & (state.count >= t)
        & (state.count % params.m == 0)
        & (state.tag < params.max_tag)
    )
    rsd, ndec, reldec = metrics(state)
    c1 = (rsd > params.rsd_limit) & (ndec < params.ndec)
    c2 = (ndec >= params.ndec) & (reldec < params.reldec_limit)
    c3 = ndec == 0
    step = due & (c1 | c2 | c3)
    new_tag = jnp.where(step, state.tag + 1, state.tag)
    return MonitorState(hist=state.hist, count=state.count, tag=new_tag)
