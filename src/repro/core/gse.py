"""GSE-SEM: Group-Shared-Exponent / Sign-ExponentIndex-Mantissa format.

Paper: "Precision-Aware Iterative Algorithms Based on Group-Shared Exponents
of Floating-Point Numbers" (Gao et al., 2024), Section III.B.

Format spec (bit-exact, generalizing the paper's k=8 example):

  * ``k`` shared exponents are extracted from the data (top-(k-1) by
    frequency plus, mandatorily, the maximum exponent).  Each table entry is
    stored as ``biased_exponent + 1`` -- the paper's denormalized convention
    that makes the hidden leading 1 explicit.
  * ``EI_BIT = ceil(log2(k))`` bits of each 16-bit *head* word index the
    table.  ``M_H = 15 - EI_BIT`` mantissa bits remain in the head.
  * The denormalized mantissa is a ``W = M_H + 48``-bit integer ``M`` such
    that  ``value = (-1)^sign * M * 2^(E_sh - W)``  where
    ``E_sh = table[expIdx] - BIAS`` is the *unbiased* shared exponent
    (table stores biased+1, so subtracting the IEEE bias directly yields the
    "+1" convention).  ``M`` is the 53-bit explicit-1 mantissa shifted by
    ``W - 52 - minDiff`` (left when positive), ``minDiff >= 1`` being the
    distance to the nearest shared exponent strictly above.
  * Segments: head mantissa = top ``M_H`` bits of ``M``; tail1 = next 16
    bits; tail2 = low 32 bits.  head/tail1/tail2 are stored as three
    contiguous arrays (struct-of-arrays) -> one copy, three precisions:

        tag=1  head                 (16 bits/val)
        tag=2  head + tail1         (32 bits/val)
        tag=3  head + tail1 + tail2 (64 bits/val)

TPU adaptation (DESIGN.md section 2): decoding never bit-scans.  A
denormalized mantissa is already an integer scaled by a power of two, so
``decode = int->float convert * 2^(E_sh - width)`` -- one convert and one
multiply per element, fully vectorizable on the VPU.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "GSEPacked",
    "extract_shared_exponents",
    "pack",
    "pack_with_table",
    "decode",
    "decode_jnp",
    "pack32_jnp",
    "pack32",
    "decode32_jnp",
    "gse_fake_quant",
    "exponent_stats",
]

_F64_BIAS = 1023
_F64_FRAC = 52
_F32_BIAS = 127
_F32_FRAC = 23
_BIG = np.int64(1 << 40)


def _ei_bit(k: int) -> int:
    if k < 2 or k > 4096:
        raise ValueError(f"k must be in [2, 4096], got {k}")
    return max(1, int(np.ceil(np.log2(k))))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GSEPacked:
    """A GSE-SEM packed tensor (pytree; segment arrays are leaves)."""

    table: jnp.ndarray   # (k,) int32, biased exponent + 1
    head: jnp.ndarray    # (...,) uint16: sign | expIdx | top mantissa
    tail1: jnp.ndarray   # (...,) uint16: mantissa bits [W-M_H-16, W-M_H)
    tail2: jnp.ndarray   # (...,) uint32: mantissa bits [0, 32)
    ei_bit: int          # static
    frac_bits: int       # static: 52 (f64 source) or 23 (f32 source)

    @property
    def m_h(self) -> int:
        return 15 - self.ei_bit

    @property
    def width(self) -> int:
        return self.m_h + 48 if self.frac_bits == _F64_FRAC else self.m_h + 16

    @property
    def shape(self):
        return self.head.shape

    def _tag_bytes(self, tag: int) -> int:
        """Per-value stored bytes a tag-``tag`` read streams.

        f32-source packs (``frac_bits=23``) have no tail2 segment
        (``width = m_h + 16``), so tag 3 is not a readable precision --
        rejected here exactly as ``decode32_jnp`` rejects it.
        """
        if tag not in (1, 2, 3):
            raise ValueError(f"tag must be 1, 2 or 3, got {tag}")
        if self.frac_bits != _F64_FRAC and tag == 3:
            raise ValueError(
                "f32-source packs (frac_bits=23) store no tail2; "
                "tags 1 and 2 only"
            )
        from repro.core.precision_table import TAG_VALUE_BYTES
        return TAG_VALUE_BYTES[tag]

    def nbytes(self, tag: int) -> int:
        n = int(np.prod(self.head.shape))
        return n * self._tag_bytes(tag) + self.table.size * 4

    def bytes_touched(self, tag: int) -> int:
        """Modeled HBM bytes a tag-``tag`` decode/matmul streams for this
        operand: exactly the stored segments the tag reads (``nbytes``)."""
        return self.nbytes(tag)

    def tree_flatten(self):
        return (self.table, self.head, self.tail1, self.tail2), (
            self.ei_bit,
            self.frac_bits,
        )

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, ei_bit=aux[0], frac_bits=aux[1])


# ---------------------------------------------------------------------------
# Shared exponent extraction (paper section III.B.1)
# ---------------------------------------------------------------------------

def extract_shared_exponents(vals: np.ndarray, k: int) -> np.ndarray:
    """Return the (k,) int32 table of shared exponents, stored biased+1.

    Top-(k-1) biased exponents by frequency of occurrence, plus the maximum
    exponent (paper: "one of the shared exponents must be the maximum
    exponent of all non-zeros plus one; otherwise a few non-zeros may not be
    represented").  Entries are sorted descending; unused slots repeat the
    max entry (harmless: they are never the argmin of a positive diff).
    """
    v = np.asarray(vals, dtype=np.float64).ravel()
    bits = v.view(np.uint64)
    e_b = ((bits >> _F64_FRAC) & 0x7FF).astype(np.int64)
    frac = bits & ((np.uint64(1) << np.uint64(_F64_FRAC)) - np.uint64(1))
    nonzero = (e_b != 0) | (frac != 0)
    e_eff = np.where(e_b != 0, e_b, 1)[nonzero]  # subnormals -> biased 1
    if e_eff.size == 0:
        return np.full((k,), 1, dtype=np.int32)
    counts = np.bincount(e_eff, minlength=2048)
    order = np.argsort(-counts, kind="stable")
    top = [int(e) for e in order[: k] if counts[e] > 0]
    e_max = int(e_eff.max())
    if e_max not in top:
        top = top[: k - 1] + [e_max]
    table = np.asarray(top, dtype=np.int64) + 1  # denormalized convention
    if table.size < k:
        table = np.concatenate(
            [table, np.full((k - table.size,), table.max(), dtype=np.int64)]
        )
    # Descending order: ties in minDiff resolve to identical encodings
    # regardless of histogram order (stable for tests).
    table = np.sort(table)[::-1]
    return table.astype(np.int32)


# ---------------------------------------------------------------------------
# Packing (paper Algorithm 1, vectorized; f64 source)
# ---------------------------------------------------------------------------

def pack_with_table(vals: np.ndarray, table: np.ndarray, k: int) -> GSEPacked:
    """Pack float64 ``vals`` against an existing shared-exponent table.

    Values whose exponent is >= every table entry saturate to the largest
    representable magnitude under the max table entry (overflow policy:
    saturate; only reachable when reusing a stale table on new data).
    """
    ei = _ei_bit(k)
    m_h = 15 - ei
    w = m_h + 48
    v = np.ascontiguousarray(np.asarray(vals, dtype=np.float64))
    shp = v.shape
    v = v.ravel()
    bits = v.view(np.uint64)
    sign = ((bits >> np.uint64(63)) & np.uint64(1)).astype(np.uint64)
    e_b = ((bits >> np.uint64(_F64_FRAC)) & np.uint64(0x7FF)).astype(np.int64)
    frac = (bits & ((np.uint64(1) << np.uint64(_F64_FRAC)) - np.uint64(1))).astype(
        np.uint64
    )
    nonzero = (e_b != 0) | (frac != 0)
    m53 = np.where(e_b != 0, (np.uint64(1) << np.uint64(_F64_FRAC)) | frac, frac)
    e_eff = np.where(e_b != 0, e_b, 1)

    tbl = np.asarray(table, dtype=np.int64)
    diff = tbl[None, :] - e_eff[:, None]  # (n, k)
    diff = np.where(diff > 0, diff, _BIG)
    exp_idx = np.argmin(diff, axis=1).astype(np.uint64)
    min_diff = diff[np.arange(diff.shape[0]), exp_idx]
    overflow = min_diff >= _BIG  # value above all table entries
    min_diff = np.where(overflow, 1, min_diff)

    lsh = w - _F64_FRAC - min_diff  # left shift amount (may be negative)
    # Right-shift path: round-to-nearest-even on the discarded bits so the
    # tag-3 decode error is <= 0.5 ulp of the W-bit mantissa (truncation
    # would double the worst case to 1 ulp).  A carry past W bits saturates
    # to the all-ones mantissa (only reachable at minDiff == 1 with an
    # all-ones significand).
    rsh = np.minimum(np.maximum(-lsh, 0), 63).astype(np.uint64)
    floor_ = m53 >> rsh
    rem = m53 & ((np.uint64(1) << rsh) - np.uint64(1))
    half = (np.uint64(1) << rsh) >> np.uint64(1)
    round_up = (rsh > 0) & (
        (rem > half) | ((rem == half) & ((floor_ & np.uint64(1)) == np.uint64(1)))
    )
    rounded = np.minimum(
        floor_ + round_up.astype(np.uint64),
        (np.uint64(1) << np.uint64(w)) - np.uint64(1),
    )
    m = np.where(lsh >= 0, m53 << np.maximum(lsh, 0).astype(np.uint64), rounded)
    m = np.where(nonzero, m, np.uint64(0))
    # Saturate overflowed values to all-ones mantissa under the max entry.
    max_idx = np.uint64(np.argmax(tbl))
    m = np.where(overflow & nonzero, (np.uint64(1) << np.uint64(w)) - np.uint64(1), m)
    exp_idx = np.where(overflow & nonzero, max_idx, exp_idx)

    head = (
        (sign << np.uint64(15))
        | (exp_idx << np.uint64(m_h))
        | (m >> np.uint64(w - m_h))
    ).astype(np.uint16)
    tail1 = ((m >> np.uint64(32)) & np.uint64(0xFFFF)).astype(np.uint16)
    tail2 = (m & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return GSEPacked(
        table=jnp.asarray(np.asarray(table, np.int32)),
        head=jnp.asarray(head.reshape(shp)),
        tail1=jnp.asarray(tail1.reshape(shp)),
        tail2=jnp.asarray(tail2.reshape(shp)),
        ei_bit=ei,
        frac_bits=_F64_FRAC,
    )


def pack(vals: np.ndarray, k: int = 8) -> GSEPacked:
    """Extract shared exponents from ``vals`` and pack (paper Algorithm 1)."""
    table = extract_shared_exponents(vals, k)
    return pack_with_table(vals, table, k)


# ---------------------------------------------------------------------------
# Decoding (paper Algorithm 2 semantics, TPU-native formulation)
# ---------------------------------------------------------------------------

def _decode_parts(
    table, head, tail1, tail2, ei_bit: int, frac_bits: int, tag: int, xp
):
    """Shared numpy/jnp decode. Returns (sign_factor, mant_float, exp_scale_pow).

    value = sign * mant * 2**pow  with  mant an integer-valued float.
    """
    m_h = 15 - ei_bit
    w = m_h + 48 if frac_bits == _F64_FRAC else m_h + 16
    h = head.astype(xp.uint32)
    sign = (h >> 15) & 0x1
    exp_idx = (h >> m_h) & ((1 << ei_bit) - 1)
    m_head = (h & ((1 << m_h) - 1)).astype(xp.uint64 if xp is np else xp.uint32)

    if tag == 1:
        mant = m_head
        bits_used = m_h
    elif tag == 2:
        mant = (m_head.astype(xp.uint64) << 16) | tail1.astype(xp.uint64)
        bits_used = m_h + 16
    elif tag == 3:
        mant = (
            (m_head.astype(xp.uint64) << 48)
            | (tail1.astype(xp.uint64) << 32)
            | tail2.astype(xp.uint64)
        )
        bits_used = w
    else:
        raise ValueError(f"tag must be 1, 2 or 3, got {tag}")

    e_sh = table[exp_idx].astype(xp.int64 if xp is np else xp.int32) - (
        _F64_BIAS if frac_bits == _F64_FRAC else _F32_BIAS
    )
    pow_ = e_sh - bits_used
    sgn = 1.0 - 2.0 * sign.astype(xp.float64 if xp is np else xp.float32)
    return sgn, mant, pow_


def decode(packed: GSEPacked, tag: int = 3) -> np.ndarray:
    """Numpy reference decode to float64. tag selects precision (1/2/3)."""
    if packed.frac_bits != _F64_FRAC and tag == 3:
        raise ValueError(
            "f32-source packs (frac_bits=23) store no tail2; tags 1 and 2 only"
        )
    table = np.asarray(packed.table)
    sgn, mant, pow_ = _decode_parts(
        table,
        np.asarray(packed.head),
        np.asarray(packed.tail1),
        np.asarray(packed.tail2),
        packed.ei_bit,
        packed.frac_bits,
        tag,
        np,
    )
    return sgn * np.ldexp(mant.astype(np.float64), pow_.astype(np.int64))


def _pow2_exact(n: jnp.ndarray, dtype) -> jnp.ndarray:
    """Exact 2**n for integer n, via exponent-field construction.

    Exponents below the normal range clip to 0 (underflow-to-zero), above it
    to the max finite binade (saturate) -- both only reachable when decoding
    far outside the target dtype's range.
    """
    if dtype in (jnp.float64, np.float64):
        e = jnp.clip(n.astype(jnp.int64) + _F64_BIAS, 0, 2046)
        return jax.lax.bitcast_convert_type(
            (e << _F64_FRAC).astype(jnp.uint64), jnp.float64
        )
    e = jnp.clip(n.astype(jnp.int32) + _F32_BIAS, 0, 254)
    f = jax.lax.bitcast_convert_type((e << _F32_FRAC).astype(jnp.uint32), jnp.float32)
    return f.astype(dtype)


@partial(jax.jit, static_argnames=("ei_bit", "frac_bits", "tag", "dtype"))
def _decode_jnp(table, head, tail1, tail2, ei_bit, frac_bits, tag, dtype):
    m_h = 15 - ei_bit
    w = m_h + 48 if frac_bits == _F64_FRAC else m_h + 16
    h = head.astype(jnp.uint32)
    sign = (h >> 15) & 0x1
    exp_idx = (h >> m_h) & ((1 << ei_bit) - 1)
    m_head = h & ((1 << m_h) - 1)

    if tag == 1:
        mant = m_head.astype(dtype)  # <= 15 bits: exact in f32
        bits_used = m_h
    elif tag == 2:
        # <= 31 bits.  f32 rounds (24-bit significand); f64 exact.
        mant = m_head.astype(dtype) * jnp.asarray(65536.0, dtype) + tail1.astype(
            dtype
        )
        bits_used = m_h + 16
    else:
        mant = (
            m_head.astype(dtype) * jnp.asarray(2.0**48, dtype)
            + tail1.astype(dtype) * jnp.asarray(2.0**32, dtype)
            + tail2.astype(dtype)
        )
        bits_used = w

    e_sh = table[exp_idx].astype(jnp.int32) - (
        _F64_BIAS if frac_bits == _F64_FRAC else _F32_BIAS
    )
    pow_ = e_sh - bits_used
    # Exact power-of-two scales via exponent-field bitcast (XLA's exp2 is
    # exp(x*ln2) and NOT correctly rounded).  Two factors so intermediate
    # scales can't overflow; clipping gives IEEE-ish under/overflow.
    half = pow_ // 2
    sgn = 1.0 - 2.0 * sign.astype(dtype)
    # Fold mant in before the second factor: scale1*scale2 alone can be
    # subnormal (flushed to 0 on some backends) even when the final value
    # is normal.
    return sgn * ((mant * _pow2_exact(half, dtype)) * _pow2_exact(pow_ - half, dtype))


def decode_jnp(packed: GSEPacked, tag: int = 3, dtype=jnp.float32) -> jnp.ndarray:
    """Jittable decode: int->float convert + scale (no bit scan; DESIGN §2)."""
    if packed.frac_bits != _F64_FRAC and tag == 3:
        raise ValueError(
            "f32-source packs (frac_bits=23) store no tail2; tags 1 and 2 only"
        )
    return _decode_jnp(
        packed.table,
        packed.head,
        packed.tail1,
        packed.tail2,
        packed.ei_bit,
        packed.frac_bits,
        tag,
        dtype,
    )


# ---------------------------------------------------------------------------
# f32-source jittable pack (gradient compression / on-device quantization)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k",))
def extract_shared_exponents_jnp(vals: jnp.ndarray, k: int) -> jnp.ndarray:
    """Jittable top-k exponent extraction for f32 tensors (biased+1 table)."""
    bits = jax.lax.bitcast_convert_type(vals.astype(jnp.float32), jnp.uint32)
    e_b = ((bits >> _F32_FRAC) & 0xFF).astype(jnp.int32)
    frac = bits & ((1 << _F32_FRAC) - 1)
    nonzero = (e_b != 0) | (frac != 0)
    e_eff = jnp.where(e_b != 0, e_b, 1)
    counts = jnp.zeros((256,), jnp.int32).at[e_eff.ravel()].add(
        nonzero.ravel().astype(jnp.int32)
    )
    top_counts, top = jax.lax.top_k(counts, k - 1)
    e_max = jnp.max(jnp.where(nonzero, e_eff, 0))
    e_max = jnp.maximum(e_max, 1)
    # Zero-count bins only win the top-k when the data has fewer than k-1
    # distinct exponents; their bin indices are arbitrary table entries.
    # The numpy reference (``extract_shared_exponents``) filters
    # ``counts[e] > 0`` and pads with the max entry -- mirror that so the
    # two tables agree on few-exponent inputs.
    top = jnp.where(top_counts > 0, top, e_max)
    table = jnp.concatenate([top.astype(jnp.int32), e_max[None].astype(jnp.int32)])
    # Deduplicate-against-max not required: duplicates are harmless.
    table = jnp.sort(table + 1)[::-1]
    return table


@partial(jax.jit, static_argnames=("k",))
def pack32_jnp(vals: jnp.ndarray, table: jnp.ndarray, k: int):
    """Jittable f32 -> (head u16, tail1 u16) pack against a (k,) table.

    W = M_H + 16; tag=1 (head) and tag=2 (head+tail1) available; tail2 is
    conceptually zero for f32 sources (24-bit significand < W).
    """
    ei = _ei_bit(k)
    m_h = 15 - ei
    w = m_h + 16
    x = vals.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = (bits >> 31) & 0x1
    e_b = ((bits >> _F32_FRAC) & 0xFF).astype(jnp.int32)
    frac = bits & ((1 << _F32_FRAC) - 1)
    nonzero = (e_b != 0) | (frac != 0)
    m24 = jnp.where(e_b != 0, (1 << _F32_FRAC) | frac, frac).astype(jnp.uint32)
    e_eff = jnp.where(e_b != 0, e_b, 1)

    diff = table.astype(jnp.int32)[None, :] - e_eff.ravel()[:, None]
    diff = jnp.where(diff > 0, diff, jnp.int32(1 << 20))
    exp_idx = jnp.argmin(diff, axis=1).astype(jnp.uint32).reshape(e_eff.shape)
    min_diff = jnp.min(diff, axis=1).reshape(e_eff.shape)
    overflow = min_diff >= (1 << 20)
    min_diff = jnp.where(overflow, 1, min_diff)

    lsh = w - _F32_FRAC - min_diff
    # m24 << lsh for lsh in [-31, w-24]; emulate signed shift.  The
    # right-shift path rounds to nearest-even on the discarded bits
    # (mirrors ``pack_with_table``); carries past W saturate.
    rsh = jnp.clip(-lsh, 0, 31).astype(jnp.uint32)
    floor_ = m24 >> rsh
    rem = m24 & ((jnp.uint32(1) << rsh) - 1)
    half = (jnp.uint32(1) << rsh) >> 1
    round_up = (rsh > 0) & ((rem > half) | ((rem == half) & ((floor_ & 1) == 1)))
    rounded = jnp.minimum(floor_ + round_up.astype(jnp.uint32), (1 << w) - 1)
    m = jnp.where(
        lsh >= 0,
        m24 << jnp.clip(lsh, 0, 31).astype(jnp.uint32),
        rounded,
    )
    m = jnp.where(nonzero, m, 0)
    m = jnp.where(overflow & nonzero, (1 << w) - 1, m)
    max_idx = jnp.argmax(table).astype(jnp.uint32)
    exp_idx = jnp.where(overflow & nonzero, max_idx, exp_idx)

    head = (
        (sign.astype(jnp.uint32) << 15) | (exp_idx << m_h) | (m >> 16)
    ).astype(jnp.uint16)
    tail1 = (m & 0xFFFF).astype(jnp.uint16)
    return head, tail1


def pack32(vals, k: int = 8, table: jnp.ndarray | None = None) -> GSEPacked:
    """f32-source pack into a ``GSEPacked`` container (tags 1/2 only).

    Wraps ``extract_shared_exponents_jnp`` + ``pack32_jnp``.  The mantissa
    width is ``m_h + 16`` -- there is no tail2 segment -- so the container's
    byte model (``nbytes``/``bytes_touched``) and decode reject tag 3,
    consistently with ``decode32_jnp``.
    """
    x = jnp.asarray(vals, jnp.float32)
    if table is None:
        table = extract_shared_exponents_jnp(x, k)
    head, tail1 = pack32_jnp(x, table, k)
    # tail2 does not exist for f32 sources; a zero-length leaf keeps the
    # pytree structure without allocating a dead full-shape array (the
    # tag-1/-2 decode branches never reference it).
    return GSEPacked(
        table=table,
        head=head,
        tail1=tail1,
        tail2=jnp.zeros((0,), jnp.uint32),
        ei_bit=_ei_bit(k),
        frac_bits=_F32_FRAC,
    )


@partial(jax.jit, static_argnames=("k", "tag", "dtype"))
def decode32_jnp(table, head, tail1, k: int, tag: int = 1, dtype=jnp.float32):
    """Jittable decode of an f32-source pack (tags 1 and 2)."""
    ei = _ei_bit(k)
    zeros = jnp.zeros(head.shape, jnp.uint32)
    if tag not in (1, 2):
        raise ValueError("f32-source packs support tags 1 and 2 only")
    return _decode_jnp(table, head, tail1, zeros, ei, _F32_FRAC, tag, dtype)


# ---------------------------------------------------------------------------
# Fake-quant (straight-through) for stepped-precision training
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def gse_fake_quant(x: jnp.ndarray, k: int = 8, tag: int = 1) -> jnp.ndarray:
    """decode(pack(x)) with identity gradient (straight-through estimator)."""
    table = extract_shared_exponents_jnp(x, k)
    head, tail1 = pack32_jnp(x, table, k)
    return decode32_jnp(table, head, tail1, k, tag, jnp.float32).astype(x.dtype)


def _fq_fwd(x, k, tag):
    return gse_fake_quant(x, k, tag), None


def _fq_bwd(k, tag, res, g):
    return (g,)


gse_fake_quant.defvjp(_fq_fwd, _fq_bwd)


# ---------------------------------------------------------------------------
# Numeric-distribution statistics (paper Fig. 1)
# ---------------------------------------------------------------------------

def exponent_stats(vals: np.ndarray, top_ks=(1, 2, 4, 8, 16, 32, 64)) -> dict:
    """Entropy of values / exponents / mantissas + top-k exponent coverage."""
    v = np.asarray(vals, np.float64).ravel()
    v = v[v != 0]
    bits = v.view(np.uint64)
    e_b = ((bits >> np.uint64(_F64_FRAC)) & np.uint64(0x7FF)).astype(np.int64)
    frac = (bits & ((np.uint64(1) << np.uint64(52)) - np.uint64(1))).astype(np.uint64)

    def entropy(arr):
        _, counts = np.unique(arr, return_counts=True)
        p = counts / counts.sum()
        return float(-(p * np.log2(p)).sum())

    counts = np.bincount(e_b, minlength=2048).astype(np.float64)
    order = np.sort(counts)[::-1]
    total = counts.sum()
    cover = {f"top{k}": float(order[:k].sum() / total) for k in top_ks}
    return {
        "entropy_value": entropy(v),
        "entropy_exponent": entropy(e_b),
        "entropy_mantissa": entropy(frac >> np.uint64(32)),  # top 20 bits
        "num_exponents": int((counts > 0).sum()),
        **cover,
    }
