"""Single source of truth for per-tag and per-dtype byte constants.

Before PR 7 these numbers were re-declared (and re-documented) in three
places -- ``sparse/csr.py`` (``_SLOT_BYTES``), ``distributed/partition.py``
(``WIRE_ENTRY_BYTES``), and ``launch/hlo.py`` (``_DTYPE_BYTES``) -- plus
the 6/8/12 B/nnz literals scattered through docstrings.  They all derive
from one fact about the GSE-SEM encoding (paper Section III.C):

  tag 1 streams the u16 head            -> 2 value bytes / entry
  tag 2 streams head + u16 tail1        -> 4 value bytes / entry
  tag 3 streams head + tail1 + u32 tail2-> 8 value bytes / entry

and every CSR/ELL/SELL entry additionally streams a packed u32 column
index (``COLIDX_BYTES``), giving the paper's 6/8/12 B/nnz matrix-stream
figures (``SLOT_BYTES``).  The halo wire ships only the *value* segments
(the receiving shard already knows which boundary entries it asked for),
so ``WIRE_ENTRY_BYTES == TAG_VALUE_BYTES``.

The old names remain importable from their original modules as aliases of
these tables; ``tests/test_precision_table.py`` pins the derived
``bytes_touched`` figures so a drift here cannot pass silently.
"""
from __future__ import annotations

__all__ = [
    "TAG_VALUE_BYTES",
    "COLIDX_BYTES",
    "SLOT_BYTES",
    "WIRE_ENTRY_BYTES",
    "DTYPE_BYTES",
    "TAGS",
]

# GSE tags in escalation order (head-only -> +tail1 -> +tail2).
TAGS = (1, 2, 3)

# Value-segment bytes ONE matrix entry (or one wire x-entry) costs at each
# tag: u16 head / +u16 tail1 / +u32 tail2.
TAG_VALUE_BYTES = {1: 2, 2: 4, 3: 8}

# Every stored entry also streams one packed u32 column index (expIdx in
# the top EI_BIT bits, column in the rest).
COLIDX_BYTES = 4

# Matrix-stream bytes one padded slot (or one nnz) costs at each tag:
# the paper's 6/8/12 B/nnz format promise (DESIGN.md section 8).
SLOT_BYTES = {t: TAG_VALUE_BYTES[t] + COLIDX_BYTES for t in TAGS}

# Bytes ONE boundary x-entry costs on the halo wire at each tag
# (DESIGN.md section 13): the wire ships value segments only.
WIRE_ENTRY_BYTES = dict(TAG_VALUE_BYTES)

# HLO shape-string dtype widths for the launch/hlo.py byte estimator.
DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
