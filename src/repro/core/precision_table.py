"""Single source of truth for per-tag and per-dtype byte constants.

Before PR 7 these numbers were re-declared (and re-documented) in three
places -- ``sparse/csr.py`` (``_SLOT_BYTES``), ``distributed/partition.py``
(``WIRE_ENTRY_BYTES``), and ``launch/hlo.py`` (``_DTYPE_BYTES``) -- plus
the 6/8/12 B/nnz literals scattered through docstrings.  They all derive
from one fact about the GSE-SEM encoding (paper Section III.C):

  tag 1 streams the u16 head            -> 2 value bytes / entry
  tag 2 streams head + u16 tail1        -> 4 value bytes / entry
  tag 3 streams head + tail1 + u32 tail2-> 8 value bytes / entry

and every CSR/ELL/SELL entry additionally streams a packed u32 column
index (``COLIDX_BYTES``), giving the paper's 6/8/12 B/nnz matrix-stream
figures (``SLOT_BYTES``).  The halo wire ships only the *value* segments
(the receiving shard already knows which boundary entries it asked for),
so ``WIRE_ENTRY_BYTES == TAG_VALUE_BYTES``.

The old names remain importable from their original modules as aliases of
these tables; ``tests/test_precision_table.py`` pins the derived
``bytes_touched`` figures so a drift here cannot pass silently.
"""
from __future__ import annotations

__all__ = [
    "TAG_VALUE_BYTES",
    "COLIDX_BYTES",
    "SLOT_BYTES",
    "WIRE_ENTRY_BYTES",
    "DTYPE_BYTES",
    "TAGS",
    "TAG_SEGMENTS",
    "SEGMENT_BYTES",
    "TAG_BITS_USED",
    "tag_operand_names",
]

# GSE tags in escalation order (head-only -> +tail1 -> +tail2).
TAGS = (1, 2, 3)

# Segment-array bytes per entry: u16 head, u16 tail1, u32 tail2.
SEGMENT_BYTES = {"head": 2, "tail1": 2, "tail2": 4}

# The tail segment arrays each tag streams BEYOND the always-read head.
# This is the one table the tag-specialized kernel operand lists, the
# SELL bucket tuples, and the byte models all derive from; before PR 10
# it was re-declared inline in gse_spmv.py, gse_spmm.py, and perf/ledger.
TAG_SEGMENTS = {1: (), 2: ("tail1",), 3: ("tail1", "tail2")}

# Mantissa bits a decode at each tag consumes from the 15-bit head plus
# the 16-bit tail1 / 32-bit tail2 splices: 15 / 31 / 63.  The dense
# GSEPacked path offsets these by the expIdx bits stolen from the head
# (``m_h = 15 - ei_bit``); the sparse path keeps all 15 head bits because
# expIdx rides colpak instead.
TAG_BITS_USED = {t: 15 + sum(8 * SEGMENT_BYTES[s] for s in TAG_SEGMENTS[t])
                 for t in TAGS}

# Value-segment bytes ONE matrix entry (or one wire x-entry) costs at each
# tag -- head + the tails TAG_SEGMENTS says that tag reads: 2 / 4 / 8.
TAG_VALUE_BYTES = {
    t: SEGMENT_BYTES["head"] + sum(SEGMENT_BYTES[s] for s in TAG_SEGMENTS[t])
    for t in TAGS
}


def tag_operand_names(tag: int) -> tuple:
    """The pallas_call operand list the tag-specialized kernels stream."""
    return ("scales", "colpak", "head") + TAG_SEGMENTS[tag] + ("x",)

# Every stored entry also streams one packed u32 column index (expIdx in
# the top EI_BIT bits, column in the rest).
COLIDX_BYTES = 4

# Matrix-stream bytes one padded slot (or one nnz) costs at each tag:
# the paper's 6/8/12 B/nnz format promise (DESIGN.md section 8).
SLOT_BYTES = {t: TAG_VALUE_BYTES[t] + COLIDX_BYTES for t in TAGS}

# Bytes ONE boundary x-entry costs on the halo wire at each tag
# (DESIGN.md section 13): the wire ships value segments only.
WIRE_ENTRY_BYTES = dict(TAG_VALUE_BYTES)

# HLO shape-string dtype widths for the launch/hlo.py byte estimator.
DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
