"""Per-group precision tag maps (PR 10, DESIGN.md §18).

The paper's segmented-mantissa store exists so precision can vary
*without repacking* -- yet until this PR every layer treated the tag as
one global scalar, so a handful of high-sensitivity row groups forced
the whole operator to stream extra tail segments.  :class:`TagMap`
makes the tag axis per-ROW-GROUP: a uint8 tag per contiguous group of
``group_size`` rows (default 8 -- exactly the kernels' sublane row
block, so one (8, 128) grid tile covers one group and a per-group
operand choice is physically realizable per row block).

Representation contract:

* **Uniform fast path.** ``TagMap.uniform(t, ...)`` normalizes to the
  plain ``int`` tag via :func:`normalize_tags`, so every solver/kernel
  call compiles to today's EXACT jaxpr -- bit-identical to the pre-PR
  ``tag=int`` API (asserted in tests/test_tagmap.py).
* **Masked-segment equivalence.** A non-uniform map is applied by
  zeroing the tail segments below each entry's induced tag -- the MAX
  of its row's and its column's group tags, so a masked SPD operand
  stays exactly symmetric (``kernels.ops.masked_for_tagmap``) -- and
  decoding at the map's MAX tag.  This is bitwise identical to a per-entry lower-tag decode:
  each partial mantissa (<= 53 significant bits) is exact in f64 and
  the scales are exact powers of two, so
  ``m_head * 2^48 * 2^(e_sh - 63) == m_head * 2^(e_sh - 15)`` exactly
  (tag-1 entry through the tag-3 formula).  No new kernel bodies, no
  repacking -- the masked arrays ride the existing tag-specialized
  pipelines.
* **SELL width-buckets are the coarse kernel unit.** The SELL path
  dispatches one ``pallas_call`` per bucket at the bucket's MAX group
  tag, so per-bucket operand lists stay static and all-tag-1 buckets
  genuinely never stream tails (DESIGN.md §18).

The map's :attr:`crc32` keys every derived cache entry (packed-operand
cache, tuned-plan resolution) so a promoted map can never hit a stale
pack or plan.
"""
from __future__ import annotations

import zlib

import numpy as np

__all__ = ["TagMap", "normalize_tags", "GROUP_SIZE"]

# Rows per tag group.  Matches the kernels' default sublane row block
# (perf.plan.DEFAULT_BLOCKS[0]): one (8, 128) grid tile == one group.
GROUP_SIZE = 8


class TagMap:
    """Per-row-group precision tags: ``tags[g]`` governs rows
    ``[g*group_size, (g+1)*group_size)``.

    Immutable by convention (promotion returns a NEW map so cache keys
    derived from :attr:`crc32` stay valid); tags are 1/2/3, the GSE
    escalation ladder.
    """

    __slots__ = ("tags", "group_size", "_crc")

    def __init__(self, tags, group_size: int = GROUP_SIZE):
        tags = np.ascontiguousarray(np.asarray(tags, np.uint8))
        if tags.ndim != 1 or tags.size == 0:
            raise ValueError(f"tags must be a non-empty 1-D array, "
                             f"got shape {tags.shape}")
        if group_size < 1:
            raise ValueError(f"group_size must be >= 1, got {group_size}")
        bad = (tags < 1) | (tags > 3)
        if bad.any():
            raise ValueError(
                f"tags must be in {{1, 2, 3}}; offending groups "
                f"{np.nonzero(bad)[0][:8].tolist()}"
            )
        tags.setflags(write=False)
        object.__setattr__(self, "tags", tags)
        object.__setattr__(self, "group_size", int(group_size))
        object.__setattr__(self, "_crc", None)

    def __setattr__(self, name, value):  # immutability guard
        raise AttributeError("TagMap is immutable; build a new map "
                             "(with_tags / promoted)")

    # -- constructors ------------------------------------------------------

    @classmethod
    def uniform(cls, tag: int, n_groups: int,
                group_size: int = GROUP_SIZE) -> "TagMap":
        return cls(np.full(n_groups, tag, np.uint8), group_size)

    @classmethod
    def for_rows(cls, m: int, tag: int = 1,
                 group_size: int = GROUP_SIZE) -> "TagMap":
        """Uniform map covering ``m`` rows (``ceil(m/group_size)`` groups)."""
        return cls.uniform(tag, -(-m // group_size), group_size)

    # -- identity ----------------------------------------------------------

    @property
    def n_groups(self) -> int:
        return int(self.tags.size)

    @property
    def is_uniform(self) -> bool:
        return bool((self.tags == self.tags[0]).all())

    @property
    def min_tag(self) -> int:
        return int(self.tags.min())

    @property
    def max_tag(self) -> int:
        return int(self.tags.max())

    @property
    def crc32(self) -> int:
        """CRC32 of the tag bytes + group size: the cache-key token every
        derived artifact (masked pack, tuned plan) is keyed under."""
        if self._crc is None:
            ck = zlib.crc32(self.tags.tobytes(),
                            zlib.crc32(np.int64(self.group_size).tobytes()))
            object.__setattr__(self, "_crc", ck)
        return self._crc

    def __eq__(self, other):
        return (isinstance(other, TagMap)
                and self.group_size == other.group_size
                and np.array_equal(self.tags, other.tags))

    def __hash__(self):
        return hash((self.group_size, self.tags.tobytes()))

    def __repr__(self):
        counts = {int(t): int(n) for t, n in
                  zip(*np.unique(self.tags, return_counts=True))}
        return (f"TagMap(n_groups={self.n_groups}, "
                f"group_size={self.group_size}, counts={counts}, "
                f"crc=0x{self.crc32:08x})")

    # -- lookups -----------------------------------------------------------

    def row_tags(self, m: int) -> np.ndarray:
        """(m,) uint8 per-row tags (rows beyond the map keep the last
        group's tag so padded rows never index out of range)."""
        g = np.minimum(np.arange(m, dtype=np.int64) // self.group_size,
                       self.n_groups - 1)
        return self.tags[g]

    def entry_tags(self, row_ids, cols=None) -> np.ndarray:
        """(nnz,) uint8 per-entry tags from CSR-order row ids.

        With ``cols`` the induced tag is SYMMETRIC: the max of the row's
        and the column's group tags.  A row-only induced tag perturbs
        entry (i, j) differently from (j, i), so the masked operand of an
        SPD matrix would silently lose symmetry and CG's convergence
        contract with it; the symmetric max keeps ``A~ = A~^T`` exactly
        (and matches the physics -- by symmetry the large entries of a
        promoted row sit in its column too).  Matrix paths MUST pass
        ``cols``; the row-only form is for row-indexed streams (halo
        vector entries, the ELL row-block model).
        """
        g = np.minimum(np.asarray(row_ids, np.int64) // self.group_size,
                       self.n_groups - 1)
        et = self.tags[g]
        if cols is not None:
            gc = np.minimum(np.asarray(cols, np.int64) // self.group_size,
                            self.n_groups - 1)
            et = np.maximum(et, self.tags[gc])
        return et

    def tag_counts(self) -> dict:
        """``{tag: n_groups_at_tag}`` over the full ladder."""
        return {t: int((self.tags == t).sum()) for t in (1, 2, 3)}

    # -- derivation --------------------------------------------------------

    def with_tags(self, group_idx, tag) -> "TagMap":
        """New map with ``tags[group_idx] = tag`` (scalar or per-index)."""
        tags = self.tags.copy()
        tags[np.asarray(group_idx, np.int64)] = tag
        return TagMap(tags, self.group_size)

    def promoted(self, group_idx, step: int = 1) -> "TagMap":
        """New map with the given groups stepped up ``step`` rungs
        (clipped at tag 3 -- the exact path is the final rung)."""
        idx = np.asarray(group_idx, np.int64)
        tags = self.tags.copy()
        tags[idx] = np.minimum(tags[idx] + step, 3).astype(np.uint8)
        return TagMap(tags, self.group_size)

    def floored(self, floor: int) -> "TagMap":
        """New map with every group raised to AT LEAST ``floor`` -- the
        per-group recovery ladder's rung (only sub-floor groups promote;
        floor 3 is the uniform exact path).  Returns ``self`` when no
        group is below the floor (cache keys stay stable)."""
        if floor <= self.min_tag:
            return self
        return TagMap(np.maximum(self.tags, min(int(floor), 3)),
                      self.group_size)


def normalize_tags(tags, m: int | None = None,
                   group_size: int = GROUP_SIZE):
    """Normalize the public ``tags=`` axis to what the pipelines dispatch on.

    * ``None``          -> ``None`` (caller keeps its legacy ``init_tag``);
    * ``int`` 1/2/3     -> the same int (legacy fast path, today's jaxpr);
    * uniform ``TagMap``-> its plain int tag (SAME jaxpr -- the uniform
      fast path the bit-identity acceptance criterion pins);
    * non-uniform map   -> the ``TagMap`` itself (masked-operand path).

    ``m`` (row count) lets a bare int be requested as a map via
    ``TagMap.for_rows`` upstream; it is unused for the cases above but
    validates a map's coverage when provided.
    """
    if tags is None:
        return None
    if isinstance(tags, (int, np.integer)):
        t = int(tags)
        if t not in (1, 2, 3):
            raise ValueError(f"tag must be 1, 2 or 3, got {t}")
        return t
    if isinstance(tags, TagMap):
        if m is not None:
            need = -(-m // tags.group_size)
            if tags.n_groups != need:
                raise ValueError(
                    f"TagMap covers {tags.n_groups} groups of "
                    f"{tags.group_size} rows but the operator has {m} rows "
                    f"({need} groups)"
                )
        return tags.max_tag if tags.is_uniform else tags
    raise TypeError(f"tags must be an int tag, a TagMap, or None; "
                    f"got {type(tags).__name__}")
