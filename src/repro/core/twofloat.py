"""Two-float (double-word) arithmetic: the TPU surrogate for FP64.

TPU v5e has no FP64 ALUs; the paper's "high-precision final phase" is
realised on-target as unevaluated (hi, lo) f32 pairs with ~49 effective
significand bits, using Dekker/Knuth error-free transformations (no FMA
required -- XLA:TPU has no user-facing scalar FMA).

On CPU the same code runs over f64 pairs (~105 effective bits), which the
tests use to cross-validate against native f64.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = ["two_sum", "split", "two_prod", "df_add", "df_mul", "df_from", "df_to",
           "df_dot"]

_SPLIT_F32 = 4097.0        # 2^12 + 1 (Dekker split for 24-bit significand)
_SPLIT_F64 = 134217729.0   # 2^27 + 1


def two_sum(a, b):
    """Error-free transformation: a + b = s + e exactly (Knuth)."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def split(a):
    """Dekker split of a float into hi + lo with non-overlapping halves."""
    c = jnp.where(jnp.asarray(a).dtype == jnp.float64, _SPLIT_F64, _SPLIT_F32) * a
    hi = c - (c - a)
    return hi, a - hi


def two_prod(a, b):
    """Error-free product: a * b = p + e exactly (Dekker, FMA-free)."""
    p = a * b
    ah, al = split(a)
    bh, bl = split(b)
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


def df_from(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return x, jnp.zeros_like(x)


def df_to(hi, lo):
    return hi + lo


def df_add(ahi, alo, bhi, blo):
    s, e = two_sum(ahi, bhi)
    e = e + (alo + blo)
    hi, lo = two_sum(s, e)
    return hi, lo


def df_mul(ahi, alo, bhi, blo):
    p, e = two_prod(ahi, bhi)
    e = e + (ahi * blo + alo * bhi)
    hi, lo = two_sum(p, e)
    return hi, lo


def df_dot(a: jnp.ndarray, b: jnp.ndarray, axis=-1):
    """Compensated dot product: returns (hi, lo) along ``axis``.

    Equivalent to Ogita-Rump-Oishi Dot2: ~2x working-precision accuracy.
    """
    p, e = two_prod(a, b)
    # Sequential compensated accumulation via pairwise two_sum reduction.
    hi = jnp.sum(p, axis=axis)
    # Error of the naive sum is approximated by summing the local products'
    # errors plus the sum's own compensation (cheap Dot2 variant).
    comp = jnp.sum(e, axis=axis)
    s, e2 = two_sum(hi, comp)
    return s, e2
