"""Core of the paper's contribution: GSE-SEM format + stepped precision."""
from repro.core import gse, precision, twofloat
from repro.core.gse import (
    GSEPacked,
    decode,
    decode_jnp,
    extract_shared_exponents,
    gse_fake_quant,
    pack,
    pack_with_table,
)
from repro.core.precision import MonitorParams, MonitorState

__all__ = [
    "gse",
    "precision",
    "twofloat",
    "GSEPacked",
    "decode",
    "decode_jnp",
    "extract_shared_exponents",
    "gse_fake_quant",
    "pack",
    "pack_with_table",
    "MonitorParams",
    "MonitorState",
]
