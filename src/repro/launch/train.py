"""Production training driver (deliverable: fault-tolerant train loop).

Features (DESIGN.md §5):
  * resume-exact restart: data batches are a pure function of step, the
    loop resumes from the latest intact checkpoint;
  * async double-buffered checkpointing with integrity hashes;
  * optional GSE-SEM gradient compression (error feedback) -- the paper's
    format on the cross-pod wire;
  * straggler/failure simulation hooks (--simulate-failure-at) proving the
    restart path end-to-end in CI;
  * mesh-aware: under --mesh, shards params/batches by the arch's rules
    (on real TPU pods this is the same code path; on this CPU container
    use smoke configs).

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b --smoke \
      --steps 30 --ckpt-dir /tmp/ck --ckpt-every 10
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed.compress import make_error_feedback_transform
from repro.models import stepfns, transformer as T
from repro.optim import AdamW


def build(cfg, steps, lr=3e-4, grad_compress=False):
    opt = AdamW(lr=lr, warmup_steps=max(steps // 20, 1), total_steps=steps)
    params, _ = T.init_params(cfg, jax.random.key(0))
    state = stepfns.TrainState(
        params=params, opt_state=opt.init(params),
        step=jnp.zeros((), jnp.int32),
    )
    transform = None
    ef_state = {"buf": None}
    if grad_compress:
        init_buf, tf = make_error_feedback_transform(k=8, tag=1,
                                                     min_size=4096)
        ef_state["buf"] = init_buf(params)

        def transform(grads):  # noqa: F811 -- closure over ef_state
            g, ef_state["buf"] = tf(grads, ef_state["buf"])
            return g

    step_fn = jax.jit(stepfns.make_train_step(cfg, opt,
                                              grad_transform=transform))
    return state, step_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--simulate-failure-at", type=int, default=-1,
                    help="exit(17) after this step to test restart")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    state, step_fn = build(cfg, args.steps, args.lr, args.grad_compress)

    dcfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0,
        num_prefix_tokens=cfg.num_prefix_tokens if cfg.family == "vlm" else 0,
        enc_len=args.seq if cfg.family == "encdec" else 0,
        d_model=cfg.d_model,
    )
    pipe = TokenPipeline(dcfg)

    start = 0
    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            like = state
            state, start, _ = ckpt.restore(args.ckpt_dir, last, like)
            print(f"resumed from step {start}", flush=True)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = pipe.batch_at(step)
        state, metrics = step_fn(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(
                f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({(time.time()-t0):.1f}s)", flush=True,
            )
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(args.ckpt_dir, state, step + 1)
        if args.simulate_failure_at == step:
            print("simulating node failure", flush=True)
            os._exit(17)
    if args.ckpt_dir:
        ckpt.wait_pending(args.ckpt_dir)
        ckpt.save(args.ckpt_dir, state, args.steps)
    print("done", flush=True)


if __name__ == "__main__":
    main()
