"""Batched serving driver: prefill + decode with optional GSE-SEM weights.

Serves batched requests against a (smoke-scale on CPU) model; ``--gse-tag``
serves linear weights from GSE-SEM segments -- one stored copy, selectable
precision per deployment (the paper's storage/compute decoupling).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --smoke \
      --batch 4 --prompt-len 12 --gen 8 [--gse-tag 2]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import stepfns, transformer as T
from repro.quant import gse_tensor as Q


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--gse-tag", type=int, default=0,
                    help="0: dense bf16; 1/2/3: GSE-SEM serving precision")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    params, _ = T.init_params(cfg, jax.random.key(0))

    if args.gse_tag:
        packed = Q.quantize_tree(params, k=8, min_size=2048)
        params = Q.dequantize_tree(packed, tag=args.gse_tag,
                                   dtype=jnp.bfloat16)
        print(
            f"serving GSE-SEM tag={args.gse_tag}: "
            f"{Q.tree_bytes(packed, args.gse_tag)/1e6:.2f} MB weight stream "
            f"(vs {Q.tree_bytes(packed, 3)/1e6:.2f} MB full)", flush=True,
        )

    rng = jax.random.key(1)
    prompts = jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    total = args.prompt_len + args.gen
    state = T.decode_state_init(cfg, args.batch, max_len=total)
    serve_step = jax.jit(stepfns.make_serve_step(cfg))

    t0 = time.time()
    # teacher-forced prefill via the decode path (batched requests)
    tok = prompts[:, 0]
    for pos in range(total - 1):
        nxt, state = serve_step(params, state, tok,
                                jnp.asarray(pos, jnp.int32))
        tok = prompts[:, pos + 1] if pos + 1 < args.prompt_len else nxt
        if pos >= args.prompt_len - 1:
            print(f"pos {pos:4d} -> tokens {nxt.tolist()}", flush=True)
    dt = time.time() - t0
    print(
        f"served {args.batch} requests x {args.gen} new tokens in {dt:.2f}s "
        f"({args.batch*args.gen/dt:.1f} tok/s)", flush=True,
    )


if __name__ == "__main__":
    main()
