import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

Lowers + compiles every (architecture x input-shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), prints memory_analysis() and
cost_analysis(), extracts collective wire bytes from the partitioned HLO,
and caches per-cell roofline records in dryrun_results/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_4b \
      --shape train_4k [--multi-pod] [--all] [--force]
"""

import argparse
import json
import time
import traceback
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro import configs
from repro.distributed import sharding as SH
from repro.launch import hlo as H
from repro.launch import shapes as SHP
from repro.launch.mesh import HW, make_production_mesh
from repro.models import stepfns
from repro.models import transformer as T
from repro.optim import AdamW

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results")


def _fit_pspec(ps: PartitionSpec, shape, mesh) -> PartitionSpec:
    """Drop mesh axes that don't divide the corresponding dim.

    jit in_shardings require exact divisibility (unlike internal
    with_sharding_constraint, which pads); batch=1 decode shapes and odd
    dims (e.g. grok's 8 experts on the 16-way axis) fall back toward
    replication on that dim.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(tuple(ps)):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        # progressively drop trailing axes until the product divides
        while axes:
            prod = 1
            for a in axes:
                prod *= sizes[a]
            if shape[i] % prod == 0:
                break
            axes = axes[:-1]
        out.append(None if not axes else
                   (axes[0] if len(axes) == 1 else axes))
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def _ns(mesh, spec_tree, rules, shapes_tree=None):
    pspecs = SH.specs_to_pspecs(spec_tree, rules)
    if shapes_tree is None:
        return jax.tree.map(
            lambda ps: NamedSharding(mesh, ps), pspecs,
            is_leaf=lambda x: isinstance(x, PartitionSpec),
        )
    return jax.tree.map(
        lambda ps, shp: NamedSharding(
            mesh, _fit_pspec(ps, shp.shape, mesh)
        ),
        pspecs, shapes_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def _param_count(shapes_tree) -> int:
    return sum(
        int(jnp.prod(jnp.asarray(l.shape)))
        for l in jax.tree.leaves(shapes_tree)
    )


def _active_param_count(cfg, shapes_tree) -> float:
    """MoE: experts contribute k/E of their params to the active count."""
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes_tree)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
        if cfg.num_experts and ("w_gate" in keys or "w_up" in keys
                                or "w_down" in keys) and "moe" in keys:
            n = n * cfg.experts_per_token / cfg.num_experts
        total += n
    return total


def model_flops(cfg, shape_spec, n_active: float) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode) -- embedding and
    attention-quadratic terms excluded by convention (noted in report)."""
    b, s = shape_spec["global_batch"], shape_spec["seq_len"]
    kind = shape_spec["kind"]
    if kind == "train":
        return 6.0 * n_active * b * s
    if kind == "prefill":
        return 2.0 * n_active * b * s
    return 2.0 * n_active * b  # decode: one token per request


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules_override: Dict = None, tag: str = "baseline",
               cfg_overrides: Dict = None) -> Dict:
    import dataclasses

    cfg = configs.get_config(arch)
    if cfg_overrides:
        ov = dict(cfg_overrides)
        if "compute_dtype" in ov:
            ov["compute_dtype"] = getattr(jnp, ov["compute_dtype"])
        if "param_dtype" in ov:
            ov["param_dtype"] = getattr(jnp, ov["param_dtype"])
        cfg = dataclasses.replace(cfg, **ov)
    shape_spec = SHP.SHAPES[shape_name]
    ok, why = SHP.applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = configs.get_rules(arch)
    if rules_override:
        rules.update(rules_override)
    n_chips = 512 if multi_pod else 256

    kind = shape_spec["kind"]
    key = jax.random.key(0)
    t0 = time.time()

    with SH.axis_rules(rules, mesh):
        # --- shapes (no allocation; specs are static -> side channel) ---
        captured = {}

        def _init_only_params(k):
            p, s = T.init_params(cfg, k)
            captured["specs"] = s
            return p

        pshapes = jax.eval_shape(_init_only_params, key)
        pspecs_tree = captured["specs"]
        params_sh = _ns(mesh, pspecs_tree, rules, pshapes)
        batch_spec = SHP.input_specs(cfg, shape_name)
        batch_sh = _ns(mesh, SHP.batch_logical_axes(batch_spec), rules,
                       batch_spec)

        if kind == "train":
            opt = AdamW(total_steps=10000)
            state_shapes = stepfns.TrainState(
                params=pshapes,
                opt_state=jax.eval_shape(opt.init, pshapes),
                step=jax.ShapeDtypeStruct((), jnp.int32),
            )
            opt_sh = type(jax.eval_shape(opt.init, pshapes))(
                mu=params_sh, nu=params_sh
            )
            state_sh = stepfns.TrainState(
                params=params_sh, opt_state=opt_sh,
                step=NamedSharding(mesh, PartitionSpec()),
            )
            step_fn = stepfns.make_train_step(cfg, opt)
            with mesh:
                lowered = jax.jit(
                    step_fn,
                    in_shardings=(state_sh, batch_sh),
                    donate_argnums=(0,),
                ).lower(state_shapes, batch_spec)
        elif kind == "prefill":
            prefill = stepfns.make_prefill_step(cfg)

            def pf(params, batch):
                return prefill(params, batch["tokens"],
                               prefix_embeds=batch.get("prefix_embeds"),
                               enc_embeds=batch.get("enc_embeds"))

            with mesh:
                lowered = jax.jit(
                    pf, in_shardings=(params_sh, batch_sh)
                ).lower(pshapes, batch_spec)
        else:  # decode
            s = shape_spec["seq_len"]
            b = shape_spec["global_batch"]
            dstate_shapes = jax.eval_shape(
                lambda: T.decode_state_init(cfg, b, s)
            )
            dstate_sh = _ns(mesh, T.decode_state_specs(cfg), rules,
                            dstate_shapes)
            serve = stepfns.make_serve_step(cfg)
            inp = SHP.input_specs(cfg, shape_name)

            if cfg.family == "encdec":
                def sv(params, state, tokens, pos, enc_out):
                    return serve(params, state, tokens, pos, enc_out)
                args = (pshapes, dstate_shapes, inp["tokens"], inp["pos"],
                        inp["enc_out"])
                shard_args = (params_sh, dstate_sh, batch_sh["tokens"],
                              NamedSharding(mesh, PartitionSpec()),
                              batch_sh["enc_out"])
            else:
                def sv(params, state, tokens, pos):
                    return serve(params, state, tokens, pos)
                args = (pshapes, dstate_shapes, inp["tokens"], inp["pos"])
                shard_args = (params_sh, dstate_sh, batch_sh["tokens"],
                              NamedSharding(mesh, PartitionSpec()))
            with mesh:
                lowered = jax.jit(
                    sv, in_shardings=shard_args, donate_argnums=(1,)
                ).lower(*args)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    # While-aware analysis (XLA cost_analysis counts scan bodies once).
    ana = H.analyze(text)
    flops_dev = ana["flops"]
    bytes_dev = ana["bytes"]
    coll_total = ana["coll_bytes"]
    coll_by_kind = ana["coll_by_kind"]
    coll_counts = ana["coll_counts"]

    n_active = _active_param_count(cfg, pshapes)
    n_total = _param_count(pshapes)
    mf = model_flops(cfg, shape_spec, n_active)
    terms = H.roofline_terms(flops_dev, bytes_dev, coll_total, HW)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "tag": tag,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "skipped": False,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "params_total": n_total,
        "params_active": n_active,
        "model_flops_global": mf,
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll_total,
        "collective_by_kind": coll_by_kind,
        "collective_counts": coll_counts,
        "model_over_hlo_flops": (
            mf / (flops_dev * n_chips) if flops_dev else 0.0
        ),
        "xla_cost_analysis_flops_per_dev": float(cost.get("flops", 0.0)),
        "xla_cost_analysis_bytes_per_dev": float(
            cost.get("bytes accessed", 0.0)),
        "top_dots": [[f, s[:120]] for f, s in ana["top_dots"][:8]],
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        **terms,
    }
    return rec


def cell_path(arch, shape, multi_pod, tag="baseline"):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mp = "mp" if multi_pod else "sp"
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mp}__{tag}.json")


def run_cell(arch, shape, multi_pod, force=False, tag="baseline",
             rules_override=None, cfg_overrides=None) -> Dict:
    path = cell_path(arch, shape, multi_pod, tag)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    try:
        rec = lower_cell(arch, shape, multi_pod, rules_override, tag,
                         cfg_overrides)
    except Exception as e:  # record failures for debugging, don't hide them
        rec = {
            "arch": arch, "shape": shape, "tag": tag,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-3000:],
        }
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--cfg-override", default=None,
                    help='JSON dict of ModelConfig overrides, e.g. '
                         '{"moe_dispatch": "grouped"}')
    ap.add_argument("--rules-override", default=None,
                    help="JSON dict of logical->mesh rule overrides")
    args = ap.parse_args()
    cfg_ov = json.loads(args.cfg_override) if args.cfg_override else None
    rules_ov = json.loads(args.rules_override) if args.rules_override else None

    if args.all:
        todo = []
        for arch, shape, ok, why in SHP.cells():
            meshes = [False, True] if args.both_meshes else [args.multi_pod]
            for mp in meshes:
                todo.append((arch, shape, mp))
    else:
        assert args.arch and args.shape
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        todo = [(args.arch, args.shape, mp) for mp in meshes]

    for arch, shape, mp in todo:
        t0 = time.time()
        rec = run_cell(arch, shape, mp, force=args.force, tag=args.tag,
                       rules_override=rules_ov, cfg_overrides=cfg_ov)
        status = (
            "SKIP" if rec.get("skipped")
            else ("ERR " if "error" in rec else "OK  ")
        )
        extra = rec.get("reason") or rec.get("error") or (
            f"comp={rec.get('t_compute_s', 0):.4f}s "
            f"mem={rec.get('t_memory_s', 0):.4f}s "
            f"coll={rec.get('t_collective_s', 0):.4f}s "
            f"bottleneck={rec.get('bottleneck')}"
        )
        print(f"{status} {arch:24s} {shape:12s} "
              f"{'2x16x16' if mp else '16x16':8s} "
              f"[{time.time()-t0:6.1f}s] {extra}", flush=True)


if __name__ == "__main__":
    main()
